//! Content-addressed design cache: fingerprint the input graph plus the
//! compile/partitioner configuration, persist the compiled artifacts, and
//! answer repeat opens with a hash lookup instead of a rebuild.
//!
//! The expensive parts of `open` are (a) the graph passes + lowering +
//! OIM construction ([`compile_design`]) and (b) the multilevel min-cut
//! search inside [`partition_ir`]. Both depend only on the input graph
//! and the `(fuse, partitioner, parts)` configuration, so their outputs
//! are cached under a 128-bit content key:
//!
//! * **memory hit** — an `Arc` clone out of the LRU front;
//! * **disk hit** — JSON loads of the OIM / IR sidecar / group
//!   dependency graph plus a [`FixedOwners`] replay of the stored
//!   ownership map (cheap cone walks, no min-cut search);
//! * **miss** — full compile + partition, then persist for next time.
//!
//! A third answer sits between "hit" and "miss":
//! [`DesignCache::open_design_incremental`] treats an exact-key miss
//! whose design *family* (same graph name and configuration, different
//! content) is already cached as a **near-miss**: it diffs the stored
//! per-register cone hashes ([`crate::graph::cone`]) against the
//! requested graph, rebuilds only the changed cones
//! ([`crate::coordinator::incremental::delta_compile`]), warm-starts the
//! partitioner from the donor's ownership, and commits the spliced
//! artifacts under the new key — a small fraction of a cold compile for
//! a single-module edit.
//!
//! See the module docs of [`crate::service`] for the on-disk layout.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::activity::GroupDepGraph;
use crate::coordinator::compile::{compile_design, CompileOpts};
use crate::coordinator::incremental::delta_compile;
use crate::designs::Design;
use crate::graph::cone::{cone_hashes, ConeHashes};
use crate::graph::ops::mask;
use crate::graph::Graph;
use crate::partition::{
    partition_ir, partition_ir_with, warm_partition, FixedOwners, PartitionerKind, Partitioning,
};
use crate::tensor::ir::LayerIr;
use crate::tensor::oim::Oim;
use crate::util::fnv::Fnv2;
use crate::util::json::{arr_str, arr_u32, arr_u64, obj, parse, Json};

/// Bumped whenever the persisted schema changes; part of the fingerprint,
/// so old entries simply miss instead of mis-parsing. v2 added the graph
/// (family) name and the per-register cone hashes to `meta.json`.
pub const CACHE_FORMAT_VERSION: u64 = 2;

/// Content key for one (input graph, compile, partitioning) combination.
/// Hashes the *un-optimized* input graph — node kinds (with their
/// payloads), argument lists, widths and names (names survive into the
/// cached IR sidecar, so they address content too) — plus the knobs that
/// change the compiled artifacts.
pub fn design_key(graph: &Graph, fuse: bool, partitioner: PartitionerKind, parts: usize) -> String {
    let mut h = Fnv2::new();
    h.word(CACHE_FORMAT_VERSION);
    h.text(&graph.name);
    h.word(graph.nodes.len() as u64);
    for n in &graph.nodes {
        // the Debug form carries the variant and every payload
        // (Const value, port index, Shl/Bits immediates, ...)
        h.text(&format!("{:?}", n.kind));
        h.byte(n.width);
        h.word(n.args.len() as u64);
        for &a in &n.args {
            h.word(a as u64);
        }
        match &n.name {
            Some(s) => h.text(s),
            None => h.byte(0xFF),
        }
    }
    h.word(graph.inputs.len() as u64);
    for p in &graph.inputs {
        h.text(&p.name);
        h.byte(p.width);
        h.word(p.node as u64);
    }
    h.word(graph.outputs.len() as u64);
    for (name, node) in &graph.outputs {
        h.text(name);
        h.word(*node as u64);
    }
    h.word(graph.regs.len() as u64);
    for r in &graph.regs {
        h.text(&r.name);
        h.word(r.node as u64);
        h.word(r.next as u64);
        h.word(r.init);
        h.byte(r.width);
    }
    h.byte(fuse as u8);
    h.text(partitioner.name());
    h.word(parts as u64);
    h.hex()
}

/// One register of the compiled design: the name clients (and
/// `lane_init`) use, the slot id it lives in, and its declared width.
#[derive(Clone, Debug)]
pub struct RegInfo {
    pub name: String,
    pub slot: u32,
    pub width: u8,
}

/// The compiled, partitioned artifacts for one design key — everything a
/// host simulator needs, with no graph pass, OIM build, GDG build or
/// min-cut search left to run.
pub struct CachedDesign {
    pub key: String,
    pub design_name: String,
    /// Name of the input *graph* — the design family. Catalog `_edit`
    /// variants share it with their base design, which is what the
    /// incremental-open donor search keys on.
    pub graph_name: String,
    pub fuse: bool,
    pub parts: usize,
    pub partitioner: PartitionerKind,
    pub ir: LayerIr,
    pub oim: Oim,
    pub gdg: GroupDepGraph,
    /// Final owner per entry of `ir.commits` (see
    /// [`Partitioning::owner_of_reg`]) — replayed through
    /// [`FixedOwners`] on every host build.
    pub owner_of_reg: Vec<usize>,
    /// Register name → slot map of the compiled graph (node ids are slot
    /// ids), for `lane_init` resolution and snapshot labeling.
    pub regs: Vec<RegInfo>,
    /// Per-register cone content hashes of the *un-optimized* input
    /// graph — the invalidation units the incremental open path diffs.
    pub cone: ConeHashes,
    /// Wall time of the original cold compile + partition, as persisted —
    /// the denominator of the warm-open speedup this cache exists for.
    pub cold_compile: Duration,
}

impl CachedDesign {
    /// Rebuild the [`Partitioning`] by replaying the cached ownership map
    /// (cone growth + RUM table only; no search).
    pub fn partitioning(&self) -> Partitioning {
        partition_ir_with(&self.ir, self.parts, &FixedOwners(self.owner_of_reg.clone()))
    }

    /// [`Design::resolved_lane_init`] against the cached register map
    /// (no [`Graph`] needed — disk hits do not carry one).
    pub fn resolved_lane_init(
        &self,
        design: &Design,
        lanes: usize,
    ) -> Result<Vec<(u32, usize, u64)>, String> {
        let mut pokes = Vec::new();
        for (name, values) in &design.lane_init {
            if values.is_empty() {
                return Err(format!("lane_init for '{name}' has no values"));
            }
            let reg = self
                .regs
                .iter()
                .find(|r| r.name == *name)
                .ok_or_else(|| format!("lane_init: no register named '{name}' in {}", self.design_name))?;
            let m = mask(reg.width);
            for l in 0..lanes {
                pokes.push((reg.slot, l, values[l % values.len()] & m));
            }
        }
        Ok(pokes)
    }
}

/// Where an `open` was answered from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpenSource {
    Memory,
    Disk,
    Compiled,
}

impl OpenSource {
    pub fn name(self) -> &'static str {
        match self {
            OpenSource::Memory => "memory",
            OpenSource::Disk => "disk",
            OpenSource::Compiled => "compiled",
        }
    }
}

/// What one `open_design` call did, for the client-visible reply (the CI
/// smoke job asserts `hit` and compares `open_time` against
/// `cold_compile`).
#[derive(Clone, Debug)]
pub struct OpenReport {
    pub key: String,
    pub hit: bool,
    pub source: OpenSource,
    /// True when this open was served by the cone-delta reuse path (a
    /// near-miss rebuilt incrementally from a same-family donor entry).
    pub incremental: bool,
    /// GDG groups carried over unchanged from the donor (incremental
    /// opens only; 0 otherwise).
    pub reused_groups: usize,
    /// GDG groups rebuilt by the delta pass (incremental opens only).
    pub rebuilt_groups: usize,
    /// Wall time of this open (lookup / load / compile, whichever ran).
    pub open_time: Duration,
    /// Cold compile + partition time recorded when the entry was built.
    pub cold_compile: Duration,
}

/// The cache itself: an on-disk store (optional — `dir: None` is a pure
/// in-memory cache) fronted by an LRU of `Arc`-shared entries.
pub struct DesignCache {
    dir: Option<PathBuf>,
    cap: usize,
    mem: HashMap<String, Arc<CachedDesign>>,
    /// LRU order over `mem` keys, most recently used last.
    order: Vec<String>,
    pub mem_hits: u64,
    pub disk_hits: u64,
    pub misses: u64,
    /// Misses answered by the cone-delta reuse path (a subset of
    /// `misses`).
    pub incremental: u64,
    /// Statically verify artifacts on every open ([`crate::analysis`]);
    /// failures turn the open into an error. Always on under
    /// `debug_assertions`; opt-in (`--verify` / `"verify":true`)
    /// otherwise.
    pub verify: bool,
}

impl DesignCache {
    /// `dir`: persistent store root (created on first write); `cap`:
    /// max designs held in memory (≥ 1).
    pub fn new(dir: Option<PathBuf>, cap: usize) -> Self {
        DesignCache {
            dir,
            cap: cap.max(1),
            mem: HashMap::new(),
            order: Vec::new(),
            mem_hits: 0,
            disk_hits: 0,
            misses: 0,
            incremental: 0,
            verify: false,
        }
    }

    /// Statically verify an entry's artifact bundle (see
    /// [`crate::analysis`]) when opted in via [`Self::verify`] — always
    /// on under `debug_assertions`. The cache verifies the shared
    /// IR/OIM/GDG; the partitioned view is replayed per-open and checked
    /// by `rteaal check` and session opens.
    fn maybe_verify(&self, e: &CachedDesign) -> Result<(), String> {
        if !(self.verify || cfg!(debug_assertions)) {
            return Ok(());
        }
        let report =
            crate::analysis::verify_artifacts(&e.design_name, &e.ir, &e.oim, &e.gdg, None);
        if report.is_clean() {
            return Ok(());
        }
        let mut msg = format!("artifact verification failed — {}", report.summary());
        for d in report
            .diags
            .iter()
            .filter(|d| d.severity == crate::analysis::Severity::Error)
            .take(4)
        {
            msg.push_str(&format!("; {d}"));
        }
        Err(msg)
    }

    pub fn len(&self) -> usize {
        self.mem.len()
    }

    pub fn is_empty(&self) -> bool {
        self.mem.is_empty()
    }

    /// Open (compile-or-fetch) a design under a configuration. Returns
    /// the shared artifacts and a report of where they came from.
    pub fn open_design(
        &mut self,
        design: &Design,
        fuse: bool,
        parts: usize,
        partitioner: PartitionerKind,
    ) -> Result<(Arc<CachedDesign>, OpenReport), String> {
        if parts == 0 {
            return Err("parts must be >= 1".into());
        }
        self.sweep_trash();
        let key = design_key(&design.graph, fuse, partitioner, parts);
        let t0 = Instant::now();

        if let Some(hit) = self.exact_hit(&key, design, fuse, parts, partitioner, t0) {
            self.maybe_verify(&hit.0)?;
            return Ok(hit);
        }

        // miss: full compile + partition, persist, then serve
        let c = compile_design(design, CompileOpts { fuse });
        let parting = partition_ir(&c.ir, parts, partitioner);
        let gdg = GroupDepGraph::build(&c.ir, &c.oim);
        let cone = cone_hashes(&design.graph);
        let regs = c
            .graph
            .regs
            .iter()
            .map(|r| RegInfo { name: r.name.clone(), slot: r.node, width: r.width })
            .collect();
        let cold = t0.elapsed();
        let entry = Arc::new(CachedDesign {
            key: key.clone(),
            design_name: design.name.clone(),
            graph_name: design.graph.name.clone(),
            fuse,
            parts,
            partitioner,
            ir: c.ir,
            oim: c.oim,
            gdg,
            owner_of_reg: parting.owner_of_reg,
            regs,
            cone,
            cold_compile: cold,
        });
        self.maybe_verify(&entry)?;
        if let Err(e) = self.persist(&entry) {
            // persistence is best-effort; the entry still serves from memory
            eprintln!("rteaal serve: cache persist failed for {key}: {e}");
        }
        self.insert(key.clone(), entry.clone());
        self.misses += 1;
        let report = OpenReport {
            key,
            hit: false,
            source: OpenSource::Compiled,
            incremental: false,
            reused_groups: 0,
            rebuilt_groups: 0,
            open_time: t0.elapsed(),
            cold_compile: cold,
        };
        Ok((entry, report))
    }

    /// [`Self::open_design`] with the **reuse path**: an exact-key miss
    /// whose design family is already cached (same graph name, `fuse`,
    /// `parts` and partitioner under a different content key) is rebuilt
    /// incrementally — cone-hash diff against the donor, delta compile of
    /// the changed cones only, warm-start partitioning seeded from the
    /// donor's ownership — and committed under the new key. Falls back to
    /// the cold path whenever no donor matches or the delta pass bails
    /// (changed interface, renamed registers, ...). Exact hits are served
    /// exactly as [`Self::open_design`] would.
    pub fn open_design_incremental(
        &mut self,
        design: &Design,
        fuse: bool,
        parts: usize,
        partitioner: PartitionerKind,
    ) -> Result<(Arc<CachedDesign>, OpenReport), String> {
        if parts == 0 {
            return Err("parts must be >= 1".into());
        }
        self.sweep_trash();
        let key = design_key(&design.graph, fuse, partitioner, parts);
        let t0 = Instant::now();

        if let Some(hit) = self.exact_hit(&key, design, fuse, parts, partitioner, t0) {
            self.maybe_verify(&hit.0)?;
            return Ok(hit);
        }

        if let Some(donor) = self.find_donor(design, fuse, parts, partitioner, &key) {
            if let Some(delta) = delta_compile(design, &donor, fuse) {
                let owner = match partitioner {
                    PartitionerKind::MinCut => {
                        // prior ownership keyed by register name, minus the
                        // edited registers (those are re-homed by the warm
                        // FM pass)
                        let commit_of_slot: HashMap<u32, usize> =
                            donor.ir.commits.iter().enumerate().map(|(i, c)| (c.0, i)).collect();
                        let mut prev: HashMap<String, usize> = HashMap::new();
                        for r in &donor.regs {
                            if delta.changed_regs.iter().any(|n| n == &r.name) {
                                continue;
                            }
                            if let Some(&ci) = commit_of_slot.get(&r.slot) {
                                prev.insert(r.name.clone(), donor.owner_of_reg[ci]);
                            }
                        }
                        warm_partition(&delta.ir, parts, &prev)
                    }
                    PartitionerKind::RoundRobin => {
                        (0..delta.ir.commits.len()).map(|i| i % parts).collect()
                    }
                };
                let cold = t0.elapsed();
                let entry = Arc::new(CachedDesign {
                    key: key.clone(),
                    design_name: design.name.clone(),
                    graph_name: design.graph.name.clone(),
                    fuse,
                    parts,
                    partitioner,
                    ir: delta.ir,
                    oim: delta.oim,
                    gdg: delta.gdg,
                    owner_of_reg: owner,
                    regs: delta.regs,
                    cone: delta.cone,
                    cold_compile: cold,
                });
                self.maybe_verify(&entry)?;
                if let Err(e) = self.persist(&entry) {
                    eprintln!("rteaal serve: cache persist failed for {key}: {e}");
                }
                self.insert(key.clone(), entry.clone());
                self.misses += 1;
                self.incremental += 1;
                let report = OpenReport {
                    key,
                    hit: false,
                    source: OpenSource::Compiled,
                    incremental: true,
                    reused_groups: delta.reused_groups,
                    rebuilt_groups: delta.rebuilt_groups,
                    open_time: t0.elapsed(),
                    cold_compile: cold,
                };
                return Ok((entry, report));
            }
        }

        self.open_design(design, fuse, parts, partitioner)
    }

    /// Serve an exact-key hit from memory or disk, if one exists.
    fn exact_hit(
        &mut self,
        key: &str,
        design: &Design,
        fuse: bool,
        parts: usize,
        partitioner: PartitionerKind,
        t0: Instant,
    ) -> Option<(Arc<CachedDesign>, OpenReport)> {
        if let Some(hit) = self.mem.get(key).cloned() {
            self.touch(key);
            self.mem_hits += 1;
            let report = OpenReport {
                key: key.to_string(),
                hit: true,
                source: OpenSource::Memory,
                incremental: false,
                reused_groups: 0,
                rebuilt_groups: 0,
                open_time: t0.elapsed(),
                cold_compile: hit.cold_compile,
            };
            return Some((hit, report));
        }
        if self.dir.is_some() {
            // a corrupt or version-skewed disk entry is not an error —
            // the caller falls through and rebuilds over it
            if let Ok(loaded) = self.load_disk(key, design, fuse, parts, partitioner) {
                let entry = Arc::new(loaded);
                self.insert(key.to_string(), entry.clone());
                self.disk_hits += 1;
                let report = OpenReport {
                    key: key.to_string(),
                    hit: true,
                    source: OpenSource::Disk,
                    incremental: false,
                    reused_groups: 0,
                    rebuilt_groups: 0,
                    open_time: t0.elapsed(),
                    cold_compile: entry.cold_compile,
                };
                return Some((entry, report));
            }
        }
        None
    }

    /// Find a same-family donor for an incremental open: an entry with
    /// the same graph name and `(fuse, parts, partitioner)` configuration
    /// under a different content key. Memory first (most recently used
    /// wins), then a scan of the store directory.
    fn find_donor(
        &self,
        design: &Design,
        fuse: bool,
        parts: usize,
        partitioner: PartitionerKind,
        skip_key: &str,
    ) -> Option<Arc<CachedDesign>> {
        let family = |e: &CachedDesign| {
            e.graph_name == design.graph.name
                && e.fuse == fuse
                && e.parts == parts
                && e.partitioner == partitioner
        };
        for key in self.order.iter().rev() {
            if key == skip_key {
                continue;
            }
            if let Some(e) = self.mem.get(key) {
                if family(e) {
                    return Some(e.clone());
                }
            }
        }
        let dir = self.dir.as_ref()?;
        let entries = std::fs::read_dir(dir).ok()?;
        for entry in entries.flatten() {
            let Ok(name) = entry.file_name().into_string() else { continue };
            if name == skip_key || name.contains(".tmp.") || name.contains(".trash.") {
                continue;
            }
            if let Ok(e) = self.load_disk_raw(&name) {
                if family(&e) {
                    return Some(Arc::new(e));
                }
            }
        }
        None
    }

    /// Remove `.trash.` tombstone directories left behind by an eviction
    /// interrupted between its rename and delete (the owner normally
    /// deletes its tombstone immediately). Best-effort, racing deleters
    /// are harmless.
    fn sweep_trash(&self) {
        let Some(dir) = &self.dir else { return };
        let Ok(entries) = std::fs::read_dir(dir) else { return };
        for entry in entries.flatten() {
            if let Ok(name) = entry.file_name().into_string() {
                if name.contains(".trash.") {
                    let _ = std::fs::remove_dir_all(entry.path());
                }
            }
        }
    }

    fn touch(&mut self, key: &str) {
        if let Some(pos) = self.order.iter().position(|k| k == key) {
            let k = self.order.remove(pos);
            self.order.push(k);
        }
    }

    fn insert(&mut self, key: String, entry: Arc<CachedDesign>) {
        if self.mem.insert(key.clone(), entry).is_none() {
            self.order.push(key);
        } else {
            self.touch(&key);
        }
        while self.mem.len() > self.cap {
            let victim = self.order.remove(0);
            self.mem.remove(&victim);
        }
    }

    fn entry_dir(&self, key: &str) -> Option<PathBuf> {
        self.dir.as_ref().map(|d| d.join(key))
    }

    fn persist(&self, e: &CachedDesign) -> Result<(), String> {
        let Some(final_dir) = self.entry_dir(&e.key) else { return Ok(()) };
        let parent = final_dir.parent().expect("entry dir has a parent");
        std::fs::create_dir_all(parent).map_err(|er| er.to_string())?;
        // stage into a pid-unique <key>.tmp.<pid>, then rename: a killed
        // server never leaves a half-written entry under the real key,
        // and two processes racing the same key never share a staging
        // directory (rename-is-commit is the only cross-process
        // synchronization; no lock file needed)
        let pid = std::process::id();
        let tmp = parent.join(format!("{}.tmp.{pid}", e.key));
        let _ = std::fs::remove_dir_all(&tmp);
        std::fs::create_dir_all(&tmp).map_err(|er| er.to_string())?;
        let write = |name: &str, j: Json| -> Result<(), String> {
            std::fs::write(tmp.join(name), j.to_string()).map_err(|er| er.to_string())
        };
        let cone_names: Vec<String> = e.cone.regs.iter().map(|(n, _)| n.clone()).collect();
        let cone_hash_strs: Vec<String> = e.cone.regs.iter().map(|(_, h)| h.clone()).collect();
        let meta = obj(vec![
            ("version", Json::Int(CACHE_FORMAT_VERSION as i64)),
            ("key", Json::Str(e.key.clone())),
            ("design", Json::Str(e.design_name.clone())),
            ("graph", Json::Str(e.graph_name.clone())),
            ("fuse", Json::Bool(e.fuse)),
            ("parts", Json::Int(e.parts as i64)),
            ("partitioner", Json::Str(e.partitioner.name().to_string())),
            ("cold_compile_ns", Json::Int(e.cold_compile.as_nanos() as i64)),
            (
                "owner_of_reg",
                arr_u64(&e.owner_of_reg.iter().map(|&p| p as u64).collect::<Vec<_>>()),
            ),
            ("reg_names", arr_str(&e.regs.iter().map(|r| r.name.clone()).collect::<Vec<_>>())),
            ("reg_slots", arr_u32(&e.regs.iter().map(|r| r.slot).collect::<Vec<_>>())),
            (
                "reg_widths",
                arr_u64(&e.regs.iter().map(|r| r.width as u64).collect::<Vec<_>>()),
            ),
            ("cone_regs", arr_str(&cone_names)),
            ("cone_reg_hashes", arr_str(&cone_hash_strs)),
            ("cone_outputs", Json::Str(e.cone.outputs.clone())),
            ("cone_inputs", Json::Str(e.cone.inputs.clone())),
        ]);
        write("meta.json", meta)?;
        write("oim.json", e.oim.to_json())?;
        write("ir.json", e.ir.to_json())?;
        write("gdg.json", e.gdg.to_json())?;
        // evicting an existing entry (we only get here when loading it
        // failed, or when another process committed it mid-race) goes
        // through a pid-unique tombstone rename, so a concurrent reader
        // never observes a half-deleted entry directory — it sees the
        // old entry, the new one, or nothing (→ recompile)
        if final_dir.exists() {
            let trash = parent.join(format!("{}.trash.{pid}", e.key));
            let _ = std::fs::remove_dir_all(&trash);
            if std::fs::rename(&final_dir, &trash).is_ok() {
                let _ = std::fs::remove_dir_all(&trash);
            }
        }
        match std::fs::rename(&tmp, &final_dir) {
            Ok(()) => Ok(()),
            Err(er) => {
                // rename-is-commit: if another process committed this key
                // between our eviction check and the rename, losing the
                // race is success — the store holds equivalent content
                let _ = std::fs::remove_dir_all(&tmp);
                if final_dir.join("meta.json").exists() {
                    Ok(())
                } else {
                    Err(er.to_string())
                }
            }
        }
    }

    fn load_disk(
        &self,
        key: &str,
        design: &Design,
        fuse: bool,
        parts: usize,
        partitioner: PartitionerKind,
    ) -> Result<CachedDesign, String> {
        let e = self.load_disk_raw(key)?;
        // paranoia against a (truncated-key) collision or a hand-edited
        // store: the stored configuration must echo the request
        if e.design_name != design.name
            || e.parts != parts
            || e.partitioner != partitioner
            || e.fuse != fuse
        {
            return Err("cache entry does not match requested configuration".into());
        }
        Ok(e)
    }

    /// Load a disk entry by key, trusting the stored configuration (no
    /// request echo-check): the donor search deliberately loads entries
    /// of *other* designs in the family.
    fn load_disk_raw(&self, key: &str) -> Result<CachedDesign, String> {
        let dir = self.entry_dir(key).ok_or("no cache dir")?;
        let read = |name: &str| -> Result<Json, String> {
            let text = std::fs::read_to_string(dir.join(name))
                .map_err(|e| format!("{name}: {e}"))?;
            parse(&text).map_err(|e| format!("{name}: {e}"))
        };
        let meta = read("meta.json")?;
        let schema = |e: crate::util::json::JsonError| format!("meta.json: {e}");
        if meta.req_u64("version").map_err(schema)? != CACHE_FORMAT_VERSION {
            return Err("cache format version skew".into());
        }
        let design_name = meta.req_str("design").map_err(schema)?.to_string();
        let graph_name = meta.req_str("graph").map_err(schema)?.to_string();
        let fuse = match meta.get("fuse") {
            Some(Json::Bool(b)) => *b,
            _ => return Err("meta.json: fuse missing or non-bool".into()),
        };
        let parts = meta.req_usize("parts").map_err(schema)?;
        let partitioner = PartitionerKind::parse(meta.req_str("partitioner").map_err(schema)?)
            .ok_or("meta.json: unknown partitioner")?;
        let cold_compile = Duration::from_nanos(meta.req_u64("cold_compile_ns").map_err(schema)?);
        let owner_of_reg: Vec<usize> = meta
            .req_u64_vec("owner_of_reg")
            .map_err(schema)?
            .into_iter()
            .map(|p| p as usize)
            .collect();
        let reg_names = meta.req_arr("reg_names").map_err(schema)?;
        let reg_slots = meta.req_u32_vec("reg_slots").map_err(schema)?;
        let reg_widths = meta.req_u64_vec("reg_widths").map_err(schema)?;
        if reg_names.len() != reg_slots.len() || reg_names.len() != reg_widths.len() {
            return Err("meta.json: register arrays disagree on length".into());
        }
        let mut regs = Vec::with_capacity(reg_names.len());
        for i in 0..reg_names.len() {
            let name = reg_names[i]
                .as_str()
                .ok_or("meta.json: reg_names holds a non-string")?
                .to_string();
            regs.push(RegInfo { name, slot: reg_slots[i], width: reg_widths[i] as u8 });
        }
        let cone_names = meta.req_arr("cone_regs").map_err(schema)?;
        let cone_hash_strs = meta.req_arr("cone_reg_hashes").map_err(schema)?;
        if cone_names.len() != cone_hash_strs.len() {
            return Err("meta.json: cone arrays disagree on length".into());
        }
        let mut cone_regs = Vec::with_capacity(cone_names.len());
        for i in 0..cone_names.len() {
            let n = cone_names[i].as_str().ok_or("meta.json: cone_regs holds a non-string")?;
            let h = cone_hash_strs[i]
                .as_str()
                .ok_or("meta.json: cone_reg_hashes holds a non-string")?;
            cone_regs.push((n.to_string(), h.to_string()));
        }
        let cone = ConeHashes {
            regs: cone_regs,
            outputs: meta.req_str("cone_outputs").map_err(schema)?.to_string(),
            inputs: meta.req_str("cone_inputs").map_err(schema)?.to_string(),
        };
        let oim = Oim::from_json(&read("oim.json")?).map_err(|e| format!("oim.json: {e}"))?;
        let ir = LayerIr::from_json_with_oim(&read("ir.json")?, &oim)
            .map_err(|e| format!("ir.json: {e}"))?;
        let gdg = GroupDepGraph::from_json(&read("gdg.json")?).map_err(|e| format!("gdg.json: {e}"))?;
        if owner_of_reg.len() != ir.commits.len() {
            return Err("meta.json: ownership map does not cover the commits".into());
        }
        if owner_of_reg.iter().any(|&p| p >= parts) {
            return Err("meta.json: ownership map exceeds partition count".into());
        }
        Ok(CachedDesign {
            key: key.to_string(),
            design_name,
            graph_name,
            fuse,
            parts,
            partitioner,
            ir,
            oim,
            gdg,
            owner_of_reg,
            regs,
            cone,
            cold_compile,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::designs::catalog;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("rteaal_cache_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    /// The fingerprint separates designs and configurations but is stable
    /// for a fixed input.
    #[test]
    fn design_key_is_stable_and_config_sensitive() {
        let a = catalog("fir8").unwrap();
        let b = catalog("alu32").unwrap();
        let k1 = design_key(&a.graph, true, PartitionerKind::MinCut, 2);
        assert_eq!(k1, design_key(&a.graph, true, PartitionerKind::MinCut, 2));
        assert_eq!(k1.len(), 32, "128-bit hex key");
        assert_ne!(k1, design_key(&b.graph, true, PartitionerKind::MinCut, 2));
        assert_ne!(k1, design_key(&a.graph, false, PartitionerKind::MinCut, 2));
        assert_ne!(k1, design_key(&a.graph, true, PartitionerKind::RoundRobin, 2));
        assert_ne!(k1, design_key(&a.graph, true, PartitionerKind::MinCut, 4));
    }

    /// Memory → disk → miss precedence, with hit/miss accounting; a
    /// second cache instance over the same directory loads from disk and
    /// its artifacts drive a bit-identical simulation.
    #[test]
    fn open_design_hits_memory_then_disk_and_replays_identically() {
        use crate::coordinator::parallel::BatchParallelSim;
        use crate::kernels::KernelConfig;

        let d = catalog("fir8").unwrap();
        let dir = tmp_dir("roundtrip");
        let mut cache = DesignCache::new(Some(dir.clone()), 4);
        let (cold, r0) = cache.open_design(&d, true, 2, PartitionerKind::MinCut).unwrap();
        assert!(!r0.hit);
        assert_eq!(r0.source, OpenSource::Compiled);
        let (_, r1) = cache.open_design(&d, true, 2, PartitionerKind::MinCut).unwrap();
        assert!(r1.hit);
        assert_eq!(r1.source, OpenSource::Memory);
        assert_eq!(r1.key, r0.key);
        assert_eq!((cache.mem_hits, cache.disk_hits, cache.misses), (1, 0, 1));

        // fresh front over the same store: must come back from disk
        let mut cache2 = DesignCache::new(Some(dir.clone()), 4);
        let (warm, r2) = cache2.open_design(&d, true, 2, PartitionerKind::MinCut).unwrap();
        assert!(r2.hit);
        assert_eq!(r2.source, OpenSource::Disk);
        assert_eq!(warm.cold_compile, cold.cold_compile);
        assert_eq!(warm.owner_of_reg, cold.owner_of_reg);
        assert_eq!(warm.regs.len(), cold.regs.len());

        // the disk-loaded artifacts simulate bit-identically to the
        // freshly compiled ones
        let lanes = 4;
        let mut sc = BatchParallelSim::with_partitioning(
            &cold.ir,
            KernelConfig::PSU,
            cold.partitioning(),
            lanes,
            false,
            cold.partitioner,
        );
        let mut sw = BatchParallelSim::with_partitioning(
            &warm.ir,
            KernelConfig::PSU,
            warm.partitioning(),
            lanes,
            false,
            warm.partitioner,
        );
        let mut stim = d.make_lane_stimulus(lanes);
        let mut stim2 = d.make_lane_stimulus(lanes);
        for cyc in 0..64 {
            sc.step(&stim(cyc));
            sw.step(&stim2(cyc));
            for l in 0..lanes {
                assert_eq!(sc.lane_outputs(l), sw.lane_outputs(l), "cycle {cyc} lane {l}");
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A truncated disk entry is rebuilt, not served or panicked on.
    #[test]
    fn corrupt_disk_entry_falls_back_to_recompile() {
        let d = catalog("counter").unwrap();
        let dir = tmp_dir("corrupt");
        let mut cache = DesignCache::new(Some(dir.clone()), 4);
        let (_, r0) = cache.open_design(&d, true, 1, PartitionerKind::MinCut).unwrap();
        // clobber the OIM payload on disk
        std::fs::write(dir.join(&r0.key).join("oim.json"), "{\"truncated\":").unwrap();
        let mut cache2 = DesignCache::new(Some(dir.clone()), 4);
        let (_, r1) = cache2.open_design(&d, true, 1, PartitionerKind::MinCut).unwrap();
        assert!(!r1.hit, "corrupt entry must rebuild");
        assert_eq!(r1.source, OpenSource::Compiled);
        // ...and the rebuild repaired the store
        let mut cache3 = DesignCache::new(Some(dir.clone()), 4);
        let (_, r2) = cache3.open_design(&d, true, 1, PartitionerKind::MinCut).unwrap();
        assert_eq!(r2.source, OpenSource::Disk);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Satellite regression: two *processes* opening the same design
    /// against one store directory leave it coherent — no shared staging
    /// directory, rename-is-commit resolves the race, no `.tmp.`/`.trash.`
    /// litter survives. The test re-invokes its own test binary (with an
    /// env marker) as the second and third process.
    #[test]
    fn two_processes_race_the_same_cache_entry() {
        let dir = match std::env::var("RTEAAL_CACHE_RACE_DIR") {
            Ok(d) => {
                // child mode: populate the shared store and exit
                let design = catalog("fir8").unwrap();
                let mut cache = DesignCache::new(Some(PathBuf::from(d)), 4);
                let (entry, _) =
                    cache.open_design(&design, true, 2, PartitionerKind::MinCut).unwrap();
                assert!(!entry.key.is_empty());
                return;
            }
            Err(_) => tmp_dir("race"),
        };
        std::fs::create_dir_all(&dir).unwrap();
        let exe = std::env::current_exe().unwrap();
        let spawn = || {
            std::process::Command::new(&exe)
                .args([
                    "service::cache::tests::two_processes_race_the_same_cache_entry",
                    "--exact",
                ])
                .env("RTEAAL_CACHE_RACE_DIR", &dir)
                .stdout(std::process::Stdio::null())
                .spawn()
                .unwrap()
        };
        let mut a = spawn();
        let mut b = spawn();
        assert!(a.wait().unwrap().success(), "first racer failed");
        assert!(b.wait().unwrap().success(), "second racer failed");

        // whichever process won, the store must hold one loadable entry
        let d = catalog("fir8").unwrap();
        let mut cache = DesignCache::new(Some(dir.clone()), 4);
        let (_, r) = cache.open_design(&d, true, 2, PartitionerKind::MinCut).unwrap();
        assert_eq!(r.source, OpenSource::Disk, "store left incoherent by the race");
        for entry in std::fs::read_dir(&dir).unwrap() {
            let name = entry.unwrap().file_name().into_string().unwrap();
            assert!(
                !name.contains(".tmp.") && !name.contains(".trash."),
                "staging litter left behind: {name}"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Touching an entry (memory hit) moves it to the MRU end, so the
    /// untouched entry is the one evicted when the cap is exceeded.
    #[test]
    fn lru_touch_changes_the_eviction_victim() {
        let dir = tmp_dir("lru_touch");
        let mut cache = DesignCache::new(Some(dir.clone()), 2);
        let counter = catalog("counter").unwrap();
        let alu = catalog("alu32").unwrap();
        let fir = catalog("fir8").unwrap();
        cache.open_design(&counter, true, 1, PartitionerKind::MinCut).unwrap();
        cache.open_design(&alu, true, 1, PartitionerKind::MinCut).unwrap();
        // touch counter: alu32 becomes the LRU victim
        let (_, r) = cache.open_design(&counter, true, 1, PartitionerKind::MinCut).unwrap();
        assert_eq!(r.source, OpenSource::Memory);
        cache.open_design(&fir, true, 1, PartitionerKind::MinCut).unwrap();
        assert_eq!(cache.len(), 2);
        let (_, rc) = cache.open_design(&counter, true, 1, PartitionerKind::MinCut).unwrap();
        assert_eq!(rc.source, OpenSource::Memory, "touched entry must survive the eviction");
        let (_, ra) = cache.open_design(&alu, true, 1, PartitionerKind::MinCut).unwrap();
        assert_eq!(ra.source, OpenSource::Disk, "untouched entry was the victim");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// `.trash.` tombstones left by an eviction that crashed between its
    /// rename and delete are swept by the next open.
    #[test]
    fn trash_tombstones_are_swept_on_open() {
        let d = catalog("counter").unwrap();
        let dir = tmp_dir("trash");
        let mut cache = DesignCache::new(Some(dir.clone()), 4);
        cache.open_design(&d, true, 1, PartitionerKind::MinCut).unwrap();
        let orphan = dir.join("deadbeef.trash.12345");
        std::fs::create_dir_all(orphan.join("sub")).unwrap();
        std::fs::write(orphan.join("meta.json"), "{}").unwrap();
        cache.open_design(&d, true, 1, PartitionerKind::MinCut).unwrap();
        assert!(!orphan.exists(), "tombstone must be swept by the next open");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The incremental open path with an in-memory donor: a cold open of
    /// the base design donates its artifacts to the `_edit` variant of
    /// the same family, which is rebuilt through the cone delta and
    /// committed under its own key — an exact hit on reopen.
    #[test]
    fn incremental_open_reuses_an_in_memory_donor() {
        let base = catalog("fir8").unwrap();
        let edit = catalog("fir8_edit").unwrap();
        let dir = tmp_dir("incr_mem");
        let mut cache = DesignCache::new(Some(dir.clone()), 4);
        let (_, rb) = cache.open_design(&base, true, 2, PartitionerKind::MinCut).unwrap();
        let (_, re) =
            cache.open_design_incremental(&edit, true, 2, PartitionerKind::MinCut).unwrap();
        assert!(re.incremental, "same-family near-miss must take the delta path");
        assert!(!re.hit);
        assert_ne!(re.key, rb.key, "the edit commits under its own content key");
        assert!(re.reused_groups > 0, "untouched groups must be carried over");
        let (_, r2) =
            cache.open_design_incremental(&edit, true, 2, PartitionerKind::MinCut).unwrap();
        assert!(r2.hit && !r2.incremental, "reopen is an exact hit");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The incremental open path with a *disk* donor: a fresh cache front
    /// whose memory holds nothing still finds the base entry by scanning
    /// the store directory.
    #[test]
    fn incremental_open_finds_the_donor_on_disk() {
        let base = catalog("fir8").unwrap();
        let edit = catalog("fir8_edit").unwrap();
        let dir = tmp_dir("incr_disk");
        {
            let mut cache = DesignCache::new(Some(dir.clone()), 4);
            cache.open_design(&base, true, 2, PartitionerKind::MinCut).unwrap();
        }
        let mut cache2 = DesignCache::new(Some(dir.clone()), 4);
        let (_, re) =
            cache2.open_design_incremental(&edit, true, 2, PartitionerKind::MinCut).unwrap();
        assert!(re.incremental, "donor must be discovered by the disk scan");
        assert_eq!(re.source, OpenSource::Compiled);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// With no family donor anywhere, the incremental open falls back to
    /// a plain cold compile.
    #[test]
    fn incremental_open_without_a_donor_falls_back_to_cold() {
        let d = catalog("counter").unwrap();
        let dir = tmp_dir("incr_cold");
        let mut cache = DesignCache::new(Some(dir.clone()), 4);
        let (_, r) = cache.open_design_incremental(&d, true, 1, PartitionerKind::MinCut).unwrap();
        assert!(!r.hit && !r.incremental);
        assert_eq!(r.source, OpenSource::Compiled);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The LRU cap bounds the in-memory set; evicted entries come back
    /// from disk.
    #[test]
    fn lru_evicts_beyond_cap() {
        let dir = tmp_dir("lru");
        let mut cache = DesignCache::new(Some(dir.clone()), 2);
        for name in ["counter", "alu32", "fir8"] {
            let d = catalog(name).unwrap();
            cache.open_design(&d, true, 1, PartitionerKind::MinCut).unwrap();
        }
        assert_eq!(cache.len(), 2, "cap respected");
        // counter was evicted; reopening is a disk hit, not a rebuild
        let d = catalog("counter").unwrap();
        let (_, r) = cache.open_design(&d, true, 1, PartitionerKind::MinCut).unwrap();
        assert_eq!(r.source, OpenSource::Disk);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
