//! Session manager: many client sessions, few host simulators.
//!
//! A **host** is one [`BatchParallelSim`] (`P` partitions × `B` lanes on
//! the persistent worker pool). A **session** owns a contiguous slice of
//! a host's lanes. Same-design sessions whose configuration matches an
//! existing host's signature — (cache key, kernel, parts, B, sparse) —
//! are packed onto it, so `K` small sessions cost one OIM walk per
//! cycle instead of `K`. Isolation is structural: lanes never interact
//! inside a kernel, each session's stimulus is scattered only into its
//! own lanes, and free lanes are driven with zeros.
//!
//! Hosts advance **bulk-synchronously** (Manticore-style): one pump
//! steps `min(queued cycles over all attached sessions)`, bounded by the
//! request deadline and per-session output-buffer backpressure. A
//! session with an empty queue therefore stalls its host-mates until it
//! submits or closes — the packing rule clients must know (see the
//! module docs of [`crate::service`]).
//!
//! Stimulus either replays the design's canonical stream (slice lane `j`
//! draws from `make_stimulus_for_lane(j)`, so a width-1 session is
//! bit-identical to scalar `rteaal sim` and a width-B session to
//! `rteaal sim --lanes B`) or is an explicit per-cycle vector queue. The
//! canonical stream is indexed by the *session* cycle: a restored
//! session fast-forwards its generators to its cycle count before
//! drawing, so checkpoint/restore does not fork the stream.
//!
//! Checkpoints: a session that owns its whole host snapshots the host's
//! complete [`SimState`](crate::coordinator::parallel::SimState)
//! (kind 0); a session sharing a host snapshots the committed registers
//! of its lanes only (kind 1) — registers are the complete architectural
//! state here (no memories; every combinational slot is recomputed from
//! them), so both restores are exact, and the round-trip tests hold both
//! kinds to bit-identity. Restore always creates a *new* session.

use std::collections::{HashMap, HashSet, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::parallel::BatchParallelSim;
use crate::designs::catalog;
use crate::kernels::{supports_sparse, KernelConfig};
use crate::partition::PartitionerKind;
use crate::service::cache::{CachedDesign, DesignCache, OpenReport};
use crate::service::checkpoint::{Snapshot, SnapshotConfig, SnapshotPayload};
use crate::sim::WaveSink;

/// One lane's stimulus stream: cycle number in, input-port values out.
type StimulusFn = Box<dyn FnMut(u64) -> Vec<u64>>;

/// Per-session output backlog cap: the pump stops before any attached
/// session's undrained buffer would exceed this (backpressure instead of
/// unbounded growth when a client submits much and polls little).
pub const OUT_BUF_CAP: usize = 4096;

/// Requested configuration for `open`.
#[derive(Clone, Debug)]
pub struct SessionConfig {
    pub design: String,
    pub kernel: KernelConfig,
    pub parts: usize,
    /// Host width B (lanes per kernel step).
    pub lanes: usize,
    /// Lanes this session owns (1 ≤ width ≤ lanes).
    pub width: usize,
    pub sparse: bool,
    pub fuse: bool,
    pub partitioner: PartitionerKind,
    /// Route the open through the cone-delta reuse path
    /// ([`DesignCache::open_design_incremental`]): an exact-key miss with
    /// a cached same-family entry is rebuilt incrementally instead of
    /// from scratch. Snapshot restores always use the exact path.
    pub incremental: bool,
    /// Run the static artifact verifier ([`crate::analysis`]) on this
    /// open and refuse the session if it reports errors. Always on under
    /// `debug_assertions` regardless of this flag.
    pub verify: bool,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig {
            design: String::new(),
            kernel: KernelConfig::PSU,
            parts: 1,
            lanes: 1,
            width: 1,
            sparse: false,
            fuse: true,
            partitioner: PartitionerKind::MinCut,
            incremental: false,
            verify: false,
        }
    }
}

/// One drained cycle: the session's slice-lane-0 design outputs after
/// that cycle's commit.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CycleRecord {
    pub cycle: u64,
    pub out: Vec<(String, u64)>,
}

/// Result of a poll: drained records plus queue status.
#[derive(Debug)]
pub struct PollResult {
    pub records: Vec<CycleRecord>,
    /// Session cycle count after pumping.
    pub cycle: u64,
    /// True when the stimulus queue is fully consumed *and* the output
    /// buffer is drained.
    pub done: bool,
    /// Incremental VCD bytes accumulated since the last poll; `None`
    /// when no `wave` sink is attached (possibly-empty bytes otherwise —
    /// quiescent cycles contribute nothing). Concatenating every chunk
    /// reproduces the exact byte stream a solo `rteaal sim --vcd` run of
    /// the same lane writes.
    pub wave_chunk: Option<Vec<u8>>,
}

/// What `open` produced.
pub struct OpenOutcome {
    pub session: u64,
    pub host: usize,
    /// Absolute host lane of the session's slice lane 0.
    pub lane0: usize,
    pub report: OpenReport,
}

#[derive(Clone, PartialEq, Eq)]
struct HostSig {
    key: String,
    kernel: KernelConfig,
    parts: usize,
    lanes: usize,
    sparse: bool,
}

struct Host {
    sig: HostSig,
    sim: BatchParallelSim,
    design: Arc<CachedDesign>,
    /// Initial slot values (graph constants + register init).
    init_slots: Vec<u64>,
    occupied: Vec<bool>,
    sessions: Vec<u64>,
    /// Set when the simulator panicked mid-step: the host is dead, its
    /// sessions are failed, the server lives on.
    wedged: bool,
    num_inputs: usize,
}

impl Host {
    fn free_run(&self, width: usize) -> Option<usize> {
        if width == 0 || width > self.occupied.len() {
            return None;
        }
        (0..=self.occupied.len() - width)
            .find(|&start| self.occupied[start..start + width].iter().all(|&o| !o))
    }
}

struct Session {
    host: usize,
    lane0: usize,
    width: usize,
    design: String,
    /// Cycles this session has advanced (== frames consumed).
    cycle: u64,
    /// Design-stream generators, one per slice lane; created lazily on
    /// the first pumped design-stimulus cycle.
    gens: Option<Vec<StimulusFn>>,
    /// Frames drawn from `gens` so far — fast-forwarded to `cycle`
    /// before drawing, so restored sessions resume the stream in place.
    gen_drawn: u64,
    /// Queued design-stream cycles.
    design_remaining: u64,
    /// Queued explicit frames (`inputs × width` lane-major words each).
    vectors: VecDeque<Vec<u64>>,
    out_buf: VecDeque<CycleRecord>,
    /// Delta-waveform sink over one slice lane's design outputs; the
    /// pump samples it every stepped cycle and `poll` drains the bytes.
    wave: Option<WaveSink<Vec<u8>>>,
    failed: Option<String>,
}

impl Session {
    fn queued(&self) -> u64 {
        self.design_remaining + self.vectors.len() as u64
    }
}

/// The service's session table: a design cache, the live hosts, and the
/// sessions packed onto them.
pub struct SessionManager {
    pub cache: DesignCache,
    hosts: Vec<Option<Host>>,
    sessions: HashMap<u64, Session>,
    next_session: u64,
}

impl SessionManager {
    pub fn new(cache_dir: Option<PathBuf>, cache_cap: usize) -> Self {
        SessionManager {
            cache: DesignCache::new(cache_dir, cache_cap),
            hosts: Vec::new(),
            sessions: HashMap::new(),
            next_session: 0,
        }
    }

    pub fn session_count(&self) -> usize {
        self.sessions.len()
    }

    pub fn host_count(&self) -> usize {
        self.hosts.iter().filter(|h| h.is_some()).count()
    }

    /// Packed-lane occupancy of every live session, sorted by session id:
    /// `(session, host, lane0, width, host_lanes)`. `host_lanes` is 0
    /// when the host is gone (wedged and dropped; the session is failed).
    pub fn occupancy(&self) -> Vec<(u64, usize, usize, usize, usize)> {
        let mut rows: Vec<_> = self
            .sessions
            .iter()
            .map(|(&id, s)| {
                let lanes = self
                    .hosts
                    .get(s.host)
                    .and_then(Option::as_ref)
                    .map(|h| h.sig.lanes)
                    .unwrap_or(0);
                (id, s.host, s.lane0, s.width, lanes)
            })
            .collect();
        rows.sort_unstable();
        rows
    }

    /// Open a session: fetch-or-compile the design, pack onto a matching
    /// host (or build one from the cached artifacts), initialize the
    /// slice lanes.
    pub fn open(&mut self, cfg: &SessionConfig) -> Result<OpenOutcome, String> {
        let design = catalog(&cfg.design)
            .ok_or_else(|| format!("unknown design '{}'", cfg.design))?;
        if cfg.lanes == 0 || cfg.width == 0 {
            return Err("lanes and width must be >= 1".into());
        }
        if cfg.width > cfg.lanes {
            return Err(format!("width {} exceeds host lanes {}", cfg.width, cfg.lanes));
        }
        if cfg.sparse && !supports_sparse(cfg.kernel) {
            return Err(format!("kernel {} has no sparse variant", cfg.kernel.name()));
        }
        if cfg.sparse && cfg.lanes > 64 {
            return Err(format!(
                "sparse supports at most 64 lanes (one activity-mask bit per lane; got {})",
                cfg.lanes
            ));
        }
        if cfg.parts == 0 {
            return Err("parts must be >= 1".into());
        }
        // per-open verification request: widen the cache's flag for this
        // open only, so one session asking never weakens a server-wide
        // `--verify` and never sticks to later sessions
        let server_verify = self.cache.verify;
        self.cache.verify = server_verify || cfg.verify;
        let opened = if cfg.incremental {
            self.cache.open_design_incremental(&design, cfg.fuse, cfg.parts, cfg.partitioner)
        } else {
            self.cache.open_design(&design, cfg.fuse, cfg.parts, cfg.partitioner)
        };
        self.cache.verify = server_verify;
        let (cached, report) = opened?;

        let sig = HostSig {
            key: cached.key.clone(),
            kernel: cfg.kernel,
            parts: cfg.parts,
            lanes: cfg.lanes,
            sparse: cfg.sparse,
        };
        let mut placement = None;
        for (h, slot) in self.hosts.iter().enumerate() {
            if let Some(host) = slot {
                if host.wedged || host.sig != sig {
                    continue;
                }
                if let Some(lane0) = host.free_run(cfg.width) {
                    placement = Some((h, lane0));
                    break;
                }
            }
        }
        let (h, lane0) = match placement {
            Some(p) => p,
            None => {
                let sim = BatchParallelSim::with_partitioning(
                    &cached.ir,
                    cfg.kernel,
                    cached.partitioning(),
                    cfg.lanes,
                    cfg.sparse,
                    cfg.partitioner,
                );
                let host = Host {
                    sig: sig.clone(),
                    init_slots: cached.ir.initial_slots(),
                    num_inputs: cached.ir.input_slots.len(),
                    sim,
                    design: cached.clone(),
                    occupied: vec![false; cfg.lanes],
                    sessions: Vec::new(),
                    wedged: false,
                };
                let h = match self.hosts.iter().position(|s| s.is_none()) {
                    Some(i) => {
                        self.hosts[i] = Some(host);
                        i
                    }
                    None => {
                        self.hosts.push(Some(host));
                        self.hosts.len() - 1
                    }
                };
                (h, 0)
            }
        };

        let id = self.next_session;
        self.next_session += 1;
        {
            let host = self.hosts[h].as_mut().expect("placed on a live host");
            host.occupied[lane0..lane0 + cfg.width].fill(true);
            host.sessions.push(id);
            // deterministic slice state regardless of what a previous
            // occupant left in these lanes: registers back to their init
            // values, then the design's divergent-lane init, addressed by
            // *slice* lane so a packed session matches a solo run
            for &(reg, _, _) in &host.design.ir.commits {
                let v = host.init_slots[reg as usize];
                for l in lane0..lane0 + cfg.width {
                    host.sim.poke_lane(reg, l, v);
                }
            }
            for (slot, j, value) in cached.resolved_lane_init(&design, cfg.width)? {
                host.sim.poke_lane(slot, lane0 + j, value);
            }
        }
        self.sessions.insert(
            id,
            Session {
                host: h,
                lane0,
                width: cfg.width,
                design: cfg.design.clone(),
                cycle: 0,
                gens: None,
                gen_drawn: 0,
                design_remaining: 0,
                vectors: VecDeque::new(),
                out_buf: VecDeque::new(),
                wave: None,
                failed: None,
            },
        );
        Ok(OpenOutcome { session: id, host: h, lane0, report })
    }

    fn session(&self, id: u64) -> Result<&Session, String> {
        self.sessions.get(&id).ok_or_else(|| format!("unknown session {id}"))
    }

    fn live_session_mut(&mut self, id: u64) -> Result<&mut Session, String> {
        let s = self.sessions.get_mut(&id).ok_or_else(|| format!("unknown session {id}"))?;
        if let Some(why) = &s.failed {
            return Err(format!("session {id} is failed: {why}"));
        }
        Ok(s)
    }

    /// Queue `cycles` of the design's canonical stimulus stream. Returns
    /// the total queued cycle count.
    pub fn submit_design(&mut self, id: u64, cycles: u64) -> Result<u64, String> {
        let s = self.live_session_mut(id)?;
        if !s.vectors.is_empty() {
            return Err("explicit vectors are still queued; poll them dry before switching stimulus kinds".into());
        }
        s.design_remaining += cycles;
        Ok(s.queued())
    }

    /// Queue explicit stimulus frames (`inputs × width` lane-major words
    /// per cycle). Returns the total queued cycle count.
    pub fn submit_vectors(&mut self, id: u64, frames: Vec<Vec<u64>>) -> Result<u64, String> {
        let (host_idx, width) = {
            let s = self.session(id)?;
            (s.host, s.width)
        };
        let num_inputs =
            self.hosts[host_idx].as_ref().map(|h| h.num_inputs).ok_or("host is gone")?;
        let s = self.live_session_mut(id)?;
        if s.design_remaining > 0 {
            return Err("design stimulus is still queued; poll it dry before switching stimulus kinds".into());
        }
        for (i, f) in frames.iter().enumerate() {
            if f.len() != num_inputs * width {
                return Err(format!(
                    "frame {i} has {} words, expected {} ({} inputs x {} lanes)",
                    f.len(),
                    num_inputs * width,
                    num_inputs,
                    width
                ));
            }
        }
        s.vectors.extend(frames);
        Ok(s.queued())
    }

    /// Attach a delta-waveform sink to `slice_lane` of the session. The
    /// pump samples it after every stepped cycle from then on and `poll`
    /// drains the accumulated VCD bytes incrementally; attach before the
    /// first poll for a stream bit-identical to a solo `--vcd` run (a
    /// later attach starts with a full value dump of the current state).
    pub fn attach_wave(&mut self, id: u64, slice_lane: usize) -> Result<(), String> {
        let (host_idx, lane0, width) = {
            let s = self.live_session_mut(id)?;
            (s.host, s.lane0, s.width)
        };
        if slice_lane >= width {
            return Err(format!("slice lane {slice_lane} out of range (width {width})"));
        }
        let host = self.hosts[host_idx].as_ref().ok_or("host is gone")?;
        let sink = WaveSink::attach_outputs(&host.design.ir, lane0 + slice_lane, Vec::new())
            .map_err(|e| format!("wave sink: {e}"))?;
        let s = self.sessions.get_mut(&id).expect("checked above");
        if s.wave.is_some() {
            return Err(format!("session {id} already streams a waveform"));
        }
        s.wave = Some(sink);
        Ok(())
    }

    /// Advance the session's host as far as queued stimulus (of every
    /// attached session), backpressure and the deadline allow, then
    /// drain up to `max_records` output records (and the waveform bytes,
    /// when a sink is attached).
    pub fn poll(
        &mut self,
        id: u64,
        max_records: usize,
        deadline: Instant,
    ) -> Result<PollResult, String> {
        let host_idx = self.live_session_mut(id)?.host;
        self.pump_host(host_idx, deadline)?;
        let s = self.live_session_mut(id)?;
        let n = max_records.min(s.out_buf.len());
        let records: Vec<CycleRecord> = s.out_buf.drain(..n).collect();
        Ok(PollResult {
            records,
            cycle: s.cycle,
            done: s.queued() == 0 && s.out_buf.is_empty(),
            wave_chunk: s.wave.as_mut().map(WaveSink::take_chunk),
        })
    }

    /// Step `hosts[h]` bulk-synchronously until some attached session's
    /// queue empties, a buffer fills, or the deadline passes.
    fn pump_host(&mut self, h: usize, deadline: Instant) -> Result<(), String> {
        let mut host = match self.hosts.get_mut(h).and_then(Option::take) {
            Some(host) => host,
            None => return Err("host is gone".into()),
        };
        let result = self.pump_host_inner(&mut host, deadline);
        if host.wedged {
            // a panicked simulator cannot be trusted; fail every attached
            // session and drop the host (the pool threads unwind with it)
            let why = result.clone().err().unwrap_or_else(|| "host wedged".into());
            for sid in &host.sessions {
                if let Some(s) = self.sessions.get_mut(sid) {
                    s.failed = Some(why.clone());
                }
            }
            self.hosts[h] = None;
        } else {
            self.hosts[h] = Some(host);
        }
        result
    }

    fn pump_host_inner(&mut self, host: &mut Host, deadline: Instant) -> Result<(), String> {
        let lanes = host.sig.lanes;
        let mut frame = vec![0u64; host.num_inputs * lanes];
        let mut wave_buf: Vec<(String, u64)> = Vec::new();
        loop {
            // how far can this bulk-synchronous step go?
            let mut can = u64::MAX;
            for sid in &host.sessions {
                let s = &self.sessions[sid];
                can = can.min(s.queued());
                if s.out_buf.len() >= OUT_BUF_CAP {
                    can = 0;
                }
            }
            if can == 0 || host.sessions.is_empty() || Instant::now() >= deadline {
                return Ok(());
            }

            // one cycle: scatter each session's next frame into its lanes
            frame.fill(0);
            for sid in host.sessions.clone() {
                let s = self.sessions.get_mut(&sid).expect("attached session exists");
                let (lane0, width) = (s.lane0, s.width);
                if s.design_remaining > 0 {
                    s.design_remaining -= 1;
                    if s.gens.is_none() {
                        let design =
                            catalog(&s.design).ok_or("design vanished from the catalog")?;
                        s.gens = Some(
                            (0..width).map(|j| design.make_stimulus_for_lane(j)).collect(),
                        );
                    }
                    let gens = s.gens.as_mut().expect("just installed");
                    // fast-forward to the session cycle (restored
                    // sessions; vector/design interleavings)
                    while s.gen_drawn < s.cycle {
                        for g in gens.iter_mut() {
                            let _ = g(s.gen_drawn);
                        }
                        s.gen_drawn += 1;
                    }
                    for (j, g) in gens.iter_mut().enumerate() {
                        let f = g(s.cycle);
                        debug_assert_eq!(f.len(), host.num_inputs);
                        for (i, &v) in f.iter().enumerate() {
                            frame[i * lanes + lane0 + j] = v;
                        }
                    }
                    s.gen_drawn += 1;
                } else {
                    let f = s.vectors.pop_front().expect("queued() said so");
                    for i in 0..host.num_inputs {
                        for j in 0..width {
                            frame[i * lanes + lane0 + j] = f[i * width + j];
                        }
                    }
                }
            }

            let stepped =
                catch_unwind(AssertUnwindSafe(|| host.sim.step(&frame))).map_err(|p| {
                    let msg = p
                        .downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| p.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "panic".into());
                    format!("host wedged mid-step: {msg}")
                });
            if let Err(e) = stepped {
                host.wedged = true;
                return Err(e);
            }

            for sid in host.sessions.clone() {
                let s = self.sessions.get_mut(&sid).expect("attached session exists");
                s.cycle += 1;
                let rec = CycleRecord { cycle: s.cycle, out: host.sim.lane_outputs(s.lane0) };
                s.out_buf.push_back(rec);
                if let Some(w) = s.wave.as_mut() {
                    // timestamped by the *session* cycle, matching the
                    // `cyc + 1` numbering of `rteaal sim --vcd`
                    w.sample_parallel(s.cycle, &host.sim, &mut wave_buf)
                        .expect("Vec<u8> writes are infallible");
                }
            }
        }
    }

    /// Current design outputs of one slice lane (no pumping).
    pub fn lane_outputs(&self, id: u64, slice_lane: usize) -> Result<Vec<(String, u64)>, String> {
        let s = self.session(id)?;
        if slice_lane >= s.width {
            return Err(format!("slice lane {slice_lane} out of range (width {})", s.width));
        }
        let host = self.hosts[s.host].as_ref().ok_or("host is gone")?;
        Ok(host.sim.lane_outputs(s.lane0 + slice_lane))
    }

    /// Committed register values of the session's lanes, as
    /// `(slot, per-slice-lane values)` in `ir.commits` order — the
    /// complete architectural state, exposed for differential tests.
    pub fn session_regs(&self, id: u64) -> Result<Vec<(u32, Vec<u64>)>, String> {
        let s = self.session(id)?;
        let host = self.hosts[s.host].as_ref().ok_or("host is gone")?;
        Ok(host
            .design
            .ir
            .commits
            .iter()
            .map(|&(reg, _, _)| {
                (reg, (0..s.width).map(|j| host.sim.reg_lane(reg, s.lane0 + j)).collect())
            })
            .collect())
    }

    /// Snapshot a session to `path`. Returns `(bytes written, cycle)`.
    pub fn checkpoint(&mut self, id: u64, path: &Path) -> Result<(u64, u64), String> {
        let snap = self.snapshot(id)?;
        let bytes = snap.write_file(path).map_err(|e| e.to_string())?;
        Ok((bytes, snap.cycle()))
    }

    /// Build the snapshot: full host state when the session owns every
    /// lane of its host, otherwise the committed registers of its slice.
    pub fn snapshot(&self, id: u64) -> Result<Snapshot, String> {
        let s = self.session(id)?;
        if let Some(why) = &s.failed {
            return Err(format!("session {id} is failed: {why}"));
        }
        let host = self.hosts[s.host].as_ref().ok_or("host is gone")?;
        let whole_host = host.sessions.len() == 1 && s.width == host.sig.lanes;
        let config = SnapshotConfig {
            design_key: host.design.key.clone(),
            design_name: host.design.design_name.clone(),
            kernel: host.sig.kernel.name().to_string(),
            partitioner: host.design.partitioner.name().to_string(),
            parts: host.sig.parts as u64,
            lanes: if whole_host { host.sig.lanes as u64 } else { s.width as u64 },
            sparse: host.sig.sparse,
            fuse: host.design.fuse,
        };
        let payload = if whole_host {
            SnapshotPayload::FullHost { cycle: s.cycle, state: host.sim.export_state() }
        } else {
            let regs = host
                .design
                .ir
                .commits
                .iter()
                .map(|&(reg, _, _)| {
                    let values =
                        (0..s.width).map(|j| host.sim.reg_lane(reg, s.lane0 + j)).collect();
                    (reg, values)
                })
                .collect();
            SnapshotPayload::LaneSlice { cycle: s.cycle, regs }
        };
        Ok(Snapshot { config, payload })
    }

    /// Restore a snapshot file into a **new** session (the checkpointed
    /// one, if still open, is untouched).
    pub fn restore(&mut self, path: &Path) -> Result<(u64, u64), String> {
        let snap = Snapshot::read_file(path).map_err(|e| e.to_string())?;
        self.restore_snapshot(&snap)
    }

    pub fn restore_snapshot(&mut self, snap: &Snapshot) -> Result<(u64, u64), String> {
        let kernel = KernelConfig::parse(&snap.config.kernel)
            .ok_or_else(|| format!("snapshot names unknown kernel '{}'", snap.config.kernel))?;
        let partitioner = PartitionerKind::parse(&snap.config.partitioner).ok_or_else(|| {
            format!("snapshot names unknown partitioner '{}'", snap.config.partitioner)
        })?;
        let width = snap.config.lanes as usize;
        let cfg = SessionConfig {
            design: snap.config.design_name.clone(),
            kernel,
            parts: snap.config.parts as usize,
            // a full-host snapshot needs a fresh host of the same width;
            // a lane slice packs wherever its width fits
            lanes: snap.config.lanes as usize,
            width,
            sparse: snap.config.sparse,
            fuse: snap.config.fuse,
            partitioner,
            // restores re-open by exact content key (checked below) —
            // the delta reuse path would commit a *different* key
            incremental: false,
            verify: false,
        };
        match &snap.payload {
            SnapshotPayload::FullHost { cycle, state } => {
                // build an unshared host by opening at full width, then
                // overwrite its entire dynamic state
                let outcome = self.open(&cfg)?;
                if outcome.report.key != snap.config.design_key {
                    self.force_close(outcome.session);
                    return Err(
                        "snapshot was taken under a different design or configuration (cache key mismatch)"
                            .into(),
                    );
                }
                let host_idx = self.sessions[&outcome.session].host;
                let host = self.hosts[host_idx].as_mut().expect("just opened");
                if host.sessions.len() != 1 {
                    // cannot happen: open() at width == lanes never packs
                    self.force_close(outcome.session);
                    return Err("full-host restore landed on a shared host".into());
                }
                if let Err(e) = host.sim.import_state(state) {
                    self.force_close(outcome.session);
                    return Err(format!("snapshot rejected: {e}"));
                }
                let s = self.sessions.get_mut(&outcome.session).expect("just opened");
                s.cycle = *cycle;
                Ok((outcome.session, *cycle))
            }
            SnapshotPayload::LaneSlice { cycle, regs } => {
                let design = catalog(&cfg.design)
                    .ok_or_else(|| format!("unknown design '{}'", cfg.design))?;
                // validate against the design's commit set *before*
                // opening, so a bogus snapshot allocates nothing
                let (cached, _) =
                    self.cache.open_design(&design, cfg.fuse, cfg.parts, cfg.partitioner)?;
                if cached.key != snap.config.design_key {
                    return Err(
                        "snapshot was taken under a different design or configuration (cache key mismatch)"
                            .into(),
                    );
                }
                let commit_slots: HashSet<u32> =
                    cached.ir.commits.iter().map(|&(reg, _, _)| reg).collect();
                if regs.len() != commit_slots.len() {
                    return Err(format!(
                        "snapshot holds {} registers, design has {}",
                        regs.len(),
                        commit_slots.len()
                    ));
                }
                for (slot, values) in regs {
                    if !commit_slots.contains(slot) {
                        return Err(format!("snapshot register slot {slot} is not a design register"));
                    }
                    if values.len() != width {
                        return Err("snapshot register lane count disagrees with its width".into());
                    }
                }
                let outcome = self.open(&cfg)?;
                let host_idx = self.sessions[&outcome.session].host;
                let host = self.hosts[host_idx].as_mut().expect("just opened");
                for (slot, values) in regs {
                    for (j, &v) in values.iter().enumerate() {
                        host.sim.poke_lane(*slot, outcome.lane0 + j, v);
                    }
                }
                let s = self.sessions.get_mut(&outcome.session).expect("just opened");
                s.cycle = *cycle;
                Ok((outcome.session, *cycle))
            }
        }
    }

    /// Close a session, freeing its lanes; an emptied host is dropped.
    pub fn close(&mut self, id: u64) -> Result<(), String> {
        let s = self.sessions.remove(&id).ok_or_else(|| format!("unknown session {id}"))?;
        if let Some(host) = self.hosts.get_mut(s.host).and_then(Option::as_mut) {
            host.occupied[s.lane0..s.lane0 + s.width].fill(false);
            host.sessions.retain(|&sid| sid != id);
            if host.sessions.is_empty() {
                self.hosts[s.host] = None;
            }
        }
        Ok(())
    }

    fn force_close(&mut self, id: u64) {
        let _ = self.close(id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn far() -> Instant {
        Instant::now() + Duration::from_secs(300)
    }

    fn mgr() -> SessionManager {
        SessionManager::new(None, 8)
    }

    fn open_fir8(m: &mut SessionManager, lanes: usize, width: usize) -> OpenOutcome {
        m.open(&SessionConfig {
            design: "fir8".into(),
            lanes,
            width,
            ..SessionConfig::default()
        })
        .unwrap()
    }

    /// Tentpole acceptance: two same-design sessions pack onto ONE
    /// B-lane host, and each is bit-identical, cycle by cycle, to a solo
    /// scalar run of the design's canonical stimulus.
    #[test]
    fn packed_sessions_match_solo_runs_bit_for_bit() {
        use crate::kernels::build_with_oim;
        use crate::sim::Simulator;

        let mut m = mgr();
        let a = open_fir8(&mut m, 4, 1);
        let b = open_fir8(&mut m, 4, 1);
        assert_eq!(a.host, b.host, "same signature must pack onto one host");
        assert_ne!(a.lane0, b.lane0, "distinct lanes");
        assert_eq!(m.host_count(), 1);

        // a third session too wide for the remaining lanes gets its own host
        let c = open_fir8(&mut m, 4, 3);
        assert_ne!(c.host, a.host);
        assert_eq!(m.host_count(), 2);

        let cycles = 50u64;
        m.submit_design(a.session, cycles).unwrap();
        m.submit_design(b.session, cycles).unwrap();
        let ra = m.poll(a.session, usize::MAX, far()).unwrap();
        let rb = m.poll(b.session, usize::MAX, far()).unwrap();
        assert!(ra.done && rb.done);
        assert_eq!(ra.records.len(), cycles as usize);

        // solo reference: the canonical scalar run
        let d = catalog("fir8").unwrap();
        let c2 = crate::coordinator::compile::compile_design(
            &d,
            crate::coordinator::compile::CompileOpts::default(),
        );
        let kernel = build_with_oim(KernelConfig::PSU, &c2.ir, &c2.oim);
        let mut solo = Simulator::new(kernel, d.make_stimulus());
        for (i, rec) in ra.records.iter().enumerate() {
            solo.run(1);
            assert_eq!(rec.cycle, i as u64 + 1);
            assert_eq!(rec.out, solo.outputs(), "session A cycle {}", rec.cycle);
        }
        // both width-1 sessions replay the same canonical stream
        assert_eq!(ra.records, rb.records);
    }

    /// An empty-queue session stalls its host-mates (the documented
    /// bulk-synchronous packing rule), and submitting releases them.
    #[test]
    fn empty_queue_session_stalls_the_host() {
        let mut m = mgr();
        let a = open_fir8(&mut m, 4, 1);
        let b = open_fir8(&mut m, 4, 1);
        m.submit_design(a.session, 10).unwrap();
        let ra = m.poll(a.session, usize::MAX, far()).unwrap();
        assert_eq!(ra.cycle, 0, "host-mate with an empty queue stalls the host");
        assert!(!ra.done);
        m.submit_design(b.session, 10).unwrap();
        let ra = m.poll(a.session, usize::MAX, far()).unwrap();
        assert_eq!(ra.cycle, 10);
        assert!(ra.done);
    }

    /// Explicit vectors drive exactly the given frames; a width mismatch
    /// is rejected with a structured error.
    #[test]
    fn vector_stimulus_validated_and_applied() {
        let mut m = mgr();
        let a = m
            .open(&SessionConfig {
                design: "counter".into(),
                lanes: 2,
                width: 1,
                ..SessionConfig::default()
            })
            .unwrap();
        // counter inputs: (en, clear) — one frame per cycle, width 1
        let bad = vec![vec![1u64, 0, 7]];
        let err = m.submit_vectors(a.session, bad).unwrap_err();
        assert!(err.contains("expected 2"), "{err}");
        m.submit_vectors(a.session, vec![vec![1, 0]; 5]).unwrap();
        let r = m.poll(a.session, usize::MAX, far()).unwrap();
        assert_eq!(r.records.last().unwrap().out[0].1, 5, "counter counted the 5 enables");
    }

    /// Closing a session frees its lanes for reuse, and the reused lanes
    /// start from clean architectural state.
    #[test]
    fn closed_lanes_are_reused_clean() {
        let mut m = mgr();
        let a = m
            .open(&SessionConfig {
                design: "counter".into(),
                lanes: 2,
                width: 1,
                ..SessionConfig::default()
            })
            .unwrap();
        let b = m
            .open(&SessionConfig {
                design: "counter".into(),
                lanes: 2,
                width: 1,
                ..SessionConfig::default()
            })
            .unwrap();
        // advance both so the lanes hold nonzero counts
        m.submit_vectors(a.session, vec![vec![1, 0]; 4]).unwrap();
        m.submit_vectors(b.session, vec![vec![1, 0]; 4]).unwrap();
        assert!(m.poll(a.session, usize::MAX, far()).unwrap().done);
        m.close(a.session).unwrap();
        let c = m
            .open(&SessionConfig {
                design: "counter".into(),
                lanes: 2,
                width: 1,
                ..SessionConfig::default()
            })
            .unwrap();
        assert_eq!(c.lane0, a.lane0, "freed lane reused");
        assert_eq!(c.host, b.host, "existing host reused");
        m.submit_vectors(c.session, vec![vec![1, 0]; 2]).unwrap();
        m.submit_vectors(b.session, vec![vec![1, 0]; 2]).unwrap();
        let rc = m.poll(c.session, usize::MAX, far()).unwrap();
        assert_eq!(rc.records.last().unwrap().out[0].1, 2, "fresh session restarted from init");
        // host-mate B kept its own state: 4 + 2 enables
        assert_eq!(m.lane_outputs(b.session, 0).unwrap()[0].1, 6);
    }

    /// The output-buffer cap backpressures the pump instead of growing
    /// without bound; draining resumes progress.
    #[test]
    fn out_buf_cap_backpressures() {
        let mut m = mgr();
        let a = m
            .open(&SessionConfig {
                design: "counter".into(),
                lanes: 1,
                width: 1,
                ..SessionConfig::default()
            })
            .unwrap();
        let total = OUT_BUF_CAP as u64 + 100;
        m.submit_vectors(a.session, vec![vec![1, 0]; total as usize]).unwrap();
        let r = m.poll(a.session, 0, far()).unwrap();
        assert_eq!(r.cycle, OUT_BUF_CAP as u64, "pump stopped at the cap");
        assert!(!r.done);
        // poll pumps before draining, so the cap-full buffer blocks this
        // pump; the drain releases the backpressure for the next one
        let r = m.poll(a.session, usize::MAX, far()).unwrap();
        assert_eq!(r.records.len(), OUT_BUF_CAP);
        let r = m.poll(a.session, usize::MAX, far()).unwrap();
        assert_eq!(r.cycle, total);
        assert_eq!(r.records.len(), 100);
        assert!(r.done);
    }
}
