//! Versioned binary checkpoints: snapshot a running session's slot
//! files, activity-tracker masks and cycle counters to disk; restore
//! bit-identically mid-run.
//!
//! Two snapshot kinds share one envelope:
//!
//! * [`SnapshotPayload::FullHost`] — the host simulator's complete
//!   [`SimState`] (every partition's lane-major slot file, kernel
//!   activity dumps, the RUM shadow, boundary-detection buffers, the
//!   partition tracker and cycle counter). Taken when the session is the
//!   sole occupant of its host; restore is `import_state`, exact by
//!   construction.
//! * [`SnapshotPayload::LaneSlice`] — the committed register values of
//!   just the session's lanes. Taken when the host is shared (the other
//!   sessions' lanes are not this session's state to save). Registers
//!   are the *complete* architectural state of these designs (every
//!   combinational slot is recomputed from them, and there are no
//!   memories), so a restore that pokes each register and replays the
//!   targeted activity wake is also exact — validated bit-for-bit by the
//!   round-trip tests.
//!
//! Layout (all integers little-endian; strings length-prefixed):
//!
//! ```text
//! "RTAL"  u16 version  u8 kind
//! config: design_key, design_name, kernel, partitioner,
//!         u64 parts, u64 lanes, u8 sparse, u8 fuse
//! payload (kind 0): u64 cycle, SimState buffers, each with a u64 length prefix
//! payload (kind 1): u64 cycle, u64 regs; per reg: u64 slot + lanes values
//! trailer: u64 FNV-1a over every preceding byte
//! ```
//!
//! Every read is bounds-checked through a cursor; a corrupt or truncated
//! file surfaces as [`SnapshotError::Corrupt`] — a structured error the
//! service maps to an error reply, never a panic.

use std::fmt;
use std::path::Path;

use crate::coordinator::parallel::SimState;

pub const SNAPSHOT_MAGIC: [u8; 4] = *b"RTAL";
pub const SNAPSHOT_VERSION: u16 = 1;

/// Checkpoint failure: an I/O problem or a malformed snapshot.
#[derive(Debug)]
pub enum SnapshotError {
    Io(std::io::Error),
    Corrupt(String),
}

impl fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SnapshotError::Io(e) => write!(f, "snapshot i/o error: {e}"),
            SnapshotError::Corrupt(m) => write!(f, "corrupt snapshot: {m}"),
        }
    }
}

impl std::error::Error for SnapshotError {}

impl From<std::io::Error> for SnapshotError {
    fn from(e: std::io::Error) -> Self {
        SnapshotError::Io(e)
    }
}

fn corrupt(m: impl Into<String>) -> SnapshotError {
    SnapshotError::Corrupt(m.into())
}

/// The configuration a snapshot was taken under. Restore refuses a
/// mismatch up front (and `import_state` re-validates every buffer
/// shape underneath).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SnapshotConfig {
    /// Design-cache content key ([`crate::service::cache::design_key`]).
    pub design_key: String,
    pub design_name: String,
    /// Kernel configuration name (`PSU`, `TI`, ...).
    pub kernel: String,
    /// Partitioner name (`mincut` / `rr`), as `PartitionerKind::name`.
    pub partitioner: String,
    pub parts: u64,
    /// Host lane count B (full-host) or the slice width (lane-slice).
    pub lanes: u64,
    pub sparse: bool,
    /// Mux-fusion compile flag — with the design name and partitioner
    /// config it pins the cache key restore must re-open under.
    pub fuse: bool,
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SnapshotPayload {
    /// Complete host dynamic state; `cycle` is the *session* cycle count
    /// (== the host's, for a sole-occupant host).
    FullHost { cycle: u64, state: SimState },
    /// Per-register lane values of one session's lane slice:
    /// `(register slot, one committed value per slice lane)`.
    LaneSlice { cycle: u64, regs: Vec<(u32, Vec<u64>)> },
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Snapshot {
    pub config: SnapshotConfig,
    pub payload: SnapshotPayload,
}

// ---- encoding ----

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn text(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }
    fn words(&mut self, ws: &[u64]) {
        self.u64(ws.len() as u64);
        for &w in ws {
            self.u64(w);
        }
    }
    fn bools(&mut self, bs: &[bool]) {
        self.u64(bs.len() as u64);
        for &b in bs {
            self.u8(b as u8);
        }
    }
}

use crate::util::fnv::fnv1a;

// ---- decoding ----

/// Bounds-checked little-endian cursor; every accessor fails with a
/// positioned [`SnapshotError::Corrupt`] instead of slicing out of range.
struct Dec<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], SnapshotError> {
        if self.pos + n > self.bytes.len() {
            return Err(corrupt(format!(
                "truncated at byte {} (wanted {n} more of {})",
                self.pos,
                self.bytes.len()
            )));
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8, SnapshotError> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16, SnapshotError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, SnapshotError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    /// Length-prefixed count, sanity-capped by the remaining bytes so a
    /// corrupt length cannot trigger an absurd allocation.
    fn len(&mut self, elem_bytes: usize) -> Result<usize, SnapshotError> {
        let n = self.u64()? as usize;
        let remaining = self.bytes.len() - self.pos;
        if n.saturating_mul(elem_bytes) > remaining {
            return Err(corrupt(format!("length {n} exceeds remaining {remaining} bytes")));
        }
        Ok(n)
    }
    fn text(&mut self) -> Result<String, SnapshotError> {
        let n = self.len(1)?;
        let s = self.take(n)?;
        String::from_utf8(s.to_vec()).map_err(|_| corrupt("non-UTF-8 string"))
    }
    fn words(&mut self) -> Result<Vec<u64>, SnapshotError> {
        let n = self.len(8)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u64()?);
        }
        Ok(out)
    }
    fn bools(&mut self) -> Result<Vec<bool>, SnapshotError> {
        let n = self.len(1)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(match self.u8()? {
                0 => false,
                1 => true,
                other => return Err(corrupt(format!("bool byte {other}"))),
            });
        }
        Ok(out)
    }
}

impl Snapshot {
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut e = Enc { buf: Vec::new() };
        e.buf.extend_from_slice(&SNAPSHOT_MAGIC);
        e.u16(SNAPSHOT_VERSION);
        let kind = match self.payload {
            SnapshotPayload::FullHost { .. } => 0u8,
            SnapshotPayload::LaneSlice { .. } => 1u8,
        };
        e.u8(kind);
        e.text(&self.config.design_key);
        e.text(&self.config.design_name);
        e.text(&self.config.kernel);
        e.text(&self.config.partitioner);
        e.u64(self.config.parts);
        e.u64(self.config.lanes);
        e.u8(self.config.sparse as u8);
        e.u8(self.config.fuse as u8);
        match &self.payload {
            SnapshotPayload::FullHost { cycle, state } => {
                e.u64(*cycle);
                e.u64(state.cycles_total);
                e.u64(state.lanes as u64);
                e.u64(state.part_slots.len() as u64);
                for p in &state.part_slots {
                    e.words(p);
                }
                e.u64(state.part_activity.len() as u64);
                for p in &state.part_activity {
                    e.words(p);
                }
                e.words(&state.shadow);
                e.words(&state.prev_inputs);
                e.words(&state.tracker_state);
                e.bools(&state.poke_dirty);
            }
            SnapshotPayload::LaneSlice { cycle, regs } => {
                e.u64(*cycle);
                e.u64(self.config.lanes);
                e.u64(regs.len() as u64);
                for (slot, values) in regs {
                    e.u64(*slot as u64);
                    for &v in values {
                        e.u64(v);
                    }
                }
            }
        }
        let sum = fnv1a(&e.buf);
        e.u64(sum);
        e.buf
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Self, SnapshotError> {
        if bytes.len() < SNAPSHOT_MAGIC.len() + 2 + 1 + 8 {
            return Err(corrupt("file shorter than the fixed envelope"));
        }
        let (body, tail) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(tail.try_into().unwrap());
        if fnv1a(body) != stored {
            return Err(corrupt("checksum mismatch (truncated or bit-flipped)"));
        }
        let mut d = Dec { bytes: body, pos: 0 };
        if d.take(4)? != SNAPSHOT_MAGIC {
            return Err(corrupt("bad magic (not an rteaal snapshot)"));
        }
        let version = d.u16()?;
        if version != SNAPSHOT_VERSION {
            return Err(corrupt(format!(
                "snapshot version {version}, this build reads {SNAPSHOT_VERSION}"
            )));
        }
        let kind = d.u8()?;
        let config = SnapshotConfig {
            design_key: d.text()?,
            design_name: d.text()?,
            kernel: d.text()?,
            partitioner: d.text()?,
            parts: d.u64()?,
            lanes: d.u64()?,
            sparse: match d.u8()? {
                0 => false,
                1 => true,
                other => return Err(corrupt(format!("sparse byte {other}"))),
            },
            fuse: match d.u8()? {
                0 => false,
                1 => true,
                other => return Err(corrupt(format!("fuse byte {other}"))),
            },
        };
        let payload = match kind {
            0 => {
                let cycle = d.u64()?;
                let cycles_total = d.u64()?;
                let lanes = d.u64()? as usize;
                let np = d.len(8)?;
                let mut part_slots = Vec::with_capacity(np);
                for _ in 0..np {
                    part_slots.push(d.words()?);
                }
                let na = d.len(8)?;
                if na != np {
                    return Err(corrupt(format!("{np} slot files but {na} activity dumps")));
                }
                let mut part_activity = Vec::with_capacity(na);
                for _ in 0..na {
                    part_activity.push(d.words()?);
                }
                let shadow = d.words()?;
                let prev_inputs = d.words()?;
                let tracker_state = d.words()?;
                let poke_dirty = d.bools()?;
                SnapshotPayload::FullHost {
                    cycle,
                    state: SimState {
                        cycles_total,
                        lanes,
                        part_slots,
                        part_activity,
                        shadow,
                        prev_inputs,
                        tracker_state,
                        poke_dirty,
                    },
                }
            }
            1 => {
                let cycle = d.u64()?;
                let width = d.u64()? as usize;
                if width as u64 != config.lanes {
                    return Err(corrupt("slice width disagrees with the config block"));
                }
                if width == 0 {
                    return Err(corrupt("zero-lane slice"));
                }
                let nregs = d.len(8 + 8 * width)?;
                let mut regs = Vec::with_capacity(nregs);
                for _ in 0..nregs {
                    let slot = d.u64()?;
                    if slot > u32::MAX as u64 {
                        return Err(corrupt(format!("slot id {slot} overflows u32")));
                    }
                    let mut values = Vec::with_capacity(width);
                    for _ in 0..width {
                        values.push(d.u64()?);
                    }
                    regs.push((slot as u32, values));
                }
                SnapshotPayload::LaneSlice { cycle, regs }
            }
            other => return Err(corrupt(format!("unknown snapshot kind {other}"))),
        };
        if d.pos != body.len() {
            return Err(corrupt(format!("{} trailing bytes after the payload", body.len() - d.pos)));
        }
        Ok(Snapshot { config, payload })
    }

    /// Serialize to `path`; returns the byte count written.
    pub fn write_file(&self, path: &Path) -> Result<u64, SnapshotError> {
        let bytes = self.to_bytes();
        std::fs::write(path, &bytes)?;
        Ok(bytes.len() as u64)
    }

    pub fn read_file(path: &Path) -> Result<Self, SnapshotError> {
        let bytes = std::fs::read(path)?;
        Self::from_bytes(&bytes)
    }

    /// The session cycle count recorded at snapshot time.
    pub fn cycle(&self) -> u64 {
        match &self.payload {
            SnapshotPayload::FullHost { cycle, .. } => *cycle,
            SnapshotPayload::LaneSlice { cycle, .. } => *cycle,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_full() -> Snapshot {
        Snapshot {
            config: SnapshotConfig {
                design_key: "abc123".into(),
                design_name: "fir8".into(),
                kernel: "PSU".into(),
                partitioner: "mincut".into(),
                parts: 2,
                lanes: 4,
                sparse: true,
                fuse: true,
            },
            payload: SnapshotPayload::FullHost {
                cycle: 13,
                state: SimState {
                    cycles_total: 13,
                    lanes: 4,
                    part_slots: vec![vec![1, 2, 3, 4], vec![5, 6]],
                    part_activity: vec![vec![7], vec![]],
                    shadow: vec![8, 9],
                    prev_inputs: vec![10],
                    tracker_state: vec![11, 12],
                    poke_dirty: vec![true, false],
                },
            },
        }
    }

    fn sample_slice() -> Snapshot {
        Snapshot {
            config: SnapshotConfig {
                design_key: "k".into(),
                design_name: "counter".into(),
                kernel: "TI".into(),
                partitioner: "rr".into(),
                parts: 1,
                lanes: 2,
                sparse: false,
                fuse: false,
            },
            payload: SnapshotPayload::LaneSlice {
                cycle: 7,
                regs: vec![(3, vec![0xAA, 0xBB]), (9, vec![1, u64::MAX])],
            },
        }
    }

    #[test]
    fn both_kinds_roundtrip_exactly() {
        for snap in [sample_full(), sample_slice()] {
            let bytes = snap.to_bytes();
            let back = Snapshot::from_bytes(&bytes).unwrap();
            assert_eq!(back, snap);
            assert_eq!(back.cycle(), snap.cycle());
        }
    }

    /// Satellite: corrupted and truncated snapshots are rejected with a
    /// structured error — every prefix of the file and every single-bit
    /// flip fails cleanly, none panics or parses.
    #[test]
    fn corruption_and_truncation_rejected_structurally() {
        let bytes = sample_full().to_bytes();
        for cut in 0..bytes.len() {
            let err = Snapshot::from_bytes(&bytes[..cut]).unwrap_err();
            assert!(matches!(err, SnapshotError::Corrupt(_)), "prefix {cut}: {err}");
        }
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0x10;
            assert!(
                Snapshot::from_bytes(&bad).is_err(),
                "bit flip at byte {i} went unnoticed"
            );
        }
    }

    #[test]
    fn wrong_magic_and_version_named_in_error() {
        let mut bytes = sample_slice().to_bytes();
        bytes[0] = b'X';
        // refresh the checksum so the magic check itself is exercised
        let n = bytes.len();
        let sum = super::fnv1a(&bytes[..n - 8]);
        bytes[n - 8..].copy_from_slice(&sum.to_le_bytes());
        let err = Snapshot::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");

        let mut bytes = sample_slice().to_bytes();
        bytes[4] = 0xEE;
        let n = bytes.len();
        let sum = super::fnv1a(&bytes[..n - 8]);
        bytes[n - 8..].copy_from_slice(&sum.to_le_bytes());
        let err = Snapshot::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
    }

    /// A file whose length prefix claims far more elements than the file
    /// holds is caught by the remaining-bytes cap (no multi-gigabyte
    /// `Vec::with_capacity` from attacker-controlled counts).
    #[test]
    fn absurd_length_prefix_rejected_without_allocation() {
        let mut e = super::Enc { buf: Vec::new() };
        e.buf.extend_from_slice(&SNAPSHOT_MAGIC);
        e.u16(SNAPSHOT_VERSION);
        e.u8(1);
        e.text("k");
        e.text("d");
        e.text("PSU");
        e.text("mincut");
        e.u64(1);
        e.u64(1);
        e.u8(0); // sparse
        e.u8(0); // fuse
        e.u64(0); // cycle
        e.u64(1); // width
        e.u64(u64::MAX); // regs "count"
        let sum = super::fnv1a(&e.buf);
        e.u64(sum);
        let err = Snapshot::from_bytes(&e.buf).unwrap_err();
        assert!(matches!(err, SnapshotError::Corrupt(_)), "{err}");
    }
}
