//! Simulation service: `rteaal serve` — a long-running simulation daemon
//! with a content-addressed design cache, concurrent lane-packed
//! sessions, and checkpoint/restore.
//!
//! The batch executors amortize one OIM walk over `B` stimulus lanes;
//! this module amortizes one *compiled design* over many client
//! sessions, and one *process* over many designs:
//!
//! * [`cache`] — the *content-addressed design cache*. Opening a design
//!   fingerprints the input graph together with the compile and
//!   partitioner configuration; compiled artifacts (OIM, IR sidecar,
//!   group dependency graph, register-ownership map) are persisted under
//!   that key and fronted by an in-memory LRU, so a repeat open is a
//!   hash lookup plus (at worst) a JSON load — never a re-compile and
//!   never a re-run of the min-cut search.
//! * [`session`] — the *session manager*. Each session owns a slice of
//!   lanes on a shared `P×B` host simulator; small same-design sessions
//!   are packed onto one B-lane kernel and isolated by lane masks, so
//!   `K` sessions cost one OIM walk, not `K`. Hosts run on the existing
//!   persistent worker pool; no per-session threads are spawned.
//! * [`checkpoint`] — *versioned binary snapshots*. A session (or a
//!   whole host) snapshots its slot files, activity-tracker masks and
//!   cycle counters to disk and restores bit-identically mid-run;
//!   corrupt or truncated snapshots are rejected with a structured
//!   error, never a panic.
//! * [`proto`] / [`api`] — the *job API*: newline-delimited JSON over
//!   stdio or a Unix socket (concurrent connections, one reader thread
//!   each, per-connection idle timeout), with per-request time budgets
//!   and structured error replies so a wedged session or client
//!   degrades gracefully instead of hanging the server.
//!
//! # Request/response schema
//!
//! One JSON object per line in both directions. Every request carries a
//! client-chosen `id`, echoed on the reply. Replies are
//! `{"id":N,"ok":true,...}` or
//! `{"id":N,"ok":false,"error":{"code":"...","message":"..."}}`.
//!
//! | verb         | request fields                                              | reply fields |
//! |--------------|-------------------------------------------------------------|--------------|
//! | `open`       | `design`; optional `kernel` (default `PSU`), `parts` (1), `lanes` (1, the host width B), `width` (1, lanes for *this* session), `sparse` (false), `fuse` (true), `incremental` (false, route an exact-key miss through the cone-delta reuse path), `verify` (false, run the static artifact verifier ([`crate::analysis`]) on this open; an error-severity finding fails the open with `bad-config`) | `session`, `cache` `{key, hit, source, incremental, reused_groups, rebuilt_groups, open_ms, cold_compile_ms}`, `host`, `lane0` |
//! | `submit`     | `session`; stimulus: `{"kind":"design","cycles":N}` or `{"kind":"vectors","vectors":[[...],...]}` (one inner array per cycle, `inputs × width` lane-major words) | `queued` (cycles now queued) |
//! | `poll`       | `session`; optional `max_cycles`                            | `cycles` (per-cycle output records drained), `cycle` (session cycle count), `done`; with a `wave` sink attached also `wave` (incremental VCD chunk, possibly empty) |
//! | `wave`       | `session`; optional `lane` (0, a *slice* lane of the session) | `wave` (true), `lane` |
//! | `checkpoint` | `session`, `path`                                           | `path`, `bytes`, `cycle` |
//! | `restore`    | `path`; optional `design` override check                    | `session` (a **new** session), `cycle` |
//! | `close`      | `session`                                                   | `closed` |
//! | `stats`      | —                                                           | `cache` `{mem_hits, disk_hits, misses, incremental, resident}` (`incremental` counts misses answered by the cone-delta reuse path), `hosts`, `sessions`, and `lanes` — per-session packed-lane occupancy rows `{session, host, lane0, width, host_lanes}` sorted by session id |
//!
//! `wave` attaches an activity-gated delta-waveform sink
//! ([`crate::sim::WaveSink`]) to one slice lane; from then on every
//! `poll` reply carries the VCD bytes produced since the previous poll
//! as a JSON string. Chunks are *not* standalone VCD documents — only
//! their concatenation is, and it is byte-identical to a solo
//! `rteaal sim --parts P --vcd` run of the same lane when the sink is
//! attached before the first poll. Quiescent cycles (no lane activity)
//! contribute zero bytes.
//!
//! Error codes: `bad-request` (malformed JSON or fields), `unknown-verb`,
//! `unknown-design`, `unknown-session`, `bad-config` (lane overflow,
//! unsupported kernel), `snapshot` (corrupt/unreadable checkpoint), `io`,
//! `timeout` (per-request budget exceeded), `wedged` (the session's host
//! panicked; the session is failed but the server keeps running).
//!
//! # Worked transcript
//!
//! ```text
//! → {"id":1,"verb":"open","design":"fir8","kernel":"PSU","lanes":8}
//! ← {"id":1,"ok":true,"session":0,"cache":{"key":"0f3a...","hit":false,"source":"compiled","incremental":false,"reused_groups":0,"rebuilt_groups":0,"open_ms":412.0,"cold_compile_ms":412.0},"host":0,"lane0":0}
//! → {"id":2,"verb":"open","design":"fir8","kernel":"PSU","lanes":8}
//! ← {"id":2,"ok":true,"session":1,"cache":{"key":"0f3a...","hit":true,"source":"memory","open_ms":0.1,...},"host":0,"lane0":1}
//! → {"id":3,"verb":"wave","session":0}
//! ← {"id":3,"ok":true,"wave":true,"lane":0}
//! → {"id":3,"verb":"submit","session":0,"stimulus":{"kind":"design","cycles":100}}
//! ← {"id":3,"ok":true,"queued":100}
//! → {"id":4,"verb":"poll","session":0}
//! ← {"id":4,"ok":true,"cycle":100,"done":true,"cycles":[{"cycle":1,"out":{"y":"0x2a"}},...],"wave":"$timescale 1ns $end\n...#1\nb101010 a\n..."}
//! → {"id":5,"verb":"checkpoint","session":0,"path":"/tmp/s0.rtal"}
//! ← {"id":5,"ok":true,"path":"/tmp/s0.rtal","bytes":1832,"cycle":100}
//! → {"id":6,"verb":"restore","path":"/tmp/s0.rtal"}
//! ← {"id":6,"ok":true,"session":2,"cycle":100}
//! → {"id":7,"verb":"close","session":0}
//! ← {"id":7,"ok":true,"closed":0}
//! ```
//!
//! # Cache directory layout
//!
//! ```text
//! <cache-dir>/<key>/          key = 128-bit FNV-1a fingerprint (hex) of
//!                             the input graph + fuse + partitioner + parts
//!   meta.json                 format version, design + graph (family)
//!                             names, config echo, cold compile time,
//!                             register name→slot map, the
//!                             register-ownership map (replayed through
//!                             FixedOwners — no min-cut search on a hit),
//!                             and the per-register cone content hashes
//!                             (`cone_regs`/`cone_reg_hashes` plus the
//!                             `cone_outputs`/`cone_inputs` signatures)
//!   oim.json                  the OIM tensors (format B; C is re-derived)
//!   ir.json                   LayerIr sidecar (ports, commits, init,
//!                             names/widths — everything the OIM lacks)
//!   gdg.json                  the group dependency graph, CSR indexes
//!                             included (no rebuild pass on load)
//! ```
//!
//! Format version 2 added the graph name and the cone hashes; version-1
//! entries miss on the version check and are recompiled (never
//! misread). The cone hashes drive the **incremental open**
//! (`open` with `"incremental":true`, or `rteaal sim --incremental`):
//! on an exact-key miss the cache looks for a *donor* — a cached entry
//! of the same graph family under the same fuse/parts/partitioner
//! config but a different key (an entry on disk is fine) — and diffs
//! the request's cone hashes against it. Registers whose fan-in cone
//! hash (and the output/input signatures) match are *reused*: their OIM
//! rows, GDG groups and slot→reader indexes are spliced from the donor;
//! only the changed cones are recompiled and grafted in, and the
//! partition assignment is warm-started from the donor's ownership map
//! (k-way FM refinement seeded with the previous owners — no
//! coarsen/split phase). The result is committed under the request's
//! *own* content key, so a later exact open hits normally; a request
//! with no donor (or a cross-family diff, e.g. changed ports or a
//! renamed register sequence) silently falls back to the cold path.
//! Snapshot restores always re-open by exact content key and never take
//! the delta path.
//!
//! Writes are staged into a pid-unique `<key>.tmp.<pid>` and renamed
//! into place — rename-is-commit is the only synchronization. A killed
//! server never leaves a half-written entry under the real key; two
//! *processes* racing the same key never share a staging directory, and
//! the loser of the commit rename treats the winner's entry as its own
//! success. Evicting a corrupt entry renames it to a pid-unique
//! `<key>.trash.<pid>` tombstone before deletion, so a concurrent
//! reader sees the old entry, the new one, or nothing (→ recompile) —
//! never a half-deleted directory. Leftover tombstones (a server killed
//! mid-eviction) are swept by the next `open_design` on the same cache
//! directory.
//!
//! # Session → lane packing rules
//!
//! * A host is one [`BatchParallelSim`](crate::coordinator::parallel::BatchParallelSim)
//!   (`P` partitions × `B` lanes on the persistent worker pool; `P = 1`
//!   covers unpartitioned designs).
//! * A new session joins an existing host iff it matches the host's
//!   **signature** — (cache key, kernel config, parts, B, sparse) — and
//!   the host has `width` contiguous free lanes. Otherwise a new host is
//!   built (from the cached artifacts; no recompilation either way).
//! * Sessions are isolated by construction: lanes never interact inside
//!   a kernel, a session's stimulus is written only to its own lanes,
//!   and unattached lanes are driven with all-zero inputs.
//! * A session driven by the *design* stimulus reproduces `rteaal sim`
//!   exactly: slice lane `i` is driven by `make_stimulus_for_lane(i)`,
//!   so a width-1 session matches a scalar run and a width-B session
//!   matches `rteaal sim --lanes B`, bit for bit.
//! * Hosts advance **bulk-synchronously**: one pump steps
//!   `min(queued cycles over all attached sessions)` (bounded by the
//!   per-request budget and output-buffer backpressure). A session with
//!   an empty stimulus queue therefore stalls its host-mates — submit
//!   stimulus in comparable batches, or open with a dedicated host
//!   (pick a distinct `lanes` value) for latency-sensitive work.

pub mod api;
pub mod cache;
pub mod checkpoint;
pub mod proto;
pub mod session;
