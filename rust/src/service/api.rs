//! The `rteaal serve` request loop: NDJSON over stdio or a Unix socket.
//!
//! One request line in, one reply line out, in order. Concurrency lives
//! in the [session manager](crate::service::session) (many sessions
//! packed onto shared hosts, hosts on the persistent worker pool) — the
//! protocol itself is deliberately sequential, so replies never
//! interleave and the transcript is a complete, replayable log.
//!
//! Each request runs under a time budget (`--timeout-ms`, overridable
//! per request via a `timeout_ms` field). The budget bounds the *pump*:
//! a `poll` that cannot finish in time replies with whatever cycles it
//! did produce (`done:false`); it only fails with code `timeout` when
//! the budget expired before a single record was available. A host that
//! panics mid-step is dropped and its sessions report `wedged` — the
//! server itself keeps serving.

use std::io::{BufRead, BufReader, Write};
use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::service::proto::{
    self, cache_json, err_reply, ok_reply, record_json, ErrorCode, Request, StimulusSpec, Verb,
};
use crate::service::session::SessionManager;
use crate::util::json::{self, Json};

/// Server configuration (from `rteaal serve` flags).
pub struct ServeOpts {
    /// On-disk design-cache directory; `None` = in-memory cache only.
    pub cache_dir: Option<PathBuf>,
    /// In-memory LRU capacity (designs).
    pub cache_cap: usize,
    /// Default per-request time budget.
    pub timeout_ms: u64,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts { cache_dir: None, cache_cap: 8, timeout_ms: 2_000 }
    }
}

/// The server: a session manager plus the request budget.
pub struct Server {
    mgr: SessionManager,
    default_timeout: Duration,
}

impl Server {
    pub fn new(opts: ServeOpts) -> Self {
        Server {
            mgr: SessionManager::new(opts.cache_dir, opts.cache_cap),
            default_timeout: Duration::from_millis(opts.timeout_ms),
        }
    }

    /// Handle one request line, producing exactly one reply line
    /// (without trailing newline). Never panics on malformed input.
    pub fn handle_line(&mut self, line: &str) -> String {
        let req = match proto::parse_request(line) {
            Ok(r) => r,
            Err((id, code, msg)) => return err_reply(id, code, &msg),
        };
        let deadline = Instant::now()
            + req.timeout_ms.map(Duration::from_millis).unwrap_or(self.default_timeout);
        self.dispatch(&req, deadline)
            .unwrap_or_else(|(code, msg)| err_reply(Some(req.id), code, &msg))
    }

    fn dispatch(
        &mut self,
        req: &Request,
        deadline: Instant,
    ) -> Result<String, (ErrorCode, String)> {
        let id = req.id;
        let fail = |msg: String| (proto::classify(&msg), msg);
        match &req.verb {
            Verb::Open(cfg) => {
                let o = self.mgr.open(cfg).map_err(fail)?;
                Ok(ok_reply(
                    id,
                    vec![
                        ("session", Json::Int(o.session as i64)),
                        ("cache", cache_json(&o.report)),
                        ("host", Json::Int(o.host as i64)),
                        ("lane0", Json::Int(o.lane0 as i64)),
                    ],
                ))
            }
            Verb::Submit { session, stimulus } => {
                let queued = match stimulus {
                    StimulusSpec::DesignCycles(n) => {
                        self.mgr.submit_design(*session, *n).map_err(fail)?
                    }
                    StimulusSpec::Vectors(frames) => {
                        self.mgr.submit_vectors(*session, frames.clone()).map_err(fail)?
                    }
                };
                Ok(ok_reply(id, vec![("queued", Json::Int(queued as i64))]))
            }
            Verb::Poll { session, max_cycles } => {
                let r = self.mgr.poll(*session, *max_cycles, deadline).map_err(fail)?;
                if r.records.is_empty() && !r.done && Instant::now() >= deadline {
                    return Err((
                        ErrorCode::Timeout,
                        "request budget expired before any cycle completed".into(),
                    ));
                }
                let cycles = Json::Arr(r.records.iter().map(record_json).collect());
                Ok(ok_reply(
                    id,
                    vec![
                        ("cycles", cycles),
                        ("cycle", Json::Int(r.cycle as i64)),
                        ("done", Json::Bool(r.done)),
                    ],
                ))
            }
            Verb::Checkpoint { session, path } => {
                let (bytes, cycle) = self.mgr.checkpoint(*session, path).map_err(fail)?;
                Ok(ok_reply(
                    id,
                    vec![
                        ("path", Json::Str(path.display().to_string())),
                        ("bytes", Json::Int(bytes as i64)),
                        ("cycle", Json::Int(cycle as i64)),
                    ],
                ))
            }
            Verb::Restore { path } => {
                let (session, cycle) = self.mgr.restore(path).map_err(fail)?;
                Ok(ok_reply(
                    id,
                    vec![
                        ("session", Json::Int(session as i64)),
                        ("cycle", Json::Int(cycle as i64)),
                    ],
                ))
            }
            Verb::Close { session } => {
                self.mgr.close(*session).map_err(fail)?;
                Ok(ok_reply(id, vec![("closed", Json::Int(*session as i64))]))
            }
            Verb::Stats => {
                let c = &self.mgr.cache;
                Ok(ok_reply(
                    id,
                    vec![
                        (
                            "cache",
                            json::obj(vec![
                                ("mem_hits", Json::Int(c.mem_hits as i64)),
                                ("disk_hits", Json::Int(c.disk_hits as i64)),
                                ("misses", Json::Int(c.misses as i64)),
                                ("resident", Json::Int(c.len() as i64)),
                            ]),
                        ),
                        ("hosts", Json::Int(self.mgr.host_count() as i64)),
                        ("sessions", Json::Int(self.mgr.session_count() as i64)),
                    ],
                ))
            }
        }
    }

    /// Serve a request stream to completion (EOF ends the server).
    pub fn serve<R: BufRead, W: Write>(&mut self, input: R, mut output: W) -> std::io::Result<()> {
        for line in input.lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let reply = self.handle_line(&line);
            output.write_all(reply.as_bytes())?;
            output.write_all(b"\n")?;
            output.flush()?;
        }
        Ok(())
    }
}

/// `rteaal serve --stdio`: requests on stdin, replies on stdout.
pub fn serve_stdio(opts: ServeOpts) -> std::io::Result<()> {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    Server::new(opts).serve(stdin.lock(), stdout.lock())
}

/// `rteaal serve --socket PATH`: accept Unix-socket connections one at a
/// time (sessions persist across connections — a client may open, drop
/// the connection, reconnect, and keep polling the same session ids).
pub fn serve_unix(path: &std::path::Path, opts: ServeOpts) -> std::io::Result<()> {
    // a previous server's leftover socket file would make bind fail
    let _ = std::fs::remove_file(path);
    let listener = std::os::unix::net::UnixListener::bind(path)?;
    let mut server = Server::new(opts);
    for conn in listener.incoming() {
        let conn = conn?;
        let reader = BufReader::new(conn.try_clone()?);
        // a dropped connection ends its serve loop, not the server
        if let Err(e) = server.serve(reader, conn) {
            eprintln!("rteaal serve: connection error: {e}");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn server() -> Server {
        Server::new(ServeOpts::default())
    }

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("rteaal_api_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn ok(reply: &str) -> Json {
        let j = json::parse(reply).unwrap();
        assert!(matches!(j.get("ok"), Some(Json::Bool(true))), "expected ok reply: {reply}");
        j
    }

    fn err_code(reply: &str) -> String {
        let j = json::parse(reply).unwrap();
        assert!(matches!(j.get("ok"), Some(Json::Bool(false))), "expected error reply: {reply}");
        j.req("error").unwrap().req_str("code").unwrap().to_string()
    }

    /// The worked transcript from the module docs, end to end against a
    /// live server: open (miss) → open (hit, same host) → submit → poll
    /// → checkpoint → restore → close.
    #[test]
    fn worked_transcript_round_trips() {
        let dir = tmp_dir("transcript");
        let mut s = server();
        let r = ok(&s.handle_line(r#"{"id":1,"verb":"open","design":"fir8","lanes":8,"width":1}"#));
        let cache = r.req("cache").unwrap();
        assert!(matches!(cache.get("hit"), Some(Json::Bool(false))));
        let r2 = ok(&s.handle_line(r#"{"id":2,"verb":"open","design":"fir8","lanes":8,"width":1}"#));
        let cache2 = r2.req("cache").unwrap();
        assert!(matches!(cache2.get("hit"), Some(Json::Bool(true))));
        assert_eq!(cache2.req_str("source").unwrap(), "memory");
        assert_eq!(r.req_u64("host").unwrap(), r2.req_u64("host").unwrap(), "packed");

        ok(&s.handle_line(
            r#"{"id":3,"verb":"submit","session":0,"stimulus":{"kind":"design","cycles":20}}"#,
        ));
        ok(&s.handle_line(
            r#"{"id":4,"verb":"submit","session":1,"stimulus":{"kind":"design","cycles":20}}"#,
        ));
        let p = ok(&s.handle_line(r#"{"id":5,"verb":"poll","session":0}"#));
        assert!(matches!(p.get("done"), Some(Json::Bool(true))));
        assert_eq!(p.req_arr("cycles").unwrap().len(), 20);
        assert_eq!(p.req_u64("cycle").unwrap(), 20);

        let ckpt = dir.join("s0.rtal");
        let c = ok(&s.handle_line(&format!(
            r#"{{"id":6,"verb":"checkpoint","session":0,"path":"{}"}}"#,
            ckpt.display()
        )));
        assert_eq!(c.req_u64("cycle").unwrap(), 20);
        assert!(c.req_u64("bytes").unwrap() > 0);

        let r = ok(&s.handle_line(&format!(
            r#"{{"id":7,"verb":"restore","path":"{}"}}"#,
            ckpt.display()
        )));
        let restored = r.req_u64("session").unwrap();
        assert_eq!(r.req_u64("cycle").unwrap(), 20);

        // the restored session continues bit-identically with the original
        for sid in [0, restored] {
            ok(&s.handle_line(&format!(
                r#"{{"id":8,"verb":"submit","session":{sid},"stimulus":{{"kind":"design","cycles":5}}}}"#,
            )));
        }
        // session 1 must also advance for host 0 to pump
        ok(&s.handle_line(
            r#"{"id":9,"verb":"submit","session":1,"stimulus":{"kind":"design","cycles":5}}"#,
        ));
        let a = ok(&s.handle_line(r#"{"id":10,"verb":"poll","session":0}"#));
        let b = ok(&s.handle_line(&format!(r#"{{"id":11,"verb":"poll","session":{restored}}}"#)));
        assert_eq!(
            a.req_arr("cycles").unwrap(),
            b.req_arr("cycles").unwrap(),
            "restored session diverged from the original"
        );

        let st = ok(&s.handle_line(r#"{"id":12,"verb":"stats"}"#));
        assert!(st.req_u64("sessions").unwrap() >= 3);
        ok(&s.handle_line(r#"{"id":13,"verb":"close","session":0}"#));
        let e = s.handle_line(r#"{"id":14,"verb":"poll","session":0}"#);
        assert_eq!(err_code(&e), "unknown-session");
    }

    #[test]
    fn structured_errors_for_bad_requests() {
        let mut s = server();
        assert_eq!(err_code(&s.handle_line("{]")), "bad-request");
        assert_eq!(err_code(&s.handle_line(r#"{"id":1,"verb":"warp"}"#)), "unknown-verb");
        assert_eq!(
            err_code(&s.handle_line(r#"{"id":2,"verb":"open","design":"no_such"}"#)),
            "unknown-design"
        );
        assert_eq!(
            err_code(&s.handle_line(r#"{"id":3,"verb":"open","design":"fir8","kernel":"QQ"}"#)),
            "bad-config"
        );
        assert_eq!(
            err_code(&s.handle_line(
                r#"{"id":4,"verb":"open","design":"fir8","lanes":2,"width":5}"#
            )),
            "bad-config"
        );
        assert_eq!(err_code(&s.handle_line(r#"{"id":5,"verb":"close","session":99}"#)), "unknown-session");
        assert_eq!(
            err_code(&s.handle_line(r#"{"id":6,"verb":"restore","path":"/nonexistent/x.rtal"}"#)),
            "io"
        );
    }

    /// A zero budget with queued work times out (code `timeout`) instead
    /// of blocking; a later poll with budget completes the work.
    #[test]
    fn zero_budget_poll_times_out_cleanly() {
        let mut s = server();
        ok(&s.handle_line(r#"{"id":1,"verb":"open","design":"counter"}"#));
        ok(&s.handle_line(
            r#"{"id":2,"verb":"submit","session":0,"stimulus":{"kind":"design","cycles":50}}"#,
        ));
        let e = s.handle_line(r#"{"id":3,"verb":"poll","session":0,"timeout_ms":0}"#);
        assert_eq!(err_code(&e), "timeout");
        let p = ok(&s.handle_line(r#"{"id":4,"verb":"poll","session":0}"#));
        assert!(matches!(p.get("done"), Some(Json::Bool(true))));
        assert_eq!(p.req_arr("cycles").unwrap().len(), 50);
    }
}
