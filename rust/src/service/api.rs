//! The `rteaal serve` request loop: NDJSON over stdio or a Unix socket.
//!
//! One request line in, one reply line out, in order. Concurrency lives
//! in the [session manager](crate::service::session) (many sessions
//! packed onto shared hosts, hosts on the persistent worker pool) — the
//! protocol itself is deliberately sequential, so replies never
//! interleave and the transcript is a complete, replayable log.
//!
//! Each request runs under a time budget (`--timeout-ms`, overridable
//! per request via a `timeout_ms` field). The budget bounds the *pump*:
//! a `poll` that cannot finish in time replies with whatever cycles it
//! did produce (`done:false`); it only fails with code `timeout` when
//! the budget expired before a single record was available. A host that
//! panics mid-step is dropped and its sessions report `wedged` — the
//! server itself keeps serving.

use std::io::{BufRead, BufReader, Write};
use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::service::proto::{
    self, cache_json, err_reply, ok_reply, record_json, ErrorCode, Request, StimulusSpec, Verb,
};
use crate::service::session::SessionManager;
use crate::util::json::{self, Json};

/// Server configuration (from `rteaal serve` flags).
pub struct ServeOpts {
    /// On-disk design-cache directory; `None` = in-memory cache only.
    pub cache_dir: Option<PathBuf>,
    /// In-memory LRU capacity (designs).
    pub cache_cap: usize,
    /// Default per-request time budget.
    pub timeout_ms: u64,
    /// Unix-socket connections idle longer than this are closed (their
    /// sessions survive; reconnect and keep polling). Ignored on stdio.
    pub idle_timeout_ms: u64,
    /// Run the static artifact verifier ([`crate::analysis`]) on every
    /// design open, server-wide (`rteaal serve --verify`). Individual
    /// sessions can also request it per open (`"verify":true`).
    pub verify: bool,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            cache_dir: None,
            cache_cap: 8,
            timeout_ms: 2_000,
            idle_timeout_ms: 30_000,
            verify: false,
        }
    }
}

/// The server: a session manager plus the request budget.
pub struct Server {
    mgr: SessionManager,
    default_timeout: Duration,
}

impl Server {
    pub fn new(opts: ServeOpts) -> Self {
        let mut mgr = SessionManager::new(opts.cache_dir, opts.cache_cap);
        mgr.cache.verify = opts.verify;
        Server { mgr, default_timeout: Duration::from_millis(opts.timeout_ms) }
    }

    /// Handle one request line, producing exactly one reply line
    /// (without trailing newline). Never panics on malformed input.
    pub fn handle_line(&mut self, line: &str) -> String {
        let req = match proto::parse_request(line) {
            Ok(r) => r,
            Err((id, code, msg)) => return err_reply(id, code, &msg),
        };
        let deadline = Instant::now()
            + req.timeout_ms.map(Duration::from_millis).unwrap_or(self.default_timeout);
        self.dispatch(&req, deadline)
            .unwrap_or_else(|(code, msg)| err_reply(Some(req.id), code, &msg))
    }

    fn dispatch(
        &mut self,
        req: &Request,
        deadline: Instant,
    ) -> Result<String, (ErrorCode, String)> {
        let id = req.id;
        let fail = |msg: String| (proto::classify(&msg), msg);
        match &req.verb {
            Verb::Open(cfg) => {
                let o = self.mgr.open(cfg).map_err(fail)?;
                Ok(ok_reply(
                    id,
                    vec![
                        ("session", Json::Int(o.session as i64)),
                        ("cache", cache_json(&o.report)),
                        ("host", Json::Int(o.host as i64)),
                        ("lane0", Json::Int(o.lane0 as i64)),
                    ],
                ))
            }
            Verb::Submit { session, stimulus } => {
                let queued = match stimulus {
                    StimulusSpec::DesignCycles(n) => {
                        self.mgr.submit_design(*session, *n).map_err(fail)?
                    }
                    StimulusSpec::Vectors(frames) => {
                        self.mgr.submit_vectors(*session, frames.clone()).map_err(fail)?
                    }
                };
                Ok(ok_reply(id, vec![("queued", Json::Int(queued as i64))]))
            }
            Verb::Poll { session, max_cycles } => {
                let r = self.mgr.poll(*session, *max_cycles, deadline).map_err(fail)?;
                if r.records.is_empty() && !r.done && Instant::now() >= deadline {
                    return Err((
                        ErrorCode::Timeout,
                        "request budget expired before any cycle completed".into(),
                    ));
                }
                let cycles = Json::Arr(r.records.iter().map(record_json).collect());
                let mut fields = vec![
                    ("cycles", cycles),
                    ("cycle", Json::Int(r.cycle as i64)),
                    ("done", Json::Bool(r.done)),
                ];
                if let Some(chunk) = r.wave_chunk {
                    // VCD is pure ASCII; ship the chunk as a JSON string
                    // (newlines escaped by the encoder)
                    fields.push(("wave", Json::Str(String::from_utf8_lossy(&chunk).into_owned())));
                }
                Ok(ok_reply(id, fields))
            }
            Verb::Wave { session, lane } => {
                self.mgr.attach_wave(*session, *lane).map_err(fail)?;
                Ok(ok_reply(
                    id,
                    vec![("wave", Json::Bool(true)), ("lane", Json::Int(*lane as i64))],
                ))
            }
            Verb::Checkpoint { session, path } => {
                let (bytes, cycle) = self.mgr.checkpoint(*session, path).map_err(fail)?;
                Ok(ok_reply(
                    id,
                    vec![
                        ("path", Json::Str(path.display().to_string())),
                        ("bytes", Json::Int(bytes as i64)),
                        ("cycle", Json::Int(cycle as i64)),
                    ],
                ))
            }
            Verb::Restore { path } => {
                let (session, cycle) = self.mgr.restore(path).map_err(fail)?;
                Ok(ok_reply(
                    id,
                    vec![
                        ("session", Json::Int(session as i64)),
                        ("cycle", Json::Int(cycle as i64)),
                    ],
                ))
            }
            Verb::Close { session } => {
                self.mgr.close(*session).map_err(fail)?;
                Ok(ok_reply(id, vec![("closed", Json::Int(*session as i64))]))
            }
            Verb::Stats => {
                let c = &self.mgr.cache;
                let lanes = Json::Arr(
                    self.mgr
                        .occupancy()
                        .into_iter()
                        .map(|(session, host, lane0, width, host_lanes)| {
                            json::obj(vec![
                                ("session", Json::Int(session as i64)),
                                ("host", Json::Int(host as i64)),
                                ("lane0", Json::Int(lane0 as i64)),
                                ("width", Json::Int(width as i64)),
                                ("host_lanes", Json::Int(host_lanes as i64)),
                            ])
                        })
                        .collect(),
                );
                Ok(ok_reply(
                    id,
                    vec![
                        (
                            "cache",
                            json::obj(vec![
                                ("mem_hits", Json::Int(c.mem_hits as i64)),
                                ("disk_hits", Json::Int(c.disk_hits as i64)),
                                ("misses", Json::Int(c.misses as i64)),
                                ("incremental", Json::Int(c.incremental as i64)),
                                ("resident", Json::Int(c.len() as i64)),
                            ]),
                        ),
                        ("hosts", Json::Int(self.mgr.host_count() as i64)),
                        ("sessions", Json::Int(self.mgr.session_count() as i64)),
                        ("lanes", lanes),
                    ],
                ))
            }
        }
    }

    /// Serve a request stream to completion (EOF ends the server).
    pub fn serve<R: BufRead, W: Write>(&mut self, input: R, mut output: W) -> std::io::Result<()> {
        for line in input.lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let reply = self.handle_line(&line);
            output.write_all(reply.as_bytes())?;
            output.write_all(b"\n")?;
            output.flush()?;
        }
        Ok(())
    }
}

/// `rteaal serve --stdio`: requests on stdin, replies on stdout.
pub fn serve_stdio(opts: ServeOpts) -> std::io::Result<()> {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    Server::new(opts).serve(stdin.lock(), stdout.lock())
}

/// `rteaal serve --socket PATH`: accept Unix-socket connections
/// concurrently (sessions persist across connections — a client may
/// open, drop the connection, reconnect, and keep polling the same
/// session ids).
///
/// The [`Server`] itself is not `Send` (stimulus closures, the worker
/// pool), so it stays on the calling thread as a dispatcher: an acceptor
/// thread spawns one reader thread per connection, readers forward
/// complete request lines over a channel and relay the reply back. A
/// client that connects and then stalls — mid-line or silent — occupies
/// only its own reader thread; other connections keep being served, and
/// the per-connection idle timeout ([`ServeOpts::idle_timeout_ms`])
/// eventually reclaims the stalled one.
pub fn serve_unix(path: &std::path::Path, opts: ServeOpts) -> std::io::Result<()> {
    use std::sync::mpsc;
    // a previous server's leftover socket file would make bind fail
    let _ = std::fs::remove_file(path);
    let listener = std::os::unix::net::UnixListener::bind(path)?;
    let idle = Duration::from_millis(opts.idle_timeout_ms.max(1));
    let mut server = Server::new(opts);
    let (tx, rx) = mpsc::channel::<(String, mpsc::Sender<String>)>();
    std::thread::spawn(move || {
        for conn in listener.incoming() {
            let Ok(conn) = conn else { continue };
            let tx = tx.clone();
            std::thread::spawn(move || {
                if let Err(e) = serve_unix_conn(conn, tx, idle) {
                    eprintln!("rteaal serve: connection error: {e}");
                }
            });
        }
    });
    // dispatcher: requests from every connection are handled here, one
    // at a time, so replies never interleave within a connection and the
    // session table needs no locking
    for (line, reply_tx) in rx {
        let reply = server.handle_line(&line);
        let _ = reply_tx.send(reply);
    }
    Ok(())
}

/// One connection's reader loop: forward request lines to the
/// dispatcher, write its replies back. Returns when the peer disconnects
/// or stays idle past `idle` (a read timeout surfaces as an error on the
/// blocked `read_line`).
fn serve_unix_conn(
    conn: std::os::unix::net::UnixStream,
    tx: std::sync::mpsc::Sender<(String, std::sync::mpsc::Sender<String>)>,
    idle: Duration,
) -> std::io::Result<()> {
    conn.set_read_timeout(Some(idle))?;
    let mut out = conn.try_clone()?;
    let reader = BufReader::new(conn);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            // idle timeout or dropped peer: close this connection only
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        if tx.send((line, reply_tx)).is_err() {
            break; // dispatcher is gone; the process is shutting down
        }
        let Ok(reply) = reply_rx.recv() else { break };
        out.write_all(reply.as_bytes())?;
        out.write_all(b"\n")?;
        out.flush()?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn server() -> Server {
        Server::new(ServeOpts::default())
    }

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("rteaal_api_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn ok(reply: &str) -> Json {
        let j = json::parse(reply).unwrap();
        assert!(matches!(j.get("ok"), Some(Json::Bool(true))), "expected ok reply: {reply}");
        j
    }

    fn err_code(reply: &str) -> String {
        let j = json::parse(reply).unwrap();
        assert!(matches!(j.get("ok"), Some(Json::Bool(false))), "expected error reply: {reply}");
        j.req("error").unwrap().req_str("code").unwrap().to_string()
    }

    /// The worked transcript from the module docs, end to end against a
    /// live server: open (miss) → open (hit, same host) → submit → poll
    /// → checkpoint → restore → close.
    #[test]
    fn worked_transcript_round_trips() {
        let dir = tmp_dir("transcript");
        let mut s = server();
        let r = ok(&s.handle_line(r#"{"id":1,"verb":"open","design":"fir8","lanes":8,"width":1}"#));
        let cache = r.req("cache").unwrap();
        assert!(matches!(cache.get("hit"), Some(Json::Bool(false))));
        let r2 = ok(&s.handle_line(r#"{"id":2,"verb":"open","design":"fir8","lanes":8,"width":1}"#));
        let cache2 = r2.req("cache").unwrap();
        assert!(matches!(cache2.get("hit"), Some(Json::Bool(true))));
        assert_eq!(cache2.req_str("source").unwrap(), "memory");
        assert_eq!(r.req_u64("host").unwrap(), r2.req_u64("host").unwrap(), "packed");

        ok(&s.handle_line(
            r#"{"id":3,"verb":"submit","session":0,"stimulus":{"kind":"design","cycles":20}}"#,
        ));
        ok(&s.handle_line(
            r#"{"id":4,"verb":"submit","session":1,"stimulus":{"kind":"design","cycles":20}}"#,
        ));
        let p = ok(&s.handle_line(r#"{"id":5,"verb":"poll","session":0}"#));
        assert!(matches!(p.get("done"), Some(Json::Bool(true))));
        assert_eq!(p.req_arr("cycles").unwrap().len(), 20);
        assert_eq!(p.req_u64("cycle").unwrap(), 20);

        let ckpt = dir.join("s0.rtal");
        let c = ok(&s.handle_line(&format!(
            r#"{{"id":6,"verb":"checkpoint","session":0,"path":"{}"}}"#,
            ckpt.display()
        )));
        assert_eq!(c.req_u64("cycle").unwrap(), 20);
        assert!(c.req_u64("bytes").unwrap() > 0);

        let r = ok(&s.handle_line(&format!(
            r#"{{"id":7,"verb":"restore","path":"{}"}}"#,
            ckpt.display()
        )));
        let restored = r.req_u64("session").unwrap();
        assert_eq!(r.req_u64("cycle").unwrap(), 20);

        // the restored session continues bit-identically with the original
        for sid in [0, restored] {
            ok(&s.handle_line(&format!(
                r#"{{"id":8,"verb":"submit","session":{sid},"stimulus":{{"kind":"design","cycles":5}}}}"#,
            )));
        }
        // session 1 must also advance for host 0 to pump
        ok(&s.handle_line(
            r#"{"id":9,"verb":"submit","session":1,"stimulus":{"kind":"design","cycles":5}}"#,
        ));
        let a = ok(&s.handle_line(r#"{"id":10,"verb":"poll","session":0}"#));
        let b = ok(&s.handle_line(&format!(r#"{{"id":11,"verb":"poll","session":{restored}}}"#)));
        assert_eq!(
            a.req_arr("cycles").unwrap(),
            b.req_arr("cycles").unwrap(),
            "restored session diverged from the original"
        );

        let st = ok(&s.handle_line(r#"{"id":12,"verb":"stats"}"#));
        assert!(st.req_u64("sessions").unwrap() >= 3);
        assert_eq!(
            st.req("cache").unwrap().req_u64("incremental").unwrap(),
            0,
            "no open used the delta reuse path"
        );
        let lanes = st.req_arr("lanes").unwrap();
        assert_eq!(lanes.len() as u64, st.req_u64("sessions").unwrap());
        // sessions 0 and 1 are packed on host 0, lanes [0] and [1]
        assert_eq!(lanes[0].req_u64("session").unwrap(), 0);
        assert_eq!(lanes[0].req_u64("lane0").unwrap(), 0);
        assert_eq!(lanes[1].req_u64("lane0").unwrap(), 1);
        assert_eq!(lanes[0].req_u64("host").unwrap(), lanes[1].req_u64("host").unwrap());
        assert_eq!(lanes[0].req_u64("host_lanes").unwrap(), 8);
        ok(&s.handle_line(r#"{"id":13,"verb":"close","session":0}"#));
        let e = s.handle_line(r#"{"id":14,"verb":"poll","session":0}"#);
        assert_eq!(err_code(&e), "unknown-session");
    }

    #[test]
    fn structured_errors_for_bad_requests() {
        let mut s = server();
        assert_eq!(err_code(&s.handle_line("{]")), "bad-request");
        assert_eq!(err_code(&s.handle_line(r#"{"id":1,"verb":"warp"}"#)), "unknown-verb");
        assert_eq!(
            err_code(&s.handle_line(r#"{"id":2,"verb":"open","design":"no_such"}"#)),
            "unknown-design"
        );
        assert_eq!(
            err_code(&s.handle_line(r#"{"id":3,"verb":"open","design":"fir8","kernel":"QQ"}"#)),
            "bad-config"
        );
        assert_eq!(
            err_code(&s.handle_line(
                r#"{"id":4,"verb":"open","design":"fir8","lanes":2,"width":5}"#
            )),
            "bad-config"
        );
        assert_eq!(err_code(&s.handle_line(r#"{"id":5,"verb":"close","session":99}"#)), "unknown-session");
        assert_eq!(
            err_code(&s.handle_line(r#"{"id":6,"verb":"restore","path":"/nonexistent/x.rtal"}"#)),
            "io"
        );
    }

    /// The `wave` verb attaches a delta-waveform sink to a packed
    /// session, `poll` streams incremental chunks, and the concatenated
    /// chunks are byte-identical to a solo session's single-shot stream
    /// of the same lane — across chunk boundaries that fall mid-stream.
    #[test]
    fn wave_verb_streams_chunks_matching_a_solo_run() {
        let mut packed = server();
        ok(&packed.handle_line(r#"{"id":1,"verb":"open","design":"fir8","lanes":2,"width":1}"#));
        ok(&packed.handle_line(r#"{"id":2,"verb":"open","design":"fir8","lanes":2,"width":1}"#));
        let w = ok(&packed.handle_line(r#"{"id":3,"verb":"wave","session":1}"#));
        assert!(matches!(w.get("wave"), Some(Json::Bool(true))));
        // double-attach and out-of-range slice lanes are structured errors
        assert_eq!(
            err_code(&packed.handle_line(r#"{"id":4,"verb":"wave","session":1}"#)),
            "bad-config"
        );
        assert_eq!(
            err_code(&packed.handle_line(r#"{"id":5,"verb":"wave","session":0,"lane":1}"#)),
            "bad-config"
        );
        assert_eq!(
            err_code(&packed.handle_line(r#"{"id":5,"verb":"wave","session":9}"#)),
            "unknown-session"
        );

        let mut solo = server();
        ok(&solo.handle_line(r#"{"id":1,"verb":"open","design":"fir8"}"#));
        ok(&solo.handle_line(r#"{"id":2,"verb":"wave","session":0}"#));

        // three submit/poll rounds against the packed server: every poll
        // reply carries one partial chunk (a truncated VCD stream —
        // chunk boundaries fall mid-waveform, not at sample boundaries)
        let mut streamed = String::new();
        for round in 0..3 {
            for sid in [0, 1] {
                ok(&packed.handle_line(&format!(
                    r#"{{"id":6,"verb":"submit","session":{sid},"stimulus":{{"kind":"design","cycles":10}}}}"#
                )));
            }
            let p = ok(&packed.handle_line(r#"{"id":7,"verb":"poll","session":1}"#));
            let chunk = p.req_str("wave").unwrap();
            if round == 0 {
                assert!(chunk.contains("$enddefinitions"), "first chunk carries the header");
            } else {
                assert!(!chunk.contains("$enddefinitions"), "header only once");
            }
            streamed.push_str(chunk);
            ok(&packed.handle_line(r#"{"id":8,"verb":"poll","session":0}"#));
        }
        ok(&solo.handle_line(
            r#"{"id":3,"verb":"submit","session":0,"stimulus":{"kind":"design","cycles":30}}"#,
        ));
        let p = ok(&solo.handle_line(r#"{"id":4,"verb":"poll","session":0}"#));
        assert_eq!(
            streamed,
            p.req_str("wave").unwrap(),
            "concatenated packed-session chunks diverge from the solo stream"
        );
        // a session without a sink has no wave field at all
        let bare = ok(&packed.handle_line(r#"{"id":9,"verb":"poll","session":0}"#));
        assert!(bare.get("wave").is_none());
    }

    /// Satellite regression: a client that connects and goes silent (or
    /// stalls mid-line) must not delay another connection's requests —
    /// the listener is one reader thread per connection with a
    /// dispatcher, not a sequential accept loop — and the idle timeout
    /// eventually reclaims the wedged connection.
    #[test]
    fn wedged_client_does_not_block_a_second_connection() {
        use std::io::Read;
        use std::os::unix::net::UnixStream;

        let dir = tmp_dir("unix_wedge");
        let sock = dir.join("serve.sock");
        let sock2 = sock.clone();
        std::thread::spawn(move || {
            let _ = serve_unix(
                &sock2,
                ServeOpts { idle_timeout_ms: 500, ..ServeOpts::default() },
            );
        });
        let t0 = Instant::now();
        let mut wedged = loop {
            match UnixStream::connect(&sock) {
                Ok(c) => break c,
                Err(e) => {
                    assert!(
                        t0.elapsed() < Duration::from_secs(10),
                        "server socket never came up: {e}"
                    );
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        };
        // the wedged client stalls mid-request: bytes but no newline
        wedged.write_all(b"{\"id\":9").unwrap();

        let mut fast = UnixStream::connect(&sock).unwrap();
        fast.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        fast.write_all(b"{\"id\":1,\"verb\":\"open\",\"design\":\"counter\"}\n").unwrap();
        let mut reader = BufReader::new(fast.try_clone().unwrap());
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        ok(&reply);
        fast.write_all(b"{\"id\":2,\"verb\":\"stats\"}\n").unwrap();
        reply.clear();
        reader.read_line(&mut reply).unwrap();
        ok(&reply);

        // the idle timeout reclaims the wedged connection: its next read
        // sees EOF once the server drops it
        wedged.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        let mut buf = [0u8; 8];
        assert_eq!(
            wedged.read(&mut buf).unwrap(),
            0,
            "server should close the idle connection"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A zero budget with queued work times out (code `timeout`) instead
    /// of blocking; a later poll with budget completes the work.
    #[test]
    fn zero_budget_poll_times_out_cleanly() {
        let mut s = server();
        ok(&s.handle_line(r#"{"id":1,"verb":"open","design":"counter"}"#));
        ok(&s.handle_line(
            r#"{"id":2,"verb":"submit","session":0,"stimulus":{"kind":"design","cycles":50}}"#,
        ));
        let e = s.handle_line(r#"{"id":3,"verb":"poll","session":0,"timeout_ms":0}"#);
        assert_eq!(err_code(&e), "timeout");
        let p = ok(&s.handle_line(r#"{"id":4,"verb":"poll","session":0}"#));
        assert!(matches!(p.get("done"), Some(Json::Bool(true))));
        assert_eq!(p.req_arr("cycles").unwrap().len(), 50);
    }
}
