//! Wire protocol for `rteaal serve`: newline-delimited JSON requests and
//! replies (schema in the [module docs](crate::service)).
//!
//! This module is pure data: parse a request line into a typed
//! [`Request`], build reply lines from typed results. The I/O loop and
//! the dispatch live in [`api`](crate::service::api).
//!
//! Register and output values are encoded as `"0x…"` hex strings in
//! replies (the custom JSON layer's integers are `i64`, and slot values
//! are full `u64` words); requests may spell stimulus words either way.

use std::path::PathBuf;

use crate::kernels::KernelConfig;
use crate::partition::PartitionerKind;
use crate::service::cache::OpenReport;
use crate::service::session::{CycleRecord, SessionConfig};
use crate::util::json::{self, Json};

/// Structured error category, reported as `error.code` on the wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    BadRequest,
    UnknownVerb,
    UnknownDesign,
    UnknownSession,
    BadConfig,
    Snapshot,
    Io,
    Timeout,
    Wedged,
}

impl ErrorCode {
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "bad-request",
            ErrorCode::UnknownVerb => "unknown-verb",
            ErrorCode::UnknownDesign => "unknown-design",
            ErrorCode::UnknownSession => "unknown-session",
            ErrorCode::BadConfig => "bad-config",
            ErrorCode::Snapshot => "snapshot",
            ErrorCode::Io => "io",
            ErrorCode::Timeout => "timeout",
            ErrorCode::Wedged => "wedged",
        }
    }
}

/// Classify a session-manager error string into a wire code. The manager
/// reports errors as prose; the stable part of the contract is the code.
pub fn classify(msg: &str) -> ErrorCode {
    if msg.contains("unknown design") {
        ErrorCode::UnknownDesign
    } else if msg.contains("unknown session") {
        ErrorCode::UnknownSession
    } else if msg.contains("wedged") || msg.contains("is failed") {
        ErrorCode::Wedged
    } else if msg.contains("snapshot") || msg.contains("Corrupt") {
        ErrorCode::Snapshot
    } else if msg.contains("No such file") || msg.contains("o such file") || msg.contains("(os error") {
        ErrorCode::Io
    } else {
        ErrorCode::BadConfig
    }
}

/// Stimulus payload of a `submit`.
#[derive(Debug)]
pub enum StimulusSpec {
    /// Replay `cycles` of the design's canonical stream.
    DesignCycles(u64),
    /// Explicit frames, one inner vec per cycle (`inputs × width` words).
    Vectors(Vec<Vec<u64>>),
}

/// A parsed request.
#[derive(Debug)]
pub enum Verb {
    Open(SessionConfig),
    Submit { session: u64, stimulus: StimulusSpec },
    Poll { session: u64, max_cycles: usize },
    /// Attach a delta-waveform sink to one *slice* lane of a session;
    /// subsequent `poll` replies carry the incremental VCD chunks.
    Wave { session: u64, lane: usize },
    Checkpoint { session: u64, path: PathBuf },
    Restore { path: PathBuf },
    Close { session: u64 },
    Stats,
}

#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub verb: Verb,
    /// Per-request time budget override (`timeout_ms` field).
    pub timeout_ms: Option<u64>,
}

/// A parse failure, carrying the request id when one was readable (so
/// the error reply can still be correlated).
pub type ParseError = (Option<u64>, ErrorCode, String);

fn bad(id: Option<u64>, msg: impl Into<String>) -> ParseError {
    (id, ErrorCode::BadRequest, msg.into())
}

/// Accept a stimulus word as an integer or a `"0x…"` hex string.
fn word(j: &Json) -> Option<u64> {
    match j {
        Json::Int(i) => u64::try_from(*i).ok(),
        Json::Str(s) => {
            let h = s.strip_prefix("0x").or_else(|| s.strip_prefix("0X"))?;
            u64::from_str_radix(h, 16).ok()
        }
        _ => None,
    }
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request, ParseError> {
    let j = json::parse(line).map_err(|e| bad(None, format!("malformed JSON: {e}")))?;
    let id = match j.get("id").and_then(Json::as_u64) {
        Some(id) => id,
        None => return Err(bad(None, "missing or non-integer 'id'")),
    };
    let some = Some(id);
    let verb = j.req_str("verb").map_err(|e| bad(some, e.to_string()))?;
    let verb = match verb {
        "open" => {
            let mut cfg = SessionConfig {
                design: j.req_str("design").map_err(|e| bad(some, e.to_string()))?.to_string(),
                ..SessionConfig::default()
            };
            if let Some(k) = j.get("kernel").and_then(Json::as_str) {
                cfg.kernel = KernelConfig::parse(k).ok_or_else(|| {
                    (some, ErrorCode::BadConfig, format!("unknown kernel '{k}'"))
                })?;
            }
            if let Some(p) = j.get("partitioner").and_then(Json::as_str) {
                cfg.partitioner = PartitionerKind::parse(p).ok_or_else(|| {
                    (some, ErrorCode::BadConfig, format!("unknown partitioner '{p}'"))
                })?;
            }
            if let Some(v) = j.get("parts") {
                cfg.parts = v.as_usize().ok_or_else(|| bad(some, "'parts' not an integer"))?;
            }
            if let Some(v) = j.get("lanes") {
                cfg.lanes = v.as_usize().ok_or_else(|| bad(some, "'lanes' not an integer"))?;
            }
            cfg.width = cfg.lanes;
            if let Some(v) = j.get("width") {
                cfg.width = v.as_usize().ok_or_else(|| bad(some, "'width' not an integer"))?;
            }
            if let Some(v) = j.get("sparse") {
                cfg.sparse = matches!(v, Json::Bool(true));
            }
            if let Some(v) = j.get("fuse") {
                cfg.fuse = !matches!(v, Json::Bool(false));
            }
            if let Some(v) = j.get("incremental") {
                cfg.incremental = matches!(v, Json::Bool(true));
            }
            if let Some(v) = j.get("verify") {
                cfg.verify = matches!(v, Json::Bool(true));
            }
            Verb::Open(cfg)
        }
        "submit" => {
            let session = j.req_u64("session").map_err(|e| bad(some, e.to_string()))?;
            let st = j.req("stimulus").map_err(|e| bad(some, e.to_string()))?;
            let kind = st.req_str("kind").map_err(|e| bad(some, e.to_string()))?;
            let stimulus = match kind {
                "design" => StimulusSpec::DesignCycles(
                    st.req_u64("cycles").map_err(|e| bad(some, e.to_string()))?,
                ),
                "vectors" => {
                    let frames = st.req_arr("vectors").map_err(|e| bad(some, e.to_string()))?;
                    let mut out = Vec::with_capacity(frames.len());
                    for (i, f) in frames.iter().enumerate() {
                        let row = f
                            .as_arr()
                            .ok_or_else(|| bad(some, format!("vector {i} is not an array")))?;
                        let mut words = Vec::with_capacity(row.len());
                        for (k, w) in row.iter().enumerate() {
                            words.push(word(w).ok_or_else(|| {
                                bad(some, format!("vector {i} word {k} is not a u64"))
                            })?);
                        }
                        out.push(words);
                    }
                    StimulusSpec::Vectors(out)
                }
                other => return Err(bad(some, format!("unknown stimulus kind '{other}'"))),
            };
            Verb::Submit { session, stimulus }
        }
        "poll" => Verb::Poll {
            session: j.req_u64("session").map_err(|e| bad(some, e.to_string()))?,
            max_cycles: j
                .get("max_cycles")
                .map(|v| v.as_usize().ok_or_else(|| bad(some, "'max_cycles' not an integer")))
                .transpose()?
                .unwrap_or(usize::MAX),
        },
        "wave" => Verb::Wave {
            session: j.req_u64("session").map_err(|e| bad(some, e.to_string()))?,
            lane: j
                .get("lane")
                .map(|v| v.as_usize().ok_or_else(|| bad(some, "'lane' not an integer")))
                .transpose()?
                .unwrap_or(0),
        },
        "checkpoint" => Verb::Checkpoint {
            session: j.req_u64("session").map_err(|e| bad(some, e.to_string()))?,
            path: PathBuf::from(j.req_str("path").map_err(|e| bad(some, e.to_string()))?),
        },
        "restore" => Verb::Restore {
            path: PathBuf::from(j.req_str("path").map_err(|e| bad(some, e.to_string()))?),
        },
        "close" => Verb::Close {
            session: j.req_u64("session").map_err(|e| bad(some, e.to_string()))?,
        },
        "stats" => Verb::Stats,
        other => return Err((some, ErrorCode::UnknownVerb, format!("unknown verb '{other}'"))),
    };
    let timeout_ms = j
        .get("timeout_ms")
        .map(|v| v.as_u64().ok_or_else(|| bad(some, "'timeout_ms' not an integer")))
        .transpose()?;
    Ok(Request { id, verb, timeout_ms })
}

fn hex(v: u64) -> Json {
    Json::Str(format!("{v:#x}"))
}

/// `{"id":N,"ok":true,<fields>}` as one line.
pub fn ok_reply(id: u64, mut fields: Vec<(&str, Json)>) -> String {
    let mut all = vec![("id", Json::Int(id as i64)), ("ok", Json::Bool(true))];
    all.append(&mut fields);
    json::obj(all).to_string()
}

/// `{"id":N,"ok":false,"error":{...}}` as one line. A `None` id (the
/// request was unreadable) is reported as JSON `null`.
pub fn err_reply(id: Option<u64>, code: ErrorCode, message: &str) -> String {
    let idj = match id {
        Some(i) => Json::Int(i as i64),
        None => Json::Null,
    };
    json::obj(vec![
        ("id", idj),
        ("ok", Json::Bool(false)),
        (
            "error",
            json::obj(vec![
                ("code", Json::Str(code.as_str().to_string())),
                ("message", Json::Str(message.to_string())),
            ]),
        ),
    ])
    .to_string()
}

/// The `cache` sub-object of an `open` reply.
pub fn cache_json(report: &OpenReport) -> Json {
    json::obj(vec![
        ("key", Json::Str(report.key.clone())),
        ("hit", Json::Bool(report.hit)),
        ("source", Json::Str(report.source.name().to_string())),
        ("incremental", Json::Bool(report.incremental)),
        ("reused_groups", Json::Int(report.reused_groups as i64)),
        ("rebuilt_groups", Json::Int(report.rebuilt_groups as i64)),
        ("open_ms", Json::Num(report.open_time.as_secs_f64() * 1e3)),
        ("cold_compile_ms", Json::Num(report.cold_compile.as_secs_f64() * 1e3)),
    ])
}

/// One drained cycle record: `{"cycle":N,"out":{"port":"0x…",...}}`.
pub fn record_json(rec: &CycleRecord) -> Json {
    json::obj(vec![
        ("cycle", Json::Int(rec.cycle as i64)),
        (
            "out",
            Json::Obj(rec.out.iter().map(|(name, v)| (name.clone(), hex(*v))).collect()),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_open_with_defaults_and_overrides() {
        let r = parse_request(r#"{"id":7,"verb":"open","design":"fir8"}"#).unwrap();
        match r.verb {
            Verb::Open(cfg) => {
                assert_eq!(r.id, 7);
                assert_eq!(cfg.design, "fir8");
                assert_eq!(cfg.kernel, KernelConfig::PSU);
                assert_eq!((cfg.parts, cfg.lanes, cfg.width), (1, 1, 1));
                assert!(!cfg.sparse);
                assert!(cfg.fuse);
            }
            v => panic!("wrong verb {v:?}"),
        }
        // width defaults to lanes, explicit width narrows it
        let r = parse_request(
            r#"{"id":8,"verb":"open","design":"fir8","kernel":"ti","lanes":8,"width":2,"sparse":true,"fuse":false,"parts":4,"partitioner":"rr"}"#,
        )
        .unwrap();
        match r.verb {
            Verb::Open(cfg) => {
                assert_eq!(cfg.kernel, KernelConfig::TI);
                assert_eq!((cfg.parts, cfg.lanes, cfg.width), (4, 8, 2));
                assert!(cfg.sparse && !cfg.fuse);
                assert_eq!(cfg.partitioner, PartitionerKind::RoundRobin);
            }
            v => panic!("wrong verb {v:?}"),
        }
    }

    #[test]
    fn parses_submit_vectors_with_hex_words() {
        let r = parse_request(
            r#"{"id":1,"verb":"submit","session":3,"stimulus":{"kind":"vectors","vectors":[[1,"0xff"],[2,3]]}}"#,
        )
        .unwrap();
        match r.verb {
            Verb::Submit { session: 3, stimulus: StimulusSpec::Vectors(v) } => {
                assert_eq!(v, vec![vec![1, 0xff], vec![2, 3]]);
            }
            v => panic!("wrong verb {v:?}"),
        }
    }

    #[test]
    fn parses_wave_with_default_lane() {
        let r = parse_request(r#"{"id":2,"verb":"wave","session":5}"#).unwrap();
        assert!(matches!(r.verb, Verb::Wave { session: 5, lane: 0 }));
        let r = parse_request(r#"{"id":3,"verb":"wave","session":1,"lane":3}"#).unwrap();
        assert!(matches!(r.verb, Verb::Wave { session: 1, lane: 3 }));
        let e = parse_request(r#"{"id":4,"verb":"wave","lane":1}"#).unwrap_err();
        assert_eq!(e.1, ErrorCode::BadRequest, "missing session: {}", e.2);
    }

    #[test]
    fn errors_carry_the_id_when_readable() {
        let e = parse_request(r#"{"id":9,"verb":"fly"}"#).unwrap_err();
        assert_eq!(e.0, Some(9));
        assert_eq!(e.1, ErrorCode::UnknownVerb);
        let e = parse_request("not json").unwrap_err();
        assert_eq!(e.0, None);
        assert_eq!(e.1, ErrorCode::BadRequest);
        let e = parse_request(r#"{"verb":"stats"}"#).unwrap_err();
        assert_eq!(e.0, None, "no id to echo");
    }

    #[test]
    fn reply_lines_are_single_line_json() {
        let ok = ok_reply(4, vec![("queued", Json::Int(10))]);
        assert!(!ok.contains('\n'));
        let j = crate::util::json::parse(&ok).unwrap();
        assert_eq!(j.req_u64("id").unwrap(), 4);
        assert!(matches!(j.get("ok"), Some(Json::Bool(true))));
        assert_eq!(j.req_u64("queued").unwrap(), 10);

        let err = err_reply(None, ErrorCode::Snapshot, "bad magic");
        let j = crate::util::json::parse(&err).unwrap();
        assert!(matches!(j.get("id"), Some(Json::Null)));
        assert_eq!(j.req("error").unwrap().req_str("code").unwrap(), "snapshot");
    }

    #[test]
    fn classify_maps_manager_errors_to_codes() {
        assert_eq!(classify("unknown design 'x'"), ErrorCode::UnknownDesign);
        assert_eq!(classify("unknown session 9"), ErrorCode::UnknownSession);
        assert_eq!(classify("session 1 is failed: host wedged mid-step"), ErrorCode::Wedged);
        assert_eq!(classify("snapshot rejected: lane mismatch"), ErrorCode::Snapshot);
        assert_eq!(classify("width 9 exceeds host lanes 8"), ErrorCode::BadConfig);
    }
}
