//! The register-affinity hypergraph behind min-cut partitioning.
//!
//! Vertices are the design's *writable* registers (commits whose
//! next-state slot differs from the register slot — a self-holding
//! register can never change value and is handled separately by
//! [`super::partition_ir`]), plus one zero-weight **anchor** vertex that
//! stands for the design-output cone, which is pinned to partition 0.
//!
//! One hyperedge is emitted per *read* register `q`: its pins are `q`
//! itself plus every register whose next-state cone (and the anchor, if
//! the output cone) transitively reads `q`'s slot. This is the transpose
//! of the "one hyperedge per combinational cone over the registers it
//! reads/writes" view, chosen because its connectivity metric is exact:
//! with register ownership as the vertex partition, the RUM must move
//! `q`'s lanes to every *distinct* partition among `q`'s readers other
//! than `q`'s owner — which is precisely `λ(e_q) − 1`, the
//! connectivity-minus-one objective the multilevel partitioner
//! ([`super::multilevel`]) minimizes. Summed over all edges it equals the
//! RUM cut in (register, reader-partition) pairs.
//!
//! Vertex weights are `1 + |cone ops|` — the replicated work a partition
//! pays for owning the register — so the balance constraint bounds
//! per-partition compute, not just register counts.

use crate::tensor::ir::LayerIr;
use crate::tensor::oim::operand_slots;

/// Sentinel for "this vertex is the output anchor, not a register".
pub const ANCHOR_REG: usize = usize::MAX;

/// The register-affinity hypergraph of a lowered design.
pub struct RegHypergraph {
    /// Vertex count (writable registers + 1 anchor).
    pub n: usize,
    /// The output-anchor vertex (always `n - 1`, weight 0, pinned to
    /// partition 0 by the partitioner).
    pub anchor: usize,
    /// Per-vertex weight: `1 + ops` in the register's next-state cone
    /// (0 for the anchor).
    pub weight: Vec<u64>,
    /// Hyperedges as sorted, deduplicated vertex lists (every edge has at
    /// least two pins).
    pub edges: Vec<Vec<u32>>,
    /// Per-edge weight (RUM pair cost contributed per crossed partition).
    pub edge_weight: Vec<u64>,
    /// Per-vertex incident edge ids.
    pub pins: Vec<Vec<u32>>,
    /// Vertex → commit index in `ir.commits` ([`ANCHOR_REG`] for the
    /// anchor).
    pub reg_of_vert: Vec<usize>,
}

/// Which commits are *never written*: the next-state slot is the register
/// slot itself (`Graph::reg`'s default self-holding wiring, e.g. the
/// `rom{i}` lane-ROM registers of `tiny_cpu_divergent`). Their value can
/// only change through out-of-band pokes, which the coordinator
/// broadcasts to every partition, so they never need RUM tracking.
pub fn never_written(ir: &LayerIr) -> Vec<bool> {
    ir.commits.iter().map(|c| c.0 == c.1).collect()
}

/// Walk the transitive fan-in cone of `seeds`, invoking `on_op(layer,
/// op)` for every op record kept and `on_source` for every source slot
/// (register, input or constant) reached. `stamp`/`epoch` implement
/// reusable visited marks; `stack` is reusable scratch. The single cone
/// traversal shared by the hypergraph build and `partition_ir`'s
/// per-partition cone growth — keeping the cut model and the replicated
/// cones derived from the same walk.
pub(super) fn walk_cone(
    ir: &LayerIr,
    writer_of_slot: &[Option<(u32, u32)>],
    seeds: &[u32],
    stamp: &mut [u32],
    epoch: u32,
    stack: &mut Vec<u32>,
    mut on_op: impl FnMut(u32, u32),
    mut on_source: impl FnMut(u32),
) {
    stack.clear();
    stack.extend_from_slice(seeds);
    while let Some(slot) = stack.pop() {
        if stamp[slot as usize] == epoch {
            continue;
        }
        stamp[slot as usize] = epoch;
        if let Some((li, oi)) = writer_of_slot[slot as usize] {
            on_op(li, oi);
            let rec = &ir.layers[li as usize][oi as usize];
            for r in operand_slots(rec, &ir.ext_args) {
                stack.push(r);
            }
        } else {
            on_source(slot);
        }
    }
}

/// `writer_of_slot[s]` = the `(layer, op)` coordinates writing slot `s`,
/// `None` for source slots (registers, inputs, constants).
pub(super) fn writer_map(ir: &LayerIr) -> Vec<Option<(u32, u32)>> {
    let mut writer_of_slot: Vec<Option<(u32, u32)>> = vec![None; ir.num_slots];
    for (li, layer) in ir.layers.iter().enumerate() {
        for (oi, rec) in layer.iter().enumerate() {
            writer_of_slot[rec.out as usize] = Some((li as u32, oi as u32));
        }
    }
    writer_of_slot
}

/// Build the register-affinity hypergraph of `ir` (see module docs).
pub fn build(ir: &LayerIr) -> RegHypergraph {
    let never = never_written(ir);
    let mut vert_of_slot: Vec<u32> = vec![u32::MAX; ir.num_slots];
    let mut reg_of_vert: Vec<usize> = Vec::new();
    for (ri, c) in ir.commits.iter().enumerate() {
        if !never[ri] {
            vert_of_slot[c.0 as usize] = reg_of_vert.len() as u32;
            reg_of_vert.push(ri);
        }
    }
    let n_writable = reg_of_vert.len();
    let anchor = n_writable;
    reg_of_vert.push(ANCHOR_REG);
    let n = n_writable + 1;

    let writer_of_slot = writer_map(ir);

    let mut weight = vec![0u64; n];
    // read register vertex → vertices whose cones read it (incl. anchor)
    let mut readers_of: Vec<Vec<u32>> = vec![Vec::new(); n_writable];
    let mut stamp = vec![0u32; ir.num_slots];
    let mut stack: Vec<u32> = Vec::new();

    for v in 0..n_writable {
        let ri = reg_of_vert[v];
        let seeds = [ir.commits[ri].1];
        let mut ops = 0u64;
        walk_cone(
            ir,
            &writer_of_slot,
            &seeds,
            &mut stamp,
            v as u32 + 1,
            &mut stack,
            |_, _| ops += 1,
            |slot| {
                let q = vert_of_slot[slot as usize];
                if q != u32::MAX {
                    readers_of[q as usize].push(v as u32);
                }
            },
        );
        weight[v] = 1 + ops;
    }
    // the output cone reads registers too: the anchor vertex stands in
    // for it, pinning that traffic toward partition 0
    let out_seeds: Vec<u32> = ir.output_slots.iter().map(|(_, s)| *s).collect();
    walk_cone(
        ir,
        &writer_of_slot,
        &out_seeds,
        &mut stamp,
        n_writable as u32 + 1,
        &mut stack,
        |_, _| {},
        |slot| {
            let q = vert_of_slot[slot as usize];
            if q != u32::MAX {
                readers_of[q as usize].push(anchor as u32);
            }
        },
    );

    let mut edges: Vec<Vec<u32>> = Vec::new();
    let mut edge_weight: Vec<u64> = Vec::new();
    for (q, readers) in readers_of.iter().enumerate() {
        if readers.is_empty() {
            continue; // write-only register: no RUM traffic possible
        }
        let mut pins: Vec<u32> = Vec::with_capacity(readers.len() + 1);
        pins.push(q as u32);
        pins.extend_from_slice(readers);
        pins.sort_unstable();
        pins.dedup();
        if pins.len() < 2 {
            continue; // only read by its own cone: never cut
        }
        edges.push(pins);
        edge_weight.push(1);
    }

    let pins = pins_of(n, &edges);
    RegHypergraph { n, anchor, weight, edges, edge_weight, pins, reg_of_vert }
}

/// Per-vertex incident edge lists for `edges` over `n` vertices.
pub fn pins_of(n: usize, edges: &[Vec<u32>]) -> Vec<Vec<u32>> {
    let mut pins: Vec<Vec<u32>> = vec![Vec::new(); n];
    for (e, edge) in edges.iter().enumerate() {
        for &v in edge {
            pins[v as usize].push(e as u32);
        }
    }
    pins
}

/// The (λ − 1) connectivity cost of `parts` over the hypergraph — equal
/// to the RUM cut in (register, reader-partition) pairs (module docs).
pub fn connectivity_cost(hg: &RegHypergraph, parts: &[u32]) -> u64 {
    let mut cost = 0u64;
    let mut seen: Vec<u32> = Vec::new();
    for (e, edge) in hg.edges.iter().enumerate() {
        seen.clear();
        for &v in edge {
            let p = parts[v as usize];
            if !seen.contains(&p) {
                seen.push(p);
            }
        }
        cost += hg.edge_weight[e] * (seen.len() as u64 - 1);
    }
    cost
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::passes::optimize;
    use crate::tensor::ir::lower;

    fn hg_for(name: &str) -> (LayerIr, RegHypergraph) {
        let d = crate::designs::catalog(name).unwrap();
        let (opt, _) = optimize(&d.graph);
        let ir = lower(&opt);
        let hg = build(&ir);
        (ir, hg)
    }

    /// Structural invariants: one vertex per writable register plus the
    /// anchor, positive weights, sorted pins referencing valid vertices.
    #[test]
    fn hypergraph_structure_is_well_formed() {
        for name in ["fir8", "gemmini_like_4", "rocket_like_1c"] {
            let (ir, hg) = hg_for(name);
            let writable = never_written(&ir).iter().filter(|&&nw| !nw).count();
            assert_eq!(hg.n, writable + 1, "{name}");
            assert_eq!(hg.anchor, hg.n - 1, "{name}");
            assert_eq!(hg.weight[hg.anchor], 0, "{name}: anchor carries no work");
            for v in 0..hg.anchor {
                assert!(hg.weight[v] >= 1, "{name}: writable reg cones weigh >= 1");
                assert!(hg.reg_of_vert[v] < ir.commits.len(), "{name}");
            }
            assert!(!hg.edges.is_empty(), "{name}: sequential designs have affinity");
            for edge in &hg.edges {
                assert!(edge.len() >= 2, "{name}: single-pin edges are dropped");
                assert!(edge.windows(2).all(|w| w[0] < w[1]), "{name}: sorted pins");
                assert!(edge.iter().all(|&v| (v as usize) < hg.n), "{name}");
            }
        }
    }

    /// A uniform partition has zero connectivity cost; scattering every
    /// vertex raises it.
    #[test]
    fn connectivity_cost_tracks_scatter() {
        let (_, hg) = hg_for("gemmini_like_4");
        let all_zero = vec![0u32; hg.n];
        assert_eq!(connectivity_cost(&hg, &all_zero), 0);
        let scattered: Vec<u32> = (0..hg.n as u32).map(|v| v % 4).collect();
        assert!(connectivity_cost(&hg, &scattered) > 0);
    }
}
