//! Multilevel (λ − 1)-connectivity hypergraph partitioning: heavy-edge
//! coarsening → greedy initial split → Fiduccia–Mattheyses boundary
//! refinement at every level, under a balance constraint.
//!
//! The shape follows hMETIS/KaHyPar at toy scale:
//!
//! 1. **Coarsening** — repeated heavy-edge matching: each vertex pairs
//!    with the unmatched neighbour it shares the most (size-normalized)
//!    hyperedge weight with; matched pairs merge, edges are remapped with
//!    identical pin sets folded together, and single-pin edges dropped.
//!    Merges are capped at the average partition weight so no coarse
//!    vertex can single-handedly break the balance constraint.
//! 2. **Initial split** — on the coarsest graph, vertices in decreasing
//!    weight order go to the feasible partition with the strongest
//!    existing affinity (most incident hyperedge weight already present),
//!    ties to the lightest partition.
//! 3. **Refinement** — k-way FM passes: repeatedly apply the best-gain
//!    feasible single-vertex move (locking the vertex), allow limited
//!    negative-gain moves to climb out of local minima, and roll back to
//!    the best prefix of the pass; projected down level by level.
//!
//! Everything is deterministic for a fixed seed: the only randomness is
//! the seeded visit order of the matching, and every tie-break is by
//! lowest index. The output is a partition id per vertex respecting
//! [`balance_limit`] (enforced by a final rebalance sweep at the finest
//! level) with the anchor vertex pinned to partition 0.

use std::collections::HashMap;

use super::hypergraph::{pins_of, RegHypergraph};
use crate::util::prng::Rng;

/// Allowed relative imbalance: no partition's vertex weight may exceed
/// `balance_limit(total, n, max_w)`.
pub const BALANCE_EPS: f64 = 0.10;

/// Coarsening stops once the graph has at most `max(8 n, 48)` vertices.
const COARSEN_STOP_FACTOR: usize = 8;
const COARSEN_MIN: usize = 48;
/// FM passes per level, and the negative-gain stall window per pass.
const MAX_FM_PASSES: usize = 6;
const FM_STALL: usize = 24;
/// Hyperedges wider than this are ignored when scoring matches (their
/// 1/(|e|−1) contribution is negligible and scanning them is quadratic).
const EDGE_SCORE_CAP: usize = 64;

/// The partition-weight ceiling: `(1 + ε)` of the average, but never less
/// than one maximal vertex on top of the average (otherwise a single hot
/// cone could make every placement infeasible).
pub fn balance_limit(total: u64, n: usize, max_w: u64) -> u64 {
    let avg_floor = total / n as u64;
    let relaxed = (total as f64 * (1.0 + BALANCE_EPS) / n as f64).ceil() as u64;
    relaxed.max(avg_floor + max_w)
}

/// One level of the coarsening hierarchy.
struct Level {
    weight: Vec<u64>,
    edges: Vec<Vec<u32>>,
    edge_weight: Vec<u64>,
    pins: Vec<Vec<u32>>,
    anchor: usize,
}

/// Partition `hg` into `n` parts; returns a part id per vertex (anchor
/// pinned to part 0). Deterministic for a fixed `seed`.
pub fn partition(hg: &RegHypergraph, n: usize, seed: u64) -> Vec<u32> {
    assert!(n >= 1);
    if n == 1 || hg.n <= 1 {
        return vec![0; hg.n];
    }
    let total: u64 = hg.weight.iter().sum();
    let max_w = hg.weight.iter().copied().max().unwrap_or(0);
    let limit = balance_limit(total, n, max_w);
    let merge_cap = (total / n as u64).max(1);

    let mut levels = vec![Level {
        weight: hg.weight.clone(),
        edges: hg.edges.clone(),
        edge_weight: hg.edge_weight.clone(),
        pins: hg.pins.clone(),
        anchor: hg.anchor,
    }];
    let mut maps: Vec<Vec<u32>> = Vec::new();
    let stop = (COARSEN_STOP_FACTOR * n).max(COARSEN_MIN);
    let mut rng = Rng::new(seed);
    while levels.last().unwrap().weight.len() > stop {
        match coarsen(levels.last().unwrap(), merge_cap, &mut rng) {
            Some((next, map)) => {
                maps.push(map);
                levels.push(next);
            }
            None => break,
        }
    }

    let mut part = initial(levels.last().unwrap(), n, limit);
    refine(levels.last().unwrap(), n, limit, &mut part);
    for li in (0..maps.len()).rev() {
        let fine = &levels[li];
        let map = &maps[li];
        let mut fine_part = vec![0u32; fine.weight.len()];
        for v in 0..fine.weight.len() {
            fine_part[v] = part[map[v] as usize];
        }
        part = fine_part;
        refine(fine, n, limit, &mut part);
    }
    rebalance(&levels[0], n, limit, &mut part);
    part
}

/// Warm-start k-way partitioning for incremental recompiles: seed the
/// assignment from a previous run instead of coarsening from scratch.
/// `prev[v]` carries vertex `v`'s prior part (clamped into range), or
/// `None` for vertices whose cone changed (or is new) — those are
/// re-homed greedily by edge affinity in decreasing weight order, exactly
/// like [`initial`]. The seed then gets the same boundary-FM polish (with
/// best-prefix rollback) and final balance repair as the cold path, so
/// the result respects [`balance_limit`] with the anchor pinned to 0 —
/// but skips the coarsening hierarchy entirely, which is what makes the
/// warm path cheap.
pub fn warm_start(hg: &RegHypergraph, n: usize, prev: &[Option<u32>]) -> Vec<u32> {
    assert!(n >= 1);
    assert_eq!(prev.len(), hg.n, "prev assignment must cover every vertex");
    if n == 1 || hg.n <= 1 {
        return vec![0; hg.n];
    }
    let total: u64 = hg.weight.iter().sum();
    let max_w = hg.weight.iter().copied().max().unwrap_or(0);
    let limit = balance_limit(total, n, max_w);
    let level = Level {
        weight: hg.weight.clone(),
        edges: hg.edges.clone(),
        edge_weight: hg.edge_weight.clone(),
        pins: hg.pins.clone(),
        anchor: hg.anchor,
    };
    const UNPLACED: u32 = u32::MAX;
    let mut part = vec![UNPLACED; hg.n];
    let mut load = vec![0u64; n];
    part[hg.anchor] = 0;
    load[0] += level.weight[hg.anchor];
    for (v, prev_p) in prev.iter().enumerate() {
        if v == hg.anchor {
            continue;
        }
        if let Some(p) = prev_p {
            // carried verbatim, even if the prior run used a different
            // balance point — refine/rebalance below repair any drift
            let p = (*p as usize).min(n - 1);
            part[v] = p as u32;
            load[p] += level.weight[v];
        }
    }
    let mut cnt: Vec<Vec<u32>> = level.edges.iter().map(|_| vec![0u32; n]).collect();
    for (e, pins) in level.edges.iter().enumerate() {
        for &v in pins {
            if part[v as usize] != UNPLACED {
                cnt[e][part[v as usize] as usize] += 1;
            }
        }
    }
    let mut order: Vec<u32> =
        (0..hg.n as u32).filter(|&v| part[v as usize] == UNPLACED).collect();
    order.sort_by_key(|&v| (std::cmp::Reverse(level.weight[v as usize]), v));
    for &v in &order {
        let v = v as usize;
        let w = level.weight[v];
        let mut best: Option<(u64, usize)> = None;
        for p in 0..n {
            if load[p] + w > limit {
                continue;
            }
            let mut s = 0u64;
            for &e in &level.pins[v] {
                if cnt[e as usize][p] > 0 {
                    s += level.edge_weight[e as usize];
                }
            }
            let better = match best {
                None => true,
                Some((bs, bp)) => s > bs || (s == bs && (load[p], p) < (load[bp], bp)),
            };
            if better {
                best = Some((s, p));
            }
        }
        let p = match best {
            Some((_, p)) => p,
            None => (0..n).min_by_key(|&p| (load[p], p)).unwrap(),
        };
        part[v] = p as u32;
        load[p] += w;
        for &e in &level.pins[v] {
            cnt[e as usize][p] += 1;
        }
    }
    refine(&level, n, limit, &mut part);
    rebalance(&level, n, limit, &mut part);
    part
}

/// One heavy-edge-matching coarsening step; `None` when matching no
/// longer shrinks the graph meaningfully.
fn coarsen(level: &Level, merge_cap: u64, rng: &mut Rng) -> Option<(Level, Vec<u32>)> {
    let nv = level.weight.len();
    let mut order: Vec<u32> = (0..nv as u32).collect();
    rng.shuffle(&mut order);
    let mut mate: Vec<u32> = vec![u32::MAX; nv];
    mate[level.anchor] = level.anchor as u32; // the anchor never merges
    let mut score: Vec<u64> = vec![0; nv];
    let mut touched: Vec<u32> = Vec::new();
    for &u in &order {
        let u = u as usize;
        if mate[u] != u32::MAX {
            continue;
        }
        for &e in &level.pins[u] {
            let pins = &level.edges[e as usize];
            if pins.len() > EDGE_SCORE_CAP {
                continue;
            }
            let s = (level.edge_weight[e as usize] << 8) / (pins.len() as u64 - 1);
            for &v in pins {
                let v = v as usize;
                if v == u || mate[v] != u32::MAX {
                    continue;
                }
                if level.weight[u] + level.weight[v] > merge_cap {
                    continue;
                }
                if score[v] == 0 {
                    touched.push(v as u32);
                }
                score[v] += s;
            }
        }
        let mut best: Option<usize> = None;
        for &v in &touched {
            let v = v as usize;
            let better = match best {
                None => true,
                Some(b) => score[v] > score[b] || (score[v] == score[b] && v < b),
            };
            if better {
                best = Some(v);
            }
        }
        match best {
            Some(v) => {
                mate[u] = v as u32;
                mate[v] = u as u32;
            }
            None => mate[u] = u as u32,
        }
        for &v in &touched {
            score[v as usize] = 0;
        }
        touched.clear();
    }

    // coarse ids in fine-index order (determinism)
    let mut map = vec![u32::MAX; nv];
    let mut n_coarse = 0u32;
    for v in 0..nv {
        if map[v] != u32::MAX {
            continue;
        }
        map[v] = n_coarse;
        map[mate[v] as usize] = n_coarse;
        n_coarse += 1;
    }
    if n_coarse as usize * 100 > nv * 97 {
        return None; // matching stalled
    }

    let mut weight = vec![0u64; n_coarse as usize];
    for v in 0..nv {
        weight[map[v] as usize] += level.weight[v];
    }
    let mut edges: Vec<Vec<u32>> = Vec::new();
    let mut edge_weight: Vec<u64> = Vec::new();
    let mut seen: HashMap<Vec<u32>, usize> = HashMap::new();
    let mut scratch: Vec<u32> = Vec::new();
    for (e, pins) in level.edges.iter().enumerate() {
        scratch.clear();
        scratch.extend(pins.iter().map(|&v| map[v as usize]));
        scratch.sort_unstable();
        scratch.dedup();
        if scratch.len() < 2 {
            continue; // edge collapsed inside one coarse vertex
        }
        match seen.get(&scratch) {
            Some(&i) => edge_weight[i] += level.edge_weight[e],
            None => {
                seen.insert(scratch.clone(), edges.len());
                edges.push(scratch.clone());
                edge_weight.push(level.edge_weight[e]);
            }
        }
    }
    let pins = pins_of(n_coarse as usize, &edges);
    let anchor = map[level.anchor] as usize;
    Some((Level { weight, edges, edge_weight, pins, anchor }, map))
}

/// Greedy affinity-based initial split of the coarsest level.
fn initial(level: &Level, n: usize, limit: u64) -> Vec<u32> {
    let nv = level.weight.len();
    let mut part = vec![0u32; nv];
    let mut load = vec![0u64; n];
    let mut cnt: Vec<Vec<u32>> = level.edges.iter().map(|_| vec![0u32; n]).collect();
    let place = |v: usize, p: usize, part: &mut [u32], load: &mut [u64], cnt: &mut [Vec<u32>]| {
        part[v] = p as u32;
        load[p] += level.weight[v];
        for &e in &level.pins[v] {
            cnt[e as usize][p] += 1;
        }
    };
    place(level.anchor, 0, &mut part, &mut load, &mut cnt);

    let mut order: Vec<u32> = (0..nv as u32).collect();
    order.sort_by_key(|&v| (std::cmp::Reverse(level.weight[v as usize]), v));
    for &v in &order {
        let v = v as usize;
        if v == level.anchor {
            continue;
        }
        let w = level.weight[v];
        let mut best: Option<(u64, usize)> = None;
        for p in 0..n {
            if load[p] + w > limit {
                continue;
            }
            let mut s = 0u64;
            for &e in &level.pins[v] {
                if cnt[e as usize][p] > 0 {
                    s += level.edge_weight[e as usize];
                }
            }
            let better = match best {
                None => true,
                Some((bs, bp)) => s > bs || (s == bs && (load[p], p) < (load[bp], bp)),
            };
            if better {
                best = Some((s, p));
            }
        }
        let p = match best {
            Some((_, p)) => p,
            // no feasible bin (can only happen at coarse levels where a
            // merged vertex outweighs the limit): fall back to lightest
            None => (0..n).min_by_key(|&p| (load[p], p)).unwrap(),
        };
        place(v, p, &mut part, &mut load, &mut cnt);
    }
    part
}

/// Per-edge part pin counts and per-part loads for `part`.
fn edge_counts(level: &Level, n: usize, part: &[u32]) -> (Vec<Vec<u32>>, Vec<u64>) {
    let mut cnt: Vec<Vec<u32>> = level.edges.iter().map(|_| vec![0u32; n]).collect();
    for (e, pins) in level.edges.iter().enumerate() {
        for &v in pins {
            cnt[e][part[v as usize] as usize] += 1;
        }
    }
    let mut load = vec![0u64; n];
    for (v, &p) in part.iter().enumerate() {
        load[p as usize] += level.weight[v];
    }
    (cnt, load)
}

fn connectivity(level: &Level, cnt: &[Vec<u32>]) -> i64 {
    let mut cost = 0i64;
    for (e, c) in cnt.iter().enumerate() {
        let parts_present = c.iter().filter(|&&x| x > 0).count() as i64;
        cost += level.edge_weight[e] as i64 * (parts_present - 1);
    }
    cost
}

/// The (λ − 1) gain of moving `v` from `from` to `to`.
fn move_gain(level: &Level, cnt: &[Vec<u32>], v: usize, from: usize, to: usize) -> i64 {
    let mut gain = 0i64;
    for &e in &level.pins[v] {
        let c = &cnt[e as usize];
        if c[from] == 1 {
            gain += level.edge_weight[e as usize] as i64;
        }
        if c[to] == 0 {
            gain -= level.edge_weight[e as usize] as i64;
        }
    }
    gain
}

fn apply_move(
    level: &Level,
    cnt: &mut [Vec<u32>],
    load: &mut [u64],
    part: &mut [u32],
    v: usize,
    to: usize,
) {
    let from = part[v] as usize;
    part[v] = to as u32;
    load[from] -= level.weight[v];
    load[to] += level.weight[v];
    for &e in &level.pins[v] {
        cnt[e as usize][from] -= 1;
        cnt[e as usize][to] += 1;
    }
}

/// K-way FM boundary refinement with best-prefix rollback.
fn refine(level: &Level, n: usize, limit: u64, part: &mut [u32]) {
    let nv = level.weight.len();
    let (mut cnt, mut load) = edge_counts(level, n, part);
    let mut cand = vec![false; n];
    let mut cand_list: Vec<usize> = Vec::new();
    for _ in 0..MAX_FM_PASSES {
        let pass_start = connectivity(level, &cnt);
        let mut cur = pass_start;
        let mut best_cut = cur;
        let mut best_prefix = 0usize;
        let mut locked = vec![false; nv];
        locked[level.anchor] = true; // the anchor stays in partition 0
        let mut moves: Vec<(u32, u32, u32)> = Vec::new();
        let mut stall = 0usize;
        loop {
            let mut best: Option<(i64, usize, usize)> = None;
            for v in 0..nv {
                if locked[v] {
                    continue;
                }
                let from = part[v] as usize;
                let w = level.weight[v];
                cand_list.clear();
                for &e in &level.pins[v] {
                    let c = &cnt[e as usize];
                    for (p, &x) in c.iter().enumerate() {
                        if p != from && x > 0 && !cand[p] {
                            cand[p] = true;
                            cand_list.push(p);
                        }
                    }
                }
                let pmin = (0..n).min_by_key(|&p| (load[p], p)).unwrap();
                if pmin != from && !cand[pmin] {
                    cand[pmin] = true;
                    cand_list.push(pmin);
                }
                for &to in &cand_list {
                    if load[to] + w > limit {
                        continue;
                    }
                    let gain = move_gain(level, &cnt, v, from, to);
                    let better = match best {
                        None => true,
                        Some((bg, _, _)) => gain > bg,
                    };
                    if better {
                        best = Some((gain, v, to));
                    }
                }
                for &p in &cand_list {
                    cand[p] = false;
                }
            }
            let Some((gain, v, to)) = best else { break };
            let from = part[v] as usize;
            apply_move(level, &mut cnt, &mut load, part, v, to);
            locked[v] = true;
            moves.push((v as u32, from as u32, to as u32));
            cur -= gain;
            if cur < best_cut {
                best_cut = cur;
                best_prefix = moves.len();
                stall = 0;
            } else {
                stall += 1;
                if stall >= FM_STALL {
                    break;
                }
            }
        }
        // roll back past the best prefix
        for &(v, from, _) in moves[best_prefix..].iter().rev() {
            apply_move(level, &mut cnt, &mut load, part, v as usize, from as usize);
        }
        if best_cut >= pass_start {
            break;
        }
    }
}

/// Final balance repair at the finest level: while some partition exceeds
/// the limit, move its least-damaging vertex to the lightest partition.
/// Feasible by construction there (every vertex fits on top of a
/// below-average load) and bounded by a move budget.
fn rebalance(level: &Level, n: usize, limit: u64, part: &mut [u32]) {
    let nv = level.weight.len();
    let (mut cnt, mut load) = edge_counts(level, n, part);
    let mut budget = nv * 4 + 16;
    loop {
        let over = (0..n).max_by_key(|&p| (load[p], std::cmp::Reverse(p))).unwrap();
        if load[over] <= limit || budget == 0 {
            break;
        }
        budget -= 1;
        let to = (0..n).min_by_key(|&p| (load[p], p)).unwrap();
        let mut best: Option<(i64, usize)> = None;
        for v in 0..nv {
            if part[v] as usize != over || v == level.anchor || level.weight[v] == 0 {
                continue;
            }
            if load[to] + level.weight[v] > limit {
                continue;
            }
            let gain = move_gain(level, &cnt, v, over, to);
            let better = match best {
                None => true,
                Some((bg, _)) => gain > bg,
            };
            if better {
                best = Some((gain, v));
            }
        }
        let Some((_, v)) = best else { break };
        apply_move(level, &mut cnt, &mut load, part, v, to);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::passes::optimize;
    use crate::partition::hypergraph::{self, connectivity_cost};
    use crate::tensor::ir::lower;

    fn hg_for(name: &str) -> hypergraph::RegHypergraph {
        let d = crate::designs::catalog(name).unwrap();
        let (opt, _) = optimize(&d.graph);
        hypergraph::build(&lower(&opt))
    }

    /// The multilevel split respects the balance limit and covers every
    /// vertex with a valid part id, across designs and part counts.
    #[test]
    fn partition_is_balanced_and_total() {
        for name in ["fir8", "gemmini_like_8", "rocket_like_1c"] {
            let hg = hg_for(name);
            let total: u64 = hg.weight.iter().sum();
            let max_w = hg.weight.iter().copied().max().unwrap();
            for n in [2usize, 4] {
                let part = partition(&hg, n, 1);
                assert_eq!(part.len(), hg.n, "{name} n={n}");
                assert!(part.iter().all(|&p| (p as usize) < n), "{name} n={n}");
                assert_eq!(part[hg.anchor], 0, "{name} n={n}: anchor pinned to 0");
                let mut load = vec![0u64; n];
                for (v, &p) in part.iter().enumerate() {
                    load[p as usize] += hg.weight[v];
                }
                let limit = balance_limit(total, n, max_w);
                for (p, &l) in load.iter().enumerate() {
                    assert!(
                        l <= limit,
                        "{name} n={n}: partition {p} weighs {l} > limit {limit}"
                    );
                }
            }
        }
    }

    /// Refinement must leave the cut far below the scatter baseline on
    /// the structured systolic array (the RepCut-style win).
    #[test]
    fn mincut_beats_scatter_on_gemmini() {
        let hg = hg_for("gemmini_like_8");
        for n in [2usize, 4] {
            let part = partition(&hg, n, 1);
            let scattered: Vec<u32> = (0..hg.n as u32).map(|v| v % n as u32).collect();
            let cut = connectivity_cost(&hg, &part);
            let base = connectivity_cost(&hg, &scattered);
            assert!(cut < base, "n={n}: multilevel cut {cut} vs scatter {base}");
        }
    }

    /// Warm-starting from a perturbed prior assignment stays balanced,
    /// keeps the anchor pinned, and lands within a small factor of the
    /// from-scratch cut.
    #[test]
    fn warm_start_stays_near_the_scratch_cut() {
        let hg = hg_for("gemmini_like_8");
        let total: u64 = hg.weight.iter().sum();
        let max_w = hg.weight.iter().copied().max().unwrap();
        for n in [2usize, 4] {
            let scratch = partition(&hg, n, 1);
            // forget every 5th vertex (the "changed cones") and feed the
            // rest back as the warm seed
            let prev: Vec<Option<u32>> = scratch
                .iter()
                .enumerate()
                .map(|(v, &p)| if v % 5 == 0 { None } else { Some(p) })
                .collect();
            let warm = warm_start(&hg, n, &prev);
            assert_eq!(warm.len(), hg.n);
            assert_eq!(warm[hg.anchor], 0, "anchor pinned to 0");
            assert!(warm.iter().all(|&p| (p as usize) < n));
            let limit = balance_limit(total, n, max_w);
            let mut load = vec![0u64; n];
            for (v, &p) in warm.iter().enumerate() {
                load[p as usize] += hg.weight[v];
            }
            assert!(load.iter().all(|&l| l <= limit), "n={n}: warm start respects balance");
            let warm_cut = connectivity_cost(&hg, &warm);
            let scratch_cut = connectivity_cost(&hg, &scratch);
            assert!(
                warm_cut <= 2 * scratch_cut.max(1),
                "n={n}: warm cut {warm_cut} vs scratch {scratch_cut}"
            );
        }
    }

    /// Same seed → same partition, across independent runs.
    #[test]
    fn partition_is_deterministic_for_a_fixed_seed() {
        let hg = hg_for("gemmini_like_4");
        let a = partition(&hg, 4, 42);
        let b = partition(&hg, 4, 42);
        assert_eq!(a, b);
        let c = partition(&hg, 4, 42);
        assert_eq!(a, c);
    }
}
