//! Register partitioning for RepCut-style parallel simulation.
//!
//! [`crate::coordinator::parallel::BatchParallelSim`] splits a design by
//! *register ownership*: each partition owns a subset of the committed
//! registers and replicates the transitive fan-in cone of their
//! next-state logic, so partitions are independent within a cycle and
//! synchronize only through the per-cycle **RUM** exchange of cut
//! registers (Cascade 2's final Einsum). The quality of the ownership
//! assignment decides the RUM cut — the per-cycle synchronization
//! traffic that limits partitioned scaling — which is what this module
//! computes:
//!
//! * [`hypergraph`] — the **register-affinity hypergraph**: one vertex
//!   per writable register (weighted by its cone's op count), one
//!   hyperedge per read register spanning the registers whose cones read
//!   it (plus an anchor vertex for the output cone, pinned to partition
//!   0). The hyperedge connectivity-minus-one cost of an ownership
//!   assignment equals the RUM cut in (register, reader-partition)
//!   pairs exactly.
//! * [`multilevel`] — a multilevel min-cut partitioner over that
//!   hypergraph: heavy-edge coarsening, greedy affinity-based initial
//!   split, and Fiduccia–Mattheyses boundary refinement (best-gain
//!   single-vertex moves with best-prefix rollback) at every level,
//!   under the [`multilevel::balance_limit`] weight constraint.
//! * [`partition_ir`] — the partitioning driver shared by every
//!   [`Partitioner`]: it turns an ownership assignment into filtered
//!   per-partition IRs, the RUM tracking table and the per-partition
//!   input dependencies the runtime needs.
//!
//! Two [`Partitioner`] implementations are exposed, selectable with
//! `rteaal sim --parts P --partitioner {rr,mincut}`:
//! [`RoundRobin`] (the original `i mod n` scatter — worst-case cut,
//! useful as a baseline and for bisection) and [`MinCut`] (the
//! multilevel partitioner, the default).
//!
//! **Never-written registers** (next-state slot == register slot, e.g.
//! the self-holding `rom{i}` lane-ROM registers of
//! `tiny_cpu_divergent`) can only change through out-of-band pokes,
//! which the coordinator broadcasts to every partition. `partition_ir`
//! therefore assigns each one to (the lowest-indexed) partition whose
//! cone reads it and keeps it out of the RUM tracking table entirely:
//! pure ROM never enters the cut, under either partitioner.

pub mod hypergraph;
pub mod multilevel;

use std::collections::{BTreeSet, HashMap};

use crate::tensor::ir::LayerIr;

pub use hypergraph::never_written;

/// Selectable register-ownership strategies.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum PartitionerKind {
    /// `register i → partition i mod n`: the historical baseline, with a
    /// near-worst-case RUM cut on structured designs.
    RoundRobin,
    /// Multilevel hypergraph min-cut ([`MinCut`]): coarsen → greedy split
    /// → FM refinement, minimizing the RUM cut under a balance bound.
    #[default]
    MinCut,
}

impl PartitionerKind {
    pub fn name(self) -> &'static str {
        match self {
            PartitionerKind::RoundRobin => "rr",
            PartitionerKind::MinCut => "mincut",
        }
    }

    /// Parse a `--partitioner` argument (`rr` | `mincut`).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "rr" | "roundrobin" | "round-robin" => Some(PartitionerKind::RoundRobin),
            "mincut" | "min-cut" => Some(PartitionerKind::MinCut),
            _ => None,
        }
    }

    pub fn build(self) -> Box<dyn Partitioner> {
        match self {
            PartitionerKind::RoundRobin => Box::new(RoundRobin),
            PartitionerKind::MinCut => Box::new(MinCut::default()),
        }
    }
}

/// A register-ownership strategy: maps every commit of `ir` to one of
/// `n` partitions. Any total assignment is *correct* (the partitioning
/// driver replicates cones and tracks the cut it induces); quality is
/// measured by [`Partitioning::cut_pairs`].
pub trait Partitioner {
    fn name(&self) -> &'static str;
    /// One owner in `0..n` per entry of `ir.commits`.
    fn assign(&self, ir: &LayerIr, n: usize) -> Vec<usize>;
}

/// `register i → partition i mod n`.
pub struct RoundRobin;

impl Partitioner for RoundRobin {
    fn name(&self) -> &'static str {
        "rr"
    }

    fn assign(&self, ir: &LayerIr, n: usize) -> Vec<usize> {
        (0..ir.commits.len()).map(|i| i % n).collect()
    }
}

/// Multilevel hypergraph min-cut ownership (see [`multilevel`]).
/// Deterministic for a fixed `seed` — two instances with the same seed
/// produce identical assignments.
pub struct MinCut {
    pub seed: u64,
}

impl Default for MinCut {
    fn default() -> Self {
        MinCut { seed: 0x5EED_CA7 }
    }
}

impl Partitioner for MinCut {
    fn name(&self) -> &'static str {
        "mincut"
    }

    fn assign(&self, ir: &LayerIr, n: usize) -> Vec<usize> {
        // provisional round-robin for never-written registers — the ones
        // with readers are re-homed by `partition_ir`
        let mut owner: Vec<usize> = (0..ir.commits.len()).map(|i| i % n).collect();
        if n > 1 {
            let hg = hypergraph::build(ir);
            let parts = multilevel::partition(&hg, n, self.seed);
            for (v, &ri) in hg.reg_of_vert.iter().enumerate() {
                if ri != hypergraph::ANCHOR_REG {
                    owner[ri] = parts[v] as usize;
                }
            }
        }
        owner
    }
}

/// Warm-start ownership for incremental recompiles: seed the FM
/// boundary refinement from a prior assignment keyed by register *name*
/// (slot numbering shifts between compiles; names survive edits).
/// Registers absent from `prev_owner` — changed cones, renamed or new
/// registers — are re-homed greedily before refinement, so a
/// single-module edit perturbs the cut locally instead of re-running
/// the full coarsen → split → refine search.
pub fn warm_partition(ir: &LayerIr, n: usize, prev_owner: &HashMap<String, usize>) -> Vec<usize> {
    assert!(n >= 1);
    let mut owner: Vec<usize> = (0..ir.commits.len()).map(|i| i % n).collect();
    if n > 1 {
        let hg = hypergraph::build(ir);
        let prev: Vec<Option<u32>> = hg
            .reg_of_vert
            .iter()
            .map(|&ri| {
                if ri == hypergraph::ANCHOR_REG {
                    return None; // the output anchor is pinned by warm_start
                }
                let slot = ir.commits[ri].0 as usize;
                ir.slot_names
                    .get(slot)
                    .and_then(|name| name.as_deref())
                    .and_then(|name| prev_owner.get(name))
                    .map(|&p| (p as u32).min(n as u32 - 1))
            })
            .collect();
        let parts = multilevel::warm_start(&hg, n, &prev);
        for (v, &ri) in hg.reg_of_vert.iter().enumerate() {
            if ri != hypergraph::ANCHOR_REG {
                owner[ri] = parts[v] as usize;
            }
        }
    }
    owner
}

/// Replay a previously computed ownership assignment verbatim (the
/// service design cache stores `Partitioning::owner_of_reg` and rebuilds
/// the cones through [`partition_ir_with`] — the cheap passes — instead
/// of re-running the multilevel min-cut search).
pub struct FixedOwners(pub Vec<usize>);

impl Partitioner for FixedOwners {
    fn name(&self) -> &'static str {
        "fixed"
    }

    fn assign(&self, ir: &LayerIr, n: usize) -> Vec<usize> {
        assert_eq!(self.0.len(), ir.commits.len(), "cached ownership is for another design");
        assert!(self.0.iter().all(|&p| p < n), "cached ownership exceeds partition count");
        self.0.clone()
    }
}

/// A register tracked across the cycle boundary: committed by `owner`,
/// read by `readers` (which may include the owner itself — its own
/// next-state logic reading the register back).
pub struct TrackedReg {
    pub owner: usize,
    pub reg_slot: u32,
    /// every partition whose cone reads the register (sorted)
    pub readers: Vec<u32>,
    /// `readers` minus the owner — the RUM value-propagation targets
    pub rum_readers: Vec<u32>,
}

/// The compile-time partitioning: filtered per-partition IRs plus the
/// dependency structure the runtime needs (RUM entries, per-partition
/// input-port reads).
pub struct Partitioning {
    pub part_irs: Vec<LayerIr>,
    pub tracked: Vec<TrackedReg>,
    /// input-port indices read by each partition's cone
    pub input_deps: Vec<Vec<u32>>,
    /// partitions whose cones read each boundary (source) slot —
    /// registers, input ports and constants alike; sorted per slot.
    /// Slots absent from the map are read by no partition. Drives the
    /// runtime's *targeted* out-of-band poke wake (readers ∪ owner)
    /// instead of a full activity recold.
    pub readers_of_slot: HashMap<u32, Vec<u32>>,
    /// replicated-ops / total-ops (RepCut's replication overhead)
    pub replication_factor: f64,
    /// final owner per entry of `ir.commits`
    pub owner_of_reg: Vec<usize>,
}

impl Partitioning {
    pub fn num_partitions(&self) -> usize {
        self.part_irs.len()
    }

    /// RUM cut in (register, reader-partition) pairs — the per-cycle
    /// value-propagation work.
    pub fn cut_pairs(&self) -> usize {
        self.tracked.iter().map(|t| t.rum_readers.len()).sum()
    }

    /// RUM cut in distinct registers that cross partitions each cycle.
    pub fn cut_regs(&self) -> usize {
        self.tracked.iter().filter(|t| !t.rum_readers.is_empty()).count()
    }
}

/// Partition `ir` into `n` pieces under the given strategy: assign
/// register ownership, grow one transitive fan-in cone per partition
/// (logic read by several partitions is *replicated*, which decouples
/// partitions within a cycle — the replication RepCut pays for
/// superlinear scaling), re-home never-written registers to a reader
/// partition, and derive the RUM tracking table. Partition 0
/// additionally owns the design outputs.
pub fn partition_ir(ir: &LayerIr, n: usize, kind: PartitionerKind) -> Partitioning {
    partition_ir_with(ir, n, &*kind.build())
}

/// [`partition_ir`] with an explicit [`Partitioner`] instance.
pub fn partition_ir_with(ir: &LayerIr, n: usize, partitioner: &dyn Partitioner) -> Partitioning {
    assert!(n >= 1);
    let n_regs = ir.commits.len();
    let mut owner_of_reg = partitioner.assign(ir, n);
    assert_eq!(owner_of_reg.len(), n_regs, "partitioner must assign every register");
    assert!(owner_of_reg.iter().all(|&p| p < n), "partition ids must be < n");
    let never = never_written(ir);

    let writer_of_slot = hypergraph::writer_map(ir);
    let mut input_of: Vec<Option<u32>> = vec![None; ir.num_slots];
    for (i, &s) in ir.input_slots.iter().enumerate() {
        input_of[s as usize] = Some(i as u32);
    }

    // Pass A: one cone per partition (the same `walk_cone` the cut model
    // is built from), seeded by its *writable* owned registers'
    // next-state slots (+ the design outputs for partition 0).
    // Never-written registers contribute no logic and no reads, so the
    // cones — and with them the reader sets — are independent of their
    // ownership, which is resolved afterwards.
    let mut keep_per_part: Vec<Vec<BTreeSet<usize>>> = Vec::with_capacity(n);
    let mut sources_per_part: Vec<BTreeSet<u32>> = vec![BTreeSet::new(); n];
    let mut input_deps: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut stamp = vec![0u32; ir.num_slots];
    let mut stack: Vec<u32> = Vec::new();
    for p in 0..n {
        let mut keep: Vec<BTreeSet<usize>> = vec![BTreeSet::new(); ir.layers.len()];
        let mut seeds: Vec<u32> = Vec::new();
        for (ri, c) in ir.commits.iter().enumerate() {
            if owner_of_reg[ri] == p && !never[ri] {
                seeds.push(c.1);
            }
        }
        if p == 0 {
            for (_, s) in &ir.output_slots {
                seeds.push(*s);
            }
        }
        let sources = &mut sources_per_part[p];
        let deps = &mut input_deps[p];
        hypergraph::walk_cone(
            ir,
            &writer_of_slot,
            &seeds,
            &mut stamp,
            p as u32 + 1,
            &mut stack,
            |li, oi| {
                keep[li as usize].insert(oi as usize);
            },
            |slot| {
                // a source slot: register, input port or constant
                sources.insert(slot);
                if let Some(port) = input_of[slot as usize] {
                    deps.push(port);
                }
            },
        );
        deps.sort_unstable();
        deps.dedup();
        keep_per_part.push(keep);
    }

    // Re-home never-written registers: the lowest-indexed reader
    // partition owns them (pure ROM read by one partition never crosses
    // the cut; read by several, its value still never moves — it is not
    // tracked at all). Unread ones keep the provisional assignment.
    for (ri, c) in ir.commits.iter().enumerate() {
        if !never[ri] {
            continue;
        }
        if let Some(p) = (0..n).find(|&p| sources_per_part[p].contains(&c.0)) {
            owner_of_reg[ri] = p;
        }
    }

    // Pass B: materialize the filtered per-partition IRs.
    let mut part_irs = Vec::with_capacity(n);
    let mut total_kept = 0usize;
    for (p, keep) in keep_per_part.iter().enumerate() {
        let mut pir = ir.clone();
        pir.layers = ir
            .layers
            .iter()
            .enumerate()
            .map(|(li, layer)| keep[li].iter().map(|&oi| layer[oi]).collect::<Vec<_>>())
            .collect();
        pir.commits = ir
            .commits
            .iter()
            .enumerate()
            .filter(|(ri, _)| owner_of_reg[*ri] == p)
            .map(|(_, c)| *c)
            .collect();
        if p != 0 {
            pir.output_slots = Vec::new();
        }
        total_kept += pir.total_ops();
        part_irs.push(pir);
    }

    // RUM / boundary tracking: for each writable register, which
    // partitions read it.
    let mut tracked = Vec::new();
    for (ri, c) in ir.commits.iter().enumerate() {
        if never[ri] {
            continue; // pure ROM: can never change, nothing to track
        }
        let owner = owner_of_reg[ri];
        let readers: Vec<u32> = (0..n)
            .filter(|&p| sources_per_part[p].contains(&c.0))
            .map(|p| p as u32)
            .collect();
        if readers.is_empty() {
            continue; // write-only register: nothing to propagate or gate
        }
        let rum_readers: Vec<u32> =
            readers.iter().copied().filter(|&p| p as usize != owner).collect();
        tracked.push(TrackedReg { owner, reg_slot: c.0, readers, rum_readers });
    }

    // Boundary-slot reader map (targeted poke wake): which partitions'
    // cones read each source slot. Built from the same source sets the
    // RUM reader lists come from, so it covers never-written ROM slots
    // (absent from `tracked`) too.
    let mut readers_of_slot: HashMap<u32, Vec<u32>> = HashMap::new();
    for (p, sources) in sources_per_part.iter().enumerate() {
        for &slot in sources {
            readers_of_slot.entry(slot).or_default().push(p as u32);
        }
    }

    let replication_factor = total_kept as f64 / ir.total_ops().max(1) as f64;
    Partitioning {
        part_irs,
        tracked,
        input_deps,
        readers_of_slot,
        replication_factor,
        owner_of_reg,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::designs::catalog;
    use crate::designs::tiny_cpu::{dhrystone_like, tiny_cpu_divergent};
    use crate::graph::passes::optimize;
    use crate::tensor::ir::lower;

    fn ir_for(name: &str) -> LayerIr {
        let d = catalog(name).unwrap();
        let (opt, _) = optimize(&d.graph);
        lower(&opt)
    }

    const BOTH: [PartitionerKind; 2] = [PartitionerKind::RoundRobin, PartitionerKind::MinCut];

    /// `--partitioner` spellings resolve, unknown ones don't.
    #[test]
    fn kind_parsing() {
        assert_eq!(PartitionerKind::parse("rr"), Some(PartitionerKind::RoundRobin));
        assert_eq!(PartitionerKind::parse("RR"), Some(PartitionerKind::RoundRobin));
        assert_eq!(PartitionerKind::parse("mincut"), Some(PartitionerKind::MinCut));
        assert_eq!(PartitionerKind::parse("min-cut"), Some(PartitionerKind::MinCut));
        assert_eq!(PartitionerKind::parse("metis"), None);
        assert_eq!(PartitionerKind::default(), PartitionerKind::MinCut);
    }

    /// Round-robin reproduces the historical `i mod n` assignment.
    #[test]
    fn round_robin_matches_modulo() {
        let ir = ir_for("gemmini_like_4");
        let owner = RoundRobin.assign(&ir, 3);
        for (i, &p) in owner.iter().enumerate() {
            assert_eq!(p, i % 3);
        }
    }

    /// Partitioner invariant: ownership is a disjoint cover of every
    /// committed register, for both strategies and several part counts.
    #[test]
    fn ownership_is_a_disjoint_cover() {
        for name in ["fir8", "gemmini_like_4", "rocket_like_1c"] {
            let ir = ir_for(name);
            let all: BTreeSet<u32> = ir.commits.iter().map(|c| c.0).collect();
            for kind in BOTH {
                for n in [1usize, 2, 4] {
                    let parting = partition_ir(&ir, n, kind);
                    assert_eq!(parting.num_partitions(), n);
                    let mut seen = BTreeSet::new();
                    for pir in &parting.part_irs {
                        for c in &pir.commits {
                            assert!(
                                seen.insert(c.0),
                                "{name} {} n={n}: register slot {} owned twice",
                                kind.name(),
                                c.0
                            );
                        }
                    }
                    assert_eq!(seen, all, "{name} {} n={n}: cover", kind.name());
                }
            }
        }
    }

    /// Partitioner invariant: the min-cut assignment is deterministic —
    /// independent instances with the same seed agree exactly.
    #[test]
    fn mincut_assignment_is_deterministic() {
        let ir = ir_for("gemmini_like_8");
        let a = MinCut { seed: 7 }.assign(&ir, 4);
        let b = MinCut { seed: 7 }.assign(&ir, 4);
        assert_eq!(a, b);
        let c = MinCut { seed: 7 }.assign(&ir, 4);
        assert_eq!(a, c);
    }

    /// The headline quality bound: on the structured systolic array the
    /// min-cut partitioning must beat round-robin's scatter *strictly*,
    /// at P = 2 and P = 4, in both cut metrics that matter (pairs moved
    /// per cycle, distinct registers crossing).
    #[test]
    fn mincut_cut_is_strictly_smaller_than_round_robin_on_gemmini_like_8() {
        let ir = ir_for("gemmini_like_8");
        for n in [2usize, 4] {
            let rr = partition_ir(&ir, n, PartitionerKind::RoundRobin);
            let mc = partition_ir(&ir, n, PartitionerKind::MinCut);
            assert!(
                mc.cut_pairs() < rr.cut_pairs(),
                "P={n}: mincut pairs {} vs rr pairs {}",
                mc.cut_pairs(),
                rr.cut_pairs()
            );
            assert!(
                mc.cut_regs() <= rr.cut_regs(),
                "P={n}: mincut regs {} vs rr regs {}",
                mc.cut_regs(),
                rr.cut_regs()
            );
        }
    }

    /// Replaying a cached `owner_of_reg` through [`FixedOwners`] rebuilds
    /// an identical partitioning — same per-partition IRs, tracking table
    /// and cut — without the min-cut search (the design-cache load path).
    #[test]
    fn fixed_owners_replay_reproduces_partitioning() {
        let ir = ir_for("gemmini_like_4");
        let orig = partition_ir(&ir, 4, PartitionerKind::MinCut);
        let replay = partition_ir_with(&ir, 4, &FixedOwners(orig.owner_of_reg.clone()));
        assert_eq!(replay.owner_of_reg, orig.owner_of_reg);
        assert_eq!(replay.cut_pairs(), orig.cut_pairs());
        assert_eq!(replay.cut_regs(), orig.cut_regs());
        assert_eq!(replay.input_deps, orig.input_deps);
        assert_eq!(replay.tracked.len(), orig.tracked.len());
        for (a, b) in replay.tracked.iter().zip(&orig.tracked) {
            assert_eq!((a.owner, a.reg_slot), (b.owner, b.reg_slot));
            assert_eq!(a.readers, b.readers);
            assert_eq!(a.rum_readers, b.rum_readers);
        }
        for (a, b) in replay.part_irs.iter().zip(&orig.part_irs) {
            assert_eq!(a.total_ops(), b.total_ops());
            assert_eq!(a.commits, b.commits);
        }
    }

    /// Warm-starting from a prior assignment (keyed by register name,
    /// with a few entries dropped to mimic edited cones) produces a
    /// valid, balanced cover whose cut stays within a small factor of
    /// the from-scratch min-cut.
    #[test]
    fn warm_partition_is_a_valid_cover_near_the_scratch_cut() {
        let ir = ir_for("gemmini_like_8");
        for n in [2usize, 4] {
            let scratch = partition_ir(&ir, n, PartitionerKind::MinCut);
            let mut prev: HashMap<String, usize> = HashMap::new();
            for (ri, c) in ir.commits.iter().enumerate() {
                if let Some(name) = ir.slot_names[c.0 as usize].as_deref() {
                    prev.insert(name.to_string(), scratch.owner_of_reg[ri]);
                }
            }
            let dropped: Vec<String> = prev.keys().take(3).cloned().collect();
            for k in &dropped {
                prev.remove(k);
            }
            let owner = warm_partition(&ir, n, &prev);
            assert_eq!(owner.len(), ir.commits.len());
            assert!(owner.iter().all(|&p| p < n));
            let warm = partition_ir_with(&ir, n, &FixedOwners(owner));
            assert!(
                warm.cut_pairs() <= 2 * scratch.cut_pairs().max(1),
                "P={n}: warm cut {} vs scratch {}",
                warm.cut_pairs(),
                scratch.cut_pairs()
            );
        }
    }

    /// The boundary-slot reader map (the targeted poke wake's index)
    /// agrees with the RUM tracking table on every tracked register:
    /// same reader partitions, in the same order.
    #[test]
    fn readers_of_slot_agrees_with_tracked_readers() {
        for name in ["fir8", "gemmini_like_4"] {
            let ir = ir_for(name);
            for kind in BOTH {
                let parting = partition_ir(&ir, 3, kind);
                assert!(!parting.tracked.is_empty(), "{name}: nothing tracked");
                for t in &parting.tracked {
                    let got: &[u32] = parting
                        .readers_of_slot
                        .get(&t.reg_slot)
                        .map(|v| v.as_slice())
                        .unwrap_or(&[]);
                    assert_eq!(
                        got,
                        t.readers.as_slice(),
                        "{name} {}: reader partitions of slot {}",
                        kind.name(),
                        t.reg_slot
                    );
                }
            }
        }
    }

    /// Never-written registers (the divergent tiny_cpu's `rom{i}` ROM)
    /// are owned by a partition that reads them and stay out of the RUM
    /// tracking table entirely, under both partitioners.
    #[test]
    fn never_written_registers_stay_out_of_the_cut() {
        let g = tiny_cpu_divergent(32, &dhrystone_like(5));
        let (opt, _) = optimize(&g);
        let ir = lower(&opt);
        let never = never_written(&ir);
        let rom_slots: BTreeSet<u32> = ir
            .commits
            .iter()
            .zip(&never)
            .filter(|(_, &nw)| nw)
            .map(|(c, _)| c.0)
            .collect();
        assert!(!rom_slots.is_empty(), "the divergent build must carry a register ROM");
        for kind in BOTH {
            let parting = partition_ir(&ir, 4, kind);
            for t in &parting.tracked {
                assert!(
                    !rom_slots.contains(&t.reg_slot),
                    "{}: ROM slot {} entered the RUM tracking table",
                    kind.name(),
                    t.reg_slot
                );
            }
        }
    }
}
