//! Incremental recompilation: content-hashed cone deltas.
//!
//! Given an edited [`Design`] and the cached artifacts of a *prior*
//! compile of the same design family ([`CachedDesign`]), diff the
//! per-register cone hashes ([`crate::graph::cone`]) and rebuild only
//! the cones that changed:
//!
//! 1. **Diff** — registers whose cone hash moved (plus the output cone,
//!    if its combined hash moved) form the invalidation set. A changed
//!    input-port interface or register list disables delta matching
//!    entirely (`None` → the caller falls back to a cold compile).
//! 2. **Sub-compile** — the changed cones are extracted into a small
//!    sub-graph (all ports and registers as boundary sources, only the
//!    changed next-state logic included) and run through the *same*
//!    optimize → lower pipeline as a cold compile. This is where the
//!    speedup comes from: the graph passes dominate cold-compile time
//!    and now see only the edited cones.
//! 3. **Graft** — the optimized sub-IR is spliced into a clone of the
//!    prior [`LayerIr`]: boundary sources map to their prior slots,
//!    new ops get fresh slots appended after the prior slot file (which
//!    keeps every layer's strictly-ascending-by-out invariant), and the
//!    changed registers' commits are repointed. Ops orphaned by the
//!    graft (the *old* cones of the changed registers) are garbage
//!    collected by a liveness walk from the commits and outputs.
//! 4. **Splice** — [`Oim::splice`] and [`GroupDepGraph::splice`] rebuild
//!    only the rows and groups of layers the graft touched, copying
//!    everything else from the prior artifacts.
//!
//! The resulting artifacts simulate bit-identically to a cold compile
//! of the edited design (compared by register *name* — slot ids differ,
//! since the graft preserves the prior numbering).

use std::collections::HashMap;

use crate::activity::GroupDepGraph;
use crate::designs::Design;
use crate::graph::cone::{cone_hashes, ConeHashes};
use crate::graph::ops::mask;
use crate::graph::{passes, Graph, NodeId, NodeKind};
use crate::service::cache::{CachedDesign, RegInfo};
use crate::tensor::ir::{lower, KOp, LayerIr};
use crate::tensor::oim::{operand_slots, Oim};

/// Everything a delta pass produces: spliced artifacts plus the reuse
/// accounting surfaced in [`crate::service::cache::OpenReport`].
pub struct DeltaOut {
    pub ir: LayerIr,
    pub oim: Oim,
    pub gdg: GroupDepGraph,
    /// Cone signature of the *edited* design, persisted with the new
    /// cache entry so it can donate deltas in turn.
    pub cone: ConeHashes,
    /// Register map of the grafted IR (prior slots, edited widths).
    pub regs: Vec<RegInfo>,
    /// GDG groups copied from the prior artifacts unchanged.
    pub reused_groups: usize,
    /// GDG groups rebuilt because their layer was touched by the graft.
    pub rebuilt_groups: usize,
    /// Names of the registers whose cones were recompiled.
    pub changed_regs: Vec<String>,
}

/// Attempt an incremental compile of `design` against `prior`. Returns
/// `None` when the designs are not delta-compatible (different port
/// interface or register list, or a register the graft needs is missing
/// from the prior artifacts) — the caller then cold-compiles instead.
pub fn delta_compile(design: &Design, prior: &CachedDesign, fuse: bool) -> Option<DeltaOut> {
    let g = &design.graph;
    let cone = cone_hashes(g);
    if cone.inputs != prior.cone.inputs || cone.regs.len() != prior.cone.regs.len() {
        return None;
    }
    // Commit order must survive the graft: the register name *sequence*
    // has to match, not just the set.
    for (a, b) in cone.regs.iter().zip(&prior.cone.regs) {
        if a.0 != b.0 {
            return None;
        }
    }
    let mut changed: Vec<usize> = Vec::new();
    for (i, (_, h)) in cone.regs.iter().enumerate() {
        if *h != prior.cone.regs[i].1 {
            changed.push(i);
        }
    }
    let outputs_changed = cone.outputs != prior.cone.outputs;
    if changed.is_empty() && !outputs_changed {
        // byte-level edits (reordered nodes, renamed wires feeding
        // nothing) that leave every cone hash intact: reuse wholesale
        return Some(DeltaOut {
            ir: prior.ir.clone(),
            oim: prior.oim.clone(),
            gdg: prior.gdg.clone(),
            cone,
            regs: prior.regs.clone(),
            reused_groups: prior.gdg.groups.len(),
            rebuilt_groups: 0,
            changed_regs: Vec::new(),
        });
    }

    // ---- sub-graph: only the changed cones, cut at sources ----
    let mut include = vec![false; g.nodes.len()];
    let mut stack: Vec<NodeId> = changed.iter().map(|&i| g.regs[i].next).collect();
    if outputs_changed {
        stack.extend(g.outputs.iter().map(|&(_, o)| o));
    }
    while let Some(id) = stack.pop() {
        let node = &g.nodes[id as usize];
        match node.kind {
            // ports and registers are boundary sources — not traversed
            NodeKind::Input(_) | NodeKind::Reg(_) => {}
            NodeKind::Const(_) | NodeKind::Prim(_) => {
                if !include[id as usize] {
                    include[id as usize] = true;
                    stack.extend(node.args.iter().copied());
                }
            }
        }
    }
    let mut sub = Graph::new(&g.name);
    let mut node_map = vec![u32::MAX; g.nodes.len()];
    for p in &g.inputs {
        node_map[p.node as usize] = sub.input(&p.name, p.width);
    }
    for r in &g.regs {
        node_map[r.node as usize] = sub.reg(&r.name, r.width, r.init);
    }
    // included nodes in ascending (= topological) id order
    for (id, node) in g.nodes.iter().enumerate() {
        if !include[id] {
            continue;
        }
        let nid = match &node.kind {
            NodeKind::Const(v) => sub.konst(*v, node.width),
            NodeKind::Prim(op) => {
                let args: Vec<NodeId> = node.args.iter().map(|&a| node_map[a as usize]).collect();
                sub.prim_w(*op, &args, node.width)
            }
            _ => unreachable!("include set holds only consts and prims"),
        };
        if let Some(name) = &node.name {
            sub.name_node(nid, name);
        }
        node_map[id] = nid;
    }
    for &ri in &changed {
        let r = &g.regs[ri];
        sub.connect_reg(node_map[r.node as usize], node_map[r.next as usize]);
    }
    if outputs_changed {
        for (name, o) in &g.outputs {
            sub.output(name, node_map[*o as usize]);
        }
    }

    // ---- same pipeline as a cold compile, on the small graph ----
    let opt = if fuse { passes::optimize(&sub).0 } else { passes::optimize_no_fusion(&sub) };
    let sub_ir = lower(&opt);

    // ---- slot map: boundary sources to prior slots, new ops fresh ----
    if opt.inputs.len() != prior.ir.input_slots.len() {
        return None;
    }
    let prior_slot_of: HashMap<&str, u32> =
        prior.regs.iter().map(|r| (r.name.as_str(), r.slot)).collect();
    let old_slots = prior.ir.num_slots;
    let mut next_fresh = old_slots as u32;
    let mut slot_of = vec![u32::MAX; opt.nodes.len()];
    for (id, node) in opt.nodes.iter().enumerate() {
        slot_of[id] = match node.kind {
            NodeKind::Input(pi) => prior.ir.input_slots[pi as usize],
            NodeKind::Reg(ri) => match prior_slot_of.get(opt.regs[ri as usize].name.as_str()) {
                Some(&s) => s,
                // the prior compile dead-coded this register away — its
                // slot is gone, so the graft cannot anchor to it
                None => return None,
            },
            NodeKind::Const(_) | NodeKind::Prim(_) => {
                let s = next_fresh;
                next_fresh += 1;
                s
            }
        };
    }

    // ---- graft the optimized sub-IR into the prior IR ----
    let mut ir = prior.ir.clone();
    ir.num_slots = next_fresh as usize;
    for node in &opt.nodes {
        if matches!(node.kind, NodeKind::Const(_) | NodeKind::Prim(_)) {
            ir.slot_names.push(node.name.clone());
            ir.slot_widths.push(node.width);
        }
    }
    for (id, node) in opt.nodes.iter().enumerate() {
        if let NodeKind::Const(v) = node.kind {
            ir.init.push((slot_of[id], v));
        }
    }
    let depth = ir.layers.len().max(sub_ir.layers.len());
    ir.layers.resize(depth, Vec::new());
    let mut touched = vec![false; depth];
    for (li, layer) in sub_ir.layers.iter().enumerate() {
        if layer.is_empty() {
            continue;
        }
        touched[li] = true;
        for rec in layer {
            let mut r2 = *rec;
            r2.out = slot_of[rec.out as usize];
            r2.a = slot_of[rec.a as usize];
            if r2.arity >= 2 {
                r2.b = slot_of[rec.b as usize];
            }
            if r2.kop() == KOp::MuxChain {
                let ar = rec.arity as usize;
                let ext = &sub_ir.ext_args[rec.ext as usize..rec.ext as usize + ar - 2];
                r2.ext = ir.ext_args.len() as u32;
                for &e in ext {
                    ir.ext_args.push(slot_of[e as usize]);
                }
            } else if r2.arity >= 3 {
                r2.c = slot_of[rec.c as usize];
            }
            // fresh out slots are all >= the prior slot count and
            // monotone in sub node id, so appending keeps each layer
            // strictly ascending by `out`
            ir.layers[li].push(r2);
        }
    }

    // repoint the changed registers' commits (and refresh their widths
    // and init values — both are part of the cone hash)
    let commit_of_slot: HashMap<u32, usize> =
        ir.commits.iter().enumerate().map(|(i, c)| (c.0, i)).collect();
    let opt_reg_of: HashMap<&str, usize> =
        opt.regs.iter().enumerate().map(|(i, r)| (r.name.as_str(), i)).collect();
    let mut changed_regs = Vec::with_capacity(changed.len());
    for &ri in &changed {
        let name = g.regs[ri].name.as_str();
        let Some(&oi) = opt_reg_of.get(name) else { return None };
        let r = &opt.regs[oi];
        let Some(&slot) = prior_slot_of.get(name) else { return None };
        let Some(&ci) = commit_of_slot.get(&slot) else { return None };
        ir.commits[ci] = (slot, slot_of[r.next as usize], mask(r.width));
        ir.slot_widths[slot as usize] = r.width;
        if let Some(e) = ir.init.iter_mut().find(|e| e.0 == slot) {
            e.1 = r.init;
        } else {
            ir.init.push((slot, r.init));
        }
        changed_regs.push(name.to_string());
    }
    if outputs_changed {
        ir.output_slots =
            sub_ir.output_slots.iter().map(|(n, s)| (n.clone(), slot_of[*s as usize])).collect();
    }

    // ---- GC: drop ops orphaned by the graft (old changed cones) ----
    let mut writer: HashMap<u32, (usize, usize)> = HashMap::new();
    for (li, layer) in ir.layers.iter().enumerate() {
        for (oi, rec) in layer.iter().enumerate() {
            writer.insert(rec.out, (li, oi));
        }
    }
    let mut live: Vec<Vec<bool>> = ir.layers.iter().map(|l| vec![false; l.len()]).collect();
    let mut roots: Vec<u32> = ir.commits.iter().map(|c| c.1).collect();
    roots.extend(ir.output_slots.iter().map(|(_, s)| *s));
    while let Some(slot) = roots.pop() {
        if let Some(&(li, oi)) = writer.get(&slot) {
            if !live[li][oi] {
                live[li][oi] = true;
                roots.extend(operand_slots(&ir.layers[li][oi], &ir.ext_args));
            }
        }
    }
    for (li, layer) in ir.layers.iter_mut().enumerate() {
        let before = layer.len();
        let mut oi = 0usize;
        layer.retain(|_| {
            let keep = live[li][oi];
            oi += 1;
            keep
        });
        if layer.len() != before {
            touched[li] = true;
        }
    }

    // ---- splice the OIM and GDG around the untouched layers ----
    let oim = Oim::splice(&prior.oim, &ir, &touched);
    let (gdg, reused, rebuilt) = GroupDepGraph::splice(&prior.gdg, &ir, &oim, &touched);

    let mut regs = prior.regs.clone();
    for &ri in &changed {
        if let Some(rr) = regs.iter_mut().find(|rr| rr.name == g.regs[ri].name) {
            rr.width = g.regs[ri].width;
        }
    }
    Some(DeltaOut {
        ir,
        oim,
        gdg,
        cone,
        regs,
        reused_groups: reused,
        rebuilt_groups: rebuilt,
        changed_regs,
    })
}
