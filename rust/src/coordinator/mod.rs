//! L3 coordinator: compiles designs, sweeps kernel configurations, selects
//! the best kernel per design/machine (autotuning), runs partitioned
//! multi-threaded simulation (RepCut-style, Cascade 2), and drives the
//! paper's experiments.

pub mod cli;
pub mod compile;
pub mod incremental;
pub mod sweep;
pub mod autotune;
pub mod pool;
pub mod parallel;
pub mod report;
