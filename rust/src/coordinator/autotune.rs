//! Best-kernel selection (paper §7.5: "for each design and machine, we
//! report the simulation time of the best-performing RTeAAL Sim kernel").
//!
//! Two strategies:
//! * [`best_measured`] — short trial runs of every configuration on this
//!   host (what the paper does per machine);
//! * [`best_modeled`] — pick by the perf model's projected
//!   cycles-per-sim-cycle on a *modeled* machine (used for the four-host
//!   projections).

use super::compile::Compiled;
use crate::designs::Design;
use crate::kernels::{KernelConfig, ALL_KERNELS};
use crate::perf::machine::Machine;
use crate::perf::trace::SimStyle;

/// Trial-run every kernel; return (config, cycles/sec).
pub fn best_measured(design: &Design, compiled: &Compiled, trial_cycles: u64) -> (KernelConfig, f64) {
    let mut best = (KernelConfig::PSU, 0.0f64);
    for cfg in ALL_KERNELS {
        let p = super::sweep::measure_kernel(design, compiled, cfg, trial_cycles);
        if p.hz > best.1 {
            best = (cfg, p.hz);
        }
    }
    best
}

/// Model every kernel on `machine`; return (config, modeled core cycles
/// per simulated cycle — lower is better).
pub fn best_modeled(compiled: &Compiled, machine: &Machine) -> (KernelConfig, f64) {
    let mut best = (KernelConfig::PSU, f64::INFINITY);
    for cfg in ALL_KERNELS {
        let (_, td) = super::sweep::modeled(compiled, SimStyle::Kernel(cfg), machine, 2);
        if td.cycles_per_sim_cycle < best.1 {
            best = (cfg, td.cycles_per_sim_cycle);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::compile::{compile_design, CompileOpts};
    use crate::designs::catalog;
    use crate::perf::machine;

    #[test]
    fn small_design_prefers_unrolled_on_big_cache_machine() {
        // paper §7.5: SHA3-small designs favour TI; big designs favour
        // rolled kernels. Model must reproduce the small-design side.
        let d = catalog("counter").unwrap();
        let c = compile_design(&d, CompileOpts::default());
        let (cfg, _) = best_modeled(&c, &machine::intel_core());
        assert!(
            matches!(cfg, KernelConfig::TI | KernelConfig::SU | KernelConfig::IU),
            "expected unrolled kernel for tiny design, got {}",
            cfg.name()
        );
    }

    #[test]
    fn big_design_prefers_rolled_on_xeon() {
        let d = catalog("rocket_like_4c").unwrap();
        let c = compile_design(&d, CompileOpts::default());
        let (cfg, _) = best_modeled(&c, &machine::intel_xeon());
        assert!(
            matches!(cfg, KernelConfig::NU | KernelConfig::PSU | KernelConfig::IU),
            "expected rolled kernel for big design on Xeon, got {}",
            cfg.name()
        );
    }
}
