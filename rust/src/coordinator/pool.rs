//! Persistent partition worker pool for
//! [`super::parallel::BatchParallelSim`].
//!
//! The original cycle loop spawned a fresh `thread::scope` every cycle,
//! so small designs paid thread creation (µs) against per-cycle work
//! (ns–µs). This pool spawns its workers **once at construction** and
//! parks them on a reusable [`Barrier`] between cycles; a cycle is two
//! barrier crossings (start → step → done), with the coordinator thread
//! stepping partition 0 itself in between.
//!
//! ## Sharing protocol (why the `unsafe` is sound)
//!
//! Kernels and the staged input buffer live in [`UnsafeCell`]s shared
//! through one `Arc`. Access is *phase-exclusive*, with the two barriers
//! providing the happens-before edges:
//!
//! * **Between cycles** (workers blocked on the *start* barrier): only
//!   the coordinator touches shared state — it stages inputs and active
//!   flags, runs the RUM exchange against every kernel's slot file, and
//!   serves reads/pokes. Workers cannot observe any of it: their next
//!   access is ordered after the coordinator's `start.wait()`.
//! * **During a step** (between the barriers): worker `i` mutates only
//!   `kernels[i]`; every thread may read the staged inputs (shared
//!   reads); the coordinator mutates only `kernels[0]`. No cell is
//!   aliased mutably.
//!
//! [`WorkerPool::step`] takes `&mut self`, so no reference handed out by
//! [`WorkerPool::kernel`]/[`WorkerPool::kernel_mut`] (which borrow
//! `self`) can be live while a step is in flight.
//!
//! The kernels may be sparse (group-masked) executors carrying their own
//! activity trackers; that changes nothing here — tracker state lives
//! inside the kernel box, the coordinator's between-cycles RUM pokes
//! (`kernel_mut(..).poke_lane(..)`, which on sparse kernels also feed
//! the tracker via targeted invalidation) and read-only stats queries
//! (`kernel(..).activity_stats()`) fall under the same phase-exclusive
//! protocol as every other kernel access.
//!
//! A panic inside a kernel step is caught on the worker, flagged, and
//! re-raised on the coordinator after the *done* barrier — the barrier
//! protocol itself never wedges. Dropping the pool releases the workers
//! through a shutdown flag raised before the *start* barrier.

use std::cell::UnsafeCell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Barrier};
use std::thread::JoinHandle;

use crate::kernels::BatchKernel;

/// Interior-mutability cell shared under the pool's barrier protocol
/// (module docs). `Sync` is sound because the protocol makes every
/// access phase-exclusive.
struct PoolCell<T>(UnsafeCell<T>);

unsafe impl<T: Send> Sync for PoolCell<T> {}

struct Shared {
    /// Threads ever spawned by *this* pool — stays at `parts - 1` for
    /// the pool's whole lifetime (stepping never spawns).
    spawned_ever: AtomicUsize,
    kernels: Vec<PoolCell<Box<dyn BatchKernel>>>,
    /// Inputs staged for the cycle in flight (lane-major, as for
    /// [`BatchKernel::step`]).
    inputs: PoolCell<Vec<u64>>,
    /// Per-partition "step this cycle" flags (sparse skipping).
    active: Vec<AtomicBool>,
    /// Per-worker panic flags, re-raised on the coordinator.
    panicked: Vec<AtomicBool>,
    shutdown: AtomicBool,
    start: Barrier,
    done: Barrier,
    /// Phase counter for debug-build protocol assertions: even = staging
    /// (coordinator owns the cells, workers parked on `start`), odd =
    /// stepping (each thread owns only its own kernel). Incremented by
    /// the coordinator alone — to odd before `start.wait()`, back to even
    /// after `done.wait()` — so each barrier crossing publishes the new
    /// phase, and a worker observing the wrong parity has caught a
    /// violation of the sharing protocol documented above.
    phase: AtomicUsize,
}

/// A pool of `P - 1` persistent worker threads driving partitions
/// `1..P`; the coordinator thread drives partition 0. `P = 1` spawns no
/// threads at all and steps inline.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

fn worker_loop(shared: Arc<Shared>, idx: usize, gate: std::sync::mpsc::Receiver<bool>) {
    // Startup gate: do not enter the barrier protocol until the
    // constructor confirms every worker spawned. If a later spawn fails,
    // the constructor sends `false` (or drops the sender) and this worker
    // exits instead of parking forever on a barrier that can never fill.
    if gate.recv() != Ok(true) {
        return;
    }
    loop {
        shared.start.wait();
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        // Past the start barrier of a live cycle: the coordinator must
        // have published the stepping (odd) phase before releasing us.
        debug_assert_eq!(
            shared.phase.load(Ordering::Relaxed) % 2,
            1,
            "worker {idx} entered a step while the pool was in the staging phase"
        );
        if shared.active[idx].load(Ordering::Relaxed) {
            let stepped = catch_unwind(AssertUnwindSafe(|| {
                // SAFETY: between the barriers this worker is the only
                // thread touching kernels[idx], and the staged inputs are
                // only read (module docs).
                let kernel = unsafe { &mut *shared.kernels[idx].0.get() };
                let inputs = unsafe { &*shared.inputs.0.get() };
                kernel.step(inputs);
            }));
            if stepped.is_err() {
                shared.panicked[idx].store(true, Ordering::Release);
            }
        }
        shared.done.wait();
    }
}

impl WorkerPool {
    /// Take ownership of one kernel per partition and spawn the worker
    /// threads (once — stepping never spawns again).
    pub fn new(kernels: Vec<Box<dyn BatchKernel>>) -> Self {
        assert!(!kernels.is_empty());
        let parts = kernels.len();
        let shared = Arc::new(Shared {
            spawned_ever: AtomicUsize::new(0),
            kernels: kernels.into_iter().map(|k| PoolCell(UnsafeCell::new(k))).collect(),
            inputs: PoolCell(UnsafeCell::new(Vec::new())),
            active: (0..parts).map(|_| AtomicBool::new(false)).collect(),
            panicked: (0..parts).map(|_| AtomicBool::new(false)).collect(),
            shutdown: AtomicBool::new(false),
            start: Barrier::new(parts),
            done: Barrier::new(parts),
            phase: AtomicUsize::new(0),
        });
        let mut handles = Vec::with_capacity(parts.saturating_sub(1));
        let mut gates = Vec::with_capacity(parts.saturating_sub(1));
        for idx in 1..parts {
            let sh = Arc::clone(&shared);
            let (tx, rx) = std::sync::mpsc::channel::<bool>();
            let spawned = std::thread::Builder::new()
                .name(format!("rteaal-part{idx}"))
                .spawn(move || worker_loop(sh, idx, rx));
            match spawned {
                Ok(h) => {
                    shared.spawned_ever.fetch_add(1, Ordering::Relaxed);
                    handles.push(h);
                    gates.push(tx);
                }
                Err(e) => {
                    // Release the workers spawned so far through their
                    // startup gates (they have not entered the barrier
                    // protocol yet), then fail construction cleanly.
                    for gate in &gates {
                        let _ = gate.send(false);
                    }
                    for h in handles.drain(..) {
                        let _ = h.join();
                    }
                    panic!("spawn partition worker: {e}");
                }
            }
        }
        // all workers exist: let them enter the barrier protocol
        for gate in &gates {
            let _ = gate.send(true);
        }
        WorkerPool { shared, handles }
    }

    pub fn parts(&self) -> usize {
        self.shared.kernels.len()
    }

    /// Worker threads owned by this pool (`parts - 1`; constant for the
    /// pool's lifetime).
    pub fn worker_threads(&self) -> usize {
        self.handles.len()
    }

    /// Threads ever spawned by this pool — equal to
    /// [`Self::worker_threads`] forever, however many cycles are stepped
    /// (the no-per-cycle-spawn guarantee, asserted in tests).
    pub fn threads_spawned_ever(&self) -> usize {
        self.shared.spawned_ever.load(Ordering::Relaxed)
    }

    /// One cycle: step every partition whose `active` flag is set, in
    /// parallel, and return once all have finished. `inputs` is
    /// lane-major, as for [`BatchKernel::step`].
    pub fn step(&mut self, inputs: &[u64], active: &[bool]) {
        debug_assert_eq!(active.len(), self.parts());
        let shared = &self.shared;
        if self.handles.is_empty() {
            if active[0] {
                // SAFETY: no workers exist; this thread has exclusive
                // access through `&mut self`.
                unsafe { &mut *shared.kernels[0].0.get() }.step(inputs);
            }
            return;
        }
        // Stage: workers are parked on the start barrier, so the
        // coordinator has exclusive access (module docs).
        {
            // SAFETY: see above.
            let staged = unsafe { &mut *shared.inputs.0.get() };
            staged.clear();
            staged.extend_from_slice(inputs);
        }
        for (flag, &a) in shared.active.iter().zip(active) {
            flag.store(a, Ordering::Relaxed);
        }
        // Enter the stepping phase *before* the start barrier: the
        // barrier's happens-before edge publishes the odd count to every
        // worker it releases.
        let prev = shared.phase.fetch_add(1, Ordering::Relaxed);
        debug_assert_eq!(prev % 2, 0, "step() entered while a step was already in flight");
        shared.start.wait();
        let own = catch_unwind(AssertUnwindSafe(|| {
            if active[0] {
                // SAFETY: between the barriers the coordinator only
                // touches kernels[0] (module docs).
                unsafe { &mut *shared.kernels[0].0.get() }.step(inputs);
            }
        }));
        shared.done.wait();
        // Back to the staging phase. Workers do not assert here — they
        // may reach their next start.wait() before this increment — but
        // the coordinator itself must observe the parity it created.
        let prev = shared.phase.fetch_add(1, Ordering::Relaxed);
        debug_assert_eq!(prev % 2, 1, "phase counter desynchronized across the done barrier");
        for p in &shared.panicked {
            if p.load(Ordering::Acquire) {
                panic!("partition worker panicked during step");
            }
        }
        if let Err(e) = own {
            resume_unwind(e);
        }
    }

    /// Read access to partition `p`'s kernel (between cycles).
    pub fn kernel(&self, p: usize) -> &dyn BatchKernel {
        // SAFETY: workers are parked between cycles; `step` takes
        // `&mut self`, so this borrow cannot span a step (module docs).
        unsafe { &**self.shared.kernels[p].0.get() }
    }

    /// Mutable access to partition `p`'s kernel (between cycles — RUM
    /// pokes, lane initialization).
    pub fn kernel_mut(&mut self, p: usize) -> &mut dyn BatchKernel {
        // SAFETY: as for `kernel`, plus `&mut self` guarantees this is
        // the only outstanding pool borrow.
        unsafe { &mut **self.shared.kernels[p].0.get() }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        if self.handles.is_empty() {
            return;
        }
        self.shared.shutdown.store(true, Ordering::Release);
        // Release the workers parked on the start barrier; they observe
        // the flag and exit before touching any cell.
        self.shared.start.wait();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}
