//! Kernel-configuration sweeps: wall-clock simulation throughput per
//! kernel on this host, plus modeled per-machine projections — the
//! engine behind the Fig 16/17/18/20 benches.

use std::time::Duration;

use super::compile::Compiled;
use crate::designs::Design;
use crate::kernels::{BatchKernel as _, KernelConfig};
use crate::partition::PartitionerKind;
use crate::perf::machine::Machine;
use crate::perf::topdown::{self, TopDown};
use crate::perf::trace::{self, SimStyle};
use crate::sim::Simulator;

/// One sweep measurement.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub label: String,
    /// measured on this host
    pub wall: Duration,
    pub cycles: u64,
    pub hz: f64,
    /// modeled program/data footprint
    pub program_bytes: usize,
    pub data_bytes: usize,
    /// fraction of (op, lane) work skipped by activity masking
    /// (sparse batched runs only)
    pub skip_rate: Option<f64>,
    /// distinct registers crossing partitions each cycle (partitioned
    /// runs only)
    pub cut_regs: Option<usize>,
    /// fraction of (op, lane) work the *composed* activity levels skipped
    /// in a sparse partitioned run — partition-skipped cycles count as
    /// skipped op-lanes (sparse partitioned runs of group-capable
    /// kernels only)
    pub group_skip_rate: Option<f64>,
}

/// Run `cycles` of `design` under one kernel config; measured wall-clock.
pub fn measure_kernel(design: &Design, compiled: &Compiled, cfg: KernelConfig, cycles: u64) -> SweepPoint {
    let (kernel, _, _) = compiled.build_kernel(cfg);
    let program_bytes = kernel.program_bytes();
    let data_bytes = kernel.data_bytes();
    let mut sim = Simulator::new(kernel, design.make_stimulus());
    // warm-up then measure
    sim.run(cycles.min(64));
    let stats = sim.run(cycles);
    SweepPoint {
        label: cfg.name().to_string(),
        wall: stats.wall,
        cycles,
        hz: stats.hz,
        program_bytes,
        data_bytes,
        skip_rate: None,
        cut_regs: None,
        group_skip_rate: None,
    }
}

/// Run `cycles` of `design` under a lane-batched kernel with `lanes`
/// stimulus lanes. `hz` reports **aggregate lane-cycles per second**
/// (`cycles * lanes / wall`) — the throughput axis the batch dimension
/// scales; per-lane latency is `hz / lanes`.
pub fn measure_kernel_lanes(
    design: &Design,
    compiled: &Compiled,
    cfg: KernelConfig,
    lanes: usize,
    cycles: u64,
) -> SweepPoint {
    let mut kernel = crate::kernels::build_batch(cfg, &compiled.ir, &compiled.oim, lanes);
    let program_bytes = crate::perf::binsize::kernel_code_bytes(cfg, &compiled.oim);
    let data_bytes = crate::perf::binsize::kernel_data_bytes(cfg, &compiled.oim);
    let mut stim = design.make_lane_stimulus(lanes);
    // warm-up then measure
    for c in 0..cycles.min(64) {
        kernel.step(&stim(c));
    }
    let t0 = std::time::Instant::now();
    for c in 0..cycles {
        kernel.step(&stim(c));
    }
    let wall = t0.elapsed();
    SweepPoint {
        label: format!("{}/B{}", cfg.name(), lanes),
        wall,
        cycles,
        hz: (cycles as f64 * lanes as f64) / wall.as_secs_f64().max(1e-12),
        program_bytes,
        data_bytes,
        skip_rate: None,
        cut_regs: None,
        group_skip_rate: None,
    }
}

/// [`measure_kernel_lanes`] against the **pre-tile baseline** executor
/// ([`crate::kernels::build_batch_baseline`]): the retained
/// lane-at-a-time loops the auto-vectorizer sees, bit-identical to the
/// tiled path. The tiled-vs-autovec comparison points of
/// `BENCH_fig22.json` pair one of these (label `.../scalar`) with a
/// [`measure_kernel_lanes`] point at the same `(cfg, lanes)`.
pub fn measure_kernel_lanes_baseline(
    design: &Design,
    compiled: &Compiled,
    cfg: KernelConfig,
    lanes: usize,
    cycles: u64,
) -> SweepPoint {
    let mut kernel =
        crate::kernels::build_batch_baseline(cfg, &compiled.ir, &compiled.oim, lanes);
    let program_bytes = crate::perf::binsize::kernel_code_bytes(cfg, &compiled.oim);
    let data_bytes = crate::perf::binsize::kernel_data_bytes(cfg, &compiled.oim);
    let mut stim = design.make_lane_stimulus(lanes);
    // warm-up then measure
    for c in 0..cycles.min(64) {
        kernel.step(&stim(c));
    }
    let t0 = std::time::Instant::now();
    for c in 0..cycles {
        kernel.step(&stim(c));
    }
    let wall = t0.elapsed();
    SweepPoint {
        label: format!("{}/B{}/scalar", cfg.name(), lanes),
        wall,
        cycles,
        hz: (cycles as f64 * lanes as f64) / wall.as_secs_f64().max(1e-12),
        program_bytes,
        data_bytes,
        skip_rate: None,
        cut_regs: None,
        group_skip_rate: None,
    }
}

/// [`measure_kernel_lanes`] but under toggle-rate-controlled stimulus
/// (`Design::make_lane_stimulus_toggle`) — the dense comparison point for
/// the sparse measurements, paying the identical stimulus-generation cost.
pub fn measure_kernel_lanes_toggle(
    design: &Design,
    compiled: &Compiled,
    cfg: KernelConfig,
    lanes: usize,
    cycles: u64,
    toggle_rate: f64,
) -> SweepPoint {
    let mut kernel = crate::kernels::build_batch(cfg, &compiled.ir, &compiled.oim, lanes);
    design.apply_lane_init(&compiled.graph, kernel.as_mut());
    let mut stim = design.make_lane_stimulus_toggle(lanes, toggle_rate);
    for c in 0..cycles.min(64) {
        kernel.step(&stim(c));
    }
    let t0 = std::time::Instant::now();
    for c in 0..cycles {
        kernel.step(&stim(c));
    }
    let wall = t0.elapsed();
    SweepPoint {
        label: format!("{}/B{}@{:.0}%", cfg.name(), lanes, toggle_rate * 100.0),
        wall,
        cycles,
        hz: (cycles as f64 * lanes as f64) / wall.as_secs_f64().max(1e-12),
        program_bytes: crate::perf::binsize::kernel_code_bytes(cfg, &compiled.oim),
        data_bytes: crate::perf::binsize::kernel_data_bytes(cfg, &compiled.oim),
        skip_rate: None,
        cut_regs: None,
        group_skip_rate: None,
    }
}

/// Run `cycles` of `design` under a **sparse** (activity-masked) batched
/// kernel with `lanes ≤ 64` stimulus lanes at the given toggle rate.
/// `hz` is aggregate lane-cycles/sec as in [`measure_kernel_lanes`];
/// `skip_rate` reports the fraction of (op, lane) work units the activity
/// masks skipped during the measured window (warm-up excluded).
pub fn measure_kernel_lanes_sparse(
    design: &Design,
    compiled: &Compiled,
    cfg: KernelConfig,
    lanes: usize,
    cycles: u64,
    toggle_rate: f64,
) -> SweepPoint {
    let mut kernel = crate::kernels::build_sparse(cfg, &compiled.ir, &compiled.oim, lanes);
    design.apply_lane_init(&compiled.graph, kernel.as_mut());
    let mut stim = design.make_lane_stimulus_toggle(lanes, toggle_rate);
    // warm-up (absorbs the cold full-evaluation cycle), then measure
    for c in 0..cycles.min(64) {
        kernel.step(&stim(c));
    }
    let warm = kernel.activity_stats().expect("sparse kernels report activity");
    let t0 = std::time::Instant::now();
    for c in 0..cycles {
        kernel.step(&stim(c));
    }
    let wall = t0.elapsed();
    let stats = kernel.activity_stats().expect("sparse kernels report activity").since(&warm);
    SweepPoint {
        label: format!("{}/B{}/sparse@{:.0}%", cfg.name(), lanes, toggle_rate * 100.0),
        wall,
        cycles,
        hz: (cycles as f64 * lanes as f64) / wall.as_secs_f64().max(1e-12),
        program_bytes: crate::perf::binsize::kernel_code_bytes(cfg, &compiled.oim),
        data_bytes: crate::perf::binsize::kernel_data_bytes(cfg, &compiled.oim),
        skip_rate: Some(stats.skip_rate()),
        cut_regs: None,
        group_skip_rate: None,
    }
}

/// Run `cycles` of `design` under the partitioned lane-batched simulator
/// ([`super::parallel::BatchParallelSim`]): `parts` thread-level
/// partitions under the given register-ownership strategy, each stepping
/// `lanes` stimulus lanes per cycle. `hz` is aggregate lane-cycles/sec
/// as in [`measure_kernel_lanes`] — the P × B composition scales it
/// along both axes at once; `cut_regs` reports the RUM cut the
/// partitioner achieved.
pub fn measure_kernel_parts_lanes(
    design: &Design,
    compiled: &Compiled,
    cfg: KernelConfig,
    parts: usize,
    lanes: usize,
    cycles: u64,
    partitioner: PartitionerKind,
) -> SweepPoint {
    let mut sim = super::parallel::BatchParallelSim::with_partitioner(
        &compiled.ir,
        cfg,
        parts,
        lanes,
        false,
        partitioner,
    );
    for (slot, lane, value) in design.resolved_lane_init(&compiled.graph, lanes) {
        sim.poke_lane(slot, lane, value);
    }
    let mut stim = design.make_lane_stimulus(lanes);
    // warm-up then measure
    for c in 0..cycles.min(64) {
        sim.step(&stim(c));
    }
    let t0 = std::time::Instant::now();
    for c in 0..cycles {
        sim.step(&stim(c));
    }
    let wall = t0.elapsed();
    SweepPoint {
        label: format!("{}/P{}xB{}/{}", cfg.name(), parts, lanes, partitioner.name()),
        wall,
        cycles,
        hz: (cycles as f64 * lanes as f64) / wall.as_secs_f64().max(1e-12),
        program_bytes: crate::perf::binsize::kernel_code_bytes(cfg, &compiled.oim),
        data_bytes: crate::perf::binsize::kernel_data_bytes(cfg, &compiled.oim),
        skip_rate: None,
        cut_regs: Some(sim.cut_regs()),
        group_skip_rate: None,
    }
}

/// [`measure_kernel_parts_lanes`] against the pre-tile baseline
/// per-partition kernels
/// ([`super::parallel::BatchParallelSim::with_partitioner_baseline`]) —
/// the P × B comparison points (label `.../scalar`) of `BENCH_fig24.json`.
#[allow(clippy::too_many_arguments)]
pub fn measure_kernel_parts_lanes_baseline(
    design: &Design,
    compiled: &Compiled,
    cfg: KernelConfig,
    parts: usize,
    lanes: usize,
    cycles: u64,
    partitioner: PartitionerKind,
) -> SweepPoint {
    let mut sim = super::parallel::BatchParallelSim::with_partitioner_baseline(
        &compiled.ir,
        cfg,
        parts,
        lanes,
        partitioner,
    );
    for (slot, lane, value) in design.resolved_lane_init(&compiled.graph, lanes) {
        sim.poke_lane(slot, lane, value);
    }
    let mut stim = design.make_lane_stimulus(lanes);
    // warm-up then measure
    for c in 0..cycles.min(64) {
        sim.step(&stim(c));
    }
    let t0 = std::time::Instant::now();
    for c in 0..cycles {
        sim.step(&stim(c));
    }
    let wall = t0.elapsed();
    SweepPoint {
        label: format!("{}/P{}xB{}/{}/scalar", cfg.name(), parts, lanes, partitioner.name()),
        wall,
        cycles,
        hz: (cycles as f64 * lanes as f64) / wall.as_secs_f64().max(1e-12),
        program_bytes: crate::perf::binsize::kernel_code_bytes(cfg, &compiled.oim),
        data_bytes: crate::perf::binsize::kernel_data_bytes(cfg, &compiled.oim),
        skip_rate: None,
        cut_regs: Some(sim.cut_regs()),
        group_skip_rate: None,
    }
}

/// [`measure_kernel_parts_lanes`] with per-partition activity masking
/// over the RUM cut (`lanes ≤ 64`), under toggle-rate-controlled
/// stimulus. `skip_rate` reports the fraction of (partition, cycle) work
/// units skipped during the measured window (warm-up excluded);
/// `group_skip_rate` additionally reports — for kernels with sparse
/// (group-masked) executors — the composed fraction of (op, lane) work
/// units skipped by partition- and group-level masking together.
pub fn measure_kernel_parts_lanes_sparse(
    design: &Design,
    compiled: &Compiled,
    cfg: KernelConfig,
    parts: usize,
    lanes: usize,
    cycles: u64,
    toggle_rate: f64,
    partitioner: PartitionerKind,
) -> SweepPoint {
    let mut sim = super::parallel::BatchParallelSim::with_partitioner(
        &compiled.ir,
        cfg,
        parts,
        lanes,
        true,
        partitioner,
    );
    for (slot, lane, value) in design.resolved_lane_init(&compiled.graph, lanes) {
        sim.poke_lane(slot, lane, value);
    }
    let mut stim = design.make_lane_stimulus_toggle(lanes, toggle_rate);
    // warm-up (absorbs the cold full-evaluation cycle), then measure
    for c in 0..cycles.min(64) {
        sim.step(&stim(c));
    }
    let warm = sim.activity_stats().expect("sparse partitioned runs report activity");
    let warm_group = sim.group_stats();
    let t0 = std::time::Instant::now();
    for c in 0..cycles {
        sim.step(&stim(c));
    }
    let wall = t0.elapsed();
    let stats =
        sim.activity_stats().expect("sparse partitioned runs report activity").since(&warm);
    let group_skip_rate =
        sim.group_stats().zip(warm_group).map(|(now, base)| now.since(&base).skip_rate());
    SweepPoint {
        label: format!(
            "{}/P{}xB{}/{}/sparse@{:.0}%",
            cfg.name(),
            parts,
            lanes,
            partitioner.name(),
            toggle_rate * 100.0
        ),
        wall,
        cycles,
        hz: (cycles as f64 * lanes as f64) / wall.as_secs_f64().max(1e-12),
        program_bytes: crate::perf::binsize::kernel_code_bytes(cfg, &compiled.oim),
        data_bytes: crate::perf::binsize::kernel_data_bytes(cfg, &compiled.oim),
        skip_rate: Some(stats.skip_rate()),
        cut_regs: Some(sim.cut_regs()),
        group_skip_rate,
    }
}

/// Run a baseline (verilator-like / essent-like / event-driven).
pub fn measure_baseline(design: &Design, compiled: &Compiled, which: &str, cycles: u64) -> SweepPoint {
    let kernel: Box<dyn crate::kernels::SimKernel> = match which {
        "verilator" => Box::new(crate::baselines::verilator_like::VerilatorLike::new(&compiled.ir, false)),
        "verilator-O0" => Box::new(crate::baselines::verilator_like::VerilatorLike::new(&compiled.ir, true)),
        "essent" => Box::new(crate::baselines::essent_like::EssentLike::new(&compiled.ir, false)),
        "essent-O0" => Box::new(crate::baselines::essent_like::EssentLike::new(&compiled.ir, true)),
        "event" => Box::new(crate::baselines::event_driven::EventDriven::new(&compiled.ir)),
        "psu-O0" => Box::new(crate::kernels::unopt::UnoptKernel::new(&compiled.ir, &compiled.oim)),
        other => panic!("unknown baseline '{other}'"),
    };
    let program_bytes = kernel.program_bytes();
    let data_bytes = kernel.data_bytes();
    let mut sim = Simulator::new(kernel, design.make_stimulus());
    sim.run(cycles.min(64));
    let stats = sim.run(cycles);
    SweepPoint {
        label: which.to_string(),
        wall: stats.wall,
        cycles,
        hz: stats.hz,
        program_bytes,
        data_bytes,
        skip_rate: None,
        cut_regs: None,
        group_skip_rate: None,
    }
}

/// Modeled (perf-model) view of a style on a machine.
pub fn modeled(compiled: &Compiled, style: SimStyle, machine: &Machine, sample_cycles: usize) -> (trace::Profile, TopDown) {
    let p = trace::profile(style, &compiled.oim, machine, sample_cycles);
    let td = topdown::analyze(&p, machine);
    (p, td)
}
