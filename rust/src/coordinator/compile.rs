//! The compile pipeline (paper Fig 14): design → dataflow graph →
//! optimizations → levelization → OIM → kernel, with wall-clock and peak
//! heap measurement for the compilation-cost experiments.

use std::time::{Duration, Instant};

use crate::designs::Design;
use crate::graph::passes;
use crate::graph::Graph;
use crate::kernels::{self, KernelConfig, SimKernel};
use crate::tensor::ir::{lower, LayerIr};
use crate::tensor::oim::Oim;
use crate::util::alloc;

/// Compiled design + cost accounting.
pub struct Compiled {
    pub name: String,
    pub graph: Graph,
    pub ir: LayerIr,
    pub oim: Oim,
    pub compile_time: Duration,
    pub peak_heap: usize,
}

/// Options for the pipeline.
#[derive(Clone, Copy, Debug)]
pub struct CompileOpts {
    /// Apply mux fusion (disable for waveform mode / XLA export).
    pub fuse: bool,
}

impl Default for CompileOpts {
    fn default() -> Self {
        CompileOpts { fuse: true }
    }
}

/// Run the front half of the pipeline (graph → OIM).
pub fn compile_design(design: &Design, opts: CompileOpts) -> Compiled {
    let t0 = Instant::now();
    let ((opt, ir, oim), peak_heap) = alloc::measure_peak(|| {
        let opt = if opts.fuse {
            passes::optimize(&design.graph).0
        } else {
            passes::optimize_no_fusion(&design.graph)
        };
        let ir = lower(&opt);
        let oim = Oim::from_ir(&ir);
        (opt, ir, oim)
    });
    Compiled {
        name: design.name.clone(),
        graph: opt,
        ir,
        oim,
        compile_time: t0.elapsed(),
        peak_heap,
    }
}

impl Compiled {
    /// Build one kernel configuration (the back half of the pipeline),
    /// measuring its own cost.
    pub fn build_kernel(&self, cfg: KernelConfig) -> (Box<dyn SimKernel>, Duration, usize) {
        let t0 = Instant::now();
        let (k, peak) = alloc::measure_peak(|| kernels::build_with_oim(cfg, &self.ir, &self.oim));
        (k, t0.elapsed(), peak)
    }

    /// Total modeled compile cost for a kernel config: the shared frontend
    /// plus the kernel build.
    pub fn kernel_compile_cost(&self, cfg: KernelConfig) -> (Duration, usize) {
        let (_, t, heap) = self.build_kernel(cfg);
        (self.compile_time + t, self.peak_heap.max(heap))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::designs::catalog;

    #[test]
    fn pipeline_produces_usable_kernel() {
        let d = catalog("counter").unwrap();
        let c = compile_design(&d, CompileOpts::default());
        assert!(c.ir.total_ops() > 0);
        let (mut k, _, _) = c.build_kernel(KernelConfig::PSU);
        k.step(&[1, 0]);
        k.step(&[1, 0]);
        assert_eq!(k.outputs()[0].1, 2);
    }
}
