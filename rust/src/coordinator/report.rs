//! Experiment drivers: one function per paper table/figure, each
//! producing a [`Table`] with the same rows/series the paper reports.
//! The `benches/` targets are thin wrappers around these (and `rteaal
//! report <id>` runs them from the CLI).
//!
//! Wall-clock columns are measured on this host; per-machine columns are
//! perf-model projections on the Table 2 machine models; baseline
//! *compile* costs are modeled with constants calibrated to paper
//! Table 7 (clang on multi-100MB C++ is not reproducible here — see
//! DESIGN.md §Substitutions).

use crate::coordinator::compile::{compile_design, CompileOpts, Compiled};
use crate::coordinator::{autotune, sweep};
use crate::designs::{catalog, Design};
use crate::graph::levelize::levelize;
use crate::kernels::{KernelConfig, ALL_KERNELS};
use crate::partition::PartitionerKind;
use crate::perf::machine::{self, Machine};
use crate::perf::topdown;
use crate::perf::trace::SimStyle;
use crate::service::cache::DesignCache;
use crate::util::fmt_bytes;
use crate::util::tables::Table;

fn fmt_s(d: std::time::Duration) -> String {
    format!("{:.3}", d.as_secs_f64())
}

fn pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

pub struct Ctx {
    pub quick: bool,
}

impl Ctx {
    pub fn from_env() -> Self {
        Ctx { quick: std::env::var("RTEAAL_FULL").is_err() }
    }
    /// measured cycles per run
    fn cycles(&self, base: u64) -> u64 {
        if self.quick {
            base / 10
        } else {
            base
        }
    }
    fn core_counts(&self) -> Vec<usize> {
        if self.quick {
            vec![1, 2, 4, 8]
        } else {
            vec![1, 2, 4, 8, 12, 16, 20, 24]
        }
    }
}

fn compiled(name: &str) -> (Design, Compiled) {
    let d = catalog(name).unwrap_or_else(|| panic!("unknown design {name}"));
    let c = compile_design(&d, CompileOpts::default());
    (d, c)
}

// ---------------------------------------------------------------- setup

/// Paper Table 2: machine summary.
pub fn table2_machines() -> Table {
    let mut t = Table::new(
        "Table 2 — machine models",
        &["machine", "L1I", "L1D", "L2", "LLC", "LLC lat", "GHz", "indirect pred"],
    );
    for m in machine::all_machines() {
        t.row(vec![
            m.name.to_string(),
            format!("{} KB", m.l1i.size_kb),
            format!("{} KB", m.l1d.size_kb),
            format!("{} KB", m.l2.size_kb),
            format!("{} KB", m.llc.size_kb),
            format!("{} cy", m.llc_lat),
            format!("{:.1}", m.ghz),
            if m.smart_indirect { "history" } else { "last-target" }.to_string(),
        ]);
    }
    t
}

/// Paper Table 3: designs + default simulated cycles.
pub fn table3_designs(ctx: &Ctx) -> Table {
    let mut t = Table::new(
        "Table 3 — designs (scaled; see DESIGN.md)",
        &["design", "eff. ops", "layers", "regs", "sim cycles"],
    );
    for name in crate::designs::main_eval_designs() {
        let (d, c) = compiled(name);
        t.row(vec![
            name.to_string(),
            c.ir.total_ops().to_string(),
            c.ir.depth().to_string(),
            c.graph.regs.len().to_string(),
            ctx.cycles(d.default_cycles).to_string(),
        ]);
    }
    t
}

// ------------------------------------------------------------- Table 1

/// Paper Table 1: identity-operation counts.
pub fn tab01_identity() -> Table {
    let mut t = Table::new(
        "Table 1 — identity operations (elided per §4.3)",
        &["design", "effectual ops", "identity ops", "ratio"],
    );
    for name in ["rocket_like_1c", "boom_like_1c", "rocket_like_8c", "boom_like_8c"] {
        let (_, c) = compiled(name);
        let lv = levelize(&c.graph);
        t.row(vec![
            name.to_string(),
            lv.effectual_ops().to_string(),
            lv.identity_ops.to_string(),
            format!("{:.1}x", lv.identity_ops as f64 / lv.effectual_ops().max(1) as f64),
        ]);
    }
    t
}

// ---------------------------------------------------------------- Fig 7

/// Paper Fig 7: top-down breakdown of the baselines (Graviton 4).
pub fn fig07_topdown(ctx: &Ctx) -> Table {
    let m = machine::aws_graviton4();
    let mut t = Table::new(
        "Fig 7 — top-down of baselines on Graviton 4 model",
        &["design", "simulator", "frontend", "bad spec", "others", "L1I MPKI"],
    );
    let cores = if ctx.quick { vec![1, 4, 8] } else { vec![1, 4, 8, 12] };
    for family in ["rocket_like", "boom_like"] {
        for &c in &cores {
            let (_, comp) = compiled(&format!("{family}_{c}c"));
            for style in [SimStyle::Verilator, SimStyle::Essent] {
                let (p, td) = sweep::modeled(&comp, style, &m, 2);
                t.row(vec![
                    format!("{family}_{c}c"),
                    style.name(),
                    pct(td.frontend_bound),
                    pct(td.bad_speculation),
                    pct(td.retiring + td.backend_bound),
                    format!("{:.1}", p.l1i_mpki()),
                ]);
            }
        }
    }
    t
}

// ------------------------------------------------- baseline compile model

/// Baseline compile-cost model, calibrated to paper Table 7 (see module
/// docs): Verilator ≈ 65 s + 27.5 s/core; ESSENT superlinear; memory
/// likewise. We scale by (our ops / paper's ops-per-core) so the model
/// tracks our scaled designs.
pub fn modeled_baseline_compile(which: &str, cores: f64) -> (f64, f64) {
    match which {
        // (time s, mem GB)
        "verilator" => (65.0 + 27.5 * cores, 0.23 + 0.002 * cores),
        "essent" => (121.0 * cores.powf(1.5), 2.8 * cores.powf(1.4)),
        _ => panic!("unknown baseline"),
    }
}

/// Paper Fig 8: compilation cost of the baselines (modeled) vs design size.
pub fn fig08_baseline_compile(ctx: &Ctx) -> Table {
    let mut t = Table::new(
        "Fig 8 — baseline compilation cost (modeled from paper Table 7)",
        &["design", "verilator time (s)", "essent time (s)", "verilator mem (GB)", "essent mem (GB)"],
    );
    for &c in &ctx.core_counts() {
        let (vt, vm) = modeled_baseline_compile("verilator", c as f64);
        let (et, em) = modeled_baseline_compile("essent", c as f64);
        t.row(vec![
            format!("r{c}"),
            format!("{vt:.0}"),
            format!("{et:.0}"),
            format!("{vm:.2}"),
            format!("{em:.1}"),
        ]);
    }
    t
}

// ------------------------------------------------------- Fig 15 / Table 4

/// Paper Fig 15 + Table 4: RTeAAL per-kernel compile cost and binary size
/// (rocket-8c). Compile time/heap are *measured* on our pipeline.
pub fn fig15_kernel_compile() -> Table {
    let (_, c) = compiled("rocket_like_8c");
    let mut t = Table::new(
        "Fig 15 + Table 4 — RTeAAL kernel compilation (rocket_like_8c)",
        &["kernel", "compile time (s)", "peak heap", "program bytes", "metadata bytes"],
    );
    for cfg in ALL_KERNELS {
        let (k, dt, heap) = c.build_kernel(cfg);
        t.row(vec![
            cfg.name().to_string(),
            fmt_s(c.compile_time + dt),
            fmt_bytes(c.peak_heap.max(heap)),
            fmt_bytes(k.program_bytes()),
            fmt_bytes(k.data_bytes()),
        ]);
    }
    t
}

// ------------------------------------------------------ Tables 5 and 6

/// Paper Tables 5 & 6: dynamic instructions, IPC and cache profile per
/// kernel (rocket-8c on the Xeon model).
pub fn tab05_06_profile() -> Table {
    let (_, c) = compiled("rocket_like_8c");
    let m = machine::intel_xeon();
    let mut t = Table::new(
        "Tables 5+6 — modeled profile per kernel (rocket_like_8c, Xeon)",
        &["kernel", "dyn inst/cycle", "IPC", "L1I miss/cyc", "L1D load/cyc", "L1D miss/cyc", "frontend"],
    );
    for cfg in ALL_KERNELS {
        let (p, td) = sweep::modeled(&c, SimStyle::Kernel(cfg), &m, 2);
        let per = p.cycles_sampled as f64;
        t.row(vec![
            cfg.name().to_string(),
            format!("{:.0}", p.instructions as f64 / per),
            format!("{:.2}", td.ipc),
            format!("{:.0}", p.l1i_misses as f64 / per),
            format!("{:.0}", p.l1d_loads as f64 / per),
            format!("{:.0}", p.l1d_misses as f64 / per),
            pct(td.frontend_bound),
        ]);
    }
    t
}

// ---------------------------------------------------------------- Fig 16

/// Paper Fig 16: simulation time per kernel across machines (rocket-8c).
/// "host (ms)" is measured wall-clock; machine columns are modeled.
pub fn fig16_kernel_sweep(ctx: &Ctx) -> Table {
    let (d, c) = compiled("rocket_like_8c");
    let cycles = ctx.cycles(d.default_cycles);
    let machines = machine::all_machines();
    let mut header = vec!["kernel".to_string(), "host (ms)".to_string(), "host Mcyc/s".to_string()];
    header.extend(machines.iter().map(|m| format!("{} (ms)", short(m))));
    let mut t = Table::new(
        &format!("Fig 16 — sim time per kernel (rocket_like_8c, {cycles} cycles)"),
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for cfg in ALL_KERNELS {
        let p = sweep::measure_kernel(&d, &c, cfg, cycles);
        let mut row = vec![
            cfg.name().to_string(),
            format!("{:.1}", p.wall.as_secs_f64() * 1e3),
            format!("{:.2}", p.hz / 1e6),
        ];
        for m in &machines {
            let (_, td) = sweep::modeled(&c, SimStyle::Kernel(cfg), m, 2);
            row.push(format!("{:.1}", topdown::modeled_sim_time(&td, m, cycles) * 1e3));
        }
        t.row(row);
    }
    t
}

fn short(m: &Machine) -> &'static str {
    if m.name.contains("Core") {
        "Core"
    } else if m.name.contains("Xeon") {
        "Xeon"
    } else if m.name.contains("AMD") {
        "AMD"
    } else {
        "Graviton"
    }
}

// ---------------------------------------------------------------- Fig 17

/// Paper Fig 17: kernel scaling with design size (measured on host).
pub fn fig17_scaling(ctx: &Ctx) -> Table {
    let mut header = vec!["design".to_string(), "ops".to_string()];
    header.extend(ALL_KERNELS.iter().map(|k| format!("{} Mcyc/s", k.name())));
    let mut t = Table::new(
        "Fig 17 — kernel scaling across design size (measured)",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for &cores in &ctx.core_counts() {
        let (d, c) = compiled(&format!("rocket_like_{cores}c"));
        let cycles = ctx.cycles(d.default_cycles).max(200);
        let mut row = vec![format!("r{cores}"), c.ir.total_ops().to_string()];
        for cfg in ALL_KERNELS {
            // RU is pathologically slow on big designs (as in the paper —
            // only its first point is shown); cap its cycles
            let cyc = if cfg == KernelConfig::RU { cycles.min(500) } else { cycles };
            let p = sweep::measure_kernel(&d, &c, cfg, cyc);
            row.push(format!("{:.2}", p.hz / 1e6));
        }
        t.row(row);
    }
    t
}

// ---------------------------------------------------------------- Fig 18

/// Paper Fig 18: PSU vs the baselines as design size grows (measured).
pub fn fig18_vs_baselines(ctx: &Ctx) -> Table {
    let mut t = Table::new(
        "Fig 18 — PSU vs baselines (measured)",
        &["design", "verilator Mcyc/s", "PSU Mcyc/s", "essent Mcyc/s", "PSU/verilator", "event Mcyc/s"],
    );
    for &cores in &ctx.core_counts() {
        let (d, c) = compiled(&format!("rocket_like_{cores}c"));
        let cycles = ctx.cycles(d.default_cycles).max(200);
        let v = sweep::measure_baseline(&d, &c, "verilator", cycles);
        let p = sweep::measure_kernel(&d, &c, KernelConfig::PSU, cycles);
        let e = sweep::measure_baseline(&d, &c, "essent", cycles);
        let ev = sweep::measure_baseline(&d, &c, "event", cycles);
        t.row(vec![
            format!("r{cores}"),
            format!("{:.2}", v.hz / 1e6),
            format!("{:.2}", p.hz / 1e6),
            format!("{:.2}", e.hz / 1e6),
            format!("{:.2}x", p.hz / v.hz),
            format!("{:.2}", ev.hz / 1e6),
        ]);
    }
    t
}

// ---------------------------------------------------------------- Fig 19

/// Paper Fig 19: the -O0 analog (naive executors).
pub fn fig19_o0(ctx: &Ctx) -> Table {
    let mut t = Table::new(
        "Fig 19 — unoptimized (-O0 analog) simulators (measured)",
        &["design", "verilator-O0", "PSU-O0", "essent-O0", "essent slowdown vs -O2"],
    );
    for &cores in &ctx.core_counts() {
        if cores > 8 && ctx.quick {
            break;
        }
        let (d, c) = compiled(&format!("rocket_like_{cores}c"));
        let cycles = (ctx.cycles(d.default_cycles) / 4).max(100);
        let v0 = sweep::measure_baseline(&d, &c, "verilator-O0", cycles);
        let p0 = sweep::measure_baseline(&d, &c, "psu-O0", cycles);
        let e0 = sweep::measure_baseline(&d, &c, "essent-O0", cycles);
        let e2 = sweep::measure_baseline(&d, &c, "essent", cycles);
        t.row(vec![
            format!("r{cores}"),
            format!("{:.2} Mcyc/s", v0.hz / 1e6),
            format!("{:.2} Mcyc/s", p0.hz / 1e6),
            format!("{:.2} Mcyc/s", e0.hz / 1e6),
            format!("{:.1}x", e2.hz / e0.hz),
        ]);
    }
    t
}

// --------------------------------------------------------------- Table 7

/// Paper Table 7: compile-cost scaling. Ours measured; baselines modeled.
pub fn tab07_compile_scaling(ctx: &Ctx) -> Table {
    let mut t = Table::new(
        "Table 7 — compile cost scaling (PSU measured; baselines modeled)",
        &["design", "PSU time (s)", "PSU heap", "verilator time (s)*", "essent time (s)*", "essent mem (GB)*"],
    );
    for &cores in &ctx.core_counts() {
        let d = catalog(&format!("rocket_like_{cores}c")).unwrap();
        let c = compile_design(&d, CompileOpts::default());
        let (dt, heap) = c.kernel_compile_cost(KernelConfig::PSU);
        let (vt, _) = modeled_baseline_compile("verilator", cores as f64);
        let (et, em) = modeled_baseline_compile("essent", cores as f64);
        t.row(vec![
            format!("r{cores}"),
            fmt_s(dt),
            fmt_bytes(heap),
            format!("{vt:.0}"),
            format!("{et:.0}"),
            format!("{em:.0}"),
        ]);
    }
    t
}

/// Designs measured by the incremental-recompile half of Table 7: the
/// cold column compiles the one-module `_edit` catalog variant from
/// scratch; the incremental column opens the base design first and then
/// routes the edit through the cone-delta reuse path.
pub const TAB07_DESIGNS: [&str; 2] = ["rocket_like_1c", "boom_like_1c"];

/// One measured (cold, incremental) compile pair for [`tab07_table`].
pub struct Tab07Point {
    pub design: String,
    pub cold: std::time::Duration,
    pub incremental: std::time::Duration,
    pub reused_groups: usize,
    pub rebuilt_groups: usize,
}

/// Measure cold vs incremental recompile of a one-module edit on each
/// [`TAB07_DESIGNS`] entry. Both caches are memory-only so the timings
/// compare compile work, not disk IO; parts=2 under the min-cut
/// partitioner so the incremental path also exercises warm-start FM.
pub fn tab07_measure(_ctx: &Ctx) -> Vec<Tab07Point> {
    let (parts, pk) = (2usize, PartitionerKind::MinCut);
    let mut points = Vec::new();
    for name in TAB07_DESIGNS {
        let edited = catalog(&format!("{name}_edit")).expect("catalog edit variant");
        // cold: a fresh cache compiles the edited design from scratch
        let mut cold_cache = DesignCache::new(None, 4);
        let t0 = std::time::Instant::now();
        let (_, rc) = cold_cache.open_design(&edited, true, parts, pk).expect("cold open");
        let cold = t0.elapsed();
        assert!(!rc.hit, "fresh cache must miss on {name}_edit");
        // incremental: warm another cache with the base, then open the edit
        let base = catalog(name).expect("catalog design");
        let mut warm_cache = DesignCache::new(None, 4);
        warm_cache.open_design(&base, true, parts, pk).expect("base open");
        let t1 = std::time::Instant::now();
        let (_, ri) = warm_cache
            .open_design_incremental(&edited, true, parts, pk)
            .expect("incremental open");
        let incremental = t1.elapsed();
        assert!(ri.incremental, "edit of {name} should take the delta path");
        points.push(Tab07Point {
            design: name.to_string(),
            cold,
            incremental,
            reused_groups: ri.reused_groups,
            rebuilt_groups: ri.rebuilt_groups,
        });
    }
    points
}

/// Table 7 (incremental half): measured cold vs incremental recompile.
pub fn tab07_table(points: &[Tab07Point]) -> Table {
    let mut t = Table::new(
        "Table 7b — incremental recompile of a one-module edit (measured)",
        &["design", "cold (s)", "incr (s)", "ratio", "groups reused", "groups rebuilt"],
    );
    for p in points {
        t.row(vec![
            p.design.clone(),
            fmt_s(p.cold),
            fmt_s(p.incremental),
            format!("{:.2}", p.incremental.as_secs_f64() / p.cold.as_secs_f64().max(1e-9)),
            p.reused_groups.to_string(),
            p.rebuilt_groups.to_string(),
        ]);
    }
    t
}

// ---------------------------------------------------------------- Fig 20

/// Paper Fig 20: main evaluation — best RTeAAL kernel vs baselines across
/// designs. Host speedups measured; best kernel picked per design.
pub fn fig20_main_eval(ctx: &Ctx) -> Table {
    let mut t = Table::new(
        "Fig 20 — main evaluation (measured on host)",
        &["design", "best kernel", "RTeAAL Mcyc/s", "verilator Mcyc/s", "essent Mcyc/s", "RTeAAL/verilator", "essent/verilator"],
    );
    for name in crate::designs::main_eval_designs() {
        let (d, c) = compiled(name);
        let cycles = ctx.cycles(d.default_cycles).max(200);
        let (best, _) = autotune::best_measured(&d, &c, (cycles / 8).max(100));
        let r = sweep::measure_kernel(&d, &c, best, cycles);
        let v = sweep::measure_baseline(&d, &c, "verilator", cycles);
        let e = sweep::measure_baseline(&d, &c, "essent", cycles);
        t.row(vec![
            name.to_string(),
            best.name().to_string(),
            format!("{:.2}", r.hz / 1e6),
            format!("{:.2}", v.hz / 1e6),
            format!("{:.2}", e.hz / 1e6),
            format!("{:.2}x", r.hz / v.hz),
            format!("{:.2}x", e.hz / v.hz),
        ]);
    }
    t
}

/// Fig 20 companion: best kernel per design × *machine model* (the
/// cross-machine claim).
pub fn fig20_best_kernel_matrix() -> Table {
    let machines = machine::all_machines();
    let mut header = vec!["design".to_string()];
    header.extend(machines.iter().map(|m| short(m).to_string()));
    let mut t = Table::new(
        "Fig 20 companion — modeled best kernel per design x machine",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for name in ["rocket_like_1c", "rocket_like_8c", "boom_like_8c", "keccak", "tiny_cpu"] {
        let (_, c) = compiled(name);
        let mut row = vec![name.to_string()];
        for m in &machines {
            let (cfg, _) = autotune::best_modeled(&c, m);
            row.push(cfg.name().to_string());
        }
        t.row(row);
    }
    t
}

// ---------------------------------------------------------------- Fig 21

/// Paper Fig 21: LLC-capacity sensitivity (Intel CAT analog), boom-8c.
/// Uses a *full-scale* boom core so the straight-line code footprint
/// exceeds L2 and actually exercises the LLC (the scaled benchmark
/// designs fit in L2, which would make the sweep vacuous).
pub fn fig21_llc() -> Table {
    let d = crate::designs::Design {
        name: "boom_like_8c_full".into(),
        graph: crate::designs::boom_like::boom_like(8, 0.5),
        stimulus: crate::designs::Stimulus::Random(21),
        default_cycles: 0,
        lane_init: vec![],
    };
    let c = compile_design(&d, CompileOpts::default());
    let mut t = Table::new(
        "Fig 21 — LLC sensitivity (modeled, boom_like_8c at scale 0.5, Xeon)",
        &["LLC", "PSU cyc/simcyc", "essent cyc/simcyc", "verilator cyc/simcyc", "PSU/verilator", "essent/verilator"],
    );
    for llc_kb in [10752usize, 7168, 3584, 1792] {
        let m = machine::intel_xeon().with_llc_kb(llc_kb);
        let (_, psu) = sweep::modeled(&c, SimStyle::Kernel(KernelConfig::PSU), &m, 2);
        let (_, ess) = sweep::modeled(&c, SimStyle::Essent, &m, 2);
        let (_, ver) = sweep::modeled(&c, SimStyle::Verilator, &m, 2);
        t.row(vec![
            format!("{:.1} MB", llc_kb as f64 / 1024.0),
            format!("{:.0}", psu.cycles_per_sim_cycle),
            format!("{:.0}", ess.cycles_per_sim_cycle),
            format!("{:.0}", ver.cycles_per_sim_cycle),
            format!("{:.2}x", ver.cycles_per_sim_cycle / psu.cycles_per_sim_cycle),
            format!("{:.2}x", ver.cycles_per_sim_cycle / ess.cycles_per_sim_cycle),
        ]);
    }
    t
}

// ---------------------------------------------------------------- Fig 22

/// Fig 22 (ours, beyond the paper): lane-batched throughput sweep.
/// Aggregate lane-cycles/sec for `B ∈ {1, 2, 4, 8, 16}` on **all seven**
/// batched binding levels — the "simulate many users/test-vectors at
/// once" scale axis enabled by the tensor form, with a complete lane
/// axis since the batched IU/SU executors landed.
pub fn fig22_lanes(ctx: &Ctx) -> Table {
    let (d, c) = compiled("rocket_like_1c");
    let cycles = ctx.cycles(d.default_cycles).max(200);
    let mut t = Table::new(
        &format!("Fig 22 — lane-batched aggregate throughput (rocket_like_1c, {cycles} cycles/lane, M lane-cyc/s)"),
        &["kernel", "B=1", "B=2", "B=4", "B=8", "B=16"],
    );
    for cfg in crate::kernels::BATCHED_KERNELS {
        let mut row = vec![cfg.name().to_string()];
        for lanes in [1usize, 2, 4, 8, 16] {
            let p = sweep::measure_kernel_lanes(&d, &c, cfg, lanes, cycles);
            row.push(format!("{:.2}", p.hz / 1e6));
        }
        t.row(row);
    }
    t
}

// ---------------------------------------------------------------- Fig 23

/// The (design, kernel, lane-count) grid of the sparse activity sweep —
/// shared by the fig23 table and the bench's JSON skip-statistics dump.
pub const FIG23_DESIGNS: [&str; 3] = ["alu_farm_64", "fir8", "tiny_cpu"];
pub const FIG23_RATES: [f64; 4] = [0.0, 0.05, 0.5, 1.0];
pub const FIG23_LANES: usize = 16;

/// One (design, kernel) row of the fig23 grid: the dense comparison
/// point plus one sparse point per toggle rate. For self-driving
/// (all-zero-stimulus) designs the toggle rate has no effect, so only a
/// single sparse point is measured (`sparse.len() == 1`) and the row is
/// labeled `[idle]`.
pub struct Fig23Point {
    pub design: &'static str,
    pub kernel: KernelConfig,
    /// whether the stimulus actually responds to the toggle rate
    pub toggleable: bool,
    pub dense: sweep::SweepPoint,
    /// (toggle rate, sparse measurement)
    pub sparse: Vec<(f64, sweep::SweepPoint)>,
}

/// Measure the fig23 grid once — shared by the rendered table and the
/// bench's JSON skip-statistics dump, so nothing is simulated twice.
pub fn fig23_measure(ctx: &Ctx) -> Vec<Fig23Point> {
    let lanes = FIG23_LANES;
    let mut points = Vec::new();
    for name in FIG23_DESIGNS {
        let (d, c) = compiled(name);
        let cycles = ctx.cycles(d.default_cycles).max(200);
        let toggleable = !matches!(d.stimulus, crate::designs::Stimulus::Zero);
        for cfg in [KernelConfig::PSU, KernelConfig::TI] {
            let dense = sweep::measure_kernel_lanes_toggle(&d, &c, cfg, lanes, cycles, 0.05);
            let rates: &[f64] = if toggleable { &FIG23_RATES } else { &FIG23_RATES[..1] };
            let sparse = rates
                .iter()
                .map(|&rate| {
                    (rate, sweep::measure_kernel_lanes_sparse(&d, &c, cfg, lanes, cycles, rate))
                })
                .collect();
            points.push(Fig23Point { design: name, kernel: cfg, toggleable, dense, sparse });
        }
    }
    points
}

/// Render measured fig23 points as the report table.
pub fn fig23_table(points: &[Fig23Point]) -> Table {
    let mut header =
        vec!["design".to_string(), "kernel".to_string(), "dense Mlc/s".to_string()];
    header.extend(
        FIG23_RATES.iter().map(|r| format!("sparse@{:.0}% Mlc/s (skip)", r * 100.0)),
    );
    let mut t = Table::new(
        &format!(
            "Fig 23 — sparse activity-masked batching (B={}, toggle-rate stimulus)",
            FIG23_LANES
        ),
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for p in points {
        let design = if p.toggleable {
            p.design.to_string()
        } else {
            // self-driving design: the stimulus is all-zero regardless of
            // the column's toggle rate, so only one cell is real
            format!("{} [idle]", p.design)
        };
        let mut row =
            vec![design, p.kernel.name().to_string(), format!("{:.2}", p.dense.hz / 1e6)];
        for (i, _) in FIG23_RATES.iter().enumerate() {
            row.push(match p.sparse.get(i) {
                Some((_, sp)) => format!(
                    "{:.2} ({:.0}%)",
                    sp.hz / 1e6,
                    100.0 * sp.skip_rate.unwrap_or(0.0)
                ),
                None => "—".to_string(),
            });
        }
        t.row(row);
    }
    t
}

/// Fig 23 (ours, beyond the paper): sparse activity-masked batched
/// execution vs dense batched execution across toggle rates. Dense
/// columns use the same toggle-controlled stimulus; sparse cells report
/// aggregate lane-cycles/sec plus the realized skip-rate. `alu_farm_64`
/// is the shallow high-lane-sparsity workload, `fir8` carries changes
/// through a deep delay line, and `tiny_cpu` is self-driving (idle
/// stimulus; it goes fully quiescent after HALT).
pub fn fig23_sparse(ctx: &Ctx) -> Table {
    fig23_table(&fig23_measure(ctx))
}

// ---------------------------------------------------------------- Fig 24

/// The (kernel, partitioner, partitions, lanes) grid of the partitions ×
/// lanes sweep — shared by the fig24 table and the bench's JSON dump.
pub const FIG24_DESIGN: &str = "gemmini_like_8";
pub const FIG24_PARTS: [usize; 3] = [1, 2, 4];
pub const FIG24_LANES: [usize; 2] = [1, 8];
pub const FIG24_PARTITIONERS: [PartitionerKind; 2] =
    [PartitionerKind::RoundRobin, PartitionerKind::MinCut];
/// Lane count of the sparse (activity-masked) column of the fig24 grid.
pub const FIG24_SPARSE_LANES: usize = 8;
/// Toggle rate of the sparse column — low enough that both activity
/// levels (partition skipping and group masks inside partitions) have
/// real work to skip.
pub const FIG24_SPARSE_TOGGLE: f64 = 0.05;

/// One (kernel, partitioner, partition-count) row of the fig24 grid: a
/// measurement per lane count, plus the RUM cut that partitioning paid
/// and the sparse (composed partition × group skipping) measurement.
pub struct Fig24Point {
    pub kernel: KernelConfig,
    pub partitioner: PartitionerKind,
    pub parts: usize,
    /// distinct registers crossing partitions each cycle
    pub cut_regs: usize,
    /// (lanes, measurement) per lane count in [`FIG24_LANES`] order
    pub cells: Vec<(usize, sweep::SweepPoint)>,
    /// sparse run at [`FIG24_SPARSE_LANES`] × [`FIG24_SPARSE_TOGGLE`]
    /// (kernels with sparse executors only): its `skip_rate` is the
    /// partition-cycle skip rate, its `group_skip_rate` the composed
    /// op-lane skip rate
    pub sparse: Option<sweep::SweepPoint>,
}

/// Measure the fig24 grid once — shared by the rendered table and the
/// bench's JSON dump, so nothing is simulated twice.
pub fn fig24_measure(ctx: &Ctx) -> Vec<Fig24Point> {
    let (d, c) = compiled(FIG24_DESIGN);
    let cycles = ctx.cycles(d.default_cycles).max(200);
    let mut points = Vec::new();
    for cfg in [KernelConfig::PSU, KernelConfig::TI] {
        for &pk in &FIG24_PARTITIONERS {
            for &parts in &FIG24_PARTS {
                let cells: Vec<(usize, sweep::SweepPoint)> = FIG24_LANES
                    .iter()
                    .map(|&lanes| {
                        (
                            lanes,
                            sweep::measure_kernel_parts_lanes(
                                &d, &c, cfg, parts, lanes, cycles, pk,
                            ),
                        )
                    })
                    .collect();
                let cut_regs = cells[0].1.cut_regs.unwrap_or(0);
                let sparse = crate::kernels::supports_sparse(cfg).then(|| {
                    sweep::measure_kernel_parts_lanes_sparse(
                        &d, &c, cfg, parts, FIG24_SPARSE_LANES, cycles, FIG24_SPARSE_TOGGLE, pk,
                    )
                });
                points.push(Fig24Point {
                    kernel: cfg,
                    partitioner: pk,
                    parts,
                    cut_regs,
                    cells,
                    sparse,
                });
            }
        }
    }
    points
}

/// Render measured fig24 points as the report table. The sparse column
/// reports throughput plus the two skip rates of the composed activity
/// levels: `part` — the fraction of (partition, cycle) units skipped
/// whole; `group` — the fraction of (op, lane) units skipped by
/// partition- and group-level masking together.
pub fn fig24_table(points: &[Fig24Point]) -> Table {
    let mut header =
        vec!["kernel".to_string(), "partitioner".to_string(), "parts".to_string()];
    header.extend(FIG24_LANES.iter().map(|b| format!("B={b} Mlc/s")));
    header.push(format!(
        "sparse B={FIG24_SPARSE_LANES}@{:.0}% (part/group skip)",
        FIG24_SPARSE_TOGGLE * 100.0
    ));
    header.push("cut_regs".to_string());
    let mut t = Table::new(
        &format!(
            "Fig 24 — partitions x lanes aggregate throughput ({FIG24_DESIGN}, M lane-cyc/s)"
        ),
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for p in points {
        let mut row = vec![
            p.kernel.name().to_string(),
            p.partitioner.name().to_string(),
            format!("P={}", p.parts),
        ];
        for (_, sp) in &p.cells {
            row.push(format!("{:.2}", sp.hz / 1e6));
        }
        row.push(match &p.sparse {
            Some(sp) => format!(
                "{:.2} ({:.0}%/{:.0}%)",
                sp.hz / 1e6,
                100.0 * sp.skip_rate.unwrap_or(0.0),
                100.0 * sp.group_skip_rate.unwrap_or(0.0)
            ),
            None => "—".to_string(),
        });
        row.push(p.cut_regs.to_string());
        t.row(row);
    }
    t
}

/// Fig 24 (ours, beyond the paper): thread-level × data-level parallelism
/// in one run — the RepCut-style partitioned simulator with lane-batched
/// kernels per partition ([`super::parallel::BatchParallelSim`]),
/// sweeping partitions P × lanes B under both register-ownership
/// strategies (round-robin scatter vs multilevel hypergraph min-cut —
/// the `cut_regs` column shows the RUM cut each pays). One run's
/// aggregate lane-cycles/sec scales along both axes at once, and the
/// sparse column shows the *composed* activity machinery — group-masked
/// sparse kernels inside partitions — with its partition-cycle and
/// op-lane skip rates side by side;
/// `benches/fig24_parts_lanes.rs` adds the sparse (partition- and
/// group-skipping) measurements on `alu_farm_64` and asserts the
/// min-cut cut never exceeds round-robin's.
pub fn fig24_parts_lanes(ctx: &Ctx) -> Table {
    fig24_table(&fig24_measure(ctx))
}

/// Run an experiment by id; returns rendered text.
pub fn run_experiment(id: &str, ctx: &Ctx) -> Option<Vec<Table>> {
    let tables = match id {
        "setup" => vec![table2_machines(), table3_designs(ctx)],
        "tab01" => vec![tab01_identity()],
        "fig07" => vec![fig07_topdown(ctx)],
        "fig08" => vec![fig08_baseline_compile(ctx)],
        "fig15" | "tab04" => vec![fig15_kernel_compile()],
        "tab05" | "tab06" => vec![tab05_06_profile()],
        "fig16" => vec![fig16_kernel_sweep(ctx)],
        "fig17" => vec![fig17_scaling(ctx)],
        "fig18" => vec![fig18_vs_baselines(ctx)],
        "fig19" => vec![fig19_o0(ctx)],
        "tab07" => vec![tab07_compile_scaling(ctx), tab07_table(&tab07_measure(ctx))],
        "fig20" => vec![fig20_main_eval(ctx), fig20_best_kernel_matrix()],
        "fig21" => vec![fig21_llc()],
        "fig22" => vec![fig22_lanes(ctx)],
        "fig23" => vec![fig23_sparse(ctx)],
        "fig24" => vec![fig24_parts_lanes(ctx)],
        _ => return None,
    };
    Some(tables)
}

pub const ALL_EXPERIMENTS: [&str; 16] = [
    "setup", "tab01", "fig07", "fig08", "fig15", "tab05", "fig16", "fig17", "fig18", "fig19",
    "tab07", "fig20", "fig21", "fig22", "fig23", "fig24",
];
