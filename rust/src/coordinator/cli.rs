//! CLI command routing (the leader entrypoint's verbs).

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use super::compile::{compile_design, CompileOpts};
use super::report;
use crate::designs::catalog;
use crate::kernels::{BatchKernel as _, KernelConfig};
use crate::sim::Simulator;
use crate::tensor::export;
use crate::util::cli::Args;
use crate::util::fmt_bytes;

const USAGE: &str = "\
rteaal — RTL simulation as sparse tensor algebra (paper reproduction)

USAGE: rteaal <command> [options]

COMMANDS:
  help                         this text
  designs                      list available designs
  compile   --design D         compile D; print graph/OIM/format statistics
            [--emit-oim F]     also write the OIM tensors as JSON (paper §6.1)
            [--emit-fir F]     also write the design as FIRRTL text
  check     [--design D]       statically verify the compiled artifact
                               bundle (LayerIr/OIM/GDG/partitioning)
                               against the sparse, partitioned, and
                               incremental invariants — stable diagnostic
                               codes IR01-IR09, GD01-GD08, PT01-PT07,
                               SP01-SP05 (catalog in the analysis module
                               docs). Without --design, sweeps the full
                               design catalog. Exits nonzero on any
                               error-severity finding; warnings are lints
            [--json]           one JSON report object per line instead of
                               human-readable text
            [--parts P]        partitions for the partition audit
                               (default 2)
            [--partitioner X]  rr|mincut (default mincut)
            [--incremental]    verify through the design cache instead of
                               a direct compile: cold-open each design,
                               then warm-open its `_edit` variant via the
                               cone-delta reuse path and verify the
                               *spliced* artifacts too
            [--cache-dir DIR]  cache directory for --incremental
                               (default .rteaal-check-cache)
  sim       --design D         simulate D
            [--kernel K]       RU|OU|NU|PSU|IU|SU|TI (default PSU)
            [--backend B]      interp|verilator|essent|event|parallel (default interp)
            [--threads N]      partitions for --backend parallel
            [--lanes B]        lane-batched run: B decorrelated stimulus
                               lanes per OIM walk (all seven kernels);
                               reports aggregate lane-cycles/sec
            [--parts P]        partitioned lane-batched run: P thread-level
                               partitions x B lanes in one run (RepCut x
                               batching) on a persistent worker pool;
                               reports aggregate lane-cycles/sec,
                               replication and cut size. With --sparse,
                               quiescent partitions are skipped entirely
                               (per-partition activity masks over the RUM
                               cut, B <= 64) and, for kernels with sparse
                               executors (NU|PSU|TI), each partition runs
                               its group-masked sparse kernel with RUM
                               change bits feeding the group trackers;
                               both the partition- and the composed
                               group-level skip-rates are reported
            [--partitioner X]  register-ownership strategy for --parts /
                               --backend parallel: mincut (multilevel
                               hypergraph min-cut, default — shrinks the
                               per-cycle RUM cut) | rr (round-robin
                               scatter baseline)
            [--sparse]         activity-masked sparse batched run (without
                               --parts: kernels NU|PSU|TI, B <= 64 — groups
                               whose inputs changed in no lane are skipped;
                               reports skip-rate alongside throughput)
            [--toggle R]       with --sparse: drive toggle-rate-controlled
                               stimulus (lane inputs change with
                               probability R per cycle; default random)
            [--cycles N]       cycle count (default: design default)
            [--vcd F]          write waveforms (delta-encoded: quiescent
                               cycles and quiescent lanes emit nothing).
                               With --lanes: every named signal of each
                               selected lane, gated by the activity
                               change masks on sparse runs. With --parts:
                               each selected lane's design output ports
                               (partition 0 commits every output;
                               internal names live in replicated cones)
            [--wave-lanes L,..] with --vcd on a --lanes/--parts run:
                               comma-separated list of lanes to stream
                               (default: lane 0). A single lane writes F
                               itself; several lanes write one file each
                               with `.laneN` inserted before the extension
            [--incremental]    open through the design cache's cone-delta
                               reuse path: if the cache holds an entry of
                               the same design family (e.g. the base of a
                               `_edit` variant) under the same config,
                               only the changed register cones are
                               recompiled and spliced into the cached
                               artifacts; prints a `cache:` line with the
                               reused/rebuilt group counts. Exact-key
                               re-opens hit as usual; with no donor the
                               open falls back to a cold compile
            [--cache-dir DIR]  design-cache directory for --incremental
                               (default .rteaal-cache)
            [--verify]         run the static artifact verifier (see
                               `check`) on the compiled or cached bundle
                               before simulating; refuse to run on any
                               error-severity finding
  serve                        run the simulation service (NDJSON requests,
                               one per line; schema in the service module
                               docs): a content-addressed design cache,
                               concurrent lane-packed sessions, and
                               checkpoint/restore
            [--stdio]          serve stdin/stdout (default)
            [--socket PATH]    serve a Unix socket instead
            [--cache-dir DIR]  persist compiled designs under DIR (repeat
                               opens are hash lookups, even across runs)
            [--cache-cap N]    in-memory cache capacity (default 8)
            [--timeout-ms N]   default per-request budget (default 2000)
            [--idle-timeout-ms N]
                               close --socket connections idle longer
                               than N ms; their sessions survive a
                               reconnect (default 30000)
            [--verify]         statically verify every design open,
                               server-wide; failing opens report
                               bad-config (sessions may also opt in per
                               open with \"verify\":true)
  xla-sim   --design D         simulate via the AOT XLA/PJRT artifact
            [--artifacts DIR]  artifact directory (default: artifacts)
            [--cycles N]
  export-tensors --design D --out F
                               write the dense tensor encoding for aot.py
  autotune  --design D         trial-run all kernels, report the best
  report    <id>|all           regenerate paper tables/figures
                               (set RTEAAL_FULL=1 for full-length runs)
";

pub fn run(args: Args) -> Result<()> {
    match args.command.as_str() {
        "" | "help" => {
            println!("{USAGE}");
            Ok(())
        }
        "designs" => {
            println!("built-in designs:");
            for name in crate::designs::main_eval_designs() {
                let d = catalog(name).unwrap();
                println!(
                    "  {name:<18} ops={:<7} regs={:<5} default_cycles={}",
                    d.graph.num_ops(),
                    d.graph.regs.len(),
                    d.default_cycles
                );
            }
            println!("  (+ counter, alu32, fir8, alu_farm_N, rocket_like_Nc, boom_like_Nc, gemmini_like_N, rocket_like_xs, tiny_cpu_divergent)");
            Ok(())
        }
        "compile" => cmd_compile(&args),
        "check" => cmd_check(&args),
        "sim" => cmd_sim(&args),
        "serve" => cmd_serve(&args),
        "xla-sim" => cmd_xla_sim(&args),
        "export-tensors" => cmd_export(&args),
        "autotune" => cmd_autotune(&args),
        "report" => cmd_report(&args),
        other => bail!("unknown command '{other}' (see `rteaal help`)"),
    }
}

fn design_arg(args: &Args) -> Result<crate::designs::Design> {
    let name = args.require("design")?;
    catalog(name).with_context(|| format!("unknown design '{name}' (see `rteaal designs`)"))
}

fn cmd_compile(args: &Args) -> Result<()> {
    let d = design_arg(args)?;
    let c = compile_design(&d, CompileOpts::default());
    println!("design       {}", c.name);
    println!("compile time {}", crate::util::fmt_duration(c.compile_time));
    println!("peak heap    {}", fmt_bytes(c.peak_heap));
    let s = c.graph.stats();
    println!("nodes={} ops={} regs={} inputs={} outputs={}", s.nodes, s.ops, s.regs, s.inputs, s.outputs);
    println!("layers (I)   {}", c.ir.depth());
    println!("identity ops {} (elided)", c.ir.identity_ops);
    let oimt = crate::einsum::OimTensor::from_ir(&c.ir);
    println!("OIM density  {:.3e}", oimt.density());
    for spec in [c.oim.format_a(), c.oim.format_b(), c.oim.format_c()] {
        println!("{}", spec.render());
    }
    if let Some(path) = args.opt("emit-oim") {
        std::fs::write(path, c.oim.to_json().to_string())?;
        println!("wrote OIM JSON to {path}");
    }
    if let Some(path) = args.opt("emit-fir") {
        std::fs::write(path, crate::firrtl::print(&c.graph))?;
        println!("wrote FIRRTL to {path}");
    }
    Ok(())
}

/// The design sweep `rteaal check` runs without `--design`: the main
/// evaluation set plus the small/structural designs the tests lean on
/// (including the ROM-carrying divergent CPU, which exercises PT04).
fn check_sweep() -> Vec<String> {
    let mut names: Vec<String> =
        ["counter", "alu32", "fir8", "tiny_cpu_divergent", "alu_farm_64", "rocket_like_xs"]
            .iter()
            .map(|s| s.to_string())
            .collect();
    for n in crate::designs::main_eval_designs() {
        names.push(n.to_string());
    }
    names
}

fn cmd_check(args: &Args) -> Result<()> {
    use crate::analysis::verify_artifacts;
    use crate::partition::partition_ir;

    let json_out = args.flag("json");
    let parts = args.opt_usize("parts", 2)?;
    if parts == 0 {
        bail!("--parts must be >= 1 (got 0)");
    }
    let name = args.opt_or("partitioner", "mincut");
    let partitioner = crate::partition::PartitionerKind::parse(name)
        .with_context(|| format!("unknown partitioner '{name}' (use rr or mincut)"))?;
    let incremental = args.flag("incremental");
    let names: Vec<String> = match args.opt("design") {
        Some(d) => vec![d.to_string()],
        None => check_sweep(),
    };

    let mut cache = incremental.then(|| {
        let dir = PathBuf::from(args.opt_or("cache-dir", ".rteaal-check-cache"));
        crate::service::cache::DesignCache::new(Some(dir), 4)
    });

    let mut reports = Vec::new();
    for name in &names {
        let d = catalog(name)
            .with_context(|| format!("unknown design '{name}' (see `rteaal designs`)"))?;
        match cache.as_mut() {
            None => {
                // direct: compile cold and verify the fresh bundle
                let c = compile_design(&d, CompileOpts::default());
                let gdg = crate::activity::GroupDepGraph::build(&c.ir, &c.oim);
                let parting = partition_ir(&c.ir, parts, partitioner);
                reports.push(verify_artifacts(name, &c.ir, &c.oim, &gdg, Some(&parting)));
            }
            Some(cache) => {
                // through the cache: cold-open the base, then warm-open
                // its `_edit` variant via the cone-delta reuse path, so
                // the *spliced* OIM/GDG get verified too
                let (entry, _) = cache
                    .open_design(&d, true, parts, partitioner)
                    .map_err(|e| anyhow::anyhow!(e))?;
                let parting = entry.partitioning();
                reports.push(verify_artifacts(
                    name,
                    &entry.ir,
                    &entry.oim,
                    &entry.gdg,
                    Some(&parting),
                ));
                let edit = format!("{name}_edit");
                if let Some(ed) = catalog(&edit) {
                    let (entry, rep) = cache
                        .open_design_incremental(&ed, true, parts, partitioner)
                        .map_err(|e| anyhow::anyhow!(e))?;
                    if !rep.incremental && !rep.hit {
                        bail!("{edit}: incremental open fell back to a cold compile (no donor?)");
                    }
                    let parting = entry.partitioning();
                    reports.push(verify_artifacts(
                        &edit,
                        &entry.ir,
                        &entry.oim,
                        &entry.gdg,
                        Some(&parting),
                    ));
                }
            }
        }
    }

    let total_errors: usize = reports.iter().map(|r| r.errors).sum();
    let total_warnings: usize = reports.iter().map(|r| r.warnings).sum();
    if json_out {
        for r in &reports {
            println!("{}", r.to_json());
        }
    } else {
        for r in &reports {
            println!("{}", r.summary());
            for diag in &r.diags {
                println!("  {diag}");
            }
        }
        println!(
            "checked {} artifact bundle(s): {total_errors} error(s), {total_warnings} warning(s)",
            reports.len()
        );
    }
    if total_errors > 0 {
        bail!("rteaal check: {total_errors} error-severity finding(s)");
    }
    Ok(())
}

/// Lane-count validation for `sim` (unit-tested below): `--lanes 0` is
/// always invalid, and the sparse executors' activity masks carry one bit
/// per lane in a `u64`, so `--sparse` caps `--lanes` at 64 (anything
/// larger would overflow the mask; 0 lanes would underflow it).
fn validate_lanes(lanes: usize, sparse: bool) -> Result<()> {
    if lanes == 0 {
        bail!("--lanes must be >= 1 (got 0)");
    }
    if sparse && lanes > 64 {
        bail!("--sparse supports at most 64 lanes (one u64 activity-mask bit per lane; got {lanes})");
    }
    Ok(())
}

/// Validate and parse `--partitioner`: only meaningful on partitioned
/// runs (`--parts` or `--backend parallel`); defaults to the multilevel
/// min-cut strategy.
fn partitioner_arg(
    args: &Args,
    parts_given: bool,
    backend: &str,
) -> Result<crate::partition::PartitionerKind> {
    if args.opt("partitioner").is_some() && !parts_given && backend != "parallel" {
        bail!("--partitioner requires --parts or --backend parallel");
    }
    let name = args.opt_or("partitioner", "mincut");
    crate::partition::PartitionerKind::parse(name)
        .with_context(|| format!("unknown partitioner '{name}' (use rr or mincut)"))
}

/// Validate and parse `--toggle`: requires `--sparse`, a rate in [0, 1],
/// and a design whose stimulus actually responds to it.
fn toggle_arg(args: &Args, d: &crate::designs::Design, sparse: bool) -> Result<Option<f64>> {
    match args.opt("toggle") {
        Some(_) if !sparse => bail!("--toggle requires --sparse"),
        Some(_) if matches!(d.stimulus, crate::designs::Stimulus::Zero) => bail!(
            "--toggle has no effect on '{}': its stimulus is all-zero (self-driving design)",
            d.name
        ),
        Some(_) => {
            let rate = args.opt_f64("toggle", 0.05)?;
            if !(0.0..=1.0).contains(&rate) {
                bail!("--toggle expects a rate in [0, 1], got {rate}");
            }
            Ok(Some(rate))
        }
        None => Ok(None),
    }
}

/// Validate and parse `--wave-lanes`: a comma-separated list of lane
/// indices to stream waveforms for. Requires `--vcd`; every entry must
/// be a valid lane of the run; duplicates are rejected (two sinks on one
/// file would interleave). Defaults to `[0]` so plain `--vcd` keeps its
/// historical lane-0 meaning.
fn wave_lanes_arg(args: &Args, lanes: usize) -> Result<Vec<usize>> {
    let spec = match args.opt("wave-lanes") {
        None => return Ok(vec![0]),
        Some(s) => s,
    };
    if args.opt("vcd").is_none() {
        bail!("--wave-lanes requires --vcd (it selects which lanes the waveform covers)");
    }
    let mut out: Vec<usize> = Vec::new();
    for tok in spec.split(',') {
        let tok = tok.trim();
        let l: usize = tok
            .parse()
            .ok()
            .with_context(|| format!("--wave-lanes: '{tok}' is not a lane index"))?;
        if l >= lanes {
            bail!("--wave-lanes: lane {l} out of range (run has {lanes} lanes)");
        }
        if out.contains(&l) {
            bail!("--wave-lanes: lane {l} listed twice");
        }
        out.push(l);
    }
    Ok(out)
}

/// Per-lane waveform file naming: a single selected lane writes the
/// `--vcd` path as given; several lanes each get `.laneN` inserted
/// before the extension (`waves.vcd` → `waves.lane3.vcd`).
fn lane_vcd_path(base: &str, lane: usize, multi: bool) -> PathBuf {
    if !multi {
        return PathBuf::from(base);
    }
    let p = PathBuf::from(base);
    match (
        p.file_stem().and_then(|s| s.to_str()),
        p.extension().and_then(|e| e.to_str()),
    ) {
        (Some(stem), Some(ext)) => p.with_file_name(format!("{stem}.lane{lane}.{ext}")),
        _ => PathBuf::from(format!("{base}.lane{lane}")),
    }
}

fn cmd_sim(args: &Args) -> Result<()> {
    let d = design_arg(args)?;
    let cycles = args.opt_u64("cycles", d.default_cycles)?;
    let backend = args.opt_or("backend", "interp");
    let lanes = args.opt_usize("lanes", 1)?;
    let parts = args.opt_usize("parts", 1)?;
    // an *explicit* --parts 1 still routes through BatchParallelSim, so a
    // P ∈ {1, 2, 4} sweep keeps uniform semantics (same kernels accepted,
    // same partition-level sparse metric) across every point
    let parts_given = args.opt("parts").is_some();
    if parts == 0 {
        bail!("--parts must be >= 1 (got 0)");
    }
    let sparse = args.flag("sparse");
    validate_lanes(lanes, sparse)?;
    let partitioner = partitioner_arg(args, parts_given, backend)?;

    if args.flag("incremental") {
        if backend != "interp" {
            bail!("--incremental requires --backend interp (got '{backend}')");
        }
        if args.opt("vcd").is_some() {
            bail!("--incremental does not stream waveforms (run without --incremental for --vcd)");
        }
        let cfg = KernelConfig::parse(args.opt_or("kernel", "PSU")).context("bad --kernel")?;
        let toggle = toggle_arg(args, &d, sparse)?;
        let cache_dir = PathBuf::from(args.opt_or("cache-dir", ".rteaal-cache"));
        let mut cache = crate::service::cache::DesignCache::new(Some(cache_dir), 8);
        cache.verify = args.flag("verify");
        let (cached, report) = cache
            .open_design_incremental(&d, true, parts, partitioner)
            .map_err(|e| anyhow::anyhow!(e))?;
        println!(
            "cache: key={} source={} incremental={} reused_groups={} rebuilt_groups={} open {}",
            report.key,
            report.source.name(),
            report.incremental,
            report.reused_groups,
            report.rebuilt_groups,
            crate::util::fmt_duration(report.open_time)
        );
        let mut sim = super::parallel::BatchParallelSim::with_partitioning(
            &cached.ir,
            cfg,
            cached.partitioning(),
            lanes,
            sparse,
            partitioner,
        );
        let pokes = cached.resolved_lane_init(&d, lanes).map_err(|e| anyhow::anyhow!(e))?;
        for (slot, lane, value) in pokes {
            sim.poke_lane(slot, lane, value);
        }
        let mut stim = match toggle {
            Some(rate) => d.make_lane_stimulus_toggle(lanes, rate),
            None => d.make_lane_stimulus(lanes),
        };
        let t0 = std::time::Instant::now();
        for cyc in 0..cycles {
            sim.step(&stim(cyc));
        }
        let dt = t0.elapsed();
        let aggregate = (cycles as f64 * lanes as f64) / dt.as_secs_f64().max(1e-12);
        println!(
            "{} x{parts} parts x{lanes} lanes [{}] (cached): {cycles} cycles/lane in {} ({:.2} M lane-cyc/s aggregate)",
            cfg.name(),
            partitioner.name(),
            crate::util::fmt_duration(dt),
            aggregate / 1e6
        );
        for (oname, v) in sim.lane_outputs(0) {
            println!("  lane0 out {oname} = {v:#x}");
        }
        return Ok(());
    }

    let c = compile_design(&d, CompileOpts { fuse: args.opt("vcd").is_none() });

    if args.flag("verify") {
        // refuse to simulate an artifact bundle the static verifier
        // rejects (warnings are reported but do not block)
        let gdg = crate::activity::GroupDepGraph::build(&c.ir, &c.oim);
        let parting = crate::partition::partition_ir(&c.ir, parts, partitioner);
        let report =
            crate::analysis::verify_artifacts(&c.name, &c.ir, &c.oim, &gdg, Some(&parting));
        for diag in &report.diags {
            eprintln!("  {diag}");
        }
        if !report.is_clean() {
            bail!("artifact verification failed — {}", report.summary());
        }
        println!("verify: {}", report.summary());
    }

    if parts_given {
        if backend != "interp" {
            bail!("--parts requires --backend interp (got '{backend}')");
        }
        let cfg = KernelConfig::parse(args.opt_or("kernel", "PSU")).context("bad --kernel")?;
        let toggle = toggle_arg(args, &d, sparse)?;
        // --vcd on a partitioned run streams the selected lanes' *output
        // ports*: internal named slots live in replicated per-partition
        // cones, but partition 0 computes every design output by
        // construction, so the buffered lane output values are globally
        // correct committed state.
        let wave = wave_lanes_arg(args, lanes)?;
        let mut sinks: Vec<crate::sim::WaveSink> = Vec::new();
        if let Some(base) = args.opt("vcd") {
            for &l in &wave {
                sinks.push(crate::sim::WaveSink::create_outputs(
                    &c.ir,
                    l,
                    &lane_vcd_path(base, l, wave.len() > 1),
                )?);
            }
        }
        let mut sim = super::parallel::BatchParallelSim::with_partitioner(
            &c.ir,
            cfg,
            parts,
            lanes,
            sparse,
            partitioner,
        );
        for (slot, lane, value) in d.resolved_lane_init(&c.graph, lanes) {
            sim.poke_lane(slot, lane, value);
        }
        let mut stim = match toggle {
            Some(rate) => d.make_lane_stimulus_toggle(lanes, rate),
            None => d.make_lane_stimulus(lanes),
        };
        let mut obuf: Vec<(String, u64)> = Vec::new();
        let t0 = std::time::Instant::now();
        for cyc in 0..cycles {
            sim.step(&stim(cyc));
            for s in &mut sinks {
                s.sample_parallel(cyc + 1, &sim, &mut obuf)
                    .context("writing VCD waveform (--vcd target)")?;
            }
        }
        let dt = t0.elapsed();
        for s in sinks {
            s.finish()?;
        }
        let aggregate = (cycles as f64 * lanes as f64) / dt.as_secs_f64().max(1e-12);
        println!(
            "{} x{parts} parts x{lanes} lanes [{}]: {cycles} cycles/lane in {} ({:.2} M lane-cyc/s aggregate), replication {:.2}x, cut {} regs / {} pairs",
            cfg.name(),
            partitioner.name(),
            crate::util::fmt_duration(dt),
            aggregate / 1e6,
            sim.replication_factor,
            sim.cut_regs(),
            sim.cut_size()
        );
        if let Some(stats) = sim.activity_stats() {
            println!(
                "  sparse: partition skip-rate {:.1}% ({} of {} partition-cycles stepped)",
                100.0 * stats.skip_rate(),
                stats.stepped_partition_cycles,
                stats.total_partition_cycles
            );
        }
        if let Some(group) = sim.group_stats() {
            println!(
                "  sparse: group skip-rate {:.1}% ({} of {} op-lanes evaluated; \
                 partition-skipped cycles count as skipped op-lanes)",
                100.0 * group.skip_rate(),
                group.evaluated_op_lanes,
                group.total_op_lanes
            );
        }
        for (oname, v) in sim.lane_outputs(0) {
            println!("  lane0 out {oname} = {v:#x}");
        }
        return Ok(());
    }

    if lanes > 1 || sparse {
        if backend != "interp" {
            bail!("--lanes/--sparse require --backend interp (got '{backend}')");
        }
        let cfg = KernelConfig::parse(args.opt_or("kernel", "PSU")).context("bad --kernel")?;
        // validate --toggle and --wave-lanes before paying for kernel
        // construction
        let toggle = toggle_arg(args, &d, sparse)?;
        let wave = wave_lanes_arg(args, lanes)?;
        let mut kernel = if sparse {
            if !crate::kernels::supports_sparse(cfg) {
                bail!(
                    "kernel {} has no sparse batched executor (use NU|PSU|TI)",
                    cfg.name()
                );
            }
            crate::kernels::build_sparse(cfg, &c.ir, &c.oim, lanes)
        } else {
            crate::kernels::build_batch(cfg, &c.ir, &c.oim, lanes)
        };
        d.apply_lane_init(&c.graph, kernel.as_mut());
        // per-lane delta waveforms: one activity-gated sink per selected
        // lane, every named slot of that lane (see crate::sim::wave)
        let mut sinks: Vec<crate::sim::WaveSink> = Vec::new();
        if let Some(base) = args.opt("vcd") {
            for &l in &wave {
                sinks.push(crate::sim::WaveSink::create(
                    &c.ir,
                    kernel.as_ref(),
                    l,
                    &lane_vcd_path(base, l, wave.len() > 1),
                )?);
            }
        }
        let mut stim = match toggle {
            Some(rate) => d.make_lane_stimulus_toggle(lanes, rate),
            None => d.make_lane_stimulus(lanes),
        };
        let t0 = std::time::Instant::now();
        for cyc in 0..cycles {
            kernel.step(&stim(cyc));
            for s in &mut sinks {
                s.sample_kernel(cyc + 1, kernel.as_ref())
                    .context("writing VCD waveform (--vcd target)")?;
            }
        }
        let dt = t0.elapsed();
        for s in sinks {
            s.finish()?;
        }
        let aggregate = (cycles as f64 * lanes as f64) / dt.as_secs_f64().max(1e-12);
        println!(
            "{} x{lanes} lanes: {cycles} cycles/lane in {} ({:.2} M lane-cyc/s aggregate, {:.2} Mcyc/s per lane)",
            cfg.name(),
            crate::util::fmt_duration(dt),
            aggregate / 1e6,
            aggregate / lanes as f64 / 1e6
        );
        if let Some(stats) = kernel.activity_stats() {
            println!(
                "  sparse: skip-rate {:.1}% ({} of {} op-lanes evaluated)",
                100.0 * stats.skip_rate(),
                stats.evaluated_op_lanes,
                stats.total_op_lanes
            );
        }
        for (oname, v) in kernel.lane_outputs(0) {
            println!("  lane0 out {oname} = {v:#x}");
        }
        return Ok(());
    }

    if backend == "parallel" {
        let threads = args.opt_usize("threads", 4)?;
        let cfg = KernelConfig::parse(args.opt_or("kernel", "PSU")).context("bad --kernel")?;
        let mut sim =
            super::parallel::ParallelSim::with_partitioner(&c.ir, cfg, threads, partitioner);
        let mut stim = d.make_stimulus();
        let t0 = std::time::Instant::now();
        for cyc in 0..cycles {
            sim.step(&stim(cyc));
        }
        let dt = t0.elapsed();
        println!(
            "parallel x{threads}: {cycles} cycles in {} ({:.2} Mcyc/s), replication {:.2}x, cut {}",
            crate::util::fmt_duration(dt),
            cycles as f64 / dt.as_secs_f64() / 1e6,
            sim.replication_factor,
            sim.cut_size()
        );
        for (name, v) in sim.outputs() {
            println!("  out {name} = {v:#x}");
        }
        return Ok(());
    }

    let kernel: Box<dyn crate::kernels::SimKernel> = match backend {
        "interp" => {
            let cfg = KernelConfig::parse(args.opt_or("kernel", "PSU")).context("bad --kernel")?;
            crate::kernels::build_with_oim(cfg, &c.ir, &c.oim)
        }
        "verilator" => Box::new(crate::baselines::verilator_like::VerilatorLike::new(&c.ir, false)),
        "essent" => Box::new(crate::baselines::essent_like::EssentLike::new(&c.ir, false)),
        "event" => Box::new(crate::baselines::event_driven::EventDriven::new(&c.ir)),
        other => bail!("unknown backend '{other}'"),
    };
    let name = kernel.config_name();
    let mut sim = Simulator::new(kernel, d.make_stimulus());
    if let Some(vcd) = args.opt("vcd") {
        sim = sim.with_vcd(&c.ir, std::path::Path::new(vcd))?;
    }
    let stats = sim.run(cycles);
    println!(
        "{name}: {cycles} cycles in {} ({:.2} Mcyc/s)",
        crate::util::fmt_duration(stats.wall),
        stats.hz / 1e6
    );
    for (oname, v) in sim.outputs() {
        println!("  out {oname} = {v:#x}");
    }
    sim.finish()?;
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use crate::service::api::{serve_stdio, serve_unix, ServeOpts};
    if args.flag("stdio") && args.opt("socket").is_some() {
        bail!("--stdio and --socket are mutually exclusive");
    }
    let opts = ServeOpts {
        cache_dir: args.opt("cache-dir").map(PathBuf::from),
        cache_cap: args.opt_usize("cache-cap", 8)?,
        timeout_ms: args.opt_u64("timeout-ms", 2_000)?,
        idle_timeout_ms: args.opt_u64("idle-timeout-ms", 30_000)?,
        verify: args.flag("verify"),
    };
    if opts.cache_cap == 0 {
        bail!("--cache-cap must be >= 1 (got 0)");
    }
    match args.opt("socket") {
        Some(path) => serve_unix(std::path::Path::new(path), opts)?,
        None => serve_stdio(opts)?,
    }
    Ok(())
}

fn cmd_xla_sim(args: &Args) -> Result<()> {
    let name = args.require("design")?;
    let d = catalog(name).context("unknown design")?;
    let dir = PathBuf::from(args.opt_or("artifacts", "artifacts"));
    let cycles = args.opt_u64("cycles", 256)?;
    let rt = crate::runtime::pjrt::PjrtRuntime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let mut backend = crate::runtime::XlaBackend::load(&rt, &dir, name)?;
    let mut stim = d.make_stimulus();
    let t0 = std::time::Instant::now();
    backend.run(cycles, |c| stim(c))?;
    let dt = t0.elapsed();
    println!(
        "xla backend: {cycles} cycles in {} ({:.2} kcyc/s, chunk={})",
        crate::util::fmt_duration(dt),
        cycles as f64 / dt.as_secs_f64() / 1e3,
        backend.chunk
    );
    for (oname, v) in backend.outputs() {
        println!("  out {oname} = {v:#x}");
    }
    Ok(())
}

fn cmd_export(args: &Args) -> Result<()> {
    let d = design_arg(args)?;
    let out = args.require("out")?;
    // no mux fusion: the dense tensor ISA has no MuxChain
    let c = compile_design(&d, CompileOpts { fuse: false });
    let dense = export::to_dense(&c.ir, 128)?;
    std::fs::write(out, dense.to_json().to_string())?;
    println!(
        "wrote {out}: slots={} layers={} max_ops={}",
        dense.num_slots, dense.num_layers, dense.max_ops
    );
    Ok(())
}

fn cmd_autotune(args: &Args) -> Result<()> {
    let d = design_arg(args)?;
    let c = compile_design(&d, CompileOpts::default());
    let trial = args.opt_u64("cycles", 500)?;
    let (best, hz) = super::autotune::best_measured(&d, &c, trial);
    println!("best kernel for {}: {} ({:.2} Mcyc/s)", d.name, best.name(), hz / 1e6);
    for m in crate::perf::machine::all_machines() {
        let (cfg, cyc) = super::autotune::best_modeled(&c, &m);
        println!("  modeled best on {:<24} {} ({cyc:.0} core-cyc/sim-cyc)", m.name, cfg.name());
    }
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let ctx = report::Ctx::from_env();
    let id = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let ids: Vec<&str> =
        if id == "all" { report::ALL_EXPERIMENTS.to_vec() } else { vec![id] };
    for id in ids {
        let tables = report::run_experiment(id, &ctx)
            .with_context(|| format!("unknown experiment '{id}'"))?;
        for t in tables {
            println!("{}", t.render());
            if let Ok(p) = t.save_csv(&format!("{id}_{}", t.title.split(' ').next().unwrap_or("t"))) {
                println!("  (csv: {})", p.display());
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    /// The `--lanes 0` underflow and the `--sparse --lanes > 64` mask
    /// overflow are rejected with proper errors instead of panicking or
    /// wrapping in the mask arithmetic.
    #[test]
    fn lane_validation_rejects_mask_underflow_and_overflow() {
        assert!(validate_lanes(0, false).is_err());
        assert!(validate_lanes(0, true).is_err());
        assert!(validate_lanes(1, false).is_ok());
        assert!(validate_lanes(1, true).is_ok());
        assert!(validate_lanes(64, true).is_ok());
        assert!(validate_lanes(65, true).is_err());
        assert!(validate_lanes(65, false).is_ok(), "dense batching has no 64-lane cap");
        let msg = validate_lanes(65, true).unwrap_err().to_string();
        assert!(msg.contains("64"), "error names the cap: {msg}");
    }

    /// `sim --lanes B --sparse` argument shapes parse the way `cmd_sim`
    /// consumes them.
    #[test]
    fn sim_sparse_arguments_parse() {
        let a = Args::parse(&v(&[
            "sim", "--design", "alu32", "--lanes", "8", "--sparse", "--toggle", "0.05",
        ]));
        assert_eq!(a.command, "sim");
        assert!(a.flag("sparse"));
        assert_eq!(a.opt_usize("lanes", 1).unwrap(), 8);
        assert_eq!(a.opt_f64("toggle", 0.0).unwrap(), 0.05);
        assert!(validate_lanes(a.opt_usize("lanes", 1).unwrap(), a.flag("sparse")).is_ok());

        let bad = Args::parse(&v(&["sim", "--design", "alu32", "--lanes", "0"]));
        assert!(validate_lanes(bad.opt_usize("lanes", 1).unwrap(), bad.flag("sparse")).is_err());
        let bad = Args::parse(&v(&["sim", "--design", "alu32", "--lanes", "65", "--sparse"]));
        assert!(validate_lanes(bad.opt_usize("lanes", 1).unwrap(), bad.flag("sparse")).is_err());
    }

    /// `sim --parts P --lanes B [--sparse]` argument shapes parse the way
    /// `cmd_sim` consumes them, and the sparse lane cap still applies to
    /// the partitioned path.
    #[test]
    fn sim_parts_arguments_parse() {
        let a = Args::parse(&v(&[
            "sim", "--design", "gemmini_like_4", "--parts", "4", "--lanes", "8", "--sparse",
        ]));
        assert_eq!(a.command, "sim");
        assert_eq!(a.opt_usize("parts", 1).unwrap(), 4);
        assert_eq!(a.opt_usize("lanes", 1).unwrap(), 8);
        assert!(a.flag("sparse"));
        assert!(validate_lanes(a.opt_usize("lanes", 1).unwrap(), a.flag("sparse")).is_ok());

        // --parts defaults to 1 (the unpartitioned batched path)
        let b = Args::parse(&v(&["sim", "--design", "alu32", "--lanes", "8"]));
        assert_eq!(b.opt_usize("parts", 1).unwrap(), 1);

        // the mask cap binds P x B sparse runs exactly as unpartitioned ones
        let c = Args::parse(&v(&[
            "sim", "--design", "alu32", "--parts", "2", "--lanes", "65", "--sparse",
        ]));
        assert!(validate_lanes(c.opt_usize("lanes", 1).unwrap(), c.flag("sparse")).is_err());
    }

    /// `--partitioner` resolves to a strategy on partitioned runs,
    /// defaults to min-cut, and is rejected on unpartitioned ones.
    #[test]
    fn partitioner_argument_validation() {
        use crate::partition::PartitionerKind;
        let a = Args::parse(&v(&[
            "sim", "--design", "gemmini_like_4", "--parts", "4", "--partitioner", "rr",
        ]));
        assert_eq!(partitioner_arg(&a, true, "interp").unwrap(), PartitionerKind::RoundRobin);

        let b = Args::parse(&v(&["sim", "--design", "gemmini_like_4", "--parts", "4"]));
        assert_eq!(partitioner_arg(&b, true, "interp").unwrap(), PartitionerKind::MinCut);

        let c = Args::parse(&v(&[
            "sim", "--design", "gemmini_like_4", "--partitioner", "mincut",
        ]));
        assert!(partitioner_arg(&c, false, "interp").is_err(), "needs --parts");
        assert_eq!(partitioner_arg(&c, false, "parallel").unwrap(), PartitionerKind::MinCut);

        let d = Args::parse(&v(&[
            "sim", "--design", "gemmini_like_4", "--parts", "2", "--partitioner", "metis",
        ]));
        let msg = partitioner_arg(&d, true, "interp").unwrap_err().to_string();
        assert!(msg.contains("metis"), "error names the bad strategy: {msg}");
    }

    /// `--wave-lanes` parses a validated lane list, defaults to lane 0,
    /// requires `--vcd`, and rejects out-of-range / duplicate /
    /// non-numeric entries with errors naming the offender.
    #[test]
    fn wave_lanes_argument_validation() {
        let a = Args::parse(&v(&[
            "sim", "--design", "fir8", "--lanes", "8", "--vcd", "w.vcd",
            "--wave-lanes", "0,3, 7",
        ]));
        assert_eq!(wave_lanes_arg(&a, 8).unwrap(), vec![0, 3, 7]);

        // plain --vcd (no --wave-lanes) keeps the historical lane-0 meaning
        let b = Args::parse(&v(&["sim", "--design", "fir8", "--lanes", "8", "--vcd", "w.vcd"]));
        assert_eq!(wave_lanes_arg(&b, 8).unwrap(), vec![0]);

        let no_vcd = Args::parse(&v(&[
            "sim", "--design", "fir8", "--lanes", "8", "--wave-lanes", "1",
        ]));
        let msg = wave_lanes_arg(&no_vcd, 8).unwrap_err().to_string();
        assert!(msg.contains("--vcd"), "error points at the missing --vcd: {msg}");

        let oob = Args::parse(&v(&[
            "sim", "--design", "fir8", "--lanes", "4", "--vcd", "w.vcd", "--wave-lanes", "4",
        ]));
        let msg = wave_lanes_arg(&oob, 4).unwrap_err().to_string();
        assert!(msg.contains("out of range"), "{msg}");

        let dup = Args::parse(&v(&[
            "sim", "--design", "fir8", "--lanes", "4", "--vcd", "w.vcd", "--wave-lanes", "2,2",
        ]));
        assert!(wave_lanes_arg(&dup, 4).is_err());

        let junk = Args::parse(&v(&[
            "sim", "--design", "fir8", "--lanes", "4", "--vcd", "w.vcd", "--wave-lanes", "1,x",
        ]));
        let msg = wave_lanes_arg(&junk, 4).unwrap_err().to_string();
        assert!(msg.contains('x'), "error names the bad token: {msg}");
    }

    /// Multi-lane waveform runs get `.laneN` inserted before the
    /// extension; a single selected lane writes the given path verbatim.
    #[test]
    fn lane_vcd_path_naming() {
        assert_eq!(lane_vcd_path("waves.vcd", 3, false), PathBuf::from("waves.vcd"));
        assert_eq!(lane_vcd_path("waves.vcd", 3, true), PathBuf::from("waves.lane3.vcd"));
        assert_eq!(
            lane_vcd_path("out/dir/w.vcd", 0, true),
            PathBuf::from("out/dir/w.lane0.vcd")
        );
        assert_eq!(lane_vcd_path("noext", 2, true), PathBuf::from("noext.lane2"));
    }
}
