//! CLI command routing (the leader entrypoint's verbs).

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use super::compile::{compile_design, CompileOpts};
use super::report;
use crate::designs::catalog;
use crate::kernels::{BatchKernel as _, KernelConfig};
use crate::sim::Simulator;
use crate::tensor::export;
use crate::util::cli::Args;
use crate::util::fmt_bytes;

const USAGE: &str = "\
rteaal — RTL simulation as sparse tensor algebra (paper reproduction)

USAGE: rteaal <command> [options]

COMMANDS:
  help                         this text
  designs                      list available designs
  compile   --design D         compile D; print graph/OIM/format statistics
            [--emit-oim F]     also write the OIM tensors as JSON (paper §6.1)
            [--emit-fir F]     also write the design as FIRRTL text
  sim       --design D         simulate D
            [--kernel K]       RU|OU|NU|PSU|IU|SU|TI (default PSU)
            [--backend B]      interp|verilator|essent|event|parallel (default interp)
            [--threads N]      partitions for --backend parallel
            [--lanes B]        lane-batched run: B decorrelated stimulus
                               lanes per OIM walk (kernels RU|NU|PSU|TI);
                               reports aggregate lane-cycles/sec
            [--cycles N]       cycle count (default: design default)
            [--vcd F]          write waveforms
  xla-sim   --design D         simulate via the AOT XLA/PJRT artifact
            [--artifacts DIR]  artifact directory (default: artifacts)
            [--cycles N]
  export-tensors --design D --out F
                               write the dense tensor encoding for aot.py
  autotune  --design D         trial-run all kernels, report the best
  report    <id>|all           regenerate paper tables/figures
                               (set RTEAAL_FULL=1 for full-length runs)
";

pub fn run(args: Args) -> Result<()> {
    match args.command.as_str() {
        "" | "help" => {
            println!("{USAGE}");
            Ok(())
        }
        "designs" => {
            println!("built-in designs:");
            for name in crate::designs::main_eval_designs() {
                let d = catalog(name).unwrap();
                println!(
                    "  {name:<18} ops={:<7} regs={:<5} default_cycles={}",
                    d.graph.num_ops(),
                    d.graph.regs.len(),
                    d.default_cycles
                );
            }
            println!("  (+ counter, alu32, fir8, rocket_like_Nc, boom_like_Nc, gemmini_like_N, rocket_like_xs)");
            Ok(())
        }
        "compile" => cmd_compile(&args),
        "sim" => cmd_sim(&args),
        "xla-sim" => cmd_xla_sim(&args),
        "export-tensors" => cmd_export(&args),
        "autotune" => cmd_autotune(&args),
        "report" => cmd_report(&args),
        other => bail!("unknown command '{other}' (see `rteaal help`)"),
    }
}

fn design_arg(args: &Args) -> Result<crate::designs::Design> {
    let name = args.require("design")?;
    catalog(name).with_context(|| format!("unknown design '{name}' (see `rteaal designs`)"))
}

fn cmd_compile(args: &Args) -> Result<()> {
    let d = design_arg(args)?;
    let c = compile_design(&d, CompileOpts::default());
    println!("design       {}", c.name);
    println!("compile time {}", crate::util::fmt_duration(c.compile_time));
    println!("peak heap    {}", fmt_bytes(c.peak_heap));
    let s = c.graph.stats();
    println!("nodes={} ops={} regs={} inputs={} outputs={}", s.nodes, s.ops, s.regs, s.inputs, s.outputs);
    println!("layers (I)   {}", c.ir.depth());
    println!("identity ops {} (elided)", c.ir.identity_ops);
    let oimt = crate::einsum::OimTensor::from_ir(&c.ir);
    println!("OIM density  {:.3e}", oimt.density());
    for spec in [c.oim.format_a(), c.oim.format_b(), c.oim.format_c()] {
        println!("{}", spec.render());
    }
    if let Some(path) = args.opt("emit-oim") {
        std::fs::write(path, c.oim.to_json().to_string())?;
        println!("wrote OIM JSON to {path}");
    }
    if let Some(path) = args.opt("emit-fir") {
        std::fs::write(path, crate::firrtl::print(&c.graph))?;
        println!("wrote FIRRTL to {path}");
    }
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    let d = design_arg(args)?;
    let cycles = args.opt_u64("cycles", d.default_cycles)?;
    let backend = args.opt_or("backend", "interp");
    let lanes = args.opt_usize("lanes", 1)?;
    if lanes == 0 {
        bail!("--lanes must be >= 1");
    }
    let c = compile_design(&d, CompileOpts { fuse: args.opt("vcd").is_none() });

    if lanes > 1 {
        if backend != "interp" {
            bail!("--lanes requires --backend interp (got '{backend}')");
        }
        if args.opt("vcd").is_some() {
            bail!("--lanes does not support --vcd (waveforms are per-lane)");
        }
        let cfg = KernelConfig::parse(args.opt_or("kernel", "PSU")).context("bad --kernel")?;
        if !crate::kernels::supports_batch(cfg) {
            bail!(
                "kernel {} has no lane-batched executor (use RU|NU|PSU|TI)",
                cfg.name()
            );
        }
        let mut kernel = crate::kernels::build_batch(cfg, &c.ir, &c.oim, lanes);
        let mut stim = d.make_lane_stimulus(lanes);
        let t0 = std::time::Instant::now();
        for cyc in 0..cycles {
            kernel.step(&stim(cyc));
        }
        let dt = t0.elapsed();
        let aggregate = (cycles as f64 * lanes as f64) / dt.as_secs_f64().max(1e-12);
        println!(
            "{} x{lanes} lanes: {cycles} cycles/lane in {} ({:.2} M lane-cyc/s aggregate, {:.2} Mcyc/s per lane)",
            cfg.name(),
            crate::util::fmt_duration(dt),
            aggregate / 1e6,
            aggregate / lanes as f64 / 1e6
        );
        for (oname, v) in kernel.lane_outputs(0) {
            println!("  lane0 out {oname} = {v:#x}");
        }
        return Ok(());
    }

    if backend == "parallel" {
        let threads = args.opt_usize("threads", 4)?;
        let cfg = KernelConfig::parse(args.opt_or("kernel", "PSU")).context("bad --kernel")?;
        let mut sim = super::parallel::ParallelSim::new(&c.ir, cfg, threads);
        let mut stim = d.make_stimulus();
        let t0 = std::time::Instant::now();
        for cyc in 0..cycles {
            sim.step(&stim(cyc));
        }
        let dt = t0.elapsed();
        println!(
            "parallel x{threads}: {cycles} cycles in {} ({:.2} Mcyc/s), replication {:.2}x, cut {}",
            crate::util::fmt_duration(dt),
            cycles as f64 / dt.as_secs_f64() / 1e6,
            sim.replication_factor,
            sim.cut_size()
        );
        for (name, v) in sim.outputs() {
            println!("  out {name} = {v:#x}");
        }
        return Ok(());
    }

    let kernel: Box<dyn crate::kernels::SimKernel> = match backend {
        "interp" => {
            let cfg = KernelConfig::parse(args.opt_or("kernel", "PSU")).context("bad --kernel")?;
            crate::kernels::build_with_oim(cfg, &c.ir, &c.oim)
        }
        "verilator" => Box::new(crate::baselines::verilator_like::VerilatorLike::new(&c.ir, false)),
        "essent" => Box::new(crate::baselines::essent_like::EssentLike::new(&c.ir, false)),
        "event" => Box::new(crate::baselines::event_driven::EventDriven::new(&c.ir)),
        other => bail!("unknown backend '{other}'"),
    };
    let name = kernel.config_name();
    let mut sim = Simulator::new(kernel, d.make_stimulus());
    if let Some(vcd) = args.opt("vcd") {
        sim = sim.with_vcd(&c.ir, std::path::Path::new(vcd))?;
    }
    let stats = sim.run(cycles);
    println!(
        "{name}: {cycles} cycles in {} ({:.2} Mcyc/s)",
        crate::util::fmt_duration(stats.wall),
        stats.hz / 1e6
    );
    for (oname, v) in sim.outputs() {
        println!("  out {oname} = {v:#x}");
    }
    sim.finish()?;
    Ok(())
}

fn cmd_xla_sim(args: &Args) -> Result<()> {
    let name = args.require("design")?;
    let d = catalog(name).context("unknown design")?;
    let dir = PathBuf::from(args.opt_or("artifacts", "artifacts"));
    let cycles = args.opt_u64("cycles", 256)?;
    let rt = crate::runtime::pjrt::PjrtRuntime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let mut backend = crate::runtime::XlaBackend::load(&rt, &dir, name)?;
    let mut stim = d.make_stimulus();
    let t0 = std::time::Instant::now();
    backend.run(cycles, |c| stim(c))?;
    let dt = t0.elapsed();
    println!(
        "xla backend: {cycles} cycles in {} ({:.2} kcyc/s, chunk={})",
        crate::util::fmt_duration(dt),
        cycles as f64 / dt.as_secs_f64() / 1e3,
        backend.chunk
    );
    for (oname, v) in backend.outputs() {
        println!("  out {oname} = {v:#x}");
    }
    Ok(())
}

fn cmd_export(args: &Args) -> Result<()> {
    let d = design_arg(args)?;
    let out = args.require("out")?;
    // no mux fusion: the dense tensor ISA has no MuxChain
    let c = compile_design(&d, CompileOpts { fuse: false });
    let dense = export::to_dense(&c.ir, 128)?;
    std::fs::write(out, dense.to_json().to_string())?;
    println!(
        "wrote {out}: slots={} layers={} max_ops={}",
        dense.num_slots, dense.num_layers, dense.max_ops
    );
    Ok(())
}

fn cmd_autotune(args: &Args) -> Result<()> {
    let d = design_arg(args)?;
    let c = compile_design(&d, CompileOpts::default());
    let trial = args.opt_u64("cycles", 500)?;
    let (best, hz) = super::autotune::best_measured(&d, &c, trial);
    println!("best kernel for {}: {} ({:.2} Mcyc/s)", d.name, best.name(), hz / 1e6);
    for m in crate::perf::machine::all_machines() {
        let (cfg, cyc) = super::autotune::best_modeled(&c, &m);
        println!("  modeled best on {:<24} {} ({cyc:.0} core-cyc/sim-cyc)", m.name, cfg.name());
    }
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let ctx = report::Ctx::from_env();
    let id = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let ids: Vec<&str> =
        if id == "all" { report::ALL_EXPERIMENTS.to_vec() } else { vec![id] };
    for id in ids {
        let tables = report::run_experiment(id, &ctx)
            .with_context(|| format!("unknown experiment '{id}'"))?;
        for t in tables {
            println!("{}", t.render());
            if let Ok(p) = t.save_csv(&format!("{id}_{}", t.title.split(' ').next().unwrap_or("t"))) {
                println!("  (csv: {})", p.display());
            }
        }
    }
    Ok(())
}
