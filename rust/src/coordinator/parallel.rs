//! RepCut-style partitioned multi-threaded simulation (paper Cascade 2,
//! Appendix C).
//!
//! The graph's registers are partitioned; each partition owns the
//! transitive fan-in cone of its registers' next-state logic (logic read
//! by several partitions is *replicated*, which decouples partitions
//! within a cycle — the replication overhead RepCut pays for superlinear
//! scaling). At the end of each cycle, the **RUM** (register update map)
//! propagates each committed register value to the partitions that read
//! it — Cascade 2's final Einsum `LI_{c+1} = LI_c · RUM`.

use std::collections::BTreeSet;

use crate::kernels::{self, KernelConfig, SimKernel};
use crate::tensor::ir::LayerIr;

/// One partition: a filtered LayerIr + its kernel.
struct Partition {
    kernel: Box<dyn SimKernel>,
    /// registers owned (committed) by this partition
    #[allow(dead_code)]
    owned_regs: Vec<u32>,
}

/// RUM entry: a register committed by `owner`, read by `readers`.
struct RumEntry {
    owner: usize,
    reg_slot: u32,
    readers: Vec<usize>,
}

pub struct ParallelSim {
    parts: Vec<Partition>,
    rum: Vec<RumEntry>,
    outputs: Vec<(String, u32)>,
    /// partition that computes each output (partition 0 by construction)
    pub replication_factor: f64,
}

impl ParallelSim {
    /// Partition `ir` into `n` pieces and build one kernel per piece.
    pub fn new(ir: &LayerIr, cfg: KernelConfig, n: usize) -> Self {
        assert!(n >= 1);
        // 1. assign registers round-robin (RepCut uses hypergraph
        //    partitioning; round-robin keeps this substrate simple while
        //    exercising the same replication/sync machinery)
        let n_regs = ir.commits.len();
        let owner_of_reg: Vec<usize> = (0..n_regs).map(|i| i % n).collect();

        // 2. compute each partition's cone: ops needed for its registers'
        //    next-state (+ partition 0 also owns the design outputs)
        let mut writer_of_slot: Vec<Option<(usize, usize)>> = vec![None; ir.num_slots];
        for (li, layer) in ir.layers.iter().enumerate() {
            for (oi, rec) in layer.iter().enumerate() {
                writer_of_slot[rec.out as usize] = Some((li, oi));
            }
        }
        let mut parts = Vec::with_capacity(n);
        let mut total_kept = 0usize;
        let mut needed_regs_per_part: Vec<BTreeSet<u32>> = vec![BTreeSet::new(); n];
        for p in 0..n {
            let mut keep: Vec<BTreeSet<usize>> = vec![BTreeSet::new(); ir.layers.len()];
            let mut stack: Vec<u32> = Vec::new();
            for (ri, c) in ir.commits.iter().enumerate() {
                if owner_of_reg[ri] == p {
                    stack.push(c.1);
                }
            }
            if p == 0 {
                for (_, s) in &ir.output_slots {
                    stack.push(*s);
                }
            }
            let mut visited = vec![false; ir.num_slots];
            while let Some(slot) = stack.pop() {
                if visited[slot as usize] {
                    continue;
                }
                visited[slot as usize] = true;
                if let Some((li, oi)) = writer_of_slot[slot as usize] {
                    keep[li].insert(oi);
                    let rec = &ir.layers[li][oi];
                    for r in crate::tensor::oim::operand_slots(rec, &ir.ext_args) {
                        stack.push(r);
                    }
                } else {
                    // a source slot: if it's a register, partition p reads it
                    needed_regs_per_part[p].insert(slot);
                }
            }
            // filtered ir
            let mut pir = ir.clone();
            pir.layers = ir
                .layers
                .iter()
                .enumerate()
                .map(|(li, layer)| {
                    keep[li].iter().map(|&oi| layer[oi]).collect::<Vec<_>>()
                })
                .collect();
            pir.commits = ir
                .commits
                .iter()
                .enumerate()
                .filter(|(ri, _)| owner_of_reg[*ri] == p)
                .map(|(_, c)| *c)
                .collect();
            if p != 0 {
                pir.output_slots = Vec::new();
            }
            total_kept += pir.total_ops();
            let oim = crate::tensor::oim::Oim::from_ir(&pir);
            let kernel = kernels::build_with_oim(cfg, &pir, &oim);
            parts.push(Partition {
                kernel,
                owned_regs: pir.commits.iter().map(|c| c.0).collect(),
            });
        }

        // 3. RUM: for each register, which partitions read it
        let mut rum = Vec::new();
        for (ri, c) in ir.commits.iter().enumerate() {
            let owner = owner_of_reg[ri];
            let readers: Vec<usize> = (0..n)
                .filter(|&p| p != owner && needed_regs_per_part[p].contains(&c.0))
                .collect();
            if !readers.is_empty() {
                rum.push(RumEntry { owner, reg_slot: c.0, readers });
            }
        }

        let replication_factor = total_kept as f64 / ir.total_ops().max(1) as f64;
        ParallelSim { parts, rum, outputs: ir.output_slots.clone(), replication_factor }
    }

    /// One cycle: partitions evaluate + commit concurrently, then the RUM
    /// synchronization step exchanges committed register values.
    pub fn step(&mut self, inputs: &[u64]) {
        if self.parts.len() == 1 {
            self.parts[0].kernel.step(inputs);
            return;
        }
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for part in &mut self.parts {
                let inputs = inputs.to_vec();
                handles.push(scope.spawn(move || part.kernel.step(&inputs)));
            }
            for h in handles {
                h.join().expect("partition thread panicked");
            }
        });
        // RUM exchange (differential: only changed values cross partitions)
        for entry in &self.rum {
            let v = self.parts[entry.owner].kernel.slots()[entry.reg_slot as usize];
            for &r in &entry.readers {
                if self.parts[r].kernel.slots()[entry.reg_slot as usize] != v {
                    self.parts[r].kernel.poke(entry.reg_slot, v);
                }
            }
        }
    }

    pub fn outputs(&self) -> Vec<(String, u64)> {
        let v = self.parts[0].kernel.slots();
        self.outputs.iter().map(|(n, s)| (n.clone(), v[*s as usize])).collect()
    }

    pub fn num_partitions(&self) -> usize {
        self.parts.len()
    }

    /// Registers whose values cross partitions each cycle.
    pub fn cut_size(&self) -> usize {
        self.rum.iter().map(|e| e.readers.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::designs::catalog;
    use crate::graph::passes::optimize;
    use crate::tensor::ir::lower;

    #[test]
    fn partitioned_sim_matches_single_threaded() {
        let d = catalog("rocket_like_1c").unwrap();
        let (opt, _) = optimize(&d.graph);
        let ir = lower(&opt);
        let mut single = crate::kernels::build(KernelConfig::PSU, &ir);
        for n in [2usize, 4] {
            let mut par = ParallelSim::new(&ir, KernelConfig::PSU, n);
            assert!(par.replication_factor >= 1.0);
            let mut stim = d.make_stimulus();
            let mut single_fresh = crate::kernels::build(KernelConfig::PSU, &ir);
            for c in 0..30u64 {
                let inputs = stim(c);
                single_fresh.step(&inputs);
                par.step(&inputs);
                assert_eq!(par.outputs(), single_fresh.outputs(), "n={n} cycle={c}");
            }
        }
        let _ = &mut single;
    }

    #[test]
    fn keccak_partitioned_runs_correct_permutation() {
        use crate::designs::keccak;
        let g = keccak::keccak_round_datapath();
        let (opt, _) = optimize(&g);
        let ir = lower(&opt);
        let mut par = ParallelSim::new(&ir, KernelConfig::TI, 3);
        let ins: [u64; 5] = [1, 2, 3, 4, 5];
        let mut golden = [[0u64; 5]; 5];
        for x in 0..5 {
            for y in 0..5 {
                golden[x][y] = ins[x].rotate_left((y * 7) as u32) ^ y as u64;
            }
        }
        keccak::keccak_f_sw(&mut golden);
        let mut load = vec![1u64, 0];
        load.extend_from_slice(&ins);
        par.step(&load);
        let mut go = vec![0u64, 1, 0, 0, 0, 0, 0];
        for _ in 0..24 {
            par.step(&mut go.clone());
        }
        let outs: std::collections::HashMap<String, u64> = par.outputs().into_iter().collect();
        assert_eq!(outs["lane00"], golden[0][0]);
        assert_eq!(outs["lane44"], golden[4][4]);
    }
}
