//! RepCut-style partitioned multi-threaded simulation (paper Cascade 2,
//! Appendix C), composed with data-level lane batching.
//!
//! The graph's registers are partitioned ([`crate::partition`]); each
//! partition owns the transitive fan-in cone of its registers'
//! next-state logic (logic read by several partitions is *replicated*,
//! which decouples partitions within a cycle — the replication overhead
//! RepCut pays for superlinear scaling). At the end of each cycle, the
//! **RUM** (register update map) propagates each committed register
//! value to the partitions that read it — Cascade 2's final Einsum
//! `LI_{c+1} = LI_c · RUM`. Ownership comes from a selectable
//! [`PartitionerKind`]: multilevel hypergraph min-cut by default
//! (shrinking the RUM cut), round-robin as the scatter baseline.
//!
//! [`BatchParallelSim`] generalizes the whole machinery over `B` stimulus
//! lanes: each partition holds one **lane-batched** kernel
//! ([`crate::kernels::BatchKernel`], lane-major `slots[s * B + lane]`),
//! and the RUM step moves `B` lanes of every cut register per cycle —
//! thread-level (partitions `P`) × data-level (lanes `B`) parallelism in
//! one run. The per-partition kernels run their lane loops through the
//! explicit `[u64; 8]` tile primitives ([`crate::kernels::tile`]), so
//! SIMD tiles × threads × (optional) sparsity compose in a single run;
//! [`BatchParallelSim::with_partitioner_baseline`] swaps in the pre-tile
//! per-partition kernels for the tiled-vs-autovec sweep points. The
//! scalar [`ParallelSim`] is a thin `B = 1` wrapper.
//!
//! The cycle loop runs on a **persistent worker pool**
//! ([`super::pool::WorkerPool`]): `P - 1` workers are spawned once at
//! construction and parked on a barrier between cycles, the coordinator
//! thread steps partition 0 and runs the RUM exchange — no per-cycle
//! thread spawns (the old `thread::scope`-per-cycle cost that dominated
//! small designs).
//!
//! With `sparse = true` the run additionally keeps **per-partition lane
//! activity masks over the RUM cut**
//! ([`crate::activity::PartitionTracker`]): a partition is skipped for a
//! cycle when no input port its cone reads changed in any lane and no
//! register it reads changed at the last commit. Skipping is exact —
//! a quiescent partition's slot file (including the registers it would
//! commit) is already identical to what stepping would produce — so
//! sparse partitioned runs are bit-identical to dense ones.
//!
//! Sparse mode composes **both activity levels** when the kernel
//! configuration has a sparse executor ([`crate::kernels::SPARSE_KERNELS`]):
//! each partition then runs its group-masked sparse kernel, and the
//! differential RUM exchange feeds every destination partition's group
//! tracker its per-register per-lane change bits through the targeted
//! [`crate::kernels::BatchKernel::poke_lane`] — quiescent partitions are
//! skipped whole, quiescent groups are skipped inside the partitions
//! that do step, and no out-of-band write recolds anything
//! ([`BatchParallelSim::group_stats`] reports the composed op-lane skip
//! rate). Out-of-band [`BatchParallelSim::poke_lane`] writes are equally
//! targeted at the
//! partition level: they wake only the poked slot's reader partitions
//! (plus its owner, whose next commit must overwrite the poke exactly as
//! a dense run's would), in the poked lane only.

use std::collections::HashMap;

use super::pool::WorkerPool;
use crate::activity::{ActivityStats, PartitionActivity, PartitionTracker};
use crate::graph::ops::mask;
use crate::kernels::{self, KernelConfig};
use crate::partition::{partition_ir, PartitionerKind, Partitioning, TrackedReg};
use crate::tensor::ir::LayerIr;

/// Full dynamic state of a [`BatchParallelSim`] — everything `step`
/// reads or writes besides the static compile artifacts, captured by
/// [`BatchParallelSim::export_state`] and re-applied bit-identically by
/// [`BatchParallelSim::import_state`]. The simulator this is restored
/// into must come from the same design, partitioning, kernel
/// configuration, lane count and sparse flag (the service layer keys
/// snapshots by the design-cache hash to enforce this; `import_state`
/// still validates every buffer shape so a mismatched or corrupted
/// snapshot is a structured error, never a panic or silent corruption).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SimState {
    /// cycles stepped when the snapshot was taken
    pub cycles_total: u64,
    pub lanes: usize,
    /// per-partition lane-major slot files
    pub part_slots: Vec<Vec<u64>>,
    /// per-partition kernel activity dumps (empty for dense kernels)
    pub part_activity: Vec<Vec<u64>>,
    /// lane-major RUM shadow of every tracked register
    pub shadow: Vec<u64>,
    /// previous cycle's masked stimulus (sparse boundary detection)
    pub prev_inputs: Vec<u64>,
    /// partition-tracker dump (empty on dense runs)
    pub tracker_state: Vec<u64>,
    /// per-tracked-register poke-dirty flags (see the RUM fast-skip)
    pub poke_dirty: Vec<bool>,
}

/// Partitioned **and** lane-batched simulation: `P` thread-level
/// partitions, each running a lane-batched kernel over `B` stimulus
/// lanes, synchronized by a `B`-lane RUM exchange each cycle. Optionally
/// sparse (per-partition activity masks over the RUM cut, `B ≤ 64`).
pub struct BatchParallelSim {
    pool: WorkerPool,
    /// registers owned (committed) by each partition
    owned: Vec<Vec<u32>>,
    tracked: Vec<TrackedReg>,
    lanes: usize,
    outputs: Vec<(String, u32)>,
    /// replicated-ops / total-ops (RepCut's replication overhead)
    pub replication_factor: f64,
    /// which ownership strategy produced this partitioning
    partitioner: PartitionerKind,
    /// owning partition per committed register slot
    owner_of_slot: HashMap<u32, usize>,
    /// lane-major shadow of every tracked register's last seen values
    /// (`shadow[t * B + lane]`), driving the differential RUM exchange
    shadow: Vec<u64>,
    /// scratch for one register's lane values during the exchange
    scratch: Vec<u64>,
    /// per-cycle "step this partition" flags handed to the pool
    active: Vec<bool>,
    /// sparse mode: the per-partition activity tracker
    tracker: Option<PartitionTracker>,
    /// sparse mode with a [`kernels::SPARSE_KERNELS`] configuration: the
    /// per-partition kernels are group-masked sparse executors
    group_sparse: bool,
    /// per-partition cone op counts (replication included) — the
    /// group-level skip accounting's denominator
    part_ops: Vec<u64>,
    /// cycles stepped so far
    cycles_total: u64,
    /// tracked registers whose shadow was overwritten by an out-of-band
    /// poke since their last RUM lane scan: the next commit may *revert*
    /// the poke without the register's writer group running, so the
    /// fast-skip must not trust `writer_active_lanes` until a scan has
    /// reconciled shadow and slot file
    poke_dirty: Vec<bool>,
    /// RUM lane scans actually performed (one per tracked register per
    /// cycle that wasn't skipped) — the fast-skip's effectiveness metric
    exchange_visits: u64,
    /// partitions whose cones read each boundary slot (targeted poke wake)
    slot_readers: HashMap<u32, Vec<u32>>,
    /// previous cycle's (masked) stimulus, for boundary change detection
    prev_inputs: Vec<u64>,
    input_changed: Vec<u64>,
    input_masks: Vec<u64>,
    num_inputs: usize,
    /// lanes in which partition 0's slot file — hence any design output —
    /// may have changed during the last step ([`Self::wave_changed`])
    wave_live: u64,
    /// an out-of-band write (`poke_lane` / `import_state`) bypassed the
    /// `wave_live` accounting; the next step reports every lane changed
    wave_dirty: bool,
}

impl BatchParallelSim {
    /// Partition `ir` into `n` pieces under the default (min-cut)
    /// partitioner and build one `lanes`-wide batched kernel of
    /// configuration `cfg` per piece. `sparse` enables the per-partition
    /// activity masks (requires `lanes ≤ 64`).
    pub fn new(ir: &LayerIr, cfg: KernelConfig, n: usize, lanes: usize, sparse: bool) -> Self {
        Self::with_partitioner(ir, cfg, n, lanes, sparse, PartitionerKind::default())
    }

    /// [`Self::new`] with an explicit register-ownership strategy.
    pub fn with_partitioner(
        ir: &LayerIr,
        cfg: KernelConfig,
        n: usize,
        lanes: usize,
        sparse: bool,
        partitioner: PartitionerKind,
    ) -> Self {
        Self::build(ir, cfg, n, lanes, sparse, partitioner, false)
    }

    /// [`Self::with_partitioner`] with pre-tile (auto-vectorized baseline)
    /// per-partition kernels ([`kernels::build_batch_baseline`]) — the
    /// tiled-vs-baseline comparison point of `benches/fig24_parts_lanes.rs`
    /// and the partitioned remainder-lane differential tests. Dense only:
    /// the sparse executors have no baseline variant (their partial-mask
    /// path is bit-iterated either way), so `sparse` baseline runs keep
    /// tiled full-mask bodies.
    pub fn with_partitioner_baseline(
        ir: &LayerIr,
        cfg: KernelConfig,
        n: usize,
        lanes: usize,
        partitioner: PartitionerKind,
    ) -> Self {
        Self::build(ir, cfg, n, lanes, false, partitioner, true)
    }

    /// Build from a precomputed [`Partitioning`] instead of re-running
    /// the partitioner — the service design cache's replay path: a cached
    /// ownership map replayed through
    /// [`crate::partition::FixedOwners`] reproduces the partitioning with
    /// the cheap cone-walk passes only, skipping the min-cut search at
    /// session-open time. `partitioner` only labels where the ownership
    /// originally came from ([`Self::partitioner`]).
    pub fn with_partitioning(
        ir: &LayerIr,
        cfg: KernelConfig,
        parting: Partitioning,
        lanes: usize,
        sparse: bool,
        partitioner: PartitionerKind,
    ) -> Self {
        Self::build_from(ir, cfg, parting, lanes, sparse, partitioner, false)
    }

    #[allow(clippy::too_many_arguments)]
    fn build(
        ir: &LayerIr,
        cfg: KernelConfig,
        n: usize,
        lanes: usize,
        sparse: bool,
        partitioner: PartitionerKind,
        baseline: bool,
    ) -> Self {
        let parting = partition_ir(ir, n, partitioner);
        Self::build_from(ir, cfg, parting, lanes, sparse, partitioner, baseline)
    }

    #[allow(clippy::too_many_arguments)]
    fn build_from(
        ir: &LayerIr,
        cfg: KernelConfig,
        parting: Partitioning,
        lanes: usize,
        sparse: bool,
        partitioner: PartitionerKind,
        baseline: bool,
    ) -> Self {
        assert!(lanes >= 1, "lanes must be >= 1");
        let n = parting.num_partitions();
        // sparse mode runs group-masked sparse executors inside the
        // partitions whenever the configuration has one; group-free
        // configurations keep dense kernels and get partition-level
        // skipping only
        let group_sparse = sparse && kernels::supports_sparse(cfg);
        let mut kernel_boxes = Vec::with_capacity(n);
        let mut owned = Vec::with_capacity(n);
        let mut part_ops = Vec::with_capacity(n);
        for pir in &parting.part_irs {
            let oim = crate::tensor::oim::Oim::from_ir(pir);
            kernel_boxes.push(if group_sparse {
                kernels::build_sparse(cfg, pir, &oim, lanes)
            } else if baseline {
                kernels::build_batch_baseline(cfg, pir, &oim, lanes)
            } else {
                kernels::build_batch(cfg, pir, &oim, lanes)
            });
            owned.push(pir.commits.iter().map(|c| c.0).collect::<Vec<u32>>());
            part_ops.push(pir.total_ops() as u64);
        }
        let mut owner_of_slot = HashMap::new();
        for (p, regs) in owned.iter().enumerate() {
            for &slot in regs {
                owner_of_slot.insert(slot, p);
            }
        }
        let init = ir.initial_slots();
        let mut shadow = vec![0u64; parting.tracked.len() * lanes];
        for (t, entry) in parting.tracked.iter().enumerate() {
            for l in 0..lanes {
                shadow[t * lanes + l] = init[entry.reg_slot as usize];
            }
        }
        let num_inputs = ir.input_slots.len();
        let tracker = if sparse {
            Some(PartitionTracker::for_partitioning(&parting, lanes))
        } else {
            None
        };
        let num_tracked = parting.tracked.len();
        BatchParallelSim {
            pool: WorkerPool::new(kernel_boxes),
            owned,
            tracked: parting.tracked,
            lanes,
            outputs: ir.output_slots.clone(),
            replication_factor: parting.replication_factor,
            partitioner,
            owner_of_slot,
            shadow,
            scratch: vec![0u64; lanes],
            active: vec![true; n],
            tracker,
            group_sparse,
            part_ops,
            cycles_total: 0,
            poke_dirty: vec![false; num_tracked],
            exchange_visits: 0,
            slot_readers: parting.readers_of_slot,
            prev_inputs: vec![0u64; num_inputs * lanes],
            input_changed: vec![0u64; num_inputs],
            input_masks: ir.input_widths.iter().map(|&w| mask(w)).collect(),
            num_inputs,
            wave_live: 0,
            wave_dirty: false,
        }
    }

    /// One cycle for every lane: (active) partitions evaluate + commit
    /// concurrently on the persistent pool, then the RUM synchronization
    /// step exchanges the lanes of each committed cut register that
    /// actually changed. `inputs` is lane-major
    /// (`inputs[i * lanes + lane]`), as for
    /// [`crate::kernels::BatchKernel::step`].
    pub fn step(&mut self, inputs: &[u64]) {
        debug_assert_eq!(inputs.len(), self.num_inputs * self.lanes);
        self.cycles_total += 1;
        // 1. sparse: boundary input change detection vs the previous cycle
        if self.tracker.is_some() {
            for i in 0..self.num_inputs {
                let m = self.input_masks[i];
                let base = i * self.lanes;
                let mut ch = 0u64;
                for l in 0..self.lanes {
                    let nv = inputs[base + l] & m;
                    if self.prev_inputs[base + l] != nv {
                        self.prev_inputs[base + l] = nv;
                        ch |= 1u64 << l;
                    }
                }
                self.input_changed[i] = ch;
            }
        }
        if let Some(tracker) = &mut self.tracker {
            tracker.begin_cycle(&self.input_changed);
        }

        // 2. step the active partitions on the persistent pool (a
        //    quiescent partition is skipped entirely)
        for p in 0..self.active.len() {
            self.active[p] = match &self.tracker {
                Some(t) => t.is_active(p),
                None => true,
            };
        }
        self.pool.step(inputs, &self.active);

        // 3. RUM exchange (differential: only changed lanes cross
        //    partitions), feeding next cycle's activity masks
        let sparse = self.tracker.is_some();
        // lanes in which a cut register was poked into partition 0 this
        // cycle — those pokes change partition 0's slot file *after* it
        // stepped, so the waveform-lane accounting below must include them
        let mut rum_poked0 = 0u64;
        for t_idx in 0..self.tracked.len() {
            let entry = &self.tracked[t_idx];
            if !sparse && entry.rum_readers.is_empty() {
                continue; // only the owner reads it: nothing to move
            }
            if let Some(t) = &self.tracker {
                // a skipped owner did not commit, so its registers
                // provably hold their previous values (RUM pokes only
                // write *non-owned* slots): skip the whole lane scan
                if !t.is_active(entry.owner) {
                    continue;
                }
            }
            // fast-skip: the owner stepped, but if the group computing
            // this register's next-state value ran in no lane, the commit
            // just rewrote the old value — the lane scan cannot find a
            // change. Not valid while a poke-dirty flag is up: an
            // out-of-band poke moved the shadow (and slot files) to the
            // poked value, and the next commit may *revert* it without
            // the writer group running, so one reconciling scan must
            // happen first. `None` (dense kernel, or no writer group)
            // means no proof — scan.
            if self.group_sparse
                && !self.poke_dirty[t_idx]
                && self.pool.kernel(entry.owner).writer_active_lanes(entry.reg_slot) == Some(0)
            {
                continue;
            }
            self.exchange_visits += 1;
            self.poke_dirty[t_idx] = false;
            let b = self.lanes;
            let base = entry.reg_slot as usize * b;
            self.scratch
                .copy_from_slice(&self.pool.kernel(entry.owner).slots()[base..base + b]);
            let sh = t_idx * b;
            let mut changed = 0u64;
            for l in 0..b {
                if self.shadow[sh + l] != self.scratch[l] {
                    self.shadow[sh + l] = self.scratch[l];
                    if sparse {
                        changed |= 1u64 << l;
                    }
                    for &r in &entry.rum_readers {
                        if r == 0 {
                            rum_poked0 |= 1u64 << l;
                        }
                        self.pool.kernel_mut(r as usize).poke_lane(
                            entry.reg_slot,
                            l,
                            self.scratch[l],
                        );
                    }
                }
            }
            if changed != 0 {
                if let Some(tr) = &mut self.tracker {
                    tr.note_reg_change(&entry.readers, changed);
                }
            }
        }

        // 4. waveform-lane accounting (sparse only): a lane's design
        //    outputs can only differ from the previous cycle when
        //    partition 0 was active in it (its cone's boundary changed),
        //    an input port changed in it (passthrough outputs), or a cut
        //    register was poked into partition 0 in it this cycle. An
        //    out-of-band poke since the last step voids the proof once.
        if let Some(t) = &self.tracker {
            self.wave_live = if std::mem::take(&mut self.wave_dirty) {
                crate::activity::full_mask(self.lanes)
            } else {
                let input_union = self.input_changed.iter().fold(0u64, |a, &m| a | m);
                t.active_mask(0) | input_union | rum_poked0
            };
        }
    }

    /// Named design outputs as seen by one lane (partition 0 computes the
    /// outputs by construction).
    pub fn lane_outputs(&self, lane: usize) -> Vec<(String, u64)> {
        let v = self.pool.kernel(0).slots();
        self.outputs
            .iter()
            .map(|(n, s)| (n.clone(), v[*s as usize * self.lanes + lane]))
            .collect()
    }

    /// [`Self::lane_outputs`] into a reusable buffer: only the values are
    /// rewritten, the names are cloned once — no per-call allocation.
    pub fn write_lane_outputs(&self, lane: usize, buf: &mut Vec<(String, u64)>) {
        if buf.len() != self.outputs.len() {
            *buf = self.outputs.iter().map(|(n, _)| (n.clone(), 0)).collect();
        }
        let v = self.pool.kernel(0).slots();
        for (dst, (_, s)) in buf.iter_mut().zip(&self.outputs) {
            dst.1 = v[*s as usize * self.lanes + lane];
        }
    }

    /// Lanes in which the design outputs may differ from the previous
    /// cycle, for the delta-waveform sink
    /// ([`crate::sim::wave::WaveSink::sample_parallel`]): `Some(mask)` on
    /// sparse runs — a clear bit *proves* the lane's outputs are
    /// bit-identical to the previous cycle's, so the sink skips the lane
    /// in O(1) — `None` on dense runs, which keep no change accounting
    /// (the sink then falls back to a full per-output value diff). Valid
    /// from the return of [`Self::step`] until the next
    /// `step`/`poke_lane`.
    pub fn wave_changed(&self) -> Option<u64> {
        self.tracker.as_ref().map(|_| self.wave_live)
    }

    /// Committed value of register slot `reg_slot` in `lane`, read from
    /// the partition that owns (commits) the register.
    pub fn reg_lane(&self, reg_slot: u32, lane: usize) -> u64 {
        let owner = *self
            .owner_of_slot
            .get(&reg_slot)
            .unwrap_or_else(|| panic!("slot {reg_slot} is not a committed register"));
        self.pool.kernel(owner).slots()[reg_slot as usize * self.lanes + lane]
    }

    /// Write one lane of one slot in every partition's slot file
    /// (divergent-lane initialization). Keeps the RUM shadow consistent
    /// and, in sparse mode, performs a *targeted* wake instead of a
    /// recold: only the partitions whose cones read the slot — plus its
    /// owner, whose next commit must overwrite the poke exactly as a
    /// dense run's would — step in the poked lane next cycle. (The
    /// per-kernel `poke_lane` is equally targeted at the group level.)
    pub fn poke_lane(&mut self, slot: u32, lane: usize, value: u64) {
        self.wave_dirty = true;
        for p in 0..self.pool.parts() {
            self.pool.kernel_mut(p).poke_lane(slot, lane, value);
        }
        let mut hit_tracked = false;
        for (t_idx, t) in self.tracked.iter().enumerate() {
            if t.reg_slot == slot {
                self.shadow[t_idx * self.lanes + lane] = value;
                self.poke_dirty[t_idx] = true;
                hit_tracked = true;
            }
        }
        if !hit_tracked {
            // a poke to any other slot (e.g. a register's next-state slot
            // during divergent-lane init) can change a tracked register at
            // the next commit without its writer group running — suspend
            // the fast-skip for every tracked register until one
            // reconciling scan has run
            for d in &mut self.poke_dirty {
                *d = true;
            }
        }
        if let Some(tr) = &mut self.tracker {
            let lane_mask = 1u64 << lane;
            let readers = self.slot_readers.get(&slot);
            if let Some(readers) = readers {
                tr.note_reg_change(readers, lane_mask);
            }
            match self.owner_of_slot.get(&slot) {
                Some(&owner) => tr.note_reg_change(&[owner as u32], lane_mask),
                // a slot the partitioning has no record of at all (e.g.
                // an internal op output): full wake in the poked lane —
                // every partition steps, and each sparse kernel's own
                // targeted invalidation re-runs the slot's writer and
                // reader groups, so the poke is overwritten exactly as a
                // dense step would overwrite it (no recold of the other
                // lanes)
                None if readers.is_none() => tr.note_all(lane_mask),
                None => {}
            }
        }
    }

    /// Capture the full dynamic state of the run — slot files, kernel
    /// activity trackers, RUM shadow, boundary-detection buffers, cycle
    /// count — so [`Self::import_state`] can later resume it
    /// bit-identically (the checkpoint/restore substrate of
    /// [`crate::service`]). Skip-rate statistics are not state: they
    /// restart from zero in the restored simulator.
    pub fn export_state(&self) -> SimState {
        let parts = self.pool.parts();
        SimState {
            cycles_total: self.cycles_total,
            lanes: self.lanes,
            part_slots: (0..parts).map(|p| self.pool.kernel(p).slots().to_vec()).collect(),
            part_activity: (0..parts)
                .map(|p| self.pool.kernel(p).export_activity().unwrap_or_default())
                .collect(),
            shadow: self.shadow.clone(),
            prev_inputs: self.prev_inputs.clone(),
            tracker_state: self.tracker.as_ref().map(|t| t.export_state()).unwrap_or_default(),
            poke_dirty: self.poke_dirty.clone(),
        }
    }

    /// Restore state captured by [`Self::export_state`] on a simulator
    /// built from the same compile artifacts. Every buffer shape is
    /// validated before anything is written, so a mismatched snapshot
    /// leaves the simulator untouched and returns an error instead of
    /// panicking or half-applying.
    pub fn import_state(&mut self, st: &SimState) -> Result<(), String> {
        let parts = self.pool.parts();
        if st.lanes != self.lanes {
            return Err(format!("snapshot has {} lanes, simulator has {}", st.lanes, self.lanes));
        }
        if st.part_slots.len() != parts || st.part_activity.len() != parts {
            return Err(format!(
                "snapshot has {} partitions, simulator has {parts}",
                st.part_slots.len()
            ));
        }
        for (p, slots) in st.part_slots.iter().enumerate() {
            if slots.len() != self.pool.kernel(p).slots().len() {
                return Err(format!(
                    "partition {p} snapshot has {} slot words, expected {}",
                    slots.len(),
                    self.pool.kernel(p).slots().len()
                ));
            }
        }
        if st.shadow.len() != self.shadow.len() {
            return Err(format!(
                "snapshot shadow has {} words, expected {}",
                st.shadow.len(),
                self.shadow.len()
            ));
        }
        if st.prev_inputs.len() != self.prev_inputs.len() {
            return Err(format!(
                "snapshot prev_inputs has {} words, expected {}",
                st.prev_inputs.len(),
                self.prev_inputs.len()
            ));
        }
        if st.poke_dirty.len() != self.poke_dirty.len() {
            return Err(format!(
                "snapshot has {} poke-dirty flags, expected {}",
                st.poke_dirty.len(),
                self.poke_dirty.len()
            ));
        }
        // a dense snapshot restored into a sparse simulator (or vice
        // versa) has mismatched tracker state — not a supported pairing
        if self.tracker.is_some() && st.tracker_state.is_empty() {
            return Err("snapshot has no partition-tracker state but simulator is sparse"
                .to_string());
        }
        if self.tracker.is_none() && !st.tracker_state.is_empty() {
            return Err("snapshot has partition-tracker state but simulator is dense".to_string());
        }
        for p in 0..parts {
            self.pool.kernel_mut(p).restore_slots(&st.part_slots[p])?;
            self.pool.kernel_mut(p).import_activity(&st.part_activity[p])?;
        }
        self.shadow.copy_from_slice(&st.shadow);
        self.prev_inputs.copy_from_slice(&st.prev_inputs);
        self.poke_dirty.copy_from_slice(&st.poke_dirty);
        if let Some(t) = &mut self.tracker {
            t.import_state(&st.tracker_state)?;
        }
        self.cycles_total = st.cycles_total;
        self.wave_dirty = true;
        Ok(())
    }

    /// RUM lane scans actually performed so far — one per (tracked
    /// register, cycle) the exchange did not skip. The fast-skip's
    /// effectiveness metric: on a quiescent sparse run this stays far
    /// below `tracked × cycles`.
    pub fn exchange_visits(&self) -> u64 {
        self.exchange_visits
    }

    /// Tracked (cross-partition) registers in the RUM exchange —
    /// [`Self::exchange_visits`]'s per-cycle denominator.
    pub fn tracked_regs(&self) -> usize {
        self.tracked.len()
    }

    /// Partition-level activity accounting of a sparse run; `None` on
    /// dense ones.
    pub fn activity_stats(&self) -> Option<PartitionActivity> {
        self.tracker.as_ref().map(|t| t.stats())
    }

    /// **Group-level** activity accounting of a sparse run whose kernel
    /// configuration has a sparse executor; `None` on dense runs and on
    /// sparse runs of group-free kernels. One op-lane is one operation
    /// evaluated in one lane, counted against everything a dense
    /// partitioned run would evaluate — replicated cone ops × lanes ×
    /// cycles, summed over partitions — so a partition-cycle skipped at
    /// the partition level contributes all its op-lanes as skipped: this
    /// is the *composed* skip rate of both activity levels.
    pub fn group_stats(&self) -> Option<ActivityStats> {
        if !self.group_sparse {
            return None;
        }
        let mut evaluated = 0u64;
        for p in 0..self.pool.parts() {
            if let Some(s) = self.pool.kernel(p).activity_stats() {
                evaluated += s.evaluated_op_lanes;
            }
        }
        let per_cycle: u64 = self.part_ops.iter().sum::<u64>() * self.lanes as u64;
        Some(ActivityStats {
            cycles: self.cycles_total,
            evaluated_op_lanes: evaluated,
            total_op_lanes: per_cycle * self.cycles_total,
        })
    }

    /// Registers owned (committed) by partition `p` — the ownership
    /// invariant every partition's commits must respect (see the unit
    /// tests).
    pub fn owned_regs(&self, p: usize) -> &[u32] {
        &self.owned[p]
    }

    pub fn num_partitions(&self) -> usize {
        self.pool.parts()
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// The ownership strategy this simulation was partitioned with.
    pub fn partitioner(&self) -> PartitionerKind {
        self.partitioner
    }

    /// (register, reader) pairs whose values cross partitions each cycle.
    pub fn cut_size(&self) -> usize {
        self.tracked.iter().map(|e| e.rum_readers.len()).sum()
    }

    /// Distinct registers whose values cross partitions each cycle.
    pub fn cut_regs(&self) -> usize {
        self.tracked.iter().filter(|e| !e.rum_readers.is_empty()).count()
    }

    /// Worker threads backing this simulation (`P - 1`; constant — the
    /// pool is built once and stepping never spawns).
    pub fn pool_threads(&self) -> usize {
        self.pool.worker_threads()
    }

    /// Threads ever spawned for this simulation — must equal
    /// [`Self::pool_threads`] forever (the no-per-cycle-spawn guarantee).
    pub fn pool_threads_spawned_ever(&self) -> usize {
        self.pool.threads_spawned_ever()
    }
}

/// Scalar RepCut-style partitioned simulation — a thin `B = 1` wrapper
/// over [`BatchParallelSim`] keeping the original single-lane API.
pub struct ParallelSim {
    inner: BatchParallelSim,
    outputs_buf: Vec<(String, u64)>,
    pub replication_factor: f64,
}

impl ParallelSim {
    /// Partition `ir` into `n` pieces under the default (min-cut)
    /// partitioner and build one kernel per piece.
    pub fn new(ir: &LayerIr, cfg: KernelConfig, n: usize) -> Self {
        Self::with_partitioner(ir, cfg, n, PartitionerKind::default())
    }

    /// [`Self::new`] with an explicit register-ownership strategy.
    pub fn with_partitioner(
        ir: &LayerIr,
        cfg: KernelConfig,
        n: usize,
        partitioner: PartitionerKind,
    ) -> Self {
        let inner = BatchParallelSim::with_partitioner(ir, cfg, n, 1, false, partitioner);
        let replication_factor = inner.replication_factor;
        ParallelSim { inner, outputs_buf: Vec::new(), replication_factor }
    }

    /// One cycle: partitions evaluate + commit concurrently, then the RUM
    /// synchronization step exchanges committed register values.
    pub fn step(&mut self, inputs: &[u64]) {
        self.inner.step(inputs);
    }

    /// Named design outputs. The values are refreshed into an internal
    /// buffer — no allocation per call (this sits in hot sweep loops).
    pub fn outputs(&mut self) -> &[(String, u64)] {
        self.inner.write_lane_outputs(0, &mut self.outputs_buf);
        &self.outputs_buf
    }

    /// Registers owned (committed) by partition `p`.
    pub fn owned_regs(&self, p: usize) -> &[u32] {
        self.inner.owned_regs(p)
    }

    pub fn num_partitions(&self) -> usize {
        self.inner.num_partitions()
    }

    /// Registers whose values cross partitions each cycle.
    pub fn cut_size(&self) -> usize {
        self.inner.cut_size()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::designs::catalog;
    use crate::graph::passes::optimize;
    use crate::tensor::ir::lower;

    const BOTH: [PartitionerKind; 2] = [PartitionerKind::RoundRobin, PartitionerKind::MinCut];

    #[test]
    fn partitioned_sim_matches_single_threaded() {
        let d = catalog("rocket_like_1c").unwrap();
        let (opt, _) = optimize(&d.graph);
        let ir = lower(&opt);
        for kind in BOTH {
            for n in [2usize, 4] {
                let mut par = ParallelSim::with_partitioner(&ir, KernelConfig::PSU, n, kind);
                assert!(par.replication_factor >= 1.0);
                let mut stim = d.make_stimulus();
                let mut single_fresh = crate::kernels::build(KernelConfig::PSU, &ir);
                for c in 0..30u64 {
                    let inputs = stim(c);
                    single_fresh.step(&inputs);
                    par.step(&inputs);
                    assert_eq!(
                        par.outputs(),
                        single_fresh.outputs(),
                        "{} n={n} cycle={c}",
                        kind.name()
                    );
                }
            }
        }
    }

    #[test]
    fn keccak_partitioned_runs_correct_permutation() {
        use crate::designs::keccak;
        let g = keccak::keccak_round_datapath();
        let (opt, _) = optimize(&g);
        let ir = lower(&opt);
        let mut par = ParallelSim::new(&ir, KernelConfig::TI, 3);
        let ins: [u64; 5] = [1, 2, 3, 4, 5];
        let mut golden = [[0u64; 5]; 5];
        for x in 0..5 {
            for y in 0..5 {
                golden[x][y] = ins[x].rotate_left((y * 7) as u32) ^ y as u64;
            }
        }
        keccak::keccak_f_sw(&mut golden);
        let mut load = vec![1u64, 0];
        load.extend_from_slice(&ins);
        par.step(&load);
        let go = vec![0u64, 1, 0, 0, 0, 0, 0];
        for _ in 0..24 {
            par.step(&go);
        }
        let outs: std::collections::HashMap<String, u64> =
            par.outputs().iter().cloned().collect();
        assert_eq!(outs["lane00"], golden[0][0]);
        assert_eq!(outs["lane44"], golden[4][4]);
    }

    /// Register ownership invariants: every committed register is owned
    /// by exactly one partition (the sets are pairwise disjoint and their
    /// union is the design's full commit list), for both partitioners.
    #[test]
    fn partition_register_ownership_is_a_disjoint_cover() {
        let d = catalog("gemmini_like_4").unwrap();
        let (opt, _) = optimize(&d.graph);
        let ir = lower(&opt);
        let all: std::collections::BTreeSet<u32> = ir.commits.iter().map(|c| c.0).collect();
        for kind in BOTH {
            for n in [1usize, 2, 4] {
                let par =
                    BatchParallelSim::with_partitioner(&ir, KernelConfig::PSU, n, 2, false, kind);
                let mut seen = std::collections::BTreeSet::new();
                for p in 0..par.num_partitions() {
                    for &slot in par.owned_regs(p) {
                        assert!(
                            seen.insert(slot),
                            "register slot {slot} owned twice (n={n}, {})",
                            kind.name()
                        );
                    }
                }
                assert_eq!(seen, all, "ownership must cover every commit (n={n})");
            }
        }
    }

    /// P × B smoke: the batched partitioned simulator is bit-identical
    /// per lane to one lane-batched kernel (no partitioning) on a catalog
    /// design — the full differential grid against RefSim lives in
    /// `tests/designs_e2e.rs`.
    #[test]
    fn batch_parallel_matches_unpartitioned_batch() {
        let d = catalog("fir8").unwrap();
        let (opt, _) = optimize(&d.graph);
        let ir = lower(&opt);
        let oim = crate::tensor::oim::Oim::from_ir(&ir);
        let lanes = 4usize;
        for n in [2usize, 3] {
            let mut par = BatchParallelSim::new(&ir, KernelConfig::TI, n, lanes, false);
            let mut single = crate::kernels::build_batch(KernelConfig::TI, &ir, &oim, lanes);
            let mut stim = d.make_lane_stimulus(lanes);
            for c in 0..40u64 {
                let inputs = stim(c);
                single.step(&inputs);
                par.step(&inputs);
                for l in 0..lanes {
                    assert_eq!(
                        par.lane_outputs(l),
                        single.lane_outputs(l),
                        "n={n} lane={l} cycle={c}"
                    );
                }
                for &(reg, _, _) in &ir.commits {
                    for l in 0..lanes {
                        assert_eq!(
                            par.reg_lane(reg, l),
                            single.slots()[reg as usize * lanes + l],
                            "n={n} reg={reg} lane={l} cycle={c}"
                        );
                    }
                }
            }
        }
    }

    /// The persistent pool is constructed once: `P - 1` workers exist
    /// after construction and stepping many cycles spawns no further
    /// threads anywhere in the process — the per-cycle `thread::scope`
    /// regression guard.
    #[test]
    fn stepping_spawns_no_per_cycle_threads() {
        let d = catalog("fir8").unwrap();
        let (opt, _) = optimize(&d.graph);
        let ir = lower(&opt);
        let parts = 4usize;
        let lanes = 2usize;
        let mut sim = BatchParallelSim::new(&ir, KernelConfig::PSU, parts, lanes, false);
        assert_eq!(sim.pool_threads(), parts - 1);
        assert_eq!(sim.pool_threads_spawned_ever(), parts - 1);
        let mut stim = d.make_lane_stimulus(lanes);
        for c in 0..200u64 {
            sim.step(&stim(c));
        }
        assert_eq!(
            sim.pool_threads_spawned_ever(),
            parts - 1,
            "stepping 200 cycles must not spawn any thread"
        );
        assert_eq!(sim.pool_threads(), parts - 1);
    }

    /// Both partitioners drive bit-identical simulations (ownership is a
    /// performance choice, never a semantic one): min-cut vs round-robin
    /// on a multi-partition batched run.
    #[test]
    fn mincut_and_round_robin_simulations_agree() {
        let d = catalog("gemmini_like_4").unwrap();
        let (opt, _) = optimize(&d.graph);
        let ir = lower(&opt);
        let lanes = 4usize;
        let mut a = BatchParallelSim::with_partitioner(
            &ir,
            KernelConfig::PSU,
            3,
            lanes,
            false,
            PartitionerKind::RoundRobin,
        );
        let mut b = BatchParallelSim::with_partitioner(
            &ir,
            KernelConfig::PSU,
            3,
            lanes,
            false,
            PartitionerKind::MinCut,
        );
        let mut stim = d.make_lane_stimulus(lanes);
        for c in 0..50u64 {
            let inputs = stim(c);
            a.step(&inputs);
            b.step(&inputs);
            for l in 0..lanes {
                assert_eq!(a.lane_outputs(l), b.lane_outputs(l), "lane={l} cycle={c}");
            }
            for &(reg, _, _) in &ir.commits {
                assert_eq!(a.reg_lane(reg, 0), b.reg_lane(reg, 0), "reg={reg} cycle={c}");
            }
        }
    }

    /// Sparse partitioned runs are bit-identical to dense ones and skip
    /// idle partitions: on `alu_farm_64` with the stimulus frozen after
    /// cycle 0 (toggle rate 0), every partition goes quiescent, so the
    /// partition-cycle skip-rate must be high while outputs stay exact.
    #[test]
    fn sparse_parallel_skips_idle_partitions_exactly() {
        let d = catalog("alu_farm_64").unwrap();
        let (opt, _) = optimize(&d.graph);
        let ir = lower(&opt);
        let parts = 4usize;
        let lanes = 8usize;
        let mut dense = BatchParallelSim::new(&ir, KernelConfig::PSU, parts, lanes, false);
        let mut sparse = BatchParallelSim::new(&ir, KernelConfig::PSU, parts, lanes, true);
        let mut stim_a = d.make_lane_stimulus_toggle(lanes, 0.0);
        let mut stim_b = d.make_lane_stimulus_toggle(lanes, 0.0);
        for c in 0..64u64 {
            let ia = stim_a(c);
            let ib = stim_b(c);
            assert_eq!(ia, ib);
            dense.step(&ia);
            sparse.step(&ib);
            for l in [0usize, lanes - 1] {
                assert_eq!(
                    sparse.lane_outputs(l),
                    dense.lane_outputs(l),
                    "lane {l} cycle {c}"
                );
            }
            for &(reg, _, _) in &ir.commits {
                assert_eq!(sparse.reg_lane(reg, 0), dense.reg_lane(reg, 0), "reg {reg} cycle {c}");
            }
        }
        let stats = sparse.activity_stats().expect("sparse runs report activity");
        assert!(dense.activity_stats().is_none());
        assert_eq!(stats.cycles, 64);
        assert_eq!(stats.total_partition_cycles, 64 * parts as u64);
        assert!(
            stats.skip_rate() > 0.5,
            "frozen stimulus must idle most partition-cycles (got {:.3})",
            stats.skip_rate()
        );
        // PSU has a sparse executor, so the sparse run also composes
        // group-level masks inside the partitions: over the whole frozen
        // run, nearly all op-lanes are skipped (only the cold first
        // cycles evaluate anything)
        let group = sparse.group_stats().expect("sparse PSU runs report group-level activity");
        assert!(dense.group_stats().is_none());
        assert_eq!(group.cycles, 64);
        assert_eq!(
            group.total_op_lanes % (64 * lanes as u64),
            0,
            "denominator covers every partition-cycle's op-lanes"
        );
        assert!(
            group.skip_rate() > 0.5,
            "frozen stimulus must idle most op-lanes (got {:.3})",
            group.skip_rate()
        );
    }

    /// Targeted poke wake: on a quiescent sparse partitioned run, a
    /// single-register poke steps only the partitions that read or own
    /// the register — not all of them (the old `force_recold` hammer) —
    /// and the run stays bit-identical to a dense partitioned run given
    /// the same poke.
    #[test]
    fn poke_lane_wakes_only_reader_partitions() {
        let d = catalog("alu_farm_64").unwrap();
        let (opt, _) = optimize(&d.graph);
        let ir = lower(&opt);
        let parts = 4usize;
        let lanes = 4usize;
        let mut dense = BatchParallelSim::new(&ir, KernelConfig::PSU, parts, lanes, false);
        let mut sparse = BatchParallelSim::new(&ir, KernelConfig::PSU, parts, lanes, true);
        let mut stim_a = d.make_lane_stimulus_toggle(lanes, 0.0);
        let mut stim_b = d.make_lane_stimulus_toggle(lanes, 0.0);
        for c in 0..16u64 {
            dense.step(&stim_a(c));
            sparse.step(&stim_b(c));
        }
        let before = sparse.activity_stats().unwrap();
        let (reg, _, m) = ir.commits[0];
        let poked = (sparse.reg_lane(reg, 1) ^ 1) & m;
        dense.poke_lane(reg, 1, poked);
        sparse.poke_lane(reg, 1, poked);
        for c in 16..20u64 {
            let ia = stim_a(c);
            dense.step(&ia);
            sparse.step(&stim_b(c));
            for l in 0..lanes {
                assert_eq!(sparse.lane_outputs(l), dense.lane_outputs(l), "lane {l} cycle {c}");
            }
            for &(r, _, _) in &ir.commits {
                assert_eq!(sparse.reg_lane(r, 1), dense.reg_lane(r, 1), "reg {r} cycle {c}");
            }
        }
        let after = sparse.activity_stats().unwrap().since(&before);
        assert_eq!(after.total_partition_cycles, 4 * parts as u64);
        assert!(
            after.stepped_partition_cycles <= 4,
            "a single-register poke must wake only its readers/owner for a ripple, \
             not every partition ({} of {} partition-cycles stepped)",
            after.stepped_partition_cycles,
            after.total_partition_cycles
        );
    }

    /// RUM fast-skip: on `alu_farm_64` with the stimulus frozen after
    /// cycle 0, the sparse run's writer groups go quiescent, so the
    /// exchange must skip nearly every per-register lane scan — far
    /// fewer visits than the dense run's every-tracked-register-every-
    /// cycle — while both runs stay bit-identical (checked lane by lane
    /// above in `sparse_parallel_skips_idle_partitions_exactly`; here
    /// against the register files directly).
    #[test]
    fn rum_fast_skip_drops_exchange_visits_on_frozen_design() {
        // round-robin ownership scatters the independent ALUs across
        // partitions, guaranteeing a non-trivial RUM cut (min-cut can
        // partition alu_farm with a near-zero cut, leaving nothing to
        // measure)
        let d = catalog("alu_farm_64").unwrap();
        let (opt, _) = optimize(&d.graph);
        let ir = lower(&opt);
        let parts = 4usize;
        let lanes = 8usize;
        let cycles = 64u64;
        let kind = PartitionerKind::RoundRobin;
        let mut dense =
            BatchParallelSim::with_partitioner(&ir, KernelConfig::PSU, parts, lanes, false, kind);
        let mut sparse =
            BatchParallelSim::with_partitioner(&ir, KernelConfig::PSU, parts, lanes, true, kind);
        let tracked = sparse.tracked_regs() as u64;
        assert!(tracked > 0, "alu_farm_64 must have tracked registers");
        assert!(dense.cut_regs() > 0, "round-robin must leave a RUM cut to measure");

        // phase 1 — frozen stimulus: whole partitions go quiescent, so
        // the sparse exchange visits almost nothing (the cold first
        // cycle only) while the dense one scans its full cut every cycle
        let mut stim_a = d.make_lane_stimulus_toggle(lanes, 0.0);
        let mut stim_b = d.make_lane_stimulus_toggle(lanes, 0.0);
        for c in 0..cycles {
            dense.step(&stim_a(c));
            sparse.step(&stim_b(c));
        }
        for &(reg, _, _) in &ir.commits {
            for l in 0..lanes {
                assert_eq!(sparse.reg_lane(reg, l), dense.reg_lane(reg, l), "reg {reg} lane {l}");
            }
        }
        assert!(
            sparse.exchange_visits() <= tracked * 2,
            "frozen run should skip the exchange (visited {} of {} reg-cycles)",
            sparse.exchange_visits(),
            tracked * cycles
        );
        assert!(sparse.exchange_visits() < dense.exchange_visits());

        // phase 2 — sparse low-rate toggling: input changes keep
        // partitions *active* most cycles, but each cycle only the few
        // toggled ALUs' writer groups run, so the per-register
        // writer-group fast-skip (not partition-level skipping) is what
        // keeps the visit count below the dense run's
        let v_dense = dense.exchange_visits();
        let v_sparse = sparse.exchange_visits();
        let mut tog_a = d.make_lane_stimulus_toggle(lanes, 0.05);
        let mut tog_b = d.make_lane_stimulus_toggle(lanes, 0.05);
        for c in 0..32u64 {
            let ia = tog_a(c);
            dense.step(&ia);
            sparse.step(&tog_b(c));
            for l in 0..lanes {
                assert_eq!(sparse.lane_outputs(l), dense.lane_outputs(l), "lane {l} cycle {c}");
            }
        }
        for &(reg, _, _) in &ir.commits {
            for l in 0..lanes {
                assert_eq!(sparse.reg_lane(reg, l), dense.reg_lane(reg, l), "reg {reg} lane {l}");
            }
        }
        let d_delta = dense.exchange_visits() - v_dense;
        let s_delta = sparse.exchange_visits() - v_sparse;
        assert!(
            s_delta < d_delta,
            "toggling run must still fast-skip idle writer groups ({s_delta} vs {d_delta})"
        );
    }

    /// export/import round trip: stop a partitioned batched run mid-way,
    /// restore the snapshot into a freshly built simulator, and the
    /// remainder of the run is bit-identical to the uninterrupted one —
    /// outputs and every committed register slot, dense and sparse.
    #[test]
    fn state_roundtrip_resumes_bit_identically() {
        let d = catalog("fir8").unwrap();
        let (opt, _) = optimize(&d.graph);
        let ir = lower(&opt);
        let lanes = 4usize;
        for sparse in [false, true] {
            let mut full = BatchParallelSim::new(&ir, KernelConfig::PSU, 2, lanes, sparse);
            let mut head = BatchParallelSim::new(&ir, KernelConfig::PSU, 2, lanes, sparse);
            let mut stim_a = d.make_lane_stimulus(lanes);
            let mut stim_b = d.make_lane_stimulus(lanes);
            for c in 0..13u64 {
                full.step(&stim_a(c));
                head.step(&stim_b(c));
            }
            let snap = head.export_state();
            assert_eq!(snap.cycles_total, 13);
            let mut tail = BatchParallelSim::new(&ir, KernelConfig::PSU, 2, lanes, sparse);
            tail.import_state(&snap).expect("well-formed snapshot restores");
            for c in 13..30u64 {
                full.step(&stim_a(c));
                tail.step(&stim_b(c));
                for l in 0..lanes {
                    assert_eq!(
                        tail.lane_outputs(l),
                        full.lane_outputs(l),
                        "sparse={sparse} lane={l} cycle={c}"
                    );
                }
                for &(reg, _, _) in &ir.commits {
                    for l in 0..lanes {
                        assert_eq!(
                            tail.reg_lane(reg, l),
                            full.reg_lane(reg, l),
                            "sparse={sparse} reg={reg} lane={l} cycle={c}"
                        );
                    }
                }
            }
            // malformed snapshots are structured errors, not panics
            let mut bad = snap.clone();
            bad.shadow.push(0);
            assert!(tail.import_state(&bad).is_err());
            let other = BatchParallelSim::new(&ir, KernelConfig::PSU, 3, lanes, sparse)
                .export_state();
            assert!(tail.import_state(&other).is_err(), "partition-count mismatch rejected");
        }
    }
}
