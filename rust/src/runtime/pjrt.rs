//! Thin wrapper over the `xla` crate's PJRT CPU client: HLO *text* in,
//! compiled executable out (see /opt/xla-example and DESIGN.md — HLO text
//! is the interchange format because jax ≥ 0.5 emits 64-bit-id protos
//! that xla_extension 0.5.1 rejects).

use std::path::Path;

use anyhow::{Context, Result};

/// A PJRT CPU client + compiled executables.
pub struct PjrtRuntime {
    pub client: xla::PjRtClient,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact.
    pub fn compile_hlo_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).with_context(|| format!("compiling {}", path.display()))
    }
}
