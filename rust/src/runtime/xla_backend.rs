//! The XLA simulation backend: executes the AOT-lowered L2 cycle function
//! (which embeds the L1 Pallas ALU kernel) from the Rust hot path.
//!
//! Artifact pair per design (built by `make artifacts`):
//! * `artifacts/<design>.hlo.txt`  — HLO text of
//!   `cycle_chunk(state[u32; S], inputs[u32; CHUNK×I]) -> (state', outputs[CHUNK×O])`
//! * `artifacts/<design>.meta.json` — shapes + chunk size
//!
//! plus `artifacts/<design>.tensors.json` (the dense design encoding the
//! Python side consumed; the backend reads IO slot metadata from it).
//!
//! Cycles run in chunks of `CHUNK` to amortize PJRT call overhead.

use std::path::Path;

use anyhow::{Context, Result};

use super::pjrt::PjrtRuntime;
use crate::util::json;

pub struct XlaBackend {
    exe: xla::PjRtLoadedExecutable,
    pub state: Vec<u32>,
    pub chunk: usize,
    pub num_inputs: usize,
    pub num_outputs: usize,
    pub output_names: Vec<String>,
    input_widths: Vec<u32>,
    /// buffered inputs for the current partial chunk
    pending: Vec<u32>,
    pending_cycles: usize,
    /// outputs of every cycle in the last executed chunk
    pub last_outputs: Vec<u32>,
    /// rows of `last_outputs` that correspond to real (requested) cycles —
    /// a padded peek flush ([`Self::run`]) executes a full chunk but only
    /// its leading rows are meaningful
    valid_rows: usize,
}

impl XlaBackend {
    /// Load a design's artifacts from `dir`.
    pub fn load(rt: &PjrtRuntime, dir: &Path, design: &str) -> Result<Self> {
        let hlo = dir.join(format!("{design}.hlo.txt"));
        let meta_path = dir.join(format!("{design}.meta.json"));
        let tensors_path = dir.join(format!("{design}.tensors.json"));
        let exe = rt.compile_hlo_file(&hlo)?;
        let meta = json::parse(&std::fs::read_to_string(&meta_path).with_context(|| format!("reading {}", meta_path.display()))?)?;
        let tensors = json::parse(&std::fs::read_to_string(&tensors_path).with_context(|| format!("reading {}", tensors_path.display()))?)?;

        let num_slots = meta.req_usize("num_slots")?;
        let chunk = meta.req_usize("chunk")?;
        let num_inputs = meta.req_usize("num_inputs")?;
        let num_outputs = meta.req_usize("num_outputs")?;
        let output_names: Vec<String> = tensors
            .req_arr("output_names")?
            .iter()
            .map(|v| v.as_str().unwrap_or("?").to_string())
            .collect();

        // initial state from the tensor encoding
        let mut state = vec![0u32; num_slots];
        let slots = tensors.req_u64_vec("init_slots")?;
        let vals = tensors.req_u64_vec("init_vals")?;
        for (s, v) in slots.iter().zip(&vals) {
            state[*s as usize] = *v as u32;
        }
        debug_assert_eq!(tensors.req_usize("num_inputs")?, num_inputs);
        let input_widths: Vec<u32> =
            tensors.req_u64_vec("input_widths")?.iter().map(|&w| w as u32).collect();

        Ok(XlaBackend {
            exe,
            state,
            chunk,
            num_inputs,
            num_outputs,
            output_names,
            input_widths,
            pending: Vec::new(),
            pending_cycles: 0,
            last_outputs: Vec::new(),
            valid_rows: 0,
        })
    }

    fn input_mask(&self, i: usize) -> u32 {
        let w = self.input_widths.get(i).copied().unwrap_or(32);
        if w >= 32 {
            u32::MAX
        } else {
            (1u32 << w) - 1
        }
    }

    /// Queue one cycle's inputs; executes a PJRT call when a full chunk is
    /// buffered. Returns true if a chunk was flushed.
    pub fn step(&mut self, inputs: &[u64]) -> Result<bool> {
        assert_eq!(inputs.len(), self.num_inputs, "input arity");
        for (i, &v) in inputs.iter().enumerate() {
            self.pending.push(v as u32 & self.input_mask(i));
        }
        self.pending_cycles += 1;
        if self.pending_cycles == self.chunk {
            self.flush()?;
            return Ok(true);
        }
        Ok(false)
    }

    /// Run exactly `cycles` cycles with a stimulus function. A final
    /// partial chunk is executed by padding it with replays of its last
    /// input row, but the padded flush is a *peek*: the committed
    /// register state is restored to the last chunk boundary afterwards
    /// and the real input rows stay buffered, so the padded cycles never
    /// advance the design. `run(cycles)` is therefore exact — safe for
    /// lockstep comparisons: [`Self::outputs`] reports the last *real*
    /// cycle's row, and a subsequent `step`/`run` continues from the
    /// boundary, replaying the buffered rows in its next full chunk.
    pub fn run(&mut self, cycles: u64, mut stim: impl FnMut(u64) -> Vec<u64>) -> Result<()> {
        for c in 0..cycles {
            self.step(&stim(c))?;
        }
        if self.pending_cycles > 0 {
            let real_cycles = self.pending_cycles;
            let real_inputs = self.pending.clone();
            if self.num_inputs > 0 {
                let pad_row: Vec<u32> =
                    self.pending[self.pending.len() - self.num_inputs..].to_vec();
                while self.pending_cycles < self.chunk {
                    self.pending.extend_from_slice(&pad_row);
                    self.pending_cycles += 1;
                }
            } else {
                self.pending_cycles = self.chunk; // nothing to pad
            }
            let committed = self.state.clone();
            self.flush()?;
            // un-advance: drop the padded cycles' state, re-buffer the
            // real rows, and expose only the real rows' outputs
            self.state = committed;
            self.pending = real_inputs;
            self.pending_cycles = real_cycles;
            self.valid_rows = real_cycles;
        }
        Ok(())
    }

    /// Execute the buffered chunk through PJRT.
    pub fn flush(&mut self) -> Result<()> {
        let state_lit = xla::Literal::vec1(&self.state);
        let inputs_flat = if self.num_inputs == 0 {
            vec![0u32; self.chunk] // placeholder column; model ignores it
        } else {
            self.pending.clone()
        };
        let cols = self.num_inputs.max(1) as i64;
        let inputs_lit =
            xla::Literal::vec1(&inputs_flat).reshape(&[self.chunk as i64, cols])?;
        let result = self.exe.execute::<xla::Literal>(&[state_lit, inputs_lit])?[0][0]
            .to_literal_sync()?;
        let (state, outputs) = result.to_tuple2()?;
        self.state = state.to_vec::<u32>()?;
        self.last_outputs = outputs.to_vec::<u32>()?;
        self.valid_rows = self.chunk;
        self.pending.clear();
        self.pending_cycles = 0;
        Ok(())
    }

    /// Named outputs as of the last executed *real* cycle (padded rows of
    /// a partial-chunk peek are never reported).
    pub fn outputs(&self) -> Vec<(String, u64)> {
        if self.last_outputs.is_empty() || self.valid_rows == 0 || self.num_outputs == 0 {
            return Vec::new();
        }
        let start = (self.valid_rows - 1) * self.num_outputs;
        let row = &self.last_outputs[start..start + self.num_outputs];
        self.output_names.iter().cloned().zip(row.iter().map(|&v| v as u64)).collect()
    }

    /// Outputs of every *real* cycle in the last executed chunk
    /// (row-major; a partial-chunk peek exposes only its real rows).
    pub fn chunk_outputs(&self) -> &[u32] {
        &self.last_outputs[..self.valid_rows * self.num_outputs]
    }
}
