//! XLA/PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them on the simulation path with
//! **no Python anywhere** — the L3↔L2 boundary of the three-layer
//! architecture.

pub mod pjrt;
pub mod xla_backend;

pub use xla_backend::XlaBackend;
