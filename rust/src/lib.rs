//! # RTeAAL Sim — RTL simulation as sparse tensor algebra
//!
//! Reproduction of *"RTeAAL Sim: Using Tensor Algebra to Represent and
//! Accelerate RTL Simulation"* (Zhu, Chen, Fletcher, Nayak; CS.AR 2026).
//!
//! The library reformulates full-cycle RTL simulation as the evaluation of a
//! cascade of extended Einsums over a sparse 5-rank tensor `OIM` (ranks
//! `I`/`S`/`N`/`O`/`R`), and provides seven progressively-unrolled kernel
//! executors (`RU`..`TI`) spanning the binding spectrum studied in the paper.
//!
//! Pipeline:
//!
//! ```text
//! FIRRTL text ──firrtl::parse──▶ Circuit AST ──firrtl::lower──▶ graph::Graph
//!    ──graph::passes──▶ optimized graph ──graph::levelize──▶ layers
//!    ──tensor::oim──▶ OIM (per-rank formats) ──kernels::compile──▶ executor
//!    ──sim::Simulator──▶ cycles (+ VCD, DMI, perf counters)
//! ```
//!
//! See `DESIGN.md` for the architecture and experiment index, and
//! `EXPERIMENTS.md` for measured results.

pub mod util;
pub mod firrtl;
pub mod graph;
pub mod tensor;
pub mod einsum;
pub mod activity;
pub mod partition;
pub mod kernels;
pub mod baselines;
pub mod perf;
pub mod sim;
pub mod designs;
pub mod analysis;
pub mod runtime;
pub mod coordinator;
pub mod service;

/// Library version string (matches Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
