//! **IU** — I-rank-unrolled kernel (paper §5.2).
//!
//! Completely unrolls the iterative rank I: the per-layer loop structure is
//! compiled away into a flat *group-command program* in which only
//! non-empty (layer, op-type) groups appear — eliminating both the
//! per-layer loop overhead and NU/PSU's zero-iteration S loops. The group
//! table becomes part of the program (code, in the paper's terms), while
//! coordinates remain data. Includes PSU's partial S unrolling.

use super::common::Driver;
use super::nu::run_group;
use super::SimKernel;
use crate::tensor::ir::{LayerIr, NUM_KOPS};
use crate::tensor::oim::Oim;

/// One command of the flattened program.
#[derive(Clone, Copy, Debug)]
pub(crate) enum Cmd {
    /// Evaluate `cnt` ops of type `n` with precomputed cursors.
    Group { n: u8, cnt: u32, op_idx: u32, r_idx: u32, lo_pos: u32 },
    /// Write `cnt` layer outputs back to LI starting at `wb_idx`.
    Writeback { wb_idx: u32, cnt: u32 },
}

pub struct IuKernel {
    d: Driver,
    oim: Oim,
    program: Vec<Cmd>,
    lo: Vec<u64>,
    chain_buf: Vec<u64>,
}

/// Flatten the (layer, op-type) loop structure into IU's group-command
/// program — IU's "compile" step: all cursors precomputed, empty groups
/// dropped, layer structure fixed into the program. Shared with the
/// lane-batched IU executor ([`super::batch::BatchIuKernel`]), which walks
/// the identical program with a lane inner loop per command.
pub(crate) fn flatten_program(oim: &Oim) -> Vec<Cmd> {
    let mut program = Vec::new();
    let mut op_idx = 0usize;
    let mut r_idx = 0usize;
    let mut wb_idx = 0usize;
    for layer in 0..oim.i_payload.len() {
        let mut lo_pos = 0usize;
        for n in 0..NUM_KOPS {
            let cnt = oim.n_payload[layer * NUM_KOPS + n] as usize;
            if cnt == 0 {
                continue; // empty groups never enter the program
            }
            program.push(Cmd::Group {
                n: n as u8,
                cnt: cnt as u32,
                op_idx: op_idx as u32,
                r_idx: r_idx as u32,
                lo_pos: lo_pos as u32,
            });
            let operands: usize =
                oim.c.arity[op_idx..op_idx + cnt].iter().map(|&a| a as usize).sum();
            op_idx += cnt;
            r_idx += operands;
            lo_pos += cnt;
        }
        let cnt = oim.i_payload[layer] as usize;
        program.push(Cmd::Writeback { wb_idx: wb_idx as u32, cnt: cnt as u32 });
        wb_idx += cnt;
    }
    program
}

impl IuKernel {
    pub fn new(ir: &LayerIr, oim: &Oim) -> Self {
        let program = flatten_program(oim);
        let max_arity = oim.c.arity.iter().copied().max().unwrap_or(1) as usize;
        IuKernel {
            d: Driver::new(ir),
            oim: oim.clone(),
            program,
            lo: vec![0; ir.max_layer_ops()],
            chain_buf: vec![0; max_arity.max(3)],
        }
    }

    pub(crate) fn num_groups(&self) -> usize {
        self.program.iter().filter(|c| matches!(c, Cmd::Group { .. })).count()
    }
}

impl SimKernel for IuKernel {
    fn config_name(&self) -> &'static str {
        "IU"
    }

    fn step(&mut self, inputs: &[u64]) {
        self.d.set_inputs(inputs);
        let o = &self.oim;
        let v = &mut self.d.v;
        for cmd in &self.program {
            match *cmd {
                Cmd::Group { n, cnt, op_idx, r_idx, lo_pos } => {
                    let (cnt, op_idx, r_idx, lo_pos) =
                        (cnt as usize, op_idx as usize, r_idx as usize, lo_pos as usize);
                    run_group::<8>(
                        n,
                        v,
                        &mut self.lo,
                        lo_pos,
                        cnt,
                        &o.c.r_coords[r_idx..],
                        &o.c.imm[op_idx..],
                        &o.c.mask[op_idx..],
                        &o.c.aux[op_idx..],
                        &o.c.arity[op_idx..],
                        &mut self.chain_buf,
                    );
                }
                Cmd::Writeback { wb_idx, cnt } => {
                    let (wb_idx, cnt) = (wb_idx as usize, cnt as usize);
                    let s = &o.c.s_coords[wb_idx..wb_idx + cnt];
                    let mut k = 0usize;
                    while k + 24 <= cnt {
                        for j in 0..24 {
                            v[s[k + j] as usize] = self.lo[k + j];
                        }
                        k += 24;
                    }
                    for i in k..cnt {
                        v[s[i] as usize] = self.lo[i];
                    }
                }
            }
        }
        self.d.commit();
    }

    fn slots(&self) -> &[u64] {
        &self.d.v
    }

    fn outputs(&self) -> Vec<(String, u64)> {
        self.d.named_outputs()
    }


    fn poke(&mut self, slot: u32, value: u64) {
        self.d.v[slot as usize] = value;
    }

    fn program_bytes(&self) -> usize {
        crate::perf::binsize::iu_code_bytes(self.num_groups(), &self.oim)
    }

    fn data_bytes(&self) -> usize {
        crate::perf::binsize::kernel_data_bytes(super::KernelConfig::IU, &self.oim)
    }
}
