//! **SU** — S-rank-unrolled kernel (paper §5.2).
//!
//! Completely unrolls the S rank: the OIM is fully embedded in the
//! program as a straight-line *tape* of self-contained op records — no
//! coordinate/payload arrays are traversed at run time (data → code, the
//! right end of the binding spectrum). Layer writebacks are unrolled into
//! the tape as well. The modeled program size is the tape (paper Table 4:
//! 6.0 MB at rocket-8c); metadata traffic drops to zero.

use super::common::Driver;
use super::SimKernel;
use crate::tensor::ir::{eval_rec, LayerIr, OpRec};
use crate::tensor::oim::Oim;

/// A tape op: the op record plus its LO position.
#[derive(Clone, Copy, Debug)]
struct TapeOp {
    rec: OpRec,
    lo_pos: u32,
}

/// Layer segment boundaries in the tape.
#[derive(Clone, Copy, Debug)]
struct Segment {
    op_start: u32,
    op_end: u32,
    wb_start: u32,
    wb_end: u32,
}

pub struct SuKernel {
    d: Driver,
    tape: Vec<TapeOp>,
    /// writeback records: (LI slot, LO position)
    wb: Vec<(u32, u32)>,
    segments: Vec<Segment>,
    ext_args: Vec<u32>,
    lo: Vec<u64>,
    total_ops: usize,
}

impl SuKernel {
    pub fn new(ir: &LayerIr, oim: &Oim) -> Self {
        let (layers, ext_args) = oim.op_recs();
        let mut tape = Vec::with_capacity(oim.total_ops());
        let mut wb = Vec::with_capacity(oim.total_ops());
        let mut segments = Vec::with_capacity(layers.len());
        for layer in &layers {
            let op_start = tape.len() as u32;
            let wb_start = wb.len() as u32;
            for (pos, rec) in layer.iter().enumerate() {
                tape.push(TapeOp { rec: *rec, lo_pos: pos as u32 });
                wb.push((rec.out, pos as u32));
            }
            segments.push(Segment {
                op_start,
                op_end: tape.len() as u32,
                wb_start,
                wb_end: wb.len() as u32,
            });
        }
        SuKernel {
            d: Driver::new(ir),
            tape,
            wb,
            segments,
            ext_args,
            lo: vec![0; ir.max_layer_ops()],
            total_ops: oim.total_ops(),
        }
    }
}

impl SimKernel for SuKernel {
    fn config_name(&self) -> &'static str {
        "SU"
    }

    fn step(&mut self, inputs: &[u64]) {
        self.d.set_inputs(inputs);
        let v = &mut self.d.v;
        for seg in &self.segments {
            // straight-line op records (OIM embedded in the "code")
            for t in &self.tape[seg.op_start as usize..seg.op_end as usize] {
                self.lo[t.lo_pos as usize] = eval_rec(&t.rec, v, &self.ext_args);
            }
            // unrolled writeback records
            for &(slot, lo_pos) in &self.wb[seg.wb_start as usize..seg.wb_end as usize] {
                v[slot as usize] = self.lo[lo_pos as usize];
            }
        }
        self.d.commit();
    }

    fn slots(&self) -> &[u64] {
        &self.d.v
    }

    fn outputs(&self) -> Vec<(String, u64)> {
        self.d.named_outputs()
    }


    fn poke(&mut self, slot: u32, value: u64) {
        self.d.v[slot as usize] = value;
    }

    fn program_bytes(&self) -> usize {
        crate::perf::binsize::su_code_bytes(self.total_ops)
    }

    fn data_bytes(&self) -> usize {
        0 // OIM fully embedded in the program
    }
}
