//! **NU / PSU** — N-rank-unrolled kernels (paper §5.2, Algorithm 4).
//!
//! Mapping-level change: the S and N ranks are swizzled (`[I, N, S, O, R]`
//! loop order) so outputs computed by the same operation type are grouped;
//! the OIM uses format C (Fig 12c: uncompressed N with per-layer counts).
//! The N rank is then fully unrolled: the case statement is replaced by a
//! separate tight loop per operation type, hoisting dispatch out of the
//! S loop. Note the uncompressed N rank means *every* op type is visited
//! in every layer, including zero-count ones — exactly the "zero-iteration
//! S loops" that IU later eliminates.
//!
//! `UNROLL` is the partial S unroll factor: `NuKernel<1>` is the paper's
//! NU, `NuKernel<8>` is PSU (S loops chunked by 8, writeback by 24).

use super::common::Driver;
use super::SimKernel;
use crate::tensor::ir::{KOp, LayerIr, NUM_KOPS};
use crate::tensor::oim::Oim;

pub struct NuKernel<const UNROLL: usize> {
    d: Driver,
    oim: Oim,
    lo: Vec<u64>,
    chain_buf: Vec<u64>,
}

impl<const UNROLL: usize> NuKernel<UNROLL> {
    pub fn new(ir: &LayerIr, oim: &Oim) -> Self {
        let max_arity = oim.c.arity.iter().copied().max().unwrap_or(1) as usize;
        NuKernel {
            d: Driver::new(ir),
            oim: oim.clone(),
            lo: vec![0; ir.max_layer_ops()],
            chain_buf: vec![0; max_arity.max(3)],
        }
    }
}

/// Tight per-op-type loop over a group of unary ops, chunked by `U`.
#[inline(always)]
pub(crate) fn group1<const U: usize>(
    v: &[u64],
    lo: &mut [u64],
    lo_pos: usize,
    cnt: usize,
    r: &[u32],
    imm: &[u8],
    msk: &[u64],
    aux: &[u64],
    f: impl Fn(u64, u8, u64) -> u64,
) {
    let mut k = 0usize;
    while k + U <= cnt {
        // fixed-trip inner loop: the compiler fully unrolls it
        for j in 0..U {
            let i = k + j;
            lo[lo_pos + i] = f(v[r[i] as usize], imm[i], aux[i]) & msk[i];
        }
        k += U;
    }
    for i in k..cnt {
        lo[lo_pos + i] = f(v[r[i] as usize], imm[i], aux[i]) & msk[i];
    }
}

/// Tight loop over a group of binary ops.
#[inline(always)]
pub(crate) fn group2<const U: usize>(
    v: &[u64],
    lo: &mut [u64],
    lo_pos: usize,
    cnt: usize,
    r: &[u32],
    imm: &[u8],
    msk: &[u64],
    f: impl Fn(u64, u64, u8) -> u64,
) {
    let mut k = 0usize;
    while k + U <= cnt {
        for j in 0..U {
            let i = k + j;
            lo[lo_pos + i] = f(v[r[2 * i] as usize], v[r[2 * i + 1] as usize], imm[i]) & msk[i];
        }
        k += U;
    }
    for i in k..cnt {
        lo[lo_pos + i] = f(v[r[2 * i] as usize], v[r[2 * i + 1] as usize], imm[i]) & msk[i];
    }
}

/// Tight loop over a group of 3-operand muxes.
#[inline(always)]
pub(crate) fn group_mux<const U: usize>(
    v: &[u64],
    lo: &mut [u64],
    lo_pos: usize,
    cnt: usize,
    r: &[u32],
    msk: &[u64],
) {
    let mut k = 0usize;
    while k + U <= cnt {
        for j in 0..U {
            let i = k + j;
            let sel = v[r[3 * i] as usize];
            lo[lo_pos + i] =
                (if sel != 0 { v[r[3 * i + 1] as usize] } else { v[r[3 * i + 2] as usize] }) & msk[i];
        }
        k += U;
    }
    for i in k..cnt {
        let sel = v[r[3 * i] as usize];
        lo[lo_pos + i] =
            (if sel != 0 { v[r[3 * i + 1] as usize] } else { v[r[3 * i + 2] as usize] }) & msk[i];
    }
}

/// Variable-arity mux chains (fused select ops): gather + priority scan.
#[inline(always)]
pub(crate) fn group_chain(
    v: &[u64],
    lo: &mut [u64],
    lo_pos: usize,
    cnt: usize,
    r: &[u32],
    imm: &[u8],
    msk: &[u64],
    arity: &[u8],
    buf: &mut [u64],
) -> usize {
    let mut r_off = 0usize;
    for i in 0..cnt {
        let ar = arity[i] as usize;
        for o in 0..ar {
            buf[o] = v[r[r_off + o] as usize];
        }
        let k = imm[i] as usize;
        let mut val = buf[2 * k];
        for j in (0..k).rev() {
            if buf[2 * j] != 0 {
                val = buf[2 * j + 1];
            }
        }
        lo[lo_pos + i] = val & msk[i];
        r_off += ar;
    }
    r_off
}

/// Dispatch one (op type, group) to its tight loop. Shared by NU/PSU/IU.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_group<const U: usize>(
    n: u8,
    v: &[u64],
    lo: &mut [u64],
    lo_pos: usize,
    cnt: usize,
    r: &[u32],
    imm: &[u8],
    msk: &[u64],
    aux: &[u64],
    arity: &[u8],
    chain_buf: &mut [u64],
) -> usize {
    // returns #operand slots consumed
    match KOp::from_u8(n) {
        KOp::Add => {
            group2::<U>(v, lo, lo_pos, cnt, r, imm, msk, |a, b, _| a.wrapping_add(b));
            2 * cnt
        }
        KOp::Sub => {
            group2::<U>(v, lo, lo_pos, cnt, r, imm, msk, |a, b, _| a.wrapping_sub(b));
            2 * cnt
        }
        KOp::Mul => {
            group2::<U>(v, lo, lo_pos, cnt, r, imm, msk, |a, b, _| a.wrapping_mul(b));
            2 * cnt
        }
        KOp::Div => {
            group2::<U>(v, lo, lo_pos, cnt, r, imm, msk, |a, b, _| if b == 0 { 0 } else { a / b });
            2 * cnt
        }
        KOp::Rem => {
            group2::<U>(v, lo, lo_pos, cnt, r, imm, msk, |a, b, _| if b == 0 { 0 } else { a % b });
            2 * cnt
        }
        KOp::Lt => {
            group2::<U>(v, lo, lo_pos, cnt, r, imm, msk, |a, b, _| (a < b) as u64);
            2 * cnt
        }
        KOp::Leq => {
            group2::<U>(v, lo, lo_pos, cnt, r, imm, msk, |a, b, _| (a <= b) as u64);
            2 * cnt
        }
        KOp::Gt => {
            group2::<U>(v, lo, lo_pos, cnt, r, imm, msk, |a, b, _| (a > b) as u64);
            2 * cnt
        }
        KOp::Geq => {
            group2::<U>(v, lo, lo_pos, cnt, r, imm, msk, |a, b, _| (a >= b) as u64);
            2 * cnt
        }
        KOp::Eq => {
            group2::<U>(v, lo, lo_pos, cnt, r, imm, msk, |a, b, _| (a == b) as u64);
            2 * cnt
        }
        KOp::Neq => {
            group2::<U>(v, lo, lo_pos, cnt, r, imm, msk, |a, b, _| (a != b) as u64);
            2 * cnt
        }
        KOp::And => {
            group2::<U>(v, lo, lo_pos, cnt, r, imm, msk, |a, b, _| a & b);
            2 * cnt
        }
        KOp::Or => {
            group2::<U>(v, lo, lo_pos, cnt, r, imm, msk, |a, b, _| a | b);
            2 * cnt
        }
        KOp::Xor => {
            group2::<U>(v, lo, lo_pos, cnt, r, imm, msk, |a, b, _| a ^ b);
            2 * cnt
        }
        KOp::Not => {
            group1::<U>(v, lo, lo_pos, cnt, r, imm, msk, aux, |a, _, _| !a);
            cnt
        }
        KOp::Neg => {
            group1::<U>(v, lo, lo_pos, cnt, r, imm, msk, aux, |a, _, _| a.wrapping_neg());
            cnt
        }
        KOp::AndrK => {
            group1::<U>(v, lo, lo_pos, cnt, r, imm, msk, aux, |a, _, x| (a == x) as u64);
            cnt
        }
        KOp::Orr => {
            group1::<U>(v, lo, lo_pos, cnt, r, imm, msk, aux, |a, _, _| (a != 0) as u64);
            cnt
        }
        KOp::Xorr => {
            group1::<U>(v, lo, lo_pos, cnt, r, imm, msk, aux, |a, _, _| (a.count_ones() & 1) as u64);
            cnt
        }
        KOp::ShlI => {
            group1::<U>(v, lo, lo_pos, cnt, r, imm, msk, aux, |a, s, _| a << s);
            cnt
        }
        KOp::ShrI => {
            group1::<U>(v, lo, lo_pos, cnt, r, imm, msk, aux, |a, s, _| a >> s);
            cnt
        }
        KOp::Dshl => {
            group2::<U>(v, lo, lo_pos, cnt, r, imm, msk, |a, b, _| if b >= 64 { 0 } else { a << b });
            2 * cnt
        }
        KOp::Dshr => {
            group2::<U>(v, lo, lo_pos, cnt, r, imm, msk, |a, b, _| if b >= 64 { 0 } else { a >> b });
            2 * cnt
        }
        KOp::Cat => {
            group2::<U>(v, lo, lo_pos, cnt, r, imm, msk, |a, b, s| (a << s) | b);
            2 * cnt
        }
        KOp::Mux => {
            group_mux::<U>(v, lo, lo_pos, cnt, r, msk);
            3 * cnt
        }
        KOp::Copy => {
            group1::<U>(v, lo, lo_pos, cnt, r, imm, msk, aux, |a, _, _| a);
            cnt
        }
        KOp::MuxChain => group_chain(v, lo, lo_pos, cnt, r, imm, msk, arity, chain_buf),
    }
}

impl<const UNROLL: usize> SimKernel for NuKernel<UNROLL> {
    fn config_name(&self) -> &'static str {
        if UNROLL == 1 {
            "NU"
        } else {
            "PSU"
        }
    }

    fn step(&mut self, inputs: &[u64]) {
        self.d.set_inputs(inputs);
        let o = &self.oim;
        let v = &mut self.d.v;
        let mut op_idx = 0usize;
        let mut r_idx = 0usize;
        let mut wb_idx = 0usize;
        let layers = o.i_payload.len();
        for layer in 0..layers {
            let mut lo_pos = 0usize;
            // ---- unrolled rank N: one (possibly empty) group per op type ----
            for n in 0..NUM_KOPS {
                let cnt = o.n_payload[layer * NUM_KOPS + n] as usize;
                if cnt == 0 {
                    continue; // the "zero-iteration S loop" overhead of NU/PSU
                }
                let consumed = run_group::<UNROLL>(
                    n as u8,
                    v,
                    &mut self.lo,
                    lo_pos,
                    cnt,
                    &o.c.r_coords[r_idx..],
                    &o.c.imm[op_idx..],
                    &o.c.mask[op_idx..],
                    &o.c.aux[op_idx..],
                    &o.c.arity[op_idx..],
                    &mut self.chain_buf,
                );
                r_idx += consumed;
                op_idx += cnt;
                lo_pos += cnt;
            }
            // ---- writeback, chunked by 24 when partially unrolled ----
            let cnt = o.i_payload[layer] as usize;
            let s = &o.c.s_coords[wb_idx..wb_idx + cnt];
            if UNROLL > 1 {
                let mut k = 0usize;
                while k + 24 <= cnt {
                    for j in 0..24 {
                        v[s[k + j] as usize] = self.lo[k + j];
                    }
                    k += 24;
                }
                for i in k..cnt {
                    v[s[i] as usize] = self.lo[i];
                }
            } else {
                for i in 0..cnt {
                    v[s[i] as usize] = self.lo[i];
                }
            }
            wb_idx += cnt;
        }
        self.d.commit();
    }

    fn slots(&self) -> &[u64] {
        &self.d.v
    }

    fn outputs(&self) -> Vec<(String, u64)> {
        self.d.named_outputs()
    }


    fn poke(&mut self, slot: u32, value: u64) {
        self.d.v[slot as usize] = value;
    }

    fn program_bytes(&self) -> usize {
        let cfg = if UNROLL == 1 { super::KernelConfig::NU } else { super::KernelConfig::PSU };
        crate::perf::binsize::kernel_code_bytes(cfg, &self.oim)
    }

    fn data_bytes(&self) -> usize {
        let cfg = if UNROLL == 1 { super::KernelConfig::NU } else { super::KernelConfig::PSU };
        crate::perf::binsize::kernel_data_bytes(cfg, &self.oim)
    }
}
