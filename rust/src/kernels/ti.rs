//! **TI** — tensor-inlining kernel (paper §5.2).
//!
//! The fully unrolled extreme: beyond SU, the LI/LO array indirection is
//! removed — every op is bound to a *precompiled per-opcode function* with
//! its operand/output slots baked in, writing results directly to the slot
//! file (no LO staging, no writeback pass). This is the interpreter analog
//! of the paper's "replace arrays with individual C++ variables, giving
//! the compiler maximum flexibility to bind values to registers": each
//! tape entry is (code pointer, inlined operands), i.e. the OIM lives
//! entirely inside the code objects.
//!
//! Direct writes are safe for the same reason tensor inlining is:
//! levelization guarantees no op reads a same-layer output, and distinct
//! out-slots never alias.

use super::common::Driver;
use super::SimKernel;
use crate::tensor::ir::{KOp, LayerIr, OpRec};

type TiFn = fn(&mut [u64], &OpRec, &[u32]);

pub struct TiKernel {
    d: Driver,
    tape: Vec<(TiFn, OpRec)>,
    ext_args: Vec<u32>,
}

macro_rules! ti_bin {
    ($name:ident, |$a:ident, $b:ident| $expr:expr) => {
        fn $name(v: &mut [u64], r: &OpRec, _e: &[u32]) {
            let $a = v[r.a as usize];
            let $b = v[r.b as usize];
            v[r.out as usize] = ($expr) & r.mask;
        }
    };
}
macro_rules! ti_un {
    ($name:ident, |$a:ident, $r:ident| $expr:expr) => {
        fn $name(v: &mut [u64], $r: &OpRec, _e: &[u32]) {
            let $a = v[$r.a as usize];
            v[$r.out as usize] = ($expr) & $r.mask;
        }
    };
}

ti_bin!(ti_add, |a, b| a.wrapping_add(b));
ti_bin!(ti_sub, |a, b| a.wrapping_sub(b));
ti_bin!(ti_mul, |a, b| a.wrapping_mul(b));
ti_bin!(ti_div, |a, b| if b == 0 { 0 } else { a / b });
ti_bin!(ti_rem, |a, b| if b == 0 { 0 } else { a % b });
ti_bin!(ti_lt, |a, b| (a < b) as u64);
ti_bin!(ti_leq, |a, b| (a <= b) as u64);
ti_bin!(ti_gt, |a, b| (a > b) as u64);
ti_bin!(ti_geq, |a, b| (a >= b) as u64);
ti_bin!(ti_eq, |a, b| (a == b) as u64);
ti_bin!(ti_neq, |a, b| (a != b) as u64);
ti_bin!(ti_and, |a, b| a & b);
ti_bin!(ti_or, |a, b| a | b);
ti_bin!(ti_xor, |a, b| a ^ b);
ti_bin!(ti_dshl, |a, b| if b >= 64 { 0 } else { a << b });
ti_bin!(ti_dshr, |a, b| if b >= 64 { 0 } else { a >> b });
ti_un!(ti_not, |a, _r| !a);
ti_un!(ti_neg, |a, _r| a.wrapping_neg());
ti_un!(ti_andr, |a, r| (a == r.aux) as u64);
ti_un!(ti_orr, |a, _r| (a != 0) as u64);
ti_un!(ti_xorr, |a, _r| (a.count_ones() & 1) as u64);
ti_un!(ti_shli, |a, r| a << r.imm);
ti_un!(ti_shri, |a, r| a >> r.imm);
ti_un!(ti_copy, |a, _r| a);

fn ti_cat(v: &mut [u64], r: &OpRec, _e: &[u32]) {
    v[r.out as usize] = ((v[r.a as usize] << r.imm) | v[r.b as usize]) & r.mask;
}
fn ti_mux(v: &mut [u64], r: &OpRec, _e: &[u32]) {
    let x = if v[r.a as usize] != 0 { v[r.b as usize] } else { v[r.c as usize] };
    v[r.out as usize] = x & r.mask;
}
fn ti_muxchain(v: &mut [u64], r: &OpRec, e: &[u32]) {
    v[r.out as usize] = crate::tensor::ir::eval_rec(r, v, e);
}

fn ti_fn(op: KOp) -> TiFn {
    match op {
        KOp::Add => ti_add,
        KOp::Sub => ti_sub,
        KOp::Mul => ti_mul,
        KOp::Div => ti_div,
        KOp::Rem => ti_rem,
        KOp::Lt => ti_lt,
        KOp::Leq => ti_leq,
        KOp::Gt => ti_gt,
        KOp::Geq => ti_geq,
        KOp::Eq => ti_eq,
        KOp::Neq => ti_neq,
        KOp::And => ti_and,
        KOp::Or => ti_or,
        KOp::Xor => ti_xor,
        KOp::Not => ti_not,
        KOp::Neg => ti_neg,
        KOp::AndrK => ti_andr,
        KOp::Orr => ti_orr,
        KOp::Xorr => ti_xorr,
        KOp::ShlI => ti_shli,
        KOp::ShrI => ti_shri,
        KOp::Dshl => ti_dshl,
        KOp::Dshr => ti_dshr,
        KOp::Cat => ti_cat,
        KOp::Mux => ti_mux,
        KOp::Copy => ti_copy,
        KOp::MuxChain => ti_muxchain,
    }
}

impl TiKernel {
    /// Build from the swizzled (format-C) op order — TI inherits all of
    /// SU's optimizations per §5.2.
    pub fn new(ir: &LayerIr, oim: &crate::tensor::oim::Oim) -> Self {
        let (layers, ext_args) = oim.op_recs();
        let mut tape = Vec::with_capacity(ir.total_ops());
        for layer in &layers {
            for rec in layer {
                tape.push((ti_fn(rec.kop()), *rec));
            }
        }
        TiKernel { d: Driver::new(ir), tape, ext_args }
    }
}

impl SimKernel for TiKernel {
    fn config_name(&self) -> &'static str {
        "TI"
    }

    fn step(&mut self, inputs: &[u64]) {
        self.d.set_inputs(inputs);
        let v = &mut self.d.v;
        for (f, rec) in &self.tape {
            f(v, rec, &self.ext_args);
        }
        self.d.commit();
    }

    fn slots(&self) -> &[u64] {
        &self.d.v
    }

    fn outputs(&self) -> Vec<(String, u64)> {
        self.d.named_outputs()
    }


    fn poke(&mut self, slot: u32, value: u64) {
        self.d.v[slot as usize] = value;
    }

    fn program_bytes(&self) -> usize {
        crate::perf::binsize::ti_code_bytes(self.tape.len())
    }

    fn data_bytes(&self) -> usize {
        0
    }
}
