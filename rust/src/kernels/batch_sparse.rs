//! Sparse (activity-masked) lane-batched executors.
//!
//! These wrap the lane-major slot files of the dense batched executors
//! with the [`crate::activity`] subsystem: change detection at the cycle
//! boundaries (tracked input writes and register commits), a per-group
//! `u64` lane activity mask propagated through the group dependency
//! graph, and group bodies that skip a zero-mask group entirely and
//! iterate only the set bits of a partial mask.
//!
//! This is the event-driven idea ([`crate::baselines::event_driven`])
//! lifted into the tensor/batch formulation where it finally pays: the
//! activity decision is made once per (layer, op-type) *group* and
//! amortized over `B ≤ 64` lanes, so the bookkeeping cost per skipped
//! op-lane vanishes as `B` grows.
//!
//! Two binding levels are provided, bracketing the spectrum the dense
//! batched executors cover:
//!
//! * [`SparseNuBatch`] — the format-C group walk of
//!   [`super::batch::BatchNuKernel`] with per-group gating (the PSU
//!   flavour shares it via [`SparseNuBatch::new_psu`], as in the dense
//!   pair).
//! * [`SparseTiBatch`] — the precompiled tape of
//!   [`super::batch::BatchTiKernel`], cut into group segments so whole
//!   tape runs are skipped.
//!
//! Skipping is exact: every operation is a pure function of its operand
//! slots, and a group is only skipped in a lane when no transitive
//! boundary source changed in that lane, so the stale slot values are
//! exactly what re-evaluation would produce. Sparse runs are bit-identical
//! to dense batched runs (property-tested in `tests/kernels_property.rs`).
//!
//! Out-of-band writes (`poke_lane` — divergent-lane init, the partitioned
//! simulator's RUM cut-register pokes) take the **targeted invalidation**
//! path: the poked slot's direct reader groups are marked pending in the
//! poked lane ([`ActivityTracker::note_slot_changed`]) and the next
//! cycle's propagation sweep wakes exactly its transitive descendants —
//! a single-slot single-lane poke no longer costs a full cold cycle over
//! every group and every lane.
//!
//! ## Lane tiling × sparsity
//!
//! The full-mask fast path — the common case whenever most lanes toggle —
//! runs through the same explicit `[u64; 8]` tile primitives as the dense
//! executors ([`super::tile`], dispatched per group via
//! [`super::batch::kop_dispatch`]), so SIMD tiling and activity masking
//! compose: a *quiescent* group is skipped outright, a *partial* mask
//! bit-iterates exactly the active lanes (tiling a sparse scatter would
//! waste the inactive slots), and a *full* mask takes the tiled loop.
//! `MuxChain` stays lane-at-a-time in every path (variable arity — the
//! documented tile exception).

use super::batch::kop_dispatch;
use super::common::BatchDriver;
use super::{tile, BatchKernel};
use crate::activity::gdg::Group;
use crate::activity::{ActivityStats, ActivityTracker, GroupDepGraph, WaveMasks};
use crate::tensor::ir::{KOp, LayerIr, OpRec};
use crate::tensor::oim::{Oim, OimArrays};

/// Iterate the lane loop of one op: contiguous when every lane is active
/// (`mask == full`, the vectorizable dense path), bit iteration otherwise.
macro_rules! for_lanes {
    ($mask:expr, $full:expr, $lanes:expr, $l:ident, $body:block) => {
        if $mask == $full {
            for $l in 0..$lanes {
                $body
            }
        } else {
            let mut rem = $mask;
            while rem != 0 {
                let $l = rem.trailing_zeros() as usize;
                rem &= rem - 1;
                $body
            }
        }
    };
}

/// Shared `poke_lane` body of the sparse executors: write the slot and —
/// only when the value actually changed — feed the tracker the targeted
/// invalidation (the slot's writer + reader groups, in the poked lane),
/// instead of the old all-groups/all-lanes recold per poke. Returns the
/// poked lane's bit if the value changed (0 for a no-op poke), which the
/// executors accumulate into the next cycle's [`WaveMasks::recheck`].
fn poke_lane_tracked(
    d: &mut BatchDriver,
    tracker: &mut ActivityTracker,
    slot: u32,
    lane: usize,
    value: u64,
) -> u64 {
    assert!(lane < d.lanes, "lane {lane} out of range (lanes = {})", d.lanes);
    let changed = d.v[slot as usize * d.lanes + lane] != value;
    d.poke_lane(slot, lane, value);
    if changed {
        tracker.note_slot_changed(slot, 1u64 << lane);
        1u64 << lane
    } else {
        0
    }
}

/// Register slot → next-state slot map of the commits, for
/// [`BatchKernel::writer_active_lanes`]: a register's committed value can
/// only differ from the previous cycle's when the group that writes its
/// `next` slot ran. Self-holding registers (`reg == next`) are excluded —
/// their "writer" is the commit itself, which has no GDG group.
fn next_of_reg(commits: &[(u32, u32, u64)]) -> std::collections::HashMap<u32, u32> {
    commits
        .iter()
        .filter(|&&(reg, next, _)| reg != next)
        .map(|&(reg, next, _)| (reg, next))
        .collect()
}

// ------------------------------------------------------ NU / PSU (sparse)

/// Evaluate one (layer, op-type) group over the active lanes only,
/// writing output slots directly (levelization guarantees no same-layer
/// consumer, so the dense executors' LO staging is unnecessary). The
/// opcode dispatch happens once per group ([`kop_dispatch`]); a full
/// mask takes the tiled in-place lane loop, a partial mask bit-iterates
/// the active lanes.
fn run_group_sparse(
    grp: &Group,
    mask: u64,
    full: u64,
    lanes: usize,
    v: &mut [u64],
    c: &OimArrays,
    chain_buf: &mut [u64],
) {
    let op0 = grp.op_start as usize;
    let cnt = grp.ops();
    let r = &c.r_coords[grp.r_start as usize..];
    let s = &c.s_coords[op0..op0 + cnt];
    let imm = &c.imm[op0..];
    let msk = &c.mask[op0..];
    let aux = &c.aux[op0..];
    let arity = &c.arity[op0..];
    macro_rules! un {
        ($f:expr) => {{
            let f = $f;
            for i in 0..cnt {
                let ab = r[i] as usize * lanes;
                let ob = s[i] as usize * lanes;
                let (im, ax) = (imm[i], aux[i]);
                if mask == full {
                    tile::un_ip(v, ab, ob, lanes, msk[i], move |a| f(a, im, ax));
                } else {
                    let mut rem = mask;
                    while rem != 0 {
                        let l = rem.trailing_zeros() as usize;
                        rem &= rem - 1;
                        v[ob + l] = f(v[ab + l], im, ax) & msk[i];
                    }
                }
            }
        }};
    }
    macro_rules! bin {
        ($f:expr) => {{
            let f = $f;
            for i in 0..cnt {
                let ab = r[2 * i] as usize * lanes;
                let bb = r[2 * i + 1] as usize * lanes;
                let ob = s[i] as usize * lanes;
                let im = imm[i];
                if mask == full {
                    tile::bin_ip(v, ab, bb, ob, lanes, msk[i], move |a, b| f(a, b, im));
                } else {
                    let mut rem = mask;
                    while rem != 0 {
                        let l = rem.trailing_zeros() as usize;
                        rem &= rem - 1;
                        v[ob + l] = f(v[ab + l], v[bb + l], im) & msk[i];
                    }
                }
            }
        }};
    }
    macro_rules! mux {
        () => {{
            for i in 0..cnt {
                let sb = r[3 * i] as usize * lanes;
                let tb = r[3 * i + 1] as usize * lanes;
                let fb = r[3 * i + 2] as usize * lanes;
                let ob = s[i] as usize * lanes;
                if mask == full {
                    tile::mux_ip(v, sb, tb, fb, ob, lanes, msk[i]);
                } else {
                    let mut rem = mask;
                    while rem != 0 {
                        let l = rem.trailing_zeros() as usize;
                        rem &= rem - 1;
                        v[ob + l] =
                            (if v[sb + l] != 0 { v[tb + l] } else { v[fb + l] }) & msk[i];
                    }
                }
            }
        }};
    }
    macro_rules! chain {
        () => {{
            let mut r_off = 0usize;
            for i in 0..cnt {
                let ar = arity[i] as usize;
                let k = imm[i] as usize;
                let ob = s[i] as usize * lanes;
                for_lanes!(mask, full, lanes, l, {
                    for o in 0..ar {
                        chain_buf[o] = v[r[r_off + o] as usize * lanes + l];
                    }
                    let mut val = chain_buf[2 * k];
                    for j in (0..k).rev() {
                        if chain_buf[2 * j] != 0 {
                            val = chain_buf[2 * j + 1];
                        }
                    }
                    v[ob + l] = val & msk[i];
                });
                r_off += ar;
            }
        }};
    }
    kop_dispatch!(KOp::from_u8(grp.opcode), un, bin, mux, chain)
}

/// Sparse **NU / PSU**: the format-C group walk gated by per-group lane
/// activity masks. As in the dense pair, the NU and PSU flavours share
/// one executor and differ only in the reported name.
pub struct SparseNuBatch {
    name: &'static str,
    d: BatchDriver,
    oim: Oim,
    tracker: ActivityTracker,
    chain_buf: Vec<u64>,
    /// reg slot → next slot (see [`next_of_reg`])
    reg_next: std::collections::HashMap<u32, u32>,
    /// union of all change sources of the last step ([`WaveMasks::changed`])
    live: u64,
    /// lanes poked out of band since the previous step ([`WaveMasks::recheck`])
    recheck: u64,
    /// poke accumulator, drained into `recheck` at the next step
    poked: u64,
}

impl SparseNuBatch {
    pub fn new(ir: &LayerIr, oim: &Oim, lanes: usize, name: &'static str) -> Self {
        let gdg = GroupDepGraph::build(ir, oim);
        let tracker = ActivityTracker::new(gdg, ir.input_slots.len(), ir.commits.len(), lanes);
        let max_arity = oim.c.arity.iter().copied().max().unwrap_or(1) as usize;
        SparseNuBatch {
            name,
            d: BatchDriver::new(ir, lanes),
            oim: oim.clone(),
            tracker,
            chain_buf: vec![0; max_arity.max(3)],
            reg_next: next_of_reg(&ir.commits),
            live: 0,
            recheck: 0,
            poked: 0,
        }
    }

    pub fn new_nu(ir: &LayerIr, oim: &Oim, lanes: usize) -> Self {
        Self::new(ir, oim, lanes, "NU")
    }

    pub fn new_psu(ir: &LayerIr, oim: &Oim, lanes: usize) -> Self {
        Self::new(ir, oim, lanes, "PSU")
    }
}

impl BatchKernel for SparseNuBatch {
    fn config_name(&self) -> &'static str {
        self.name
    }

    fn lanes(&self) -> usize {
        self.d.lanes
    }

    fn step(&mut self, inputs: &[u64]) {
        self.d.set_inputs_tracked(inputs, &mut self.tracker.input_changed);
        // union of every change source this cycle, for WaveMasks::changed:
        // input boundary bits must be read here (begin_cycle consumes them)
        let mut live: u64 = self.tracker.input_changed.iter().fold(0, |a, &m| a | m);
        self.recheck = std::mem::take(&mut self.poked);
        live |= self.recheck;
        self.tracker.begin_cycle();
        let lanes = self.d.lanes;
        let full = self.tracker.full;
        let o = &self.oim;
        let v = &mut self.d.v;
        for (g, grp) in self.tracker.gdg.groups.iter().enumerate() {
            let mask = self.tracker.active[g];
            if mask == 0 {
                continue;
            }
            live |= mask;
            run_group_sparse(grp, mask, full, lanes, v, &o.c, &mut self.chain_buf);
        }
        self.d.commit_tracked(&mut self.tracker.reg_changed);
        self.live = live | self.tracker.reg_changed.iter().fold(0, |a, &m| a | m);
    }

    fn slots(&self) -> &[u64] {
        &self.d.v
    }

    fn lane_outputs(&self, lane: usize) -> Vec<(String, u64)> {
        self.d.lane_outputs(lane)
    }

    fn write_lane_outputs(&self, lane: usize, buf: &mut Vec<(String, u64)>) {
        self.d.write_lane_outputs(lane, buf);
    }

    fn poke_lane(&mut self, slot: u32, lane: usize, value: u64) {
        self.poked |= poke_lane_tracked(&mut self.d, &mut self.tracker, slot, lane, value);
    }

    fn activity_stats(&self) -> Option<ActivityStats> {
        Some(self.tracker.stats())
    }

    fn wave_masks(&self) -> Option<WaveMasks<'_>> {
        Some(WaveMasks {
            gdg: &self.tracker.gdg,
            active: &self.tracker.active,
            reg_changed: &self.tracker.reg_changed,
            changed: self.live,
            recheck: self.recheck,
        })
    }

    fn restore_slots(&mut self, slots: &[u64]) -> Result<(), String> {
        self.d.restore_slots(slots)?;
        // Without the matching tracker state the cached masks are stale;
        // recold so the next cycle re-establishes everything. A following
        // import_activity overwrites this with the exact snapshot state.
        self.tracker.force_recold();
        Ok(())
    }

    fn export_activity(&self) -> Option<Vec<u64>> {
        Some(self.tracker.export_state())
    }

    fn import_activity(&mut self, data: &[u64]) -> Result<(), String> {
        self.tracker.import_state(data)
    }

    fn writer_active_lanes(&self, slot: u32) -> Option<u64> {
        let next = *self.reg_next.get(&slot)?;
        let g = self.tracker.gdg.writer_of(next)?;
        Some(self.tracker.active[g as usize])
    }
}

// --------------------------------------------------------------- TI (sparse)

/// Masked tape function: like the dense tape functions of
/// [`super::batch`], plus the active-lane mask.
type SpFn = fn(&mut [u64], &OpRec, &[u32], usize, u64, u64);

// The sp_* bodies below intentionally mirror the dense bt_* set in
// `super::batch` one for one (a full mask takes the same tiled in-place
// loop; only the partial-mask bit-iteration differs): the dense TI hot
// path stays branch-free, and any semantic drift between the two sets is
// caught by the sparse-vs-dense bit-identity property test at toggle
// rate 1.0, where every mask is full.
macro_rules! sp_bin {
    ($name:ident, |$a:ident, $b:ident| $expr:expr) => {
        fn $name(v: &mut [u64], r: &OpRec, _e: &[u32], lanes: usize, mask: u64, full: u64) {
            let ab = r.a as usize * lanes;
            let bb = r.b as usize * lanes;
            let ob = r.out as usize * lanes;
            if mask == full {
                tile::bin_ip(v, ab, bb, ob, lanes, r.mask, |$a, $b| $expr);
            } else {
                let mut rem = mask;
                while rem != 0 {
                    let l = rem.trailing_zeros() as usize;
                    rem &= rem - 1;
                    let $a = v[ab + l];
                    let $b = v[bb + l];
                    v[ob + l] = ($expr) & r.mask;
                }
            }
        }
    };
}
macro_rules! sp_un {
    ($name:ident, |$a:ident, $r:ident| $expr:expr) => {
        fn $name(v: &mut [u64], $r: &OpRec, _e: &[u32], lanes: usize, mask: u64, full: u64) {
            let ab = $r.a as usize * lanes;
            let ob = $r.out as usize * lanes;
            if mask == full {
                tile::un_ip(v, ab, ob, lanes, $r.mask, |$a| $expr);
            } else {
                let mut rem = mask;
                while rem != 0 {
                    let l = rem.trailing_zeros() as usize;
                    rem &= rem - 1;
                    let $a = v[ab + l];
                    v[ob + l] = ($expr) & $r.mask;
                }
            }
        }
    };
}

sp_bin!(sp_add, |a, b| a.wrapping_add(b));
sp_bin!(sp_sub, |a, b| a.wrapping_sub(b));
sp_bin!(sp_mul, |a, b| a.wrapping_mul(b));
sp_bin!(sp_div, |a, b| if b == 0 { 0 } else { a / b });
sp_bin!(sp_rem, |a, b| if b == 0 { 0 } else { a % b });
sp_bin!(sp_lt, |a, b| (a < b) as u64);
sp_bin!(sp_leq, |a, b| (a <= b) as u64);
sp_bin!(sp_gt, |a, b| (a > b) as u64);
sp_bin!(sp_geq, |a, b| (a >= b) as u64);
sp_bin!(sp_eq, |a, b| (a == b) as u64);
sp_bin!(sp_neq, |a, b| (a != b) as u64);
sp_bin!(sp_and, |a, b| a & b);
sp_bin!(sp_or, |a, b| a | b);
sp_bin!(sp_xor, |a, b| a ^ b);
sp_bin!(sp_dshl, |a, b| if b >= 64 { 0 } else { a << b });
sp_bin!(sp_dshr, |a, b| if b >= 64 { 0 } else { a >> b });
sp_un!(sp_not, |a, _r| !a);
sp_un!(sp_neg, |a, _r| a.wrapping_neg());
sp_un!(sp_andr, |a, r| (a == r.aux) as u64);
sp_un!(sp_orr, |a, _r| (a != 0) as u64);
sp_un!(sp_xorr, |a, _r| (a.count_ones() & 1) as u64);
sp_un!(sp_shli, |a, r| a << r.imm);
sp_un!(sp_shri, |a, r| a >> r.imm);
sp_un!(sp_copy, |a, _r| a);

fn sp_cat(v: &mut [u64], r: &OpRec, _e: &[u32], lanes: usize, mask: u64, full: u64) {
    let ab = r.a as usize * lanes;
    let bb = r.b as usize * lanes;
    let ob = r.out as usize * lanes;
    if mask == full {
        let imm = r.imm;
        tile::bin_ip(v, ab, bb, ob, lanes, r.mask, move |a, b| (a << imm) | b);
    } else {
        let mut rem = mask;
        while rem != 0 {
            let l = rem.trailing_zeros() as usize;
            rem &= rem - 1;
            v[ob + l] = ((v[ab + l] << r.imm) | v[bb + l]) & r.mask;
        }
    }
}

fn sp_mux(v: &mut [u64], r: &OpRec, _e: &[u32], lanes: usize, mask: u64, full: u64) {
    let sb = r.a as usize * lanes;
    let tb = r.b as usize * lanes;
    let fb = r.c as usize * lanes;
    let ob = r.out as usize * lanes;
    if mask == full {
        tile::mux_ip(v, sb, tb, fb, ob, lanes, r.mask);
    } else {
        let mut rem = mask;
        while rem != 0 {
            let l = rem.trailing_zeros() as usize;
            rem &= rem - 1;
            v[ob + l] = (if v[sb + l] != 0 { v[tb + l] } else { v[fb + l] }) & r.mask;
        }
    }
}

/// Masked mirror of the dense tape's MuxChain: operands are `sel0 = a`,
/// `v0 = b`, then `ext` holds `(sel1, v1, .., default)`.
fn sp_muxchain(v: &mut [u64], r: &OpRec, e: &[u32], lanes: usize, mask: u64, full: u64) {
    let k = r.imm as usize;
    let ob = r.out as usize * lanes;
    let ext = &e[r.ext as usize..r.ext as usize + 2 * k - 1];
    for_lanes!(mask, full, lanes, l, {
        let val = if v[r.a as usize * lanes + l] != 0 {
            v[r.b as usize * lanes + l]
        } else {
            let mut x = v[ext[2 * k - 2] as usize * lanes + l];
            for i in (0..k - 1).rev() {
                if v[ext[2 * i] as usize * lanes + l] != 0 {
                    x = v[ext[2 * i + 1] as usize * lanes + l];
                }
            }
            x
        };
        v[ob + l] = val & r.mask;
    });
}

fn sp_fn(op: KOp) -> SpFn {
    match op {
        KOp::Add => sp_add,
        KOp::Sub => sp_sub,
        KOp::Mul => sp_mul,
        KOp::Div => sp_div,
        KOp::Rem => sp_rem,
        KOp::Lt => sp_lt,
        KOp::Leq => sp_leq,
        KOp::Gt => sp_gt,
        KOp::Geq => sp_geq,
        KOp::Eq => sp_eq,
        KOp::Neq => sp_neq,
        KOp::And => sp_and,
        KOp::Or => sp_or,
        KOp::Xor => sp_xor,
        KOp::Not => sp_not,
        KOp::Neg => sp_neg,
        KOp::AndrK => sp_andr,
        KOp::Orr => sp_orr,
        KOp::Xorr => sp_xorr,
        KOp::ShlI => sp_shli,
        KOp::ShrI => sp_shri,
        KOp::Dshl => sp_dshl,
        KOp::Dshr => sp_dshr,
        KOp::Cat => sp_cat,
        KOp::Mux => sp_mux,
        KOp::Copy => sp_copy,
        KOp::MuxChain => sp_muxchain,
    }
}

/// Sparse **TI**: the precompiled per-opcode tape, cut into (layer,
/// op-type) segments so a quiescent group skips its whole tape run; a
/// partially active group replays its segment over the set mask bits
/// only. The tape is in format-C order (as the dense tape is), so segment
/// boundaries coincide with the GDG's group op ranges.
pub struct SparseTiBatch {
    d: BatchDriver,
    tape: Vec<(SpFn, OpRec)>,
    ext_args: Vec<u32>,
    /// tape range per GDG group (parallel to `tracker.gdg.groups`)
    ranges: Vec<(u32, u32)>,
    tracker: ActivityTracker,
    /// reg slot → next slot (see [`next_of_reg`])
    reg_next: std::collections::HashMap<u32, u32>,
    /// union of all change sources of the last step ([`WaveMasks::changed`])
    live: u64,
    /// lanes poked out of band since the previous step ([`WaveMasks::recheck`])
    recheck: u64,
    /// poke accumulator, drained into `recheck` at the next step
    poked: u64,
}

impl SparseTiBatch {
    pub fn new(ir: &LayerIr, oim: &Oim, lanes: usize) -> Self {
        let gdg = GroupDepGraph::build(ir, oim);
        let (layers, ext_args) = oim.op_recs();
        let mut tape = Vec::with_capacity(ir.total_ops());
        for layer in &layers {
            for rec in layer {
                tape.push((sp_fn(rec.kop()), *rec));
            }
        }
        let ranges: Vec<(u32, u32)> = gdg.groups.iter().map(|g| (g.op_start, g.op_end)).collect();
        debug_assert_eq!(ranges.last().map(|&(_, e)| e as usize).unwrap_or(0), tape.len());
        let tracker = ActivityTracker::new(gdg, ir.input_slots.len(), ir.commits.len(), lanes);
        SparseTiBatch {
            d: BatchDriver::new(ir, lanes),
            tape,
            ext_args,
            ranges,
            tracker,
            reg_next: next_of_reg(&ir.commits),
            live: 0,
            recheck: 0,
            poked: 0,
        }
    }
}

impl BatchKernel for SparseTiBatch {
    fn config_name(&self) -> &'static str {
        "TI"
    }

    fn lanes(&self) -> usize {
        self.d.lanes
    }

    fn step(&mut self, inputs: &[u64]) {
        self.d.set_inputs_tracked(inputs, &mut self.tracker.input_changed);
        // see SparseNuBatch::step — same WaveMasks::changed accumulation
        let mut live: u64 = self.tracker.input_changed.iter().fold(0, |a, &m| a | m);
        self.recheck = std::mem::take(&mut self.poked);
        live |= self.recheck;
        self.tracker.begin_cycle();
        let lanes = self.d.lanes;
        let full = self.tracker.full;
        let v = &mut self.d.v;
        for (g, &(start, end)) in self.ranges.iter().enumerate() {
            let mask = self.tracker.active[g];
            if mask == 0 {
                continue;
            }
            live |= mask;
            for (f, rec) in &self.tape[start as usize..end as usize] {
                f(v, rec, &self.ext_args, lanes, mask, full);
            }
        }
        self.d.commit_tracked(&mut self.tracker.reg_changed);
        self.live = live | self.tracker.reg_changed.iter().fold(0, |a, &m| a | m);
    }

    fn slots(&self) -> &[u64] {
        &self.d.v
    }

    fn lane_outputs(&self, lane: usize) -> Vec<(String, u64)> {
        self.d.lane_outputs(lane)
    }

    fn write_lane_outputs(&self, lane: usize, buf: &mut Vec<(String, u64)>) {
        self.d.write_lane_outputs(lane, buf);
    }

    fn poke_lane(&mut self, slot: u32, lane: usize, value: u64) {
        self.poked |= poke_lane_tracked(&mut self.d, &mut self.tracker, slot, lane, value);
    }

    fn activity_stats(&self) -> Option<ActivityStats> {
        Some(self.tracker.stats())
    }

    fn wave_masks(&self) -> Option<WaveMasks<'_>> {
        Some(WaveMasks {
            gdg: &self.tracker.gdg,
            active: &self.tracker.active,
            reg_changed: &self.tracker.reg_changed,
            changed: self.live,
            recheck: self.recheck,
        })
    }

    fn restore_slots(&mut self, slots: &[u64]) -> Result<(), String> {
        self.d.restore_slots(slots)?;
        self.tracker.force_recold();
        Ok(())
    }

    fn export_activity(&self) -> Option<Vec<u64>> {
        Some(self.tracker.export_state())
    }

    fn import_activity(&mut self, data: &[u64]) -> Result<(), String> {
        self.tracker.import_state(data)
    }

    fn writer_active_lanes(&self, slot: u32) -> Option<u64> {
        let next = *self.reg_next.get(&slot)?;
        let g = self.tracker.gdg.writer_of(next)?;
        Some(self.tracker.active[g as usize])
    }
}

#[cfg(test)]
mod tests {
    use super::super::{build_batch, build_sparse, BatchKernel, SPARSE_KERNELS};
    use crate::graph::builder::{random_circuit, random_inputs};
    use crate::graph::passes::optimize;
    use crate::tensor::ir::lower;
    use crate::tensor::oim::Oim;
    use crate::util::prng::Rng;

    /// In-module smoke test (the toggle-rate matrix lives in
    /// `tests/kernels_property.rs`): sparse executors match their dense
    /// counterparts on a random circuit under random stimulus.
    #[test]
    fn sparse_matches_dense_smoke() {
        let mut rng = Rng::new(88_010);
        let g = random_circuit(&mut rng, 60);
        let (opt, _) = optimize(&g);
        let ir = lower(&opt);
        let oim = Oim::from_ir(&ir);
        let lanes = 5usize;
        for cfg in SPARSE_KERNELS {
            let mut dense = build_batch(cfg, &ir, &oim, lanes);
            let mut sparse = build_sparse(cfg, &ir, &oim, lanes);
            for cycle in 0..8 {
                let mut flat = vec![0u64; opt.inputs.len() * lanes];
                for l in 0..lanes {
                    for (i, &val) in random_inputs(&mut rng, &opt).iter().enumerate() {
                        flat[i * lanes + l] = val;
                    }
                }
                dense.step(&flat);
                sparse.step(&flat);
                assert_eq!(
                    sparse.slots(),
                    dense.slots(),
                    "{} slot files diverged at cycle {cycle}",
                    cfg.name()
                );
            }
            let stats = sparse.activity_stats().expect("sparse kernels report stats");
            assert_eq!(stats.cycles, 8);
            assert_eq!(stats.total_op_lanes, (ir.total_ops() * lanes * 8) as u64);
        }
    }

    /// A design that goes idle drives the skip machinery: after the
    /// stimulus freezes, whole cycles cost zero evaluated op-lanes, and a
    /// change in one lane re-evaluates only that lane.
    #[test]
    fn quiescent_lanes_are_skipped() {
        use crate::graph::ops::PrimOp;
        let mut g = crate::graph::Graph::new("cone");
        let a = g.input("a", 8);
        let x = g.prim(PrimOp::Not, &[a]);
        let y = g.prim(PrimOp::Neg, &[x]);
        g.output("y", y);
        let ir = lower(&g);
        let oim = Oim::from_ir(&ir);
        let lanes = 4usize;
        let ops = ir.total_ops() as u64; // 2
        for cfg in SPARSE_KERNELS {
            let mut k = build_sparse(cfg, &ir, &oim, lanes);
            let frozen = vec![7u64; lanes];
            for _ in 0..10 {
                k.step(&frozen);
            }
            let s = k.activity_stats().unwrap();
            // only the cold first cycle evaluates anything
            assert_eq!(s.evaluated_op_lanes, ops * lanes as u64, "{}", cfg.name());
            assert_eq!(s.total_op_lanes, ops * lanes as u64 * 10, "{}", cfg.name());
            assert!(s.skip_rate() > 0.85, "{}", cfg.name());
            // waking one lane evaluates exactly that lane
            let mut poke = frozen.clone();
            poke[2] = 9;
            k.step(&poke);
            let after = k.activity_stats().unwrap().since(&s);
            assert_eq!(after.evaluated_op_lanes, ops, "{} one active lane", cfg.name());
            // and the woken lane's outputs are correct
            assert_eq!(k.lane_outputs(2)[0].1, (!9u64).wrapping_neg() & 0xFF);
            assert_eq!(k.lane_outputs(0)[0].1, (!7u64).wrapping_neg() & 0xFF);
        }
    }

    /// Targeted poke invalidation: an out-of-band `poke_lane` on a
    /// quiescent run evaluates, on the next cycle, exactly the poked
    /// slot's GDG descendants in exactly the poked lane — one op-lane
    /// here, not the `ops × lanes` a recold used to cost — while staying
    /// bit-identical to a dense run given the same poke.
    #[test]
    fn poke_lane_wakes_only_descendants_in_the_poked_lane() {
        use crate::graph::ops::PrimOp;
        let mut g = crate::graph::Graph::new("poke");
        let a = g.input("a", 8);
        let x = g.prim(PrimOp::Not, &[a]); // cone A: 2 ops off the input
        let y = g.prim(PrimOp::Neg, &[x]);
        g.output("y", y);
        let r = g.reg("r", 8, 3);
        let z = g.prim(PrimOp::Orr, &[r]); // cone R: 1 op off the register
        g.connect_reg(r, r); // self-holding: only a poke can change r
        g.output("z", z);
        let ir = lower(&g);
        let oim = Oim::from_ir(&ir);
        let lanes = 4usize;
        let reg_slot = ir.commits[0].0;
        for cfg in SPARSE_KERNELS {
            let mut sparse = build_sparse(cfg, &ir, &oim, lanes);
            let mut dense = build_batch(cfg, &ir, &oim, lanes);
            let frozen = vec![5u64; lanes];
            for _ in 0..4 {
                sparse.step(&frozen);
                dense.step(&frozen);
            }
            let before = sparse.activity_stats().unwrap();
            sparse.poke_lane(reg_slot, 2, 0);
            dense.poke_lane(reg_slot, 2, 0);
            sparse.step(&frozen);
            dense.step(&frozen);
            let after = sparse.activity_stats().unwrap().since(&before);
            assert_eq!(
                after.evaluated_op_lanes,
                1,
                "{}: only the register's reader group, only lane 2",
                cfg.name()
            );
            assert_eq!(sparse.slots(), dense.slots(), "{}: poke result", cfg.name());
            // an equal-value poke is a no-op: nothing wakes at all
            let quiet = sparse.activity_stats().unwrap();
            sparse.poke_lane(reg_slot, 2, 0);
            sparse.step(&frozen);
            let after = sparse.activity_stats().unwrap().since(&quiet);
            assert_eq!(after.evaluated_op_lanes, 0, "{}: no-change poke", cfg.name());
        }
    }
}
