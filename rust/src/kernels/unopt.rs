//! Unoptimized kernel variant — the `clang -O0` analog (paper §7.4).
//!
//! The paper studies how much each simulator depends on aggressive
//! compiler optimization by rebuilding everything at `-O0`. Our executors
//! are compiled once, so the analog is an executor written the way `-O0`
//! code behaves: every intermediate value round-trips through memory, each
//! op re-derives everything from scratch (fresh operand `Vec` per op —
//! an allocation per operation), dispatch goes through a boxed callable
//! (no inlining), and nothing is grouped or chunked.

use super::common::{eval_op, Driver};
use super::SimKernel;
use crate::tensor::ir::{KOp, LayerIr};
use crate::tensor::oim::Oim;

type DynOp = Box<dyn Fn(&[u64], u8, u64, u64) -> u64 + Send + Sync>;

pub struct UnoptKernel {
    d: Driver,
    oim: Oim,
    /// one boxed evaluator per op type — the un-inlined dispatch table
    table: Vec<DynOp>,
}

impl UnoptKernel {
    pub fn new(ir: &LayerIr, oim: &Oim) -> Self {
        let table: Vec<DynOp> = (0..crate::tensor::ir::NUM_KOPS as u8)
            .map(|n| {
                let op = KOp::from_u8(n);
                Box::new(move |operands: &[u64], imm: u8, mask: u64, aux: u64| {
                    eval_op(op, operands, imm, mask, aux)
                }) as DynOp
            })
            .collect();
        UnoptKernel { d: Driver::new(ir), oim: oim.clone(), table }
    }
}

impl SimKernel for UnoptKernel {
    fn config_name(&self) -> &'static str {
        "PSU-O0"
    }

    fn step(&mut self, inputs: &[u64]) {
        self.d.set_inputs(inputs);
        let o = &self.oim;
        let mut op_idx = 0usize;
        let mut r_idx = 0usize;
        let mut wb_idx = 0usize;
        for &cnt in &o.i_payload {
            // LO allocated fresh every layer (-O0 keeps temporaries in memory)
            let mut lo: Vec<u64> = Vec::with_capacity(cnt as usize);
            for _ in 0..cnt {
                let arity = o.b.arity[op_idx] as usize;
                // fresh operand vector per op: the malloc-per-op behaviour
                let mut operands: Vec<u64> = Vec::with_capacity(arity);
                for oo in 0..arity {
                    operands.push(self.d.v[o.b.r_coords[r_idx + oo] as usize]);
                }
                let f = &self.table[o.b.opcode[op_idx] as usize];
                lo.push(f(&operands, o.b.imm[op_idx], o.b.mask[op_idx], o.b.aux[op_idx]));
                r_idx += arity;
                op_idx += 1;
            }
            for (s, val) in lo.iter().enumerate() {
                self.d.v[o.b.s_coords[wb_idx + s] as usize] = *val;
            }
            wb_idx += cnt as usize;
        }
        self.d.commit();
    }

    fn slots(&self) -> &[u64] {
        &self.d.v
    }

    fn outputs(&self) -> Vec<(String, u64)> {
        self.d.named_outputs()
    }


    fn poke(&mut self, slot: u32, value: u64) {
        self.d.v[slot as usize] = value;
    }

    fn program_bytes(&self) -> usize {
        // -O0 binaries are a few x larger than -O2/-O3 for the same code
        crate::perf::binsize::kernel_code_bytes(super::KernelConfig::PSU, &self.oim) * 3
    }

    fn data_bytes(&self) -> usize {
        crate::perf::binsize::kernel_data_bytes(super::KernelConfig::PSU, &self.oim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::{random_circuit, random_inputs};
    use crate::graph::passes::optimize;
    use crate::graph::RefSim;
    use crate::tensor::ir::lower;
    use crate::util::prng::Rng;

    #[test]
    fn unopt_matches_reference() {
        let mut rng = Rng::new(60_001);
        let g = random_circuit(&mut rng, 70);
        let (opt, _) = optimize(&g);
        let ir = lower(&opt);
        let oim = Oim::from_ir(&ir);
        let mut reference = RefSim::new(opt.clone());
        let mut k = UnoptKernel::new(&ir, &oim);
        for _ in 0..10 {
            let inputs = random_inputs(&mut rng, &reference.graph);
            reference.step(&inputs);
            k.step(&inputs);
            assert_eq!(k.outputs(), reference.outputs());
        }
    }
}
