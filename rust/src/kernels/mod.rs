//! The seven RTeAAL kernel configurations (paper §5.2).
//!
//! The paper's kernels are C++ code-generation variants spanning the
//! binding spectrum from fully rolled to fully unrolled. Here each kernel
//! is a progressively specialized *executor* over a progressively
//! flattened OIM encoding — the binding-level property each step changes
//! (dispatch per element vs per group vs per program; metadata in data
//! arrays vs embedded in the program) is preserved:
//!
//! | kernel | paper                                | here | batched | tiled |
//! |--------|--------------------------------------|------|---------|-------|
//! | RU     | rolled `[I,S,N,O,R]`, per-op case    | cursor walk of format-B arrays, `match` per op, operand loop | [`batch::BatchRuKernel`] | — (per-element dispatch *is* the binding level) |
//! | OU     | + unroll O                           | operand fetches inlined by arity | [`batch::BatchOuKernel`] | — (per-element dispatch *is* the binding level) |
//! | NU     | + S/N swizzle, per-op-type loops     | format-C group walk, dispatch hoisted out of the S loop | [`batch::BatchNuKernel`] | ✓ `[u64; 8]` group bodies |
//! | PSU    | + partial S unroll (8 / 24)          | chunked inner loops (`UNROLL=8`), writeback by 24 | [`batch::BatchNuKernel`] (lane loop replaces the S unroll) | ✓ (shares NU's tiled bodies) |
//! | IU     | + unroll I (drop empty groups)       | flattened group-command program, zero per-layer overhead | [`batch::BatchIuKernel`] | ✓ `[u64; 8]` group bodies |
//! | SU     | + unroll S fully (OIM in binary)     | straight-line op tape — no metadata arrays | [`batch::BatchSuKernel`] | ✓ tiled per-record lane loops |
//! | TI     | + tensor inlining (values in regs)   | tape of precompiled per-op closures, direct slot writes, no LO | [`batch::BatchTiKernel`] | ✓ tiled `bt_*` tape functions |
//!
//! The "tiled" column is the explicit-SIMD axis ([`tile`]): the batched
//! executors' hot lane loops run over fixed-width `[u64; 8]` lane tiles
//! (with a single `[u64; 4]` step and a scalar remainder loop for
//! `B % 8 != 0`) instead of lane-at-a-time closure calls, so the
//! data-level parallelism the tensor formulation exposes is spelled out
//! for the backend rather than left to the auto-vectorizer. Every tiled
//! executor keeps its pre-tile path alive as a *baseline* variant
//! ([`build_batch_baseline`]) for the tiled-vs-autovec sweep points in
//! `BENCH_fig22.json`/`BENCH_fig24.json` and for differential tests; the
//! two paths are bit-identical by the remainder-loop invariant documented
//! in [`tile`]. `MuxChain` (variable arity — no fixed tile shape) and the
//! RU/OU executors (whose per-element dispatch is exactly what their
//! binding level rolls up) stay lane-at-a-time.
//!
//! All kernels implement [`SimKernel`] and are property-tested to agree
//! with `graph::RefSim` and the Einsum cascade evaluator.
//!
//! ## Lane batching (throughput simulation)
//!
//! Because the tensor form decouples behaviour (the OIM) from the program,
//! one walk of the metadata can step `B` independent stimulus lanes at
//! once — many users / test vectors simulated per pass, amortizing the
//! per-op metadata traffic and dispatch that dominate the rolled kernels
//! and the tape walk that dominates the unrolled ones. Batched executors
//! implement [`BatchKernel`] and store every slot file **lane-major**:
//!
//! ```text
//! slots[s * B + lane]   // lane runs fastest: contiguous inner loops
//! ```
//!
//! Inputs follow the same convention (`inputs[i * B + lane]`). Lanes are
//! fully independent: a `B`-lane batched run is bit-identical to `B`
//! single-lane runs of the corresponding scalar kernel (differential
//! property test in `tests/kernels_property.rs`). Every binding level has
//! a batched executor — the "batched" column of the table above — so the
//! Fig 16-style sweep has a complete lane axis (see [`BATCHED_KERNELS`]
//! and [`batch`]); `rteaal sim --lanes B` and `benches/fig22_lanes.rs`
//! drive them, and [`crate::coordinator::parallel::BatchParallelSim`]
//! composes lanes with thread-level partitions (P × B).
//!
//! **Partitioning** (one more row on the binding table, orthogonal to
//! it): every batched executor above also serves as the per-partition
//! engine of the partitioned simulator — [`crate::partition`] assigns
//! register ownership (round-robin or multilevel hypergraph min-cut,
//! `rteaal sim --parts P --partitioner {rr,mincut}`), each partition
//! compiles its replicated cone through the *same* kernel constructors
//! over a filtered `LayerIr`, and a persistent worker pool steps them
//! with a differential RUM exchange per cycle. A kernel needs no
//! partition awareness beyond [`BatchKernel::poke_lane`], which the RUM
//! uses to write cut registers into reader partitions.
//!
//! ## Sparse activity masking (dynamic sparsity)
//!
//! The OIM occupancy is *static* sparsity; real workloads add *dynamic*
//! sparsity — most signals don't toggle most cycles. The sparse batched
//! executors ([`batch_sparse`], see [`SPARSE_KERNELS`] and
//! [`build_sparse`]) exploit it with three pieces from the
//! [`crate::activity`] subsystem:
//!
//! * **Group dependency graph (GDG)** — computed once at compile time
//!   from the format-C `r_coords`/`s_coords`: for every (layer, op-type)
//!   group, the upstream groups, input ports and register slots whose
//!   writes can change its inputs.
//! * **Lane activity masks** — one `u64` per group, one bit per lane
//!   (`B ≤ 64`). Change detection happens only at the cycle boundaries:
//!   the driver's tracked input writes and register commits compare old
//!   vs new per lane and set the changed bits; masks then propagate
//!   forward through the GDG, so a group is active in lane `l` exactly
//!   when a boundary source it transitively depends on changed in `l`.
//! * **Masked group bodies** — a zero-mask group is skipped outright; a
//!   partial mask runs bit-iterated over the active lanes; a full mask
//!   takes the same contiguous vectorizable loop as the dense executor.
//!
//! Out-of-band slot writes ([`BatchKernel::poke_lane`] — divergent-lane
//! init and the partitioned RUM exchange) bypass the boundary detectors;
//! they use **targeted invalidation** instead: the GDG carries a
//! slot → direct-reader-groups index
//! ([`crate::activity::GroupDepGraph::readers_of`]), and
//! [`crate::activity::ActivityTracker::note_slot_changed`] marks the
//! written slot's readers pending in the written lane so the next
//! propagation sweep wakes exactly its transitive descendants — a poke no
//! longer recolds every group in every lane.
//!
//! Skipping is exact, not approximate: operations are pure functions of
//! their operand slots, so a (group, lane) with no changed transitive
//! source holds slot values identical to what re-evaluation would
//! produce. Sparse runs are therefore bit-identical to dense batched
//! runs at any toggle rate (property-tested in
//! `tests/kernels_property.rs`), and [`BatchKernel::activity_stats`]
//! reports the realized skip rate (`rteaal sim --lanes B --sparse`,
//! `benches/fig23_sparse.rs`).
//!
//! The sparse executors also run **inside partitions**: a sparse
//! partitioned run (`rteaal sim --parts P --lanes B --sparse` with a
//! kernel from [`SPARSE_KERNELS`]) builds one sparse executor per
//! partition, the RUM exchange feeds each destination partition's group
//! tracker its per-register per-lane change bits through the targeted
//! `poke_lane`, and partition-level skipping
//! ([`crate::activity::PartitionTracker`]) composes with group-level
//! skipping in one run — quiescent partitions are skipped whole,
//! quiescent groups are skipped inside the partitions that do step
//! (`BatchParallelSim::group_stats` reports the composed op-lane skip
//! rate alongside the partition-cycle rate).
//!
//! This is the classically-unprofitable event-driven idea
//! ([`crate::baselines::event_driven`]) made profitable by the batch
//! dimension: one activity decision per group amortizes over `B` lanes,
//! and the per-op dirty worklist collapses into `O(groups)` mask words.

pub mod common;
pub mod tile;
pub mod ru;
pub mod ou;
pub mod nu;
pub mod iu;
pub mod su;
pub mod ti;
pub mod unopt;
pub mod batch;
pub mod batch_sparse;

use crate::tensor::ir::LayerIr;
use crate::tensor::oim::Oim;

/// Kernel configuration identifier (paper naming).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum KernelConfig {
    RU,
    OU,
    NU,
    PSU,
    IU,
    SU,
    TI,
}

pub const ALL_KERNELS: [KernelConfig; 7] = [
    KernelConfig::RU,
    KernelConfig::OU,
    KernelConfig::NU,
    KernelConfig::PSU,
    KernelConfig::IU,
    KernelConfig::SU,
    KernelConfig::TI,
];

impl KernelConfig {
    pub fn name(self) -> &'static str {
        match self {
            KernelConfig::RU => "RU",
            KernelConfig::OU => "OU",
            KernelConfig::NU => "NU",
            KernelConfig::PSU => "PSU",
            KernelConfig::IU => "IU",
            KernelConfig::SU => "SU",
            KernelConfig::TI => "TI",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_uppercase().as_str() {
            "RU" => Some(KernelConfig::RU),
            "OU" => Some(KernelConfig::OU),
            "NU" => Some(KernelConfig::NU),
            "PSU" => Some(KernelConfig::PSU),
            "IU" => Some(KernelConfig::IU),
            "SU" => Some(KernelConfig::SU),
            "TI" => Some(KernelConfig::TI),
            _ => None,
        }
    }
}

/// A compiled simulation kernel: drive inputs, advance one cycle, observe.
/// `Send` so partitioned simulation can move kernels across threads.
pub trait SimKernel: Send {
    fn config_name(&self) -> &'static str;
    /// Simulate one cycle (inputs in port order, masked by the kernel).
    fn step(&mut self, inputs: &[u64]);
    /// The LI slot file after the last step.
    fn slots(&self) -> &[u64];
    /// Named design outputs.
    fn outputs(&self) -> Vec<(String, u64)>;
    /// Write a slot directly (partitioned simulation uses this for the
    /// RUM synchronization step — Cascade 2's final Einsum).
    fn poke(&mut self, slot: u32, value: u64);
    /// Modeled program ("binary") bytes: code plus any OIM embedded in it.
    fn program_bytes(&self) -> usize;
    /// Modeled metadata ("data") bytes streamed per cycle.
    fn data_bytes(&self) -> usize;
}

/// Build a kernel of the given configuration from the lowered design.
pub fn build(config: KernelConfig, ir: &LayerIr) -> Box<dyn SimKernel> {
    let oim = Oim::from_ir(ir);
    build_with_oim(config, ir, &oim)
}

/// Build from a pre-constructed OIM (avoids re-deriving it in sweeps).
pub fn build_with_oim(config: KernelConfig, ir: &LayerIr, oim: &Oim) -> Box<dyn SimKernel> {
    match config {
        KernelConfig::RU => Box::new(ru::RuKernel::new(ir, oim)),
        KernelConfig::OU => Box::new(ou::OuKernel::new(ir, oim)),
        KernelConfig::NU => Box::new(nu::NuKernel::<1>::new(ir, oim)),
        KernelConfig::PSU => Box::new(nu::NuKernel::<8>::new(ir, oim)),
        KernelConfig::IU => Box::new(iu::IuKernel::new(ir, oim)),
        KernelConfig::SU => Box::new(su::SuKernel::new(ir, oim)),
        KernelConfig::TI => Box::new(ti::TiKernel::new(ir, oim)),
    }
}

/// A lane-batched simulation kernel: `B` independent stimulus lanes step
/// together through one walk of the OIM metadata / tape. Slot files and
/// inputs are lane-major (see the module docs).
pub trait BatchKernel: Send {
    fn config_name(&self) -> &'static str;
    /// Number of lanes `B`.
    fn lanes(&self) -> usize;
    /// Simulate one cycle for every lane. `inputs[i * lanes + lane]` is
    /// input port `i` of `lane` (masked by the kernel).
    fn step(&mut self, inputs: &[u64]);
    /// The lane-major LI slot file (`slots[s * lanes + lane]`).
    fn slots(&self) -> &[u64];
    /// Named design outputs as observed by one lane.
    fn lane_outputs(&self, lane: usize) -> Vec<(String, u64)>;
    /// [`Self::lane_outputs`] into a reusable buffer for per-cycle sweep
    /// and differential loops. The buffer is **per kernel**: the fast
    /// paths rewrite only the values once it has the right shape, so
    /// reusing one buffer across kernels of different designs can keep
    /// the previous design's names. The driver-backed executors override
    /// this with [`common::BatchDriver::write_lane_outputs`]
    /// (allocation-free; names cloned once); this default merely
    /// delegates to [`Self::lane_outputs`].
    fn write_lane_outputs(&self, lane: usize, buf: &mut Vec<(String, u64)>) {
        *buf = self.lane_outputs(lane);
    }
    /// Write one lane of one slot directly — pre-run initialization of
    /// divergent lanes ([`crate::designs::Design::lane_init`]) and the
    /// partitioned simulator's RUM cut-register pokes. Sparse executors
    /// additionally note the write in their activity tracker (*targeted*
    /// invalidation: the next cycle re-evaluates exactly the written
    /// slot's dependent groups, in the written lane only — see
    /// [`crate::activity::ActivityTracker::note_slot_changed`]).
    fn poke_lane(&mut self, slot: u32, lane: usize, value: u64);
    /// Activity accounting of a sparse executor; `None` on dense ones.
    fn activity_stats(&self) -> Option<crate::activity::ActivityStats> {
        None
    }
    /// Overwrite the entire lane-major slot file from a snapshot captured
    /// via [`Self::slots`] (checkpoint restore). The snapshot must come
    /// from a kernel of the same design and lane count; errors on a
    /// length mismatch rather than panicking so a corrupt snapshot
    /// surfaces as a structured failure.
    fn restore_slots(&mut self, slots: &[u64]) -> Result<(), String>;
    /// Dynamic activity-tracker state of a sparse executor as a flat word
    /// dump (see [`crate::activity::ActivityTracker::export_state`]);
    /// `None` on dense executors, whose only cross-cycle state is the
    /// slot file itself.
    fn export_activity(&self) -> Option<Vec<u64>> {
        None
    }
    /// Restore state captured by [`Self::export_activity`]. Dense
    /// executors accept only an empty dump.
    fn import_activity(&mut self, data: &[u64]) -> Result<(), String> {
        if data.is_empty() {
            Ok(())
        } else {
            Err(format!(
                "dense executor {} has no activity state to restore ({} words given)",
                self.config_name(),
                data.len()
            ))
        }
    }
    /// Borrowed change-mask view of the cycle just stepped, for the
    /// delta-waveform sink ([`crate::sim::wave::WaveSink`]): which groups
    /// evaluated, which commits changed, and which lanes changed at all.
    /// `None` on dense executors, which detect no changes (the sink then
    /// falls back to a full per-var value-diff scan). Valid from the
    /// return of [`Self::step`] until the next `step`/`poke_lane`.
    fn wave_masks(&self) -> Option<crate::activity::WaveMasks<'_>> {
        None
    }
    /// Active-lane mask of the group that computed register `slot`'s
    /// next-state value in the last [`Self::step`] — the RUM exchange's
    /// fast-skip oracle: `Some(0)` proves no lane re-evaluated the
    /// register's writer this cycle, so its committed value cannot differ
    /// from the previous cycle's. `None` means no such proof is available
    /// (dense executor, or no writer group) and the caller must scan.
    fn writer_active_lanes(&self, _slot: u32) -> Option<u64> {
        None
    }
}

/// The kernel configurations with lane-batched executors — since the
/// batched IU/SU executors landed, **all seven** binding levels (PSU
/// shares NU's batched group bodies), so unlike [`supports_sparse`]
/// there is no support gate to check before [`build_batch`].
pub const BATCHED_KERNELS: [KernelConfig; 7] = ALL_KERNELS;

/// Build a lane-batched kernel of the given configuration.
pub fn build_batch(
    config: KernelConfig,
    ir: &LayerIr,
    oim: &Oim,
    lanes: usize,
) -> Box<dyn BatchKernel> {
    match config {
        KernelConfig::RU => Box::new(batch::BatchRuKernel::new(ir, oim, lanes)),
        KernelConfig::OU => Box::new(batch::BatchOuKernel::new(ir, oim, lanes)),
        KernelConfig::NU => Box::new(batch::BatchNuKernel::new(ir, oim, lanes, "NU")),
        KernelConfig::PSU => Box::new(batch::BatchNuKernel::new(ir, oim, lanes, "PSU")),
        KernelConfig::IU => Box::new(batch::BatchIuKernel::new(ir, oim, lanes)),
        KernelConfig::SU => Box::new(batch::BatchSuKernel::new(ir, oim, lanes)),
        KernelConfig::TI => Box::new(batch::BatchTiKernel::new(ir, oim, lanes)),
    }
}

/// Build the pre-tile (auto-vectorized baseline) variant of a lane-batched
/// kernel: the retained lane-at-a-time loops from before the explicit
/// `[u64; 8]` lane tiling, bit-identical to [`build_batch`] and kept for
/// the tiled-vs-baseline sweep points (`BENCH_fig22.json` /
/// `BENCH_fig24.json`) and the remainder-lane differential tests. RU/OU
/// have no tiled path (their per-element dispatch is the binding level),
/// so for them this returns the same executor as [`build_batch`].
pub fn build_batch_baseline(
    config: KernelConfig,
    ir: &LayerIr,
    oim: &Oim,
    lanes: usize,
) -> Box<dyn BatchKernel> {
    match config {
        KernelConfig::RU => Box::new(batch::BatchRuKernel::new(ir, oim, lanes)),
        KernelConfig::OU => Box::new(batch::BatchOuKernel::new(ir, oim, lanes)),
        KernelConfig::NU => Box::new(batch::BatchNuKernel::new_baseline(ir, oim, lanes, "NU")),
        KernelConfig::PSU => Box::new(batch::BatchNuKernel::new_baseline(ir, oim, lanes, "PSU")),
        KernelConfig::IU => Box::new(batch::BatchIuKernel::new_baseline(ir, oim, lanes)),
        KernelConfig::SU => Box::new(batch::BatchSuKernel::new_baseline(ir, oim, lanes)),
        KernelConfig::TI => Box::new(batch::BatchTiKernel::new_baseline(ir, oim, lanes)),
    }
}

/// The kernel configurations with *sparse* (activity-masked) batched
/// executors — the group-walk and tape binding levels, where a (layer,
/// op-type) group is a contiguous unit that can be gated as a whole.
pub const SPARSE_KERNELS: [KernelConfig; 3] =
    [KernelConfig::NU, KernelConfig::PSU, KernelConfig::TI];

/// Whether `config` has a sparse batched executor.
pub fn supports_sparse(config: KernelConfig) -> bool {
    SPARSE_KERNELS.contains(&config)
}

/// Build a sparse (activity-masked) lane-batched kernel; `lanes` must be
/// in `1..=64` (one activity-mask bit per lane). Panics for
/// configurations without one — gate on [`supports_sparse`] first.
pub fn build_sparse(
    config: KernelConfig,
    ir: &LayerIr,
    oim: &Oim,
    lanes: usize,
) -> Box<dyn BatchKernel> {
    match config {
        KernelConfig::NU => Box::new(batch_sparse::SparseNuBatch::new_nu(ir, oim, lanes)),
        KernelConfig::PSU => Box::new(batch_sparse::SparseNuBatch::new_psu(ir, oim, lanes)),
        KernelConfig::TI => Box::new(batch_sparse::SparseTiBatch::new(ir, oim, lanes)),
        other => panic!(
            "kernel {} has no sparse batched executor (supported: NU, PSU, TI)",
            other.name()
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::{random_circuit, random_inputs};
    use crate::graph::passes::optimize;
    use crate::graph::RefSim;
    use crate::tensor::ir::lower;
    use crate::util::prng::Rng;

    /// Every kernel configuration agrees with the reference interpreter on
    /// random optimized circuits — the core correctness property.
    #[test]
    fn all_kernels_match_reference() {
        for seed in 0..8 {
            let mut rng = Rng::new(40_000 + seed);
            let g = random_circuit(&mut rng, 90);
            let (opt, _) = optimize(&g);
            let ir = lower(&opt);
            let mut reference = RefSim::new(opt.clone());
            let mut kernels: Vec<Box<dyn SimKernel>> =
                ALL_KERNELS.iter().map(|&k| build(k, &ir)).collect();
            for cycle in 0..10 {
                let inputs = random_inputs(&mut rng, &reference.graph);
                reference.step(&inputs);
                let want = reference.outputs();
                for k in &mut kernels {
                    k.step(&inputs);
                    assert_eq!(
                        k.outputs(),
                        want,
                        "kernel {} diverged (seed {seed}, cycle {cycle})",
                        k.config_name()
                    );
                }
            }
        }
    }

    /// Program bytes grow monotonically toward the unrolled end while data
    /// bytes shrink — the paper's I-cache/D-cache pressure trade-off.
    #[test]
    fn code_data_tradeoff() {
        let mut rng = Rng::new(999);
        let g = random_circuit(&mut rng, 400);
        let (opt, _) = optimize(&g);
        let ir = lower(&opt);
        let ru = build(KernelConfig::RU, &ir);
        let su = build(KernelConfig::SU, &ir);
        assert!(su.program_bytes() > ru.program_bytes());
        assert!(su.data_bytes() < ru.data_bytes());
    }
}
