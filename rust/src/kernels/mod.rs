//! The seven RTeAAL kernel configurations (paper §5.2).
//!
//! The paper's kernels are C++ code-generation variants spanning the
//! binding spectrum from fully rolled to fully unrolled. Here each kernel
//! is a progressively specialized *executor* over a progressively
//! flattened OIM encoding — the binding-level property each step changes
//! (dispatch per element vs per group vs per program; metadata in data
//! arrays vs embedded in the program) is preserved:
//!
//! | kernel | paper                                | here |
//! |--------|--------------------------------------|------|
//! | RU     | rolled `[I,S,N,O,R]`, per-op case    | cursor walk of format-B arrays, `match` per op, operand loop |
//! | OU     | + unroll O                           | operand fetches inlined by arity |
//! | NU     | + S/N swizzle, per-op-type loops     | format-C group walk, dispatch hoisted out of the S loop |
//! | PSU    | + partial S unroll (8 / 24)          | chunked inner loops (`UNROLL=8`), writeback by 24 |
//! | IU     | + unroll I (drop empty groups)       | flattened group-command program, zero per-layer overhead |
//! | SU     | + unroll S fully (OIM in binary)     | straight-line op tape — no metadata arrays |
//! | TI     | + tensor inlining (values in regs)   | tape of precompiled per-op closures, direct slot writes, no LO |
//!
//! All kernels implement [`SimKernel`] and are property-tested to agree
//! with `graph::RefSim` and the Einsum cascade evaluator.
//!
//! ## Lane batching (throughput simulation)
//!
//! Because the tensor form decouples behaviour (the OIM) from the program,
//! one walk of the metadata can step `B` independent stimulus lanes at
//! once — many users / test vectors simulated per pass, amortizing the
//! per-op metadata traffic and dispatch that dominate the rolled kernels
//! and the tape walk that dominates the unrolled ones. Batched executors
//! implement [`BatchKernel`] and store every slot file **lane-major**:
//!
//! ```text
//! slots[s * B + lane]   // lane runs fastest: contiguous inner loops
//! ```
//!
//! Inputs follow the same convention (`inputs[i * B + lane]`). Lanes are
//! fully independent: a `B`-lane batched run is bit-identical to `B`
//! single-lane runs of the corresponding scalar kernel (differential
//! property test in `tests/kernels_property.rs`). Batched executors exist
//! for the three binding levels that bracket the spectrum — RU, NU/PSU
//! and TI (see [`BATCHED_KERNELS`] and [`batch`]); `rteaal sim --lanes B`
//! and `benches/fig22_lanes.rs` drive them.

pub mod common;
pub mod ru;
pub mod ou;
pub mod nu;
pub mod iu;
pub mod su;
pub mod ti;
pub mod unopt;
pub mod batch;

use crate::tensor::ir::LayerIr;
use crate::tensor::oim::Oim;

/// Kernel configuration identifier (paper naming).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum KernelConfig {
    RU,
    OU,
    NU,
    PSU,
    IU,
    SU,
    TI,
}

pub const ALL_KERNELS: [KernelConfig; 7] = [
    KernelConfig::RU,
    KernelConfig::OU,
    KernelConfig::NU,
    KernelConfig::PSU,
    KernelConfig::IU,
    KernelConfig::SU,
    KernelConfig::TI,
];

impl KernelConfig {
    pub fn name(self) -> &'static str {
        match self {
            KernelConfig::RU => "RU",
            KernelConfig::OU => "OU",
            KernelConfig::NU => "NU",
            KernelConfig::PSU => "PSU",
            KernelConfig::IU => "IU",
            KernelConfig::SU => "SU",
            KernelConfig::TI => "TI",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_uppercase().as_str() {
            "RU" => Some(KernelConfig::RU),
            "OU" => Some(KernelConfig::OU),
            "NU" => Some(KernelConfig::NU),
            "PSU" => Some(KernelConfig::PSU),
            "IU" => Some(KernelConfig::IU),
            "SU" => Some(KernelConfig::SU),
            "TI" => Some(KernelConfig::TI),
            _ => None,
        }
    }
}

/// A compiled simulation kernel: drive inputs, advance one cycle, observe.
/// `Send` so partitioned simulation can move kernels across threads.
pub trait SimKernel: Send {
    fn config_name(&self) -> &'static str;
    /// Simulate one cycle (inputs in port order, masked by the kernel).
    fn step(&mut self, inputs: &[u64]);
    /// The LI slot file after the last step.
    fn slots(&self) -> &[u64];
    /// Named design outputs.
    fn outputs(&self) -> Vec<(String, u64)>;
    /// Write a slot directly (partitioned simulation uses this for the
    /// RUM synchronization step — Cascade 2's final Einsum).
    fn poke(&mut self, slot: u32, value: u64);
    /// Modeled program ("binary") bytes: code plus any OIM embedded in it.
    fn program_bytes(&self) -> usize;
    /// Modeled metadata ("data") bytes streamed per cycle.
    fn data_bytes(&self) -> usize;
}

/// Build a kernel of the given configuration from the lowered design.
pub fn build(config: KernelConfig, ir: &LayerIr) -> Box<dyn SimKernel> {
    let oim = Oim::from_ir(ir);
    build_with_oim(config, ir, &oim)
}

/// Build from a pre-constructed OIM (avoids re-deriving it in sweeps).
pub fn build_with_oim(config: KernelConfig, ir: &LayerIr, oim: &Oim) -> Box<dyn SimKernel> {
    match config {
        KernelConfig::RU => Box::new(ru::RuKernel::new(ir, oim)),
        KernelConfig::OU => Box::new(ou::OuKernel::new(ir, oim)),
        KernelConfig::NU => Box::new(nu::NuKernel::<1>::new(ir, oim)),
        KernelConfig::PSU => Box::new(nu::NuKernel::<8>::new(ir, oim)),
        KernelConfig::IU => Box::new(iu::IuKernel::new(ir, oim)),
        KernelConfig::SU => Box::new(su::SuKernel::new(ir, oim)),
        KernelConfig::TI => Box::new(ti::TiKernel::new(ir, oim)),
    }
}

/// A lane-batched simulation kernel: `B` independent stimulus lanes step
/// together through one walk of the OIM metadata / tape. Slot files and
/// inputs are lane-major (see the module docs).
pub trait BatchKernel: Send {
    fn config_name(&self) -> &'static str;
    /// Number of lanes `B`.
    fn lanes(&self) -> usize;
    /// Simulate one cycle for every lane. `inputs[i * lanes + lane]` is
    /// input port `i` of `lane` (masked by the kernel).
    fn step(&mut self, inputs: &[u64]);
    /// The lane-major LI slot file (`slots[s * lanes + lane]`).
    fn slots(&self) -> &[u64];
    /// Named design outputs as observed by one lane.
    fn lane_outputs(&self, lane: usize) -> Vec<(String, u64)>;
}

/// The kernel configurations with lane-batched executors — the three
/// binding levels bracketing the design space (PSU shares NU's batched
/// group bodies).
pub const BATCHED_KERNELS: [KernelConfig; 4] =
    [KernelConfig::RU, KernelConfig::NU, KernelConfig::PSU, KernelConfig::TI];

/// Whether `config` has a lane-batched executor.
pub fn supports_batch(config: KernelConfig) -> bool {
    BATCHED_KERNELS.contains(&config)
}

/// Build a lane-batched kernel. Panics for configurations without a
/// batched executor — gate on [`supports_batch`] first.
pub fn build_batch(
    config: KernelConfig,
    ir: &LayerIr,
    oim: &Oim,
    lanes: usize,
) -> Box<dyn BatchKernel> {
    match config {
        KernelConfig::RU => Box::new(batch::BatchRuKernel::new(ir, oim, lanes)),
        KernelConfig::NU => Box::new(batch::BatchNuKernel::new(ir, oim, lanes, "NU")),
        KernelConfig::PSU => Box::new(batch::BatchNuKernel::new(ir, oim, lanes, "PSU")),
        KernelConfig::TI => Box::new(batch::BatchTiKernel::new(ir, oim, lanes)),
        other => panic!(
            "kernel {} has no lane-batched executor (supported: RU, NU, PSU, TI)",
            other.name()
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::{random_circuit, random_inputs};
    use crate::graph::passes::optimize;
    use crate::graph::RefSim;
    use crate::tensor::ir::lower;
    use crate::util::prng::Rng;

    /// Every kernel configuration agrees with the reference interpreter on
    /// random optimized circuits — the core correctness property.
    #[test]
    fn all_kernels_match_reference() {
        for seed in 0..8 {
            let mut rng = Rng::new(40_000 + seed);
            let g = random_circuit(&mut rng, 90);
            let (opt, _) = optimize(&g);
            let ir = lower(&opt);
            let mut reference = RefSim::new(opt.clone());
            let mut kernels: Vec<Box<dyn SimKernel>> =
                ALL_KERNELS.iter().map(|&k| build(k, &ir)).collect();
            for cycle in 0..10 {
                let inputs = random_inputs(&mut rng, &reference.graph);
                reference.step(&inputs);
                let want = reference.outputs();
                for k in &mut kernels {
                    k.step(&inputs);
                    assert_eq!(
                        k.outputs(),
                        want,
                        "kernel {} diverged (seed {seed}, cycle {cycle})",
                        k.config_name()
                    );
                }
            }
        }
    }

    /// Program bytes grow monotonically toward the unrolled end while data
    /// bytes shrink — the paper's I-cache/D-cache pressure trade-off.
    #[test]
    fn code_data_tradeoff() {
        let mut rng = Rng::new(999);
        let g = random_circuit(&mut rng, 400);
        let (opt, _) = optimize(&g);
        let ir = lower(&opt);
        let ru = build(KernelConfig::RU, &ir);
        let su = build(KernelConfig::SU, &ir);
        assert!(su.program_bytes() > ru.program_bytes());
        assert!(su.data_bytes() < ru.data_bytes());
    }
}
