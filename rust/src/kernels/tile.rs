//! Fixed-width SIMD lane tiles — the explicit data-level-parallel inner
//! loops of the batched executors.
//!
//! The lane-major layout (`v[s * B + lane]`) makes every per-op lane loop
//! a contiguous streaming loop, but PRs 1–5 left the *vectorization* of
//! those loops to the compiler: each `LaneOp`/`bt_*`/`sp_*` body iterated
//! lanes one at a time through a function-pointer call, and the
//! auto-vectorizer had to prove the call away. Here the DLP is spelled
//! out instead (the Manticore lesson — statically scheduled bulk
//! parallelism beats hoped-for parallelism): lanes are processed in
//! fixed-width tiles of [`TILE_W`] (`[u64; 8]`, one AVX-512 register or
//! two AVX2 registers) with a [`TILE_W4`] (`[u64; 4]`) step and a scalar
//! remainder loop covering `B % W != 0`.
//!
//! **Remainder-loop invariant**: for every primitive in this module, the
//! 8-wide tile, the 4-wide tile and the scalar remainder apply the *same*
//! op body, the same result mask and the same store order to each lane,
//! so a `B`-lane tiled run is bit-identical to the lane-at-a-time loop it
//! replaces for every `B` — including `B < 4`, where only the remainder
//! loop runs. Each tile loads all its operands before storing any result,
//! which preserves scalar semantics even when an in-place primitive's
//! destination base equals one of its source bases (slot bases are
//! multiples of `lanes`, so per-tile ranges either coincide exactly or
//! are disjoint — a store can never alias a *later* load of the same
//! tile at a different lane). The only op body that stays lane-at-a-time
//! everywhere is `MuxChain` (variable arity — no fixed-shape tile), which
//! the dispatch sites document individually.
//!
//! Two families of primitives:
//!
//! * **staged** ([`un`], [`bin`], [`mux`]) — read from one slice, write
//!   to a disjoint LO staging buffer (the group-walk executors NU/PSU/IU
//!   and the tape executor SU);
//! * **in-place** ([`un_ip`], [`bin_ip`], [`mux_ip`]) — read and write
//!   the same lane-major slot file (the TI tapes, the sparse executors'
//!   full-mask fast path, and the [`super::common::BatchDriver`] cycle
//!   boundaries).
//!
//! [`store_changed`] / [`store_changed_ip`] are the tiled change-detecting
//! stores behind the sparse drivers' boundary detection (`lanes ≤ 64`,
//! one changed bit per lane).

/// Primary tile width: 8 lanes of `u64` per tile.
pub const TILE_W: usize = 8;
/// Fallback tile width for the `4 ≤ remainder < 8` step.
pub const TILE_W4: usize = 4;

/// Staged unary tile op: `dst[ob + l] = f(src[ab + l]) & m` for all lanes.
#[inline(always)]
pub fn un(src: &[u64], ab: usize, dst: &mut [u64], ob: usize, lanes: usize, m: u64, f: impl Fn(u64) -> u64 + Copy) {
    let mut l = 0;
    while l + TILE_W <= lanes {
        let mut t = [0u64; TILE_W];
        for k in 0..TILE_W {
            t[k] = f(src[ab + l + k]) & m;
        }
        dst[ob + l..ob + l + TILE_W].copy_from_slice(&t);
        l += TILE_W;
    }
    if l + TILE_W4 <= lanes {
        let mut t = [0u64; TILE_W4];
        for k in 0..TILE_W4 {
            t[k] = f(src[ab + l + k]) & m;
        }
        dst[ob + l..ob + l + TILE_W4].copy_from_slice(&t);
        l += TILE_W4;
    }
    while l < lanes {
        dst[ob + l] = f(src[ab + l]) & m;
        l += 1;
    }
}

/// Staged binary tile op: `dst[ob + l] = f(src[ab + l], src[bb + l]) & m`.
#[inline(always)]
pub fn bin(src: &[u64], ab: usize, bb: usize, dst: &mut [u64], ob: usize, lanes: usize, m: u64, f: impl Fn(u64, u64) -> u64 + Copy) {
    let mut l = 0;
    while l + TILE_W <= lanes {
        let mut t = [0u64; TILE_W];
        for k in 0..TILE_W {
            t[k] = f(src[ab + l + k], src[bb + l + k]) & m;
        }
        dst[ob + l..ob + l + TILE_W].copy_from_slice(&t);
        l += TILE_W;
    }
    if l + TILE_W4 <= lanes {
        let mut t = [0u64; TILE_W4];
        for k in 0..TILE_W4 {
            t[k] = f(src[ab + l + k], src[bb + l + k]) & m;
        }
        dst[ob + l..ob + l + TILE_W4].copy_from_slice(&t);
        l += TILE_W4;
    }
    while l < lanes {
        dst[ob + l] = f(src[ab + l], src[bb + l]) & m;
        l += 1;
    }
}

/// Staged mux tile op:
/// `dst[ob + l] = (src[sb + l] != 0 ? src[tb + l] : src[fb + l]) & m`.
#[inline(always)]
pub fn mux(src: &[u64], sb: usize, tb: usize, fb: usize, dst: &mut [u64], ob: usize, lanes: usize, m: u64) {
    let mut l = 0;
    while l + TILE_W <= lanes {
        let mut t = [0u64; TILE_W];
        for k in 0..TILE_W {
            t[k] = (if src[sb + l + k] != 0 { src[tb + l + k] } else { src[fb + l + k] }) & m;
        }
        dst[ob + l..ob + l + TILE_W].copy_from_slice(&t);
        l += TILE_W;
    }
    if l + TILE_W4 <= lanes {
        let mut t = [0u64; TILE_W4];
        for k in 0..TILE_W4 {
            t[k] = (if src[sb + l + k] != 0 { src[tb + l + k] } else { src[fb + l + k] }) & m;
        }
        dst[ob + l..ob + l + TILE_W4].copy_from_slice(&t);
        l += TILE_W4;
    }
    while l < lanes {
        dst[ob + l] = (if src[sb + l] != 0 { src[tb + l] } else { src[fb + l] }) & m;
        l += 1;
    }
}

/// In-place unary tile op over one lane-major slot file:
/// `v[ob + l] = f(v[ab + l]) & m`. Safe for `ob == ab` (loads precede
/// stores within each tile; the scalar loop reads and writes the same
/// lane only).
#[inline(always)]
pub fn un_ip(v: &mut [u64], ab: usize, ob: usize, lanes: usize, m: u64, f: impl Fn(u64) -> u64 + Copy) {
    let mut l = 0;
    while l + TILE_W <= lanes {
        let mut t = [0u64; TILE_W];
        for k in 0..TILE_W {
            t[k] = f(v[ab + l + k]) & m;
        }
        v[ob + l..ob + l + TILE_W].copy_from_slice(&t);
        l += TILE_W;
    }
    if l + TILE_W4 <= lanes {
        let mut t = [0u64; TILE_W4];
        for k in 0..TILE_W4 {
            t[k] = f(v[ab + l + k]) & m;
        }
        v[ob + l..ob + l + TILE_W4].copy_from_slice(&t);
        l += TILE_W4;
    }
    while l < lanes {
        v[ob + l] = f(v[ab + l]) & m;
        l += 1;
    }
}

/// In-place binary tile op: `v[ob + l] = f(v[ab + l], v[bb + l]) & m`.
#[inline(always)]
pub fn bin_ip(v: &mut [u64], ab: usize, bb: usize, ob: usize, lanes: usize, m: u64, f: impl Fn(u64, u64) -> u64 + Copy) {
    let mut l = 0;
    while l + TILE_W <= lanes {
        let mut t = [0u64; TILE_W];
        for k in 0..TILE_W {
            t[k] = f(v[ab + l + k], v[bb + l + k]) & m;
        }
        v[ob + l..ob + l + TILE_W].copy_from_slice(&t);
        l += TILE_W;
    }
    if l + TILE_W4 <= lanes {
        let mut t = [0u64; TILE_W4];
        for k in 0..TILE_W4 {
            t[k] = f(v[ab + l + k], v[bb + l + k]) & m;
        }
        v[ob + l..ob + l + TILE_W4].copy_from_slice(&t);
        l += TILE_W4;
    }
    while l < lanes {
        v[ob + l] = f(v[ab + l], v[bb + l]) & m;
        l += 1;
    }
}

/// In-place mux tile op:
/// `v[ob + l] = (v[sb + l] != 0 ? v[tb + l] : v[fb + l]) & m`.
#[inline(always)]
pub fn mux_ip(v: &mut [u64], sb: usize, tb: usize, fb: usize, ob: usize, lanes: usize, m: u64) {
    let mut l = 0;
    while l + TILE_W <= lanes {
        let mut t = [0u64; TILE_W];
        for k in 0..TILE_W {
            t[k] = (if v[sb + l + k] != 0 { v[tb + l + k] } else { v[fb + l + k] }) & m;
        }
        v[ob + l..ob + l + TILE_W].copy_from_slice(&t);
        l += TILE_W;
    }
    if l + TILE_W4 <= lanes {
        let mut t = [0u64; TILE_W4];
        for k in 0..TILE_W4 {
            t[k] = (if v[sb + l + k] != 0 { v[tb + l + k] } else { v[fb + l + k] }) & m;
        }
        v[ob + l..ob + l + TILE_W4].copy_from_slice(&t);
        l += TILE_W4;
    }
    while l < lanes {
        v[ob + l] = (if v[sb + l] != 0 { v[tb + l] } else { v[fb + l] }) & m;
        l += 1;
    }
}

/// Tiled change-detecting store from a separate source slice:
/// `dst[ob + l] = src[ab + l] & m`, returning a bitmask with bit `l` set
/// where the stored value differs from the previous one (`lanes ≤ 64` —
/// one mask bit per lane). The driver's tracked input writes.
#[inline(always)]
pub fn store_changed(src: &[u64], ab: usize, dst: &mut [u64], ob: usize, lanes: usize, m: u64) -> u64 {
    debug_assert!(lanes <= 64);
    let mut changed = 0u64;
    let mut l = 0;
    while l + TILE_W <= lanes {
        let mut t = [0u64; TILE_W];
        for k in 0..TILE_W {
            t[k] = src[ab + l + k] & m;
        }
        for k in 0..TILE_W {
            changed |= ((dst[ob + l + k] != t[k]) as u64) << (l + k);
        }
        dst[ob + l..ob + l + TILE_W].copy_from_slice(&t);
        l += TILE_W;
    }
    if l + TILE_W4 <= lanes {
        let mut t = [0u64; TILE_W4];
        for k in 0..TILE_W4 {
            t[k] = src[ab + l + k] & m;
        }
        for k in 0..TILE_W4 {
            changed |= ((dst[ob + l + k] != t[k]) as u64) << (l + k);
        }
        dst[ob + l..ob + l + TILE_W4].copy_from_slice(&t);
        l += TILE_W4;
    }
    while l < lanes {
        let nv = src[ab + l] & m;
        changed |= ((dst[ob + l] != nv) as u64) << l;
        dst[ob + l] = nv;
        l += 1;
    }
    changed
}

/// Tiled change-detecting store within one lane-major slot file:
/// `v[ob + l] = v[ab + l] & m`, returning the changed-lane bitmask
/// (`lanes ≤ 64`). The driver's tracked register commits; safe for
/// `ob == ab` (a self-holding register commit never reports a change
/// once its value is masked).
#[inline(always)]
pub fn store_changed_ip(v: &mut [u64], ab: usize, ob: usize, lanes: usize, m: u64) -> u64 {
    debug_assert!(lanes <= 64);
    let mut changed = 0u64;
    let mut l = 0;
    while l + TILE_W <= lanes {
        let mut t = [0u64; TILE_W];
        for k in 0..TILE_W {
            t[k] = v[ab + l + k] & m;
        }
        for k in 0..TILE_W {
            changed |= ((v[ob + l + k] != t[k]) as u64) << (l + k);
        }
        v[ob + l..ob + l + TILE_W].copy_from_slice(&t);
        l += TILE_W;
    }
    if l + TILE_W4 <= lanes {
        let mut t = [0u64; TILE_W4];
        for k in 0..TILE_W4 {
            t[k] = v[ab + l + k] & m;
        }
        for k in 0..TILE_W4 {
            changed |= ((v[ob + l + k] != t[k]) as u64) << (l + k);
        }
        v[ob + l..ob + l + TILE_W4].copy_from_slice(&t);
        l += TILE_W4;
    }
    while l < lanes {
        let nv = v[ab + l] & m;
        changed |= ((v[ob + l] != nv) as u64) << l;
        v[ob + l] = nv;
        l += 1;
    }
    changed
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every lane count around the tile widths exercises a different
    /// 8/4/scalar decomposition; each must match the plain scalar loop.
    const LANE_GRID: [usize; 12] = [1, 2, 3, 4, 5, 7, 8, 9, 12, 13, 16, 63];

    fn ramp(n: usize, seed: u64) -> Vec<u64> {
        (0..n as u64).map(|i| i.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ seed).collect()
    }

    #[test]
    fn staged_primitives_match_scalar_loops_on_remainder_lanes() {
        for &lanes in &LANE_GRID {
            let src = ramp(4 * lanes, 7);
            let m = 0x00FF_FFFF_FFFF_FFFFu64;
            let mut got = vec![0u64; lanes];
            let mut want = vec![0u64; lanes];
            un(&src, lanes, &mut got, 0, lanes, m, |a| a.wrapping_mul(3));
            for l in 0..lanes {
                want[l] = src[lanes + l].wrapping_mul(3) & m;
            }
            assert_eq!(got, want, "un lanes={lanes}");
            bin(&src, 0, 2 * lanes, &mut got, 0, lanes, m, |a, b| a ^ b.rotate_left(7));
            for l in 0..lanes {
                want[l] = (src[l] ^ src[2 * lanes + l].rotate_left(7)) & m;
            }
            assert_eq!(got, want, "bin lanes={lanes}");
            mux(&src, 0, lanes, 2 * lanes, &mut got, 0, lanes, m);
            for l in 0..lanes {
                want[l] = (if src[l] != 0 { src[lanes + l] } else { src[2 * lanes + l] }) & m;
            }
            assert_eq!(got, want, "mux lanes={lanes}");
        }
    }

    #[test]
    fn in_place_primitives_match_scalar_loops_on_remainder_lanes() {
        for &lanes in &LANE_GRID {
            let init = ramp(4 * lanes, 99);
            let m = u64::MAX;
            let mut v = init.clone();
            bin_ip(&mut v, 0, lanes, 3 * lanes, lanes, m, |a, b| a.wrapping_add(b));
            for l in 0..lanes {
                assert_eq!(v[3 * lanes + l], init[l].wrapping_add(init[lanes + l]), "bin_ip lanes={lanes}");
            }
            let mut v = init.clone();
            mux_ip(&mut v, 0, lanes, 2 * lanes, 3 * lanes, lanes, 0xFFFF);
            for l in 0..lanes {
                let x = if init[l] != 0 { init[lanes + l] } else { init[2 * lanes + l] };
                assert_eq!(v[3 * lanes + l], x & 0xFFFF, "mux_ip lanes={lanes}");
            }
        }
    }

    /// The self-aliasing case the commit path hits on self-holding
    /// registers: `ob == ab` must behave like the scalar in-place loop.
    #[test]
    fn in_place_unary_tolerates_aliased_destination() {
        for &lanes in &LANE_GRID {
            let init = ramp(lanes, 5);
            let mut v = init.clone();
            un_ip(&mut v, 0, 0, lanes, 0xFF, |a| a);
            for l in 0..lanes {
                assert_eq!(v[l], init[l] & 0xFF, "aliased un_ip lanes={lanes}");
            }
        }
    }

    #[test]
    fn change_detecting_stores_report_exact_lane_bits() {
        for &lanes in &LANE_GRID {
            let src = ramp(lanes, 21);
            // dst starts equal to the masked source except in lanes ≡ 2 (mod 5)
            let m = 0x0FFF_FFFF_FFFF_FFFFu64;
            let mut dst: Vec<u64> = src.iter().map(|&x| x & m).collect();
            let mut want = 0u64;
            for l in (2..lanes).step_by(5) {
                dst[l] ^= 1;
                want |= 1u64 << l;
            }
            let got = store_changed(&src, 0, &mut dst, 0, lanes, m);
            assert_eq!(got, want, "store_changed lanes={lanes}");
            for l in 0..lanes {
                assert_eq!(dst[l], src[l] & m);
            }
            // in-place: copy the (already masked) dst region onto itself —
            // a self-holding commit — must report zero changes
            let mut v = dst.clone();
            assert_eq!(store_changed_ip(&mut v, 0, 0, lanes, m), 0, "self commit lanes={lanes}");
            assert_eq!(v, dst);
        }
    }

    /// First-store semantics around `u64::MAX`: a lane whose previous
    /// value coincidentally equals the new one reports no change, while a
    /// genuine change to/from `u64::MAX` is reported.
    #[test]
    fn change_detection_has_no_sentinel_value() {
        let lanes = 9;
        let src = vec![u64::MAX; lanes];
        let mut dst = vec![u64::MAX; lanes];
        dst[4] = 0;
        let got = store_changed(&src, 0, &mut dst, 0, lanes, u64::MAX);
        assert_eq!(got, 1u64 << 4);
        assert!(dst.iter().all(|&x| x == u64::MAX));
    }
}
