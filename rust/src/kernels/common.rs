//! Shared kernel machinery: the LI slot file with input/commit handling
//! (scalar [`Driver`] and lane-batched [`BatchDriver`]), and the generic
//! per-operation evaluator used by the rolled kernels' case dispatch (the
//! paper's Algorithm 2 `op_r[n]` case statement).

use super::tile;
use crate::graph::ops::mask;
use crate::tensor::ir::{KOp, LayerIr};

/// The LI slot file plus cycle boundary plumbing (testbench inputs at the
/// start of a cycle; register commits — the `◇ : i ≡ I` connects — at the
/// end).
#[derive(Clone, Debug)]
pub struct Driver {
    pub v: Vec<u64>,
    pub input_slots: Vec<u32>,
    pub input_masks: Vec<u64>,
    pub commits: Vec<(u32, u32, u64)>,
    pub outputs: Vec<(String, u32)>,
}

impl Driver {
    pub fn new(ir: &LayerIr) -> Self {
        Driver {
            v: ir.initial_slots(),
            input_slots: ir.input_slots.clone(),
            input_masks: ir.input_widths.iter().map(|&w| mask(w)).collect(),
            commits: ir.commits.clone(),
            outputs: ir.output_slots.clone(),
        }
    }

    #[inline]
    pub fn set_inputs(&mut self, inputs: &[u64]) {
        debug_assert_eq!(inputs.len(), self.input_slots.len());
        for i in 0..self.input_slots.len() {
            self.v[self.input_slots[i] as usize] = inputs[i] & self.input_masks[i];
        }
    }

    #[inline]
    pub fn commit(&mut self) {
        for &(reg, next, m) in &self.commits {
            self.v[reg as usize] = self.v[next as usize] & m;
        }
    }

    pub fn named_outputs(&self) -> Vec<(String, u64)> {
        self.outputs.iter().map(|(n, s)| (n.clone(), self.v[*s as usize])).collect()
    }
}

/// Lane-batched LI slot file: `B` independent stimulus lanes share one OIM
/// walk, with the slot file stored **lane-major** (`v[s * B + lane]`) so
/// the per-op lane loop touches contiguous memory.
///
/// All lanes start from the same initial slot values (constants + register
/// init); they diverge only through their per-lane inputs.
#[derive(Clone, Debug)]
pub struct BatchDriver {
    /// Number of lanes `B` (>= 1).
    pub lanes: usize,
    /// Lane-major slot file, `num_slots * lanes` entries.
    pub v: Vec<u64>,
    pub input_slots: Vec<u32>,
    pub input_masks: Vec<u64>,
    pub commits: Vec<(u32, u32, u64)>,
    pub outputs: Vec<(String, u32)>,
}

impl BatchDriver {
    pub fn new(ir: &LayerIr, lanes: usize) -> Self {
        assert!(lanes >= 1, "lanes must be >= 1");
        let init = ir.initial_slots();
        let mut v = vec![0u64; init.len() * lanes];
        for (s, &val) in init.iter().enumerate() {
            for l in 0..lanes {
                v[s * lanes + l] = val;
            }
        }
        BatchDriver {
            lanes,
            v,
            input_slots: ir.input_slots.clone(),
            input_masks: ir.input_widths.iter().map(|&w| mask(w)).collect(),
            commits: ir.commits.clone(),
            outputs: ir.output_slots.clone(),
        }
    }

    /// Drive all lanes' inputs. `inputs` is lane-major:
    /// `inputs[i * lanes + lane]` is input port `i` for `lane`. The copy
    /// runs tile-strided ([`tile::un`]) like the kernel bodies, so the
    /// cycle boundary shares the explicit-SIMD inner loop shape.
    #[inline]
    pub fn set_inputs(&mut self, inputs: &[u64]) {
        debug_assert_eq!(inputs.len(), self.input_slots.len() * self.lanes);
        for i in 0..self.input_slots.len() {
            let m = self.input_masks[i];
            let base = self.input_slots[i] as usize * self.lanes;
            tile::un(inputs, i * self.lanes, &mut self.v, base, self.lanes, m, |a| a);
        }
    }

    /// Register commits for every lane (the `◇ : i ≡ I` connects),
    /// tile-strided. `reg == next` (self-holding registers) is safe: the
    /// in-place tile primitive loads a whole tile before storing it.
    #[inline]
    pub fn commit(&mut self) {
        for ci in 0..self.commits.len() {
            let (reg, next, m) = self.commits[ci];
            let rb = reg as usize * self.lanes;
            let nb = next as usize * self.lanes;
            tile::un_ip(&mut self.v, nb, rb, self.lanes, m, |a| a);
        }
    }

    /// [`Self::set_inputs`] with per-lane change detection: lane `l` of
    /// `changed[i]` is OR-ed in when input port `i` changed in lane `l`.
    /// Only meaningful for `lanes ≤ 64` (one mask bit per lane).
    #[inline]
    pub fn set_inputs_tracked(&mut self, inputs: &[u64], changed: &mut [u64]) {
        debug_assert_eq!(inputs.len(), self.input_slots.len() * self.lanes);
        debug_assert_eq!(changed.len(), self.input_slots.len());
        debug_assert!(self.lanes <= 64);
        for i in 0..self.input_slots.len() {
            let m = self.input_masks[i];
            let base = self.input_slots[i] as usize * self.lanes;
            changed[i] |=
                tile::store_changed(inputs, i * self.lanes, &mut self.v, base, self.lanes, m);
        }
    }

    /// [`Self::commit`] with per-lane change detection: lane `l` of
    /// `changed[ci]` is OR-ed in when commit `ci`'s register changed in
    /// lane `l`. Only meaningful for `lanes ≤ 64`.
    #[inline]
    pub fn commit_tracked(&mut self, changed: &mut [u64]) {
        debug_assert_eq!(changed.len(), self.commits.len());
        debug_assert!(self.lanes <= 64);
        for ci in 0..self.commits.len() {
            let (reg, next, m) = self.commits[ci];
            let rb = reg as usize * self.lanes;
            let nb = next as usize * self.lanes;
            changed[ci] |= tile::store_changed_ip(&mut self.v, nb, rb, self.lanes, m);
        }
    }

    /// Overwrite the whole lane-major slot file from a snapshot of the
    /// same shape (checkpoint restore). Sits behind
    /// [`crate::kernels::BatchKernel::restore_slots`] for every
    /// driver-backed executor.
    pub fn restore_slots(&mut self, slots: &[u64]) -> Result<(), String> {
        if slots.len() != self.v.len() {
            return Err(format!(
                "slot snapshot has {} words, expected {} ({} slots x {} lanes)",
                slots.len(),
                self.v.len(),
                self.v.len() / self.lanes,
                self.lanes
            ));
        }
        self.v.copy_from_slice(slots);
        Ok(())
    }

    /// Write one lane of one slot directly (divergent-lane initialization).
    #[inline]
    pub fn poke_lane(&mut self, slot: u32, lane: usize, value: u64) {
        assert!(lane < self.lanes, "lane {lane} out of range (lanes = {})", self.lanes);
        self.v[slot as usize * self.lanes + lane] = value;
    }

    /// Named design outputs as seen by one lane.
    pub fn lane_outputs(&self, lane: usize) -> Vec<(String, u64)> {
        assert!(lane < self.lanes, "lane {lane} out of range (lanes = {})", self.lanes);
        self.outputs
            .iter()
            .map(|(n, s)| (n.clone(), self.v[*s as usize * self.lanes + lane]))
            .collect()
    }

    /// [`Self::lane_outputs`] into a reusable buffer: only the values are
    /// rewritten, the names are cloned once — no allocation per call.
    /// Sits behind [`crate::kernels::BatchKernel::write_lane_outputs`]
    /// for the per-cycle sweep and differential loops.
    pub fn write_lane_outputs(&self, lane: usize, buf: &mut Vec<(String, u64)>) {
        assert!(lane < self.lanes, "lane {lane} out of range (lanes = {})", self.lanes);
        if buf.len() != self.outputs.len() {
            *buf = self.outputs.iter().map(|(n, _)| (n.clone(), 0)).collect();
        }
        for (dst, (_, s)) in buf.iter_mut().zip(&self.outputs) {
            dst.1 = self.v[*s as usize * self.lanes + lane];
        }
    }
}

/// Generic operation evaluation over gathered operand values — the big
/// case statement of Algorithm 2. Rolled kernels (RU/OU) dispatch through
/// this per element; more unrolled kernels hoist the dispatch out.
#[inline(always)]
pub fn eval_op(op: KOp, operands: &[u64], imm: u8, m: u64, aux: u64) -> u64 {
    let a = operands[0];
    let raw = match op {
        KOp::Add => a.wrapping_add(operands[1]),
        KOp::Sub => a.wrapping_sub(operands[1]),
        KOp::Mul => a.wrapping_mul(operands[1]),
        KOp::Div => {
            let b = operands[1];
            if b == 0 {
                0
            } else {
                a / b
            }
        }
        KOp::Rem => {
            let b = operands[1];
            if b == 0 {
                0
            } else {
                a % b
            }
        }
        KOp::Lt => (a < operands[1]) as u64,
        KOp::Leq => (a <= operands[1]) as u64,
        KOp::Gt => (a > operands[1]) as u64,
        KOp::Geq => (a >= operands[1]) as u64,
        KOp::Eq => (a == operands[1]) as u64,
        KOp::Neq => (a != operands[1]) as u64,
        KOp::And => a & operands[1],
        KOp::Or => a | operands[1],
        KOp::Xor => a ^ operands[1],
        KOp::Not => !a,
        KOp::Neg => a.wrapping_neg(),
        KOp::AndrK => (a == aux) as u64,
        KOp::Orr => (a != 0) as u64,
        KOp::Xorr => (a.count_ones() & 1) as u64,
        KOp::ShlI => a << imm,
        KOp::ShrI => a >> imm,
        KOp::Dshl => {
            let b = operands[1];
            if b >= 64 {
                0
            } else {
                a << b
            }
        }
        KOp::Dshr => {
            let b = operands[1];
            if b >= 64 {
                0
            } else {
                a >> b
            }
        }
        KOp::Cat => (a << imm) | operands[1],
        KOp::Mux => {
            if a != 0 {
                operands[1]
            } else {
                operands[2]
            }
        }
        KOp::Copy => a,
        KOp::MuxChain => {
            let k = imm as usize;
            let mut v = operands[2 * k];
            for i in (0..k).rev() {
                if operands[2 * i] != 0 {
                    v = operands[2 * i + 1];
                }
            }
            v
        }
    };
    raw & m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_op_matches_eval_rec() {
        use crate::tensor::ir::{eval_rec, OpRec};
        // spot-check agreement between the gathered-operand evaluator and
        // the slot-indexed evaluator
        let li = [0u64, 13, 5, 1, 7, 9];
        for (op, arity, imm, aux) in [
            (KOp::Add, 2, 0, 0),
            (KOp::Sub, 2, 0, 0),
            (KOp::Cat, 2, 3, 0),
            (KOp::AndrK, 1, 0, 13),
            (KOp::ShrI, 1, 2, 0),
            (KOp::Mux, 3, 0, 0),
        ] {
            let rec = OpRec {
                out: 0,
                a: 1,
                b: 2,
                c: 4,
                mask: 0xFF,
                aux,
                op: op as u8,
                arity,
                imm,
                _pad: 0,
                ext: 0,
            };
            let slots: Vec<u64> = [1u32, 2, 4][..arity as usize].iter().map(|&i| li[i as usize]).collect();
            assert_eq!(
                eval_rec(&rec, &li, &[]),
                eval_op(op, &slots, imm, 0xFF, aux),
                "{op:?}"
            );
        }
    }
}
