//! **OU** — O-rank-unrolled kernel (paper §5.2).
//!
//! Same loop order and format-B metadata as RU, but the operand loop is
//! completely unrolled: operands are fetched inline by arity (no `O` loop
//! body, no `sel_inputs` staging buffer for the common arities), which
//! removes redundant data movement and loop overhead. Format unchanged —
//! the O rank had no explicit metadata to begin with (Fig 12b).

use super::common::{eval_op, Driver};
use super::SimKernel;
use crate::tensor::ir::{KOp, LayerIr};
use crate::tensor::oim::Oim;

pub struct OuKernel {
    d: Driver,
    oim: Oim,
    lo: Vec<u64>,
    chain_buf: Vec<u64>,
}

impl OuKernel {
    pub fn new(ir: &LayerIr, oim: &Oim) -> Self {
        let max_arity = oim.b.arity.iter().copied().max().unwrap_or(1) as usize;
        OuKernel {
            d: Driver::new(ir),
            oim: oim.clone(),
            lo: vec![0; ir.max_layer_ops()],
            chain_buf: vec![0; max_arity.max(3)],
        }
    }
}

impl SimKernel for OuKernel {
    fn config_name(&self) -> &'static str {
        "OU"
    }

    fn step(&mut self, inputs: &[u64]) {
        self.d.set_inputs(inputs);
        let o = &self.oim;
        let v = &mut self.d.v;
        let mut op_idx = 0usize;
        let mut r_idx = 0usize;
        let mut wb_idx = 0usize;
        for &cnt in &o.i_payload {
            for s in 0..cnt as usize {
                let n = KOp::from_u8(o.b.opcode[op_idx]);
                let arity = o.b.arity[op_idx] as usize;
                let imm = o.b.imm[op_idx];
                let m = o.b.mask[op_idx];
                // O unrolled: direct fetches, no operand loop for arity<=3.
                self.lo[s] = match arity {
                    1 => {
                        let a = v[o.b.r_coords[r_idx] as usize];
                        eval_op(n, &[a], imm, m, o.b.aux[op_idx])
                    }
                    2 => {
                        let a = v[o.b.r_coords[r_idx] as usize];
                        let b = v[o.b.r_coords[r_idx + 1] as usize];
                        eval_op(n, &[a, b], imm, m, o.b.aux[op_idx])
                    }
                    3 => {
                        let a = v[o.b.r_coords[r_idx] as usize];
                        let b = v[o.b.r_coords[r_idx + 1] as usize];
                        let c = v[o.b.r_coords[r_idx + 2] as usize];
                        eval_op(n, &[a, b, c], imm, m, o.b.aux[op_idx])
                    }
                    _ => {
                        // MuxChain: variable arity still gathers
                        for oo in 0..arity {
                            self.chain_buf[oo] = v[o.b.r_coords[r_idx + oo] as usize];
                        }
                        eval_op(n, &self.chain_buf[..arity], imm, m, o.b.aux[op_idx])
                    }
                };
                r_idx += arity;
                op_idx += 1;
            }
            for s in 0..cnt as usize {
                v[o.b.s_coords[wb_idx + s] as usize] = self.lo[s];
            }
            wb_idx += cnt as usize;
        }
        self.d.commit();
    }

    fn slots(&self) -> &[u64] {
        &self.d.v
    }

    fn outputs(&self) -> Vec<(String, u64)> {
        self.d.named_outputs()
    }


    fn poke(&mut self, slot: u32, value: u64) {
        self.d.v[slot as usize] = value;
    }

    fn program_bytes(&self) -> usize {
        crate::perf::binsize::kernel_code_bytes(super::KernelConfig::OU, &self.oim)
    }

    fn data_bytes(&self) -> usize {
        crate::perf::binsize::kernel_data_bytes(super::KernelConfig::OU, &self.oim)
    }
}
