//! **RU** — R-rank-unrolled kernel (paper Algorithm 3).
//!
//! The mostly rolled extreme: traverses the format-B OIM arrays
//! (`[I, S, N, O, R]` loop order) with cursors, dispatching through the
//! `op_r[n]` case statement *per operation* and looping over operands
//! (only the one-hot R rank is "unrolled", i.e. there is no R loop).
//! Minimal program size, maximal metadata traffic — the tensor-algebra
//! default the paper starts from.

use super::common::{eval_op, Driver};
use super::SimKernel;
use crate::tensor::ir::{KOp, LayerIr};
use crate::tensor::oim::Oim;

pub struct RuKernel {
    d: Driver,
    oim: Oim,
    /// LO buffer (layer-output tensor), reused across layers.
    lo: Vec<u64>,
    /// operand gather buffer (`sel_inputs` in Algorithm 3)
    operands: Vec<u64>,
}

impl RuKernel {
    pub fn new(ir: &LayerIr, oim: &Oim) -> Self {
        let max_layer = ir.max_layer_ops();
        let max_arity = oim.b.arity.iter().copied().max().unwrap_or(1) as usize;
        RuKernel {
            d: Driver::new(ir),
            oim: oim.clone(),
            lo: vec![0; max_layer],
            operands: vec![0; max_arity.max(3)],
        }
    }
}

impl SimKernel for RuKernel {
    fn config_name(&self) -> &'static str {
        "RU"
    }

    fn step(&mut self, inputs: &[u64]) {
        self.d.set_inputs(inputs);
        let o = &self.oim;
        let v = &mut self.d.v;
        let mut op_idx = 0usize;
        let mut r_idx = 0usize;
        let mut wb_idx = 0usize;
        for &cnt in &o.i_payload {
            // ---- rank S loop (rolled) ----
            for s in 0..cnt as usize {
                // rank N: read the op type coordinate
                let n = o.b.opcode[op_idx];
                let arity = o.b.arity[op_idx] as usize;
                // ---- rank O loop (rolled; R one-hot, fetched inline) ----
                for oo in 0..arity {
                    self.operands[oo] = v[o.b.r_coords[r_idx + oo] as usize];
                }
                // case dispatch (op_u/op_r/op_s fused per Algorithm 2/3)
                self.lo[s] = eval_op(
                    KOp::from_u8(n),
                    &self.operands[..arity],
                    o.b.imm[op_idx],
                    o.b.mask[op_idx],
                    o.b.aux[op_idx],
                );
                r_idx += arity;
                op_idx += 1;
            }
            // ---- writeback: LI_{i+1,s} = LO_{i,s} (final cascade Einsum) ----
            for s in 0..cnt as usize {
                v[o.b.s_coords[wb_idx + s] as usize] = self.lo[s];
            }
            wb_idx += cnt as usize;
        }
        self.d.commit();
    }

    fn slots(&self) -> &[u64] {
        &self.d.v
    }

    fn outputs(&self) -> Vec<(String, u64)> {
        self.d.named_outputs()
    }


    fn poke(&mut self, slot: u32, value: u64) {
        self.d.v[slot as usize] = value;
    }

    fn program_bytes(&self) -> usize {
        crate::perf::binsize::kernel_code_bytes(super::KernelConfig::RU, &self.oim)
    }

    fn data_bytes(&self) -> usize {
        crate::perf::binsize::kernel_data_bytes(super::KernelConfig::RU, &self.oim)
    }
}
