//! Lane-batched kernel executors (throughput simulation).
//!
//! One walk of the OIM metadata (or of the SU/TI-style tape) steps `B`
//! independent stimulus lanes at once: the per-op metadata fetch, dispatch
//! and cursor arithmetic are paid once per operation instead of once per
//! (operation, lane). Slot files are **lane-major** (`v[s * B + lane]`, see
//! [`super::common::BatchDriver`]) so the innermost lane loop is a
//! contiguous streaming loop the compiler can vectorize.
//!
//! All seven binding levels have a batched executor (mirroring the
//! scalar kernels they batch):
//!
//! * [`BatchRuKernel`] — format-B cursor walk, case dispatch per op
//!   (batched RU): the rolled extreme, where batching amortizes the most
//!   metadata traffic per lane.
//! * [`BatchOuKernel`] — format-B walk with the operand loop unrolled
//!   (batched OU): fetch bases resolved inline by arity, no gather
//!   buffer for the common arities.
//! * [`BatchNuKernel`] — format-C group walk with dispatch hoisted out of
//!   the S loop (batched NU; the PSU flavour shares it, differing only in
//!   name — the lane loop replaces the scalar partial S unroll).
//! * [`BatchIuKernel`] — the flattened group-command program of the
//!   scalar IU (empty groups compiled away, cursors precomputed), with a
//!   lane inner loop per command.
//! * [`BatchSuKernel`] — straight-line op tape over lane-major slots
//!   (batched SU): the OIM embedded in the program, writebacks unrolled
//!   into per-record lane loops.
//! * [`BatchTiKernel`] — tape of precompiled per-opcode functions with
//!   operand slots baked in (batched TI): the unrolled extreme, where
//!   batching amortizes the tape walk itself.
//!
//! The sparse (activity-masked) wrappers over these live in
//! [`super::batch_sparse`].
//!
//! Lanes never interact: a `B`-lane batched run is bit-identical to `B`
//! independent single-lane runs of the corresponding scalar kernel
//! (property-tested in `tests/kernels_property.rs`).
//!
//! ## Lane tiling
//!
//! The group-walk bodies (NU/PSU/IU), the SU tape records and the TI
//! tape functions run their lane loops through the fixed-width tile
//! primitives of [`super::tile`] (`[u64; 8]` tiles, `[u64; 4]` fallback,
//! scalar remainder for `B % W != 0`), with the op body dispatched
//! through [`kop_dispatch`] so each opcode monomorphizes its own tiled
//! loop — no per-lane function-pointer call in the hot path. `MuxChain`
//! is the documented exception: its variable arity has no fixed-shape
//! tile, so it stays lane-at-a-time in every executor. The pre-tile
//! lane-at-a-time path is retained (`run_group_lanes_scalar`, the
//! `bt*_scalar` tape, [`super::build_batch_baseline`]) as the
//! auto-vectorized baseline the tiled executors are benchmarked and
//! differentially tested against.

use super::common::{eval_op, BatchDriver};
use super::{tile, BatchKernel};
use crate::tensor::ir::{KOp, LayerIr, OpRec, NUM_KOPS};
use crate::tensor::oim::Oim;

// --------------------------------------------------------------- RU (batched)

/// Batched **RU**: traverses the format-B arrays with cursors, dispatching
/// through the `op_r[n]` case statement once per operation and evaluating
/// all lanes inside the dispatch.
pub struct BatchRuKernel {
    d: BatchDriver,
    oim: Oim,
    /// lane-major LO buffer (`max_layer_ops * lanes`)
    lo: Vec<u64>,
    /// per-lane operand gather buffer
    operands: Vec<u64>,
}

impl BatchRuKernel {
    pub fn new(ir: &LayerIr, oim: &Oim, lanes: usize) -> Self {
        let max_arity = oim.b.arity.iter().copied().max().unwrap_or(1) as usize;
        BatchRuKernel {
            d: BatchDriver::new(ir, lanes),
            oim: oim.clone(),
            lo: vec![0; ir.max_layer_ops() * lanes],
            operands: vec![0; max_arity.max(3)],
        }
    }
}

impl BatchKernel for BatchRuKernel {
    fn config_name(&self) -> &'static str {
        "RU"
    }

    fn lanes(&self) -> usize {
        self.d.lanes
    }

    fn step(&mut self, inputs: &[u64]) {
        self.d.set_inputs(inputs);
        let lanes = self.d.lanes;
        let o = &self.oim;
        let v = &mut self.d.v;
        let mut op_idx = 0usize;
        let mut r_idx = 0usize;
        let mut wb_idx = 0usize;
        for &cnt in &o.i_payload {
            for s in 0..cnt as usize {
                let n = KOp::from_u8(o.b.opcode[op_idx]);
                let arity = o.b.arity[op_idx] as usize;
                let imm = o.b.imm[op_idx];
                let m = o.b.mask[op_idx];
                let aux = o.b.aux[op_idx];
                let ob = s * lanes;
                for l in 0..lanes {
                    for oo in 0..arity {
                        self.operands[oo] = v[o.b.r_coords[r_idx + oo] as usize * lanes + l];
                    }
                    self.lo[ob + l] = eval_op(n, &self.operands[..arity], imm, m, aux);
                }
                r_idx += arity;
                op_idx += 1;
            }
            for s in 0..cnt as usize {
                let sb = o.b.s_coords[wb_idx + s] as usize * lanes;
                let lb = s * lanes;
                for l in 0..lanes {
                    v[sb + l] = self.lo[lb + l];
                }
            }
            wb_idx += cnt as usize;
        }
        self.d.commit();
    }

    fn slots(&self) -> &[u64] {
        &self.d.v
    }

    fn lane_outputs(&self, lane: usize) -> Vec<(String, u64)> {
        self.d.lane_outputs(lane)
    }

    fn write_lane_outputs(&self, lane: usize, buf: &mut Vec<(String, u64)>) {
        self.d.write_lane_outputs(lane, buf);
    }

    fn poke_lane(&mut self, slot: u32, lane: usize, value: u64) {
        self.d.poke_lane(slot, lane, value);
    }

    fn restore_slots(&mut self, slots: &[u64]) -> Result<(), String> {
        self.d.restore_slots(slots)
    }
}

// --------------------------------------------------------------- OU (batched)

/// Batched **OU**: same format-B cursor walk as [`BatchRuKernel`], but the
/// operand loop is unrolled — fetch bases are computed inline by arity and
/// the per-lane gather buffer disappears for the common arities, exactly
/// the redundant data movement the scalar OU removes from RU. The lane
/// loop stays innermost and contiguous.
pub struct BatchOuKernel {
    d: BatchDriver,
    oim: Oim,
    /// lane-major LO buffer (`max_layer_ops * lanes`)
    lo: Vec<u64>,
    /// per-lane gather buffer (MuxChain only)
    chain_buf: Vec<u64>,
}

impl BatchOuKernel {
    pub fn new(ir: &LayerIr, oim: &Oim, lanes: usize) -> Self {
        let max_arity = oim.b.arity.iter().copied().max().unwrap_or(1) as usize;
        BatchOuKernel {
            d: BatchDriver::new(ir, lanes),
            oim: oim.clone(),
            lo: vec![0; ir.max_layer_ops() * lanes],
            chain_buf: vec![0; max_arity.max(3)],
        }
    }
}

impl BatchKernel for BatchOuKernel {
    fn config_name(&self) -> &'static str {
        "OU"
    }

    fn lanes(&self) -> usize {
        self.d.lanes
    }

    fn step(&mut self, inputs: &[u64]) {
        self.d.set_inputs(inputs);
        let lanes = self.d.lanes;
        let o = &self.oim;
        let v = &mut self.d.v;
        let mut op_idx = 0usize;
        let mut r_idx = 0usize;
        let mut wb_idx = 0usize;
        for &cnt in &o.i_payload {
            for s in 0..cnt as usize {
                let n = KOp::from_u8(o.b.opcode[op_idx]);
                let arity = o.b.arity[op_idx] as usize;
                let imm = o.b.imm[op_idx];
                let m = o.b.mask[op_idx];
                let aux = o.b.aux[op_idx];
                let ob = s * lanes;
                // O unrolled: operand bases resolved once per op, no
                // gather loop for arity <= 3.
                match arity {
                    1 => {
                        let ab = o.b.r_coords[r_idx] as usize * lanes;
                        for l in 0..lanes {
                            self.lo[ob + l] = eval_op(n, &[v[ab + l]], imm, m, aux);
                        }
                    }
                    2 => {
                        let ab = o.b.r_coords[r_idx] as usize * lanes;
                        let bb = o.b.r_coords[r_idx + 1] as usize * lanes;
                        for l in 0..lanes {
                            self.lo[ob + l] = eval_op(n, &[v[ab + l], v[bb + l]], imm, m, aux);
                        }
                    }
                    3 => {
                        let ab = o.b.r_coords[r_idx] as usize * lanes;
                        let bb = o.b.r_coords[r_idx + 1] as usize * lanes;
                        let cb = o.b.r_coords[r_idx + 2] as usize * lanes;
                        for l in 0..lanes {
                            self.lo[ob + l] =
                                eval_op(n, &[v[ab + l], v[bb + l], v[cb + l]], imm, m, aux);
                        }
                    }
                    _ => {
                        // MuxChain: variable arity still gathers per lane
                        for l in 0..lanes {
                            for oo in 0..arity {
                                self.chain_buf[oo] =
                                    v[o.b.r_coords[r_idx + oo] as usize * lanes + l];
                            }
                            self.lo[ob + l] =
                                eval_op(n, &self.chain_buf[..arity], imm, m, aux);
                        }
                    }
                }
                r_idx += arity;
                op_idx += 1;
            }
            for s in 0..cnt as usize {
                let sb = o.b.s_coords[wb_idx + s] as usize * lanes;
                let lb = s * lanes;
                for l in 0..lanes {
                    v[sb + l] = self.lo[lb + l];
                }
            }
            wb_idx += cnt as usize;
        }
        self.d.commit();
    }

    fn slots(&self) -> &[u64] {
        &self.d.v
    }

    fn lane_outputs(&self, lane: usize) -> Vec<(String, u64)> {
        self.d.lane_outputs(lane)
    }

    fn write_lane_outputs(&self, lane: usize, buf: &mut Vec<(String, u64)>) {
        self.d.write_lane_outputs(lane, buf);
    }

    fn poke_lane(&mut self, slot: u32, lane: usize, value: u64) {
        self.d.poke_lane(slot, lane, value);
    }

    fn restore_slots(&mut self, slots: &[u64]) -> Result<(), String> {
        self.d.restore_slots(slots)
    }
}

// ---------------------------------------------------- NU / PSU (batched)

/// Central opcode table shared by every tiled dispatch site (the dense
/// group walk, the SU record evaluator, and the sparse group walk in
/// [`super::batch_sparse`]): maps each [`KOp`] to one of four loop
/// *shapes*, handing the op body to the shape as an inline closure.
/// Each call site supplies the four shapes as local macros, so every
/// (site, opcode) pair monomorphizes its own tiled lane loop — the
/// dispatch happens once per group/record, never per lane.
///
/// Closure signatures: `$un` receives `|a, imm, aux| -> u64`, `$bin`
/// receives `|a, b, imm| -> u64`; `$mux` and `$chain` take no body
/// (their shapes are fixed). Result masking is the shape's job.
macro_rules! kop_dispatch {
    ($n:expr, $un:ident, $bin:ident, $mux:ident, $chain:ident) => {
        match $n {
            KOp::Add => $bin!(|a, b, _imm| a.wrapping_add(b)),
            KOp::Sub => $bin!(|a, b, _imm| a.wrapping_sub(b)),
            KOp::Mul => $bin!(|a, b, _imm| a.wrapping_mul(b)),
            KOp::Div => $bin!(|a, b, _imm| if b == 0 { 0 } else { a / b }),
            KOp::Rem => $bin!(|a, b, _imm| if b == 0 { 0 } else { a % b }),
            KOp::Lt => $bin!(|a, b, _imm| (a < b) as u64),
            KOp::Leq => $bin!(|a, b, _imm| (a <= b) as u64),
            KOp::Gt => $bin!(|a, b, _imm| (a > b) as u64),
            KOp::Geq => $bin!(|a, b, _imm| (a >= b) as u64),
            KOp::Eq => $bin!(|a, b, _imm| (a == b) as u64),
            KOp::Neq => $bin!(|a, b, _imm| (a != b) as u64),
            KOp::And => $bin!(|a, b, _imm| a & b),
            KOp::Or => $bin!(|a, b, _imm| a | b),
            KOp::Xor => $bin!(|a, b, _imm| a ^ b),
            KOp::Dshl => $bin!(|a, b, _imm| if b >= 64 { 0 } else { a << b }),
            KOp::Dshr => $bin!(|a, b, _imm| if b >= 64 { 0 } else { a >> b }),
            KOp::Cat => $bin!(|a, b, imm| (a << imm) | b),
            KOp::Not => $un!(|a, _imm, _aux| !a),
            KOp::Neg => $un!(|a, _imm, _aux| a.wrapping_neg()),
            KOp::AndrK => $un!(|a, _imm, aux| (a == aux) as u64),
            KOp::Orr => $un!(|a, _imm, _aux| (a != 0) as u64),
            KOp::Xorr => $un!(|a, _imm, _aux| (a.count_ones() & 1) as u64),
            KOp::ShlI => $un!(|a, imm, _aux| a << imm),
            KOp::ShrI => $un!(|a, imm, _aux| a >> imm),
            KOp::Copy => $un!(|a, _imm, _aux| a),
            KOp::Mux => $mux!(),
            KOp::MuxChain => $chain!(),
        }
    };
}
pub(super) use kop_dispatch;

/// Scalar op body used by the **baseline** (pre-tile) group loops: the
/// dispatch happens once per (layer, op-type) group, then the group loop
/// iterates (element, lane) calling one of these function pointers per
/// lane — the lane-at-a-time path the tiled executors replaced, kept as
/// the auto-vectorized comparison point ([`super::build_batch_baseline`]).
pub(super) enum LaneOp {
    /// `(a, imm, aux) -> out`
    Un(fn(u64, u8, u64) -> u64),
    /// `(a, b, imm) -> out`
    Bin(fn(u64, u64, u8) -> u64),
    Mux,
    Chain,
}

pub(super) fn lane_op(n: KOp) -> LaneOp {
    match n {
        KOp::Add => LaneOp::Bin(|a, b, _| a.wrapping_add(b)),
        KOp::Sub => LaneOp::Bin(|a, b, _| a.wrapping_sub(b)),
        KOp::Mul => LaneOp::Bin(|a, b, _| a.wrapping_mul(b)),
        KOp::Div => LaneOp::Bin(|a, b, _| if b == 0 { 0 } else { a / b }),
        KOp::Rem => LaneOp::Bin(|a, b, _| if b == 0 { 0 } else { a % b }),
        KOp::Lt => LaneOp::Bin(|a, b, _| (a < b) as u64),
        KOp::Leq => LaneOp::Bin(|a, b, _| (a <= b) as u64),
        KOp::Gt => LaneOp::Bin(|a, b, _| (a > b) as u64),
        KOp::Geq => LaneOp::Bin(|a, b, _| (a >= b) as u64),
        KOp::Eq => LaneOp::Bin(|a, b, _| (a == b) as u64),
        KOp::Neq => LaneOp::Bin(|a, b, _| (a != b) as u64),
        KOp::And => LaneOp::Bin(|a, b, _| a & b),
        KOp::Or => LaneOp::Bin(|a, b, _| a | b),
        KOp::Xor => LaneOp::Bin(|a, b, _| a ^ b),
        KOp::Not => LaneOp::Un(|a, _, _| !a),
        KOp::Neg => LaneOp::Un(|a, _, _| a.wrapping_neg()),
        KOp::AndrK => LaneOp::Un(|a, _, x| (a == x) as u64),
        KOp::Orr => LaneOp::Un(|a, _, _| (a != 0) as u64),
        KOp::Xorr => LaneOp::Un(|a, _, _| (a.count_ones() & 1) as u64),
        KOp::ShlI => LaneOp::Un(|a, s, _| a << s),
        KOp::ShrI => LaneOp::Un(|a, s, _| a >> s),
        KOp::Dshl => LaneOp::Bin(|a, b, _| if b >= 64 { 0 } else { a << b }),
        KOp::Dshr => LaneOp::Bin(|a, b, _| if b >= 64 { 0 } else { a >> b }),
        KOp::Cat => LaneOp::Bin(|a, b, s| (a << s) | b),
        KOp::Mux => LaneOp::Mux,
        KOp::Copy => LaneOp::Un(|a, _, _| a),
        KOp::MuxChain => LaneOp::Chain,
    }
}

/// Evaluate one (op type, group) over all lanes through the tiled lane
/// loops of [`super::tile`] — the opcode dispatch happens once per group
/// ([`kop_dispatch`]), each opcode monomorphizing its own `[u64; 8]` /
/// `[u64; 4]` / scalar-remainder loop. Returns the number of
/// operand-slot entries consumed (as `run_group` does for the scalar
/// path). `MuxChain` keeps the lane-at-a-time gather (variable arity —
/// the documented tile exception).
#[allow(clippy::too_many_arguments)]
fn run_group_lanes(
    n: u8,
    lanes: usize,
    v: &[u64],
    lo: &mut [u64],
    lo_pos: usize,
    cnt: usize,
    r: &[u32],
    imm: &[u8],
    msk: &[u64],
    aux: &[u64],
    arity: &[u8],
    chain_buf: &mut [u64],
) -> usize {
    macro_rules! un {
        ($f:expr) => {{
            let f = $f;
            for i in 0..cnt {
                let ab = r[i] as usize * lanes;
                let ob = (lo_pos + i) * lanes;
                let (im, ax) = (imm[i], aux[i]);
                tile::un(v, ab, lo, ob, lanes, msk[i], move |a| f(a, im, ax));
            }
            cnt
        }};
    }
    macro_rules! bin {
        ($f:expr) => {{
            let f = $f;
            for i in 0..cnt {
                let ab = r[2 * i] as usize * lanes;
                let bb = r[2 * i + 1] as usize * lanes;
                let ob = (lo_pos + i) * lanes;
                let im = imm[i];
                tile::bin(v, ab, bb, lo, ob, lanes, msk[i], move |a, b| f(a, b, im));
            }
            2 * cnt
        }};
    }
    macro_rules! mux {
        () => {{
            for i in 0..cnt {
                let sb = r[3 * i] as usize * lanes;
                let tb = r[3 * i + 1] as usize * lanes;
                let fb = r[3 * i + 2] as usize * lanes;
                let ob = (lo_pos + i) * lanes;
                tile::mux(v, sb, tb, fb, lo, ob, lanes, msk[i]);
            }
            3 * cnt
        }};
    }
    macro_rules! chain {
        () => {{
            let mut r_off = 0usize;
            for i in 0..cnt {
                let ar = arity[i] as usize;
                let ob = (lo_pos + i) * lanes;
                let k = imm[i] as usize;
                for l in 0..lanes {
                    for o in 0..ar {
                        chain_buf[o] = v[r[r_off + o] as usize * lanes + l];
                    }
                    let mut val = chain_buf[2 * k];
                    for j in (0..k).rev() {
                        if chain_buf[2 * j] != 0 {
                            val = chain_buf[2 * j + 1];
                        }
                    }
                    lo[ob + l] = val & msk[i];
                }
                r_off += ar;
            }
            r_off
        }};
    }
    kop_dispatch!(KOp::from_u8(n), un, bin, mux, chain)
}

/// The pre-tile lane-at-a-time group body ([`LaneOp`] function pointer
/// per lane) — the baseline executors' counterpart of
/// [`run_group_lanes`], bit-identical to it by the remainder-loop
/// invariant (differentially tested in `tests/kernels_property.rs`).
#[allow(clippy::too_many_arguments)]
fn run_group_lanes_scalar(
    n: u8,
    lanes: usize,
    v: &[u64],
    lo: &mut [u64],
    lo_pos: usize,
    cnt: usize,
    r: &[u32],
    imm: &[u8],
    msk: &[u64],
    aux: &[u64],
    arity: &[u8],
    chain_buf: &mut [u64],
) -> usize {
    match lane_op(KOp::from_u8(n)) {
        LaneOp::Un(f) => {
            for i in 0..cnt {
                let ab = r[i] as usize * lanes;
                let ob = (lo_pos + i) * lanes;
                for l in 0..lanes {
                    lo[ob + l] = f(v[ab + l], imm[i], aux[i]) & msk[i];
                }
            }
            cnt
        }
        LaneOp::Bin(f) => {
            for i in 0..cnt {
                let ab = r[2 * i] as usize * lanes;
                let bb = r[2 * i + 1] as usize * lanes;
                let ob = (lo_pos + i) * lanes;
                for l in 0..lanes {
                    lo[ob + l] = f(v[ab + l], v[bb + l], imm[i]) & msk[i];
                }
            }
            2 * cnt
        }
        LaneOp::Mux => {
            for i in 0..cnt {
                let sb = r[3 * i] as usize * lanes;
                let tb = r[3 * i + 1] as usize * lanes;
                let fb = r[3 * i + 2] as usize * lanes;
                let ob = (lo_pos + i) * lanes;
                for l in 0..lanes {
                    lo[ob + l] =
                        (if v[sb + l] != 0 { v[tb + l] } else { v[fb + l] }) & msk[i];
                }
            }
            3 * cnt
        }
        LaneOp::Chain => {
            let mut r_off = 0usize;
            for i in 0..cnt {
                let ar = arity[i] as usize;
                let ob = (lo_pos + i) * lanes;
                let k = imm[i] as usize;
                for l in 0..lanes {
                    for o in 0..ar {
                        chain_buf[o] = v[r[r_off + o] as usize * lanes + l];
                    }
                    let mut val = chain_buf[2 * k];
                    for j in (0..k).rev() {
                        if chain_buf[2 * j] != 0 {
                            val = chain_buf[2 * j + 1];
                        }
                    }
                    lo[ob + l] = val & msk[i];
                }
                r_off += ar;
            }
            r_off
        }
    }
}

/// Layer writeback shared by the batched group-walk executors (NU/PSU
/// and IU): copy each lane-major LO entry into its LI slot. (The batched
/// SU intentionally does *not* route through this — its writebacks are
/// unrolled into explicit per-record tape entries, mirroring the scalar
/// SU's binding level.)
#[inline]
fn write_back_lanes(v: &mut [u64], lo: &[u64], s: &[u32], lanes: usize) {
    for (i, &slot) in s.iter().enumerate() {
        let sb = slot as usize * lanes;
        let lb = i * lanes;
        v[sb..sb + lanes].copy_from_slice(&lo[lb..lb + lanes]);
    }
}

/// Batched **NU / PSU**: format-C group walk with per-op-type dispatch
/// hoisted out of the (S, lane) loops. In the batched executors the lane
/// loop takes the place of the scalar PSU's partial S unroll as the
/// innermost fixed-trip loop, so the NU and PSU flavours share one
/// executor and differ only in the reported name.
pub struct BatchNuKernel {
    name: &'static str,
    d: BatchDriver,
    oim: Oim,
    lo: Vec<u64>,
    chain_buf: Vec<u64>,
    /// tiled lane loops (default) vs the pre-tile lane-at-a-time baseline
    tiled: bool,
}

impl BatchNuKernel {
    pub fn new(ir: &LayerIr, oim: &Oim, lanes: usize, name: &'static str) -> Self {
        Self::with_tiling(ir, oim, lanes, name, true)
    }

    /// The pre-tile (auto-vectorized baseline) variant — lane loops call
    /// a [`LaneOp`] function pointer per lane instead of the tiled bodies.
    pub fn new_baseline(ir: &LayerIr, oim: &Oim, lanes: usize, name: &'static str) -> Self {
        Self::with_tiling(ir, oim, lanes, name, false)
    }

    fn with_tiling(ir: &LayerIr, oim: &Oim, lanes: usize, name: &'static str, tiled: bool) -> Self {
        let max_arity = oim.c.arity.iter().copied().max().unwrap_or(1) as usize;
        BatchNuKernel {
            name,
            d: BatchDriver::new(ir, lanes),
            oim: oim.clone(),
            lo: vec![0; ir.max_layer_ops() * lanes],
            chain_buf: vec![0; max_arity.max(3)],
            tiled,
        }
    }
}

impl BatchKernel for BatchNuKernel {
    fn config_name(&self) -> &'static str {
        self.name
    }

    fn lanes(&self) -> usize {
        self.d.lanes
    }

    fn step(&mut self, inputs: &[u64]) {
        self.d.set_inputs(inputs);
        let lanes = self.d.lanes;
        let o = &self.oim;
        let v = &mut self.d.v;
        let mut op_idx = 0usize;
        let mut r_idx = 0usize;
        let mut wb_idx = 0usize;
        let layers = o.i_payload.len();
        for layer in 0..layers {
            let mut lo_pos = 0usize;
            for n in 0..NUM_KOPS {
                let cnt = o.n_payload[layer * NUM_KOPS + n] as usize;
                if cnt == 0 {
                    continue;
                }
                let body = if self.tiled { run_group_lanes } else { run_group_lanes_scalar };
                let consumed = body(
                    n as u8,
                    lanes,
                    v,
                    &mut self.lo,
                    lo_pos,
                    cnt,
                    &o.c.r_coords[r_idx..],
                    &o.c.imm[op_idx..],
                    &o.c.mask[op_idx..],
                    &o.c.aux[op_idx..],
                    &o.c.arity[op_idx..],
                    &mut self.chain_buf,
                );
                r_idx += consumed;
                op_idx += cnt;
                lo_pos += cnt;
            }
            let cnt = o.i_payload[layer] as usize;
            write_back_lanes(v, &self.lo, &o.c.s_coords[wb_idx..wb_idx + cnt], lanes);
            wb_idx += cnt;
        }
        self.d.commit();
    }

    fn slots(&self) -> &[u64] {
        &self.d.v
    }

    fn lane_outputs(&self, lane: usize) -> Vec<(String, u64)> {
        self.d.lane_outputs(lane)
    }

    fn write_lane_outputs(&self, lane: usize, buf: &mut Vec<(String, u64)>) {
        self.d.write_lane_outputs(lane, buf);
    }

    fn poke_lane(&mut self, slot: u32, lane: usize, value: u64) {
        self.d.poke_lane(slot, lane, value);
    }

    fn restore_slots(&mut self, slots: &[u64]) -> Result<(), String> {
        self.d.restore_slots(slots)
    }
}

// --------------------------------------------------------------- IU (batched)

/// Batched **IU**: walks the same flattened group-command program as the
/// scalar [`super::iu::IuKernel`] (empty groups compiled away, all
/// cursors precomputed — zero per-layer overhead), running the lane inner
/// loop inside each group command. The group bodies are shared with
/// [`BatchNuKernel`]; what IU adds is the program flattening.
pub struct BatchIuKernel {
    d: BatchDriver,
    oim: Oim,
    program: Vec<super::iu::Cmd>,
    /// lane-major LO buffer (`max_layer_ops * lanes`)
    lo: Vec<u64>,
    chain_buf: Vec<u64>,
    /// tiled lane loops (default) vs the pre-tile lane-at-a-time baseline
    tiled: bool,
}

impl BatchIuKernel {
    pub fn new(ir: &LayerIr, oim: &Oim, lanes: usize) -> Self {
        Self::with_tiling(ir, oim, lanes, true)
    }

    /// The pre-tile (auto-vectorized baseline) variant.
    pub fn new_baseline(ir: &LayerIr, oim: &Oim, lanes: usize) -> Self {
        Self::with_tiling(ir, oim, lanes, false)
    }

    fn with_tiling(ir: &LayerIr, oim: &Oim, lanes: usize, tiled: bool) -> Self {
        let max_arity = oim.c.arity.iter().copied().max().unwrap_or(1) as usize;
        BatchIuKernel {
            d: BatchDriver::new(ir, lanes),
            oim: oim.clone(),
            program: super::iu::flatten_program(oim),
            lo: vec![0; ir.max_layer_ops() * lanes],
            chain_buf: vec![0; max_arity.max(3)],
            tiled,
        }
    }
}

impl BatchKernel for BatchIuKernel {
    fn config_name(&self) -> &'static str {
        "IU"
    }

    fn lanes(&self) -> usize {
        self.d.lanes
    }

    fn step(&mut self, inputs: &[u64]) {
        self.d.set_inputs(inputs);
        let lanes = self.d.lanes;
        let o = &self.oim;
        let v = &mut self.d.v;
        let body = if self.tiled { run_group_lanes } else { run_group_lanes_scalar };
        for cmd in &self.program {
            match *cmd {
                super::iu::Cmd::Group { n, cnt, op_idx, r_idx, lo_pos } => {
                    let (cnt, op_idx, r_idx, lo_pos) =
                        (cnt as usize, op_idx as usize, r_idx as usize, lo_pos as usize);
                    body(
                        n,
                        lanes,
                        v,
                        &mut self.lo,
                        lo_pos,
                        cnt,
                        &o.c.r_coords[r_idx..],
                        &o.c.imm[op_idx..],
                        &o.c.mask[op_idx..],
                        &o.c.aux[op_idx..],
                        &o.c.arity[op_idx..],
                        &mut self.chain_buf,
                    );
                }
                super::iu::Cmd::Writeback { wb_idx, cnt } => {
                    let (wb_idx, cnt) = (wb_idx as usize, cnt as usize);
                    write_back_lanes(v, &self.lo, &o.c.s_coords[wb_idx..wb_idx + cnt], lanes);
                }
            }
        }
        self.d.commit();
    }

    fn slots(&self) -> &[u64] {
        &self.d.v
    }

    fn lane_outputs(&self, lane: usize) -> Vec<(String, u64)> {
        self.d.lane_outputs(lane)
    }

    fn write_lane_outputs(&self, lane: usize, buf: &mut Vec<(String, u64)>) {
        self.d.write_lane_outputs(lane, buf);
    }

    fn poke_lane(&mut self, slot: u32, lane: usize, value: u64) {
        self.d.poke_lane(slot, lane, value);
    }

    fn restore_slots(&mut self, slots: &[u64]) -> Result<(), String> {
        self.d.restore_slots(slots)
    }
}

// --------------------------------------------------------------- SU (batched)

/// A batched tape op: the self-contained record plus its LO position
/// (mirrors the scalar SU's `TapeOp`).
#[derive(Clone, Copy, Debug)]
struct BatchTapeOp {
    rec: OpRec,
    lo_pos: u32,
}

/// Layer segment boundaries in the batched SU tape.
#[derive(Clone, Copy, Debug)]
struct BatchSegment {
    op_start: u32,
    op_end: u32,
    wb_start: u32,
    wb_end: u32,
}

/// Evaluate one self-contained tape record over all lanes into the
/// lane-major LO buffer at `ob` — the lane-strided analog of the scalar
/// SU's `eval_rec` call, dispatching from the record at run time (the
/// OIM lives in the "code"; contrast [`BatchTiKernel`], which resolves
/// the dispatch to a function pointer at build time). The lane loop runs
/// tiled ([`kop_dispatch`] + [`super::tile`]); `MuxChain` stays
/// lane-at-a-time (variable arity).
fn eval_rec_lanes(rec: &OpRec, v: &[u64], ext: &[u32], lanes: usize, lo: &mut [u64], ob: usize) {
    macro_rules! un {
        ($f:expr) => {{
            let f = $f;
            let (im, ax) = (rec.imm, rec.aux);
            tile::un(v, rec.a as usize * lanes, lo, ob, lanes, rec.mask, move |a| f(a, im, ax));
        }};
    }
    macro_rules! bin {
        ($f:expr) => {{
            let f = $f;
            let im = rec.imm;
            tile::bin(
                v,
                rec.a as usize * lanes,
                rec.b as usize * lanes,
                lo,
                ob,
                lanes,
                rec.mask,
                move |a, b| f(a, b, im),
            );
        }};
    }
    macro_rules! mux {
        () => {
            tile::mux(
                v,
                rec.a as usize * lanes,
                rec.b as usize * lanes,
                rec.c as usize * lanes,
                lo,
                ob,
                lanes,
                rec.mask,
            )
        };
    }
    macro_rules! chain {
        () => {{
            // operands: sel0 = a, v0 = b, then ext (sel1, v1, .., default)
            let k = rec.imm as usize;
            let e = &ext[rec.ext as usize..rec.ext as usize + 2 * k - 1];
            for l in 0..lanes {
                let val = if v[rec.a as usize * lanes + l] != 0 {
                    v[rec.b as usize * lanes + l]
                } else {
                    let mut x = v[e[2 * k - 2] as usize * lanes + l];
                    for i in (0..k - 1).rev() {
                        if v[e[2 * i] as usize * lanes + l] != 0 {
                            x = v[e[2 * i + 1] as usize * lanes + l];
                        }
                    }
                    x
                };
                lo[ob + l] = val & rec.mask;
            }
        }};
    }
    kop_dispatch!(rec.kop(), un, bin, mux, chain)
}

/// The pre-tile lane-at-a-time record evaluator — the baseline SU's
/// counterpart of [`eval_rec_lanes`].
fn eval_rec_lanes_scalar(rec: &OpRec, v: &[u64], ext: &[u32], lanes: usize, lo: &mut [u64], ob: usize) {
    match lane_op(rec.kop()) {
        LaneOp::Un(f) => {
            let ab = rec.a as usize * lanes;
            for l in 0..lanes {
                lo[ob + l] = f(v[ab + l], rec.imm, rec.aux) & rec.mask;
            }
        }
        LaneOp::Bin(f) => {
            let ab = rec.a as usize * lanes;
            let bb = rec.b as usize * lanes;
            for l in 0..lanes {
                lo[ob + l] = f(v[ab + l], v[bb + l], rec.imm) & rec.mask;
            }
        }
        LaneOp::Mux => {
            let sb = rec.a as usize * lanes;
            let tb = rec.b as usize * lanes;
            let fb = rec.c as usize * lanes;
            for l in 0..lanes {
                lo[ob + l] = (if v[sb + l] != 0 { v[tb + l] } else { v[fb + l] }) & rec.mask;
            }
        }
        LaneOp::Chain => {
            // operands: sel0 = a, v0 = b, then ext (sel1, v1, .., default)
            let k = rec.imm as usize;
            let e = &ext[rec.ext as usize..rec.ext as usize + 2 * k - 1];
            for l in 0..lanes {
                let val = if v[rec.a as usize * lanes + l] != 0 {
                    v[rec.b as usize * lanes + l]
                } else {
                    let mut x = v[e[2 * k - 2] as usize * lanes + l];
                    for i in (0..k - 1).rev() {
                        if v[e[2 * i] as usize * lanes + l] != 0 {
                            x = v[e[2 * i + 1] as usize * lanes + l];
                        }
                    }
                    x
                };
                lo[ob + l] = val & rec.mask;
            }
        }
    }
}

/// Batched **SU**: the straight-line op tape of the scalar
/// [`super::su::SuKernel`] — the OIM fully embedded in the program, no
/// coordinate/payload arrays traversed at run time — with each tape
/// record and each unrolled writeback evaluating all lanes over the
/// lane-major slot file.
pub struct BatchSuKernel {
    d: BatchDriver,
    tape: Vec<BatchTapeOp>,
    /// writeback records: (LI slot, LO position)
    wb: Vec<(u32, u32)>,
    segments: Vec<BatchSegment>,
    ext_args: Vec<u32>,
    /// lane-major LO buffer (`max_layer_ops * lanes`)
    lo: Vec<u64>,
    /// tiled lane loops (default) vs the pre-tile lane-at-a-time baseline
    tiled: bool,
}

impl BatchSuKernel {
    pub fn new(ir: &LayerIr, oim: &Oim, lanes: usize) -> Self {
        Self::with_tiling(ir, oim, lanes, true)
    }

    /// The pre-tile (auto-vectorized baseline) variant.
    pub fn new_baseline(ir: &LayerIr, oim: &Oim, lanes: usize) -> Self {
        Self::with_tiling(ir, oim, lanes, false)
    }

    fn with_tiling(ir: &LayerIr, oim: &Oim, lanes: usize, tiled: bool) -> Self {
        let (layers, ext_args) = oim.op_recs();
        let mut tape = Vec::with_capacity(oim.total_ops());
        let mut wb = Vec::with_capacity(oim.total_ops());
        let mut segments = Vec::with_capacity(layers.len());
        for layer in &layers {
            let op_start = tape.len() as u32;
            let wb_start = wb.len() as u32;
            for (pos, rec) in layer.iter().enumerate() {
                tape.push(BatchTapeOp { rec: *rec, lo_pos: pos as u32 });
                wb.push((rec.out, pos as u32));
            }
            segments.push(BatchSegment {
                op_start,
                op_end: tape.len() as u32,
                wb_start,
                wb_end: wb.len() as u32,
            });
        }
        BatchSuKernel {
            d: BatchDriver::new(ir, lanes),
            tape,
            wb,
            segments,
            ext_args,
            lo: vec![0; ir.max_layer_ops() * lanes],
            tiled,
        }
    }
}

impl BatchKernel for BatchSuKernel {
    fn config_name(&self) -> &'static str {
        "SU"
    }

    fn lanes(&self) -> usize {
        self.d.lanes
    }

    fn step(&mut self, inputs: &[u64]) {
        self.d.set_inputs(inputs);
        let lanes = self.d.lanes;
        let v = &mut self.d.v;
        let body = if self.tiled { eval_rec_lanes } else { eval_rec_lanes_scalar };
        for seg in &self.segments {
            // straight-line op records (OIM embedded in the "code")
            for t in &self.tape[seg.op_start as usize..seg.op_end as usize] {
                let ob = t.lo_pos as usize * lanes;
                body(&t.rec, v, &self.ext_args, lanes, &mut self.lo, ob);
            }
            // unrolled writeback records
            for &(slot, lo_pos) in &self.wb[seg.wb_start as usize..seg.wb_end as usize] {
                let sb = slot as usize * lanes;
                let lb = lo_pos as usize * lanes;
                v[sb..sb + lanes].copy_from_slice(&self.lo[lb..lb + lanes]);
            }
        }
        self.d.commit();
    }

    fn slots(&self) -> &[u64] {
        &self.d.v
    }

    fn lane_outputs(&self, lane: usize) -> Vec<(String, u64)> {
        self.d.lane_outputs(lane)
    }

    fn write_lane_outputs(&self, lane: usize, buf: &mut Vec<(String, u64)>) {
        self.d.write_lane_outputs(lane, buf);
    }

    fn poke_lane(&mut self, slot: u32, lane: usize, value: u64) {
        self.d.poke_lane(slot, lane, value);
    }

    fn restore_slots(&mut self, slots: &[u64]) -> Result<(), String> {
        self.d.restore_slots(slots)
    }
}

// --------------------------------------------------------------- TI (batched)

type BtFn = fn(&mut [u64], &OpRec, &[u32], usize);

/// Each `bt_*` macro emits a **pair** of tape functions from one body: the
/// tiled variant (default, built on [`tile`]'s in-place primitives so every
/// opcode gets an explicitly unrollable `[u64; 8]` inner loop) and the
/// pre-tile lane-at-a-time scalar variant (the auto-vectorized baseline,
/// selected by [`BatchTiKernel::new_baseline`]).
macro_rules! bt_bin {
    ($tiled:ident, $scalar:ident, |$a:ident, $b:ident| $expr:expr) => {
        fn $tiled(v: &mut [u64], r: &OpRec, _e: &[u32], lanes: usize) {
            tile::bin_ip(
                v,
                r.a as usize * lanes,
                r.b as usize * lanes,
                r.out as usize * lanes,
                lanes,
                r.mask,
                |$a, $b| $expr,
            );
        }
        fn $scalar(v: &mut [u64], r: &OpRec, _e: &[u32], lanes: usize) {
            let ab = r.a as usize * lanes;
            let bb = r.b as usize * lanes;
            let ob = r.out as usize * lanes;
            for l in 0..lanes {
                let $a = v[ab + l];
                let $b = v[bb + l];
                v[ob + l] = ($expr) & r.mask;
            }
        }
    };
}
macro_rules! bt_un {
    ($tiled:ident, $scalar:ident, |$a:ident, $r:ident| $expr:expr) => {
        fn $tiled(v: &mut [u64], $r: &OpRec, _e: &[u32], lanes: usize) {
            let ab = $r.a as usize * lanes;
            let ob = $r.out as usize * lanes;
            tile::un_ip(v, ab, ob, lanes, $r.mask, |$a| $expr);
        }
        fn $scalar(v: &mut [u64], $r: &OpRec, _e: &[u32], lanes: usize) {
            let ab = $r.a as usize * lanes;
            let ob = $r.out as usize * lanes;
            for l in 0..lanes {
                let $a = v[ab + l];
                v[ob + l] = ($expr) & $r.mask;
            }
        }
    };
}

bt_bin!(bt_add, bts_add, |a, b| a.wrapping_add(b));
bt_bin!(bt_sub, bts_sub, |a, b| a.wrapping_sub(b));
bt_bin!(bt_mul, bts_mul, |a, b| a.wrapping_mul(b));
bt_bin!(bt_div, bts_div, |a, b| if b == 0 { 0 } else { a / b });
bt_bin!(bt_rem, bts_rem, |a, b| if b == 0 { 0 } else { a % b });
bt_bin!(bt_lt, bts_lt, |a, b| (a < b) as u64);
bt_bin!(bt_leq, bts_leq, |a, b| (a <= b) as u64);
bt_bin!(bt_gt, bts_gt, |a, b| (a > b) as u64);
bt_bin!(bt_geq, bts_geq, |a, b| (a >= b) as u64);
bt_bin!(bt_eq, bts_eq, |a, b| (a == b) as u64);
bt_bin!(bt_neq, bts_neq, |a, b| (a != b) as u64);
bt_bin!(bt_and, bts_and, |a, b| a & b);
bt_bin!(bt_or, bts_or, |a, b| a | b);
bt_bin!(bt_xor, bts_xor, |a, b| a ^ b);
bt_bin!(bt_dshl, bts_dshl, |a, b| if b >= 64 { 0 } else { a << b });
bt_bin!(bt_dshr, bts_dshr, |a, b| if b >= 64 { 0 } else { a >> b });
bt_un!(bt_not, bts_not, |a, _r| !a);
bt_un!(bt_neg, bts_neg, |a, _r| a.wrapping_neg());
bt_un!(bt_andr, bts_andr, |a, r| (a == r.aux) as u64);
bt_un!(bt_orr, bts_orr, |a, _r| (a != 0) as u64);
bt_un!(bt_xorr, bts_xorr, |a, _r| (a.count_ones() & 1) as u64);
bt_un!(bt_shli, bts_shli, |a, r| a << r.imm);
bt_un!(bt_shri, bts_shri, |a, r| a >> r.imm);
bt_un!(bt_copy, bts_copy, |a, _r| a);

fn bt_cat(v: &mut [u64], r: &OpRec, _e: &[u32], lanes: usize) {
    let imm = r.imm;
    tile::bin_ip(
        v,
        r.a as usize * lanes,
        r.b as usize * lanes,
        r.out as usize * lanes,
        lanes,
        r.mask,
        move |a, b| (a << imm) | b,
    );
}

fn bts_cat(v: &mut [u64], r: &OpRec, _e: &[u32], lanes: usize) {
    let ab = r.a as usize * lanes;
    let bb = r.b as usize * lanes;
    let ob = r.out as usize * lanes;
    for l in 0..lanes {
        v[ob + l] = ((v[ab + l] << r.imm) | v[bb + l]) & r.mask;
    }
}

fn bt_mux(v: &mut [u64], r: &OpRec, _e: &[u32], lanes: usize) {
    tile::mux_ip(
        v,
        r.a as usize * lanes,
        r.b as usize * lanes,
        r.c as usize * lanes,
        r.out as usize * lanes,
        lanes,
        r.mask,
    );
}

fn bts_mux(v: &mut [u64], r: &OpRec, _e: &[u32], lanes: usize) {
    let sb = r.a as usize * lanes;
    let tb = r.b as usize * lanes;
    let fb = r.c as usize * lanes;
    let ob = r.out as usize * lanes;
    for l in 0..lanes {
        let x = if v[sb + l] != 0 { v[tb + l] } else { v[fb + l] };
        v[ob + l] = x & r.mask;
    }
}

/// Lane-strided mirror of `tensor::ir::eval_rec`'s MuxChain case:
/// operands are `sel0 = a`, `v0 = b`, then `ext` holds
/// `(sel1, v1, .., default)` — first true selector wins.
fn bt_muxchain(v: &mut [u64], r: &OpRec, e: &[u32], lanes: usize) {
    let k = r.imm as usize;
    let ob = r.out as usize * lanes;
    let ext = &e[r.ext as usize..r.ext as usize + 2 * k - 1];
    for l in 0..lanes {
        let val = if v[r.a as usize * lanes + l] != 0 {
            v[r.b as usize * lanes + l]
        } else {
            let mut x = v[ext[2 * k - 2] as usize * lanes + l];
            for i in (0..k - 1).rev() {
                if v[ext[2 * i] as usize * lanes + l] != 0 {
                    x = v[ext[2 * i + 1] as usize * lanes + l];
                }
            }
            x
        };
        v[ob + l] = val & r.mask;
    }
}

fn bt_fn(op: KOp) -> BtFn {
    match op {
        KOp::Add => bt_add,
        KOp::Sub => bt_sub,
        KOp::Mul => bt_mul,
        KOp::Div => bt_div,
        KOp::Rem => bt_rem,
        KOp::Lt => bt_lt,
        KOp::Leq => bt_leq,
        KOp::Gt => bt_gt,
        KOp::Geq => bt_geq,
        KOp::Eq => bt_eq,
        KOp::Neq => bt_neq,
        KOp::And => bt_and,
        KOp::Or => bt_or,
        KOp::Xor => bt_xor,
        KOp::Not => bt_not,
        KOp::Neg => bt_neg,
        KOp::AndrK => bt_andr,
        KOp::Orr => bt_orr,
        KOp::Xorr => bt_xorr,
        KOp::ShlI => bt_shli,
        KOp::ShrI => bt_shri,
        KOp::Dshl => bt_dshl,
        KOp::Dshr => bt_dshr,
        KOp::Cat => bt_cat,
        KOp::Mux => bt_mux,
        KOp::Copy => bt_copy,
        KOp::MuxChain => bt_muxchain,
    }
}

/// Pre-tile lane-at-a-time tape functions; `MuxChain` shares the scalar
/// implementation with the tiled table (variable arity — no fixed tile shape).
fn bt_fn_scalar(op: KOp) -> BtFn {
    match op {
        KOp::Add => bts_add,
        KOp::Sub => bts_sub,
        KOp::Mul => bts_mul,
        KOp::Div => bts_div,
        KOp::Rem => bts_rem,
        KOp::Lt => bts_lt,
        KOp::Leq => bts_leq,
        KOp::Gt => bts_gt,
        KOp::Geq => bts_geq,
        KOp::Eq => bts_eq,
        KOp::Neq => bts_neq,
        KOp::And => bts_and,
        KOp::Or => bts_or,
        KOp::Xor => bts_xor,
        KOp::Not => bts_not,
        KOp::Neg => bts_neg,
        KOp::AndrK => bts_andr,
        KOp::Orr => bts_orr,
        KOp::Xorr => bts_xorr,
        KOp::ShlI => bts_shli,
        KOp::ShrI => bts_shri,
        KOp::Dshl => bts_dshl,
        KOp::Dshr => bts_dshr,
        KOp::Cat => bts_cat,
        KOp::Mux => bts_mux,
        KOp::Copy => bts_copy,
        KOp::MuxChain => bt_muxchain,
    }
}

/// Batched **TI**: tape of precompiled per-opcode functions with operand
/// slots baked into each record; each tape entry evaluates all lanes with
/// direct lane-major slot writes (no LO staging). Batching amortizes the
/// tape walk — the code-pointer and record fetches — across lanes, which
/// is exactly the frontend pressure the paper charges to the unrolled
/// kernels.
pub struct BatchTiKernel {
    d: BatchDriver,
    tape: Vec<(BtFn, OpRec)>,
    ext_args: Vec<u32>,
}

impl BatchTiKernel {
    pub fn new(ir: &LayerIr, oim: &Oim, lanes: usize) -> Self {
        Self::with_table(ir, oim, lanes, bt_fn)
    }

    /// The pre-tile (auto-vectorized baseline) variant: same tape, but each
    /// entry points at the lane-at-a-time scalar function.
    pub fn new_baseline(ir: &LayerIr, oim: &Oim, lanes: usize) -> Self {
        Self::with_table(ir, oim, lanes, bt_fn_scalar)
    }

    fn with_table(ir: &LayerIr, oim: &Oim, lanes: usize, table: fn(KOp) -> BtFn) -> Self {
        let (layers, ext_args) = oim.op_recs();
        let mut tape = Vec::with_capacity(ir.total_ops());
        for layer in &layers {
            for rec in layer {
                tape.push((table(rec.kop()), *rec));
            }
        }
        BatchTiKernel { d: BatchDriver::new(ir, lanes), tape, ext_args }
    }
}

impl BatchKernel for BatchTiKernel {
    fn config_name(&self) -> &'static str {
        "TI"
    }

    fn lanes(&self) -> usize {
        self.d.lanes
    }

    fn step(&mut self, inputs: &[u64]) {
        self.d.set_inputs(inputs);
        let lanes = self.d.lanes;
        let v = &mut self.d.v;
        for (f, rec) in &self.tape {
            f(v, rec, &self.ext_args, lanes);
        }
        self.d.commit();
    }

    fn slots(&self) -> &[u64] {
        &self.d.v
    }

    fn lane_outputs(&self, lane: usize) -> Vec<(String, u64)> {
        self.d.lane_outputs(lane)
    }

    fn write_lane_outputs(&self, lane: usize, buf: &mut Vec<(String, u64)>) {
        self.d.write_lane_outputs(lane, buf);
    }

    fn poke_lane(&mut self, slot: u32, lane: usize, value: u64) {
        self.d.poke_lane(slot, lane, value);
    }

    fn restore_slots(&mut self, slots: &[u64]) -> Result<(), String> {
        self.d.restore_slots(slots)
    }
}

#[cfg(test)]
mod tests {
    use super::super::{build_batch, build_with_oim, BatchKernel, SimKernel, BATCHED_KERNELS};
    use crate::graph::builder::{random_circuit, random_inputs};
    use crate::graph::passes::optimize;
    use crate::tensor::ir::lower;
    use crate::tensor::oim::Oim;
    use crate::util::prng::Rng;

    /// Quick in-module smoke test (the heavyweight differential property
    /// lives in `tests/kernels_property.rs`): a 4-lane batched run matches
    /// 4 scalar runs on a random circuit.
    #[test]
    fn batched_matches_scalar_lanes() {
        let mut rng = Rng::new(88_001);
        let g = random_circuit(&mut rng, 60);
        let (opt, _) = optimize(&g);
        let ir = lower(&opt);
        let oim = Oim::from_ir(&ir);
        let lanes = 4usize;
        for cfg in BATCHED_KERNELS {
            let mut batched = build_batch(cfg, &ir, &oim, lanes);
            let mut singles: Vec<_> =
                (0..lanes).map(|_| build_with_oim(cfg, &ir, &oim)).collect();
            for cycle in 0..6 {
                let per_lane: Vec<Vec<u64>> =
                    (0..lanes).map(|_| random_inputs(&mut rng, &opt)).collect();
                let mut flat = vec![0u64; opt.inputs.len() * lanes];
                for (l, inp) in per_lane.iter().enumerate() {
                    for (i, &val) in inp.iter().enumerate() {
                        flat[i * lanes + l] = val;
                    }
                }
                batched.step(&flat);
                for (l, s) in singles.iter_mut().enumerate() {
                    s.step(&per_lane[l]);
                    assert_eq!(
                        batched.lane_outputs(l),
                        s.outputs(),
                        "{} lane {l} cycle {cycle}",
                        cfg.name()
                    );
                }
            }
        }
    }

    /// Lane-major layout invariant: slot `s` of lane `l` lives at
    /// `s * lanes + l`, and all lanes start identical.
    #[test]
    fn lane_major_layout_and_initial_state() {
        let mut rng = Rng::new(88_002);
        let g = random_circuit(&mut rng, 30);
        let (opt, _) = optimize(&g);
        let ir = lower(&opt);
        let oim = Oim::from_ir(&ir);
        let lanes = 3usize;
        let k = build_batch(crate::kernels::KernelConfig::TI, &ir, &oim, lanes);
        assert_eq!(k.lanes(), lanes);
        assert_eq!(k.slots().len(), ir.num_slots * lanes);
        let init = ir.initial_slots();
        for (s, &val) in init.iter().enumerate() {
            for l in 0..lanes {
                assert_eq!(k.slots()[s * lanes + l], val, "slot {s} lane {l}");
            }
        }
    }
}
