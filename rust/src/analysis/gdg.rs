//! GDG soundness pass (codes GD01–GD08; catalog in [`super`]).
//!
//! Proves the property the sparse executors and targeted invalidation
//! assume: the [`GroupDepGraph`] is an *exact* index of the format-C op
//! stream. The pass recomputes the within-cycle writer map group by
//! group (in the graph's own topological order) and demands that every
//! operand produce its precise edge — reader-CSR membership (GD01), the
//! classified dependency edge (GD08), and writer agreement (GD05) — while
//! the set checks (GD07, GD06) bound the over-approximation from above.

use std::collections::HashSet;

use crate::activity::gdg::GroupDepGraph;
use crate::tensor::ir::{LayerIr, NUM_KOPS};
use crate::tensor::oim::Oim;

use super::Sink;

pub(crate) fn check(ir: &LayerIr, oim: &Oim, gdg: &GroupDepGraph, sink: &mut Sink) {
    let n_groups = gdg.groups.len();
    let ns = oim.num_slots as usize;

    // ---- GD04: groups tile the format-C arrays exactly ----
    let mut tiling_ok = true;
    if gdg.group_deps.len() != n_groups
        || gdg.input_deps.len() != n_groups
        || gdg.reg_deps.len() != n_groups
    {
        sink.error(
            "GD04",
            format!(
                "dependency lists disagree with group count ({}/{}/{} vs {n_groups})",
                gdg.group_deps.len(),
                gdg.input_deps.len(),
                gdg.reg_deps.len()
            ),
        );
        return; // indices below would be meaningless
    }
    let (mut expect_op, mut expect_r) = (0u32, 0u32);
    let mut prev_key: Option<(u32, u8)> = None;
    for (gi, grp) in gdg.groups.iter().enumerate() {
        if grp.op_start != expect_op {
            tiling_ok = false;
            sink.error(
                "GD04",
                format!("group {gi}: op range starts at {}, expected {expect_op}", grp.op_start),
            );
        }
        if grp.op_end <= grp.op_start {
            tiling_ok = false;
            sink.error("GD04", format!("group {gi}: empty or inverted op range"));
        }
        if grp.r_start != expect_r {
            tiling_ok = false;
            sink.error(
                "GD04",
                format!("group {gi}: operand range starts at {}, expected {expect_r}", grp.r_start),
            );
        }
        if let Some(pk) = prev_key {
            if (grp.layer, grp.opcode) <= pk {
                tiling_ok = false;
                sink.error(
                    "GD04",
                    format!(
                        "group {gi}: (layer {}, opcode {}) not above predecessor {pk:?}",
                        grp.layer, grp.opcode
                    ),
                );
            }
        }
        prev_key = Some((grp.layer, grp.opcode));
        let idx = grp.layer as usize * NUM_KOPS + grp.opcode as usize;
        match oim.n_payload.get(idx) {
            Some(&n) if n as usize == grp.ops() => {}
            got => {
                tiling_ok = false;
                sink.error(
                    "GD04",
                    format!(
                        "group {gi} (layer {}, opcode {}): {} ops but n_payload says {got:?}",
                        grp.layer,
                        grp.opcode,
                        grp.ops()
                    ),
                );
            }
        }
        let ops = oim.c.opcode.get(grp.op_start as usize..grp.op_end as usize).unwrap_or(&[]);
        if ops.len() != grp.ops() {
            tiling_ok = false;
            sink.error("GD04", format!("group {gi}: op range exceeds format-C arrays"));
        } else if ops.iter().any(|&o| o != grp.opcode) {
            tiling_ok = false;
            sink.error(
                "GD04",
                format!("group {gi}: format-C opcode disagrees with group opcode {}", grp.opcode),
            );
        }
        expect_op = grp.op_end;
        let arities = oim.c.arity.get(grp.op_start as usize..grp.op_end as usize).unwrap_or(&[]);
        expect_r = grp.r_start + arities.iter().map(|&a| a as u32).sum::<u32>();
    }
    if expect_op as usize != oim.total_ops() {
        tiling_ok = false;
        sink.error(
            "GD04",
            format!("groups cover {expect_op} format-C ops, OIM holds {}", oim.total_ops()),
        );
    }
    if gdg.total_ops != oim.total_ops() {
        tiling_ok = false;
        sink.error(
            "GD04",
            format!("gdg.total_ops {} != oim.total_ops() {}", gdg.total_ops, oim.total_ops()),
        );
    }

    // ---- GD02 / GD03: dependency list sanity (independent of tiling) ----
    let mut edges = 0usize;
    for (gi, deps) in gdg.group_deps.iter().enumerate() {
        edges += deps.len();
        for &d in deps {
            if d as usize >= n_groups {
                sink.error("GD02", format!("group {gi}: dep {d} >= group count {n_groups}"));
            } else if d as usize >= gi {
                sink.error("GD03", format!("group {gi}: dep {d} is not strictly upstream"));
            } else if gdg.groups[d as usize].layer >= gdg.groups[gi].layer {
                sink.error(
                    "GD03",
                    format!(
                        "group {gi} (layer {}): dep {d} lives in layer {} (not earlier)",
                        gdg.groups[gi].layer,
                        gdg.groups[d as usize].layer
                    ),
                );
            }
        }
    }
    for (gi, deps) in gdg.input_deps.iter().enumerate() {
        edges += deps.len();
        for &i in deps {
            if i as usize >= ir.input_slots.len() {
                sink.error(
                    "GD02",
                    format!("group {gi}: input dep {i} >= {} ports", ir.input_slots.len()),
                );
            }
        }
    }
    for (gi, deps) in gdg.reg_deps.iter().enumerate() {
        edges += deps.len();
        for &c in deps {
            if c as usize >= ir.commits.len() {
                sink.error(
                    "GD02",
                    format!("group {gi}: register dep {c} >= {} commits", ir.commits.len()),
                );
            }
        }
    }
    if edges != gdg.num_edges {
        sink.error("GD02", format!("num_edges {} but lists hold {edges}", gdg.num_edges));
    }

    if !tiling_ok {
        return; // operand-exactness checks key off the op ranges
    }

    // ---- operand walk: GD01, GD08, GD05, and the actual reader pairs ----
    const NONE: u32 = u32::MAX;
    let mut input_of = vec![NONE; ns];
    for (i, &s) in ir.input_slots.iter().enumerate() {
        if (s as usize) < ns {
            input_of[s as usize] = i as u32;
        }
    }
    let mut commit_of = vec![NONE; ns];
    for (ci, &(reg, _, _)) in ir.commits.iter().enumerate() {
        if (reg as usize) < ns {
            commit_of[reg as usize] = ci as u32;
        }
    }
    let mut writer = vec![NONE; ns];
    let mut actual_pairs: HashSet<(u32, u32)> = HashSet::new();
    let mut read_slots = vec![false; ns];
    let mut r_idx;
    for (gi, grp) in gdg.groups.iter().enumerate() {
        r_idx = grp.r_start as usize;
        for op in grp.op_start..grp.op_end {
            let ar = oim.c.arity.get(op as usize).map(|&a| a as usize).unwrap_or(0);
            let Some(operands) = oim.c.r_coords.get(r_idx..r_idx + ar) else {
                sink.error("GD04", format!("group {gi}: operand range exceeds r_coords"));
                return;
            };
            for &slot in operands {
                if slot as usize >= ns {
                    continue; // SP02 already reported the coordinate
                }
                read_slots[slot as usize] = true;
                actual_pairs.insert((slot, gi as u32));
                if gdg.readers_of(slot).binary_search(&(gi as u32)).is_err() {
                    sink.error(
                        "GD01",
                        format!(
                            "group {gi} reads slot {slot} but is missing from the slot→reader \
                             index (targeted invalidation would skip it)"
                        ),
                    );
                }
                let w = writer[slot as usize];
                if w != NONE {
                    if gdg.group_deps[gi].binary_search(&w).is_err() {
                        sink.error(
                            "GD08",
                            format!(
                                "group {gi} reads slot {slot} written by group {w}, but \
                                 group_deps has no such edge"
                            ),
                        );
                    }
                } else if input_of[slot as usize] != NONE {
                    if gdg.input_deps[gi].binary_search(&input_of[slot as usize]).is_err() {
                        sink.error(
                            "GD08",
                            format!(
                                "group {gi} reads input port {} (slot {slot}), but input_deps \
                                 has no such edge",
                                input_of[slot as usize]
                            ),
                        );
                    }
                } else if commit_of[slot as usize] != NONE
                    && gdg.reg_deps[gi].binary_search(&commit_of[slot as usize]).is_err()
                {
                    sink.error(
                        "GD08",
                        format!(
                            "group {gi} reads register commit {} (slot {slot}), but reg_deps \
                             has no such edge",
                            commit_of[slot as usize]
                        ),
                    );
                }
            }
            r_idx += ar;
        }
        for op in grp.op_start..grp.op_end {
            if let Some(&s) = oim.c.s_coords.get(op as usize) {
                if (s as usize) < ns {
                    writer[s as usize] = gi as u32;
                }
            }
        }
    }

    // ---- GD05: slot→writer map matches the recomputation ----
    let (_, _, slot_writer) = gdg.reader_csr();
    if slot_writer.len() == ns {
        for (s, (&got, &want)) in slot_writer.iter().zip(&writer).enumerate() {
            if got != want {
                sink.error(
                    "GD05",
                    format!("slot {s}: slot_writer says group {got}, recomputation says {want}"),
                );
            }
        }
    } // length mismatch is SP05's finding

    // ---- GD07: phantom readers (over-approximation is safe → warning) ----
    let (offsets, rows, _) = gdg.reader_csr();
    if offsets.len() == ns + 1 {
        for (s, w) in offsets.windows(2).enumerate() {
            let Some(row) = rows.get(w[0] as usize..w[1] as usize) else { continue };
            for &g in row {
                if !actual_pairs.contains(&(s as u32, g)) {
                    sink.warn(
                        "GD07",
                        format!(
                            "slot {s} lists group {g} as a reader, but no operand of that group \
                             touches the slot (harmless over-invalidation)"
                        ),
                    );
                }
            }
        }
    }

    // ---- GD06: dead groups ----
    let mut live = read_slots;
    for (_, s) in &ir.output_slots {
        if (*s as usize) < ns {
            live[*s as usize] = true;
        }
    }
    for &(_, next, _) in &ir.commits {
        if (next as usize) < ns {
            live[next as usize] = true;
        }
    }
    for (gi, grp) in gdg.groups.iter().enumerate() {
        let outs = oim.c.s_coords.get(grp.op_start as usize..grp.op_end as usize).unwrap_or(&[]);
        if !outs.is_empty() && outs.iter().all(|&s| (s as usize) < ns && !live[s as usize]) {
            sink.warn(
                "GD06",
                format!(
                    "group {gi} (layer {}, opcode {}): no output slot is read, committed, or a \
                     design output — the group is dead weight in every cycle",
                    grp.layer, grp.opcode
                ),
            );
        }
    }
}
