//! Splice / OIM structural audit (codes SP01–SP05; catalog in [`super`]).
//!
//! Proves that an [`Oim`] — whether built cold by `Oim::from_ir` or grown
//! by `Oim::splice` — is exactly the OIM the IR denotes, and that the
//! [`GroupDepGraph`]'s slot→reader CSR is structurally sound. Because
//! format B is defined as a field-for-field flattening of the IR layers
//! (SP03) and format C as the per-layer stable opcode sort of B (SP04),
//! a clean report here is equivalent to the splice oracle's bit-identity
//! claim, at a fraction of the cost of recompiling.

use crate::activity::gdg::GroupDepGraph;
use crate::tensor::ir::{KOp, LayerIr, NUM_KOPS};
use crate::tensor::oim::{Oim, OimArrays};

use super::Sink;

/// Per-layer (op offset, operand offset) cursors into an [`OimArrays`],
/// derived from `i_payload`. Returns `None` when the arity array itself
/// is too short to walk (reported by the caller as SP02).
fn layer_cursors(i_payload: &[u32], arrays: &OimArrays) -> Option<Vec<(usize, usize)>> {
    let mut cursors = Vec::with_capacity(i_payload.len());
    let (mut op, mut r) = (0usize, 0usize);
    for &n in i_payload {
        cursors.push((op, r));
        let end = op + n as usize;
        let seg = arrays.arity.get(op..end)?;
        r += seg.iter().map(|&a| a as usize).sum::<usize>();
        op = end;
    }
    Some(cursors)
}

/// Checks the internal consistency of one format's arrays: equal lengths,
/// coordinate/opcode/arity bounds, and r_coords sized by the arity sums.
fn check_arrays(fmt: &str, arrays: &OimArrays, num_slots: usize, sink: &mut Sink) -> bool {
    let n = arrays.s_coords.len();
    let lens = [
        arrays.arity.len(),
        arrays.opcode.len(),
        arrays.imm.len(),
        arrays.mask.len(),
        arrays.aux.len(),
    ];
    if lens.iter().any(|&l| l != n) {
        sink.error(
            "SP02",
            format!(
                "format {fmt}: parallel array lengths disagree (s_coords {n}, others {lens:?})"
            ),
        );
        return false;
    }
    let mut ok = true;
    for (i, &s) in arrays.s_coords.iter().enumerate() {
        if s as usize >= num_slots {
            let msg = format!("format {fmt} op {i}: out coord {s} >= num_slots {num_slots}");
            sink.error("SP02", msg);
            ok = false;
        }
    }
    for (i, &o) in arrays.opcode.iter().enumerate() {
        if o as usize >= NUM_KOPS {
            sink.error("SP02", format!("format {fmt} op {i}: opcode {o} out of range"));
            ok = false;
        }
    }
    let mut r_expect = 0usize;
    for (i, &a) in arrays.arity.iter().enumerate() {
        if a == 0 {
            sink.error("SP02", format!("format {fmt} op {i}: arity 0"));
            ok = false;
        }
        if arrays.opcode[i] as usize == KOp::MuxChain as usize && (a < 3 || a % 2 == 0) {
            sink.error(
                "SP02",
                format!("format {fmt} op {i}: muxchain arity {a} not an odd count >= 3"),
            );
            ok = false;
        }
        r_expect += a as usize;
    }
    if arrays.r_coords.len() != r_expect {
        sink.error(
            "SP02",
            format!(
                "format {fmt}: r_coords has {} entries but arities sum to {r_expect}",
                arrays.r_coords.len()
            ),
        );
        ok = false;
    }
    for (i, &s) in arrays.r_coords.iter().enumerate() {
        if s as usize >= num_slots {
            sink.error(
                "SP02",
                format!("format {fmt} operand {i}: coord {s} >= num_slots {num_slots}"),
            );
            ok = false;
        }
    }
    ok
}

pub(crate) fn check(ir: &LayerIr, oim: &Oim, gdg: &GroupDepGraph, sink: &mut Sink) {
    // ---- SP01: layer shape ----
    if oim.num_slots as usize != ir.num_slots {
        sink.error(
            "SP01",
            format!("oim.num_slots {} != ir.num_slots {}", oim.num_slots, ir.num_slots),
        );
    }
    if oim.i_payload.len() != ir.layers.len() {
        sink.error(
            "SP01",
            format!("i_payload has {} layers, IR has {}", oim.i_payload.len(), ir.layers.len()),
        );
        return; // every later comparison keys off the layer structure
    }
    for (li, (&n, layer)) in oim.i_payload.iter().zip(&ir.layers).enumerate() {
        if n as usize != layer.len() {
            sink.error(
                "SP01",
                format!("layer {li}: i_payload says {n} ops, IR has {}", layer.len()),
            );
        }
    }
    if oim.n_payload.len() != ir.layers.len() * NUM_KOPS {
        sink.error(
            "SP01",
            format!(
                "n_payload has {} entries, expected layers * NUM_KOPS = {}",
                oim.n_payload.len(),
                ir.layers.len() * NUM_KOPS
            ),
        );
    } else {
        for (li, &n) in oim.i_payload.iter().enumerate() {
            let sum: u32 = oim.n_payload[li * NUM_KOPS..(li + 1) * NUM_KOPS].iter().sum();
            if sum != n {
                sink.error(
                    "SP01",
                    format!("layer {li}: n_payload opcode counts sum to {sum}, i_payload says {n}"),
                );
            }
        }
    }

    // ---- SP02: array-level consistency of both formats ----
    let b_ok = check_arrays("B", &oim.b, oim.num_slots as usize, sink);
    let c_ok = check_arrays("C", &oim.c, oim.num_slots as usize, sink);

    // ---- SP03: format B is the IR layers, field for field ----
    if b_ok {
        match layer_cursors(&oim.i_payload, &oim.b) {
            Some(cursors) => {
                'layers: for (li, layer) in ir.layers.iter().enumerate() {
                    let (mut op, mut r) = cursors[li];
                    for (oi, rec) in layer.iter().enumerate() {
                        if op >= oim.b.s_coords.len() {
                            sink.error(
                                "SP03",
                                format!("layer {li}: format B ends before IR op {oi}"),
                            );
                            break 'layers;
                        }
                        let operands = match super::ir::safe_operands(rec, &ir.ext_args) {
                            Ok(v) => v,
                            Err(_) => continue, // already an IR06; comparison meaningless
                        };
                        let b_r = oim.b.r_coords.get(r..r + operands.len()).unwrap_or(&[]);
                        let same = oim.b.s_coords[op] == rec.out
                            && oim.b.opcode[op] == rec.op
                            && oim.b.arity[op] == rec.arity
                            && oim.b.imm[op] == rec.imm
                            && oim.b.mask[op] == rec.mask
                            && oim.b.aux[op] == rec.aux
                            && b_r == operands.as_slice();
                        if !same {
                            sink.error(
                                "SP03",
                                format!(
                                    "layer {li} op {oi}: format B disagrees with IR (B out {} op {} \
                                     vs IR out {} op {})",
                                    oim.b.s_coords[op], oim.b.opcode[op], rec.out, rec.op
                                ),
                            );
                        }
                        r += operands.len();
                        op += 1;
                    }
                }
            }
            None => sink.error("SP03", "format B arity array too short to walk layers".to_string()),
        }
    }

    // ---- SP04: format C is the per-layer stable opcode sort of B ----
    if b_ok && c_ok && oim.b.s_coords.len() == oim.c.s_coords.len() {
        let (b_cur, c_cur) = (
            layer_cursors(&oim.i_payload, &oim.b),
            layer_cursors(&oim.i_payload, &oim.c),
        );
        if let (Some(b_cur), Some(c_cur)) = (b_cur, c_cur) {
            for li in 0..ir.layers.len() {
                let n = oim.i_payload[li] as usize;
                let (b_op, b_r) = b_cur[li];
                let (c_op, mut c_r) = c_cur[li];
                if b_op + n > oim.b.s_coords.len() || c_op + n > oim.c.s_coords.len() {
                    break;
                }
                // Stable sort of B's in-layer op indices by opcode.
                let mut order: Vec<usize> = (b_op..b_op + n).collect();
                order.sort_by_key(|&i| oim.b.opcode[i]);
                // Operand offset of each B op within the layer.
                let mut b_off = vec![0usize; n];
                let mut acc = b_r;
                for (k, slot) in b_off.iter_mut().enumerate() {
                    *slot = acc;
                    acc += oim.b.arity[b_op + k] as usize;
                }
                let mut reported = false;
                for (k, &bi) in order.iter().enumerate() {
                    let ci = c_op + k;
                    let ar = oim.b.arity[bi] as usize;
                    let b_seg = oim.b.r_coords.get(b_off[bi - b_op]..b_off[bi - b_op] + ar);
                    let c_seg = oim.c.r_coords.get(c_r..c_r + oim.c.arity[ci] as usize);
                    let same = oim.c.s_coords[ci] == oim.b.s_coords[bi]
                        && oim.c.opcode[ci] == oim.b.opcode[bi]
                        && oim.c.arity[ci] == oim.b.arity[bi]
                        && oim.c.imm[ci] == oim.b.imm[bi]
                        && oim.c.mask[ci] == oim.b.mask[bi]
                        && oim.c.aux[ci] == oim.b.aux[bi]
                        && b_seg.is_some()
                        && b_seg == c_seg;
                    if !same && !reported {
                        reported = true;
                        sink.error(
                            "SP04",
                            format!(
                                "layer {li} position {k}: format C is not the stable opcode sort \
                                 of B (C out {} op {} vs expected out {} op {})",
                                oim.c.s_coords[ci],
                                oim.c.opcode[ci],
                                oim.b.s_coords[bi],
                                oim.b.opcode[bi]
                            ),
                        );
                    }
                    c_r += oim.c.arity[ci] as usize;
                }
            }
        }
    } else if b_ok && c_ok {
        sink.error(
            "SP04",
            format!(
                "formats B and C have different op counts ({} vs {})",
                oim.b.s_coords.len(),
                oim.c.s_coords.len()
            ),
        );
    }

    // ---- SP05: slot→reader CSR structure ----
    let (offsets, rows, slot_writer) = gdg.reader_csr();
    let ns = ir.num_slots;
    if offsets.len() != ns + 1 {
        sink.error(
            "SP05",
            format!("reader CSR has {} offsets for {ns} slots (want {})", offsets.len(), ns + 1),
        );
        return;
    }
    if offsets.first() != Some(&0) {
        sink.error("SP05", format!("reader CSR offsets start at {:?}, not 0", offsets.first()));
    }
    if offsets.last().copied().unwrap_or(0) as usize != rows.len() {
        sink.error(
            "SP05",
            format!(
                "reader CSR last offset {} != reader_groups len {}",
                offsets.last().copied().unwrap_or(0),
                rows.len()
            ),
        );
    }
    let mut monotone_ok = true;
    for (s, w) in offsets.windows(2).enumerate() {
        if w[1] < w[0] {
            monotone_ok = false;
            sink.error(
                "SP05",
                format!("reader CSR offsets non-monotone at slot {s}: {} -> {}", w[0], w[1]),
            );
        }
    }
    let n_groups = gdg.groups.len() as u32;
    if monotone_ok {
        for (s, w) in offsets.windows(2).enumerate() {
            let Some(row) = rows.get(w[0] as usize..w[1] as usize) else { continue };
            for pair in row.windows(2) {
                if pair[1] <= pair[0] {
                    sink.error(
                        "SP05",
                        format!(
                            "slot {s} reader row not strictly increasing: {} then {}",
                            pair[0], pair[1]
                        ),
                    );
                }
            }
            for &g in row {
                if g >= n_groups {
                    sink.error(
                        "SP05",
                        format!("slot {s} reader row references group {g} >= {n_groups}"),
                    );
                }
            }
        }
    }
    if slot_writer.len() != ns {
        sink.error(
            "SP05",
            format!("slot_writer has {} entries for {ns} slots", slot_writer.len()),
        );
    } else {
        for (s, &g) in slot_writer.iter().enumerate() {
            if g != u32::MAX && g >= n_groups {
                sink.error("SP05", format!("slot_writer[{s}] = {g} >= group count {n_groups}"));
            }
        }
    }
}
