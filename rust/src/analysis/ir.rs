//! IR well-formedness pass (codes IR01–IR09; catalog in [`super`]).
//!
//! Consumes only the [`LayerIr`]: schedule soundness (write-before-read,
//! single driver, format-B in-layer order), reference bounds, mask/width
//! agreement, an independent combinational-cycle check, and the width /
//! dead-op lints.

use crate::graph::ops::mask;
use crate::tensor::ir::{KOp, LayerIr, OpRec, NUM_KOPS};

use super::Sink;

/// Operand slots of a record, with every index defensively bounded (a
/// corrupted record must produce a diagnostic, not a panic). Returns
/// `Err` with a description when the record's opcode / arity / ext range
/// is itself out of bounds.
pub(crate) fn safe_operands(rec: &OpRec, ext_args: &[u32]) -> Result<Vec<u32>, String> {
    if rec.op as usize >= NUM_KOPS {
        return Err(format!("opcode {} out of range (NUM_KOPS = {NUM_KOPS})", rec.op));
    }
    let ar = rec.arity as usize;
    if ar == 0 {
        return Err("arity 0".to_string());
    }
    if rec.kop() == KOp::MuxChain {
        if ar < 3 || ar % 2 == 0 {
            return Err(format!("muxchain arity {ar} not an odd count >= 3"));
        }
        let (start, end) = (rec.ext as usize, rec.ext as usize + ar - 2);
        let Some(ext) = ext_args.get(start..end) else {
            return Err(format!(
                "muxchain ext range {start}..{end} exceeds ext_args ({})",
                ext_args.len()
            ));
        };
        let mut v = vec![rec.a, rec.b];
        v.extend_from_slice(ext);
        Ok(v)
    } else {
        if ar > 3 {
            return Err(format!("arity {ar} > 3 for non-muxchain op"));
        }
        Ok([rec.a, rec.b, rec.c][..ar].to_vec())
    }
}

/// Exact result width of a record given its operand widths, capped at 65
/// (the only question asked is "does it exceed the 64-bit word").
fn inferred_width(rec: &OpRec, ops: &[u32], width_of: impl Fn(u32) -> u32) -> u32 {
    let cap = |w: u32| w.min(65);
    let wa = ops.first().map(|&s| width_of(s)).unwrap_or(0);
    let wb = ops.get(1).map(|&s| width_of(s)).unwrap_or(0);
    match rec.kop() {
        KOp::Add | KOp::Sub => cap(wa.max(wb) + 1),
        KOp::Mul => cap(wa + wb),
        KOp::Div => wa,
        KOp::Rem => wa.min(wb),
        KOp::Lt
        | KOp::Leq
        | KOp::Gt
        | KOp::Geq
        | KOp::Eq
        | KOp::Neq
        | KOp::AndrK
        | KOp::Orr
        | KOp::Xorr => 1,
        KOp::And | KOp::Or | KOp::Xor => wa.max(wb),
        KOp::Not | KOp::Copy | KOp::Dshr => wa,
        KOp::Neg => cap(wa + 1),
        KOp::ShlI | KOp::Cat => cap(wa + rec.imm as u32),
        KOp::ShrI => wa.saturating_sub(rec.imm as u32),
        // a << b with b up to 2^wb - 1
        KOp::Dshl => {
            if wb >= 7 {
                65
            } else {
                cap(wa + (1u32 << wb) - 1)
            }
        }
        // widest selected value (selectors contribute nothing)
        KOp::Mux | KOp::MuxChain => {
            ops.iter().skip(1).map(|&s| width_of(s)).max().unwrap_or(0)
        }
    }
}

pub(crate) fn check(ir: &LayerIr, sink: &mut Sink) {
    let ns = ir.num_slots;
    let oob = |s: u32| s as usize >= ns;

    // ---- IR06: bounds of every slot reference outside the op stream ----
    for (i, &s) in ir.input_slots.iter().enumerate() {
        if oob(s) {
            sink.error("IR06", format!("input port {i} slot {s} >= num_slots {ns}"));
        }
    }
    for (name, s) in &ir.output_slots {
        if oob(*s) {
            sink.error("IR06", format!("output '{name}' slot {s} >= num_slots {ns}"));
        }
    }
    for (ci, &(reg, next, _)) in ir.commits.iter().enumerate() {
        if oob(reg) || oob(next) {
            sink.error("IR06", format!("commit {ci} ({reg} <- {next}) references slot >= {ns}"));
        }
    }
    for &(s, _) in &ir.init {
        if oob(s) {
            sink.error("IR06", format!("init entry slot {s} >= num_slots {ns}"));
        }
    }
    if ir.slot_widths.len() != ns {
        sink.error(
            "IR06",
            format!("slot_widths has {} entries for {ns} slots", ir.slot_widths.len()),
        );
    }
    let width_of = |s: u32| ir.slot_widths.get(s as usize).map(|&w| w as u32).unwrap_or(64);

    // ---- slot classification (boundary sources) ----
    let mut is_input = vec![false; ns];
    for &s in &ir.input_slots {
        if !oob(s) {
            is_input[s as usize] = true;
        }
    }
    let mut is_reg = vec![false; ns];
    for &(reg, _, _) in &ir.commits {
        if !oob(reg) {
            is_reg[reg as usize] = true;
        }
    }
    let mut is_init = vec![false; ns];
    for &(s, _) in &ir.init {
        if !oob(s) {
            is_init[s as usize] = true;
        }
    }

    // ---- walk 1: drivers, layer order, masks ----
    const NONE: u32 = u32::MAX;
    let mut writer_layer = vec![NONE; ns];
    for (li, layer) in ir.layers.iter().enumerate() {
        let mut prev_out: Option<u32> = None;
        let mut order_reported = false;
        for (oi, rec) in layer.iter().enumerate() {
            if rec.op as usize >= NUM_KOPS {
                sink.error("IR06", format!("layer {li} op {oi}: opcode {} out of range", rec.op));
                continue;
            }
            if oob(rec.out) {
                sink.error(
                    "IR06",
                    format!("layer {li} op {oi}: out slot {} >= num_slots {ns}", rec.out),
                );
                continue;
            }
            if let Some(p) = prev_out {
                if rec.out <= p && !order_reported {
                    order_reported = true;
                    sink.error(
                        "IR05",
                        format!(
                            "layer {li}: op {oi} out {} not strictly above predecessor {p} \
                             (format-B natural S order broken)",
                            rec.out
                        ),
                    );
                }
            }
            prev_out = Some(rec.out);
            if writer_layer[rec.out as usize] != NONE {
                sink.error(
                    "IR02",
                    format!(
                        "slot {} driven twice: layer {} and layer {li}",
                        rec.out, writer_layer[rec.out as usize]
                    ),
                );
            } else {
                writer_layer[rec.out as usize] = li as u32;
            }
            if is_input[rec.out as usize] || is_reg[rec.out as usize] {
                sink.error(
                    "IR02",
                    format!(
                        "layer {li} op {oi} drives slot {}, which is an input port or register",
                        rec.out
                    ),
                );
            }
            let declared = mask(width_of(rec.out).min(64) as u8);
            if rec.mask & !declared != 0 {
                sink.error(
                    "IR04",
                    format!(
                        "layer {li} op {oi} (slot {}): mask {:#x} admits bits above declared \
                         width {}",
                        rec.out,
                        rec.mask,
                        width_of(rec.out)
                    ),
                );
            }
        }
    }
    for (ci, &(reg, _, m)) in ir.commits.iter().enumerate() {
        if oob(reg) {
            continue;
        }
        let declared = mask(width_of(reg).min(64) as u8);
        if m & !declared != 0 {
            sink.error(
                "IR04",
                format!(
                    "commit {ci} (register slot {reg}): mask {m:#x} admits bits above declared \
                     width {}",
                    width_of(reg)
                ),
            );
        }
    }

    // ---- walk 2: operand discipline + width lints ----
    let mut read = vec![false; ns];
    for (name, s) in &ir.output_slots {
        let _ = name;
        if !oob(*s) {
            read[*s as usize] = true;
        }
    }
    for &(_, next, _) in &ir.commits {
        if !oob(next) {
            read[next as usize] = true;
        }
    }
    for (li, layer) in ir.layers.iter().enumerate() {
        for (oi, rec) in layer.iter().enumerate() {
            let ops = match safe_operands(rec, &ir.ext_args) {
                Ok(v) => v,
                Err(e) => {
                    sink.error("IR06", format!("layer {li} op {oi}: {e}"));
                    continue;
                }
            };
            for &r in &ops {
                if oob(r) {
                    sink.error(
                        "IR06",
                        format!("layer {li} op {oi}: operand slot {r} >= num_slots {ns}"),
                    );
                    continue;
                }
                read[r as usize] = true;
                let wl = writer_layer[r as usize];
                if wl != NONE {
                    if wl >= li as u32 {
                        sink.error(
                            "IR01",
                            format!(
                                "layer {li} op {oi} reads slot {r} written in layer {wl} \
                                 (write-before-read violated)"
                            ),
                        );
                    }
                } else if !(is_input[r as usize] || is_reg[r as usize] || is_init[r as usize]) {
                    sink.error(
                        "IR01",
                        format!(
                            "layer {li} op {oi} reads slot {r}, which is never written and \
                             never initialized"
                        ),
                    );
                }
            }
            let inf = inferred_width(rec, &ops, width_of);
            if inf > 64 {
                sink.warn(
                    "IR07",
                    format!(
                        "layer {li} op {oi} ({}): exact result exceeds 64 bits; value wraps in \
                         the u64 slot file",
                        rec.kop().mnemonic()
                    ),
                );
            }
        }
    }

    // ---- IR08: commit truncation lint ----
    for (ci, &(reg, next, m)) in ir.commits.iter().enumerate() {
        if oob(next) {
            continue;
        }
        if width_of(next) > m.count_ones() {
            sink.warn(
                "IR08",
                format!(
                    "commit {ci} (register slot {reg}): next-state slot {next} is {} bits wide \
                     but the commit mask keeps {}",
                    width_of(next),
                    m.count_ones()
                ),
            );
        }
    }

    // ---- IR09: dead ops ----
    for (li, layer) in ir.layers.iter().enumerate() {
        for (oi, rec) in layer.iter().enumerate() {
            if !oob(rec.out) && !read[rec.out as usize] {
                sink.warn(
                    "IR09",
                    format!(
                        "layer {li} op {oi}: slot {} is read by nothing, committed nowhere, and \
                         not a design output",
                        rec.out
                    ),
                );
            }
        }
    }

    // ---- IR03: combinational cycles, independent of the schedule ----
    // Kahn toposort over the op dependence graph derived purely from
    // operand/writer slots; the layer structure is deliberately ignored
    // so a corrupted schedule cannot mask a cycle.
    let flat: Vec<&OpRec> = ir.layers.iter().flatten().collect();
    let total = flat.len();
    let mut writer_op = vec![NONE; ns];
    for (id, rec) in flat.iter().enumerate() {
        if !oob(rec.out) && writer_op[rec.out as usize] == NONE {
            writer_op[rec.out as usize] = id as u32;
        }
    }
    let mut indeg = vec![0u32; total];
    let mut adj: Vec<Vec<u32>> = vec![Vec::new(); total];
    for (id, rec) in flat.iter().enumerate() {
        let Ok(ops) = safe_operands(rec, &ir.ext_args) else { continue };
        for &r in &ops {
            if oob(r) {
                continue;
            }
            let w = writer_op[r as usize];
            if w != NONE {
                adj[w as usize].push(id as u32);
                indeg[id] += 1;
            }
        }
    }
    let mut queue: Vec<u32> =
        indeg.iter().enumerate().filter(|&(_, &d)| d == 0).map(|(i, _)| i as u32).collect();
    let mut done = 0usize;
    while let Some(id) = queue.pop() {
        done += 1;
        for &dep in &adj[id as usize] {
            indeg[dep as usize] -= 1;
            if indeg[dep as usize] == 0 {
                queue.push(dep);
            }
        }
    }
    if done < total {
        sink.error(
            "IR03",
            format!("combinational cycle: {} of {total} ops unreachable by toposort", total - done),
        );
    }
}
