//! `rteaal check`: static verification of the compiled artifact bundle.
//!
//! The paper's core representation claim — simulation behavior lives in
//! tensor *data* (`LayerIr`/`Oim`/`GroupDepGraph`), not generated code —
//! means the invariants the runtime depends on are checkable on the
//! artifacts themselves, before a single cycle runs. This module is that
//! checker: four pass families over the bundle, each emitting diagnostics
//! with **stable codes** (never renumbered; retired codes are not
//! reused). An `Error` means a runtime that consumes the artifact can
//! produce wrong values or panic; a `Warning` is a lint — the artifact is
//! sound but suspicious (dead logic, wrap/truncation hazards,
//! over-approximated activity indices that cost work but never
//! correctness).
//!
//! Entry points: [`verify_artifacts`] (the full bundle; the partition
//! audit runs only when a [`Partitioning`] is supplied) — called by the
//! `rteaal check` CLI verb and, opt-in via `--verify` / `"verify":true`
//! (always-on under `debug_assertions`), from
//! `DesignCache::open_design{,_incremental}`.
//!
//! # Diagnostic catalog
//!
//! **IR well-formedness** ([`ir`]) — over [`LayerIr`] alone:
//!
//! | code | severity | invariant |
//! |------|----------|-----------|
//! | IR01 | error | write-before-read: every operand slot is an input, a register, an initialized constant, or written in a strictly earlier layer |
//! | IR02 | error | single driver: no slot is written by two ops, or by an op and a port/commit |
//! | IR03 | error | no combinational cycles (Kahn toposort over the op dependence graph, independent of the layer schedule) |
//! | IR04 | error | result/commit masks never admit bits above the declared slot width |
//! | IR05 | error | within a layer, ops are strictly ascending by `out` (the format-B natural S order the OIM lowering assumes) |
//! | IR06 | error | every slot / opcode / `ext_args` reference is in range |
//! | IR07 | warn  | width-overflow lint: an op whose exact result exceeds 64 bits wraps in the u64 slot file |
//! | IR08 | warn  | commit-truncation lint: a commit mask narrower than its next-state slot's declared width drops bits |
//! | IR09 | warn  | dead-op lint: an op output read by nothing, committed nowhere, and not a design output |
//!
//! Exactness: IR01/IR02/IR05/IR06 are literal scans of the schedule;
//! IR03 re-derives reachability without trusting layers, so a corrupted
//! schedule cannot mask a cycle. IR04 is exact because kernels apply
//! `rec.mask` verbatim ([`crate::tensor::ir::eval_rec`]). IR07–IR09 are
//! conservative lints: they may fire on intentional RTL idioms (wrapping
//! counters, rotate-by-shift), never on artifacts the runtime would
//! misexecute — hence warnings.
//!
//! **GDG soundness** ([`gdg`]) — the properties sparse targeted
//! invalidation assumes ([`crate::activity`]):
//!
//! | code | severity | invariant |
//! |------|----------|-----------|
//! | GD01 | error | every operand slot of every group appears in the slot→reader CSR (`readers_of`) — the exact property `note_slot_changed` relies on |
//! | GD02 | error | no dangling refs: dependency lists index real groups / input ports / commits |
//! | GD03 | error | dependencies are topological: strictly earlier group, strictly earlier layer |
//! | GD04 | error | groups tile the format-C op/operand arrays exactly, in (layer, opcode) order, matching `n_payload` |
//! | GD05 | error | the slot→writer map equals the last-writer relation of the format-C walk |
//! | GD06 | warn  | dead-group lint: a group none of whose outputs is read, committed, or a design output |
//! | GD07 | warn  | phantom-reader lint: a CSR entry for a slot the group never reads (wasted wakeups, never wrong values) |
//! | GD08 | error | every classified operand yields its dependency edge (group/input/register) in the per-group lists |
//!
//! Exactness: GD01/GD05/GD08 recompute the classification of
//! [`GroupDepGraph::build`] from the format-C arrays and compare — a
//! single dropped edge (which would make the sparse executors skip live
//! work) is reported with its (group, slot) witness. GD07 is the safe
//! direction (over-approximation) and therefore a lint.
//!
//! **Partition audit** ([`partition`]) — over a [`Partitioning`]:
//!
//! | code | severity | invariant |
//! |------|----------|-----------|
//! | PT01 | error | `owner_of_reg` is total and in range |
//! | PT02 | error | register ownership is a disjoint cover: every commit in exactly one partition, agreeing with `owner_of_reg` |
//! | PT03 | error | every cross-partition register read appears in the RUM exchange set (`tracked` readers / `rum_readers`) |
//! | PT04 | error | never-written (ROM) registers stay out of the tracking table |
//! | PT05 | error | the boundary reader map (`readers_of_slot`) agrees with the tracking table |
//! | PT06 | error | partition 0 owns the design outputs; others export none |
//! | PT07 | warn  | phantom-RUM-reader lint: a tracked reader partition that never reads the register |
//! |
//!
//! Exactness of PT03: a partition reads register `r` iff `r` is an
//! operand of a kept op, a commit next-state slot, or (partition 0) an
//! output slot — register slots have no within-cycle writer, so this
//! equals the cone-boundary source set `partition_ir` derives readers
//! from. Both directions are compared; the unsafe one (missing reader)
//! is the error.
//!
//! **Splice audit** ([`splice`]) — structural proof for incrementally
//! spliced `Oim`/`GroupDepGraph` (cheap replacement for the
//! splice-oracle differential test, also valid on cold artifacts):
//!
//! | code | severity | invariant |
//! |------|----------|-----------|
//! | SP01 | error | OIM layer shape: `i_payload`/`n_payload` lengths and sums match the IR's layers |
//! | SP02 | error | coordinate/arity/opcode bounds: every S/R coordinate < `num_slots`, operand totals match arities |
//! | SP03 | error | format B equals the (grafted) IR's layers field-for-field, operand-for-operand |
//! | SP04 | error | format C is exactly the stable opcode sort of format B, layer by layer, agreeing with `n_payload` |
//! | SP05 | error | the reader CSR is structurally sound: monotone offsets covering `num_slots`, sorted/deduplicated rows, in-range entries |
//!
//! Exactness: `Oim::splice` promises bit-identity with `Oim::from_ir(ir)`;
//! SP03+SP04 verify precisely that (B is `from_ir`'s natural order, C its
//! stable sort), so a splice that copied a stale row or mis-sliced an
//! operand segment cannot pass. SP05 proves the spliced CSR is a valid
//! index regardless of provenance.

// This module takes none of the crate-wide clippy allowances (see the CI
// lint job): the verifier is new code with no index-loop heritage, so it
// holds itself to the unrelaxed lint set.
#![deny(
    clippy::needless_range_loop,
    clippy::manual_memcpy,
    clippy::too_many_arguments,
    clippy::inherent_to_string,
    clippy::type_complexity,
    clippy::new_without_default
)]

use std::collections::HashMap;

use crate::activity::GroupDepGraph;
use crate::partition::Partitioning;
use crate::tensor::ir::LayerIr;
use crate::tensor::oim::Oim;
use crate::util::json::{obj, Json};

pub mod gdg;
pub mod ir;
pub mod partition;
pub mod splice;

/// Diagnostic severity. `Error` = the runtime can misexecute the
/// artifact; `Warning` = lint (sound but suspicious).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

impl Severity {
    pub fn name(self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// One finding: a stable code, a severity, and a witness message naming
/// the concrete slot/op/group/partition that violates the invariant.
#[derive(Clone, Debug)]
pub struct Diag {
    pub code: &'static str,
    pub severity: Severity,
    pub message: String,
}

impl std::fmt::Display for Diag {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} {}: {}", self.severity.name(), self.code, self.message)
    }
}

/// Every diagnostic code with its severity (the seeded-fault test corpus
/// asserts one mutator per entry).
pub const ALL_CODES: &[(&str, Severity)] = &[
    ("IR01", Severity::Error),
    ("IR02", Severity::Error),
    ("IR03", Severity::Error),
    ("IR04", Severity::Error),
    ("IR05", Severity::Error),
    ("IR06", Severity::Error),
    ("IR07", Severity::Warning),
    ("IR08", Severity::Warning),
    ("IR09", Severity::Warning),
    ("GD01", Severity::Error),
    ("GD02", Severity::Error),
    ("GD03", Severity::Error),
    ("GD04", Severity::Error),
    ("GD05", Severity::Error),
    ("GD06", Severity::Warning),
    ("GD07", Severity::Warning),
    ("GD08", Severity::Error),
    ("PT01", Severity::Error),
    ("PT02", Severity::Error),
    ("PT03", Severity::Error),
    ("PT04", Severity::Error),
    ("PT05", Severity::Error),
    ("PT06", Severity::Error),
    ("PT07", Severity::Warning),
    ("SP01", Severity::Error),
    ("SP02", Severity::Error),
    ("SP03", Severity::Error),
    ("SP04", Severity::Error),
    ("SP05", Severity::Error),
];

/// Per-code cap on *stored* diagnostics: a badly corrupted artifact
/// trips the same invariant thousands of times; the report keeps the
/// first few witnesses per code and counts the rest in `suppressed`.
const PER_CODE_CAP: usize = 16;

/// Collecting sink the passes emit into.
#[derive(Default)]
pub(crate) struct Sink {
    diags: Vec<Diag>,
    per_code: HashMap<&'static str, usize>,
    suppressed: usize,
}

impl Sink {
    pub(crate) fn new() -> Self {
        Sink { diags: Vec::new(), per_code: HashMap::new(), suppressed: 0 }
    }

    fn emit(&mut self, code: &'static str, severity: Severity, message: String) {
        debug_assert!(
            ALL_CODES.iter().any(|&(c, s)| c == code && s == severity),
            "unregistered diagnostic {code}/{}",
            severity.name()
        );
        let n = self.per_code.entry(code).or_insert(0);
        *n += 1;
        if *n > PER_CODE_CAP {
            self.suppressed += 1;
        } else {
            self.diags.push(Diag { code, severity, message });
        }
    }

    pub(crate) fn error(&mut self, code: &'static str, message: String) {
        self.emit(code, Severity::Error, message);
    }

    pub(crate) fn warn(&mut self, code: &'static str, message: String) {
        self.emit(code, Severity::Warning, message);
    }

    fn into_report(self, design: &str) -> Report {
        let errors = self
            .per_code
            .iter()
            .filter(|(c, _)| matches!(lookup(c), Some(Severity::Error)))
            .map(|(_, n)| n)
            .sum();
        let warnings = self
            .per_code
            .iter()
            .filter(|(c, _)| matches!(lookup(c), Some(Severity::Warning)))
            .map(|(_, n)| n)
            .sum();
        Report {
            design: design.to_string(),
            diags: self.diags,
            errors,
            warnings,
            suppressed: self.suppressed,
        }
    }
}

fn lookup(code: &str) -> Option<Severity> {
    ALL_CODES.iter().find(|&&(c, _)| c == code).map(|&(_, s)| s)
}

/// The result of a verification run. `errors`/`warnings` count every
/// occurrence (including ones suppressed past the per-code cap);
/// `diags` holds the stored witnesses.
#[derive(Clone, Debug)]
pub struct Report {
    pub design: String,
    pub diags: Vec<Diag>,
    pub errors: usize,
    pub warnings: usize,
    pub suppressed: usize,
}

impl Report {
    /// Zero errors (warnings allowed).
    pub fn is_clean(&self) -> bool {
        self.errors == 0
    }

    /// Did any diagnostic with this code fire?
    pub fn has(&self, code: &str) -> bool {
        self.diags.iter().any(|d| d.code == code)
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{}: {} error(s), {} warning(s){}",
            self.design,
            self.errors,
            self.warnings,
            if self.suppressed > 0 {
                format!(" ({} suppressed past per-code cap)", self.suppressed)
            } else {
                String::new()
            }
        )
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("design", Json::Str(self.design.clone())),
            ("errors", Json::Int(self.errors as i64)),
            ("warnings", Json::Int(self.warnings as i64)),
            ("suppressed", Json::Int(self.suppressed as i64)),
            (
                "diags",
                Json::Arr(
                    self.diags
                        .iter()
                        .map(|d| {
                            obj(vec![
                                ("code", Json::Str(d.code.to_string())),
                                ("severity", Json::Str(d.severity.name().to_string())),
                                ("message", Json::Str(d.message.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Verify a compiled artifact bundle. Runs the IR, splice and GDG pass
/// families always, and the partition audit when `parting` is supplied
/// (the design-cache hook passes `None` — partitionings are replayed
/// per-open, so the cache verifies the shared artifacts and `rteaal
/// check` / session open verify the partitioned view).
pub fn verify_artifacts(
    design: &str,
    layer_ir: &LayerIr,
    oim: &Oim,
    dep_graph: &GroupDepGraph,
    parting: Option<&Partitioning>,
) -> Report {
    let mut sink = Sink::new();
    ir::check(layer_ir, &mut sink);
    splice::check(layer_ir, oim, dep_graph, &mut sink);
    gdg::check(layer_ir, oim, dep_graph, &mut sink);
    if let Some(p) = parting {
        partition::check(layer_ir, p, &mut sink);
    }
    sink.into_report(design)
}
