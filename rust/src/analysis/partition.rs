//! Partition audit pass (codes PT01–PT07; catalog in [`super`]).
//!
//! Recomputes, from the filtered per-partition IRs alone, exactly which
//! boundary register slots each partition reads, and demands that the
//! RUM tracking table cover every cross-partition read (PT03) — the
//! property that makes the bulk-synchronous exchange sound. The
//! recomputation is exact because a register slot has no within-cycle
//! writer: a partition reads it iff it appears as an operand (or seed)
//! of the partition's cone with no cone-local producer.

use std::collections::{HashMap, HashSet};

use crate::partition::{never_written, Partitioning};
use crate::tensor::ir::LayerIr;

use super::Sink;

/// Boundary source slots of one per-partition IR: slots consumed by its
/// ops / commits / outputs that no op of the same IR produces.
fn source_slots(pir: &LayerIr, ns: usize) -> HashSet<u32> {
    let mut written = vec![false; ns];
    for rec in pir.layers.iter().flatten() {
        if (rec.out as usize) < ns {
            written[rec.out as usize] = true;
        }
    }
    let mut sources = HashSet::new();
    let mut note = |s: u32| {
        if (s as usize) < ns && !written[s as usize] {
            sources.insert(s);
        }
    };
    for rec in pir.layers.iter().flatten() {
        if let Ok(ops) = super::ir::safe_operands(rec, &pir.ext_args) {
            for s in ops {
                note(s);
            }
        }
    }
    for &(_, next, _) in &pir.commits {
        note(next);
    }
    for (_, s) in &pir.output_slots {
        note(*s);
    }
    sources
}

pub(crate) fn check(ir: &LayerIr, parting: &Partitioning, sink: &mut Sink) {
    let n = parting.num_partitions();
    let ns = ir.num_slots;
    let n_regs = ir.commits.len();

    // ---- PT01: ownership vector shape ----
    if parting.owner_of_reg.len() != n_regs {
        sink.error(
            "PT01",
            format!(
                "owner_of_reg has {} entries for {n_regs} commits",
                parting.owner_of_reg.len()
            ),
        );
        return; // the cover check below indexes by commit
    }
    for (ri, &p) in parting.owner_of_reg.iter().enumerate() {
        if p >= n {
            sink.error("PT01", format!("register {ri}: owner {p} >= partition count {n}"));
        }
    }

    // ---- PT02: per-partition commits form a disjoint cover ----
    let ri_of_reg: HashMap<u32, usize> =
        ir.commits.iter().enumerate().map(|(ri, &(reg, _, _))| (reg, ri)).collect();
    let mut seen = vec![false; n_regs];
    for (p, pir) in parting.part_irs.iter().enumerate() {
        for &(reg, _, _) in &pir.commits {
            let Some(&ri) = ri_of_reg.get(&reg) else {
                sink.error(
                    "PT02",
                    format!("partition {p} commits register slot {reg}, unknown to the full IR"),
                );
                continue;
            };
            if seen[ri] {
                sink.error(
                    "PT02",
                    format!("register {ri} (slot {reg}) committed by more than one partition"),
                );
            }
            seen[ri] = true;
            if parting.owner_of_reg[ri] != p {
                sink.error(
                    "PT02",
                    format!(
                        "register {ri} (slot {reg}) committed by partition {p} but owned by {}",
                        parting.owner_of_reg[ri]
                    ),
                );
            }
        }
    }
    for (ri, s) in seen.iter().enumerate() {
        if !s {
            sink.error(
                "PT02",
                format!(
                    "register {ri} (slot {}) committed by no partition — state would freeze",
                    ir.commits[ri].0
                ),
            );
        }
    }

    // ---- PT06: partition 0 owns the design outputs, others own none ----
    if let Some(p0) = parting.part_irs.first() {
        if p0.output_slots != ir.output_slots {
            sink.error(
                "PT06",
                format!(
                    "partition 0 carries {} output slots, full IR has {}",
                    p0.output_slots.len(),
                    ir.output_slots.len()
                ),
            );
        }
    }
    for (p, pir) in parting.part_irs.iter().enumerate().skip(1) {
        if !pir.output_slots.is_empty() {
            sink.error(
                "PT06",
                format!(
                    "partition {p} carries {} output slots (only 0 may)",
                    pir.output_slots.len()
                ),
            );
        }
    }

    // ---- recompute boundary reads per partition ----
    let never = never_written(ir);
    let sources: Vec<HashSet<u32>> =
        parting.part_irs.iter().map(|pir| source_slots(pir, ns)).collect();
    let tracked_of_slot: HashMap<u32, &crate::partition::TrackedReg> =
        parting.tracked.iter().map(|t| (t.reg_slot, t)).collect();

    // ---- PT04: ROM never enters the tracking table ----
    for t in &parting.tracked {
        if let Some(&ri) = ri_of_reg.get(&t.reg_slot) {
            if never[ri] {
                sink.error(
                    "PT04",
                    format!(
                        "register {ri} (slot {}) is never written (pure ROM) but is RUM-tracked",
                        t.reg_slot
                    ),
                );
            }
        } else {
            sink.error(
                "PT04",
                format!("tracked slot {} is not a register of the full IR", t.reg_slot),
            );
        }
        if t.owner >= n {
            sink.error(
                "PT01",
                format!("tracked slot {}: owner {} >= partition count {n}", t.reg_slot, t.owner),
            );
        }
    }

    // ---- PT03: every cross-partition register read is RUM-covered ----
    for (p, srcs) in sources.iter().enumerate() {
        for &slot in srcs {
            let Some(&ri) = ri_of_reg.get(&slot) else { continue }; // input/constant slot
            if never[ri] {
                continue; // ROM: value can never change, correctly untracked
            }
            let Some(t) = tracked_of_slot.get(&slot) else {
                sink.error(
                    "PT03",
                    format!(
                        "partition {p} reads register slot {slot} (register {ri}), which is \
                         absent from the RUM tracking table"
                    ),
                );
                continue;
            };
            if t.readers.binary_search(&(p as u32)).is_err() {
                sink.error(
                    "PT03",
                    format!(
                        "partition {p} reads register slot {slot} but is missing from its \
                         reader list"
                    ),
                );
            }
            if p != t.owner && t.rum_readers.binary_search(&(p as u32)).is_err() {
                sink.error(
                    "PT03",
                    format!(
                        "partition {p} reads register slot {slot} owned by partition {}, but \
                         the RUM exchange set omits it — the read would see a stale value",
                        t.owner
                    ),
                );
            }
        }
    }
    // rum_readers must be exactly readers minus the owner
    for t in &parting.tracked {
        let want: Vec<u32> =
            t.readers.iter().copied().filter(|&p| p as usize != t.owner).collect();
        if t.rum_readers != want {
            sink.error(
                "PT03",
                format!(
                    "tracked slot {}: rum_readers {:?} != readers-minus-owner {:?}",
                    t.reg_slot, t.rum_readers, want
                ),
            );
        }
    }

    // ---- PT07: phantom RUM readers (over-approximation is safe) ----
    for t in &parting.tracked {
        for &p in &t.readers {
            if (p as usize) < n && !sources[p as usize].contains(&t.reg_slot) {
                sink.warn(
                    "PT07",
                    format!(
                        "tracked slot {}: partition {p} is listed as a reader but its cone \
                         never reads the slot (harmless extra propagation)",
                        t.reg_slot
                    ),
                );
            }
        }
    }

    // ---- PT05: the targeted-wake slot map agrees with the cones ----
    for (&slot, readers) in &parting.readers_of_slot {
        let want: Vec<u32> = (0..n)
            .filter(|&p| sources[p].contains(&slot))
            .map(|p| p as u32)
            .collect();
        if *readers != want {
            sink.error(
                "PT05",
                format!(
                    "readers_of_slot[{slot}] = {readers:?}, but the cones read it from {want:?}"
                ),
            );
        }
    }
    for (p, srcs) in sources.iter().enumerate() {
        for &slot in srcs {
            if !parting.readers_of_slot.contains_key(&slot) {
                sink.error(
                    "PT05",
                    format!(
                        "partition {p} reads boundary slot {slot}, absent from readers_of_slot \
                         (targeted poke wake would miss it)"
                    ),
                );
            }
        }
    }
}
