//! User-defined EDGE operators for the RTeAAL cascade (paper Alg. 2):
//! `op_u[n]` (unary map compute), `op_r[n]` (reduce compute) and
//! `op_s[n]` (select populate), indexed by the operation-type coordinate
//! `n`. Each `n` is an [`OpDesc`]: an executor opcode plus its static
//! parameters (the paper's toy op set has no parameters; FIRRTL's
//! `bits`/`shl`/`cat` do, and they are part of the operation type).

use crate::tensor::ir::KOp;

/// Operation descriptor — the coordinate space of rank N.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct OpDesc {
    pub op: KOp,
    pub imm: u8,
    pub mask: u64,
    pub aux: u64,
}

impl OpDesc {
    /// Is this a select operation (handled by `op_s`, Einsum 13)?
    pub fn is_select(&self) -> bool {
        matches!(self.op, KOp::Mux | KOp::MuxChain)
    }

    /// `op_u[n]` — map compute operator (applies to single-operand ops;
    /// pass-through for multi-operand ops, per §4.1).
    pub fn op_u(&self, a: u64) -> u64 {
        match self.op {
            KOp::Not => !a,
            KOp::Neg => a.wrapping_neg(),
            KOp::AndrK => (a == self.aux) as u64,
            KOp::Orr => (a != 0) as u64,
            KOp::Xorr => (a.count_ones() & 1) as u64,
            KOp::ShlI => a << self.imm,
            KOp::ShrI => a >> self.imm,
            KOp::Copy => a,
            _ => a, // pass-through (1) for reducible ops
        }
    }

    /// `op_r[n]` — reduce compute operator. `left` is the current reduce
    /// temporary, `right` the incoming map temporary; the O rank fixes the
    /// traversal order, making non-commutative reductions well-defined
    /// (§4.1).
    pub fn op_r(&self, left: u64, right: u64) -> u64 {
        match self.op {
            KOp::Add => left.wrapping_add(right),
            KOp::Sub => left.wrapping_sub(right),
            KOp::Mul => left.wrapping_mul(right),
            KOp::Div => {
                if right == 0 {
                    0
                } else {
                    left / right
                }
            }
            KOp::Rem => {
                if right == 0 {
                    0
                } else {
                    left % right
                }
            }
            KOp::Lt => (left < right) as u64,
            KOp::Leq => (left <= right) as u64,
            KOp::Gt => (left > right) as u64,
            KOp::Geq => (left >= right) as u64,
            KOp::Eq => (left == right) as u64,
            KOp::Neq => (left != right) as u64,
            KOp::And => left & right,
            KOp::Or => left | right,
            KOp::Xor => left ^ right,
            KOp::Dshl => {
                if right >= 64 {
                    0
                } else {
                    left << right
                }
            }
            KOp::Dshr => {
                if right >= 64 {
                    0
                } else {
                    left >> right
                }
            }
            KOp::Cat => (left << self.imm) | right,
            // unary ops never reduce (occupancy-1 O fiber): copy-through
            _ => right,
        }
    }

    /// `op_s[n]` — populate coordinate operator for select operations:
    /// consumes the whole ordered O-fiber of reduce temporaries (§4.1,
    /// Appendix A: "effectively implements a multiplexer").
    pub fn op_s(&self, ordered: &[u64]) -> u64 {
        match self.op {
            KOp::Mux => {
                if ordered[0] != 0 {
                    ordered[1]
                } else {
                    ordered[2]
                }
            }
            KOp::MuxChain => {
                let k = self.imm as usize;
                let mut v = ordered[2 * k]; // default
                for i in (0..k).rev() {
                    if ordered[2 * i] != 0 {
                        v = ordered[2 * i + 1];
                    }
                }
                v
            }
            _ => panic!("op_s on non-select operation {:?}", self.op),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn d(op: KOp) -> OpDesc {
        OpDesc { op, imm: 0, mask: u64::MAX, aux: 0 }
    }

    #[test]
    fn reduce_order_matters_for_sub() {
        let sub = d(KOp::Sub);
        let t = sub.op_u(10); // pass-through
        assert_eq!(sub.op_r(t, 3), 7);
        // reversed order gives a different (wrong) answer — the O rank
        // constraint exists precisely for this
        assert_ne!(sub.op_r(3, 10), 7);
    }

    #[test]
    fn unary_via_op_u() {
        assert_eq!(d(KOp::Not).op_u(0), u64::MAX);
        let andr = OpDesc { op: KOp::AndrK, imm: 0, mask: 1, aux: 0xF };
        assert_eq!(andr.op_u(0xF), 1);
        assert_eq!(andr.op_u(0x7), 0);
    }

    #[test]
    fn select_consumes_whole_fiber() {
        let mux = d(KOp::Mux);
        assert_eq!(mux.op_s(&[1, 42, 7]), 42);
        assert_eq!(mux.op_s(&[0, 42, 7]), 7);
        let chain = OpDesc { op: KOp::MuxChain, imm: 2, mask: u64::MAX, aux: 0 };
        assert_eq!(chain.op_s(&[0, 1, 1, 2, 9]), 2);
        assert_eq!(chain.op_s(&[0, 1, 0, 2, 9]), 9);
        assert_eq!(chain.op_s(&[1, 1, 1, 2, 9]), 1);
    }
}
