//! Executable extended-Einsum cascade (paper §4, Cascade 1).
//!
//! This module is the *formal specification* of RTeAAL Sim's computation:
//! the cascade
//!
//! ```text
//! OI_{i,n,o,r,s}   = LI_{i,r} · OIM_{i,n,o,r,s}      :: ∧ ←(→)
//! LO_{i,n,s}       = OI_{i,n,o,r,s}                  :: ∧ op_u[n](←) ∨ op_r[n](→)
//! LO_sel_{i,n,o*,r,s} = OI_{i,n,o,r,s}               :: ∧ 1(←) ⋘ 1(op_s[n])
//! LI_{i+1,s}       = LO / LO_sel                     :: ∧ 1(←) ∨ ANY(→)   ◇ i ≡ I
//! ```
//!
//! evaluated literally over fibertrees, with the user-defined operators
//! `op_u[n]` (map compute), `op_r[n]` (reduce compute, O-rank order
//! sensitive for non-commutative ops) and `op_s[n]` (populate coordinate
//! operator for select operations). It runs orders of magnitude slower
//! than the kernels in `crate::kernels` — it exists as the oracle the
//! kernels are property-tested against, mirroring how the paper derives
//! the kernels from the cascade.

pub mod cascade;
pub mod operators;

pub use cascade::{CascadeSim, OimTensor};
pub use operators::OpDesc;
