//! Cascade 1 evaluated over fibertrees (the executable specification).

use std::collections::{BTreeMap, HashMap};

use super::operators::OpDesc;
use crate::graph::ops::mask;
use crate::tensor::fibertree::Fiber;
use crate::tensor::ir::{KOp, LayerIr};

/// The OIM tensor with rank order [I, S, N, O, R] as a fibertree, plus the
/// operation-descriptor table that gives meaning to the N coordinates.
pub struct OimTensor {
    pub fiber: Fiber,
    pub descs: Vec<OpDesc>,
    pub shapes: OimShapes,
}

/// Shapes of the five ranks (for density reporting, paper §5.1).
#[derive(Clone, Copy, Debug)]
pub struct OimShapes {
    pub i: usize,
    pub s: usize,
    pub n: usize,
    pub o: usize,
    pub r: usize,
}

impl OimTensor {
    /// Build the OIM fibertree from the lowered design.
    pub fn from_ir(ir: &LayerIr) -> Self {
        let mut desc_ids: HashMap<OpDesc, usize> = HashMap::new();
        let mut descs: Vec<OpDesc> = Vec::new();
        let mut max_o = 1usize;

        // First pass: descriptor table.
        for layer in &ir.layers {
            for rec in layer {
                let d = OpDesc { op: rec.kop(), imm: rec.imm, mask: rec.mask, aux: rec.aux };
                if !desc_ids.contains_key(&d) {
                    desc_ids.insert(d, descs.len());
                    descs.push(d);
                }
                max_o = max_o.max(rec.arity as usize);
            }
        }

        let shapes = OimShapes {
            i: ir.layers.len(),
            s: ir.num_slots,
            n: descs.len().max(1),
            o: max_o,
            r: ir.num_slots,
        };

        let mut root = Fiber::new(shapes.i);
        for (i, layer) in ir.layers.iter().enumerate() {
            for rec in layer {
                let d = OpDesc { op: rec.kop(), imm: rec.imm, mask: rec.mask, aux: rec.aux };
                let n = desc_ids[&d];
                let s = rec.out as usize;
                for (o, r) in operand_slots(rec, &ir.ext_args).into_iter().enumerate() {
                    // OIM is a mask tensor: leaf payload 1 at
                    // (i, s, n, o, r) marks "operand o of op s comes from r".
                    root.set_path(
                        &[i, s, n, o, r as usize],
                        &[shapes.s, shapes.n, shapes.o, shapes.r],
                        1,
                    );
                }
            }
        }
        OimTensor { fiber: root, descs, shapes }
    }

    /// Tensor density = occupancy / size of the iteration space. The paper
    /// reports 1e-7..1e-9 for real designs (§5.1).
    pub fn density(&self) -> f64 {
        let leaves = self.fiber.count_leaves() as f64;
        let space =
            self.shapes.i as f64 * self.shapes.s as f64 * self.shapes.n as f64 * self.shapes.o as f64 * self.shapes.r as f64;
        leaves / space
    }
}

/// Ordered operand slots of a record (a,b,c then ext for MuxChain).
fn operand_slots(rec: &crate::tensor::ir::OpRec, ext_args: &[u32]) -> Vec<u32> {
    let ar = rec.arity as usize;
    match rec.kop() {
        KOp::MuxChain => {
            let mut v = vec![rec.a, rec.b];
            v.extend_from_slice(&ext_args[rec.ext as usize..rec.ext as usize + ar - 2]);
            v
        }
        _ => [rec.a, rec.b, rec.c][..ar].to_vec(),
    }
}

/// Cycle-level simulator that evaluates Cascade 1 literally.
pub struct CascadeSim {
    pub oim: OimTensor,
    /// LI: the flat value file (identity elision makes it layer-invariant).
    pub li: Vec<u64>,
    ir_inputs: Vec<(u32, u8)>,
    commits: Vec<(u32, u32, u64)>,
    outputs: Vec<(String, u32)>,
}

impl CascadeSim {
    pub fn new(ir: &LayerIr) -> Self {
        let oim = OimTensor::from_ir(ir);
        CascadeSim {
            oim,
            li: ir.initial_slots(),
            ir_inputs: ir.input_slots.iter().copied().zip(ir.input_widths.iter().copied()).collect(),
            commits: ir.commits.clone(),
            outputs: ir.output_slots.clone(),
        }
    }

    /// One simulation cycle = one full evaluation of Cascade 1 over the
    /// iterative rank I, followed by the register-commit connects.
    pub fn step(&mut self, inputs: &[u64]) {
        for ((slot, w), &v) in self.ir_inputs.iter().zip(inputs) {
            self.li[*slot as usize] = v & mask(*w);
        }
        // ◇ : i ≡ I — iterate the cascade over layers.
        for (_i, layer_payload) in self.oim.fiber.iter() {
            let s_fiber = layer_payload.as_fiber();
            // LO / LO_sel (merged: s coordinates are unique, §4.2).
            let mut lo: BTreeMap<usize, u64> = BTreeMap::new();
            for (s, n_payload) in s_fiber.iter() {
                // N fibers are one-hot: each op has exactly one type.
                let n_fiber = n_payload.as_fiber();
                debug_assert_eq!(n_fiber.occupancy(), 1, "N fiber must be one-hot");
                let (n, o_payload) = n_fiber.iter().next().unwrap();
                let desc = self.oim.descs[n];
                let o_fiber = o_payload.as_fiber();

                // Einsum 10 (map ∧ ←(→)): OI = LI gathered through OIM.
                // O-rank traversal is coordinate-ascending (the ordering
                // constraint of §4.1); R fibers are one-hot.
                let mut oi: Vec<u64> = Vec::with_capacity(o_fiber.occupancy());
                for (_o, r_payload) in o_fiber.iter() {
                    let r_fiber = r_payload.as_fiber();
                    debug_assert_eq!(r_fiber.occupancy(), 1, "R fiber must be one-hot");
                    let (r, leaf) = r_fiber.iter().next().unwrap();
                    debug_assert_eq!(leaf.as_val(), 1, "OIM is a binary mask");
                    oi.push(self.li[r]);
                }

                let value = if desc.is_select() {
                    // Einsum 13: populate ⋘ 1(op_s[n]) over the O fiber.
                    desc.op_s(&oi) & desc.mask
                } else {
                    // Einsum 12: ∧ op_u[n](←) ∨ op_r[n](→).
                    let mut t = desc.op_u(oi[0]);
                    for &v in &oi[1..] {
                        t = desc.op_r(t, v);
                    }
                    t & desc.mask
                };
                lo.insert(s, value);
            }
            // Final Einsum: LI_{i+1,s} = LO_{i,n,s} / LO_sel (ANY-reduce).
            for (s, v) in lo {
                self.li[s] = v;
            }
        }
        for &(reg, next, m) in &self.commits {
            self.li[reg as usize] = self.li[next as usize] & m;
        }
    }

    pub fn outputs(&self) -> Vec<(String, u64)> {
        self.outputs.iter().map(|(n, s)| (n.clone(), self.li[*s as usize])).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::{random_circuit, random_inputs};
    use crate::graph::passes::optimize;
    use crate::tensor::ir::{lower, IrSim};
    use crate::util::prng::Rng;

    /// The executable cascade must agree with the slot-file simulator —
    /// this ties the Einsum formulation (§4) to the kernel semantics (§5).
    #[test]
    fn cascade_matches_ir_sim() {
        for seed in 0..10 {
            let mut rng = Rng::new(31000 + seed);
            let g = random_circuit(&mut rng, 50);
            let (opt, _) = optimize(&g);
            let ir = lower(&opt);
            let mut irsim = IrSim::new(ir.clone());
            let mut cas = CascadeSim::new(&ir);
            for cycle in 0..10 {
                let inputs = random_inputs(&mut rng, &crate::graph::Graph { inputs: opt.inputs.clone(), ..Default::default() });
                irsim.step(&inputs);
                cas.step(&inputs);
                assert_eq!(irsim.outputs(), cas.outputs(), "seed {seed} cycle {cycle}");
            }
        }
    }

    #[test]
    fn oim_is_extremely_sparse() {
        let mut rng = Rng::new(5);
        let g = random_circuit(&mut rng, 300);
        let ir = lower(&g);
        let oim = OimTensor::from_ir(&ir);
        // the paper reports 1e-7..1e-9 on real designs; even small random
        // circuits are already well below 1e-4
        assert!(oim.density() < 1e-4, "density {}", oim.density());
    }

    /// Paper Appendix A, Einsum 14: `B_{r*} = A_r :: ⋘ 1(max2)` — a
    /// custom populate-coordinate operator acting on a whole fiber,
    /// keeping the two largest values (coordinates preserved). This is
    /// the general mechanism `op_s[n]`/`LO_sel`'s `o*` rank uses.
    #[test]
    fn appendix_a_max2_populate_operator() {
        use crate::tensor::fibertree::{Fiber, Payload};
        let mut a = Fiber::new(8);
        for (c, v) in [(0usize, 3u64), (2, 9), (3, 1), (6, 7)] {
            a.set(c, Payload::Val(v));
        }
        // populate ⋘ 1(max2): operator sees the whole input fiber and
        // decides which output coordinates to populate
        let max2 = |fiber: &Fiber| -> Fiber {
            let mut entries: Vec<(usize, u64)> =
                fiber.iter().map(|(c, p)| (c, p.as_val())).collect();
            entries.sort_by_key(|&(_, v)| std::cmp::Reverse(v));
            entries.truncate(2);
            let mut out = Fiber::new(fiber.shape);
            for (c, v) in entries {
                out.set(c, Payload::Val(v));
            }
            out
        };
        let b = max2(&a);
        assert_eq!(b.occupancy(), 2);
        assert_eq!(b.get_path(&[2]), Some(9));
        assert_eq!(b.get_path(&[6]), Some(7));
        assert_eq!(b.get_path(&[0]), None);
    }

    #[test]
    fn oim_leaves_equal_total_operands() {
        let mut rng = Rng::new(6);
        let g = random_circuit(&mut rng, 80);
        let ir = lower(&g);
        let oim = OimTensor::from_ir(&ir);
        let operands: usize =
            ir.layers.iter().flat_map(|l| l.iter()).map(|r| r.arity as usize).sum();
        assert_eq!(oim.fiber.count_leaves(), operands);
    }
}
