//! Baseline simulators (substitutes for the paper's comparators).
//!
//! The paper evaluates against Verilator 5.016 and ESSENT (-O2). Neither
//! can run here (no Chipyard designs, no multi-hundred-GB compiles), so we
//! implement executors with the same *structural* properties the paper
//! measures:
//!
//! * [`verilator_like`] — compiled per-node code with data-dependent
//!   branching and moderate optimization (Verilator's macrotask style).
//! * [`essent_like`] — fully flattened straight-line op list with
//!   pre-resolved operands and direct writes (ESSENT's full-cycle -O2
//!   mode; activity-aware -O3 is out of scope, as in the paper §3).
//! * [`event_driven`] — a classic activity-aware event-driven simulator
//!   (bonus baseline; the paper's §2.1 taxonomy).
//!
//! `graph::RefSim` (the semantic oracle) lives with the graph IR.

pub mod verilator_like;
pub mod essent_like;
pub mod event_driven;

#[cfg(test)]
mod tests {
    use crate::graph::builder::{random_circuit, random_inputs};
    use crate::graph::passes::optimize_no_fusion;
    use crate::graph::RefSim;
    use crate::kernels::SimKernel;
    use crate::tensor::ir::lower;
    use crate::util::prng::Rng;

    #[test]
    fn baselines_match_reference() {
        for seed in 0..8 {
            let mut rng = Rng::new(70_000 + seed);
            let g = random_circuit(&mut rng, 80);
            let opt = optimize_no_fusion(&g);
            let ir = lower(&opt);
            let mut reference = RefSim::new(opt.clone());
            let mut sims: Vec<Box<dyn SimKernel>> = vec![
                Box::new(super::verilator_like::VerilatorLike::new(&ir, false)),
                Box::new(super::verilator_like::VerilatorLike::new(&ir, true)),
                Box::new(super::essent_like::EssentLike::new(&ir, false)),
                Box::new(super::essent_like::EssentLike::new(&ir, true)),
                Box::new(super::event_driven::EventDriven::new(&ir)),
            ];
            for cycle in 0..12 {
                let inputs = random_inputs(&mut rng, &reference.graph);
                reference.step(&inputs);
                for s in &mut sims {
                    s.step(&inputs);
                    assert_eq!(
                        s.outputs(),
                        reference.outputs(),
                        "{} diverged seed {seed} cycle {cycle}",
                        s.config_name()
                    );
                }
            }
        }
    }
}
