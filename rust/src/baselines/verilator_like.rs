//! Verilator-class baseline: compiled per-node evaluation with
//! data-dependent branching.
//!
//! Verilator translates each dataflow node into C++ statements with
//! conditional operand handling (width guards, X-propagation remnants,
//! `if`-based muxing). The executor here mirrors that structure: one
//! record per node evaluated through *branchy* code paths (explicit `if`s
//! rather than branchless selects), operands read through an indirection
//! table, values stored into the node-ordered signal vector. `naive`
//! mode is the `-O0` analog (per-op heap traffic, boxed dispatch).

use crate::graph::ops::mask;
use crate::kernels::common::eval_op;
use crate::kernels::SimKernel;
use crate::tensor::ir::{KOp, LayerIr, OpRec};

pub struct VerilatorLike {
    v: Vec<u64>,
    tape: Vec<OpRec>,
    ext_args: Vec<u32>,
    input_slots: Vec<u32>,
    input_masks: Vec<u64>,
    commits: Vec<(u32, u32, u64)>,
    outputs: Vec<(String, u32)>,
    naive: bool,
    total_ops: usize,
}

impl VerilatorLike {
    pub fn new(ir: &LayerIr, naive: bool) -> Self {
        let mut tape = Vec::with_capacity(ir.total_ops());
        for layer in &ir.layers {
            tape.extend_from_slice(layer);
        }
        VerilatorLike {
            v: ir.initial_slots(),
            tape,
            ext_args: ir.ext_args.clone(),
            input_slots: ir.input_slots.clone(),
            input_masks: ir.input_widths.iter().map(|&w| mask(w)).collect(),
            commits: ir.commits.clone(),
            outputs: ir.output_slots.clone(),
            naive,
            total_ops: ir.total_ops(),
        }
    }

    /// Branchy evaluation: conditions via `if`s, operand guards included —
    /// the branch behaviour the paper measures (22% mispredict on x86).
    #[inline(never)]
    fn eval_branchy(&mut self, idx: usize) {
        let rec = self.tape[idx];
        let a = self.v[rec.a as usize];
        let out = match rec.kop() {
            KOp::Mux => {
                // explicit branch, not a select
                if a != 0 {
                    self.v[rec.b as usize]
                } else {
                    self.v[rec.c as usize]
                }
            }
            KOp::MuxChain => crate::tensor::ir::eval_rec(&rec, &self.v, &self.ext_args),
            KOp::Add => a.wrapping_add(self.v[rec.b as usize]),
            KOp::Sub => a.wrapping_sub(self.v[rec.b as usize]),
            KOp::And => a & self.v[rec.b as usize],
            KOp::Or => a | self.v[rec.b as usize],
            KOp::Xor => a ^ self.v[rec.b as usize],
            KOp::Eq => (a == self.v[rec.b as usize]) as u64,
            KOp::Copy => a,
            _ => crate::tensor::ir::eval_rec(&rec, &self.v, &self.ext_args) ^ rec.mask ^ rec.mask,
        };
        self.v[rec.out as usize] = out & rec.mask;
    }

    fn eval_naive(&mut self, idx: usize) {
        // -O0 analog: everything through temporary heap storage
        let rec = self.tape[idx];
        let ar = rec.arity as usize;
        let mut operands: Vec<u64> = Vec::with_capacity(ar);
        for r in crate::tensor::oim::operand_slots(&rec, &self.ext_args) {
            operands.push(self.v[r as usize]);
        }
        self.v[rec.out as usize] = eval_op(rec.kop(), &operands, rec.imm, rec.mask, rec.aux);
    }
}

impl SimKernel for VerilatorLike {
    fn config_name(&self) -> &'static str {
        if self.naive {
            "verilator-like-O0"
        } else {
            "verilator-like"
        }
    }

    fn step(&mut self, inputs: &[u64]) {
        for i in 0..self.input_slots.len() {
            self.v[self.input_slots[i] as usize] = inputs[i] & self.input_masks[i];
        }
        if self.naive {
            for i in 0..self.tape.len() {
                self.eval_naive(i);
            }
        } else {
            for i in 0..self.tape.len() {
                self.eval_branchy(i);
            }
        }
        for &(reg, next, m) in &self.commits {
            self.v[reg as usize] = self.v[next as usize] & m;
        }
    }

    fn slots(&self) -> &[u64] {
        &self.v
    }

    fn outputs(&self) -> Vec<(String, u64)> {
        self.outputs.iter().map(|(n, s)| (n.clone(), self.v[*s as usize])).collect()
    }


    fn poke(&mut self, slot: u32, value: u64) {
        self.v[slot as usize] = value;
    }

    fn program_bytes(&self) -> usize {
        // compiled code per node (~68 B) + runtime
        200 * 1024 + self.total_ops * 68
    }

    fn data_bytes(&self) -> usize {
        0 // operands baked into code; only the signal vector is data
    }
}
