//! ESSENT-class baseline: fully unrolled straight-line evaluation.
//!
//! ESSENT emits the whole dataflow graph as straight-line C++ (full-cycle,
//! -O2 — the paper's activity-oblivious configuration), giving minimal
//! branching and maximal compiler optimization at the cost of a huge
//! binary and compile. The executor here is a flat tape of precompiled
//! per-op closures with direct slot writes — the fastest interpreter
//! structure available to us, standing in for "most aggressively compiled".
//! `naive` mode models ESSENT at -O0: the paper measures a 103× dynamic
//! instruction blow-up because every straight-line temporary round-trips
//! through memory; we model it with boxed per-op thunks and per-op heap
//! traffic.

use crate::graph::ops::mask;
use crate::kernels::common::eval_op;
use crate::kernels::SimKernel;
use crate::tensor::ir::{LayerIr, OpRec};

type EsFn = fn(&mut [u64], &OpRec, &[u32]);
type BoxedThunk = Box<dyn Fn(&mut Vec<u64>, &[u32]) + Send + Sync>;

pub struct EssentLike {
    v: Vec<u64>,
    tape: Vec<(EsFn, OpRec)>,
    naive_tape: Vec<BoxedThunk>,
    ext_args: Vec<u32>,
    input_slots: Vec<u32>,
    input_masks: Vec<u64>,
    commits: Vec<(u32, u32, u64)>,
    outputs: Vec<(String, u32)>,
    naive: bool,
    total_ops: usize,
}

fn es_eval(v: &mut [u64], rec: &OpRec, ext: &[u32]) {
    v[rec.out as usize] = crate::tensor::ir::eval_rec(rec, v, ext);
}

impl EssentLike {
    pub fn new(ir: &LayerIr, naive: bool) -> Self {
        let mut tape: Vec<(EsFn, OpRec)> = Vec::with_capacity(ir.total_ops());
        let mut naive_tape: Vec<BoxedThunk> = Vec::new();
        for layer in &ir.layers {
            for rec in layer {
                if naive {
                    let rec = *rec;
                    naive_tape.push(Box::new(move |v: &mut Vec<u64>, ext: &[u32]| {
                        // -O0: gather to heap, evaluate, write back
                        let slots = crate::tensor::oim::operand_slots(&rec, ext);
                        let operands: Vec<u64> = slots.iter().map(|&r| v[r as usize]).collect();
                        let out = eval_op(rec.kop(), &operands, rec.imm, rec.mask, rec.aux);
                        v[rec.out as usize] = out;
                    }));
                } else {
                    tape.push((es_eval, *rec));
                }
            }
        }
        EssentLike {
            v: ir.initial_slots(),
            tape,
            naive_tape,
            ext_args: ir.ext_args.clone(),
            input_slots: ir.input_slots.clone(),
            input_masks: ir.input_widths.iter().map(|&w| mask(w)).collect(),
            commits: ir.commits.clone(),
            outputs: ir.output_slots.clone(),
            naive,
            total_ops: ir.total_ops(),
        }
    }
}

impl SimKernel for EssentLike {
    fn config_name(&self) -> &'static str {
        if self.naive {
            "essent-like-O0"
        } else {
            "essent-like"
        }
    }

    fn step(&mut self, inputs: &[u64]) {
        for i in 0..self.input_slots.len() {
            self.v[self.input_slots[i] as usize] = inputs[i] & self.input_masks[i];
        }
        if self.naive {
            // temporarily move v to satisfy the borrow checker cheaply
            let mut v = std::mem::take(&mut self.v);
            for thunk in &self.naive_tape {
                thunk(&mut v, &self.ext_args);
            }
            self.v = v;
        } else {
            for (f, rec) in &self.tape {
                f(&mut self.v, rec, &self.ext_args);
            }
        }
        for &(reg, next, m) in &self.commits {
            self.v[reg as usize] = self.v[next as usize] & m;
        }
    }

    fn slots(&self) -> &[u64] {
        &self.v
    }

    fn outputs(&self) -> Vec<(String, u64)> {
        self.outputs.iter().map(|(n, s)| (n.clone(), self.v[*s as usize])).collect()
    }


    fn poke(&mut self, slot: u32, value: u64) {
        self.v[slot as usize] = value;
    }

    fn program_bytes(&self) -> usize {
        let per_op = if self.naive { 160 } else { 40 };
        150 * 1024 + self.total_ops * per_op
    }

    fn data_bytes(&self) -> usize {
        0
    }
}
