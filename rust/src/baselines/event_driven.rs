//! Event-driven (activity-aware) baseline — the paper's §2.1 alternative
//! paradigm. Nodes are re-evaluated only when an input changed, at the
//! cost of tracking dirtiness and maintaining a worklist. Full-cycle
//! simulators usually win because this bookkeeping outweighs the skipped
//! work (the observation that motivates the paper's full-cycle focus);
//! having it in-repo lets the benches show that trade-off.

use crate::graph::ops::mask;
use crate::kernels::SimKernel;
use crate::tensor::ir::{eval_rec, LayerIr, OpRec};

pub struct EventDriven {
    v: Vec<u64>,
    layers: Vec<Vec<OpRec>>,
    ext_args: Vec<u32>,
    /// per-slot fanout: ops (layer, index) reading each slot
    fanout: Vec<Vec<(u32, u32)>>,
    /// dirty marks per (layer, op)
    dirty: Vec<Vec<bool>>,
    input_slots: Vec<u32>,
    input_masks: Vec<u64>,
    commits: Vec<(u32, u32, u64)>,
    outputs: Vec<(String, u32)>,
    pub evaluated_ops: u64,
    pub total_ops_per_cycle: u64,
}

impl EventDriven {
    pub fn new(ir: &LayerIr) -> Self {
        let mut fanout: Vec<Vec<(u32, u32)>> = vec![Vec::new(); ir.num_slots];
        for (li, layer) in ir.layers.iter().enumerate() {
            for (oi, rec) in layer.iter().enumerate() {
                for r in crate::tensor::oim::operand_slots(rec, &ir.ext_args) {
                    fanout[r as usize].push((li as u32, oi as u32));
                }
            }
        }
        let dirty = ir.layers.iter().map(|l| vec![true; l.len()]).collect();
        EventDriven {
            v: ir.initial_slots(),
            layers: ir.layers.clone(),
            ext_args: ir.ext_args.clone(),
            fanout,
            dirty,
            input_slots: ir.input_slots.clone(),
            input_masks: ir.input_widths.iter().map(|&w| mask(w)).collect(),
            commits: ir.commits.clone(),
            outputs: ir.output_slots.clone(),
            evaluated_ops: 0,
            total_ops_per_cycle: ir.total_ops() as u64,
        }
    }

    fn touch(&mut self, slot: u32) {
        for &(li, oi) in &self.fanout[slot as usize] {
            self.dirty[li as usize][oi as usize] = true;
        }
    }

    /// Fraction of ops actually evaluated (activity factor).
    pub fn activity_factor(&self, cycles: u64) -> f64 {
        if cycles == 0 {
            return 1.0;
        }
        self.evaluated_ops as f64 / (self.total_ops_per_cycle * cycles) as f64
    }
}

impl SimKernel for EventDriven {
    fn config_name(&self) -> &'static str {
        "event-driven"
    }

    fn step(&mut self, inputs: &[u64]) {
        for i in 0..self.input_slots.len() {
            let slot = self.input_slots[i];
            let nv = inputs[i] & self.input_masks[i];
            if self.v[slot as usize] != nv {
                self.v[slot as usize] = nv;
                self.touch(slot);
            }
        }
        for li in 0..self.layers.len() {
            for oi in 0..self.layers[li].len() {
                if !self.dirty[li][oi] {
                    continue;
                }
                self.dirty[li][oi] = false;
                let rec = self.layers[li][oi];
                let nv = eval_rec(&rec, &self.v, &self.ext_args);
                self.evaluated_ops += 1;
                if self.v[rec.out as usize] != nv {
                    self.v[rec.out as usize] = nv;
                    self.touch(rec.out);
                }
            }
        }
        for ci in 0..self.commits.len() {
            let (reg, next, m) = self.commits[ci];
            let nv = self.v[next as usize] & m;
            if self.v[reg as usize] != nv {
                self.v[reg as usize] = nv;
                self.touch(reg);
            }
        }
    }

    fn slots(&self) -> &[u64] {
        &self.v
    }

    fn outputs(&self) -> Vec<(String, u64)> {
        self.outputs.iter().map(|(n, s)| (n.clone(), self.v[*s as usize])).collect()
    }


    fn poke(&mut self, slot: u32, value: u64) {
        if self.v[slot as usize] != value {
            self.v[slot as usize] = value;
            self.touch(slot);
        }
    }

    fn program_bytes(&self) -> usize {
        250 * 1024
    }

    fn data_bytes(&self) -> usize {
        // metadata + fanout lists + dirty marks
        self.fanout.iter().map(|f| f.len() * 8 + 24).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::graph::ops::PrimOp;
    use crate::tensor::ir::lower;

    #[test]
    fn activity_tracking_skips_stable_logic() {
        // two independent cones; only one sees changing inputs
        let mut g = Graph::new("t");
        let a = g.input("a", 8);
        let b = g.input("b", 8);
        let mut x = a;
        for _ in 0..10 {
            x = g.prim(PrimOp::Not, &[x]);
        }
        let mut y = b;
        for _ in 0..10 {
            y = g.prim(PrimOp::Not, &[y]);
        }
        g.output("x", x);
        g.output("y", y);
        let ir = lower(&g);
        let mut sim = EventDriven::new(&ir);
        sim.step(&[1, 1]);
        let after_first = sim.evaluated_ops;
        assert_eq!(after_first, 20); // cold start evaluates everything
        // b stable -> its cone not re-evaluated
        for i in 0..10u64 {
            sim.step(&[i % 2, 1]);
        }
        assert_eq!(sim.evaluated_ops, after_first + 10 * 10);
        assert!(sim.activity_factor(11) < 0.7);
    }
}
