//! RTeAAL Sim command-line interface (leader entrypoint).
//!
//! Subcommands are routed to `coordinator::cli` — see `rteaal help`.

rteaal::install_tracking_alloc!();

fn main() {
    let args = rteaal::util::cli::Args::from_env();
    if let Err(e) = rteaal::coordinator::cli::run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
