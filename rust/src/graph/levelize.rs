//! Levelization (§4.2): slice the dataflow graph into layers so that each
//! operation depends only on outputs of strictly earlier layers, plus the
//! identity-operation accounting of §4.3 / Table 1.
//!
//! Sources (constants, inputs, registers) sit at level 0 ("LI"). A primitive
//! op's level is `1 + max(level(args))`. Layer `i` (0-based) holds the ops
//! at level `i + 1`.
//!
//! Identity operations: with strict layer-to-layer propagation (the cascade
//! in §4.2), a value produced at level `L` and consumed at level `L' > L+1`
//! must be carried by one identity op per intermediate layer. Our kernels
//! elide all of them by assigning matching source/destination coordinates
//! (flat slot file), exactly as §4.3 prescribes, but we still *count* them
//! to reproduce Table 1.

use super::{Graph, NodeId, NodeKind};

/// Result of levelization.
#[derive(Debug, Clone)]
pub struct Levelized {
    /// For each node, its level (sources = 0).
    pub level: Vec<u32>,
    /// Layers of primitive ops: `layers[i]` = node ids at level `i + 1`,
    /// in ascending node-id order (deterministic).
    pub layers: Vec<Vec<NodeId>>,
    /// Number of identity operations that full layer-to-layer propagation
    /// would require (elided in execution; Table 1 reproduces this).
    pub identity_ops: usize,
}

impl Levelized {
    /// Number of layers (the shape of rank `I`).
    pub fn depth(&self) -> usize {
        self.layers.len()
    }

    /// Total effectual (non-identity) operations.
    pub fn effectual_ops(&self) -> usize {
        self.layers.iter().map(|l| l.len()).sum()
    }
}

/// Levelize a graph.
pub fn levelize(g: &Graph) -> Levelized {
    let n = g.nodes.len();
    let mut level = vec![0u32; n];
    let mut max_level = 0u32;
    for i in 0..n {
        let node = &g.nodes[i];
        if node.is_source() {
            level[i] = 0;
        } else {
            let lv = node.args.iter().map(|&a| level[a as usize]).max().unwrap_or(0) + 1;
            level[i] = lv;
            max_level = max_level.max(lv);
        }
    }

    let mut layers = vec![Vec::new(); max_level as usize];
    for i in 0..n {
        if matches!(g.nodes[i].kind, NodeKind::Prim(_)) {
            layers[(level[i] - 1) as usize].push(i as NodeId);
        }
    }

    // Identity accounting: for each value, the span between its level and
    // its deepest consumer requires one identity per intermediate layer.
    // Register next-state reads and outputs are consumed "at the end"
    // (level max_level + 1) because the final Einsum writes LI_{i+1}.
    let mut deepest_use = vec![0u32; n];
    for (i, node) in g.nodes.iter().enumerate() {
        for &a in &node.args {
            deepest_use[a as usize] = deepest_use[a as usize].max(level[i]);
        }
    }
    let end_level = max_level + 1;
    for r in &g.regs {
        deepest_use[r.next as usize] = deepest_use[r.next as usize].max(end_level);
    }
    for (_, o) in &g.outputs {
        deepest_use[*o as usize] = deepest_use[*o as usize].max(end_level);
    }
    let mut identity_ops = 0usize;
    for i in 0..n {
        if deepest_use[i] > 0 {
            let produced = level[i];
            // consumed at deepest_use[i]; identities carry it through
            // layers produced+1 .. deepest_use[i]-1
            identity_ops += deepest_use[i].saturating_sub(produced + 1) as usize;
        }
    }

    Levelized { level, layers, identity_ops }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ops::PrimOp;
    use crate::util::prng::Rng;

    #[test]
    fn levels_respect_dependencies() {
        let mut rng = Rng::new(5);
        for _ in 0..10 {
            let g = crate::graph::builder::random_circuit(&mut rng, 60);
            let lv = levelize(&g);
            for (i, node) in g.nodes.iter().enumerate() {
                for &a in &node.args {
                    assert!(
                        lv.level[a as usize] < lv.level[i].max(1),
                        "node {i} level {} arg {a} level {}",
                        lv.level[i],
                        lv.level[a as usize]
                    );
                }
            }
            // every prim appears in exactly one layer
            let total: usize = lv.layers.iter().map(|l| l.len()).sum();
            assert_eq!(total, g.num_ops());
        }
    }

    #[test]
    fn identity_count_linear_chain() {
        // in -> a -> b -> c, with `in` ALSO consumed at the last layer:
        // identities must carry `in` across intermediate layers.
        let mut g = Graph::new("chain");
        let i = g.input("in", 8);
        let a = g.prim(PrimOp::Not, &[i]); // level 1
        let b = g.prim(PrimOp::Not, &[a]); // level 2
        let c = g.prim_w(PrimOp::Add, &[b, i], 8); // level 3, uses `in` (level 0)
        g.output("o", c);
        let lv = levelize(&g);
        assert_eq!(lv.depth(), 3);
        // `in` produced at 0, deepest use level 3 -> 2 identities
        // a: produced 1, used at 2 -> 0; b: produced 2 used 3 -> 0
        // c: produced 3, output consumed at end (4) -> 0
        assert_eq!(lv.identity_ops, 2);
    }

    #[test]
    fn register_feedback_counts_to_end() {
        // r' = r + 1 computed at level 1, but a value at level 1 feeding a
        // reg in a 3-deep design must be carried to the end.
        let mut g = Graph::new("t");
        let r = g.reg("r", 8, 0);
        let one = g.konst(1, 8);
        let inc = g.prim_w(PrimOp::Add, &[r, one], 8); // level 1
        let x = g.prim(PrimOp::Not, &[inc]); // level 2
        let y = g.prim(PrimOp::Not, &[x]); // level 3
        g.connect_reg(r, inc);
        g.output("y", y);
        let lv = levelize(&g);
        assert_eq!(lv.depth(), 3);
        // inc: produced 1, consumed by reg at end level 4 => 2 identities
        assert_eq!(lv.identity_ops, 2);
    }
}
