//! Primitive operations of the dataflow graph and their evaluation
//! semantics.
//!
//! The op set covers the FIRRTL primitive operations used by our designs
//! (§6.1 of the paper: "OIM's N rank supports all FIRRTL primitive
//! operations and the custom mux-chain operation"). All values are
//! unsigned, stored in `u64`, and every node's result is masked to its
//! declared width — this single definition of semantics is shared by the
//! reference interpreter, constant folding, the Einsum cascade evaluator
//! and all seven kernels, so agreement between them is meaningful.

/// Bit mask for a width in 1..=64.
#[inline]
pub fn mask(width: u8) -> u64 {
    if width >= 64 {
        u64::MAX
    } else {
        (1u64 << width) - 1
    }
}

/// Primitive operation (with static immediates where FIRRTL has them).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PrimOp {
    // Arithmetic (reducible in the paper's taxonomy)
    Add,
    Sub,
    Mul,
    Div,
    Rem,
    // Comparisons
    Lt,
    Leq,
    Gt,
    Geq,
    Eq,
    Neq,
    // Bitwise (reducible)
    And,
    Or,
    Xor,
    // Unary
    Not,
    Neg,
    Andr,
    Orr,
    Xorr,
    /// Static left shift by `n`.
    Shl(u8),
    /// Static right shift by `n`.
    Shr(u8),
    // Dynamic shifts
    Dshl,
    Dshr,
    /// Concatenate: `(a << width(b)) | b`.
    Cat,
    /// Bit extract `[hi:lo]`.
    Bits(u8, u8),
    /// Top `n` bits.
    Head(u8),
    /// Drop top `n` bits.
    Tail(u8),
    /// Widen to `width + n` (value-preserving for UInt).
    Pad(u8),
    /// Select operation: `sel != 0 ? t : f` (args `[sel, t, f]`).
    Mux,
    /// Identity / copy (inserted by levelization, elided per §4.3).
    Id,
    /// Fused mux chain (operator fusion, §B.1): args
    /// `[s0, v0, s1, v1, .., s_{k-1}, v_{k-1}, default]`; first true
    /// selector wins.
    MuxChain(u8),
}

impl PrimOp {
    /// Number of graph arguments this op consumes.
    pub fn arity(&self) -> usize {
        match self {
            PrimOp::Not
            | PrimOp::Neg
            | PrimOp::Andr
            | PrimOp::Orr
            | PrimOp::Xorr
            | PrimOp::Shl(_)
            | PrimOp::Shr(_)
            | PrimOp::Bits(..)
            | PrimOp::Head(_)
            | PrimOp::Tail(_)
            | PrimOp::Pad(_)
            | PrimOp::Id => 1,
            PrimOp::Mux => 3,
            PrimOp::MuxChain(k) => 2 * (*k as usize) + 1,
            _ => 2,
        }
    }

    /// Operation class per the paper §4.1: reducible / unary / select.
    pub fn class(&self) -> OpClass {
        match self {
            PrimOp::Mux | PrimOp::MuxChain(_) => OpClass::Select,
            p if p.arity() == 1 => OpClass::Unary,
            _ => OpClass::Reducible,
        }
    }

    /// Short mnemonic (used in FIRRTL text, VCD and reports).
    pub fn mnemonic(&self) -> &'static str {
        match self {
            PrimOp::Add => "add",
            PrimOp::Sub => "sub",
            PrimOp::Mul => "mul",
            PrimOp::Div => "div",
            PrimOp::Rem => "rem",
            PrimOp::Lt => "lt",
            PrimOp::Leq => "leq",
            PrimOp::Gt => "gt",
            PrimOp::Geq => "geq",
            PrimOp::Eq => "eq",
            PrimOp::Neq => "neq",
            PrimOp::And => "and",
            PrimOp::Or => "or",
            PrimOp::Xor => "xor",
            PrimOp::Not => "not",
            PrimOp::Neg => "neg",
            PrimOp::Andr => "andr",
            PrimOp::Orr => "orr",
            PrimOp::Xorr => "xorr",
            PrimOp::Shl(_) => "shl",
            PrimOp::Shr(_) => "shr",
            PrimOp::Dshl => "dshl",
            PrimOp::Dshr => "dshr",
            PrimOp::Cat => "cat",
            PrimOp::Bits(..) => "bits",
            PrimOp::Head(_) => "head",
            PrimOp::Tail(_) => "tail",
            PrimOp::Pad(_) => "pad",
            PrimOp::Mux => "mux",
            PrimOp::Id => "id",
            PrimOp::MuxChain(_) => "muxchain",
        }
    }
}

/// The paper's three operation classes (§4.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpClass {
    Reducible,
    Unary,
    Select,
}

/// Evaluate a primitive op.
///
/// `args` are the (already width-masked) operand values, `arg_widths` their
/// widths, `out_width` the result width. The result is masked to
/// `out_width`.
pub fn eval_prim(op: PrimOp, args: &[u64], arg_widths: &[u8], out_width: u8) -> u64 {
    let a = args.first().copied().unwrap_or(0);
    let b = args.get(1).copied().unwrap_or(0);
    let raw = match op {
        PrimOp::Add => a.wrapping_add(b),
        PrimOp::Sub => a.wrapping_sub(b),
        PrimOp::Mul => a.wrapping_mul(b),
        PrimOp::Div => {
            if b == 0 {
                0
            } else {
                a / b
            }
        }
        PrimOp::Rem => {
            if b == 0 {
                0
            } else {
                a % b
            }
        }
        PrimOp::Lt => (a < b) as u64,
        PrimOp::Leq => (a <= b) as u64,
        PrimOp::Gt => (a > b) as u64,
        PrimOp::Geq => (a >= b) as u64,
        PrimOp::Eq => (a == b) as u64,
        PrimOp::Neq => (a != b) as u64,
        PrimOp::And => a & b,
        PrimOp::Or => a | b,
        PrimOp::Xor => a ^ b,
        PrimOp::Not => !a,
        PrimOp::Neg => a.wrapping_neg(),
        PrimOp::Andr => (a == mask(arg_widths[0])) as u64,
        PrimOp::Orr => (a != 0) as u64,
        PrimOp::Xorr => (a.count_ones() & 1) as u64,
        PrimOp::Shl(n) => {
            if n >= 64 {
                0
            } else {
                a << n
            }
        }
        PrimOp::Shr(n) => {
            if n >= 64 {
                0
            } else {
                a >> n
            }
        }
        PrimOp::Dshl => {
            if b >= 64 {
                0
            } else {
                a << b
            }
        }
        PrimOp::Dshr => {
            if b >= 64 {
                0
            } else {
                a >> b
            }
        }
        PrimOp::Cat => {
            let wb = arg_widths[1];
            if wb >= 64 {
                b
            } else {
                (a << wb) | b
            }
        }
        PrimOp::Bits(hi, lo) => (a >> lo) & mask(hi - lo + 1),
        PrimOp::Head(n) => a >> (arg_widths[0] - n),
        PrimOp::Tail(n) => a & mask(arg_widths[0] - n),
        PrimOp::Pad(_) => a,
        PrimOp::Mux => {
            if a != 0 {
                b
            } else {
                args[2]
            }
        }
        PrimOp::Id => a,
        PrimOp::MuxChain(k) => {
            let k = k as usize;
            let mut v = args[2 * k]; // default
            for i in (0..k).rev() {
                if args[2 * i] != 0 {
                    v = args[2 * i + 1];
                }
            }
            // NOTE: iterating in reverse and overwriting implements
            // "first true selector wins".
            v
        }
    };
    raw & mask(out_width)
}

/// FIRRTL-style result width for an op given argument widths.
pub fn result_width(op: PrimOp, arg_widths: &[u8]) -> u8 {
    let a = arg_widths.first().copied().unwrap_or(1);
    let b = arg_widths.get(1).copied().unwrap_or(1);
    let w = match op {
        PrimOp::Add | PrimOp::Sub => a.max(b) + 1,
        PrimOp::Mul => a + b,
        PrimOp::Div => a,
        PrimOp::Rem => a.min(b),
        PrimOp::Lt
        | PrimOp::Leq
        | PrimOp::Gt
        | PrimOp::Geq
        | PrimOp::Eq
        | PrimOp::Neq
        | PrimOp::Andr
        | PrimOp::Orr
        | PrimOp::Xorr => 1,
        PrimOp::And | PrimOp::Or | PrimOp::Xor => a.max(b),
        PrimOp::Not | PrimOp::Neg => a,
        PrimOp::Shl(n) => a + n,
        PrimOp::Shr(n) => a.saturating_sub(n).max(1),
        PrimOp::Dshl => a, // truncating dshl (lowered form)
        PrimOp::Dshr => a,
        PrimOp::Cat => a + b,
        PrimOp::Bits(hi, lo) => hi - lo + 1,
        PrimOp::Head(n) => n,
        PrimOp::Tail(n) => a - n,
        PrimOp::Pad(n) => a.max(n),
        PrimOp::Mux => b.max(arg_widths[2]),
        PrimOp::Id => a,
        PrimOp::MuxChain(k) => {
            let mut w = arg_widths[2 * (k as usize)];
            for i in 0..(k as usize) {
                w = w.max(arg_widths[2 * i + 1]);
            }
            w
        }
    };
    w.min(64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(op: PrimOp, args: &[u64], widths: &[u8], out: u8) -> u64 {
        eval_prim(op, args, widths, out)
    }

    #[test]
    fn arithmetic_masks() {
        assert_eq!(ev(PrimOp::Add, &[7, 1], &[3, 3], 3), 0); // 8 masked to 3 bits
        assert_eq!(ev(PrimOp::Add, &[7, 1], &[3, 3], 4), 8);
        assert_eq!(ev(PrimOp::Sub, &[0, 1], &[4, 4], 4), 15); // wraps
        assert_eq!(ev(PrimOp::Mul, &[6, 7], &[3, 3], 6), 42);
    }

    #[test]
    fn division_by_zero_is_zero() {
        assert_eq!(ev(PrimOp::Div, &[5, 0], &[4, 4], 4), 0);
        assert_eq!(ev(PrimOp::Rem, &[5, 0], &[4, 4], 4), 0);
        assert_eq!(ev(PrimOp::Div, &[13, 3], &[4, 4], 4), 4);
        assert_eq!(ev(PrimOp::Rem, &[13, 3], &[4, 4], 2), 1);
    }

    #[test]
    fn comparisons() {
        assert_eq!(ev(PrimOp::Lt, &[2, 3], &[4, 4], 1), 1);
        assert_eq!(ev(PrimOp::Geq, &[3, 3], &[4, 4], 1), 1);
        assert_eq!(ev(PrimOp::Neq, &[3, 3], &[4, 4], 1), 0);
    }

    #[test]
    fn reductions() {
        assert_eq!(ev(PrimOp::Andr, &[0b111], &[3], 1), 1);
        assert_eq!(ev(PrimOp::Andr, &[0b101], &[3], 1), 0);
        assert_eq!(ev(PrimOp::Orr, &[0], &[3], 1), 0);
        assert_eq!(ev(PrimOp::Xorr, &[0b110], &[3], 1), 0);
        assert_eq!(ev(PrimOp::Xorr, &[0b100], &[3], 1), 1);
    }

    #[test]
    fn shifts_and_slices() {
        assert_eq!(ev(PrimOp::Shl(2), &[0b11], &[2], 4), 0b1100);
        assert_eq!(ev(PrimOp::Shr(1), &[0b110], &[3], 2), 0b11);
        assert_eq!(ev(PrimOp::Dshl, &[1, 70], &[4, 8], 4), 0); // overshift
        assert_eq!(ev(PrimOp::Bits(3, 1), &[0b1010], &[4], 3), 0b101);
        assert_eq!(ev(PrimOp::Head(2), &[0b1011], &[4], 2), 0b10);
        assert_eq!(ev(PrimOp::Tail(1), &[0b1011], &[4], 3), 0b011);
    }

    #[test]
    fn cat_orders_high_low() {
        assert_eq!(ev(PrimOp::Cat, &[0b10, 0b01], &[2, 2], 4), 0b1001);
    }

    #[test]
    fn mux_and_chain() {
        assert_eq!(ev(PrimOp::Mux, &[1, 5, 9], &[1, 4, 4], 4), 5);
        assert_eq!(ev(PrimOp::Mux, &[0, 5, 9], &[1, 4, 4], 4), 9);
        // chain: sel0=0, sel1=1 -> v1; default otherwise
        let args = [0u64, 10, 1, 11, 99];
        let widths = [1u8, 4, 1, 4, 7];
        assert_eq!(ev(PrimOp::MuxChain(2), &args, &widths, 7), 11);
        let args = [0u64, 10, 0, 11, 99];
        assert_eq!(ev(PrimOp::MuxChain(2), &args, &widths, 7), 99);
        // first-true-wins
        let args = [1u64, 10, 1, 11, 99];
        assert_eq!(ev(PrimOp::MuxChain(2), &args, &widths, 7), 10);
    }

    #[test]
    fn classes() {
        assert_eq!(PrimOp::Add.class(), OpClass::Reducible);
        assert_eq!(PrimOp::Not.class(), OpClass::Unary);
        assert_eq!(PrimOp::Mux.class(), OpClass::Select);
        assert_eq!(PrimOp::MuxChain(3).class(), OpClass::Select);
    }

    #[test]
    fn widths() {
        assert_eq!(result_width(PrimOp::Add, &[3, 5]), 6);
        assert_eq!(result_width(PrimOp::Cat, &[3, 5]), 8);
        assert_eq!(result_width(PrimOp::Bits(4, 2), &[8]), 3);
        assert_eq!(result_width(PrimOp::Mul, &[40, 40]), 64); // clamped
    }

    #[test]
    fn width64_edge_cases() {
        assert_eq!(mask(64), u64::MAX);
        assert_eq!(ev(PrimOp::Add, &[u64::MAX, 1], &[64, 64], 64), 0);
        assert_eq!(ev(PrimOp::Not, &[0], &[64], 64), u64::MAX);
        assert_eq!(ev(PrimOp::Andr, &[u64::MAX], &[64], 1), 1);
    }
}
