//! Copy propagation (paper §B.1, data level): forward uses of `Id` nodes to
//! their sources, so the identities inserted during cascade construction —
//! and any copies left by other passes — never cost an operation.
//!
//! Only width-preserving copies are forwarded: downstream ops like `cat`,
//! `head` and `andr` consume argument *widths*, so forwarding a node of a
//! different width would change semantics.

use crate::graph::ops::PrimOp;
use crate::graph::{Graph, NodeKind};

pub fn run(g: &Graph) -> Graph {
    super::rewrite(g, |rw, g, id| {
        let node = &g.nodes[id as usize];
        if let NodeKind::Prim(PrimOp::Id) = node.kind {
            let src_new = rw.map[node.args[0] as usize];
            if rw.out.width(src_new) == node.width {
                return src_new; // forward; never emitted
            }
        }
        rw.emit_default(g, id)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ops::PrimOp;
    use crate::graph::{Graph, RefSim};

    #[test]
    fn forwards_chained_ids() {
        let mut g = Graph::new("t");
        let a = g.input("a", 8);
        let i1 = g.prim(PrimOp::Id, &[a]);
        let i2 = g.prim(PrimOp::Id, &[i1]);
        let r = g.prim(PrimOp::Not, &[i2]);
        g.output("o", r);
        let out = run(&g);
        // both ids gone
        assert_eq!(out.num_ops(), 1);
        let mut s1 = RefSim::new(g);
        let mut s2 = RefSim::new(out);
        s1.step(&[0x5A]);
        s2.step(&[0x5A]);
        assert_eq!(s1.outputs(), s2.outputs());
    }

    #[test]
    fn keeps_width_changing_copy() {
        let mut g = Graph::new("t");
        let a = g.input("a", 4);
        // Id with an artificially widened width must not be forwarded
        let w = g.prim_w(PrimOp::Id, &[a], 8);
        let c = g.prim(PrimOp::Cat, &[a, w]); // cat depends on arg width 8
        g.output("o", c);
        let out = run(&g);
        assert_eq!(out.num_ops(), 2);
    }
}
