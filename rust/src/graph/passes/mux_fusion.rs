//! Operator fusion (paper §B.1, cascade level): extract mux chains —
//! priority-select ladders of the form
//! `mux(s0, v0, mux(s1, v1, mux(s2, v2, d)))` — into a single fused
//! [`PrimOp::MuxChain`] operation. Real designs are dominated by these
//! ladders (`when`/`elsewhen` lowering), and fusing them removes the
//! intermediate layer-to-layer traffic the paper attributes to mux chains.

use crate::graph::ops::PrimOp;
use crate::graph::{Graph, NodeId, NodeKind};

/// Maximum fused chain length (keeps `MuxChain` arity bounded).
pub const MAX_CHAIN: usize = 24;

pub fn run(g: &Graph) -> Graph {
    let uses = super::use_counts(g);
    // A mux is *absorbable* if it is the false-arm of exactly one user and
    // nothing else observes it.
    let is_mux = |id: NodeId| matches!(g.nodes[id as usize].kind, NodeKind::Prim(PrimOp::Mux));

    super::rewrite(g, |rw, g, id| {
        let node = &g.nodes[id as usize];
        if !matches!(node.kind, NodeKind::Prim(PrimOp::Mux)) {
            return rw.emit_default(g, id);
        }
        // Walk the false-arm chain in the *old* graph.
        let mut sels_vals: Vec<(NodeId, NodeId)> = vec![(node.args[0], node.args[1])];
        let mut tail = node.args[2];
        while is_mux(tail) && uses[tail as usize] == 1 && sels_vals.len() < MAX_CHAIN {
            let t = &g.nodes[tail as usize];
            sels_vals.push((t.args[0], t.args[1]));
            tail = t.args[2];
        }
        if sels_vals.len() < 2 {
            return rw.emit_default(g, id);
        }
        let mut new_args: Vec<NodeId> = Vec::with_capacity(sels_vals.len() * 2 + 1);
        for (s, v) in &sels_vals {
            new_args.push(rw.map[*s as usize]);
            new_args.push(rw.map[*v as usize]);
        }
        new_args.push(rw.map[tail as usize]);
        let fused = rw.out.prim_w(PrimOp::MuxChain(sels_vals.len() as u8), &new_args, node.width);
        if let Some(name) = &node.name {
            rw.out.name_node(fused, name);
        }
        fused
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::passes::dce;
    use crate::graph::{builder, Graph, RefSim};
    use crate::util::prng::Rng;

    fn ladder(depth: usize) -> Graph {
        let mut g = Graph::new("ladder");
        let mut sels = Vec::new();
        let mut vals = Vec::new();
        for i in 0..depth {
            sels.push(g.input(&format!("s{i}"), 1));
            vals.push(g.input(&format!("v{i}"), 8));
        }
        let d = g.input("d", 8);
        let mut cur = d;
        for i in (0..depth).rev() {
            cur = g.prim(PrimOp::Mux, &[sels[i], vals[i], cur]);
        }
        g.output("o", cur);
        g
    }

    #[test]
    fn fuses_ladder_into_single_chain() {
        let g = ladder(5);
        let fused = dce::run(&run(&g));
        assert_eq!(fused.num_ops(), 1);
        match fused.nodes.iter().find_map(|n| match n.kind {
            NodeKind::Prim(PrimOp::MuxChain(k)) => Some(k),
            _ => None,
        }) {
            Some(k) => assert_eq!(k, 5),
            None => panic!("no MuxChain produced"),
        }
    }

    #[test]
    fn chain_semantics_match() {
        let g = ladder(6);
        let fused = dce::run(&run(&g));
        let mut rng = Rng::new(17);
        let mut s1 = RefSim::new(g);
        let mut s2 = RefSim::new(fused);
        for _ in 0..40 {
            let inputs = builder::random_inputs(&mut rng, &s1.graph);
            s1.step(&inputs);
            s2.step(&inputs);
            assert_eq!(s1.outputs(), s2.outputs());
        }
    }

    #[test]
    fn shared_inner_mux_not_fused() {
        // The inner mux has two users -> must stay separate.
        let mut g = Graph::new("t");
        let s0 = g.input("s0", 1);
        let s1 = g.input("s1", 1);
        let a = g.input("a", 4);
        let b = g.input("b", 4);
        let inner = g.prim(PrimOp::Mux, &[s1, a, b]);
        let outer = g.prim(PrimOp::Mux, &[s0, b, inner]);
        g.output("o1", outer);
        g.output("o2", inner); // second use
        let fused = run(&g);
        assert_eq!(
            fused.nodes.iter().filter(|n| matches!(n.kind, NodeKind::Prim(PrimOp::Mux))).count(),
            2
        );
    }
}
