//! Constant folding / propagation (paper §6.1: "classical optimizations,
//! e.g. constant propagation, as a means to optimize the OIM").
//!
//! Folds primitive ops whose operands are all constants, and resolves muxes
//! with constant selectors (forwarding the surviving branch when widths
//! allow, otherwise via an explicit `Pad`).

use crate::graph::ops::{eval_prim, PrimOp};
use crate::graph::{Graph, NodeKind};

pub fn run(g: &Graph) -> Graph {
    super::rewrite(g, |rw, g, id| {
        let node = &g.nodes[id as usize];
        let NodeKind::Prim(op) = node.kind else {
            return rw.emit_default(g, id);
        };
        // Gather new-graph operand info.
        let new_args: Vec<_> = node.args.iter().map(|&a| rw.map[a as usize]).collect();
        let consts: Option<Vec<u64>> = new_args
            .iter()
            .map(|&a| match rw.out.nodes[a as usize].kind {
                NodeKind::Const(c) => Some(c),
                _ => None,
            })
            .collect();
        if let Some(vals) = consts {
            let widths: Vec<u8> = new_args.iter().map(|&a| rw.out.width(a)).collect();
            let v = eval_prim(op, &vals, &widths, node.width);
            return rw.out.konst(v, node.width);
        }
        // Mux with constant selector: keep only the taken branch.
        if op == PrimOp::Mux {
            if let NodeKind::Const(sel) = rw.out.nodes[new_args[0] as usize].kind {
                let taken = if sel != 0 { new_args[1] } else { new_args[2] };
                let tw = rw.out.width(taken);
                if tw == node.width {
                    return taken;
                } else if tw < node.width {
                    return rw.out.prim_w(PrimOp::Pad(node.width), &[taken], node.width);
                }
                // taken wider than mux result cannot happen (mux width =
                // max of branches) — fall through defensively.
            }
        }
        // Algebraic simplifications that need only one constant operand.
        if new_args.len() == 2 {
            let c0 = matches!(rw.out.nodes[new_args[0] as usize].kind, NodeKind::Const(0));
            let c1 = matches!(rw.out.nodes[new_args[1] as usize].kind, NodeKind::Const(0));
            match op {
                PrimOp::And if c0 || c1 => return rw.out.konst(0, node.width),
                PrimOp::Mul if c0 || c1 => return rw.out.konst(0, node.width),
                _ => {}
            }
        }
        rw.emit_default(g, id)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Graph, NodeKind, RefSim};

    #[test]
    fn folds_constant_tree() {
        let mut g = Graph::new("t");
        let a = g.konst(3, 4);
        let b = g.konst(5, 4);
        let s = g.prim(PrimOp::Add, &[a, b]); // 8
        let m = g.prim(PrimOp::Mul, &[s, b]); // 40
        g.output("o", m);
        let out = run(&g);
        assert_eq!(out.num_ops(), 0);
        let (_, o) = &out.outputs[0];
        assert!(matches!(out.nodes[*o as usize].kind, NodeKind::Const(40)));
    }

    #[test]
    fn const_mux_selector() {
        let mut g = Graph::new("t");
        let sel = g.konst(1, 1);
        let a = g.input("a", 8);
        let b = g.input("b", 8);
        let m = g.prim(PrimOp::Mux, &[sel, a, b]);
        g.output("o", m);
        let out = run(&g);
        assert_eq!(out.num_ops(), 0);
        let mut s = RefSim::new(out);
        s.step(&[7, 9]);
        assert_eq!(s.outputs()[0].1, 7);
    }

    #[test]
    fn and_with_zero() {
        let mut g = Graph::new("t");
        let z = g.konst(0, 8);
        let a = g.input("a", 8);
        let m = g.prim(PrimOp::And, &[a, z]);
        g.output("o", m);
        let out = run(&g);
        assert_eq!(out.num_ops(), 0);
    }

    #[test]
    fn semantics_preserved_on_partial_consts() {
        let mut g = Graph::new("t");
        let a = g.input("a", 8);
        let c = g.konst(12, 8);
        let s = g.prim_w(PrimOp::Add, &[a, c], 8);
        g.output("o", s);
        let out = run(&g);
        let mut s1 = RefSim::new(g);
        let mut s2 = RefSim::new(out);
        s1.step(&[30]);
        s2.step(&[30]);
        assert_eq!(s1.outputs(), s2.outputs());
    }
}
