//! Dataflow-graph optimization passes (paper §6.1 and Box 1, bold entries).
//!
//! * [`copy_prop`] — copy propagation (data level)
//! * [`const_fold`] — constant folding/propagation (data level)
//! * [`cse`] — common-subexpression elimination (data level; enables the
//!   dedup-style reuse described in Box 1)
//! * [`mux_fusion`] — mux-chain extraction (cascade-level operator fusion)
//! * [`dce`] — dead code elimination
//!
//! Every pass is a semantics-preserving graph→graph rewrite (property-tested
//! against the reference interpreter in `tests/passes_equiv.rs`).

pub mod const_fold;
pub mod copy_prop;
pub mod cse;
pub mod dce;
pub mod mux_fusion;

use super::{Graph, NodeId, NodeKind};

/// Shared machinery for streaming rewrites over a graph in topological
/// (node-id) order. Keeps port/register indices consistent in the output.
pub struct Rewriter {
    pub out: Graph,
    /// old node id -> new node id
    pub map: Vec<NodeId>,
}

impl Rewriter {
    pub fn new(g: &Graph) -> Self {
        let out = Graph::new(&g.name);
        Rewriter { out, map: Vec::with_capacity(g.nodes.len()) }
    }

    /// Default translation of a node: push an equivalent node into `out`
    /// with remapped args. Sources keep their port/register index spaces
    /// dense and in order.
    pub fn emit_default(&mut self, g: &Graph, id: NodeId) -> NodeId {
        let node = &g.nodes[id as usize];
        let new_args: Vec<NodeId> = node.args.iter().map(|&a| self.map[a as usize]).collect();
        match node.kind {
            NodeKind::Const(c) => self.out.konst(c, node.width),
            NodeKind::Input(_) => {
                let name = node.name.as_deref().unwrap_or("in");
                self.out.input(name, node.width)
            }
            NodeKind::Reg(r) => {
                let def = &g.regs[r as usize];
                self.out.reg(&def.name, def.width, def.init)
            }
            NodeKind::Prim(op) => {
                let nid = self.out.prim_w(op, &new_args, node.width);
                if let Some(name) = &node.name {
                    self.out.name_node(nid, name);
                }
                nid
            }
        }
    }

    /// Finish: connect registers and outputs through the map.
    /// `reg_live` optionally drops registers (DCE); inputs are always kept.
    pub fn finish(mut self, g: &Graph) -> Graph {
        // regs were re-created in order by emit; connect their nexts
        for (ri, def) in g.regs.iter().enumerate() {
            // find the new reg node via the map of its old node
            let new_node = self.map[def.node as usize];
            if let NodeKind::Reg(new_ri) = self.out.nodes[new_node as usize].kind {
                let _ = ri;
                let new_next = self.map[def.next as usize];
                self.out.regs[new_ri as usize].next = new_next;
            }
        }
        for (name, o) in &g.outputs {
            let new_o = self.map[*o as usize];
            self.out.outputs.push((name.clone(), new_o));
        }
        self.out
    }
}

/// Streaming rewrite: `f(rw, g, id)` must return the new node id for `id`
/// (either by emitting or by forwarding to an existing new node).
pub fn rewrite(g: &Graph, mut f: impl FnMut(&mut Rewriter, &Graph, NodeId) -> NodeId) -> Graph {
    let mut rw = Rewriter::new(g);
    for id in 0..g.nodes.len() as NodeId {
        let new_id = f(&mut rw, g, id);
        rw.map.push(new_id);
    }
    rw.finish(g)
}

/// Count uses of each node (args + register nexts + outputs).
pub fn use_counts(g: &Graph) -> Vec<u32> {
    let mut uses = vec![0u32; g.nodes.len()];
    for n in &g.nodes {
        for &a in &n.args {
            uses[a as usize] += 1;
        }
    }
    for r in &g.regs {
        uses[r.next as usize] += 1;
    }
    for (_, o) in &g.outputs {
        uses[*o as usize] += 1;
    }
    uses
}

/// Per-pass statistics for compile reports.
#[derive(Debug, Clone)]
pub struct PassReport {
    pub pass: &'static str,
    pub nodes_before: usize,
    pub nodes_after: usize,
}

/// The standard optimization pipeline (paper Fig 14, "dataflow graph
/// optimizations"). Returns the optimized graph plus a per-pass report.
pub fn optimize(g: &Graph) -> (Graph, Vec<PassReport>) {
    let mut reports = Vec::new();
    let mut cur = g.clone();
    // Two rounds: folding exposes copies, CSE exposes dead code, and
    // mux fusion benefits from a cleaned graph.
    for round in 0..2 {
        for (name, pass) in [
            ("copy_prop", copy_prop::run as fn(&Graph) -> Graph),
            ("const_fold", const_fold::run),
            ("cse", cse::run),
            ("mux_fusion", mux_fusion::run),
            ("dce", dce::run),
        ] {
            // mux fusion only on the final round so CSE sees plain muxes
            if name == "mux_fusion" && round == 0 {
                continue;
            }
            let before = cur.nodes.len();
            cur = pass(&cur);
            reports.push(PassReport { pass: name, nodes_before: before, nodes_after: cur.nodes.len() });
            debug_assert!(cur.validate().is_empty(), "{name} broke the graph: {:?}", cur.validate());
        }
    }
    (cur, reports)
}

/// Lightweight pipeline used where mux fusion must be disabled (e.g.
/// waveform mode keeps individual muxes visible).
pub fn optimize_no_fusion(g: &Graph) -> Graph {
    let mut cur = copy_prop::run(g);
    cur = const_fold::run(&cur);
    cur = cse::run(&cur);
    dce::run(&cur)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::{random_circuit, random_inputs};
    use crate::graph::RefSim;
    use crate::util::prng::Rng;

    /// The full pipeline must preserve I/O behaviour on random circuits.
    #[test]
    fn optimize_preserves_semantics() {
        for seed in 0..12 {
            let mut rng = Rng::new(100 + seed);
            let g = random_circuit(&mut rng, 80);
            let (opt, _) = optimize(&g);
            assert!(opt.validate().is_empty());
            let mut a = RefSim::new(g);
            let mut b = RefSim::new(opt);
            for cycle in 0..16 {
                let inputs = random_inputs(&mut rng, &a.graph);
                a.step(&inputs);
                b.step(&inputs);
                assert_eq!(a.outputs(), b.outputs(), "seed {seed} cycle {cycle}");
            }
        }
    }

    #[test]
    fn optimize_reduces_node_count() {
        let mut rng = Rng::new(7);
        let g = random_circuit(&mut rng, 200);
        let (opt, reports) = optimize(&g);
        assert!(opt.nodes.len() <= g.nodes.len());
        assert!(!reports.is_empty());
    }
}
