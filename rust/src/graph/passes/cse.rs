//! Common-subexpression elimination. Structurally identical nodes (same op,
//! same already-deduplicated operands, same width) are merged. This is the
//! per-node form of the "instance reuse" idea in Box 1: identical logic is
//! represented once in the OIM.

use std::collections::HashMap;

use crate::graph::{Graph, NodeId, NodeKind};

#[derive(Hash, PartialEq, Eq)]
enum Key {
    Const(u64, u8),
    Prim(crate::graph::ops::PrimOp, Vec<NodeId>, u8),
}

pub fn run(g: &Graph) -> Graph {
    let mut seen: HashMap<Key, NodeId> = HashMap::new();
    super::rewrite(g, |rw, g, id| {
        let node = &g.nodes[id as usize];
        let key = match node.kind {
            NodeKind::Const(c) => Key::Const(c, node.width),
            NodeKind::Prim(op) => {
                let new_args: Vec<NodeId> = node.args.iter().map(|&a| rw.map[a as usize]).collect();
                Key::Prim(op, new_args, node.width)
            }
            // Never merge inputs/registers: they are distinct state.
            _ => return rw.emit_default(g, id),
        };
        if let Some(&existing) = seen.get(&key) {
            return existing;
        }
        let new_id = rw.emit_default(g, id);
        seen.insert(key, new_id);
        new_id
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ops::PrimOp;
    use crate::graph::{Graph, RefSim};

    #[test]
    fn merges_identical_subtrees() {
        let mut g = Graph::new("t");
        let a = g.input("a", 8);
        let b = g.input("b", 8);
        let x1 = g.prim_w(PrimOp::Add, &[a, b], 8);
        let x2 = g.prim_w(PrimOp::Add, &[a, b], 8);
        let y = g.prim_w(PrimOp::Xor, &[x1, x2], 8);
        g.output("o", y);
        let out = run(&g);
        assert_eq!(out.num_ops(), 2); // one add + the xor
        let mut s1 = RefSim::new(g);
        let mut s2 = RefSim::new(out);
        s1.step(&[3, 9]);
        s2.step(&[3, 9]);
        assert_eq!(s1.outputs(), s2.outputs());
    }

    #[test]
    fn does_not_merge_different_widths() {
        let mut g = Graph::new("t");
        let a = g.input("a", 8);
        let x1 = g.prim_w(PrimOp::Not, &[a], 8);
        let x2 = g.prim_w(PrimOp::Not, &[a], 4); // different width
        let y = g.prim(PrimOp::Cat, &[x1, x2]);
        g.output("o", y);
        let out = run(&g);
        assert_eq!(out.num_ops(), 3);
    }

    #[test]
    fn merges_duplicate_constants() {
        let mut g = Graph::new("t");
        let c1 = g.konst(7, 4);
        let c2 = g.konst(7, 4);
        let s = g.prim(PrimOp::Add, &[c1, c2]);
        g.output("o", s);
        let out = run(&g);
        // both constants collapse to one node
        let n_consts =
            out.nodes.iter().filter(|n| matches!(n.kind, crate::graph::NodeKind::Const(_))).count();
        assert_eq!(n_consts, 1);
    }
}
