//! Dead-code elimination: drop nodes not reachable from outputs or from the
//! next-state logic of live registers. Input ports are always preserved
//! (they are the module interface); registers are dropped when nothing
//! observable depends on them.

use crate::graph::{Graph, NodeId, NodeKind};

pub fn run(g: &Graph) -> Graph {
    let n = g.nodes.len();
    let mut live = vec![false; n];
    let mut stack: Vec<NodeId> = Vec::new();

    let mark = |id: NodeId, live: &mut Vec<bool>, stack: &mut Vec<NodeId>| {
        if !live[id as usize] {
            live[id as usize] = true;
            stack.push(id);
        }
    };

    for (_, o) in &g.outputs {
        mark(*o, &mut live, &mut stack);
    }
    // Inputs are interface: live by definition.
    for p in &g.inputs {
        mark(p.node, &mut live, &mut stack);
    }
    while let Some(id) = stack.pop() {
        let node = &g.nodes[id as usize];
        for &a in &node.args {
            mark(a, &mut live, &mut stack);
        }
        // A live register keeps its next-state cone alive.
        if let NodeKind::Reg(r) = node.kind {
            mark(g.regs[r as usize].next, &mut live, &mut stack);
        }
    }

    // Rebuild with only live nodes. Maps dead nodes to u32::MAX (never read).
    let mut out = Graph::new(&g.name);
    let mut map = vec![u32::MAX; n];
    for id in 0..n {
        if !live[id] {
            continue;
        }
        let node = &g.nodes[id];
        let new_id = match node.kind {
            NodeKind::Const(c) => out.konst(c, node.width),
            NodeKind::Input(_) => out.input(node.name.as_deref().unwrap_or("in"), node.width),
            NodeKind::Reg(r) => {
                let def = &g.regs[r as usize];
                out.reg(&def.name, def.width, def.init)
            }
            NodeKind::Prim(op) => {
                let args: Vec<NodeId> = node.args.iter().map(|&a| map[a as usize]).collect();
                let nid = out.prim_w(op, &args, node.width);
                if let Some(name) = &node.name {
                    out.name_node(nid, name);
                }
                nid
            }
        };
        map[id] = new_id;
    }
    // Reconnect live registers.
    for def in &g.regs {
        if live[def.node as usize] {
            let new_node = map[def.node as usize];
            if let NodeKind::Reg(new_ri) = out.nodes[new_node as usize].kind {
                out.regs[new_ri as usize].next = map[def.next as usize];
            }
        }
    }
    for (name, o) in &g.outputs {
        out.outputs.push((name.clone(), map[*o as usize]));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ops::PrimOp;
    use crate::graph::{Graph, RefSim};

    #[test]
    fn drops_unreachable_ops() {
        let mut g = Graph::new("t");
        let a = g.input("a", 8);
        let _dead = g.prim(PrimOp::Not, &[a]);
        let live = g.prim(PrimOp::Neg, &[a]);
        g.output("o", live);
        let out = run(&g);
        assert_eq!(out.num_ops(), 1);
    }

    #[test]
    fn keeps_register_feedback_cones() {
        let mut g = Graph::new("t");
        let r = g.reg("r", 8, 1);
        let one = g.konst(1, 8);
        let nxt = g.prim_w(PrimOp::Add, &[r, one], 8);
        g.connect_reg(r, nxt);
        g.output("o", r);
        let out = run(&g);
        assert_eq!(out.num_ops(), 1);
        assert_eq!(out.regs.len(), 1);
        let mut s = RefSim::new(out);
        s.step(&[]);
        s.step(&[]);
        assert_eq!(s.outputs()[0].1, 3);
    }

    #[test]
    fn drops_unobserved_register() {
        let mut g = Graph::new("t");
        let a = g.input("a", 8);
        let r = g.reg("dead_reg", 8, 0);
        let nxt = g.prim_w(PrimOp::Add, &[r, a], 8);
        g.connect_reg(r, nxt);
        g.output("o", a); // register never observed
        let out = run(&g);
        assert_eq!(out.regs.len(), 0);
        assert_eq!(out.num_ops(), 0);
    }
}
