//! Random circuit generation for property-based testing, plus small
//! hand-rolled building blocks shared by the synthetic design generators.

use super::ops::PrimOp;
use super::{Graph, NodeId};
use crate::util::prng::Rng;

/// Generate a random synchronous circuit with roughly `size` primitive ops.
///
/// The generator only produces valid graphs (operands before users, widths
/// inferred) and biases towards the op mix found in real designs: heavy on
/// mux/bit-select/logic, lighter on arithmetic — mirroring the paper's
/// observation that mux chains dominate (§6.1, operator fusion).
pub fn random_circuit(rng: &mut Rng, size: usize) -> Graph {
    let mut g = Graph::new("random");
    let n_inputs = 1 + rng.index(4);
    let n_regs = 1 + rng.index(4.max(size / 4));
    for i in 0..n_inputs {
        let w = 1 + rng.index(16) as u8;
        g.input(&format!("in{i}"), w);
    }
    let mut regs = Vec::new();
    for i in 0..n_regs {
        let w = 1 + rng.index(16) as u8;
        let init = rng.bits(w);
        regs.push(g.reg(&format!("r{i}"), w, init));
    }
    // a couple of constants to seed the pool
    let mut pool: Vec<NodeId> = (0..g.nodes.len() as NodeId).collect();
    for _ in 0..3 {
        let w = 1 + rng.index(12) as u8;
        let v = rng.bits(w);
        pool.push(g.konst(v, w));
    }

    let n_ops = size.max(1);
    for _ in 0..n_ops {
        let id = random_op(&mut g, rng, &pool);
        pool.push(id);
    }

    // connect registers to random pool nodes (width-adapted)
    for &r in &regs {
        let src = *rng.pick(&pool);
        let rw = g.width(r);
        let adapted = adapt_width(&mut g, src, rw);
        g.connect_reg(r, adapted);
    }
    // a few outputs
    let n_out = 1 + rng.index(3);
    for i in 0..n_out {
        let src = *rng.pick(&pool);
        g.output(&format!("out{i}"), src);
    }
    debug_assert!(g.validate().is_empty(), "random_circuit invalid: {:?}", g.validate());
    g
}

/// Append one random primitive op reading from `pool`.
fn random_op(g: &mut Graph, rng: &mut Rng, pool: &[NodeId]) -> NodeId {
    // Weighted op selection (mux/bits/logic-heavy).
    let roll = rng.index(100);
    let a = *rng.pick(pool);
    let b = *rng.pick(pool);
    let wa = g.width(a);
    match roll {
        0..=17 => {
            // mux
            let sel_src = *rng.pick(pool);
            let sel = bit_of(g, rng, sel_src);
            let fv = adapt_width(g, b, wa);
            g.prim(PrimOp::Mux, &[sel, a, fv])
        }
        18..=29 => {
            // bits extract
            let hi = rng.index(wa as usize) as u8;
            let lo = rng.index(hi as usize + 1) as u8;
            g.prim(PrimOp::Bits(hi, lo), &[a])
        }
        30..=43 => {
            let op = *rng.pick(&[PrimOp::And, PrimOp::Or, PrimOp::Xor]);
            let b = adapt_width(g, b, wa);
            g.prim(op, &[a, b])
        }
        44..=57 => {
            let op = *rng.pick(&[PrimOp::Add, PrimOp::Sub]);
            g.prim(op, &[a, b])
        }
        58..=61 => {
            if wa.saturating_add(g.width(b)) <= 64 {
                g.prim(PrimOp::Mul, &[a, b])
            } else {
                let bw = adapt_width(g, b, wa);
                g.prim(PrimOp::Xor, &[a, bw])
            }
        }
        62..=65 => {
            let op = *rng.pick(&[PrimOp::Div, PrimOp::Rem]);
            g.prim(op, &[a, b])
        }
        66..=73 => {
            let op = *rng.pick(&[PrimOp::Eq, PrimOp::Neq, PrimOp::Lt, PrimOp::Leq, PrimOp::Gt, PrimOp::Geq]);
            g.prim(op, &[a, b])
        }
        74..=79 => {
            let op = *rng.pick(&[PrimOp::Not, PrimOp::Neg]);
            g.prim(op, &[a])
        }
        80..=83 => {
            let op = *rng.pick(&[PrimOp::Andr, PrimOp::Orr, PrimOp::Xorr]);
            g.prim(op, &[a])
        }
        84..=88 => {
            let n = rng.index(8) as u8 + 1;
            if wa + n <= 64 {
                g.prim(PrimOp::Shl(n), &[a])
            } else {
                g.prim(PrimOp::Shr(n.min(wa - 1)), &[a])
            }
        }
        89..=92 => {
            let n = rng.index(wa as usize) as u8;
            g.prim(PrimOp::Shr(n), &[a])
        }
        93..=95 => {
            if wa as usize + g.width(b) as usize <= 64 {
                g.prim(PrimOp::Cat, &[a, b])
            } else {
                g.prim(PrimOp::Id, &[a])
            }
        }
        96..=97 => {
            let amt = g.konst(rng.index(wa as usize) as u64, 6.min(wa).max(1));
            g.prim(PrimOp::Dshr, &[a, amt])
        }
        _ => {
            let n = (wa + rng.index(4) as u8 + 1).min(64);
            g.prim(PrimOp::Pad(n), &[a])
        }
    }
}

/// Reduce or widen `id` to exactly `w` bits.
pub fn adapt_width(g: &mut Graph, id: NodeId, w: u8) -> NodeId {
    let cur = g.width(id);
    if cur == w {
        id
    } else if cur > w {
        g.prim(PrimOp::Bits(w - 1, 0), &[id])
    } else {
        g.prim_w(PrimOp::Pad(w), &[id], w)
    }
}

/// A 1-bit view of `id` (its LSB or an orr-reduction).
pub fn bit_of(g: &mut Graph, rng: &mut Rng, id: NodeId) -> NodeId {
    if g.width(id) == 1 {
        id
    } else if rng.chance(0.5) {
        g.prim(PrimOp::Bits(0, 0), &[id])
    } else {
        g.prim(PrimOp::Orr, &[id])
    }
}

/// Random input stimulus for a graph.
pub fn random_inputs(rng: &mut Rng, g: &Graph) -> Vec<u64> {
    g.inputs.iter().map(|p| rng.bits(p.width)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::RefSim;

    #[test]
    fn random_circuits_are_valid_and_run() {
        for seed in 0..20 {
            let mut rng = Rng::new(seed);
            let g = random_circuit(&mut rng, 40);
            assert!(g.validate().is_empty(), "seed {seed}: {:?}", g.validate());
            let mut sim = RefSim::new(g);
            for _ in 0..8 {
                let inputs = random_inputs(&mut rng, &sim.graph);
                sim.step(&inputs);
            }
        }
    }

    #[test]
    fn sizes_scale() {
        let mut rng = Rng::new(1);
        let small = random_circuit(&mut rng, 10);
        let big = random_circuit(&mut rng, 500);
        assert!(big.num_ops() > small.num_ops() * 5);
    }
}
