//! Dataflow-graph IR.
//!
//! A [`Graph`] is the levelizable dataflow graph of Figure 1 (middle): nodes
//! are primitive operations or sources (constants, input ports, registers);
//! edges are the `args` lists. Node ids are assigned in topological order by
//! construction (builders must create operands before users), which the
//! reference interpreter and levelization rely on; [`Graph::validate`]
//! checks the invariant.

pub mod ops;
pub mod builder;
pub mod cone;
pub mod passes;
pub mod levelize;

use ops::{eval_prim, mask, result_width, PrimOp};

pub type NodeId = u32;

/// What a node computes.
#[derive(Clone, Debug, PartialEq)]
pub enum NodeKind {
    /// A literal.
    Const(u64),
    /// Input port (index into `Graph::inputs`).
    Input(u32),
    /// Register output (index into `Graph::regs`).
    Reg(u32),
    /// Primitive operation over `args`.
    Prim(PrimOp),
}

/// A dataflow node.
#[derive(Clone, Debug)]
pub struct Node {
    pub kind: NodeKind,
    pub args: Vec<NodeId>,
    pub width: u8,
    /// Optional signal name (ports, registers, named wires — kept for VCD).
    pub name: Option<Box<str>>,
}

impl Node {
    pub fn is_source(&self) -> bool {
        matches!(self.kind, NodeKind::Const(_) | NodeKind::Input(_) | NodeKind::Reg(_))
    }
}

/// Register definition.
#[derive(Clone, Debug)]
pub struct RegDef {
    /// The node representing this register's current value.
    pub node: NodeId,
    /// The node computing the next state (hooked up after creation).
    pub next: NodeId,
    pub init: u64,
    pub width: u8,
    pub name: String,
}

/// Input port definition.
#[derive(Clone, Debug)]
pub struct PortDef {
    pub name: String,
    pub width: u8,
    pub node: NodeId,
}

/// A synchronous, single-clock dataflow graph.
#[derive(Clone, Debug, Default)]
pub struct Graph {
    pub name: String,
    pub nodes: Vec<Node>,
    pub inputs: Vec<PortDef>,
    pub outputs: Vec<(String, NodeId)>,
    pub regs: Vec<RegDef>,
}

impl Graph {
    pub fn new(name: &str) -> Self {
        Graph { name: name.to_string(), ..Default::default() }
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id as usize]
    }

    pub fn width(&self, id: NodeId) -> u8 {
        self.nodes[id as usize].width
    }

    fn push(&mut self, node: Node) -> NodeId {
        let id = self.nodes.len() as NodeId;
        self.nodes.push(node);
        id
    }

    /// Add a constant literal of `width` bits.
    pub fn konst(&mut self, value: u64, width: u8) -> NodeId {
        debug_assert_eq!(value & mask(width), value, "constant wider than declared");
        self.push(Node { kind: NodeKind::Const(value & mask(width)), args: vec![], width, name: None })
    }

    /// Add an input port.
    pub fn input(&mut self, name: &str, width: u8) -> NodeId {
        let idx = self.inputs.len() as u32;
        let id = self.push(Node {
            kind: NodeKind::Input(idx),
            args: vec![],
            width,
            name: Some(name.into()),
        });
        self.inputs.push(PortDef { name: name.to_string(), width, node: id });
        id
    }

    /// Add a register (next-state connected later via [`Graph::connect_reg`]).
    pub fn reg(&mut self, name: &str, width: u8, init: u64) -> NodeId {
        let idx = self.regs.len() as u32;
        let id = self.push(Node {
            kind: NodeKind::Reg(idx),
            args: vec![],
            width,
            name: Some(name.into()),
        });
        self.regs.push(RegDef { node: id, next: id, init: init & mask(width), width, name: name.to_string() });
        id
    }

    /// Connect a register's next-state input.
    pub fn connect_reg(&mut self, reg_node: NodeId, next: NodeId) {
        let idx = match self.nodes[reg_node as usize].kind {
            NodeKind::Reg(i) => i,
            _ => panic!("connect_reg on non-register node"),
        };
        self.regs[idx as usize].next = next;
    }

    /// Add a primitive op node; width is inferred by FIRRTL rules.
    pub fn prim(&mut self, op: PrimOp, args: &[NodeId]) -> NodeId {
        debug_assert_eq!(args.len(), op.arity(), "{op:?} expects {} args", op.arity());
        let widths: Vec<u8> = args.iter().map(|&a| self.width(a)).collect();
        let width = result_width(op, &widths);
        self.prim_w(op, args, width)
    }

    /// Add a primitive op node with an explicit result width.
    pub fn prim_w(&mut self, op: PrimOp, args: &[NodeId], width: u8) -> NodeId {
        for &a in args {
            debug_assert!((a as usize) < self.nodes.len(), "arg created after use");
        }
        self.push(Node { kind: NodeKind::Prim(op), args: args.to_vec(), width, name: None })
    }

    /// Name an existing node (for waveforms).
    pub fn name_node(&mut self, id: NodeId, name: &str) {
        self.nodes[id as usize].name = Some(name.into());
    }

    /// Mark a node as a design output.
    pub fn output(&mut self, name: &str, id: NodeId) {
        self.outputs.push((name.to_string(), id));
    }

    /// Number of primitive (effectual) operations.
    pub fn num_ops(&self) -> usize {
        self.nodes.iter().filter(|n| matches!(n.kind, NodeKind::Prim(_))).count()
    }

    /// Validate structural invariants (topological ids, arities, widths,
    /// register hookups). Returns a list of problems (empty = valid).
    pub fn validate(&self) -> Vec<String> {
        let mut problems = Vec::new();
        for (i, n) in self.nodes.iter().enumerate() {
            for &a in &n.args {
                if a as usize >= i {
                    problems.push(format!("node {i} uses arg {a} not created before it"));
                }
            }
            if let NodeKind::Prim(op) = n.kind {
                if n.args.len() != op.arity() {
                    problems.push(format!("node {i} {op:?} has {} args, wants {}", n.args.len(), op.arity()));
                }
            }
            if n.width == 0 || n.width > 64 {
                problems.push(format!("node {i} has invalid width {}", n.width));
            }
        }
        for (ri, r) in self.regs.iter().enumerate() {
            if r.next as usize >= self.nodes.len() {
                problems.push(format!("reg {ri} next out of range"));
            }
            if self.width(r.next) > r.width && false {
                // widths may differ; commit masks — no check needed
            }
        }
        for (name, o) in &self.outputs {
            if *o as usize >= self.nodes.len() {
                problems.push(format!("output {name} out of range"));
            }
        }
        problems
    }

    /// Summary statistics for reports.
    pub fn stats(&self) -> GraphStats {
        let mut by_op = std::collections::BTreeMap::new();
        for n in &self.nodes {
            if let NodeKind::Prim(op) = n.kind {
                *by_op.entry(op.mnemonic()).or_insert(0usize) += 1;
            }
        }
        GraphStats {
            nodes: self.nodes.len(),
            ops: self.num_ops(),
            regs: self.regs.len(),
            inputs: self.inputs.len(),
            outputs: self.outputs.len(),
            by_op,
        }
    }
}

/// Aggregate statistics about a graph.
#[derive(Debug, Clone)]
pub struct GraphStats {
    pub nodes: usize,
    pub ops: usize,
    pub regs: usize,
    pub inputs: usize,
    pub outputs: usize,
    pub by_op: std::collections::BTreeMap<&'static str, usize>,
}

/// Reference interpreter: evaluates the graph cycle by cycle in node order.
/// This is the semantic oracle every kernel is tested against.
pub struct RefSim {
    pub graph: Graph,
    values: Vec<u64>,
    reg_next: Vec<u64>,
}

impl RefSim {
    pub fn new(graph: Graph) -> Self {
        let mut values = vec![0u64; graph.nodes.len()];
        for r in &graph.regs {
            values[r.node as usize] = r.init;
        }
        let reg_next = vec![0u64; graph.regs.len()];
        Self { graph, values, reg_next }
    }

    /// Value of a node after the last `step`.
    pub fn value(&self, id: NodeId) -> u64 {
        self.values[id as usize]
    }

    /// Overwrite a node's current value — pre-run initialization of
    /// divergent-lane register state ([`crate::designs::Design::lane_init`]),
    /// mirroring `BatchKernel::poke_lane` on the reference interpreter.
    pub fn poke(&mut self, id: NodeId, value: u64) {
        self.values[id as usize] = value;
    }

    /// Values of all declared outputs.
    pub fn outputs(&self) -> Vec<(String, u64)> {
        self.graph.outputs.iter().map(|(n, id)| (n.clone(), self.values[*id as usize])).collect()
    }

    /// Simulate one cycle: drive inputs, settle combinational logic,
    /// compute and commit register next-states.
    pub fn step(&mut self, inputs: &[u64]) {
        assert_eq!(inputs.len(), self.graph.inputs.len(), "input count mismatch");
        for (p, &v) in self.graph.inputs.iter().zip(inputs) {
            self.values[p.node as usize] = v & mask(p.width);
        }
        let mut argbuf: Vec<u64> = Vec::with_capacity(8);
        let mut widbuf: Vec<u8> = Vec::with_capacity(8);
        for i in 0..self.graph.nodes.len() {
            let n = &self.graph.nodes[i];
            if let NodeKind::Prim(op) = n.kind {
                argbuf.clear();
                widbuf.clear();
                for &a in &n.args {
                    argbuf.push(self.values[a as usize]);
                    widbuf.push(self.graph.nodes[a as usize].width);
                }
                self.values[i] = eval_prim(op, &argbuf, &widbuf, n.width);
            } else if let NodeKind::Const(c) = n.kind {
                self.values[i] = c;
            }
        }
        for (ri, r) in self.graph.regs.iter().enumerate() {
            self.reg_next[ri] = self.values[r.next as usize] & mask(r.width);
        }
        for (ri, r) in self.graph.regs.iter().enumerate() {
            self.values[r.node as usize] = self.reg_next[ri];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a 4-bit counter with enable: r' = en ? r + 1 : r
    fn counter() -> Graph {
        let mut g = Graph::new("counter");
        let en = g.input("en", 1);
        let r = g.reg("count", 4, 0);
        let one = g.konst(1, 4);
        let inc = g.prim_w(PrimOp::Add, &[r, one], 4);
        let nxt = g.prim(PrimOp::Mux, &[en, inc, r]);
        g.connect_reg(r, nxt);
        g.output("count", r);
        g
    }

    #[test]
    fn counter_counts() {
        let g = counter();
        assert!(g.validate().is_empty(), "{:?}", g.validate());
        let mut sim = RefSim::new(g);
        for _ in 0..5 {
            sim.step(&[1]);
        }
        assert_eq!(sim.outputs()[0].1, 5);
        sim.step(&[0]);
        assert_eq!(sim.outputs()[0].1, 5);
        // wraps at 4 bits
        for _ in 0..12 {
            sim.step(&[1]);
        }
        assert_eq!(sim.outputs()[0].1, 1);
    }

    #[test]
    fn register_reads_old_value_within_cycle() {
        // r1' = r0, r0' = in : a 2-stage shift register; r1 must lag r0.
        let mut g = Graph::new("shift");
        let i = g.input("in", 8);
        let r0 = g.reg("r0", 8, 0);
        let r1 = g.reg("r1", 8, 0);
        g.connect_reg(r0, i);
        g.connect_reg(r1, r0);
        g.output("out", r1);
        let mut sim = RefSim::new(g);
        sim.step(&[0xAA]);
        assert_eq!(sim.outputs()[0].1, 0);
        sim.step(&[0xBB]);
        assert_eq!(sim.outputs()[0].1, 0xAA);
        sim.step(&[0xCC]);
        assert_eq!(sim.outputs()[0].1, 0xBB);
    }

    #[test]
    fn validate_catches_bad_width() {
        let mut g = Graph::new("bad");
        let a = g.input("a", 4);
        let id = g.prim_w(PrimOp::Id, &[a], 0);
        let _ = id;
        assert!(!g.validate().is_empty());
    }

    #[test]
    fn stats_counts_ops() {
        let g = counter();
        let s = g.stats();
        assert_eq!(s.regs, 1);
        assert_eq!(s.inputs, 1);
        assert_eq!(s.ops, 2);
        assert_eq!(s.by_op["add"], 1);
        assert_eq!(s.by_op["mux"], 1);
    }
}
