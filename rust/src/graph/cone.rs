//! Per-register cone content hashes for incremental compilation.
//!
//! Each writable register's next-state cone (the combinational logic
//! feeding its `next` node, cut at sources: constants, input ports and
//! *other registers* — referenced by name, not traversed) is hashed with
//! the same dual-stream FNV used by the design-cache key
//! ([`crate::util::fnv::Fnv2`]). Two designs of the same family whose
//! register `r` hashes equal are guaranteed to compute identical
//! next-state functions for `r`, regardless of how node ids shifted —
//! the hash encodes the cone's *shape* (DFS visit order with back-
//! references), not the ids. That is the invalidation unit of the
//! incremental compile path ([`crate::coordinator::incremental`]): after
//! an edit, only registers whose cone hash changed (plus the output cone,
//! if its hash changed) are recompiled.

use std::collections::HashMap;

use super::{Graph, NodeId, NodeKind};
use crate::util::fnv::Fnv2;

/// The content signature of every invalidation unit of a design.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ConeHashes {
    /// `(register name, cone hash)` per entry of `Graph::regs`, in
    /// register order. The hash covers the register's own declaration
    /// (name, width, init) plus its next-state cone.
    pub regs: Vec<(String, String)>,
    /// One hash over all output cones, in output order (names included).
    pub outputs: String,
    /// Signature of the input-port interface (names + widths, in order).
    /// A changed interface disables delta matching entirely.
    pub inputs: String,
}

/// Hash the combinational cone rooted at `start`. Registers are leaves
/// (identified by name); constants and inputs are leaves; primitive ops
/// hash their opcode, width and argument structure. `order` carries the
/// DFS visit indices so shared subtrees hash as back-references — the
/// hash is a function of the cone's structure only, never of node ids.
fn hash_cone(g: &Graph, start: NodeId, h: &mut Fnv2, order: &mut HashMap<NodeId, u32>) {
    // iterative preorder DFS; children pushed in reverse so they pop in
    // argument order
    let mut stack: Vec<NodeId> = vec![start];
    while let Some(id) = stack.pop() {
        if let Some(&ix) = order.get(&id) {
            h.text("ref");
            h.word(ix as u64);
            continue;
        }
        order.insert(id, order.len() as u32);
        let node = &g.nodes[id as usize];
        match &node.kind {
            NodeKind::Const(v) => {
                h.text("C");
                h.word(*v);
                h.byte(node.width);
            }
            NodeKind::Input(pi) => {
                h.text("I");
                h.text(&g.inputs[*pi as usize].name);
                h.byte(node.width);
            }
            NodeKind::Reg(ri) => {
                // leaf: cones are combinational; the register's own cone
                // is hashed separately under its name
                h.text("R");
                h.text(&g.regs[*ri as usize].name);
                h.byte(node.width);
            }
            NodeKind::Prim(op) => {
                h.text("P");
                h.text(&format!("{op:?}"));
                h.byte(node.width);
                h.word(node.args.len() as u64);
                for &a in node.args.iter().rev() {
                    stack.push(a);
                }
            }
        }
    }
}

/// Compute the full [`ConeHashes`] signature of a graph. O(total cone
/// size): each register cone is walked once with a fresh visit map.
pub fn cone_hashes(g: &Graph) -> ConeHashes {
    let mut regs = Vec::with_capacity(g.regs.len());
    for r in &g.regs {
        let mut h = Fnv2::new();
        h.text("REG");
        h.text(&r.name);
        h.byte(r.width);
        h.word(r.init);
        let mut order = HashMap::new();
        hash_cone(g, r.next, &mut h, &mut order);
        regs.push((r.name.clone(), h.hex()));
    }
    let mut ho = Fnv2::new();
    ho.word(g.outputs.len() as u64);
    for (name, node) in &g.outputs {
        ho.text(name);
        let mut order = HashMap::new();
        hash_cone(g, *node, &mut ho, &mut order);
    }
    let mut hi = Fnv2::new();
    hi.word(g.inputs.len() as u64);
    for p in &g.inputs {
        hi.text(&p.name);
        hi.byte(p.width);
    }
    ConeHashes { regs, outputs: ho.hex(), inputs: hi.hex() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ops::PrimOp;

    fn two_reg_design(k: u64) -> Graph {
        let mut g = Graph::new("t");
        let i = g.input("in", 8);
        let r0 = g.reg("r0", 8, 0);
        let r1 = g.reg("r1", 8, 0);
        let c = g.konst(k, 8);
        let a = g.prim_w(PrimOp::Add, &[i, c], 8);
        let x = g.prim_w(PrimOp::Xor, &[r0, i], 8);
        g.connect_reg(r0, a);
        g.connect_reg(r1, x);
        g.output("out", r1);
        g
    }

    /// Editing one register's cone changes exactly that register's hash
    /// (node ids shift, but untouched cones hash identically).
    #[test]
    fn edit_invalidates_only_the_touched_cone() {
        let a = cone_hashes(&two_reg_design(1));
        let b = cone_hashes(&two_reg_design(2));
        assert_eq!(a.regs.len(), 2);
        assert_eq!(a.inputs, b.inputs);
        assert_eq!(a.outputs, b.outputs, "outputs read only r1, which is unchanged");
        assert_ne!(a.regs[0], b.regs[0], "r0's cone carries the edited constant");
        assert_eq!(a.regs[1], b.regs[1], "r1's cone is untouched");
    }

    /// The hash is id-independent: inserting an unrelated node before the
    /// cone leaves its hash unchanged.
    #[test]
    fn hash_ignores_node_id_shifts() {
        let g1 = two_reg_design(1);
        let mut g2 = Graph::new("t");
        let _pad = g2.konst(0x3F, 8); // shifts every later node id
        let i = g2.input("in", 8);
        let r0 = g2.reg("r0", 8, 0);
        let r1 = g2.reg("r1", 8, 0);
        let c = g2.konst(1, 8);
        let a = g2.prim_w(PrimOp::Add, &[i, c], 8);
        let x = g2.prim_w(PrimOp::Xor, &[r0, i], 8);
        g2.connect_reg(r0, a);
        g2.connect_reg(r1, x);
        g2.output("out", r1);
        let h1 = cone_hashes(&g1);
        let h2 = cone_hashes(&g2);
        assert_eq!(h1.regs, h2.regs);
        assert_eq!(h1.outputs, h2.outputs);
        assert_eq!(h1.inputs, h2.inputs);
    }

    /// Shared subtrees hash as back-references, and diamond sharing is
    /// distinguished from duplicated structure.
    #[test]
    fn sharing_is_part_of_the_shape() {
        let mut g1 = Graph::new("s");
        let i = g1.input("in", 8);
        let n = g1.prim_w(PrimOp::Not, &[i], 8);
        let shared = g1.prim_w(PrimOp::Add, &[n, n], 8); // same node twice
        let r = g1.reg("r", 8, 0);
        g1.connect_reg(r, shared);

        let mut g2 = Graph::new("s");
        let i = g2.input("in", 8);
        let n1 = g2.prim_w(PrimOp::Not, &[i], 8);
        let n2 = g2.prim_w(PrimOp::Not, &[i], 8); // structurally equal twin
        let dup = g2.prim_w(PrimOp::Add, &[n1, n2], 8);
        let r = g2.reg("r", 8, 0);
        g2.connect_reg(r, dup);

        assert_ne!(cone_hashes(&g1).regs[0].1, cone_hashes(&g2).regs[0].1);
    }

    /// Catalog designs hash deterministically.
    #[test]
    fn catalog_hashes_are_stable() {
        let d = crate::designs::catalog("fir8").unwrap();
        let a = cone_hashes(&d.graph);
        let b = cone_hashes(&d.graph);
        assert_eq!(a, b);
        assert_eq!(a.regs.len(), d.graph.regs.len());
        for (_, h) in &a.regs {
            assert_eq!(h.len(), 32);
        }
    }
}
