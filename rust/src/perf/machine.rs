//! Host machine models (paper Table 2).
//!
//! Cache geometries are taken directly from the paper's Table 2; latencies
//! and core-width parameters are representative figures for each part
//! (the paper notes the Xeon's LLC latency is roughly 2× the Core's —
//! the root cause it gives for their different frontend behaviour).

use super::cache::CacheCfg;

/// A modeled host machine.
#[derive(Clone, Debug)]
pub struct Machine {
    pub name: &'static str,
    pub l1i: CacheCfg,
    pub l1d: CacheCfg,
    pub l2: CacheCfg,
    pub llc: CacheCfg,
    /// cycles: L2 hit, LLC hit, DRAM
    pub l2_lat: u32,
    pub llc_lat: u32,
    pub mem_lat: u32,
    /// pipeline issue width (top-down slot accounting)
    pub issue_width: u32,
    /// branch mispredict penalty (cycles)
    pub mispredict_penalty: u32,
    /// indirect-target predictor entries
    pub btb_entries: usize,
    /// history-based indirect predictor (ITTAGE-class): learns repeating
    /// dispatch-target sequences. The paper observes Graviton 4 collapses
    /// Verilator's mispredict rate (22% -> 0.22%) — this is the mechanism
    /// we model for it.
    pub smart_indirect: bool,
    /// nominal sustained clock (GHz) — converts modeled cycles to time
    pub ghz: f64,
}

impl Machine {
    /// Override the LLC capacity (Intel CAT experiment, paper Fig 21).
    pub fn with_llc_kb(mut self, kb: usize) -> Self {
        self.llc.size_kb = kb;
        self
    }
}

const fn cc(size_kb: usize, assoc: usize) -> CacheCfg {
    CacheCfg { size_kb, assoc, line_bytes: 64 }
}

/// Intel Core i9-13900K (desktop): big fast LLC, wide core.
pub fn intel_core() -> Machine {
    Machine {
        name: "Intel Core i9-13900K",
        l1i: cc(32, 8),
        l1d: cc(48, 12),
        l2: cc(2 * 1024, 16),
        llc: cc(36 * 1024, 12),
        l2_lat: 14,
        llc_lat: 40,
        mem_lat: 220,
        issue_width: 6,
        mispredict_penalty: 17,
        btb_entries: 8192,
        smart_indirect: false,
        ghz: 5.4,
    }
}

/// Intel Xeon Gold 5512U (server): large but *slow* LLC (≈2× Core latency,
/// per the paper's fetch-latency analysis).
pub fn intel_xeon() -> Machine {
    Machine {
        name: "Intel Xeon Gold 5512U",
        l1i: cc(32, 8),
        l1d: cc(48, 12),
        l2: cc(2 * 1024, 16),
        llc: cc(52 * 1024 + 512, 15),
        l2_lat: 16,
        llc_lat: 80,
        mem_lat: 300,
        issue_width: 6,
        mispredict_penalty: 17,
        btb_entries: 8192,
        smart_indirect: false,
        ghz: 3.4,
    }
}

/// AMD Ryzen 7 4800HS (laptop): small 8 MB LLC — the machine where
/// RTeAAL's compact binaries win outright (paper §7.5).
pub fn amd_ryzen() -> Machine {
    Machine {
        name: "AMD Ryzen 7 4800HS",
        l1i: cc(32, 8),
        l1d: cc(32, 8),
        l2: cc(512, 8),
        llc: cc(8 * 1024, 16),
        l2_lat: 12,
        llc_lat: 38,
        mem_lat: 260,
        issue_width: 5,
        mispredict_penalty: 16,
        btb_entries: 4096,
        smart_indirect: false,
        ghz: 4.2,
    }
}

/// AWS Graviton 4 (Arm server): big L1s, strong branch prediction (the
/// paper observes Verilator's mispredict rate collapses on this machine).
pub fn aws_graviton4() -> Machine {
    Machine {
        name: "AWS Graviton 4",
        l1i: cc(64, 8),
        l1d: cc(64, 8),
        l2: cc(2 * 1024, 16),
        llc: cc(36 * 1024, 16),
        l2_lat: 13,
        llc_lat: 45,
        mem_lat: 250,
        issue_width: 6,
        mispredict_penalty: 12,
        btb_entries: 65536,
        smart_indirect: true,
        ghz: 2.8,
    }
}

/// The paper's four hosts.
pub fn all_machines() -> Vec<Machine> {
    vec![intel_core(), intel_xeon(), amd_ryzen(), aws_graviton4()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_geometries() {
        let m = amd_ryzen();
        assert_eq!(m.llc.size_kb, 8 * 1024);
        assert_eq!(m.l2.size_kb, 512);
        let g = aws_graviton4();
        assert_eq!(g.l1i.size_kb, 64);
        assert!(intel_xeon().llc_lat > intel_core().llc_lat);
    }

    #[test]
    fn cat_override() {
        let m = intel_xeon().with_llc_kb(3584);
        assert_eq!(m.llc.size_kb, 3584);
    }
}
