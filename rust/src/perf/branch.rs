//! Branch prediction model: a bimodal 2-bit direction predictor plus a
//! last-target indirect predictor (BTB). The paper's branch story —
//! Verilator ≈22% mispredicts on x86, ESSENT ≈0.1%, RTeAAL-PSU ≈0.12%,
//! and Graviton 4 collapsing Verilator's rate — emerges from how each
//! executor's dispatch sites see opcode sequences.

/// 2-bit saturating-counter bimodal predictor.
pub struct Bimodal {
    table: Vec<u8>,
    mask: usize,
    pub predictions: u64,
    pub mispredicts: u64,
}

impl Bimodal {
    pub fn new(entries: usize) -> Self {
        let n = entries.next_power_of_two();
        Bimodal { table: vec![1u8; n], mask: n - 1, predictions: 0, mispredicts: 0 }
    }

    /// Record one conditional branch outcome; returns true if predicted
    /// correctly.
    pub fn branch(&mut self, site: u64, taken: bool) -> bool {
        self.predictions += 1;
        let idx = (site as usize ^ (site >> 16) as usize) & self.mask;
        let ctr = &mut self.table[idx];
        let pred = *ctr >= 2;
        if taken && *ctr < 3 {
            *ctr += 1;
        } else if !taken && *ctr > 0 {
            *ctr -= 1;
        }
        let correct = pred == taken;
        if !correct {
            self.mispredicts += 1;
        }
        correct
    }
}

/// Indirect-target predictor. In last-target mode it models a plain BTB
/// (mispredicts whenever a site's target changes — the x86 behaviour the
/// paper measures for Verilator). In history mode it hashes a global
/// target-history register into the index, modeling ITTAGE-class
/// predictors that learn the *repeating* dispatch sequence an RTL
/// simulator produces every cycle (the Graviton 4 behaviour).
pub struct Indirect {
    table: Vec<u64>,
    mask: usize,
    history: u64,
    use_history: bool,
    pub predictions: u64,
    pub mispredicts: u64,
}

impl Indirect {
    pub fn new(entries: usize, use_history: bool) -> Self {
        let n = entries.next_power_of_two();
        Indirect {
            table: vec![u64::MAX; n],
            mask: n - 1,
            history: 0,
            use_history,
            predictions: 0,
            mispredicts: 0,
        }
    }

    /// Record one indirect jump from `site` to `target`.
    pub fn jump(&mut self, site: u64, target: u64) -> bool {
        self.predictions += 1;
        let key = if self.use_history { site ^ self.history.wrapping_mul(0x9E3779B97F4A7C15) } else { site };
        let idx = (key as usize ^ (key >> 12) as usize) & self.mask;
        let correct = self.table[idx] == target;
        if !correct {
            self.mispredicts += 1;
            self.table[idx] = target;
        }
        if self.use_history {
            self.history = (self.history << 4) ^ target ^ site;
        }
        correct
    }
}

/// Combined predictor state + counters for a replay.
pub struct Predictor {
    pub cond: Bimodal,
    pub ind: Indirect,
}

impl Predictor {
    pub fn new(btb_entries: usize, smart_indirect: bool) -> Self {
        Predictor {
            cond: Bimodal::new(btb_entries),
            ind: Indirect::new(btb_entries, smart_indirect),
        }
    }

    pub fn for_machine(m: &super::machine::Machine) -> Self {
        Self::new(m.btb_entries, m.smart_indirect)
    }

    pub fn total_branches(&self) -> u64 {
        self.cond.predictions + self.ind.predictions
    }

    pub fn total_mispredicts(&self) -> u64 {
        self.cond.mispredicts + self.ind.mispredicts
    }

    pub fn mispredict_rate(&self) -> f64 {
        let t = self.total_branches();
        if t == 0 {
            0.0
        } else {
            self.total_mispredicts() as f64 / t as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bimodal_learns_biased_branches() {
        let mut p = Bimodal::new(1024);
        for _ in 0..1000 {
            p.branch(0x40, true);
        }
        assert!(p.mispredicts <= 2);
    }

    #[test]
    fn bimodal_struggles_on_random() {
        let mut rng = crate::util::prng::Rng::new(1);
        let mut p = Bimodal::new(1024);
        for _ in 0..10_000 {
            p.branch(0x40, rng.chance(0.5));
        }
        let rate = p.mispredicts as f64 / p.predictions as f64;
        assert!(rate > 0.3, "rate {rate}");
    }

    #[test]
    fn indirect_stable_target_predicts() {
        let mut p = Indirect::new(1024, false);
        for _ in 0..100 {
            p.jump(0x80, 0x1000);
        }
        assert_eq!(p.mispredicts, 1); // cold miss only
    }

    #[test]
    fn indirect_alternating_targets_mispredict() {
        let mut p = Indirect::new(1024, false);
        for i in 0..100u64 {
            p.jump(0x80, 0x1000 + (i % 2) * 64);
        }
        assert!(p.mispredicts > 90);
    }

    #[test]
    fn history_indirect_learns_repeating_sequences() {
        // a repeating dispatch sequence (same circuit each cycle):
        // last-target predictor mispredicts forever; history predictor
        // learns it — the Graviton-vs-x86 contrast from the paper.
        let seq: Vec<u64> = vec![1, 7, 3, 7, 2, 9, 1, 4, 4, 3];
        let mut plain = Indirect::new(4096, false);
        let mut smart = Indirect::new(65536, true);
        for _ in 0..200 {
            for &t in &seq {
                plain.jump(0x80, 0x1000 + t * 64);
                smart.jump(0x80, 0x1000 + t * 64);
            }
        }
        let plain_rate = plain.mispredicts as f64 / plain.predictions as f64;
        let smart_rate = smart.mispredicts as f64 / smart.predictions as f64;
        assert!(plain_rate > 0.5, "plain {plain_rate}");
        assert!(smart_rate < 0.05, "smart {smart_rate}");
    }
}
