//! Program ("binary") size and metadata ("data") size model per kernel
//! configuration — the code/data split that drives the paper's I-cache vs
//! D-cache pressure story (Tables 4 & 6).
//!
//! Calibration: constants are fitted to paper Table 4 (8-core RocketChip,
//! ≈139 K effectual operations): RU/OU/NU/PSU ≈ 0.35 MB (dominated by the
//! fixed binary base), IU 0.91 MB (per-group code), SU 6.0 MB (≈40 B of
//! straight-line code per op), TI 5.3 MB (≈36 B/op — better register
//! binding shrinks each op's code).

use crate::kernels::KernelConfig;
use crate::tensor::oim::Oim;

/// Fixed binary base: runtime + harness + the rolled kernel bodies
/// (paper's rolled kernels are ~0.35 MB total).
pub const BASE_BYTES: usize = 330 * 1024;

/// Straight-line code bytes per op for SU / TI.
pub const SU_BYTES_PER_OP: usize = 40;
pub const TI_BYTES_PER_OP: usize = 36;
/// Per-(layer, op-type) group code for IU.
pub const IU_BYTES_PER_GROUP: usize = 48;

/// Modeled program bytes for a kernel configuration.
pub fn kernel_code_bytes(cfg: KernelConfig, oim: &Oim) -> usize {
    match cfg {
        KernelConfig::RU => BASE_BYTES + 6 * 1024,
        KernelConfig::OU => BASE_BYTES + 7 * 1024,
        // per-op-type loops are individually tiny and share the case
        // bodies the rolled kernels carried anyway
        KernelConfig::NU => BASE_BYTES + 5 * 1024,
        KernelConfig::PSU => BASE_BYTES + 12 * 1024,
        KernelConfig::IU => iu_code_bytes(nonzero_groups(oim), oim),
        KernelConfig::SU => su_code_bytes(oim.total_ops()),
        KernelConfig::TI => ti_code_bytes(oim.total_ops()),
    }
}

pub fn iu_code_bytes(groups: usize, _oim: &Oim) -> usize {
    BASE_BYTES + 12 * 1024 + groups * IU_BYTES_PER_GROUP
}

pub fn su_code_bytes(total_ops: usize) -> usize {
    BASE_BYTES + 4 * 1024 + total_ops * SU_BYTES_PER_OP
}

pub fn ti_code_bytes(total_ops: usize) -> usize {
    BASE_BYTES + 4 * 1024 + total_ops * TI_BYTES_PER_OP
}

/// Non-empty (layer, op type) groups — IU's program length.
pub fn nonzero_groups(oim: &Oim) -> usize {
    oim.n_payload.iter().filter(|&&c| c != 0).count()
}

/// Modeled metadata bytes the kernel streams from the D-cache each cycle
/// (the OIM arrays in the format that configuration traverses).
pub fn kernel_data_bytes(cfg: KernelConfig, oim: &Oim) -> usize {
    let ops = oim.total_ops();
    let operands = oim.b.r_coords.len();
    let params = ops * (1 + 8 + 8 + 1); // imm + mask + aux + arity
    match cfg {
        // format B: i_payload(u32) + s(u32) + n(u8) + r(u32) + params
        KernelConfig::RU | KernelConfig::OU => {
            oim.i_payload.len() * 4 + ops * 4 + ops + operands * 4 + params
        }
        // format C: n_payload(u32 per layer*optype) + s(u32) + r(u32) + params
        KernelConfig::NU | KernelConfig::PSU => {
            oim.n_payload.len() * 4 + ops * 4 + operands * 4 + params
        }
        // group table moved into the program; coordinates remain data
        KernelConfig::IU => ops * 4 + operands * 4 + params,
        // OIM fully embedded in code
        KernelConfig::SU | KernelConfig::TI => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::random_circuit;
    use crate::tensor::ir::lower;
    use crate::util::prng::Rng;

    fn sample(size: usize) -> Oim {
        let mut rng = Rng::new(123);
        let g = random_circuit(&mut rng, size);
        Oim::from_ir(&lower(&g))
    }

    #[test]
    fn code_size_ordering_matches_paper() {
        let o = sample(2000);
        let b = |c| kernel_code_bytes(c, &o);
        // rolled kernels are all near BASE; IU > rolled; SU/TI dominate
        assert!(b(KernelConfig::IU) > b(KernelConfig::PSU));
        assert!(b(KernelConfig::SU) > b(KernelConfig::IU));
        assert!(b(KernelConfig::TI) < b(KernelConfig::SU));
        assert!(b(KernelConfig::TI) > b(KernelConfig::IU));
    }

    #[test]
    fn data_size_ordering() {
        let o = sample(2000);
        let d = |c| kernel_data_bytes(c, &o);
        assert!(d(KernelConfig::RU) >= d(KernelConfig::NU));
        assert!(d(KernelConfig::NU) >= d(KernelConfig::IU));
        assert_eq!(d(KernelConfig::SU), 0);
        assert_eq!(d(KernelConfig::TI), 0);
    }

    #[test]
    fn table4_calibration_scale() {
        // at ~139K ops SU should be ~6 MB, TI ~5.3 MB (paper Table 4)
        assert!((su_code_bytes(139_000) as f64 - 6.0e6).abs() < 0.7e6);
        assert!((ti_code_bytes(139_000) as f64 - 5.3e6).abs() < 0.7e6);
    }
}
