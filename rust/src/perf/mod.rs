//! Microarchitectural performance model.
//!
//! The paper's counter-level results (top-down breakdowns, MPKI, IPC,
//! LLC sensitivity) come from hardware PMUs on four machines. This
//! substrate reproduces their *shapes* from first principles:
//!
//! * [`binsize`] — program vs metadata footprint per kernel configuration;
//! * [`machine`] — the four host models of paper Table 2 (cache
//!   geometries, fetch/miss penalties, branch predictor size);
//! * [`cache`] — a set-associative, multi-level cache simulator;
//! * [`branch`] — a bimodal branch predictor model;
//! * [`trace`] — instrumented walkers that replay a kernel configuration's
//!   per-cycle instruction/memory/branch behaviour into the models;
//! * [`topdown`] — a top-down (Yasin) slot accounting built from the
//!   modeled miss/mispredict rates, giving frontend-bound/bad-speculation
//!   fractions and an IPC estimate.

pub mod binsize;
pub mod machine;
pub mod cache;
pub mod branch;
pub mod trace;
pub mod topdown;
