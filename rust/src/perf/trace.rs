//! Instrumented replay: walks a simulator configuration's per-cycle
//! instruction-fetch / data-access / branch behaviour into the cache and
//! branch models, producing the counter-level profile the paper reads off
//! hardware PMUs.
//!
//! The walker replays the *same iteration the executor performs* (format-B
//! order for RU/OU and the compiled baselines; format-C order for
//! NU/PSU/IU/SU/TI), with a simulated address map:
//!
//! ```text
//! 0x0000_0000  code  (per-style layout; unrolled styles get per-op sites)
//! 0x4x00_0000  OIM metadata arrays (one base per array)
//! 0x8000_0000  LI slot file (8 B per slot)
//! 0x9000_0000  LO layer-output buffer
//! ```

use super::branch::Predictor;
use super::cache::Hierarchy;
use super::machine::Machine;
use crate::kernels::KernelConfig;
use crate::tensor::ir::NUM_KOPS;
use crate::tensor::oim::Oim;
use crate::util::prng::Rng;

/// What is being profiled.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimStyle {
    Kernel(KernelConfig),
    /// Compiled per-node branchy code (Verilator-class).
    Verilator,
    /// Fully unrolled straight-line code (ESSENT-class, -O2).
    Essent,
}

impl SimStyle {
    pub fn name(&self) -> String {
        match self {
            SimStyle::Kernel(k) => k.name().to_string(),
            SimStyle::Verilator => "verilator-like".into(),
            SimStyle::Essent => "essent-like".into(),
        }
    }
}

/// Counter-level profile over the sampled cycles.
#[derive(Debug, Clone)]
pub struct Profile {
    pub style: String,
    pub cycles_sampled: u64,
    pub instructions: u64,
    pub l1i_accesses: u64,
    pub l1i_misses: u64,
    pub l1d_loads: u64,
    pub l1d_stores: u64,
    pub l1d_misses: u64,
    pub llc_misses: u64,
    pub branches: u64,
    pub mispredicts: u64,
    pub fetch_stall_cycles: u64,
    pub data_stall_cycles: u64,
}

impl Profile {
    pub fn mispredict_rate(&self) -> f64 {
        if self.branches == 0 {
            0.0
        } else {
            self.mispredicts as f64 / self.branches as f64
        }
    }
    pub fn l1i_mpki(&self) -> f64 {
        self.l1i_misses as f64 / (self.instructions as f64 / 1000.0)
    }
    pub fn l1d_mpki(&self) -> f64 {
        self.l1d_misses as f64 / (self.instructions as f64 / 1000.0)
    }
}

// ---- simulated address map ----
const CODE: u64 = 0x0000_0000;
const UNROLLED_CODE: u64 = 0x0100_0000; // per-op code sites for IU/SU/TI
const I_PAYLOAD: u64 = 0x4000_0000;
const N_PAYLOAD: u64 = 0x4100_0000;
const S_COORDS: u64 = 0x4200_0000;
const N_COORDS: u64 = 0x4300_0000;
const R_COORDS: u64 = 0x4400_0000;
const IMM: u64 = 0x4500_0000;
const MASKA: u64 = 0x4600_0000;
const ARITY: u64 = 0x4800_0000;
const LI: u64 = 0x8000_0000;
const LO: u64 = 0x9000_0000;

/// Modeled dynamic instructions per op for each style (loop + fetch +
/// compute + store overheads; calibrated to reproduce the RU→TI dynamic
/// instruction decline of paper Table 5).
fn insts_per_op(style: SimStyle, arity: usize) -> u64 {
    let a = arity as u64;
    match style {
        SimStyle::Kernel(KernelConfig::RU) => 18 + 4 * a,
        SimStyle::Kernel(KernelConfig::OU) => 12 + 3 * a,
        SimStyle::Kernel(KernelConfig::NU) => 8 + 2 * a,
        SimStyle::Kernel(KernelConfig::PSU) => 6 + 2 * a,
        SimStyle::Kernel(KernelConfig::IU) => 5 + 2 * a,
        SimStyle::Kernel(KernelConfig::SU) => 4 + 2 * a,
        SimStyle::Kernel(KernelConfig::TI) => 3 + a,
        SimStyle::Verilator => 10 + 3 * a, // branchy compiled code
        SimStyle::Essent => 2 + a,         // aggressively optimized straight line
    }
}

/// Writeback instructions per op.
fn wb_insts_per_op(style: SimStyle) -> u64 {
    match style {
        SimStyle::Kernel(KernelConfig::RU | KernelConfig::OU | KernelConfig::NU) => 4,
        SimStyle::Kernel(KernelConfig::PSU | KernelConfig::IU) => 2,
        SimStyle::Kernel(KernelConfig::SU) => 2,
        // TI / baselines write slots directly
        _ => 0,
    }
}

/// Straight-line code bytes per op (I-footprint of unrolled styles).
fn code_bytes_per_op(style: SimStyle) -> u64 {
    match style {
        SimStyle::Kernel(KernelConfig::SU) => super::binsize::SU_BYTES_PER_OP as u64,
        SimStyle::Kernel(KernelConfig::TI) => super::binsize::TI_BYTES_PER_OP as u64,
        SimStyle::Verilator => 68, // compiled, moderately optimized, branchy
        SimStyle::Essent => 40,    // compiled, heavily optimized
        _ => 0,
    }
}

/// Profile one simulator style over `sample_cycles` (plus warm-up).
pub fn profile(style: SimStyle, oim: &Oim, machine: &Machine, sample_cycles: usize) -> Profile {
    let mut hier = Hierarchy::new(machine);
    let mut pred = Predictor::for_machine(machine);
    let mut insts = 0u64;
    // warm-up cycle fills the caches/predictors, then reset counters
    replay_cycle(style, oim, &mut hier, &mut pred, &mut insts, 0);
    hier.reset_stats();
    pred.cond.predictions = 0;
    pred.cond.mispredicts = 0;
    pred.ind.predictions = 0;
    pred.ind.mispredicts = 0;
    insts = 0;
    for cycle in 1..=sample_cycles {
        replay_cycle(style, oim, &mut hier, &mut pred, &mut insts, cycle as u64);
    }
    Profile {
        style: style.name(),
        cycles_sampled: sample_cycles as u64,
        instructions: insts,
        l1i_accesses: hier.stats.ifetches,
        l1i_misses: hier.stats.l1i_misses,
        l1d_loads: hier.stats.dloads,
        l1d_stores: hier.stats.dstores,
        l1d_misses: hier.stats.l1d_misses,
        llc_misses: hier.stats.llc_misses,
        branches: pred.total_branches(),
        mispredicts: pred.total_mispredicts(),
        fetch_stall_cycles: hier.stats.fetch_stall_cycles,
        data_stall_cycles: hier.stats.data_stall_cycles,
    }
}

#[allow(clippy::too_many_arguments)]
fn replay_cycle(
    style: SimStyle,
    oim: &Oim,
    hier: &mut Hierarchy,
    pred: &mut Predictor,
    insts: &mut u64,
    cycle: u64,
) {
    use KernelConfig::*;
    let c_order = matches!(
        style,
        SimStyle::Kernel(NU) | SimStyle::Kernel(PSU) | SimStyle::Kernel(IU) | SimStyle::Kernel(SU) | SimStyle::Kernel(TI)
    );
    let arrays = if c_order { &oim.c } else { &oim.b };
    let meta = !matches!(style, SimStyle::Kernel(SU) | SimStyle::Kernel(TI) | SimStyle::Verilator | SimStyle::Essent);
    let uses_lo = wb_insts_per_op(style) > 0;
    // per-op data-dependent branch outcomes for the Verilator model:
    // branch conditions follow signal values, which are mostly stable
    // cycle-to-cycle; a small fraction flip each cycle.
    let mut flip_rng = Rng::new(0xBAD5EED ^ cycle);

    let mut op_idx = 0usize;
    let mut r_idx = 0usize;
    let mut group_idx = 0usize;
    *insts += 50; // cycle prologue/epilogue (inputs + commit)

    for (layer, &cnt) in oim.i_payload.iter().enumerate() {
        let cnt = cnt as usize;
        if meta && !c_order {
            hier.daccess(I_PAYLOAD + layer as u64 * 4, false);
        }
        if meta && c_order && !matches!(style, SimStyle::Kernel(IU)) {
            // NU/PSU scan all op types per layer (n_payload loads)
            for n in 0..NUM_KOPS {
                hier.daccess(N_PAYLOAD + ((layer * NUM_KOPS + n) as u64) * 4, false);
                *insts += 2; // the zero-iteration check overhead
            }
        }
        let layer_start = op_idx;
        for s in 0..cnt {
            let i = layer_start + s;
            let opcode = arrays.opcode[i];
            let arity = arrays.arity[i] as usize;
            *insts += insts_per_op(style, arity);

            // ---- instruction fetch ----
            match style {
                SimStyle::Kernel(RU) | SimStyle::Kernel(OU) => {
                    // shared loop body + per-opcode case body
                    hier.ifetch(CODE + 0x8000);
                    hier.ifetch(CODE + opcode as u64 * 128);
                    // the case dispatch is an indirect jump whose target is
                    // the opcode's case body
                    pred.ind.jump(CODE + 0x8000, opcode as u64);
                }
                SimStyle::Kernel(NU) | SimStyle::Kernel(PSU) | SimStyle::Kernel(IU) => {
                    // group bodies: reused within a group
                    hier.ifetch(CODE + opcode as u64 * 512);
                }
                SimStyle::Kernel(SU) | SimStyle::Kernel(TI) | SimStyle::Essent => {
                    // straight-line: every op has its own code site
                    let per = code_bytes_per_op(style).max(36);
                    let site = UNROLLED_CODE + i as u64 * per;
                    hier.ifetch(site);
                    if (site / 64) != ((site + per - 1) / 64) {
                        hier.ifetch(site + per - 1);
                    }
                    if matches!(style, SimStyle::Kernel(TI)) {
                        // indirect call into the shared per-opcode fn
                        hier.ifetch(CODE + opcode as u64 * 128);
                    }
                }
                SimStyle::Verilator => {
                    let per = code_bytes_per_op(style);
                    let site = UNROLLED_CODE + i as u64 * per;
                    hier.ifetch(site);
                    if (site / 64) != ((site + per - 1) / 64) {
                        hier.ifetch(site + per - 1);
                    }
                    // two data-dependent conditional branches per op,
                    // mostly stable across cycles
                    for b in 0..2u64 {
                        let stable = ((i as u64).wrapping_mul(0x9E37) >> b) & 1 != 0;
                        let taken = if flip_rng.chance(0.08) { !stable } else { stable };
                        if !pred.cond.branch(site + b * 8, taken) {
                            *insts += 2;
                        }
                    }
                }
            }

            // ---- metadata loads ----
            if meta {
                if !c_order {
                    hier.daccess(N_COORDS + i as u64, false);
                }
                hier.daccess(ARITY + i as u64, false);
                hier.daccess(IMM + i as u64, false);
                hier.daccess(MASKA + i as u64 * 8, false);
                for o in 0..arity {
                    hier.daccess(R_COORDS + (r_idx + o) as u64 * 4, false);
                }
            }

            // ---- LI operand loads ----
            for o in 0..arity {
                let slot = arrays.r_coords[r_idx + o] as u64;
                hier.daccess(LI + slot * 8, false);
            }
            // ---- result ----
            if uses_lo {
                hier.daccess(LO + s as u64 * 8, true);
            } else {
                hier.daccess(LI + arrays.s_coords[i] as u64 * 8, true);
            }
            r_idx += arity;
        }
        op_idx += cnt;

        // ---- writeback pass ----
        if uses_lo {
            for s in 0..cnt {
                let i = layer_start + s;
                *insts += wb_insts_per_op(style);
                if meta || matches!(style, SimStyle::Kernel(SU)) {
                    // s_coords load (SU bakes them in code; approximate as code)
                    if meta {
                        hier.daccess(S_COORDS + i as u64 * 4, false);
                    }
                }
                hier.daccess(LO + s as u64 * 8, false);
                hier.daccess(LI + arrays.s_coords[i] as u64 * 8, true);
            }
        }

        // loop branches: layer backedge (well-predicted)
        pred.cond.branch(CODE + 0x40, true);
        *insts += 4;
        let _ = group_idx;
        group_idx += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::random_circuit;
    use crate::graph::passes::optimize;
    use crate::perf::machine;
    use crate::tensor::ir::lower;
    use crate::util::prng::Rng;

    fn sample_oim(size: usize) -> Oim {
        let mut rng = Rng::new(7);
        let g = random_circuit(&mut rng, size);
        let (opt, _) = optimize(&g);
        Oim::from_ir(&lower(&opt))
    }

    #[test]
    fn dynamic_instructions_decline_with_unrolling() {
        let oim = sample_oim(800);
        let m = machine::intel_xeon();
        let mut prev = u64::MAX;
        for cfg in crate::kernels::ALL_KERNELS {
            let p = profile(SimStyle::Kernel(cfg), &oim, &m, 2);
            assert!(
                p.instructions <= prev,
                "{}: {} > previous {}",
                cfg.name(),
                p.instructions,
                prev
            );
            prev = p.instructions;
        }
    }

    #[test]
    fn unrolled_kernels_touch_more_icache() {
        let oim = sample_oim(3000);
        let m = machine::intel_xeon();
        let psu = profile(SimStyle::Kernel(crate::kernels::KernelConfig::PSU), &oim, &m, 2);
        let su = profile(SimStyle::Kernel(crate::kernels::KernelConfig::SU), &oim, &m, 2);
        assert!(
            su.l1i_misses > psu.l1i_misses * 5,
            "SU {} vs PSU {}",
            su.l1i_misses,
            psu.l1i_misses
        );
        // and fewer D-loads (paper Table 6)
        assert!(su.l1d_loads < psu.l1d_loads);
    }

    #[test]
    fn verilator_mispredicts_on_x86_not_graviton() {
        let oim = sample_oim(2000);
        let x86 = profile(SimStyle::Verilator, &oim, &machine::intel_xeon(), 3);
        let arm = profile(SimStyle::Verilator, &oim, &machine::aws_graviton4(), 3);
        assert!(x86.mispredict_rate() > 0.04, "x86 rate {}", x86.mispredict_rate());
        // ESSENT-class straight line barely mispredicts anywhere
        let ess = profile(SimStyle::Essent, &oim, &machine::intel_xeon(), 3);
        assert!(ess.mispredict_rate() < 0.01, "essent rate {}", ess.mispredict_rate());
        // graviton's history predictor does no worse than x86
        assert!(arm.mispredict_rate() <= x86.mispredict_rate());
    }
}
