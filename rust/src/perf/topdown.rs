//! Top-down slot accounting (Yasin, ISPASS'14 — paper Fig 7 / §7.2) built
//! from the modeled miss and mispredict counts, plus an IPC / wall-time
//! estimator used for the per-machine performance projections.

use super::machine::Machine;
use super::trace::Profile;

/// Top-down breakdown + derived rates for one profile on one machine.
#[derive(Debug, Clone)]
pub struct TopDown {
    pub frontend_bound: f64,
    pub bad_speculation: f64,
    pub retiring: f64,
    pub backend_bound: f64,
    pub ipc: f64,
    /// modeled core cycles per simulated RTL cycle
    pub cycles_per_sim_cycle: f64,
    pub l1i_mpki: f64,
    pub l1d_mpki: f64,
    pub mispredict_rate: f64,
}

/// Build the top-down view of a profile.
pub fn analyze(p: &Profile, m: &Machine) -> TopDown {
    let insts = p.instructions as f64;
    let issue = m.issue_width as f64;
    // cycle composition
    let base_cycles = insts / issue;
    let fetch_cycles = p.fetch_stall_cycles as f64;
    let spec_cycles = p.mispredicts as f64 * m.mispredict_penalty as f64;
    let data_cycles = p.data_stall_cycles as f64;
    let cycles = base_cycles + fetch_cycles + spec_cycles + data_cycles;

    TopDown {
        frontend_bound: fetch_cycles / cycles,
        bad_speculation: spec_cycles / cycles,
        retiring: base_cycles / cycles,
        backend_bound: data_cycles / cycles,
        ipc: insts / cycles,
        cycles_per_sim_cycle: cycles / p.cycles_sampled as f64,
        l1i_mpki: p.l1i_mpki(),
        l1d_mpki: p.l1d_mpki(),
        mispredict_rate: p.mispredict_rate(),
    }
}

/// Modeled wall-clock seconds to simulate `sim_cycles` RTL cycles on `m`.
pub fn modeled_sim_time(td: &TopDown, m: &Machine, sim_cycles: u64) -> f64 {
    td.cycles_per_sim_cycle * sim_cycles as f64 / (m.ghz * 1e9)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::random_circuit;
    use crate::graph::passes::optimize;
    use crate::kernels::KernelConfig;
    use crate::perf::machine;
    use crate::perf::trace::{profile, SimStyle};
    use crate::tensor::ir::lower;
    use crate::tensor::oim::Oim;
    use crate::util::prng::Rng;

    fn oim(size: usize) -> Oim {
        let mut rng = Rng::new(11);
        let g = random_circuit(&mut rng, size);
        let (opt, _) = optimize(&g);
        Oim::from_ir(&lower(&opt))
    }

    #[test]
    fn fractions_sum_to_one() {
        let o = oim(500);
        let m = machine::intel_xeon();
        for cfg in crate::kernels::ALL_KERNELS {
            let p = profile(SimStyle::Kernel(cfg), &o, &m, 2);
            let td = analyze(&p, &m);
            let sum = td.frontend_bound + td.bad_speculation + td.retiring + td.backend_bound;
            assert!((sum - 1.0).abs() < 1e-9, "{}: {sum}", cfg.name());
            assert!(td.ipc > 0.0 && td.ipc <= m.issue_width as f64);
        }
    }

    #[test]
    fn su_is_more_frontend_bound_than_psu_on_xeon() {
        // the paper's central §7.2 observation: ~5% frontend for PSU vs
        // ~80% for SU on the Xeon (big design); shapes must match
        let o = oim(4000);
        let m = machine::intel_xeon();
        let psu = analyze(&profile(SimStyle::Kernel(KernelConfig::PSU), &o, &m, 2), &m);
        let su = analyze(&profile(SimStyle::Kernel(KernelConfig::SU), &o, &m, 2), &m);
        assert!(
            su.frontend_bound > psu.frontend_bound * 3.0,
            "SU {} vs PSU {}",
            su.frontend_bound,
            psu.frontend_bound
        );
    }

    #[test]
    fn modeled_time_scales_with_cycles() {
        let o = oim(300);
        let m = machine::amd_ryzen();
        let td = analyze(&profile(SimStyle::Kernel(KernelConfig::PSU), &o, &m, 2), &m);
        let t1 = modeled_sim_time(&td, &m, 1000);
        let t2 = modeled_sim_time(&td, &m, 2000);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
    }
}
