//! Set-associative cache simulator with true-LRU replacement, composed
//! into the 3-level hierarchy of the modeled machines.

/// Geometry of one cache level.
#[derive(Clone, Copy, Debug)]
pub struct CacheCfg {
    pub size_kb: usize,
    pub assoc: usize,
    pub line_bytes: usize,
}

/// One cache level. LRU order is maintained by position in the way vector
/// (front = MRU) — fine for the small associativities we model.
pub struct Cache {
    cfg: CacheCfg,
    sets: Vec<Vec<u64>>,
    set_mask: u64,
    line_shift: u32,
    pub accesses: u64,
    pub misses: u64,
}

impl Cache {
    pub fn new(cfg: CacheCfg) -> Self {
        let lines = (cfg.size_kb * 1024 / cfg.line_bytes).max(cfg.assoc);
        let n_sets = (lines / cfg.assoc).next_power_of_two().max(1);
        Cache {
            cfg,
            sets: vec![Vec::with_capacity(cfg.assoc); n_sets],
            set_mask: (n_sets - 1) as u64,
            line_shift: cfg.line_bytes.trailing_zeros(),
            accesses: 0,
            misses: 0,
        }
    }

    /// Access a byte address; returns true on hit.
    pub fn access(&mut self, addr: u64) -> bool {
        self.accesses += 1;
        let line = addr >> self.line_shift;
        let set = (line & self.set_mask) as usize;
        let ways = &mut self.sets[set];
        if let Some(pos) = ways.iter().position(|&t| t == line) {
            let t = ways.remove(pos);
            ways.insert(0, t); // move to MRU
            true
        } else {
            self.misses += 1;
            if ways.len() >= self.cfg.assoc {
                ways.pop();
            }
            ways.insert(0, line);
            false
        }
    }

    pub fn reset_stats(&mut self) {
        self.accesses = 0;
        self.misses = 0;
    }

    pub fn miss_rate(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.misses as f64 / self.accesses as f64
        }
    }
}

/// Aggregated event counts from a hierarchy replay.
#[derive(Debug, Clone, Copy, Default)]
pub struct HierStats {
    pub ifetches: u64,
    pub l1i_misses: u64,
    pub dloads: u64,
    pub dstores: u64,
    pub l1d_misses: u64,
    pub l2_misses: u64,
    pub llc_misses: u64,
    /// accumulated stall cycles attributable to instruction fetch
    pub fetch_stall_cycles: u64,
    /// accumulated memory latency cycles from data misses
    pub data_stall_cycles: u64,
}

/// Three-level hierarchy (split L1, unified L2 + LLC).
pub struct Hierarchy {
    pub l1i: Cache,
    pub l1d: Cache,
    pub l2: Cache,
    pub llc: Cache,
    l2_lat: u32,
    llc_lat: u32,
    mem_lat: u32,
    pub stats: HierStats,
}

impl Hierarchy {
    pub fn new(m: &super::machine::Machine) -> Self {
        Hierarchy {
            l1i: Cache::new(m.l1i),
            l1d: Cache::new(m.l1d),
            l2: Cache::new(m.l2),
            llc: Cache::new(m.llc),
            l2_lat: m.l2_lat,
            llc_lat: m.llc_lat,
            mem_lat: m.mem_lat,
            stats: HierStats::default(),
        }
    }

    fn lower_latency(&mut self, addr: u64) -> u32 {
        if self.l2.access(addr) {
            self.l2_lat
        } else if self.llc.access(addr) {
            self.llc_lat
        } else {
            self.stats.llc_misses += 1;
            self.mem_lat
        }
    }

    /// Instruction fetch of one cache line.
    pub fn ifetch(&mut self, addr: u64) {
        self.stats.ifetches += 1;
        if !self.l1i.access(addr) {
            self.stats.l1i_misses += 1;
            let lat = self.lower_latency(addr);
            if lat > self.l2_lat {
                self.stats.l2_misses += 1;
            }
            self.stats.fetch_stall_cycles += lat as u64;
        }
    }

    /// Data load/store.
    pub fn daccess(&mut self, addr: u64, store: bool) {
        if store {
            self.stats.dstores += 1;
        } else {
            self.stats.dloads += 1;
        }
        if !self.l1d.access(addr) {
            self.stats.l1d_misses += 1;
            let lat = self.lower_latency(addr);
            if lat > self.l2_lat {
                self.stats.l2_misses += 1;
            }
            // loads stall the pipeline only partially (OoO overlap): charge
            // a fraction of the latency
            self.stats.data_stall_cycles += (lat / 3) as u64;
        }
    }

    pub fn reset_stats(&mut self) {
        self.stats = HierStats::default();
        self.l1i.reset_stats();
        self.l1d.reset_stats();
        self.l2.reset_stats();
        self.llc.reset_stats();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Cache {
        Cache::new(CacheCfg { size_kb: 1, assoc: 2, line_bytes: 64 }) // 16 lines, 8 sets
    }

    #[test]
    fn hits_after_fill() {
        let mut c = small();
        assert!(!c.access(0));
        assert!(c.access(0));
        assert!(c.access(63)); // same line
        assert!(!c.access(64)); // next line
    }

    #[test]
    fn lru_eviction() {
        let mut c = small();
        // 8 sets: addresses 0, 8*64, 16*64 map to set 0
        c.access(0);
        c.access(8 * 64);
        assert!(c.access(0)); // still resident, now MRU
        c.access(16 * 64); // evicts 8*64 (LRU)
        assert!(c.access(0));
        assert!(!c.access(8 * 64));
    }

    #[test]
    fn working_set_behaviour() {
        // a working set larger than the cache must thrash
        let mut c = small();
        for round in 0..4 {
            for i in 0..64u64 {
                c.access(i * 64);
            }
            let _ = round;
        }
        assert!(c.miss_rate() > 0.9);
        // a tiny working set must hit
        let mut c2 = small();
        for _ in 0..100 {
            for i in 0..4u64 {
                c2.access(i * 64);
            }
        }
        assert!(c2.miss_rate() < 0.05);
    }

    #[test]
    fn hierarchy_counts_stall_cycles() {
        let m = crate::perf::machine::amd_ryzen();
        let mut h = Hierarchy::new(&m);
        for i in 0..10_000u64 {
            h.ifetch(i * 64);
        }
        assert_eq!(h.stats.l1i_misses, 10_000);
        assert!(h.stats.fetch_stall_cycles > 0);
    }
}
