//! Activity subsystem: lane-masked sparse batched execution.
//!
//! The OIM exploits the *static* sparsity of the design (which (layer,
//! slot, op, operand) coordinates are occupied); this module adds the
//! *dynamic* sparsity of real workloads — most signals do not toggle most
//! cycles. Event-driven skipping is classically unprofitable per scalar
//! run ([`crate::baselines::event_driven`]): the per-op dirty bookkeeping
//! outweighs the skipped work. Lifted to the lane-batched executors the
//! trade flips, because one activity decision is amortized over `B ≤ 64`
//! lanes and the bookkeeping granularity is a whole (layer, op-type)
//! *group*, not an op:
//!
//! * [`gdg::GroupDepGraph`] — the **group dependency graph**, derived once
//!   at compile time from the format-C group walk (`r_coords` /
//!   `s_coords`): for every (layer, op-type) group, the upstream groups,
//!   input ports and register slots whose writes can change its inputs.
//! * [`mask::ActivityTracker`] — the per-group **lane activity mask**, one
//!   `u64` with one bit per lane. Change detection happens only at the
//!   cycle boundaries (testbench input writes and register commits);
//!   masks then propagate through the GDG in topological (layer) order,
//!   so a group is active in lane `l` exactly when some boundary source
//!   it transitively depends on changed in lane `l`.
//!
//! A group whose mask is zero is skipped entirely by the sparse batched
//! executors ([`crate::kernels::batch_sparse`]); a partial mask runs only
//! the active lanes via bit iteration. Because every operation is a pure
//! function of its operand slots, a skipped (group, lane) necessarily
//! holds its previous — still correct — slot values, so sparse execution
//! is bit-identical to dense batched execution (property-tested in
//! `tests/kernels_property.rs`).
//!
//! Out-of-band slot writes (`poke_lane`: divergent-lane init, the
//! partitioned RUM exchange) bypass the boundary detectors and use
//! **targeted invalidation** instead of a recold: the GDG carries a
//! slot → direct-reader-groups index ([`GroupDepGraph::readers_of`])
//! and [`ActivityTracker::note_slot_changed`] marks exactly the written
//! slot's readers pending in the written lanes — the next propagation
//! sweep wakes its transitive descendants and nothing else.
//!
//! The same idea lifts one level up to thread-level partitions:
//! [`partition::PartitionTracker`] gates whole partitions of a
//! RepCut-style partitioned batched run over the RUM cut (sparse
//! [`crate::coordinator::parallel::BatchParallelSim`]), skipping a
//! quiescent partition's entire kernel step. The two levels **compose**:
//! a sparse partitioned run of a group-capable kernel builds one sparse
//! executor per partition and routes the RUM exchange's per-register
//! per-lane change bits into each destination partition's group tracker
//! through the targeted `poke_lane` — quiescent partitions skip whole,
//! quiescent groups skip inside the partitions that do step.

pub mod gdg;
pub mod mask;
pub mod partition;

pub use gdg::GroupDepGraph;
pub use mask::ActivityTracker;
pub use partition::{PartitionActivity, PartitionTracker};

/// Cumulative activity accounting of a sparse batched run. One *op-lane*
/// is one operation evaluated in one lane — the unit of work the dense
/// batched executors spend `total_op_lanes` of per run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ActivityStats {
    /// Cycles stepped.
    pub cycles: u64,
    /// (op, lane) work units actually evaluated.
    pub evaluated_op_lanes: u64,
    /// (op, lane) work units a dense run would evaluate.
    pub total_op_lanes: u64,
}

impl ActivityStats {
    /// Fraction of op-lanes skipped (0 = dense-equivalent, →1 = idle).
    pub fn skip_rate(&self) -> f64 {
        if self.total_op_lanes == 0 {
            0.0
        } else {
            1.0 - self.evaluated_op_lanes as f64 / self.total_op_lanes as f64
        }
    }

    /// Stats accumulated since an earlier snapshot `base` of the same run.
    pub fn since(&self, base: &ActivityStats) -> ActivityStats {
        ActivityStats {
            cycles: self.cycles - base.cycles,
            evaluated_op_lanes: self.evaluated_op_lanes - base.evaluated_op_lanes,
            total_op_lanes: self.total_op_lanes - base.total_op_lanes,
        }
    }
}

/// Borrowed view of a sparse kernel's change masks for the cycle just
/// stepped, consumed by the delta-waveform sink
/// ([`crate::sim::wave::WaveSink`]). Valid from the return of `step()`
/// until the next `step()`/`poke_lane`:
///
/// * `active[g]` — the lanes group `g` evaluated this cycle (a clear bit
///   proves every slot the group writes is unchanged in that lane);
/// * `reg_changed[c]` — the lanes in which commit `c` (in `ir.commits`
///   order) committed a *different* value this cycle (exact, not just
///   sufficient: the commit loop compares old vs new per lane);
/// * `changed` — the union over groups, commits, input-port boundary
///   changes **and out-of-band pokes**: a clear lane bit here proves
///   every slot of that lane — combinational, register and input alike —
///   is bit-identical to the previous cycle, so a waveform sink can skip
///   the whole lane in O(1);
/// * `recheck` — the lanes an out-of-band `poke_lane` wrote between the
///   previous step and this one. Per-class gating is *not* exhaustive
///   there (a poked self-holding register changes with no active writer
///   group and no `reg_changed` bit), so a sink must fall back to the
///   full value-diff scan in these lanes. Always a subset of `changed`.
pub struct WaveMasks<'a> {
    /// The group dependency graph the masks are indexed by
    /// (`GroupDepGraph::writer_of` classifies slots to groups).
    pub gdg: &'a GroupDepGraph,
    pub active: &'a [u64],
    pub reg_changed: &'a [u64],
    pub changed: u64,
    pub recheck: u64,
}

/// The all-lanes-active mask for a `lanes`-wide batch (`lanes ≤ 64`).
#[inline]
pub fn full_mask(lanes: usize) -> u64 {
    assert!(
        (1..=64).contains(&lanes),
        "lane activity masks are u64 bitmasks: lanes must be in 1..=64 (got {lanes})"
    );
    if lanes == 64 {
        u64::MAX
    } else {
        (1u64 << lanes) - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_mask_widths() {
        assert_eq!(full_mask(1), 1);
        assert_eq!(full_mask(3), 0b111);
        assert_eq!(full_mask(64), u64::MAX);
    }

    #[test]
    #[should_panic]
    fn full_mask_rejects_zero() {
        full_mask(0);
    }

    #[test]
    #[should_panic]
    fn full_mask_rejects_over_64() {
        full_mask(65);
    }

    #[test]
    fn skip_rate_arithmetic() {
        let a = ActivityStats { cycles: 10, evaluated_op_lanes: 25, total_op_lanes: 100 };
        assert!((a.skip_rate() - 0.75).abs() < 1e-12);
        let b = ActivityStats { cycles: 4, evaluated_op_lanes: 25, total_op_lanes: 40 };
        let d = a.since(&b);
        assert_eq!(d.cycles, 6);
        assert_eq!(d.evaluated_op_lanes, 0);
        assert_eq!(d.total_op_lanes, 60);
        assert!((d.skip_rate() - 1.0).abs() < 1e-12);
        assert_eq!(ActivityStats::default().skip_rate(), 0.0);
    }
}
