//! The group dependency graph (GDG): compile-time dataflow between the
//! format-C (layer, op-type) groups and the cycle-boundary sources that
//! can change their inputs.
//!
//! Built once from the OIM's format-C arrays: a single pass over
//! `c.r_coords` classifies every operand slot of every group as (a) the
//! output of an upstream group (`c.s_coords` tells us which group wrote
//! it), (b) a testbench input port, (c) a register slot (written by a
//! commit at the end of the previous cycle), or (d) a constant — which can
//! never change and contributes no edge. Levelization guarantees an
//! operand is produced strictly before the consuming group runs, so group
//! indices are already a topological order and the runtime mask
//! propagation ([`super::mask::ActivityTracker`]) is a single forward
//! sweep over `group_deps`.

use crate::tensor::ir::{LayerIr, NUM_KOPS};
use crate::tensor::oim::Oim;
use crate::util::json::{arr_u32, obj, Json, JsonError};

/// One (layer, op-type) group of the format-C walk, addressed by its flat
/// op range in the format-C arrays (`c.s_coords[op_start..op_end]` are its
/// output slots; its operand slots start at `c.r_coords[r_start]`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Group {
    pub layer: u32,
    pub opcode: u8,
    pub op_start: u32,
    pub op_end: u32,
    pub r_start: u32,
}

impl Group {
    /// Operations in the group.
    #[inline]
    pub fn ops(&self) -> usize {
        (self.op_end - self.op_start) as usize
    }
}

/// The compile-time dependency structure driving activity propagation.
/// All dependency lists are sorted and deduplicated.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GroupDepGraph {
    /// Groups in execution (topological) order.
    pub groups: Vec<Group>,
    /// Upstream groups per group (indices into `groups`, all `< g`).
    pub group_deps: Vec<Vec<u32>>,
    /// Input-port indices per group (indices into `LayerIr::input_slots`).
    pub input_deps: Vec<Vec<u32>>,
    /// Commit indices per group (indices into `LayerIr::commits`).
    pub reg_deps: Vec<Vec<u32>>,
    /// Total direct dependency edges (group + input + register).
    pub num_edges: usize,
    /// Total effectual operations across all groups.
    pub total_ops: usize,
    /// CSR offsets of the slot → direct-reader-groups index
    /// ([`Self::readers_of`]); `num_slots + 1` entries.
    reader_offsets: Vec<u32>,
    /// CSR payload of the slot → direct-reader-groups index, sorted per
    /// slot.
    reader_groups: Vec<u32>,
    /// Group writing each slot within the cycle ([`Self::writer_of`]);
    /// `u32::MAX` for slots no group writes (registers, inputs,
    /// constants).
    slot_writer: Vec<u32>,
}

impl GroupDepGraph {
    pub fn build(ir: &LayerIr, oim: &Oim) -> Self {
        let num_slots = oim.num_slots as usize;
        const NONE: u32 = u32::MAX;
        // slot classification maps
        let mut writer = vec![NONE; num_slots];
        let mut input_of = vec![NONE; num_slots];
        for (i, &s) in ir.input_slots.iter().enumerate() {
            input_of[s as usize] = i as u32;
        }
        let mut commit_of = vec![NONE; num_slots];
        for (ci, &(reg, _, _)) in ir.commits.iter().enumerate() {
            commit_of[reg as usize] = ci as u32;
        }

        let mut g = GroupDepGraph::default();
        // (slot, reader group) pairs, turned into the CSR index below
        let mut reader_edges: Vec<(u32, u32)> = Vec::new();
        let mut op_idx = 0usize;
        let mut r_idx = 0usize;
        for layer in 0..oim.num_layers() {
            for n in 0..NUM_KOPS {
                let cnt = oim.n_payload[layer * NUM_KOPS + n] as usize;
                if cnt == 0 {
                    continue;
                }
                let gid = g.groups.len() as u32;
                let group = Group {
                    layer: layer as u32,
                    opcode: n as u8,
                    op_start: op_idx as u32,
                    op_end: (op_idx + cnt) as u32,
                    r_start: r_idx as u32,
                };
                let mut gdeps: Vec<u32> = Vec::new();
                let mut ideps: Vec<u32> = Vec::new();
                let mut rdeps: Vec<u32> = Vec::new();
                for _ in 0..cnt {
                    let ar = oim.c.arity[op_idx] as usize;
                    for o in 0..ar {
                        let slot = oim.c.r_coords[r_idx + o] as usize;
                        reader_edges.push((slot as u32, gid));
                        let w = writer[slot];
                        if w != NONE {
                            debug_assert!(w < gid, "operand produced in the same layer");
                            gdeps.push(w);
                        } else if input_of[slot] != NONE {
                            ideps.push(input_of[slot]);
                        } else if commit_of[slot] != NONE {
                            rdeps.push(commit_of[slot]);
                        }
                        // else: constant — never changes, no edge
                    }
                    r_idx += ar;
                    op_idx += 1;
                }
                // register this group as the writer of its output slots
                for op in group.op_start..group.op_end {
                    writer[oim.c.s_coords[op as usize] as usize] = gid;
                }
                for d in [&mut gdeps, &mut ideps, &mut rdeps] {
                    d.sort_unstable();
                    d.dedup();
                }
                g.num_edges += gdeps.len() + ideps.len() + rdeps.len();
                g.total_ops += cnt;
                g.groups.push(group);
                g.group_deps.push(gdeps);
                g.input_deps.push(ideps);
                g.reg_deps.push(rdeps);
            }
        }
        debug_assert_eq!(g.total_ops, oim.total_ops());
        // Slot → direct-reader-groups CSR. *Every* operand slot is
        // indexed, including ones the dependency classification above
        // filed as constants: a partitioned IR presents cut registers
        // (committed by another partition, written here only through RUM
        // pokes) with no writer, input port or commit of its own, and
        // targeted invalidation must still find their reader groups.
        reader_edges.sort_unstable();
        reader_edges.dedup();
        let mut offsets = vec![0u32; num_slots + 1];
        for &(s, _) in &reader_edges {
            offsets[s as usize + 1] += 1;
        }
        for i in 1..offsets.len() {
            offsets[i] += offsets[i - 1];
        }
        g.reader_offsets = offsets;
        g.reader_groups = reader_edges.into_iter().map(|(_, gid)| gid).collect();
        g.slot_writer = writer;
        g
    }

    /// Splice a new GDG out of a prior one plus a grafted IR/OIM (the
    /// incremental-compile path). Groups living in layers not marked
    /// `touched` keep their prior dependency lists and slot→reader CSR
    /// entries (group indices remapped by `(layer, opcode)` identity,
    /// which is stable across a graft); groups in touched layers — the
    /// ones whose op composition may have changed — re-run the full
    /// operand classification of [`GroupDepGraph::build`]. Returns the
    /// spliced graph plus `(reused, rebuilt)` group counts. The result is
    /// equal to `build(ir, oim)` whenever the untouched layers really are
    /// unchanged, which the delta pass guarantees: grafted ops only write
    /// fresh slots, surviving ops never change layer or opcode, and a
    /// slot read by a surviving op keeps its writer.
    pub fn splice(
        prior: &GroupDepGraph,
        ir: &LayerIr,
        oim: &Oim,
        touched: &[bool],
    ) -> (Self, usize, usize) {
        use std::collections::HashMap;
        assert_eq!(touched.len(), oim.num_layers(), "touched flags must cover every layer");
        let num_slots = oim.num_slots as usize;
        const NONE: u32 = u32::MAX;
        let mut writer = vec![NONE; num_slots];
        let mut input_of = vec![NONE; num_slots];
        for (i, &s) in ir.input_slots.iter().enumerate() {
            input_of[s as usize] = i as u32;
        }
        let mut commit_of = vec![NONE; num_slots];
        for (ci, &(reg, _, _)) in ir.commits.iter().enumerate() {
            commit_of[reg as usize] = ci as u32;
        }
        let prior_of: HashMap<(u32, u8), u32> = prior
            .groups
            .iter()
            .enumerate()
            .map(|(i, pg)| ((pg.layer, pg.opcode), i as u32))
            .collect();
        let mut new_of_prior = vec![NONE; prior.groups.len()];
        let mut reused_prior = vec![false; prior.groups.len()];
        let (mut reused, mut rebuilt) = (0usize, 0usize);

        let mut g = GroupDepGraph::default();
        let mut reader_edges: Vec<(u32, u32)> = Vec::new();
        let mut op_idx = 0usize;
        let mut r_idx = 0usize;
        for layer in 0..oim.num_layers() {
            for n in 0..NUM_KOPS {
                let cnt = oim.n_payload[layer * NUM_KOPS + n] as usize;
                if cnt == 0 {
                    continue;
                }
                let gid = g.groups.len() as u32;
                let group = Group {
                    layer: layer as u32,
                    opcode: n as u8,
                    op_start: op_idx as u32,
                    op_end: (op_idx + cnt) as u32,
                    r_start: r_idx as u32,
                };
                let prior_gid = prior_of.get(&(layer as u32, n as u8)).copied();
                if let Some(pg) = prior_gid {
                    new_of_prior[pg as usize] = gid;
                }
                // Reuse the prior lists when the layer is untouched and
                // every upstream dep survived (always, by construction —
                // the check is defensive).
                let mut lists: Option<(Vec<u32>, Vec<u32>, Vec<u32>)> = None;
                if !touched[layer] {
                    if let Some(pg) = prior_gid {
                        let pg = pg as usize;
                        let deps = prior.group_deps[pg].iter();
                        let mapped: Vec<u32> = deps.map(|&d| new_of_prior[d as usize]).collect();
                        if !mapped.contains(&NONE) {
                            reused_prior[pg] = true;
                            let ideps = prior.input_deps[pg].clone();
                            let rdeps = prior.reg_deps[pg].clone();
                            lists = Some((mapped, ideps, rdeps));
                        }
                    }
                }
                let (gdeps, ideps, rdeps) = if let Some(l) = lists {
                    reused += 1;
                    for _ in 0..cnt {
                        r_idx += oim.c.arity[op_idx] as usize;
                        op_idx += 1;
                    }
                    l
                } else {
                    rebuilt += 1;
                    let mut gdeps: Vec<u32> = Vec::new();
                    let mut ideps: Vec<u32> = Vec::new();
                    let mut rdeps: Vec<u32> = Vec::new();
                    for _ in 0..cnt {
                        let ar = oim.c.arity[op_idx] as usize;
                        for o in 0..ar {
                            let slot = oim.c.r_coords[r_idx + o] as usize;
                            reader_edges.push((slot as u32, gid));
                            let w = writer[slot];
                            if w != NONE {
                                debug_assert!(w < gid, "operand produced in the same layer");
                                gdeps.push(w);
                            } else if input_of[slot] != NONE {
                                ideps.push(input_of[slot]);
                            } else if commit_of[slot] != NONE {
                                rdeps.push(commit_of[slot]);
                            }
                        }
                        r_idx += ar;
                        op_idx += 1;
                    }
                    for d in [&mut gdeps, &mut ideps, &mut rdeps] {
                        d.sort_unstable();
                        d.dedup();
                    }
                    (gdeps, ideps, rdeps)
                };
                for op in group.op_start..group.op_end {
                    writer[oim.c.s_coords[op as usize] as usize] = gid;
                }
                g.num_edges += gdeps.len() + ideps.len() + rdeps.len();
                g.total_ops += cnt;
                g.groups.push(group);
                g.group_deps.push(gdeps);
                g.input_deps.push(ideps);
                g.reg_deps.push(rdeps);
            }
        }
        debug_assert_eq!(g.total_ops, oim.total_ops());
        // Reader pairs of reused groups carry over from the prior CSR
        // (their operand sets are unchanged); rebuilt groups contributed
        // theirs during the scan above.
        let prior_slots = prior.reader_offsets.len().saturating_sub(1);
        for slot in 0..prior_slots {
            for &pg in prior.readers_of(slot as u32) {
                if reused_prior[pg as usize] {
                    reader_edges.push((slot as u32, new_of_prior[pg as usize]));
                }
            }
        }
        reader_edges.sort_unstable();
        reader_edges.dedup();
        let mut offsets = vec![0u32; num_slots + 1];
        for &(s, _) in &reader_edges {
            offsets[s as usize + 1] += 1;
        }
        for i in 1..offsets.len() {
            offsets[i] += offsets[i - 1];
        }
        g.reader_offsets = offsets;
        g.reader_groups = reader_edges.into_iter().map(|(_, gid)| gid).collect();
        g.slot_writer = writer;
        (g, reused, rebuilt)
    }

    /// The group that writes `slot` within the cycle, if any (`None` for
    /// registers, input ports, constants and out-of-range slots). An
    /// out-of-band write to an op-*output* slot must re-run this group so
    /// the poked value is overwritten exactly as a dense step would
    /// overwrite it.
    #[inline]
    pub fn writer_of(&self, slot: u32) -> Option<u32> {
        match self.slot_writer.get(slot as usize) {
            Some(&w) if w != u32::MAX => Some(w),
            _ => None,
        }
    }

    /// Serialize for the service design cache. Everything is stored —
    /// including the private slot→reader CSR and the slot→writer map —
    /// so a cached load skips the `build` pass entirely.
    pub fn to_json(&self) -> Json {
        let flat_csr = |lists: &[Vec<u32>]| -> (Vec<u32>, Vec<u32>) {
            let mut offsets = Vec::with_capacity(lists.len() + 1);
            let mut flat = Vec::new();
            offsets.push(0u32);
            for l in lists {
                flat.extend_from_slice(l);
                offsets.push(flat.len() as u32);
            }
            (offsets, flat)
        };
        let (gd_off, gd) = flat_csr(&self.group_deps);
        let (id_off, id) = flat_csr(&self.input_deps);
        let (rd_off, rd) = flat_csr(&self.reg_deps);
        obj(vec![
            ("layer", arr_u32(&self.groups.iter().map(|g| g.layer).collect::<Vec<_>>())),
            (
                "opcode",
                Json::Arr(self.groups.iter().map(|g| Json::Int(g.opcode as i64)).collect()),
            ),
            ("op_start", arr_u32(&self.groups.iter().map(|g| g.op_start).collect::<Vec<_>>())),
            ("op_end", arr_u32(&self.groups.iter().map(|g| g.op_end).collect::<Vec<_>>())),
            ("r_start", arr_u32(&self.groups.iter().map(|g| g.r_start).collect::<Vec<_>>())),
            ("group_dep_offsets", arr_u32(&gd_off)),
            ("group_deps", arr_u32(&gd)),
            ("input_dep_offsets", arr_u32(&id_off)),
            ("input_deps", arr_u32(&id)),
            ("reg_dep_offsets", arr_u32(&rd_off)),
            ("reg_deps", arr_u32(&rd)),
            ("num_edges", Json::Int(self.num_edges as i64)),
            ("total_ops", Json::Int(self.total_ops as i64)),
            ("reader_offsets", arr_u32(&self.reader_offsets)),
            ("reader_groups", arr_u32(&self.reader_groups)),
            ("slot_writer", arr_u32(&self.slot_writer)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self, JsonError> {
        let unflatten = |key: &str| -> Result<Vec<Vec<u32>>, JsonError> {
            let offsets = j.req_u32_vec(&format!("{key}_offsets"))?;
            let flat = j.req_u32_vec(key)?;
            let mut lists = Vec::with_capacity(offsets.len().saturating_sub(1));
            for w in offsets.windows(2) {
                let (a, b) = (w[0] as usize, w[1] as usize);
                if a > b || b > flat.len() {
                    return Err(JsonError::Schema(format!("bad CSR offsets in '{key}'")));
                }
                lists.push(flat[a..b].to_vec());
            }
            Ok(lists)
        };
        let layer = j.req_u32_vec("layer")?;
        let opcode = j.req_u32_vec("opcode")?;
        let op_start = j.req_u32_vec("op_start")?;
        let op_end = j.req_u32_vec("op_end")?;
        let r_start = j.req_u32_vec("r_start")?;
        let n = layer.len();
        if [opcode.len(), op_start.len(), op_end.len(), r_start.len()] != [n; 4] {
            return Err(JsonError::Schema("gdg group arrays disagree on length".into()));
        }
        let groups = (0..n)
            .map(|i| Group {
                layer: layer[i],
                opcode: opcode[i] as u8,
                op_start: op_start[i],
                op_end: op_end[i],
                r_start: r_start[i],
            })
            .collect();
        let g = GroupDepGraph {
            groups,
            group_deps: unflatten("group_deps")?,
            input_deps: unflatten("input_deps")?,
            reg_deps: unflatten("reg_deps")?,
            num_edges: j.req_usize("num_edges")?,
            total_ops: j.req_usize("total_ops")?,
            reader_offsets: j.req_u32_vec("reader_offsets")?,
            reader_groups: j.req_u32_vec("reader_groups")?,
            slot_writer: j.req_u32_vec("slot_writer")?,
        };
        if g.group_deps.len() != n || g.input_deps.len() != n || g.reg_deps.len() != n {
            return Err(JsonError::Schema("gdg dependency CSRs disagree with group count".into()));
        }
        if g.reader_offsets.last().copied().unwrap_or(0) as usize != g.reader_groups.len() {
            return Err(JsonError::Schema("gdg reader CSR is inconsistent".into()));
        }
        Ok(g)
    }

    /// Raw slot→reader CSR (`offsets`, `groups`) and slot→writer map,
    /// exposed for static verification ([`crate::analysis`]) only — the
    /// runtime entry points are [`Self::readers_of`] / [`Self::writer_of`].
    #[inline]
    pub fn reader_csr(&self) -> (&[u32], &[u32], &[u32]) {
        (&self.reader_offsets, &self.reader_groups, &self.slot_writer)
    }

    /// The groups with a direct operand on `slot` (sorted, deduplicated);
    /// empty for unread and out-of-range slots. This is the entry point of
    /// targeted invalidation ([`super::mask::ActivityTracker::note_slot_changed`]):
    /// an out-of-band write to `slot` must re-evaluate exactly these
    /// groups and their transitive descendants.
    #[inline]
    pub fn readers_of(&self, slot: u32) -> &[u32] {
        let s = slot as usize;
        if s + 1 >= self.reader_offsets.len() {
            return &[];
        }
        &self.reader_groups[self.reader_offsets[s] as usize..self.reader_offsets[s + 1] as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::random_circuit;
    use crate::graph::passes::optimize;
    use crate::tensor::ir::lower;
    use crate::util::prng::Rng;

    fn sample(seed: u64, size: usize) -> (GroupDepGraph, LayerIr, Oim) {
        let mut rng = Rng::new(seed);
        let g = random_circuit(&mut rng, size);
        let (opt, _) = optimize(&g);
        let ir = lower(&opt);
        let oim = Oim::from_ir(&ir);
        let gdg = GroupDepGraph::build(&ir, &oim);
        (gdg, ir, oim)
    }

    /// Groups tile the format-C op/r arrays exactly, in topological order,
    /// and every dependency points strictly upward.
    #[test]
    fn groups_tile_format_c_and_deps_are_topological() {
        let (gdg, ir, oim) = sample(31_001, 120);
        assert_eq!(gdg.total_ops, ir.total_ops());
        let mut expect_op = 0u32;
        for (gi, grp) in gdg.groups.iter().enumerate() {
            assert_eq!(grp.op_start, expect_op, "group {gi} op range is contiguous");
            assert!(grp.op_end > grp.op_start);
            expect_op = grp.op_end;
            for op in grp.op_start..grp.op_end {
                assert_eq!(oim.c.opcode[op as usize], grp.opcode);
            }
            if gi > 0 {
                assert!(grp.layer >= gdg.groups[gi - 1].layer, "layer order");
            }
            for &d in &gdg.group_deps[gi] {
                assert!((d as usize) < gi, "dep {d} of group {gi} not upstream");
                assert!(gdg.groups[d as usize].layer < grp.layer, "dep in earlier layer");
            }
            for &i in &gdg.input_deps[gi] {
                assert!((i as usize) < ir.input_slots.len());
            }
            for &c in &gdg.reg_deps[gi] {
                assert!((c as usize) < ir.commits.len());
            }
        }
        assert_eq!(expect_op as usize, oim.total_ops());
    }

    /// Every non-constant operand slot of every op yields its **specific**
    /// dependency edge: an op-output operand must put its writer group in
    /// `group_deps`, an input-port operand its port index in `input_deps`,
    /// a register operand its commit index in `reg_deps` — and constants
    /// contribute nothing. A single dropped edge here would make the
    /// sparse executors skip live work.
    #[test]
    fn every_operand_yields_its_exact_edge() {
        let (gdg, ir, oim) = sample(31_002, 150);
        use std::collections::HashMap;
        let input_of: HashMap<u32, u32> = ir
            .input_slots
            .iter()
            .enumerate()
            .map(|(i, &s)| (s, i as u32))
            .collect();
        let commit_of: HashMap<u32, u32> = ir
            .commits
            .iter()
            .enumerate()
            .map(|(ci, &(r, _, _))| (r, ci as u32))
            .collect();
        // writer map rebuilt incrementally, groups in topological order
        let mut writer: HashMap<u32, u32> = HashMap::new();
        let mut r_idx = 0usize;
        for (gi, grp) in gdg.groups.iter().enumerate() {
            assert_eq!(grp.r_start as usize, r_idx, "group {gi} r range is contiguous");
            for op in grp.op_start..grp.op_end {
                let ar = oim.c.arity[op as usize] as usize;
                for o in 0..ar {
                    let slot = oim.c.r_coords[r_idx + o];
                    if let Some(&w) = writer.get(&slot) {
                        assert!(
                            gdg.group_deps[gi].binary_search(&w).is_ok(),
                            "group {gi} reads slot {slot} written by group {w}, edge missing"
                        );
                    } else if let Some(&i) = input_of.get(&slot) {
                        assert!(
                            gdg.input_deps[gi].binary_search(&i).is_ok(),
                            "group {gi} reads input port {i} (slot {slot}), edge missing"
                        );
                    } else if let Some(&ci) = commit_of.get(&slot) {
                        assert!(
                            gdg.reg_deps[gi].binary_search(&ci).is_ok(),
                            "group {gi} reads register commit {ci} (slot {slot}), edge missing"
                        );
                    }
                    // else: constant — correctly contributes no edge
                }
                r_idx += ar;
            }
            for op in grp.op_start..grp.op_end {
                writer.insert(oim.c.s_coords[op as usize], gi as u32);
            }
        }
        // and no phantom edges: every listed dep is justified by some operand
        for (gi, deps) in gdg.group_deps.iter().enumerate() {
            for &d in deps {
                assert!((d as usize) < gi, "group {gi} has non-topological dep {d}");
            }
        }
    }

    /// JSON round-trip reproduces every field, including the private
    /// reader CSR and writer map the design cache depends on.
    #[test]
    fn json_roundtrip_is_exact() {
        let (gdg, _ir, oim) = sample(31_004, 140);
        let text = gdg.to_json().to_string();
        let back =
            GroupDepGraph::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.groups.len(), gdg.groups.len());
        for (a, b) in back.groups.iter().zip(&gdg.groups) {
            assert_eq!(
                (a.layer, a.opcode, a.op_start, a.op_end, a.r_start),
                (b.layer, b.opcode, b.op_start, b.op_end, b.r_start)
            );
        }
        assert_eq!(back.group_deps, gdg.group_deps);
        assert_eq!(back.input_deps, gdg.input_deps);
        assert_eq!(back.reg_deps, gdg.reg_deps);
        assert_eq!(back.num_edges, gdg.num_edges);
        assert_eq!(back.total_ops, gdg.total_ops);
        for slot in 0..oim.num_slots {
            assert_eq!(back.readers_of(slot), gdg.readers_of(slot));
            assert_eq!(back.writer_of(slot), gdg.writer_of(slot));
        }
        // corruption is a schema error, not a panic
        let j = crate::util::json::parse(&text).unwrap();
        let mut o = j.as_obj().unwrap().clone();
        o.insert("reader_offsets".into(), crate::util::json::arr_u32(&[0, 999]));
        assert!(GroupDepGraph::from_json(&Json::Obj(o)).is_err());
    }

    /// The slot → reader-groups index is exact: `readers_of(slot)` lists
    /// precisely the groups with a direct operand on that slot (every
    /// operand slot is indexed, constants included), and unread or
    /// out-of-range slots return the empty slice.
    #[test]
    fn slot_reader_index_is_exact() {
        let (gdg, _ir, oim) = sample(31_003, 130);
        use std::collections::{BTreeMap, BTreeSet};
        let mut want: BTreeMap<u32, BTreeSet<u32>> = BTreeMap::new();
        let mut r_idx = 0usize;
        for (gi, grp) in gdg.groups.iter().enumerate() {
            for op in grp.op_start..grp.op_end {
                let ar = oim.c.arity[op as usize] as usize;
                for o in 0..ar {
                    want.entry(oim.c.r_coords[r_idx + o]).or_default().insert(gi as u32);
                }
                r_idx += ar;
            }
        }
        // writer map: the last group writing a slot (in group order) owns it
        let mut want_writer: BTreeMap<u32, u32> = BTreeMap::new();
        for (gi, grp) in gdg.groups.iter().enumerate() {
            for op in grp.op_start..grp.op_end {
                want_writer.insert(oim.c.s_coords[op as usize], gi as u32);
            }
        }
        for slot in 0..oim.num_slots {
            let got: BTreeSet<u32> = gdg.readers_of(slot).iter().copied().collect();
            assert_eq!(
                got.len(),
                gdg.readers_of(slot).len(),
                "slot {slot}: reader list must be deduplicated"
            );
            let expect = want.get(&slot).cloned().unwrap_or_default();
            assert_eq!(got, expect, "slot {slot}: reader set");
            assert_eq!(
                gdg.writer_of(slot),
                want_writer.get(&slot).copied(),
                "slot {slot}: writer group"
            );
        }
        assert!(gdg.readers_of(oim.num_slots + 7).is_empty(), "out-of-range slot");
        assert_eq!(gdg.writer_of(oim.num_slots + 7), None, "out-of-range slot writer");
    }
}
