//! Partition-level lane activity masks for the batched partitioned
//! simulator ([`crate::coordinator::parallel::BatchParallelSim`]).
//!
//! Where [`super::mask::ActivityTracker`] gates individual (layer,
//! op-type) groups inside one kernel, this tracker gates whole
//! *partitions* of a RepCut-style partitioned run: a partition is skipped
//! for a cycle when no input port it reads changed in any lane **and** no
//! register it reads (its own or a RUM cut register) changed at the last
//! commit. The per-partition boundary sets come from the ownership map
//! computed by [`crate::partition::partition_ir`]
//! ([`PartitionTracker::for_partitioning`]) and are valid for *any*
//! [`crate::partition::Partitioner`] — skipping exactness depends only
//! on cone closure, not on which partition owns which register. Because every combinational slot of a partition is a pure
//! function of exactly those boundary sources, a skipped partition's slot
//! file — including the registers it would have committed — is identical
//! to what stepping it would produce, so skipping is exact.
//!
//! The coordinator supplies the two boundary signals: per-port input
//! change masks (compared against the previous cycle's stimulus) before
//! stepping, and per-register change masks (observed during the RUM
//! exchange, which already compares old vs new lane values) after
//! stepping. Register changes feed the *next* cycle's masks — matching
//! register semantics, where a value committed at the end of cycle `k`
//! is first visible in cycle `k + 1`.

use super::full_mask;

/// Cumulative partition-level activity accounting. One *partition-cycle*
/// is one partition stepped (all lanes) in one cycle — the unit of work a
/// dense partitioned run spends `total_partition_cycles` of.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PartitionActivity {
    /// Cycles stepped.
    pub cycles: u64,
    /// (partition, cycle) units actually stepped.
    pub stepped_partition_cycles: u64,
    /// (partition, cycle) units a dense run would step.
    pub total_partition_cycles: u64,
}

impl PartitionActivity {
    /// Fraction of partition-cycles skipped (0 = dense, →1 = idle).
    pub fn skip_rate(&self) -> f64 {
        if self.total_partition_cycles == 0 {
            0.0
        } else {
            1.0 - self.stepped_partition_cycles as f64 / self.total_partition_cycles as f64
        }
    }

    /// Stats accumulated since an earlier snapshot `base` of the same run.
    pub fn since(&self, base: &PartitionActivity) -> PartitionActivity {
        PartitionActivity {
            cycles: self.cycles - base.cycles,
            stepped_partition_cycles: self.stepped_partition_cycles
                - base.stepped_partition_cycles,
            total_partition_cycles: self.total_partition_cycles - base.total_partition_cycles,
        }
    }
}

/// Per-cycle partition activity state (`lanes ≤ 64`, one mask bit per
/// lane, as in [`super::mask::ActivityTracker`]).
#[derive(Clone, Debug)]
pub struct PartitionTracker {
    pub lanes: usize,
    /// The all-lanes mask (`lanes` low bits set).
    pub full: u64,
    /// Input-port indices read by each partition's cone.
    input_deps: Vec<Vec<u32>>,
    /// Register-change masks accumulated for the *next* cycle, per
    /// partition (filled by [`Self::note_reg_change`] after stepping).
    pending: Vec<u64>,
    /// This cycle's active-lane mask per partition.
    active: Vec<u64>,
    /// First cycle (or post-poke): step everything once to establish all
    /// combinational slot values.
    cold: bool,
    stats: PartitionActivity,
}

impl PartitionTracker {
    /// Build a tracker keyed off a [`crate::partition::Partitioning`]'s
    /// ownership map: one gate per partition, watching exactly the input
    /// ports that partition's cone reads. (Register-side gating comes
    /// from the coordinator's RUM exchange, which already walks the
    /// partitioning's tracked-register table.)
    pub fn for_partitioning(parting: &crate::partition::Partitioning, lanes: usize) -> Self {
        Self::new(parting.input_deps.clone(), lanes)
    }

    /// `input_deps[p]` lists the input-port indices partition `p` reads.
    pub fn new(input_deps: Vec<Vec<u32>>, lanes: usize) -> Self {
        let full = full_mask(lanes);
        let parts = input_deps.len();
        PartitionTracker {
            lanes,
            full,
            input_deps,
            pending: vec![0; parts],
            active: vec![0; parts],
            cold: true,
            stats: PartitionActivity::default(),
        }
    }

    /// Compute this cycle's per-partition activity masks from the pending
    /// register changes and the per-port input change masks. Call once per
    /// cycle, before stepping the partitions.
    pub fn begin_cycle(&mut self, input_changed: &[u64]) {
        if self.cold {
            self.cold = false;
            for a in &mut self.active {
                *a = self.full;
            }
        } else {
            for p in 0..self.active.len() {
                let mut m = self.pending[p];
                for &i in &self.input_deps[p] {
                    m |= input_changed[i as usize];
                }
                self.active[p] = m;
            }
        }
        for x in &mut self.pending {
            *x = 0;
        }
        self.stats.cycles += 1;
        self.stats.total_partition_cycles += self.active.len() as u64;
        self.stats.stepped_partition_cycles +=
            self.active.iter().filter(|&&m| m != 0).count() as u64;
    }

    /// Whether partition `p` must step this cycle.
    #[inline]
    pub fn is_active(&self, p: usize) -> bool {
        self.active[p] != 0
    }

    /// The lane mask partition `p` is active in this cycle: a clear bit
    /// proves no boundary source partition `p`'s cone reads (input port
    /// or cut register) changed in that lane, so everything the
    /// partition computes there — combinational slots and commits alike
    /// — is bit-identical to the previous cycle (the delta-waveform
    /// sink's per-lane skip oracle, [`crate::activity::WaveMasks`]).
    pub fn active_mask(&self, p: usize) -> u64 {
        self.active[p]
    }

    /// Record that a register read by `readers` changed in the lanes of
    /// `mask` — those partitions must step next cycle. Drives both the
    /// RUM exchange's differential change bits and the coordinator's
    /// targeted `poke_lane` wake (readers ∪ owner of the poked slot).
    pub fn note_reg_change(&mut self, readers: &[u32], mask: u64) {
        for &r in readers {
            self.pending[r as usize] |= mask;
        }
    }

    /// Conservative fallback of [`Self::note_reg_change`] for a slot the
    /// partitioning has no reader/owner record of: every partition steps
    /// in the lanes of `mask` next cycle.
    pub fn note_all(&mut self, mask: u64) {
        for p in &mut self.pending {
            *p |= mask;
        }
    }

    /// Invalidate all cached slot values: the next cycle steps every
    /// partition in every lane. An explicit full-invalidate escape hatch
    /// (and test aid); production out-of-band writes take the targeted
    /// [`Self::note_reg_change`] / [`Self::note_all`] path instead.
    pub fn force_recold(&mut self) {
        self.cold = true;
    }

    pub fn stats(&self) -> PartitionActivity {
        self.stats
    }

    /// Flat dump of the tracker's dynamic state for checkpointing:
    /// `[cold, pending.., active..]`. `pending` is live between cycles
    /// (the RUM exchange feeds it after stepping), so bit-identical
    /// restore must carry it; stats are excluded.
    pub fn export_state(&self) -> Vec<u64> {
        let mut v = Vec::with_capacity(1 + 2 * self.pending.len());
        v.push(self.cold as u64);
        v.extend_from_slice(&self.pending);
        v.extend_from_slice(&self.active);
        v
    }

    /// Restore state captured by [`Self::export_state`] on a tracker of
    /// the same shape.
    pub fn import_state(&mut self, data: &[u64]) -> Result<(), String> {
        let want = 1 + 2 * self.pending.len();
        if data.len() != want {
            return Err(format!(
                "partition tracker state has {} words, expected {want}",
                data.len()
            ));
        }
        self.cold = data[0] != 0;
        let parts = self.pending.len();
        self.pending.copy_from_slice(&data[1..1 + parts]);
        self.active.copy_from_slice(&data[1 + parts..1 + 2 * parts]);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Masks follow exactly the boundary sources that changed: input
    /// changes gate the partitions whose cones read the port this cycle,
    /// register changes gate their readers the following cycle.
    #[test]
    fn masks_follow_inputs_and_registers() {
        // partition 0 reads port 0, partition 1 reads port 1, partition 2
        // reads no inputs (register-driven only)
        let mut t = PartitionTracker::new(vec![vec![0], vec![1], vec![]], 4);
        assert_eq!(t.full, 0b1111);

        // cold cycle: everything steps
        t.begin_cycle(&[0, 0]);
        assert!(t.is_active(0) && t.is_active(1) && t.is_active(2));

        // port 0 changed in lane 2 only → partition 0 alone
        t.begin_cycle(&[0b0100, 0]);
        assert!(t.is_active(0));
        assert!(!t.is_active(1));
        assert!(!t.is_active(2));

        // a register read by partitions 1 and 2 changed in lanes 0, 3
        t.note_reg_change(&[1, 2], 0b1001);
        t.begin_cycle(&[0, 0]);
        assert!(!t.is_active(0));
        assert!(t.is_active(1));
        assert!(t.is_active(2));

        // quiescent
        t.begin_cycle(&[0, 0]);
        assert!(!t.is_active(0) && !t.is_active(1) && !t.is_active(2));

        let s = t.stats();
        assert_eq!(s.cycles, 4);
        assert_eq!(s.total_partition_cycles, 12);
        assert_eq!(s.stepped_partition_cycles, 3 + 1 + 2);
        assert!((s.skip_rate() - 0.5).abs() < 1e-12);

        // recold forces a full cycle again
        t.force_recold();
        t.begin_cycle(&[0, 0]);
        assert!(t.is_active(0) && t.is_active(1) && t.is_active(2));
    }

    #[test]
    fn partition_activity_since_arithmetic() {
        let a = PartitionActivity {
            cycles: 10,
            stepped_partition_cycles: 5,
            total_partition_cycles: 40,
        };
        let b = PartitionActivity {
            cycles: 4,
            stepped_partition_cycles: 5,
            total_partition_cycles: 16,
        };
        let d = a.since(&b);
        assert_eq!(d.cycles, 6);
        assert_eq!(d.stepped_partition_cycles, 0);
        assert_eq!(d.total_partition_cycles, 24);
        assert!((d.skip_rate() - 1.0).abs() < 1e-12);
        assert_eq!(PartitionActivity::default().skip_rate(), 0.0);
    }
}
