//! Runtime lane activity masks: one `u64` per group, one bit per lane.
//!
//! Change detection happens only at the cycle boundaries — the batched
//! driver's tracked input writes and register commits fill the
//! `input_changed` / `reg_changed` masks (see
//! [`crate::kernels::common::BatchDriver::set_inputs_tracked`] /
//! [`commit_tracked`](crate::kernels::common::BatchDriver::commit_tracked)).
//! [`ActivityTracker::begin_cycle`] then propagates them through the GDG
//! in one forward sweep: a group's mask is the OR of its direct input /
//! register sources and its upstream groups' masks (already computed —
//! the GDG is in topological order). This is conservative (a changed
//! source does not guarantee a changed output) but never misses work, and
//! it costs `O(edges)` per cycle regardless of `B`.
//!
//! Out-of-band slot writes (`poke_lane` for divergent-lane init, the
//! partitioned simulator's RUM pokes) bypass the boundary detectors; they
//! feed [`ActivityTracker::note_slot_changed`] instead, which marks the
//! written slot's direct reader groups pending in the written lanes via
//! the GDG's slot → reader-groups index — the forward sweep then wakes
//! exactly the poked slot's descendants in exactly the poked lanes,
//! rather than recolding every group in every lane.

use super::gdg::GroupDepGraph;
use super::{full_mask, ActivityStats};

/// Per-cycle activity state for one sparse batched kernel instance.
#[derive(Clone, Debug)]
pub struct ActivityTracker {
    pub gdg: GroupDepGraph,
    pub lanes: usize,
    /// The all-lanes mask (`lanes` low bits set).
    pub full: u64,
    /// Lanes whose value changed, per input port (filled by the driver).
    pub input_changed: Vec<u64>,
    /// Lanes whose register changed at the last commit, per commit index.
    pub reg_changed: Vec<u64>,
    /// Active lanes per group, recomputed each cycle.
    pub active: Vec<u64>,
    /// Targeted out-of-band invalidations for the next cycle, per group
    /// (filled by [`Self::note_slot_changed`], consumed and cleared by
    /// [`Self::begin_cycle`]).
    pending: Vec<u64>,
    /// First cycle: run everything once to establish all combinational
    /// slot values.
    cold: bool,
    stats: ActivityStats,
}

impl ActivityTracker {
    /// `num_inputs` / `num_commits` are the design's input-port and
    /// register-commit counts (`LayerIr::input_slots` / `commits` lengths).
    pub fn new(gdg: GroupDepGraph, num_inputs: usize, num_commits: usize, lanes: usize) -> Self {
        let full = full_mask(lanes);
        let groups = gdg.groups.len();
        ActivityTracker {
            gdg,
            lanes,
            full,
            input_changed: vec![0; num_inputs],
            reg_changed: vec![0; num_commits],
            active: vec![0; groups],
            pending: vec![0; groups],
            cold: true,
            stats: ActivityStats::default(),
        }
    }

    /// Compute this cycle's per-group activity masks from the boundary
    /// change masks, then clear them for the next cycle. Call after the
    /// tracked input write and before walking the groups.
    pub fn begin_cycle(&mut self) {
        if self.cold {
            self.cold = false;
            for a in &mut self.active {
                *a = self.full;
            }
        } else {
            for g in 0..self.gdg.groups.len() {
                // pending carries targeted out-of-band invalidations; the
                // forward sweep below propagates them (like every other
                // source) to all transitive descendants within this cycle
                let mut m = self.pending[g];
                for &i in &self.gdg.input_deps[g] {
                    m |= self.input_changed[i as usize];
                }
                for &c in &self.gdg.reg_deps[g] {
                    m |= self.reg_changed[c as usize];
                }
                for &h in &self.gdg.group_deps[g] {
                    m |= self.active[h as usize];
                }
                self.active[g] = m;
            }
        }
        for x in &mut self.input_changed {
            *x = 0;
        }
        for x in &mut self.reg_changed {
            *x = 0;
        }
        for x in &mut self.pending {
            *x = 0;
        }
        self.stats.cycles += 1;
        self.stats.total_op_lanes += (self.gdg.total_ops * self.lanes) as u64;
        for (g, &m) in self.active.iter().enumerate() {
            self.stats.evaluated_op_lanes +=
                m.count_ones() as u64 * self.gdg.groups[g].ops() as u64;
        }
    }

    /// Targeted invalidation for an out-of-band slot write (`poke_lane`,
    /// partitioned RUM pokes): OR `lane_mask` into the pending masks of
    /// the groups that read `slot` directly ([`GroupDepGraph::readers_of`])
    /// — plus the group that *writes* it, if any: a dense step recomputes
    /// an op-output slot from its operands (overwriting the poke), so
    /// re-running the writer is what keeps pokes of non-register slots
    /// dense-equivalent. The next [`Self::begin_cycle`] forward sweep
    /// carries the mask to every transitive descendant, so exactly the
    /// cone around the written slot re-evaluates, in exactly the written
    /// lanes — replacing the all-groups/all-lanes recold these writes
    /// used to pay.
    pub fn note_slot_changed(&mut self, slot: u32, lane_mask: u64) {
        if let Some(w) = self.gdg.writer_of(slot) {
            self.pending[w as usize] |= lane_mask;
        }
        for &gid in self.gdg.readers_of(slot) {
            self.pending[gid as usize] |= lane_mask;
        }
    }

    /// Invalidate all cached slot values: the next cycle runs every group
    /// in every lane. An explicit full-invalidate escape hatch (and test
    /// aid); production out-of-band writes use the targeted
    /// [`Self::note_slot_changed`] instead.
    pub fn force_recold(&mut self) {
        self.cold = true;
    }

    pub fn stats(&self) -> ActivityStats {
        self.stats
    }

    /// Flat dump of the tracker's *dynamic* state for checkpointing:
    /// `[cold, input_changed.., reg_changed.., pending.., active..]`.
    /// Between cycles `reg_changed` (filled at the last commit) and
    /// `pending` (filled by out-of-band pokes, e.g. the RUM exchange) are
    /// live — tracker masks are real simulator state, not a cache — so a
    /// bit-identical restore must carry them. Stats are deliberately
    /// excluded (accounting, not semantics).
    pub fn export_state(&self) -> Vec<u64> {
        let mut v = Vec::with_capacity(
            1 + self.input_changed.len() + self.reg_changed.len() + 2 * self.pending.len(),
        );
        v.push(self.cold as u64);
        v.extend_from_slice(&self.input_changed);
        v.extend_from_slice(&self.reg_changed);
        v.extend_from_slice(&self.pending);
        v.extend_from_slice(&self.active);
        v
    }

    /// Restore state captured by [`Self::export_state`] on a tracker of
    /// the same shape.
    pub fn import_state(&mut self, data: &[u64]) -> Result<(), String> {
        let want =
            1 + self.input_changed.len() + self.reg_changed.len() + 2 * self.pending.len();
        if data.len() != want {
            return Err(format!(
                "activity tracker state has {} words, expected {want}",
                data.len()
            ));
        }
        self.cold = data[0] != 0;
        let mut at = 1usize;
        for dst in [&mut self.input_changed, &mut self.reg_changed, &mut self.pending, &mut self.active]
        {
            dst.copy_from_slice(&data[at..at + dst.len()]);
            at += dst.len();
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ops::PrimOp;
    use crate::graph::Graph;
    use crate::tensor::ir::lower;
    use crate::tensor::oim::Oim;

    /// Two independent input cones plus one register cone: masks follow
    /// exactly the sources that changed, per lane.
    #[test]
    fn masks_follow_sources_per_lane() {
        let mut g = Graph::new("t");
        let a = g.input("a", 8);
        let b = g.input("b", 8);
        let na = g.prim(PrimOp::Not, &[a]); // cone A: depends on input 0
        let nb = g.prim(PrimOp::Neg, &[b]); // cone B: depends on input 1
        let r = g.reg("r", 8, 0);
        let nr = g.prim(PrimOp::Orr, &[r]); // cone R: depends on the register
        g.connect_reg(r, na);
        g.output("x", na);
        g.output("y", nb);
        g.output("z", nr);
        let ir = lower(&g);
        let oim = Oim::from_ir(&ir);
        let gdg = GroupDepGraph::build(&ir, &oim);
        // three single-op groups in layer 0 (Not, Neg, Orr — any order)
        assert_eq!(gdg.groups.len(), 3);
        let find = |op: crate::tensor::ir::KOp| {
            gdg.groups.iter().position(|grp| grp.opcode == op as u8).unwrap()
        };
        let ga = find(crate::tensor::ir::KOp::Not);
        let gb = find(crate::tensor::ir::KOp::Neg);
        let gr = find(crate::tensor::ir::KOp::Orr);

        let mut t = ActivityTracker::new(gdg, ir.input_slots.len(), ir.commits.len(), 4);
        // cold cycle: everything active in every lane
        t.begin_cycle();
        assert_eq!(t.active, vec![0b1111; 3]);

        // input 0 changed in lane 2 only; nothing else
        t.input_changed[0] = 0b0100;
        t.begin_cycle();
        assert_eq!(t.active[ga], 0b0100);
        assert_eq!(t.active[gb], 0);
        assert_eq!(t.active[gr], 0);

        // register commit changed in lanes 0 and 3
        t.reg_changed[0] = 0b1001;
        t.begin_cycle();
        assert_eq!(t.active[ga], 0);
        assert_eq!(t.active[gb], 0);
        assert_eq!(t.active[gr], 0b1001);

        // stats: 3 cold-cycle groups × 4 lanes + 1 + 2 op-lanes after
        let s = t.stats();
        assert_eq!(s.cycles, 3);
        assert_eq!(s.total_op_lanes, 3 * 4 * 3);
        assert_eq!(s.evaluated_op_lanes, 12 + 1 + 2);

        // recold forces a full cycle again
        t.force_recold();
        t.begin_cycle();
        assert_eq!(t.active, vec![0b1111; 3]);
    }

    /// Targeted invalidation: a single-slot `note_slot_changed` wakes
    /// exactly the GDG cone around that slot — its writer group (which
    /// must overwrite the poke, as a dense step would), the groups
    /// reading it, and everything transitively downstream — in exactly
    /// the noted lane, and nothing else. A second quiet cycle goes fully
    /// idle (no recold anywhere).
    #[test]
    fn note_slot_changed_wakes_only_descendants_in_the_noted_lane() {
        use crate::tensor::ir::KOp;
        let mut g = Graph::new("poketarget");
        let a = g.input("a", 8);
        let b = g.input("b", 8);
        let x = g.prim(PrimOp::Not, &[a]); // layer 0, cone A
        let w = g.prim(PrimOp::Neg, &[b]); // layer 0, independent cone B
        let y = g.prim(PrimOp::Neg, &[x]); // layer 1, downstream of x
        let z = g.prim(PrimOp::Orr, &[y]); // layer 2, downstream of y
        g.output("z", z);
        g.output("w", w);
        let ir = lower(&g);
        let oim = Oim::from_ir(&ir);
        let gdg = GroupDepGraph::build(&ir, &oim);
        assert_eq!(gdg.groups.len(), 4);
        let find = |layer: u32, op: KOp| {
            gdg.groups
                .iter()
                .position(|grp| grp.layer == layer && grp.opcode == op as u8)
                .unwrap()
        };
        let g_not = find(0, KOp::Not);
        let g_negb = find(0, KOp::Neg);
        let g_negx = find(1, KOp::Neg);
        let g_orr = find(2, KOp::Orr);
        // the slot the layer-0 Not writes (x): read by g_negx, written by
        // g_not
        let x_slot = oim.c.s_coords[gdg.groups[g_not].op_start as usize];
        assert_eq!(gdg.readers_of(x_slot), &[g_negx as u32]);
        assert_eq!(gdg.writer_of(x_slot), Some(g_not as u32));
        // input and register-free slots have no writer group
        assert_eq!(gdg.writer_of(ir.input_slots[0]), None);

        let mut t = ActivityTracker::new(gdg, ir.input_slots.len(), ir.commits.len(), 4);
        t.begin_cycle(); // cold
        assert_eq!(t.active, vec![0b1111; 4]);

        // out-of-band write of x in lane 2 only
        t.note_slot_changed(x_slot, 0b0100);
        t.begin_cycle();
        assert_eq!(t.active[g_not], 0b0100, "x's writer re-runs (overwrites the poke)");
        assert_eq!(t.active[g_negb], 0, "independent cone stays idle");
        assert_eq!(t.active[g_negx], 0b0100, "direct reader wakes in lane 2");
        assert_eq!(t.active[g_orr], 0b0100, "transitive descendant wakes in lane 2");

        // quiet next cycle: the poke was targeted, not a recold
        t.begin_cycle();
        assert_eq!(t.active, vec![0; 4], "no residual activity after the poke drains");

        // a note on an unread slot wakes nothing
        t.note_slot_changed(oim.num_slots + 3, u64::MAX);
        t.begin_cycle();
        assert_eq!(t.active, vec![0; 4]);
    }

    /// A chained design propagates activity transitively through
    /// group-to-group edges within the cycle.
    #[test]
    fn masks_propagate_through_group_chain() {
        let mut g = Graph::new("chain");
        let a = g.input("a", 8);
        let x = g.prim(PrimOp::Not, &[a]);
        let y = g.prim(PrimOp::Neg, &[x]);
        let z = g.prim(PrimOp::Orr, &[y]);
        g.output("z", z);
        let ir = lower(&g);
        let oim = Oim::from_ir(&ir);
        let gdg = GroupDepGraph::build(&ir, &oim);
        assert_eq!(gdg.groups.len(), 3);
        let mut t = ActivityTracker::new(gdg, ir.input_slots.len(), ir.commits.len(), 2);
        t.begin_cycle(); // cold
        t.input_changed[0] = 0b10;
        t.begin_cycle();
        assert_eq!(t.active, vec![0b10; 3], "change reaches every downstream group");
        t.begin_cycle();
        assert_eq!(t.active, vec![0; 3], "quiescent with no boundary changes");
    }
}
