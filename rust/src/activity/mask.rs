//! Runtime lane activity masks: one `u64` per group, one bit per lane.
//!
//! Change detection happens only at the cycle boundaries — the batched
//! driver's tracked input writes and register commits fill the
//! `input_changed` / `reg_changed` masks (see
//! [`crate::kernels::common::BatchDriver::set_inputs_tracked`] /
//! [`commit_tracked`](crate::kernels::common::BatchDriver::commit_tracked)).
//! [`ActivityTracker::begin_cycle`] then propagates them through the GDG
//! in one forward sweep: a group's mask is the OR of its direct input /
//! register sources and its upstream groups' masks (already computed —
//! the GDG is in topological order). This is conservative (a changed
//! source does not guarantee a changed output) but never misses work, and
//! it costs `O(edges)` per cycle regardless of `B`.

use super::gdg::GroupDepGraph;
use super::{full_mask, ActivityStats};

/// Per-cycle activity state for one sparse batched kernel instance.
#[derive(Clone, Debug)]
pub struct ActivityTracker {
    pub gdg: GroupDepGraph,
    pub lanes: usize,
    /// The all-lanes mask (`lanes` low bits set).
    pub full: u64,
    /// Lanes whose value changed, per input port (filled by the driver).
    pub input_changed: Vec<u64>,
    /// Lanes whose register changed at the last commit, per commit index.
    pub reg_changed: Vec<u64>,
    /// Active lanes per group, recomputed each cycle.
    pub active: Vec<u64>,
    /// First cycle (or post-poke): run everything once to establish all
    /// combinational slot values.
    cold: bool,
    stats: ActivityStats,
}

impl ActivityTracker {
    /// `num_inputs` / `num_commits` are the design's input-port and
    /// register-commit counts (`LayerIr::input_slots` / `commits` lengths).
    pub fn new(gdg: GroupDepGraph, num_inputs: usize, num_commits: usize, lanes: usize) -> Self {
        let full = full_mask(lanes);
        let groups = gdg.groups.len();
        ActivityTracker {
            gdg,
            lanes,
            full,
            input_changed: vec![0; num_inputs],
            reg_changed: vec![0; num_commits],
            active: vec![0; groups],
            cold: true,
            stats: ActivityStats::default(),
        }
    }

    /// Compute this cycle's per-group activity masks from the boundary
    /// change masks, then clear them for the next cycle. Call after the
    /// tracked input write and before walking the groups.
    pub fn begin_cycle(&mut self) {
        if self.cold {
            self.cold = false;
            for a in &mut self.active {
                *a = self.full;
            }
        } else {
            for g in 0..self.gdg.groups.len() {
                let mut m = 0u64;
                for &i in &self.gdg.input_deps[g] {
                    m |= self.input_changed[i as usize];
                }
                for &c in &self.gdg.reg_deps[g] {
                    m |= self.reg_changed[c as usize];
                }
                for &h in &self.gdg.group_deps[g] {
                    m |= self.active[h as usize];
                }
                self.active[g] = m;
            }
        }
        for x in &mut self.input_changed {
            *x = 0;
        }
        for x in &mut self.reg_changed {
            *x = 0;
        }
        self.stats.cycles += 1;
        self.stats.total_op_lanes += (self.gdg.total_ops * self.lanes) as u64;
        for (g, &m) in self.active.iter().enumerate() {
            self.stats.evaluated_op_lanes +=
                m.count_ones() as u64 * self.gdg.groups[g].ops() as u64;
        }
    }

    /// Invalidate all cached slot values: the next cycle runs every group
    /// in every lane. Used after out-of-band slot writes (`poke_lane`),
    /// which bypass boundary change detection.
    pub fn force_recold(&mut self) {
        self.cold = true;
    }

    pub fn stats(&self) -> ActivityStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ops::PrimOp;
    use crate::graph::Graph;
    use crate::tensor::ir::lower;
    use crate::tensor::oim::Oim;

    /// Two independent input cones plus one register cone: masks follow
    /// exactly the sources that changed, per lane.
    #[test]
    fn masks_follow_sources_per_lane() {
        let mut g = Graph::new("t");
        let a = g.input("a", 8);
        let b = g.input("b", 8);
        let na = g.prim(PrimOp::Not, &[a]); // cone A: depends on input 0
        let nb = g.prim(PrimOp::Neg, &[b]); // cone B: depends on input 1
        let r = g.reg("r", 8, 0);
        let nr = g.prim(PrimOp::Orr, &[r]); // cone R: depends on the register
        g.connect_reg(r, na);
        g.output("x", na);
        g.output("y", nb);
        g.output("z", nr);
        let ir = lower(&g);
        let oim = Oim::from_ir(&ir);
        let gdg = GroupDepGraph::build(&ir, &oim);
        // three single-op groups in layer 0 (Not, Neg, Orr — any order)
        assert_eq!(gdg.groups.len(), 3);
        let find = |op: crate::tensor::ir::KOp| {
            gdg.groups.iter().position(|grp| grp.opcode == op as u8).unwrap()
        };
        let ga = find(crate::tensor::ir::KOp::Not);
        let gb = find(crate::tensor::ir::KOp::Neg);
        let gr = find(crate::tensor::ir::KOp::Orr);

        let mut t = ActivityTracker::new(gdg, ir.input_slots.len(), ir.commits.len(), 4);
        // cold cycle: everything active in every lane
        t.begin_cycle();
        assert_eq!(t.active, vec![0b1111; 3]);

        // input 0 changed in lane 2 only; nothing else
        t.input_changed[0] = 0b0100;
        t.begin_cycle();
        assert_eq!(t.active[ga], 0b0100);
        assert_eq!(t.active[gb], 0);
        assert_eq!(t.active[gr], 0);

        // register commit changed in lanes 0 and 3
        t.reg_changed[0] = 0b1001;
        t.begin_cycle();
        assert_eq!(t.active[ga], 0);
        assert_eq!(t.active[gb], 0);
        assert_eq!(t.active[gr], 0b1001);

        // stats: 3 cold-cycle groups × 4 lanes + 1 + 2 op-lanes after
        let s = t.stats();
        assert_eq!(s.cycles, 3);
        assert_eq!(s.total_op_lanes, 3 * 4 * 3);
        assert_eq!(s.evaluated_op_lanes, 12 + 1 + 2);

        // recold forces a full cycle again
        t.force_recold();
        t.begin_cycle();
        assert_eq!(t.active, vec![0b1111; 3]);
    }

    /// A chained design propagates activity transitively through
    /// group-to-group edges within the cycle.
    #[test]
    fn masks_propagate_through_group_chain() {
        let mut g = Graph::new("chain");
        let a = g.input("a", 8);
        let x = g.prim(PrimOp::Not, &[a]);
        let y = g.prim(PrimOp::Neg, &[x]);
        let z = g.prim(PrimOp::Orr, &[y]);
        g.output("z", z);
        let ir = lower(&g);
        let oim = Oim::from_ir(&ir);
        let gdg = GroupDepGraph::build(&ir, &oim);
        assert_eq!(gdg.groups.len(), 3);
        let mut t = ActivityTracker::new(gdg, ir.input_slots.len(), ir.commits.len(), 2);
        t.begin_cycle(); // cold
        t.input_changed[0] = 0b10;
        t.begin_cycle();
        assert_eq!(t.active, vec![0b10; 3], "change reaches every downstream group");
        t.begin_cycle();
        assert_eq!(t.active, vec![0; 3], "quiescent with no boundary changes");
    }
}
