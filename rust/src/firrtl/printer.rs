//! Graph → FIRRTL text. Used by the synthetic design generators to emit
//! `.fir` files and by round-trip tests. Inverse of [`super::parse`] up to
//! node naming: parsing re-infers widths, so the printer inserts explicit
//! `bits`/`pad` adjustments wherever a node's declared width differs from
//! the FIRRTL-inferred width of its expression.

use std::fmt::Write as _;

use crate::graph::ops::{result_width, PrimOp};
use crate::graph::{Graph, NodeId, NodeKind};

/// Render a graph as parseable FIRRTL text.
pub fn print(g: &Graph) -> String {
    let mut out = String::new();
    let name = if g.name.is_empty() { "Top" } else { &g.name };
    let _ = writeln!(out, "circuit {name} :");
    let _ = writeln!(out, "  module {name} :");
    let _ = writeln!(out, "    input clock : Clock");

    // Stable, collision-free names: ports and regs keep their names,
    // everything else becomes _n<id>.
    let node_name = |id: NodeId| -> String {
        let n = &g.nodes[id as usize];
        match n.kind {
            NodeKind::Input(i) => sanitize(&g.inputs[i as usize].name),
            NodeKind::Reg(r) => sanitize(&g.regs[r as usize].name),
            _ => format!("_n{id}"),
        }
    };

    for p in &g.inputs {
        let _ = writeln!(out, "    input {} : UInt<{}>", sanitize(&p.name), p.width);
    }
    for (i, (oname, src)) in g.outputs.iter().enumerate() {
        let _ = writeln!(out, "    output {} : UInt<{}>", sanitize_out(oname, i), g.width(*src));
    }
    let _ = writeln!(out);
    for r in &g.regs {
        let _ = writeln!(
            out,
            "    reg {} : UInt<{}>, clock with : (reset => (reset, UInt<{}>({})))",
            sanitize(&r.name),
            r.width,
            r.width,
            r.init
        );
    }

    for id in 0..g.nodes.len() as NodeId {
        let n = &g.nodes[id as usize];
        match n.kind {
            NodeKind::Const(c) => {
                let _ = writeln!(out, "    node _n{id} = UInt<{}>({})", n.width, c);
            }
            NodeKind::Prim(op) => {
                let expr = prim_expr(g, op, &n.args, n.width, &node_name);
                let _ = writeln!(out, "    node _n{id} = {expr}");
            }
            _ => {}
        }
    }

    let _ = writeln!(out);
    for r in &g.regs {
        let _ = writeln!(out, "    {} <= {}", sanitize(&r.name), node_name(r.next));
    }
    for (i, (oname, src)) in g.outputs.iter().enumerate() {
        let _ = writeln!(out, "    {} <= {}", sanitize_out(oname, i), node_name(*src));
    }
    out
}

/// FIRRTL identifiers: [A-Za-z_][A-Za-z0-9_$]*
fn sanitize(name: &str) -> String {
    let mut s: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' || c == '$' { c } else { '_' })
        .collect();
    if s.is_empty() || s.chars().next().unwrap().is_ascii_digit() {
        s.insert(0, '_');
    }
    s
}

fn sanitize_out(name: &str, idx: usize) -> String {
    let s = sanitize(name);
    // Output names may collide with internal signals; suffix with index
    // only when the raw name is empty.
    if s.is_empty() {
        format!("out{idx}")
    } else {
        s
    }
}

/// Render a primitive op expression, fixing up declared-vs-inferred width.
fn prim_expr(g: &Graph, op: PrimOp, args: &[NodeId], declared: u8, name: &dyn Fn(NodeId) -> String) -> String {
    let widths: Vec<u8> = args.iter().map(|&a| g.width(a)).collect();
    let base = match op {
        PrimOp::MuxChain(k) => {
            // De-fuse into nested muxes (MuxChain is internal, not FIRRTL).
            let k = k as usize;
            let mut expr = name(args[2 * k]);
            let mut w = g.width(args[2 * k]);
            for i in (0..k).rev() {
                let vw = g.width(args[2 * i + 1]);
                w = w.max(vw);
                expr = format!("mux({}, {}, {})", name(args[2 * i]), name(args[2 * i + 1]), expr);
            }
            return fix_width(expr, w, declared);
        }
        PrimOp::Shl(n) => format!("shl({}, {n})", name(args[0])),
        PrimOp::Shr(n) => format!("shr({}, {n})", name(args[0])),
        PrimOp::Bits(hi, lo) => format!("bits({}, {hi}, {lo})", name(args[0])),
        PrimOp::Head(n) => format!("head({}, {n})", name(args[0])),
        PrimOp::Tail(n) => format!("tail({}, {n})", name(args[0])),
        PrimOp::Pad(n) => format!("pad({}, {n})", name(args[0])),
        PrimOp::Id => format!("asUInt({})", name(args[0])),
        _ => {
            let parts: Vec<String> = args.iter().map(|&a| name(a)).collect();
            format!("{}({})", op.mnemonic(), parts.join(", "))
        }
    };
    fix_width(base, result_width(op, &widths), declared)
}

fn fix_width(expr: String, inferred: u8, declared: u8) -> String {
    if inferred == declared {
        expr
    } else if inferred > declared {
        format!("bits({expr}, {}, 0)", declared - 1)
    } else {
        format!("pad({expr}, {declared})")
    }
}

#[cfg(test)]
mod tests {
    use crate::graph::ops::PrimOp;
    use crate::graph::{Graph, RefSim};

    #[test]
    fn prints_and_reparses_width_mismatches() {
        let mut g = Graph::new("W");
        let a = g.input("a", 8);
        let b = g.input("b", 8);
        // declared width narrower than inferred (add -> 9, declared 8)
        let s = g.prim_w(PrimOp::Add, &[a, b], 8);
        // declared wider than inferred
        let x = g.prim_w(PrimOp::Xor, &[a, b], 12);
        let c = g.prim(PrimOp::Cat, &[s, x]);
        g.output("o", c);
        let text = super::print(&g);
        let g2 = crate::firrtl::parse(&text).expect(&text);
        let mut s1 = RefSim::new(g);
        let mut s2 = RefSim::new(g2);
        s1.step(&[200, 100]);
        s2.step(&[200, 100]);
        assert_eq!(s1.outputs(), s2.outputs());
    }

    #[test]
    fn muxchain_defuses() {
        let mut g = Graph::new("M");
        let s0 = g.input("s0", 1);
        let v0 = g.input("v0", 4);
        let s1 = g.input("s1", 1);
        let v1 = g.input("v1", 4);
        let d = g.input("d", 4);
        let m = g.prim(PrimOp::MuxChain(2), &[s0, v0, s1, v1, d]);
        g.output("o", m);
        let text = super::print(&g);
        assert!(text.contains("mux("));
        let g2 = crate::firrtl::parse(&text).unwrap();
        let mut a = RefSim::new(g);
        let mut b = RefSim::new(g2);
        for bits in 0..32u64 {
            let inputs =
                vec![bits & 1, (bits >> 1) & 0xF, (bits >> 2) & 1, 0xA, 0x5];
            a.step(&inputs);
            b.step(&inputs);
            assert_eq!(a.outputs(), b.outputs());
        }
    }
}
