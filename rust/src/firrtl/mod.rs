//! FIRRTL frontend (paper §6.1: "The compiler takes a digital circuit
//! expressed in FIRRTL").
//!
//! We accept a *lowered*, single-clock FIRRTL subset — the level of
//! abstraction LoFIRRTL reaches after the standard Chisel lowering passes:
//! flat modules, `UInt` types only, no `when` blocks (already lowered to
//! muxes), registers + nodes + connects. This matches how RTeAAL Sim's
//! compiler consumes FIRRTL in the paper (XMR and when-lowering happen in
//! upstream FIRRTL transforms).
//!
//! Grammar (line-oriented, indentation not significant beyond ordering):
//!
//! ```text
//! circuit <name> :
//!   module <name> :
//!     input  <id> : UInt<w>        (also: Clock — ignored)
//!     output <id> : UInt<w>
//!     reg    <id> : UInt<w>, clock [with : (reset => (<id>, UInt<w>(init)))]
//!     node   <id> = <expr>
//!     <id> <= <expr>               ; connect: output port or register next
//!     skip
//! ```
//!
//! `<expr>` is an identifier, a literal `UInt<w>(value)`, or a primitive
//! `op(arg, ...)` with nested expressions and integer immediates
//! (`add, sub, mul, div, rem, lt, leq, gt, geq, eq, neq, and, or, xor,
//! not, neg, andr, orr, xorr, shl, shr, dshl, dshr, cat, bits, head,
//! tail, pad, mux`).

mod lexer;
mod parser;
mod printer;

pub use parser::{parse, ParseError};
pub use printer::print;

#[cfg(test)]
mod tests {
    use crate::graph::builder::{random_circuit, random_inputs};
    use crate::graph::RefSim;
    use crate::util::prng::Rng;

    /// print -> parse round trip preserves behaviour on random circuits.
    #[test]
    fn roundtrip_random_circuits() {
        for seed in 0..10 {
            let mut rng = Rng::new(7000 + seed);
            let g = random_circuit(&mut rng, 50);
            let text = super::print(&g);
            let g2 = super::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
            let mut a = RefSim::new(g);
            let mut b = RefSim::new(g2);
            for _ in 0..12 {
                let inputs = random_inputs(&mut rng, &a.graph);
                a.step(&inputs);
                b.step(&inputs);
                assert_eq!(a.outputs(), b.outputs(), "seed {seed}");
            }
        }
    }
}
