//! Recursive-descent parser for the FIRRTL subset, producing a
//! [`crate::graph::Graph`] directly (the "extract connectivity information
//! … construct a dataflow graph" step of Figure 14).

use std::collections::HashMap;

use super::lexer::{lex, Spanned, Tok};
use crate::graph::ops::{mask, PrimOp};
use crate::graph::{Graph, NodeId, NodeKind};

#[derive(Debug)]
pub struct ParseError {
    pub line: u32,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "firrtl parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse FIRRTL text into a dataflow graph.
pub fn parse(src: &str) -> Result<Graph, ParseError> {
    let toks = lex(src).map_err(|msg| ParseError { line: 0, msg })?;
    Parser { toks, pos: 0, names: HashMap::new(), g: Graph::default(), pending: Vec::new() }.circuit()
}

struct Parser {
    toks: Vec<Spanned>,
    pos: usize,
    /// symbol table: identifier -> node
    names: HashMap<String, NodeId>,
    g: Graph,
    /// connects to resolve at the end: (target name, source node, line)
    pending: Vec<(String, NodeId, u32)>,
}

impl Parser {
    fn line(&self) -> u32 {
        self.toks.get(self.pos).map(|s| s.line).unwrap_or(0)
    }
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, ParseError> {
        Err(ParseError { line: self.line(), msg: msg.into() })
    }
    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.pos).map(|s| &s.tok)
    }
    fn bump(&mut self) -> Option<Tok> {
        let t = self.toks.get(self.pos).map(|s| s.tok.clone());
        if t.is_some() {
            self.pos += 1;
        }
        t
    }
    fn expect(&mut self, t: &Tok) -> Result<(), ParseError> {
        match self.bump() {
            Some(got) if got == *t => Ok(()),
            Some(got) => self.err(format!("expected '{t}', got '{got}'")),
            None => self.err(format!("expected '{t}', got EOF")),
        }
    }
    fn ident(&mut self) -> Result<String, ParseError> {
        match self.bump() {
            Some(Tok::Ident(s)) => Ok(s),
            Some(got) => self.err(format!("expected identifier, got '{got}'")),
            None => self.err("expected identifier, got EOF"),
        }
    }
    fn int(&mut self) -> Result<u64, ParseError> {
        match self.bump() {
            Some(Tok::Int(v)) => Ok(v),
            Some(got) => self.err(format!("expected integer, got '{got}'")),
            None => self.err("expected integer, got EOF"),
        }
    }
    fn skip_newlines(&mut self) {
        while self.peek() == Some(&Tok::Newline) {
            self.pos += 1;
        }
    }
    fn end_stmt(&mut self) -> Result<(), ParseError> {
        match self.bump() {
            Some(Tok::Newline) | None => Ok(()),
            Some(got) => self.err(format!("expected end of statement, got '{got}'")),
        }
    }

    fn circuit(mut self) -> Result<Graph, ParseError> {
        self.skip_newlines();
        let kw = self.ident()?;
        if kw != "circuit" {
            return self.err("expected 'circuit'");
        }
        let name = self.ident()?;
        self.g.name = name;
        self.expect(&Tok::Colon)?;
        self.end_stmt()?;
        self.skip_newlines();
        let kw = self.ident()?;
        if kw != "module" {
            return self.err("expected 'module' (flat single-module subset)");
        }
        let _mname = self.ident()?;
        self.expect(&Tok::Colon)?;
        self.end_stmt()?;
        loop {
            self.skip_newlines();
            if self.peek().is_none() {
                break;
            }
            self.statement()?;
        }
        self.resolve_pending()?;
        Ok(self.g)
    }

    fn statement(&mut self) -> Result<(), ParseError> {
        let first = self.ident()?;
        match first.as_str() {
            "skip" => self.end_stmt(),
            "input" => {
                let name = self.ident()?;
                self.expect(&Tok::Colon)?;
                let w = self.ty()?;
                if let Some(w) = w {
                    let id = self.g.input(&name, w);
                    self.names.insert(name, id);
                }
                // Clock/Reset inputs (w = None) are ignored: single clock domain.
                self.end_stmt()
            }
            "output" => {
                let name = self.ident()?;
                self.expect(&Tok::Colon)?;
                let w = self.ty()?;
                if let Some(w) = w {
                    // Output node created lazily when connected; remember width.
                    self.g.outputs.push((name, u32::MAX));
                    let _ = w;
                }
                self.end_stmt()
            }
            "reg" => {
                let name = self.ident()?;
                self.expect(&Tok::Colon)?;
                let w = self.ty()?.ok_or(ParseError { line: self.line(), msg: "reg must be UInt".into() })?;
                self.expect(&Tok::Comma)?;
                let _clock = self.ident()?; // `clock`
                let mut init = 0u64;
                // optional: `with : (reset => (reset, UInt<w>(init)))`
                if self.peek() == Some(&Tok::Ident("with".into())) {
                    self.bump();
                    self.expect(&Tok::Colon)?;
                    self.expect(&Tok::LParen)?;
                    let kw = self.ident()?;
                    if kw != "reset" {
                        return self.err("expected 'reset' in with-block");
                    }
                    self.expect(&Tok::Arrow)?;
                    self.expect(&Tok::LParen)?;
                    let _rst = self.ident()?;
                    self.expect(&Tok::Comma)?;
                    init = self.literal_value()?;
                    self.expect(&Tok::RParen)?;
                    self.expect(&Tok::RParen)?;
                }
                let id = self.g.reg(&name, w, init & mask(w));
                self.names.insert(name, id);
                self.end_stmt()
            }
            "node" | "wire" => {
                let name = self.ident()?;
                self.expect(&Tok::Eq)?;
                let id = self.expr()?;
                // keep user names on nodes for waveforms
                if self.g.nodes[id as usize].name.is_none() {
                    self.g.name_node(id, &name);
                }
                self.names.insert(name, id);
                self.end_stmt()
            }
            target => {
                // connect: `<target> <= <expr>`
                let target = target.to_string();
                self.expect(&Tok::Connect)?;
                let line = self.line();
                let src = self.expr()?;
                self.pending.push((target, src, line));
                self.end_stmt()
            }
        }
    }

    /// Parse `UInt<w>` (Some(w)) or `Clock`/`Reset`/`AsyncReset` (None).
    fn ty(&mut self) -> Result<Option<u8>, ParseError> {
        let t = self.ident()?;
        match t.as_str() {
            "UInt" => {
                self.expect(&Tok::Lt)?;
                let w = self.int()?;
                self.expect(&Tok::Gt)?;
                if w == 0 || w > 64 {
                    return self.err(format!("unsupported width {w} (1..=64)"));
                }
                Ok(Some(w as u8))
            }
            "Clock" | "Reset" | "AsyncReset" => Ok(None),
            other => self.err(format!("unsupported type '{other}' (UInt-only subset)")),
        }
    }

    /// Parse `UInt<w>(value)` returning just the value.
    fn literal_value(&mut self) -> Result<u64, ParseError> {
        let kw = self.ident()?;
        if kw != "UInt" {
            return self.err("expected UInt literal");
        }
        self.expect(&Tok::Lt)?;
        let _w = self.int()?;
        self.expect(&Tok::Gt)?;
        self.expect(&Tok::LParen)?;
        let v = self.int()?;
        self.expect(&Tok::RParen)?;
        Ok(v)
    }

    fn expr(&mut self) -> Result<NodeId, ParseError> {
        let head = self.ident()?;
        // literal
        if head == "UInt" {
            self.expect(&Tok::Lt)?;
            let w = self.int()? as u8;
            self.expect(&Tok::Gt)?;
            self.expect(&Tok::LParen)?;
            let v = self.int()?;
            self.expect(&Tok::RParen)?;
            if w == 0 || w > 64 {
                return self.err(format!("unsupported literal width {w}"));
            }
            return Ok(self.g.konst(v & mask(w), w));
        }
        // primop?
        if self.peek() == Some(&Tok::LParen) {
            if let Some(builder) = prim_builder(&head) {
                self.bump(); // (
                let mut args: Vec<NodeId> = Vec::new();
                let mut imms: Vec<u64> = Vec::new();
                loop {
                    match self.peek() {
                        Some(Tok::Int(_)) => {
                            let v = self.int()?;
                            imms.push(v);
                        }
                        Some(Tok::RParen) => {}
                        _ => {
                            let a = self.expr()?;
                            args.push(a);
                        }
                    }
                    match self.bump() {
                        Some(Tok::Comma) => continue,
                        Some(Tok::RParen) => break,
                        Some(got) => return self.err(format!("expected ',' or ')', got '{got}'")),
                        None => return self.err("unterminated primop"),
                    }
                }
                let widths: Vec<u8> = args.iter().map(|&a| self.g.width(a)).collect();
                let op = builder(&imms, &widths).map_err(|msg| ParseError { line: self.line(), msg })?;
                if args.len() != op.arity() {
                    return self.err(format!(
                        "{head} expects {} expression args, got {}",
                        op.arity(),
                        args.len()
                    ));
                }
                return Ok(self.g.prim(op, &args));
            }
            return self.err(format!("unknown primitive op '{head}'"));
        }
        // identifier reference
        match self.names.get(&head) {
            Some(&id) => Ok(id),
            None => self.err(format!("use of undefined signal '{head}'")),
        }
    }

    fn resolve_pending(&mut self) -> Result<(), ParseError> {
        let pending = std::mem::take(&mut self.pending);
        for (target, src, line) in pending {
            // register?
            if let Some(&node) = self.names.get(&target) {
                if matches!(self.g.nodes[node as usize].kind, NodeKind::Reg(_)) {
                    self.g.connect_reg(node, src);
                    continue;
                }
                return Err(ParseError { line, msg: format!("cannot connect to non-register '{target}'") });
            }
            // declared output?
            if let Some(slot) = self.g.outputs.iter_mut().find(|(n, id)| n == &target && *id == u32::MAX)
            {
                slot.1 = src;
                continue;
            }
            return Err(ParseError { line, msg: format!("connect to undeclared target '{target}'") });
        }
        // all outputs connected?
        if let Some((name, _)) = self.g.outputs.iter().find(|(_, id)| *id == u32::MAX) {
            return Err(ParseError { line: 0, msg: format!("output '{name}' never connected") });
        }
        Ok(())
    }
}

type PrimBuilder = fn(&[u64], &[u8]) -> Result<PrimOp, String>;

/// Map a mnemonic to a PrimOp constructor (imms = trailing integer params).
fn prim_builder(name: &str) -> Option<PrimBuilder> {
    macro_rules! simple {
        ($op:expr) => {{
            fn f(imms: &[u64], _w: &[u8]) -> Result<PrimOp, String> {
                if !imms.is_empty() {
                    return Err("unexpected integer parameter".into());
                }
                Ok($op)
            }
            Some(f as PrimBuilder)
        }};
    }
    match name {
        "add" => simple!(PrimOp::Add),
        "sub" => simple!(PrimOp::Sub),
        "mul" => simple!(PrimOp::Mul),
        "div" => simple!(PrimOp::Div),
        "rem" => simple!(PrimOp::Rem),
        "lt" => simple!(PrimOp::Lt),
        "leq" => simple!(PrimOp::Leq),
        "gt" => simple!(PrimOp::Gt),
        "geq" => simple!(PrimOp::Geq),
        "eq" => simple!(PrimOp::Eq),
        "neq" => simple!(PrimOp::Neq),
        "and" => simple!(PrimOp::And),
        "or" => simple!(PrimOp::Or),
        "xor" => simple!(PrimOp::Xor),
        "not" => simple!(PrimOp::Not),
        "neg" => simple!(PrimOp::Neg),
        "andr" => simple!(PrimOp::Andr),
        "orr" => simple!(PrimOp::Orr),
        "xorr" => simple!(PrimOp::Xorr),
        "dshl" => simple!(PrimOp::Dshl),
        "dshr" => simple!(PrimOp::Dshr),
        "cat" => simple!(PrimOp::Cat),
        "mux" => simple!(PrimOp::Mux),
        "asUInt" => simple!(PrimOp::Id),
        "shl" => {
            fn f(imms: &[u64], _w: &[u8]) -> Result<PrimOp, String> {
                match imms {
                    [n] => Ok(PrimOp::Shl(*n as u8)),
                    _ => Err("shl expects one integer parameter".into()),
                }
            }
            Some(f)
        }
        "shr" => {
            fn f(imms: &[u64], _w: &[u8]) -> Result<PrimOp, String> {
                match imms {
                    [n] => Ok(PrimOp::Shr(*n as u8)),
                    _ => Err("shr expects one integer parameter".into()),
                }
            }
            Some(f)
        }
        "bits" => {
            fn f(imms: &[u64], w: &[u8]) -> Result<PrimOp, String> {
                match imms {
                    [hi, lo] if hi >= lo && (*hi as u8) < w.first().copied().unwrap_or(64) => {
                        Ok(PrimOp::Bits(*hi as u8, *lo as u8))
                    }
                    [hi, lo] => Err(format!("bits({hi},{lo}) out of range")),
                    _ => Err("bits expects (expr, hi, lo)".into()),
                }
            }
            Some(f)
        }
        "head" => {
            fn f(imms: &[u64], w: &[u8]) -> Result<PrimOp, String> {
                match imms {
                    [n] if *n > 0 && (*n as u8) <= w[0] => Ok(PrimOp::Head(*n as u8)),
                    _ => Err("head parameter out of range".into()),
                }
            }
            Some(f)
        }
        "tail" => {
            fn f(imms: &[u64], w: &[u8]) -> Result<PrimOp, String> {
                match imms {
                    [n] if (*n as u8) < w[0] => Ok(PrimOp::Tail(*n as u8)),
                    _ => Err("tail parameter out of range".into()),
                }
            }
            Some(f)
        }
        "pad" => {
            fn f(imms: &[u64], _w: &[u8]) -> Result<PrimOp, String> {
                match imms {
                    [n] if *n <= 64 => Ok(PrimOp::Pad(*n as u8)),
                    _ => Err("pad parameter out of range".into()),
                }
            }
            Some(f)
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use crate::graph::RefSim;

    const COUNTER: &str = r#"
circuit Counter :
  module Counter :
    input clock : Clock
    input en : UInt<1>
    output count : UInt<4>

    reg r : UInt<4>, clock with : (reset => (reset, UInt<4>(0)))
    node inc = tail(add(r, UInt<4>(1)), 1)
    r <= mux(en, inc, r)
    count <= r
"#;

    #[test]
    fn parses_counter() {
        let g = super::parse(COUNTER).unwrap();
        assert!(g.validate().is_empty(), "{:?}", g.validate());
        assert_eq!(g.inputs.len(), 1); // clock ignored
        assert_eq!(g.regs.len(), 1);
        let mut sim = RefSim::new(g);
        for _ in 0..6 {
            sim.step(&[1]);
        }
        assert_eq!(sim.outputs()[0].1, 6);
    }

    #[test]
    fn nested_exprs() {
        let src = r#"
circuit T :
  module T :
    input a : UInt<8>
    input b : UInt<8>
    output o : UInt<8>
    node x = bits(add(and(a, b), UInt<8>(1)), 7, 0)
    o <= x
"#;
        let g = super::parse(src).unwrap();
        let mut sim = RefSim::new(g);
        sim.step(&[0xF0, 0x3C]);
        assert_eq!(sim.outputs()[0].1, (0xF0u64 & 0x3C) + 1);
    }

    #[test]
    fn error_on_undefined_signal() {
        let src = "circuit T :\n  module T :\n    output o : UInt<1>\n    o <= nope\n";
        let e = super::parse(src).unwrap_err();
        assert!(e.msg.contains("undefined"), "{e}");
    }

    #[test]
    fn error_on_unconnected_output() {
        let src = "circuit T :\n  module T :\n    input a : UInt<1>\n    output o : UInt<1>\n    skip\n";
        let e = super::parse(src).unwrap_err();
        assert!(e.msg.contains("never connected"), "{e}");
    }

    #[test]
    fn error_on_bad_bits_range() {
        let src = "circuit T :\n  module T :\n    input a : UInt<4>\n    output o : UInt<4>\n    node x = bits(a, 9, 0)\n    o <= x\n";
        assert!(super::parse(src).is_err());
    }
}
