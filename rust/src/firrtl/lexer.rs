//! Tokenizer for the FIRRTL subset.

use std::fmt;

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Tok {
    Ident(String),
    Int(u64),
    Colon,
    Comma,
    LParen,
    RParen,
    Lt,      // <
    Gt,      // >
    Eq,      // =
    Connect, // <=
    Arrow,   // =>
    Newline,
}

impl fmt::Display for Tok {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Tok::Ident(s) => write!(f, "{s}"),
            Tok::Int(v) => write!(f, "{v}"),
            Tok::Colon => write!(f, ":"),
            Tok::Comma => write!(f, ","),
            Tok::LParen => write!(f, "("),
            Tok::RParen => write!(f, ")"),
            Tok::Lt => write!(f, "<"),
            Tok::Gt => write!(f, ">"),
            Tok::Eq => write!(f, "="),
            Tok::Connect => write!(f, "<="),
            Tok::Arrow => write!(f, "=>"),
            Tok::Newline => write!(f, "\\n"),
        }
    }
}

/// A token with its source line (1-based) for error reporting.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Spanned {
    pub tok: Tok,
    pub line: u32,
}

/// Tokenize FIRRTL text. Comments (`;` to end of line) are skipped;
/// newlines are significant (statement separators) but runs collapse.
pub fn lex(src: &str) -> Result<Vec<Spanned>, String> {
    let mut out: Vec<Spanned> = Vec::new();
    let b = src.as_bytes();
    let mut i = 0usize;
    let mut line = 1u32;
    let push = |tok: Tok, line: u32, out: &mut Vec<Spanned>| {
        if tok == Tok::Newline {
            if matches!(out.last(), None | Some(Spanned { tok: Tok::Newline, .. })) {
                return; // collapse blank lines / leading newline
            }
        }
        out.push(Spanned { tok, line });
    };
    while i < b.len() {
        let c = b[i];
        match c {
            b'\n' => {
                push(Tok::Newline, line, &mut out);
                line += 1;
                i += 1;
            }
            b' ' | b'\t' | b'\r' => i += 1,
            b';' => {
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
            }
            b':' => {
                push(Tok::Colon, line, &mut out);
                i += 1;
            }
            b',' => {
                push(Tok::Comma, line, &mut out);
                i += 1;
            }
            b'(' => {
                push(Tok::LParen, line, &mut out);
                i += 1;
            }
            b')' => {
                push(Tok::RParen, line, &mut out);
                i += 1;
            }
            b'<' => {
                if b.get(i + 1) == Some(&b'=') {
                    push(Tok::Connect, line, &mut out);
                    i += 2;
                } else {
                    push(Tok::Lt, line, &mut out);
                    i += 1;
                }
            }
            b'>' => {
                push(Tok::Gt, line, &mut out);
                i += 1;
            }
            b'=' => {
                if b.get(i + 1) == Some(&b'>') {
                    push(Tok::Arrow, line, &mut out);
                    i += 2;
                } else {
                    push(Tok::Eq, line, &mut out);
                    i += 1;
                }
            }
            b'"' => {
                // String literal used for hex values: "hABC"
                let start = i + 1;
                let mut j = start;
                while j < b.len() && b[j] != b'"' {
                    j += 1;
                }
                if j >= b.len() {
                    return Err(format!("line {line}: unterminated string"));
                }
                let body = &src[start..j];
                let v = if let Some(hex) = body.strip_prefix('h') {
                    u64::from_str_radix(hex, 16).map_err(|_| format!("line {line}: bad hex '{body}'"))?
                } else {
                    body.parse::<u64>().map_err(|_| format!("line {line}: bad number '{body}'"))?
                };
                push(Tok::Int(v), line, &mut out);
                i = j + 1;
            }
            b'0'..=b'9' => {
                let start = i;
                if c == b'0' && b.get(i + 1) == Some(&b'x') {
                    i += 2;
                    while i < b.len() && (b[i] as char).is_ascii_hexdigit() {
                        i += 1;
                    }
                    let v = u64::from_str_radix(&src[start + 2..i], 16)
                        .map_err(|_| format!("line {line}: bad hex"))?;
                    push(Tok::Int(v), line, &mut out);
                } else {
                    while i < b.len() && b[i].is_ascii_digit() {
                        i += 1;
                    }
                    let v = src[start..i].parse::<u64>().map_err(|_| format!("line {line}: bad int"))?;
                    push(Tok::Int(v), line, &mut out);
                }
            }
            c if c == b'_' || c.is_ascii_alphabetic() => {
                let start = i;
                while i < b.len() && (b[i] == b'_' || b[i] == b'$' || b[i].is_ascii_alphanumeric()) {
                    i += 1;
                }
                push(Tok::Ident(src[start..i].to_string()), line, &mut out);
            }
            _ => return Err(format!("line {line}: unexpected character '{}'", c as char)),
        }
    }
    push(Tok::Newline, line, &mut out);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexes_statements() {
        let toks = lex("node x = add(a, UInt<4>(3)) ; comment\ny <= x\n").unwrap();
        let kinds: Vec<&Tok> = toks.iter().map(|s| &s.tok).collect();
        assert!(kinds.contains(&&Tok::Ident("add".into())));
        assert!(kinds.contains(&&Tok::Int(3)));
        assert!(kinds.contains(&&Tok::Connect));
        // comment dropped
        assert!(!kinds.iter().any(|t| matches!(t, Tok::Ident(s) if s == "comment")));
    }

    #[test]
    fn hex_literals() {
        let toks = lex("UInt<8>(\"hFF\") 0x1a").unwrap();
        let ints: Vec<u64> = toks
            .iter()
            .filter_map(|s| match s.tok {
                Tok::Int(v) => Some(v),
                _ => None,
            })
            .collect();
        assert_eq!(ints, vec![8, 255, 26]);
    }

    #[test]
    fn newline_collapse() {
        let toks = lex("a\n\n\nb\n").unwrap();
        let newlines = toks.iter().filter(|s| s.tok == Tok::Newline).count();
        assert_eq!(newlines, 2);
    }

    #[test]
    fn line_numbers() {
        let toks = lex("a\nb\nc").unwrap();
        let c = toks.iter().find(|s| s.tok == Tok::Ident("c".into())).unwrap();
        assert_eq!(c.line, 3);
    }
}
