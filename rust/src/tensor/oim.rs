//! Concrete OIM tensor: the coordinate/payload arrays the rolled kernels
//! traverse (paper §5.1, Figs 12–13), plus JSON import/export (§6.1: "the
//! OIM tensor is stored in JSON files and loaded at runtime").
//!
//! Two concrete lowerings are materialized, matching the paper's formats:
//!
//! * **Format B** `[I, S, N, O, R]` (Fig 12b): ops in natural S order.
//!   `i_payload` (uncompressed I, payload = ops/layer), `s_coords`
//!   (compressed, coords only), `n_coords` (compressed, coords only —
//!   payloads elided because the op type determines the O occupancy),
//!   O implicit, `r_coords` (coords only — OIM is a mask, so R payloads
//!   are elided). Used by RU/OU.
//! * **Format C** `[I, N, S, O, R]` (Fig 12c, after the S/N swizzle): ops
//!   re-ordered so each layer groups by op type; `n_payload` (uncompressed
//!   N per layer, payload = ops of that type) replaces `n_coords` and makes
//!   `i_payload` redundant. Used by NU/PSU/IU (and the SU/TI tapes, which
//!   inherit the swizzle).
//!
//! Operation parameters (`imm`, `mask`, `aux`) ride in side arrays — the
//! FIRRTL op set needs them; they are counted in every format's footprint.

use crate::tensor::format::{bits_for, FormatSpec, RankFormat};
use crate::tensor::ir::{KOp, LayerIr, OpRec, NUM_KOPS};
use crate::util::json::{arr_u32, arr_u64, obj, Json, JsonError};

/// One order's flat per-op arrays.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct OimArrays {
    /// out slot per op (rank S coords)
    pub s_coords: Vec<u32>,
    /// operand slots, flat in (op, o) order (rank R coords)
    pub r_coords: Vec<u32>,
    /// operand count per op (derived from opcode except MuxChain)
    pub arity: Vec<u8>,
    /// opcode per op (needed by both orders to execute; only format B
    /// *stores* it as rank-N coordinates)
    pub opcode: Vec<u8>,
    // --- operation parameter arrays ---
    pub imm: Vec<u8>,
    pub mask: Vec<u64>,
    pub aux: Vec<u64>,
}

impl OimArrays {
    fn push(&mut self, rec: &OpRec, ext_args: &[u32]) {
        self.s_coords.push(rec.out);
        self.opcode.push(rec.op);
        self.arity.push(rec.arity);
        self.imm.push(rec.imm);
        self.mask.push(rec.mask);
        self.aux.push(rec.aux);
        for r in operand_slots(rec, ext_args) {
            self.r_coords.push(r);
        }
    }
}

/// The concrete OIM: shared rank-I payloads plus both format lowerings.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Oim {
    /// ops per layer (format B: payload array of rank I)
    pub i_payload: Vec<u32>,
    /// format B arrays (natural S order)
    pub b: OimArrays,
    /// format C arrays (each layer sorted by opcode)
    pub c: OimArrays,
    /// ops per (layer, opcode) — format C: payload array of uncompressed N
    pub n_payload: Vec<u32>,
    /// number of slots in LI
    pub num_slots: u32,
}

impl Oim {
    pub fn from_ir(ir: &LayerIr) -> Self {
        let mut o = Oim { num_slots: ir.num_slots as u32, ..Default::default() };
        for layer in &ir.layers {
            o.i_payload.push(layer.len() as u32);
            // format B: natural order
            for rec in layer {
                o.b.push(rec, &ir.ext_args);
            }
            // format C: stable-sort by opcode (the S/N swizzle)
            let mut sorted: Vec<&OpRec> = layer.iter().collect();
            sorted.sort_by_key(|r| r.op);
            let mut per_op = vec![0u32; NUM_KOPS];
            for rec in sorted {
                per_op[rec.op as usize] += 1;
                o.c.push(rec, &ir.ext_args);
            }
            o.n_payload.extend_from_slice(&per_op);
        }
        o
    }

    pub fn total_ops(&self) -> usize {
        self.b.s_coords.len()
    }

    pub fn num_layers(&self) -> usize {
        self.i_payload.len()
    }

    /// Format specification per Fig 12a: every rank keeps explicit
    /// coordinate + payload arrays (the unoptimized lowering).
    pub fn format_a(&self) -> FormatSpec {
        let ops = self.total_ops();
        let operands = self.b.r_coords.len();
        let layers = self.num_layers();
        let slot_bits = bits_for(self.num_slots.saturating_sub(1) as u64);
        let op_bits = bits_for((NUM_KOPS - 1) as u64);
        let max_arity = self.b.arity.iter().copied().max().unwrap_or(1);
        FormatSpec {
            name: "A (unoptimized)".into(),
            ranks: vec![
                RankFormat { rank: "I", compressed: false, cbits: 0, pbits: bits_for(ops as u64), entries: layers },
                RankFormat { rank: "S", compressed: true, cbits: slot_bits, pbits: bits_for(1), entries: ops },
                RankFormat { rank: "N", compressed: true, cbits: op_bits, pbits: bits_for(max_arity as u64), entries: ops },
                RankFormat { rank: "O", compressed: false, cbits: bits_for(max_arity as u64), pbits: bits_for(1), entries: operands },
                RankFormat { rank: "R", compressed: true, cbits: slot_bits, pbits: 1, entries: operands },
            ],
            param_bytes: self.param_bytes(),
        }
    }

    /// Format specification per Fig 12b (optimized, loop order [I,S,N,O,R]).
    pub fn format_b(&self) -> FormatSpec {
        let ops = self.total_ops();
        let operands = self.b.r_coords.len();
        let layers = self.num_layers();
        let slot_bits = bits_for(self.num_slots.saturating_sub(1) as u64);
        let op_bits = bits_for((NUM_KOPS - 1) as u64);
        FormatSpec {
            name: "B [I,S,N,O,R]".into(),
            ranks: vec![
                RankFormat { rank: "I", compressed: false, cbits: 0, pbits: bits_for(ops as u64), entries: layers },
                RankFormat { rank: "S", compressed: true, cbits: slot_bits, pbits: 0, entries: ops },
                RankFormat { rank: "N", compressed: true, cbits: op_bits, pbits: 0, entries: ops },
                RankFormat { rank: "O", compressed: false, cbits: 0, pbits: 0, entries: operands },
                RankFormat { rank: "R", compressed: true, cbits: slot_bits, pbits: 0, entries: operands },
            ],
            param_bytes: self.param_bytes(),
        }
    }

    /// Format specification per Fig 12c (swizzled, loop order [I,N,S,O,R]).
    pub fn format_c(&self) -> FormatSpec {
        let ops = self.total_ops();
        let operands = self.c.r_coords.len();
        let layers = self.num_layers();
        let slot_bits = bits_for(self.num_slots.saturating_sub(1) as u64);
        let max_cnt = self.n_payload.iter().copied().max().unwrap_or(1);
        FormatSpec {
            name: "C [I,N,S,O,R]".into(),
            ranks: vec![
                // I payloads redundant: N is uncompressed with constant occupancy.
                RankFormat { rank: "I", compressed: false, cbits: 0, pbits: 0, entries: layers },
                RankFormat { rank: "N", compressed: false, cbits: 0, pbits: bits_for(max_cnt as u64), entries: layers * NUM_KOPS },
                RankFormat { rank: "S", compressed: true, cbits: slot_bits, pbits: 0, entries: ops },
                RankFormat { rank: "O", compressed: false, cbits: 0, pbits: 0, entries: operands },
                RankFormat { rank: "R", compressed: true, cbits: slot_bits, pbits: 0, entries: operands },
            ],
            param_bytes: self.param_bytes(),
        }
    }

    /// Bytes of the operation-parameter side arrays (imm/mask/aux),
    /// stored at the widths actually required.
    fn param_bytes(&self) -> usize {
        let ops = self.total_ops();
        let mask_bits = bits_for(self.b.mask.iter().copied().max().unwrap_or(1));
        let n_aux = self.b.aux.iter().filter(|&&a| a != 0).count();
        let aux_bits = bits_for(self.b.aux.iter().copied().max().unwrap_or(0).max(1));
        (ops * 8 + 7) / 8 // imm (u8)
            + (ops * mask_bits as usize + 7) / 8
            + (n_aux * aux_bits as usize + 7) / 8
    }

    /// Serialize as JSON (paper §6.1 stores OIM as JSON files). Format B
    /// arrays are authoritative; format C is re-derived on load.
    pub fn to_json(&self) -> Json {
        let u8arr = |xs: &[u8]| Json::Arr(xs.iter().map(|&v| Json::Int(v as i64)).collect());
        obj(vec![
            ("num_slots", Json::Int(self.num_slots as i64)),
            ("i_payload", arr_u32(&self.i_payload)),
            ("s_coords", arr_u32(&self.b.s_coords)),
            ("n_coords", u8arr(&self.b.opcode)),
            ("r_coords", arr_u32(&self.b.r_coords)),
            ("arity", u8arr(&self.b.arity)),
            ("imm", u8arr(&self.b.imm)),
            ("mask", arr_u64(&self.b.mask)),
            ("aux", arr_u64(&self.b.aux)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self, JsonError> {
        let b8 = |key: &str| -> Result<Vec<u8>, JsonError> {
            Ok(j.req_u64_vec(key)?.into_iter().map(|v| v as u8).collect())
        };
        let b = OimArrays {
            s_coords: j.req_u32_vec("s_coords")?,
            r_coords: j.req_u32_vec("r_coords")?,
            arity: b8("arity")?,
            opcode: b8("n_coords")?,
            imm: b8("imm")?,
            mask: j.req_u64_vec("mask")?,
            aux: j.req_u64_vec("aux")?,
        };
        let i_payload = j.req_u32_vec("i_payload")?;
        let num_slots = j.req_u64("num_slots")? as u32;
        // Re-derive format C from B.
        let (layers, ext) = recs_from_arrays(&i_payload, &b);
        let mut o = Oim { num_slots, i_payload: i_payload.clone(), b, ..Default::default() };
        for layer in &layers {
            let mut sorted: Vec<&OpRec> = layer.iter().collect();
            sorted.sort_by_key(|r| r.op);
            let mut per_op = vec![0u32; NUM_KOPS];
            for rec in sorted {
                per_op[rec.op as usize] += 1;
                o.c.push(rec, &ext);
            }
            o.n_payload.extend_from_slice(&per_op);
        }
        Ok(o)
    }

    /// Per-op records in format-C (swizzled) order — the SU/TI tape source.
    pub fn op_recs(&self) -> (Vec<Vec<OpRec>>, Vec<u32>) {
        recs_from_arrays(&self.i_payload, &self.c)
    }

    /// Per-op records in format-B (natural S) order — exactly the
    /// `LayerIr::layers` the OIM was lowered from, which makes the IR
    /// reconstructable from a cached OIM plus the small
    /// [`crate::tensor::ir::LayerIr::to_json`] sidecar.
    pub fn op_recs_natural(&self) -> (Vec<Vec<OpRec>>, Vec<u32>) {
        recs_from_arrays(&self.i_payload, &self.b)
    }

    /// Splice a new OIM out of a prior one plus a grafted IR (the
    /// incremental-compile path): layers not marked `touched` copy the
    /// prior's format-B and format-C array segments verbatim; touched
    /// layers — and any layers beyond the prior's depth — are rebuilt
    /// from `ir.layers` exactly as [`Oim::from_ir`] would. The result is
    /// bit-identical to `Oim::from_ir(ir)` whenever untouched layers of
    /// `ir` really are unchanged from the prior IR, which the delta pass
    /// guarantees by construction (grafted ops only ever land in touched
    /// layers).
    pub fn splice(prior: &Oim, ir: &LayerIr, touched: &[bool]) -> Oim {
        assert_eq!(touched.len(), ir.layers.len(), "touched flags must cover every layer");
        // Per-layer (op, operand) offsets into the prior's flat arrays.
        // Both orders share op offsets (a layer occupies the same flat op
        // range in B and C) and, since a layer's operand total is the sum
        // of its arities in either order, operand offsets too.
        let mut off = Vec::with_capacity(prior.i_payload.len() + 1);
        {
            let (mut op, mut r) = (0usize, 0usize);
            for &cnt in &prior.i_payload {
                off.push((op, r));
                for k in 0..cnt as usize {
                    r += prior.b.arity[op + k] as usize;
                }
                op += cnt as usize;
            }
            off.push((op, r));
        }
        fn copy(dst: &mut OimArrays, src: &OimArrays, ops: (usize, usize), rs: (usize, usize)) {
            dst.s_coords.extend_from_slice(&src.s_coords[ops.0..ops.1]);
            dst.opcode.extend_from_slice(&src.opcode[ops.0..ops.1]);
            dst.arity.extend_from_slice(&src.arity[ops.0..ops.1]);
            dst.imm.extend_from_slice(&src.imm[ops.0..ops.1]);
            dst.mask.extend_from_slice(&src.mask[ops.0..ops.1]);
            dst.aux.extend_from_slice(&src.aux[ops.0..ops.1]);
            dst.r_coords.extend_from_slice(&src.r_coords[rs.0..rs.1]);
        }
        let mut o = Oim { num_slots: ir.num_slots as u32, ..Default::default() };
        for (li, layer) in ir.layers.iter().enumerate() {
            o.i_payload.push(layer.len() as u32);
            if !touched[li] && li < prior.i_payload.len() {
                debug_assert_eq!(prior.i_payload[li] as usize, layer.len());
                let ((o0, r0), (o1, r1)) = (off[li], off[li + 1]);
                copy(&mut o.b, &prior.b, (o0, o1), (r0, r1));
                copy(&mut o.c, &prior.c, (o0, o1), (r0, r1));
                let n = &prior.n_payload[li * NUM_KOPS..(li + 1) * NUM_KOPS];
                o.n_payload.extend_from_slice(n);
            } else {
                for rec in layer {
                    o.b.push(rec, &ir.ext_args);
                }
                let mut sorted: Vec<&OpRec> = layer.iter().collect();
                sorted.sort_by_key(|r| r.op);
                let mut per_op = vec![0u32; NUM_KOPS];
                for rec in sorted {
                    per_op[rec.op as usize] += 1;
                    o.c.push(rec, &ir.ext_args);
                }
                o.n_payload.extend_from_slice(&per_op);
            }
        }
        o
    }
}

/// Rebuild AoS records from one order's arrays.
fn recs_from_arrays(i_payload: &[u32], a: &OimArrays) -> (Vec<Vec<OpRec>>, Vec<u32>) {
    let mut layers = Vec::with_capacity(i_payload.len());
    let mut ext_args: Vec<u32> = Vec::new();
    let mut op_idx = 0usize;
    let mut r_idx = 0usize;
    for &cnt in i_payload {
        let mut layer = Vec::with_capacity(cnt as usize);
        for _ in 0..cnt {
            let ar = a.arity[op_idx] as usize;
            let slots = &a.r_coords[r_idx..r_idx + ar];
            let mut rec = OpRec {
                out: a.s_coords[op_idx],
                a: slots.first().copied().unwrap_or(0),
                b: slots.get(1).copied().unwrap_or(0),
                c: slots.get(2).copied().unwrap_or(0),
                mask: a.mask[op_idx],
                aux: a.aux[op_idx],
                op: a.opcode[op_idx],
                arity: ar as u8,
                imm: a.imm[op_idx],
                _pad: 0,
                ext: 0,
            };
            if rec.kop() == KOp::MuxChain {
                rec.ext = ext_args.len() as u32;
                ext_args.extend_from_slice(&slots[2..]);
            }
            layer.push(rec);
            op_idx += 1;
            r_idx += ar;
        }
        layers.push(layer);
    }
    (layers, ext_args)
}

/// Ordered operand slots of a record.
pub fn operand_slots(rec: &OpRec, ext_args: &[u32]) -> Vec<u32> {
    let ar = rec.arity as usize;
    match rec.kop() {
        KOp::MuxChain => {
            let mut v = vec![rec.a, rec.b];
            v.extend_from_slice(&ext_args[rec.ext as usize..rec.ext as usize + ar - 2]);
            v
        }
        _ => [rec.a, rec.b, rec.c][..ar].to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::random_circuit;
    use crate::graph::passes::optimize;
    use crate::tensor::ir::lower;
    use crate::util::prng::Rng;

    fn sample_oim(seed: u64, size: usize) -> (Oim, crate::tensor::ir::LayerIr) {
        let mut rng = Rng::new(seed);
        let g = random_circuit(&mut rng, size);
        let (opt, _) = optimize(&g);
        let ir = lower(&opt);
        (Oim::from_ir(&ir), ir)
    }

    #[test]
    fn arrays_are_consistent() {
        let (o, ir) = sample_oim(42, 120);
        assert_eq!(o.total_ops(), ir.total_ops());
        assert_eq!(o.i_payload.iter().sum::<u32>() as usize, o.total_ops());
        assert_eq!(o.n_payload.iter().sum::<u32>() as usize, o.total_ops());
        assert_eq!(o.b.r_coords.len(), o.c.r_coords.len());
        assert_eq!(o.n_payload.len(), o.num_layers() * NUM_KOPS);
        // C order is grouped by opcode within each layer
        let mut idx = 0usize;
        for &cnt in &o.i_payload {
            let ops = &o.c.opcode[idx..idx + cnt as usize];
            for w in ops.windows(2) {
                assert!(w[0] <= w[1]);
            }
            idx += cnt as usize;
        }
    }

    #[test]
    fn json_roundtrip_rebuilds_c() {
        let (o, _) = sample_oim(43, 80);
        let j = o.to_json();
        let o2 = Oim::from_json(&crate::util::json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(o.b, o2.b);
        assert_eq!(o.c, o2.c);
        assert_eq!(o.n_payload, o2.n_payload);
        assert_eq!(o.num_slots, o2.num_slots);
    }

    #[test]
    fn op_recs_roundtrip_semantics() {
        use crate::tensor::ir::IrSim;
        let mut rng = Rng::new(44);
        let g = random_circuit(&mut rng, 80);
        let (opt, _) = optimize(&g);
        let ir = lower(&opt);
        let oim = Oim::from_ir(&ir);
        let (layers, ext) = oim.op_recs();
        let mut ir2 = ir.clone();
        ir2.layers = layers;
        ir2.ext_args = ext;
        let mut a = IrSim::new(ir);
        let mut b = IrSim::new(ir2);
        for _ in 0..10 {
            let inputs = crate::graph::builder::random_inputs(&mut rng, &opt);
            a.step(&inputs);
            b.step(&inputs);
            assert_eq!(a.outputs(), b.outputs());
        }
    }

    #[test]
    fn format_sizes_shrink_a_to_b() {
        let (o, _) = sample_oim(45, 200);
        let a = o.format_a().total_bytes();
        let b = o.format_b().total_bytes();
        assert!(b < a, "expected B ({b}) < A ({a})");
    }
}
