//! The fibertree abstraction (paper §2.2, after Sze et al.).
//!
//! A tensor is a tree of [`Fiber`]s, one level per rank; each fiber maps
//! coordinates to payloads, and a payload is either a scalar (leaf) or a
//! reference to the next-level fiber. Sparse fibers simply omit empty
//! coordinates. This representation is deliberately *abstract* — concrete
//! formats (coordinate/payload arrays, cbits/pbits) live in
//! [`super::format`] — and is used by the Einsum cascade evaluator
//! (`crate::einsum`), i.e. on the specification/oracle path, never on the
//! simulation hot path.

use std::collections::BTreeMap;

/// Payload: scalar at the leaf rank, sub-fiber otherwise.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    Val(u64),
    Sub(Fiber),
}

impl Payload {
    pub fn as_val(&self) -> u64 {
        match self {
            Payload::Val(v) => *v,
            Payload::Sub(_) => panic!("expected leaf payload"),
        }
    }
    pub fn as_fiber(&self) -> &Fiber {
        match self {
            Payload::Sub(f) => f,
            Payload::Val(_) => panic!("expected sub-fiber payload"),
        }
    }
}

/// A fiber: ordered (coordinate → payload) with a declared shape.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Fiber {
    /// Number of possible coordinates (paper: "shape").
    pub shape: usize,
    pub entries: BTreeMap<usize, Payload>,
}

impl Fiber {
    pub fn new(shape: usize) -> Self {
        Fiber { shape, entries: BTreeMap::new() }
    }

    /// Paper: "occupancy" — number of non-empty coordinates.
    pub fn occupancy(&self) -> usize {
        self.entries.len()
    }

    pub fn get(&self, coord: usize) -> Option<&Payload> {
        self.entries.get(&coord)
    }

    pub fn set(&mut self, coord: usize, p: Payload) {
        debug_assert!(coord < self.shape, "coordinate {coord} out of shape {}", self.shape);
        self.entries.insert(coord, p);
    }

    /// Set a leaf value at a path of coordinates, creating intermediate
    /// fibers (with the given shapes) as needed.
    pub fn set_path(&mut self, path: &[usize], shapes: &[usize], v: u64) {
        debug_assert_eq!(path.len(), shapes.len() + 1);
        if path.len() == 1 {
            self.set(path[0], Payload::Val(v));
            return;
        }
        let entry = self
            .entries
            .entry(path[0])
            .or_insert_with(|| Payload::Sub(Fiber::new(shapes[0])));
        match entry {
            Payload::Sub(f) => f.set_path(&path[1..], &shapes[1..], v),
            Payload::Val(_) => panic!("leaf/sub mismatch at coordinate {}", path[0]),
        }
    }

    /// Leaf value at a full path (None if any coordinate is empty).
    pub fn get_path(&self, path: &[usize]) -> Option<u64> {
        let p = self.get(path[0])?;
        if path.len() == 1 {
            Some(p.as_val())
        } else {
            p.as_fiber().get_path(&path[1..])
        }
    }

    /// Iterate (coordinate, payload) in coordinate-ascending order — the
    /// traversal-order guarantee the O rank relies on (§4.1).
    pub fn iter(&self) -> impl Iterator<Item = (usize, &Payload)> {
        self.entries.iter().map(|(c, p)| (*c, p))
    }

    /// Count leaves (points with scalar values) in the whole subtree.
    pub fn count_leaves(&self) -> usize {
        self.entries
            .values()
            .map(|p| match p {
                Payload::Val(_) => 1,
                Payload::Sub(f) => f.count_leaves(),
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_figure2_example() {
        // Matrix A (M=3, K=3) with A[0,2]=1, A[2,0]=2, A[2,1]=3, A[2,2]=4:
        // rank M: one fiber shape 3 occupancy 2; rank K: fibers occ 1 and 3.
        let mut a = Fiber::new(3);
        a.set_path(&[0, 2], &[3], 1);
        a.set_path(&[2, 0], &[3], 2);
        a.set_path(&[2, 1], &[3], 3);
        a.set_path(&[2, 2], &[3], 4);
        assert_eq!(a.occupancy(), 2);
        assert_eq!(a.get(0).unwrap().as_fiber().occupancy(), 1);
        assert_eq!(a.get(2).unwrap().as_fiber().occupancy(), 3);
        assert_eq!(a.get_path(&[2, 1]), Some(3));
        assert_eq!(a.get_path(&[1, 1]), None);
        assert_eq!(a.count_leaves(), 4);
    }

    #[test]
    fn ascending_iteration() {
        let mut f = Fiber::new(10);
        for c in [7, 1, 4] {
            f.set(c, Payload::Val(c as u64));
        }
        let coords: Vec<usize> = f.iter().map(|(c, _)| c).collect();
        assert_eq!(coords, vec![1, 4, 7]);
    }
}
