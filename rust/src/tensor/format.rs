//! Per-rank concrete tensor formats (paper §2.5.2 and Fig 12).
//!
//! A rank's format is `(un)compressed` + coordinate bitwidth (`cbits`) +
//! payload bitwidth (`pbits`); setting a bitwidth to zero elides that
//! array. [`FormatSpec`] describes one lowering of the OIM onto arrays and
//! computes its storage cost — this drives the paper's format-optimization
//! story (Fig 12 a→b→c) and the D-cache footprint model.

use crate::util::fmt_bytes;

/// Bits needed to encode values in `0..=max`.
pub fn bits_for(max: u64) -> u8 {
    (64 - max.leading_zeros()).max(1) as u8
}

/// One rank of a format specification.
#[derive(Clone, Debug)]
pub struct RankFormat {
    pub rank: &'static str,
    /// Compressed (size ∝ occupancy) or uncompressed (size ∝ shape).
    pub compressed: bool,
    pub cbits: u8,
    pub pbits: u8,
    /// Number of stored entries (occupancy for compressed ranks, shape for
    /// uncompressed ones).
    pub entries: usize,
}

impl RankFormat {
    pub fn bytes(&self) -> usize {
        // Arrays are stored separately; each is byte-aligned as a whole.
        let coord = (self.entries * self.cbits as usize + 7) / 8;
        let payload = (self.entries * self.pbits as usize + 7) / 8;
        coord + payload
    }
}

/// A complete format specification for a tensor.
#[derive(Clone, Debug)]
pub struct FormatSpec {
    pub name: String,
    pub ranks: Vec<RankFormat>,
    /// Side metadata not part of the rank arrays (operation parameters:
    /// imm/mask/aux). The paper's toy op set has none; FIRRTL's does.
    pub param_bytes: usize,
}

impl FormatSpec {
    pub fn total_bytes(&self) -> usize {
        self.ranks.iter().map(|r| r.bytes()).sum::<usize>() + self.param_bytes
    }

    pub fn render(&self) -> String {
        let mut t = crate::util::tables::Table::new(
            &format!("format {} — {}", self.name, fmt_bytes(self.total_bytes())),
            &["rank", "C/U", "cbits", "pbits", "entries", "bytes"],
        );
        for r in &self.ranks {
            t.row(vec![
                r.rank.to_string(),
                if r.compressed { "C" } else { "U" }.to_string(),
                r.cbits.to_string(),
                r.pbits.to_string(),
                r.entries.to_string(),
                r.bytes().to_string(),
            ]);
        }
        if self.param_bytes > 0 {
            t.row(vec!["(params)".into(), "-".into(), "-".into(), "-".into(), "-".into(), self.param_bytes.to_string()]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_for_boundaries() {
        assert_eq!(bits_for(0), 1);
        assert_eq!(bits_for(1), 1);
        assert_eq!(bits_for(2), 2);
        assert_eq!(bits_for(255), 8);
        assert_eq!(bits_for(256), 9);
        assert_eq!(bits_for(u64::MAX), 64);
    }

    #[test]
    fn zero_bits_elides_array() {
        let r = RankFormat { rank: "O", compressed: false, cbits: 0, pbits: 0, entries: 1000 };
        assert_eq!(r.bytes(), 0);
    }

    #[test]
    fn byte_rounding() {
        let r = RankFormat { rank: "S", compressed: true, cbits: 10, pbits: 0, entries: 3 };
        assert_eq!(r.bytes(), 4); // 30 bits -> 4 bytes
    }
}
