//! Lowered layer IR: the logical content of the `LI`/`OIM`/`LO` tensors.
//!
//! Lowering (from a levelized graph):
//! * every graph node gets a *slot* in the flat value file `LI` (identity
//!   elision, §4.3: source and destination coordinates match, so identity
//!   ops vanish);
//! * each primitive op becomes an [`OpRec`] with a normalized executor
//!   opcode ([`KOp`]): width-dependent FIRRTL ops (`bits`, `head`, `tail`,
//!   `pad`, `andr`, `cat`) are rewritten into shift/mask/compare form with
//!   precomputed immediates so kernels never consult operand widths;
//! * ops within a layer stay in natural S order (the format-B order);
//!   the S/N swizzle of §5.2 (format C) is materialized by
//!   [`crate::tensor::oim::Oim`].

use crate::graph::levelize::{levelize, Levelized};
use crate::graph::ops::{mask, PrimOp};
use crate::graph::{Graph, NodeKind};

/// Executor opcode. Every variant's semantics are fully determined by the
/// record's operands + immediates (no width lookups at run time).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[repr(u8)]
pub enum KOp {
    Add = 0,
    Sub,
    Mul,
    Div,
    Rem,
    Lt,
    Leq,
    Gt,
    Geq,
    Eq,
    Neq,
    And,
    Or,
    Xor,
    Not,
    Neg,
    /// `out = (a == aux)` — and-reduction against a precomputed full mask.
    AndrK,
    Orr,
    Xorr,
    /// `out = a << imm`
    ShlI,
    /// `out = a >> imm`
    ShrI,
    Dshl,
    Dshr,
    /// `out = (a << imm) | b` (imm = width of b)
    Cat,
    /// `out = a ? b : c`
    Mux,
    /// `out = a & mask` (absorbs id/pad/tail/bits-with-zero-shift)
    Copy,
    /// Fused mux chain; operands beyond the first 3 live in `ext_args`.
    MuxChain,
}

pub const NUM_KOPS: usize = 27;

impl KOp {
    pub fn from_u8(v: u8) -> KOp {
        assert!((v as usize) < NUM_KOPS);
        // SAFETY: repr(u8), contiguous discriminants checked above.
        unsafe { std::mem::transmute(v) }
    }

    pub fn mnemonic(self) -> &'static str {
        match self {
            KOp::Add => "add",
            KOp::Sub => "sub",
            KOp::Mul => "mul",
            KOp::Div => "div",
            KOp::Rem => "rem",
            KOp::Lt => "lt",
            KOp::Leq => "leq",
            KOp::Gt => "gt",
            KOp::Geq => "geq",
            KOp::Eq => "eq",
            KOp::Neq => "neq",
            KOp::And => "and",
            KOp::Or => "or",
            KOp::Xor => "xor",
            KOp::Not => "not",
            KOp::Neg => "neg",
            KOp::AndrK => "andr",
            KOp::Orr => "orr",
            KOp::Xorr => "xorr",
            KOp::ShlI => "shli",
            KOp::ShrI => "shri",
            KOp::Dshl => "dshl",
            KOp::Dshr => "dshr",
            KOp::Cat => "cat",
            KOp::Mux => "mux",
            KOp::Copy => "copy",
            KOp::MuxChain => "muxchain",
        }
    }

    /// Number of slot operands read from `LI` (MuxChain reads `imm*2+1`).
    pub fn arity(self) -> usize {
        match self {
            KOp::Not | KOp::Neg | KOp::AndrK | KOp::Orr | KOp::Xorr | KOp::ShlI | KOp::ShrI | KOp::Copy => 1,
            KOp::Mux => 3,
            KOp::MuxChain => usize::MAX, // variable; use OpRec::arity
            _ => 2,
        }
    }
}

/// One operation record: the paper's `(s, n, {o→r})` OIM entry plus the
/// normalized immediates. 48 bytes, cache-line friendly.
#[derive(Clone, Copy, Debug)]
#[repr(C)]
pub struct OpRec {
    /// Output slot (the S coordinate after identity elision).
    pub out: u32,
    /// First three operand slots (R coordinates in O order).
    pub a: u32,
    pub b: u32,
    pub c: u32,
    /// Result mask (`mask(out_width)`, possibly tightened by bits/tail).
    pub mask: u64,
    /// AndrK compare value.
    pub aux: u64,
    /// Opcode (KOp as u8).
    pub op: u8,
    /// Operand count (for MuxChain: 2k+1).
    pub arity: u8,
    /// Shift amount / cat's b-width / muxchain k.
    pub imm: u8,
    pub _pad: u8,
    /// Offset into `LayerIr::ext_args` for operands beyond 3 (MuxChain).
    pub ext: u32,
}

impl OpRec {
    pub fn kop(&self) -> KOp {
        KOp::from_u8(self.op)
    }
}

/// Evaluate one op record against the slot file. The single definition
/// shared by all kernels' scalar paths.
#[inline(always)]
pub fn eval_rec(rec: &OpRec, li: &[u64], ext_args: &[u32]) -> u64 {
    let a = li[rec.a as usize];
    let raw = match rec.kop() {
        KOp::Add => a.wrapping_add(li[rec.b as usize]),
        KOp::Sub => a.wrapping_sub(li[rec.b as usize]),
        KOp::Mul => a.wrapping_mul(li[rec.b as usize]),
        KOp::Div => {
            let b = li[rec.b as usize];
            if b == 0 {
                0
            } else {
                a / b
            }
        }
        KOp::Rem => {
            let b = li[rec.b as usize];
            if b == 0 {
                0
            } else {
                a % b
            }
        }
        KOp::Lt => (a < li[rec.b as usize]) as u64,
        KOp::Leq => (a <= li[rec.b as usize]) as u64,
        KOp::Gt => (a > li[rec.b as usize]) as u64,
        KOp::Geq => (a >= li[rec.b as usize]) as u64,
        KOp::Eq => (a == li[rec.b as usize]) as u64,
        KOp::Neq => (a != li[rec.b as usize]) as u64,
        KOp::And => a & li[rec.b as usize],
        KOp::Or => a | li[rec.b as usize],
        KOp::Xor => a ^ li[rec.b as usize],
        KOp::Not => !a,
        KOp::Neg => a.wrapping_neg(),
        KOp::AndrK => (a == rec.aux) as u64,
        KOp::Orr => (a != 0) as u64,
        KOp::Xorr => (a.count_ones() & 1) as u64,
        KOp::ShlI => a << rec.imm,
        KOp::ShrI => a >> rec.imm,
        KOp::Dshl => {
            let b = li[rec.b as usize];
            if b >= 64 {
                0
            } else {
                a << b
            }
        }
        KOp::Dshr => {
            let b = li[rec.b as usize];
            if b >= 64 {
                0
            } else {
                a >> b
            }
        }
        KOp::Cat => (a << rec.imm) | li[rec.b as usize],
        KOp::Mux => {
            if a != 0 {
                li[rec.b as usize]
            } else {
                li[rec.c as usize]
            }
        }
        KOp::Copy => a,
        KOp::MuxChain => {
            let k = rec.imm as usize;
            // operands: sel0=a, v0=b, then ext (sel1,v1,...,default)
            if a != 0 {
                li[rec.b as usize]
            } else {
                let ext = &ext_args[rec.ext as usize..rec.ext as usize + 2 * k - 1];
                let mut v = li[ext[2 * k - 2] as usize]; // default
                for i in (0..k - 1).rev() {
                    if li[ext[2 * i] as usize] != 0 {
                        v = li[ext[2 * i + 1] as usize];
                    }
                }
                v
            }
        }
    };
    raw & rec.mask
}

/// The lowered design: everything a kernel needs to simulate cycles.
#[derive(Clone, Debug)]
pub struct LayerIr {
    pub name: String,
    /// Slot-file size (== node count of the lowered graph).
    pub num_slots: usize,
    /// Per-layer op records, each layer sorted by (opcode, out).
    pub layers: Vec<Vec<OpRec>>,
    /// Extra operands for MuxChain records.
    pub ext_args: Vec<u32>,
    /// Register commits: (register slot, next-state slot, width mask).
    pub commits: Vec<(u32, u32, u64)>,
    /// Input port slots (testbench writes these between cycles).
    pub input_slots: Vec<u32>,
    /// Input port widths (masking applied by the testbench driver).
    pub input_widths: Vec<u8>,
    /// Named outputs.
    pub output_slots: Vec<(String, u32)>,
    /// Initial slot values: constants + register init values.
    pub init: Vec<(u32, u64)>,
    /// Per-slot signal names (waveforms); parallel to slots, may be empty.
    pub slot_names: Vec<Option<Box<str>>>,
    /// Per-slot widths (VCD + export).
    pub slot_widths: Vec<u8>,
    /// Identity-op count from levelization (Table 1 reporting).
    pub identity_ops: usize,
}

impl LayerIr {
    /// Serialize everything the OIM does **not** carry (the service design
    /// cache stores this sidecar next to the OIM JSON): ports, commits,
    /// initial values, names and widths. `layers`/`ext_args` are elided —
    /// OIM format B is the layers in their natural order, so
    /// [`Self::from_json_with_oim`] rebuilds them via
    /// [`Oim::op_recs_natural`](crate::tensor::oim::Oim::op_recs_natural).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{arr_str, arr_u32, arr_u64, obj, Json};
        let u8arr = |xs: &[u8]| Json::Arr(xs.iter().map(|&v| Json::Int(v as i64)).collect());
        obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("num_slots", Json::Int(self.num_slots as i64)),
            ("commit_reg", arr_u32(&self.commits.iter().map(|c| c.0).collect::<Vec<_>>())),
            ("commit_next", arr_u32(&self.commits.iter().map(|c| c.1).collect::<Vec<_>>())),
            ("commit_mask", arr_u64(&self.commits.iter().map(|c| c.2).collect::<Vec<_>>())),
            ("input_slots", arr_u32(&self.input_slots)),
            ("input_widths", u8arr(&self.input_widths)),
            (
                "output_names",
                arr_str(&self.output_slots.iter().map(|(n, _)| n.clone()).collect::<Vec<_>>()),
            ),
            (
                "output_slots",
                arr_u32(&self.output_slots.iter().map(|(_, s)| *s).collect::<Vec<_>>()),
            ),
            ("init_slots", arr_u32(&self.init.iter().map(|i| i.0).collect::<Vec<_>>())),
            ("init_vals", arr_u64(&self.init.iter().map(|i| i.1).collect::<Vec<_>>())),
            (
                "slot_names",
                Json::Arr(
                    self.slot_names
                        .iter()
                        .map(|n| match n {
                            Some(s) => Json::Str(s.to_string()),
                            None => Json::Null,
                        })
                        .collect(),
                ),
            ),
            ("slot_widths", u8arr(&self.slot_widths)),
            ("identity_ops", Json::Int(self.identity_ops as i64)),
        ])
    }

    /// Rebuild the full IR from the sidecar plus the OIM it was saved
    /// with (see [`Self::to_json`]).
    pub fn from_json_with_oim(
        j: &crate::util::json::Json,
        oim: &crate::tensor::oim::Oim,
    ) -> Result<Self, crate::util::json::JsonError> {
        use crate::util::json::{Json, JsonError};
        let num_slots = j.req_usize("num_slots")?;
        if num_slots != oim.num_slots as usize {
            return Err(JsonError::Schema(format!(
                "IR sidecar slot count {num_slots} disagrees with OIM {}",
                oim.num_slots
            )));
        }
        let (layers, ext_args) = oim.op_recs_natural();
        let b8 = |key: &str| -> Result<Vec<u8>, JsonError> {
            Ok(j.req_u64_vec(key)?.into_iter().map(|v| v as u8).collect())
        };
        let commit_reg = j.req_u32_vec("commit_reg")?;
        let commit_next = j.req_u32_vec("commit_next")?;
        let commit_mask = j.req_u64_vec("commit_mask")?;
        if commit_reg.len() != commit_next.len() || commit_reg.len() != commit_mask.len() {
            return Err(JsonError::Schema("commit arrays disagree on length".into()));
        }
        let output_names = j.req_arr("output_names")?;
        let output_slots = j.req_u32_vec("output_slots")?;
        if output_names.len() != output_slots.len() {
            return Err(JsonError::Schema("output arrays disagree on length".into()));
        }
        let init_slots = j.req_u32_vec("init_slots")?;
        let init_vals = j.req_u64_vec("init_vals")?;
        if init_slots.len() != init_vals.len() {
            return Err(JsonError::Schema("init arrays disagree on length".into()));
        }
        let slot_names = j
            .req_arr("slot_names")?
            .iter()
            .map(|v| match v {
                Json::Null => Ok(None),
                Json::Str(s) => Ok(Some(s.clone().into_boxed_str())),
                _ => Err(JsonError::Schema("slot_names element not string/null".into())),
            })
            .collect::<Result<Vec<_>, _>>()?;
        if slot_names.len() != num_slots {
            return Err(JsonError::Schema("slot_names length disagrees with num_slots".into()));
        }
        Ok(LayerIr {
            name: j.req_str("name")?.to_string(),
            num_slots,
            layers,
            ext_args,
            commits: commit_reg
                .into_iter()
                .zip(commit_next)
                .zip(commit_mask)
                .map(|((r, n), m)| (r, n, m))
                .collect(),
            input_slots: j.req_u32_vec("input_slots")?,
            input_widths: b8("input_widths")?,
            output_slots: output_names
                .iter()
                .map(|n| {
                    n.as_str()
                        .map(String::from)
                        .ok_or_else(|| JsonError::Schema("output name not a string".into()))
                })
                .collect::<Result<Vec<_>, _>>()?
                .into_iter()
                .zip(output_slots)
                .collect(),
            init: init_slots.into_iter().zip(init_vals).collect(),
            slot_names,
            slot_widths: b8("slot_widths")?,
            identity_ops: j.req_usize("identity_ops")?,
        })
    }

    /// Total effectual operations.
    pub fn total_ops(&self) -> usize {
        self.layers.iter().map(|l| l.len()).sum()
    }

    /// Depth of the dataflow graph (shape of rank I).
    pub fn depth(&self) -> usize {
        self.layers.len()
    }

    /// Widest layer (shape of rank S).
    pub fn max_layer_ops(&self) -> usize {
        self.layers.iter().map(|l| l.len()).max().unwrap_or(0)
    }

    /// Fresh slot file with constants and register initial values applied.
    pub fn initial_slots(&self) -> Vec<u64> {
        let mut v = vec![0u64; self.num_slots];
        for &(slot, val) in &self.init {
            v[slot as usize] = val;
        }
        v
    }
}

/// Lower a graph to layer IR (levelize + normalize + sort).
pub fn lower(g: &Graph) -> LayerIr {
    let lv: Levelized = levelize(g);
    let mut layers: Vec<Vec<OpRec>> = vec![Vec::new(); lv.depth()];
    let mut ext_args: Vec<u32> = Vec::new();

    for (li, layer) in lv.layers.iter().enumerate() {
        for &nid in layer {
            let node = &g.nodes[nid as usize];
            let NodeKind::Prim(op) = node.kind else { unreachable!() };
            let arg_w: Vec<u8> = node.args.iter().map(|&a| g.width(a)).collect();
            let rec = normalize(op, &node.args, &arg_w, node.width, nid, &mut ext_args);
            layers[li].push(rec);
        }
    }

    let mut init: Vec<(u32, u64)> = Vec::new();
    for (i, n) in g.nodes.iter().enumerate() {
        if let NodeKind::Const(c) = n.kind {
            init.push((i as u32, c));
        }
    }
    for r in &g.regs {
        init.push((r.node, r.init));
    }

    LayerIr {
        name: g.name.clone(),
        num_slots: g.nodes.len(),
        layers,
        ext_args,
        commits: g.regs.iter().map(|r| (r.node, r.next, mask(r.width))).collect(),
        input_slots: g.inputs.iter().map(|p| p.node).collect(),
        input_widths: g.inputs.iter().map(|p| p.width).collect(),
        output_slots: g.outputs.clone(),
        init,
        slot_names: g.nodes.iter().map(|n| n.name.clone()).collect(),
        slot_widths: g.nodes.iter().map(|n| n.width).collect(),
        identity_ops: lv.identity_ops,
    }
}

/// Normalize a graph primitive into an executor record.
fn normalize(
    op: PrimOp,
    args: &[u32],
    arg_w: &[u8],
    out_w: u8,
    out: u32,
    ext_args: &mut Vec<u32>,
) -> OpRec {
    let m = mask(out_w);
    let mut rec = OpRec {
        out,
        a: args.first().copied().unwrap_or(0),
        b: args.get(1).copied().unwrap_or(0),
        c: args.get(2).copied().unwrap_or(0),
        mask: m,
        aux: 0,
        op: 0,
        arity: args.len().min(255) as u8,
        imm: 0,
        _pad: 0,
        ext: 0,
    };
    let kop = match op {
        PrimOp::Add => KOp::Add,
        PrimOp::Sub => KOp::Sub,
        PrimOp::Mul => KOp::Mul,
        PrimOp::Div => KOp::Div,
        PrimOp::Rem => KOp::Rem,
        PrimOp::Lt => KOp::Lt,
        PrimOp::Leq => KOp::Leq,
        PrimOp::Gt => KOp::Gt,
        PrimOp::Geq => KOp::Geq,
        PrimOp::Eq => KOp::Eq,
        PrimOp::Neq => KOp::Neq,
        PrimOp::And => KOp::And,
        PrimOp::Or => KOp::Or,
        PrimOp::Xor => KOp::Xor,
        PrimOp::Not => KOp::Not,
        PrimOp::Neg => KOp::Neg,
        PrimOp::Orr => KOp::Orr,
        PrimOp::Xorr => KOp::Xorr,
        PrimOp::Dshl => KOp::Dshl,
        PrimOp::Dshr => KOp::Dshr,
        PrimOp::Mux => KOp::Mux,
        PrimOp::Andr => {
            rec.aux = mask(arg_w[0]);
            KOp::AndrK
        }
        PrimOp::Shl(n) => {
            if n == 0 {
                KOp::Copy
            } else if n >= 64 {
                rec.mask = 0;
                KOp::Copy
            } else {
                rec.imm = n;
                KOp::ShlI
            }
        }
        PrimOp::Shr(n) => {
            if n == 0 {
                KOp::Copy
            } else if n >= 64 {
                rec.mask = 0;
                KOp::Copy
            } else {
                rec.imm = n;
                KOp::ShrI
            }
        }
        PrimOp::Cat => {
            rec.imm = arg_w[1];
            if arg_w[1] >= 64 {
                // degenerate: b occupies the whole word; out = b
                rec.a = rec.b;
                KOp::Copy
            } else {
                KOp::Cat
            }
        }
        PrimOp::Bits(hi, lo) => {
            rec.mask = m & mask(hi - lo + 1);
            if lo == 0 {
                KOp::Copy
            } else {
                rec.imm = lo;
                KOp::ShrI
            }
        }
        PrimOp::Head(n) => {
            let shift = arg_w[0] - n;
            rec.mask = m & mask(n);
            if shift == 0 {
                KOp::Copy
            } else {
                rec.imm = shift;
                KOp::ShrI
            }
        }
        PrimOp::Tail(n) => {
            rec.mask = m & mask(arg_w[0] - n);
            KOp::Copy
        }
        PrimOp::Pad(_) | PrimOp::Id => KOp::Copy,
        PrimOp::MuxChain(k) => {
            rec.imm = k;
            rec.arity = (2 * k + 1).min(255);
            // a = sel0, b = v0; rest to ext_args
            rec.ext = ext_args.len() as u32;
            ext_args.extend_from_slice(&args[2..]);
            KOp::MuxChain
        }
    };
    rec.op = kop as u8;
    rec
}

/// Slot-file simulator over the layer IR — the "semantic bridge" between
/// the graph world and the kernel world (kernels must match this exactly,
/// and this must match `graph::RefSim`).
pub struct IrSim {
    pub ir: LayerIr,
    pub slots: Vec<u64>,
}

impl IrSim {
    pub fn new(ir: LayerIr) -> Self {
        let slots = ir.initial_slots();
        Self { ir, slots }
    }

    pub fn step(&mut self, inputs: &[u64]) {
        for (i, &slot) in self.ir.input_slots.iter().enumerate() {
            self.slots[slot as usize] = inputs[i] & mask(self.ir.input_widths[i]);
        }
        for layer in &self.ir.layers {
            for rec in layer {
                self.slots[rec.out as usize] = eval_rec(rec, &self.slots, &self.ir.ext_args);
            }
        }
        for &(reg, next, m) in &self.ir.commits {
            self.slots[reg as usize] = self.slots[next as usize] & m;
        }
    }

    pub fn outputs(&self) -> Vec<(String, u64)> {
        self.ir.output_slots.iter().map(|(n, s)| (n.clone(), self.slots[*s as usize])).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::{random_circuit, random_inputs};
    use crate::graph::passes::optimize;
    use crate::graph::RefSim;
    use crate::util::prng::Rng;

    /// IR lowering preserves semantics vs the graph interpreter, both on
    /// raw and optimized graphs (which contain MuxChain records).
    #[test]
    fn ir_sim_matches_ref() {
        for seed in 0..15 {
            let mut rng = Rng::new(9000 + seed);
            let g = random_circuit(&mut rng, 70);
            let (opt, _) = optimize(&g);
            let mut r = RefSim::new(g.clone());
            let mut a = IrSim::new(lower(&g));
            let mut b = IrSim::new(lower(&opt));
            for cycle in 0..12 {
                let inputs = random_inputs(&mut rng, &r.graph);
                r.step(&inputs);
                a.step(&inputs);
                b.step(&inputs);
                assert_eq!(r.outputs(), a.outputs(), "raw ir seed {seed} cycle {cycle}");
                assert_eq!(r.outputs(), b.outputs(), "opt ir seed {seed} cycle {cycle}");
            }
        }
    }

    #[test]
    fn layers_respect_slot_order() {
        let mut rng = Rng::new(77);
        let g = random_circuit(&mut rng, 100);
        let ir = lower(&g);
        for layer in &ir.layers {
            for w in layer.windows(2) {
                assert!(w[0].out < w[1].out, "format-B natural S order");
            }
        }
    }

    #[test]
    fn normalization_removes_width_dependence() {
        // bits/head/tail/pad become shift+mask records
        let mut g = crate::graph::Graph::new("t");
        let a = g.input("a", 12);
        let b1 = g.prim(PrimOp::Bits(7, 2), &[a]);
        let h = g.prim(PrimOp::Head(3), &[a]);
        let t = g.prim(PrimOp::Tail(4), &[a]);
        let p = g.prim(PrimOp::Pad(16), &[a]);
        let c = g.prim(PrimOp::Cat, &[b1, h]);
        g.output("b", b1);
        g.output("h", h);
        g.output("t", t);
        g.output("p", p);
        g.output("c", c);
        let ir = lower(&g);
        let mut sim = IrSim::new(ir);
        sim.step(&[0b1010_1101_0110]);
        let o: std::collections::HashMap<String, u64> = sim.outputs().into_iter().collect();
        assert_eq!(o["b"], 0b110101);
        assert_eq!(o["h"], 0b101);
        assert_eq!(o["t"], 0b1101_0110);
        assert_eq!(o["p"], 0b1010_1101_0110);
        assert_eq!(o["c"], (0b110101 << 3) | 0b101);
    }

    /// The sidecar + OIM pair reconstructs a semantically identical IR
    /// (the design-cache load path): same step behavior, ports, commits
    /// and metadata.
    #[test]
    fn sidecar_roundtrip_through_oim() {
        use crate::tensor::oim::Oim;
        let mut rng = Rng::new(9100);
        let g = random_circuit(&mut rng, 90);
        let (opt, _) = optimize(&g);
        let ir = lower(&opt);
        let oim = Oim::from_ir(&ir);
        let oim2 =
            Oim::from_json(&crate::util::json::parse(&oim.to_json().to_string()).unwrap()).unwrap();
        let side = crate::util::json::parse(&ir.to_json().to_string()).unwrap();
        let ir2 = LayerIr::from_json_with_oim(&side, &oim2).unwrap();
        assert_eq!(ir2.name, ir.name);
        assert_eq!(ir2.commits, ir.commits);
        assert_eq!(ir2.input_slots, ir.input_slots);
        assert_eq!(ir2.output_slots, ir.output_slots);
        assert_eq!(ir2.init, ir.init);
        assert_eq!(ir2.slot_names, ir.slot_names);
        assert_eq!(ir2.slot_widths, ir.slot_widths);
        assert_eq!(ir2.total_ops(), ir.total_ops());
        let mut a = IrSim::new(ir);
        let mut b = IrSim::new(ir2);
        for _ in 0..10 {
            let inputs = random_inputs(&mut rng, &opt);
            a.step(&inputs);
            b.step(&inputs);
            assert_eq!(a.outputs(), b.outputs());
        }
    }

    #[test]
    fn opcode_roundtrip() {
        for v in 0..NUM_KOPS as u8 {
            assert_eq!(KOp::from_u8(v) as u8, v);
        }
    }
}
