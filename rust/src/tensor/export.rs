//! Dense tensor-ISA export for the XLA/PJRT backend.
//!
//! The L2 jax model (`python/compile/model.py`) consumes this encoding at
//! AOT time and lowers one full simulation cycle to HLO. The encoding is
//! the *dense* instantiation of the cascade: per layer, padded arrays
//! `opcode/a/b/c/imm/mask/aux` of shape `[num_layers, max_ops]`; a cycle
//! is `gather → multi-op ALU (the L1 Pallas kernel) → contiguous update`
//! per layer, then the register commit.
//!
//! **Slot layout (scatter-free contract with L2).** xla_extension 0.5.1
//! (the version the `xla` crate binds) mis-executes the scatter ops newer
//! jax emits for `state.at[idx].set`, so the export renumbers slots such
//! that every state update is a contiguous `dynamic_update_slice`:
//!
//! ```text
//! [0, n_inputs)                      input ports (row update at 0)
//! [n_inputs, +n_regs)                registers   (commit update here)
//! [.., +n_consts)                    constants
//! [sources_end + i*max_ops, +max_ops)  layer i outputs (one DUS per layer)
//! ```
//!
//! `max_ops` is padded to a multiple of the Pallas block (128); padding
//! lanes are mask-0 copies of slot 0 writing their own (dead) lane slot.
//!
//! Constraints (checked): all signal widths ≤ 32 (u32 tensor values) and
//! no fused mux chains (export from the `optimize_no_fusion` pipeline).

use crate::tensor::ir::{KOp, LayerIr};
use crate::util::json::{arr_str, arr_u32, obj, Json, JsonError};

#[derive(Debug)]
pub enum ExportError {
    TooWide(u8),
    HasMuxChain,
}

impl std::fmt::Display for ExportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExportError::TooWide(w) => {
                write!(f, "design has a signal of width {w} > 32; XLA backend is u32")
            }
            ExportError::HasMuxChain => {
                write!(f, "design contains fused mux chains; export from optimize_no_fusion")
            }
        }
    }
}

impl std::error::Error for ExportError {}

/// Dense encoding of a design for the XLA backend.
#[derive(Debug, Clone, PartialEq)]
pub struct DenseDesign {
    pub name: String,
    pub num_slots: usize,
    pub num_layers: usize,
    pub max_ops: usize,
    /// start of the layer-output region (== number of source slots)
    pub sources_end: usize,
    pub num_inputs: usize,
    pub num_regs: usize,
    pub opcode: Vec<u32>,
    pub a: Vec<u32>,
    pub b: Vec<u32>,
    pub c: Vec<u32>,
    pub imm: Vec<u32>,
    pub mask: Vec<u32>,
    pub aux: Vec<u32>,
    /// next-state slot per register (commit = gather + DUS at n_inputs)
    pub commit_next: Vec<u32>,
    pub commit_mask: Vec<u32>,
    pub input_widths: Vec<u32>,
    pub init_slots: Vec<u32>,
    pub init_vals: Vec<u32>,
    pub output_slots: Vec<u32>,
    pub output_names: Vec<String>,
}

/// Lower a LayerIr to the dense scatter-free encoding. `pad_to` rounds
/// `max_ops` up (Pallas block tiling).
pub fn to_dense(ir: &LayerIr, pad_to: usize) -> Result<DenseDesign, ExportError> {
    for &w in &ir.slot_widths {
        if w > 32 {
            return Err(ExportError::TooWide(w));
        }
    }
    let num_layers = ir.depth().max(1);
    let raw_max = ir.max_layer_ops().max(1);
    let max_ops = raw_max.div_ceil(pad_to.max(1)) * pad_to.max(1);

    // ---- slot renumbering ----
    let n_inputs = ir.input_slots.len();
    let n_regs = ir.commits.len();
    let mut map: Vec<Option<u32>> = vec![None; ir.num_slots];
    let mut next = 0u32;
    for &s in &ir.input_slots {
        map[s as usize] = Some(next);
        next += 1;
    }
    for &(reg, _, _) in &ir.commits {
        map[reg as usize] = Some(next);
        next += 1;
    }
    // constants (and any register-init slots already mapped above)
    for &(slot, _) in &ir.init {
        if map[slot as usize].is_none() {
            map[slot as usize] = Some(next);
            next += 1;
        }
    }
    let sources_end = next as usize;
    for (li, layer) in ir.layers.iter().enumerate() {
        for (pos, rec) in layer.iter().enumerate() {
            map[rec.out as usize] = Some((sources_end + li * max_ops + pos) as u32);
        }
    }
    let num_slots = sources_end + num_layers * max_ops;
    let remap = |old: u32| -> u32 {
        map[old as usize].unwrap_or_else(|| panic!("slot {old} unmapped (unused source?)"))
    };

    let n = num_layers * max_ops;
    let mut d = DenseDesign {
        name: ir.name.clone(),
        num_slots,
        num_layers,
        max_ops,
        sources_end,
        num_inputs: n_inputs,
        num_regs: n_regs,
        opcode: vec![KOp::Copy as u8 as u32; n],
        a: vec![0; n],
        b: vec![0; n],
        c: vec![0; n],
        imm: vec![0; n],
        mask: vec![0; n],
        aux: vec![0; n],
        commit_next: ir.commits.iter().map(|c| remap(c.1)).collect(),
        commit_mask: ir.commits.iter().map(|c| c.2 as u32).collect(),
        input_widths: ir.input_widths.iter().map(|&w| w as u32).collect(),
        init_slots: Vec::new(),
        init_vals: Vec::new(),
        output_slots: ir.output_slots.iter().map(|o| remap(o.1)).collect(),
        output_names: ir.output_slots.iter().map(|o| o.0.clone()).collect(),
    };
    for &(slot, val) in &ir.init {
        d.init_slots.push(remap(slot));
        d.init_vals.push(val as u32);
    }
    for (li, layer) in ir.layers.iter().enumerate() {
        for (pos, rec) in layer.iter().enumerate() {
            if rec.kop() == KOp::MuxChain {
                return Err(ExportError::HasMuxChain);
            }
            let idx = li * max_ops + pos;
            d.opcode[idx] = rec.op as u32;
            d.a[idx] = remap(rec.a);
            d.b[idx] = if rec.arity >= 2 { remap(rec.b) } else { 0 };
            d.c[idx] = if rec.arity >= 3 { remap(rec.c) } else { 0 };
            d.imm[idx] = rec.imm as u32;
            d.mask[idx] = rec.mask as u32;
            d.aux[idx] = rec.aux as u32;
        }
    }
    Ok(d)
}

impl DenseDesign {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("num_slots", Json::Int(self.num_slots as i64)),
            ("num_layers", Json::Int(self.num_layers as i64)),
            ("max_ops", Json::Int(self.max_ops as i64)),
            ("sources_end", Json::Int(self.sources_end as i64)),
            ("num_inputs", Json::Int(self.num_inputs as i64)),
            ("num_regs", Json::Int(self.num_regs as i64)),
            ("opcode", arr_u32(&self.opcode)),
            ("a", arr_u32(&self.a)),
            ("b", arr_u32(&self.b)),
            ("c", arr_u32(&self.c)),
            ("imm", arr_u32(&self.imm)),
            ("mask", arr_u32(&self.mask)),
            ("aux", arr_u32(&self.aux)),
            ("commit_next", arr_u32(&self.commit_next)),
            ("commit_mask", arr_u32(&self.commit_mask)),
            ("input_widths", arr_u32(&self.input_widths)),
            ("init_slots", arr_u32(&self.init_slots)),
            ("init_vals", arr_u32(&self.init_vals)),
            ("output_slots", arr_u32(&self.output_slots)),
            ("output_names", arr_str(&self.output_names)),
        ])
    }

    /// Inverse of [`DenseDesign::to_json`] (the encoding the Python AOT
    /// side reads; round-trip property-tested in `tests/kernels_property`).
    pub fn from_json(j: &Json) -> Result<Self, JsonError> {
        let output_names = j
            .req_arr("output_names")?
            .iter()
            .map(|v| {
                v.as_str()
                    .map(|s| s.to_string())
                    .ok_or_else(|| JsonError::Schema("output_names element not a string".into()))
            })
            .collect::<Result<Vec<String>, JsonError>>()?;
        Ok(DenseDesign {
            name: j.req_str("name")?.to_string(),
            num_slots: j.req_usize("num_slots")?,
            num_layers: j.req_usize("num_layers")?,
            max_ops: j.req_usize("max_ops")?,
            sources_end: j.req_usize("sources_end")?,
            num_inputs: j.req_usize("num_inputs")?,
            num_regs: j.req_usize("num_regs")?,
            opcode: j.req_u32_vec("opcode")?,
            a: j.req_u32_vec("a")?,
            b: j.req_u32_vec("b")?,
            c: j.req_u32_vec("c")?,
            imm: j.req_u32_vec("imm")?,
            mask: j.req_u32_vec("mask")?,
            aux: j.req_u32_vec("aux")?,
            commit_next: j.req_u32_vec("commit_next")?,
            commit_mask: j.req_u32_vec("commit_mask")?,
            input_widths: j.req_u32_vec("input_widths")?,
            init_slots: j.req_u32_vec("init_slots")?,
            init_vals: j.req_u32_vec("init_vals")?,
            output_slots: j.req_u32_vec("output_slots")?,
            output_names,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::random_circuit;
    use crate::graph::ops::PrimOp;
    use crate::graph::passes::optimize_no_fusion;
    use crate::tensor::ir::lower;
    use crate::util::prng::Rng;

    #[test]
    fn export_layout_is_contiguous() {
        let mut rng = Rng::new(50);
        let g = random_circuit(&mut rng, 60);
        let opt = optimize_no_fusion(&g);
        let ir = lower(&opt);
        let d = to_dense(&ir, 8).unwrap();
        assert_eq!(d.opcode.len(), d.num_layers * d.max_ops);
        assert_eq!(d.max_ops % 8, 0);
        assert_eq!(d.num_slots, d.sources_end + d.num_layers * d.max_ops);
        // operands always reference earlier slots (sources or earlier layers)
        for li in 0..d.num_layers {
            let layer_base = (d.sources_end + li * d.max_ops) as u32;
            for pos in 0..d.max_ops {
                let i = li * d.max_ops + pos;
                assert!(d.a[i] < layer_base, "layer {li} op {pos} reads its own layer");
                assert!(d.b[i] < layer_base);
                assert!(d.c[i] < layer_base);
            }
        }
    }

    #[test]
    fn rejects_wide_designs() {
        let mut g = crate::graph::Graph::new("wide");
        let a = g.input("a", 40);
        let n = g.prim(PrimOp::Not, &[a]);
        g.output("o", n);
        let ir = lower(&g);
        assert!(matches!(to_dense(&ir, 8), Err(ExportError::TooWide(40))));
    }

    #[test]
    fn rejects_mux_chains() {
        let mut g = crate::graph::Graph::new("mc");
        let s0 = g.input("s0", 1);
        let v0 = g.input("v0", 4);
        let s1 = g.input("s1", 1);
        let v1 = g.input("v1", 4);
        let d0 = g.input("d", 4);
        let m = g.prim(PrimOp::MuxChain(2), &[s0, v0, s1, v1, d0]);
        g.output("o", m);
        let ir = lower(&g);
        assert!(matches!(to_dense(&ir, 8), Err(ExportError::HasMuxChain)));
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let mut rng = Rng::new(52);
        let mut checked = 0;
        for _ in 0..20 {
            let g = random_circuit(&mut rng, 40);
            let opt = optimize_no_fusion(&g);
            let ir = lower(&opt);
            if ir.slot_widths.iter().any(|&w| w > 32) {
                continue; // dense export is u32-only
            }
            let d = to_dense(&ir, 8).unwrap();
            let j = crate::util::json::parse(&d.to_json().to_string()).unwrap();
            let d2 = DenseDesign::from_json(&j).unwrap();
            assert_eq!(d, d2);
            checked += 1;
        }
        assert!(checked > 0, "no 32-bit-safe sample circuit found");
    }

    #[test]
    fn json_has_all_fields() {
        let mut rng = Rng::new(51);
        let g = random_circuit(&mut rng, 30);
        let opt = optimize_no_fusion(&g);
        let d = to_dense(&lower(&opt), 8).unwrap();
        let j = crate::util::json::parse(&d.to_json().to_string()).unwrap();
        for f in ["opcode", "a", "b", "c", "imm", "mask", "aux", "commit_next", "sources_end"] {
            assert!(j.get(f).is_some(), "missing {f}");
        }
        assert_eq!(j.req_usize("max_ops").unwrap(), d.max_ops);
    }
}
