//! Tensor representation of the RTL dataflow graph (paper §4–§5).
//!
//! * [`ir`] — the lowered layer IR: levelized operations with normalized
//!   executor opcodes ([`ir::KOp`]) and packed records ([`ir::OpRec`]).
//!   This is the *logical content* of the `LI`/`OIM`/`LO` tensors.
//! * [`fibertree`] — the fibertree abstraction of Sze et al. (paper §2.2),
//!   used by the Einsum cascade evaluator and for format reasoning.
//! * [`format`] — per-rank concrete formats: (un)compressed, cbits/pbits
//!   (paper §2.5.2 and Fig 12), and the three OIM format instantiations.
//! * [`oim`] — the OIM tensor builder: rank coordinate/payload arrays in
//!   format B ([I,S,N,O,R]) and format C (swizzled [I,N,S,O,R]), plus JSON
//!   import/export (the paper stores OIM as JSON).
//! * [`export`] — dense tensor-ISA export for the XLA/PJRT backend (the L2
//!   jax model consumes this encoding at AOT time).

pub mod ir;
pub mod fibertree;
pub mod format;
pub mod oim;
pub mod export;
