//! DMI-style host–DUT channel (paper §6.2): "RTeAAL Sim connects the
//! frontend server and the DUT by reading and updating DTM signals in the
//! LI at the end of each simulation cycle."
//!
//! [`DmiHost`] is a minimal FESVR analog for `tiny_cpu`: it drives the
//! `dmi_*` input ports to write words into DUT RAM before releasing the
//! core, and reads results back through `dmi_rdata` after completion.

use crate::kernels::SimKernel;

/// Input port order expected from `designs::tiny_cpu`:
/// `[dmi_wen, dmi_addr, dmi_wdata, dmi_raddr]`.
pub struct DmiHost;

impl DmiHost {
    /// Write `words` into DUT RAM starting at `base` (one word per cycle).
    pub fn load(kernel: &mut dyn SimKernel, base: u32, words: &[u32]) {
        for (i, &w) in words.iter().enumerate() {
            kernel.step(&[1, (base + i as u32) as u64, w as u64, 0]);
        }
        // settle cycle with DMI idle
        kernel.step(&[0, 0, 0, 0]);
    }

    /// Read one word of DUT RAM via the DMI read port.
    pub fn peek(kernel: &mut dyn SimKernel, addr: u32) -> u64 {
        // drive raddr; the read is combinational, visible after the step
        kernel.step(&[0, 0, 0, addr as u64]);
        kernel
            .outputs()
            .into_iter()
            .find(|(n, _)| n == "dmi_rdata")
            .map(|(_, v)| v)
            .expect("design exposes dmi_rdata")
    }

    /// Run until the DUT raises `halted` (returns cycles, None on timeout).
    pub fn run_to_halt(kernel: &mut dyn SimKernel, max_cycles: u64) -> Option<u64> {
        for c in 0..max_cycles {
            kernel.step(&[0, 0, 0, 0]);
            if kernel.outputs().iter().any(|(n, v)| n == "halted" && *v == 1) {
                return Some(c + 1);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::designs::tiny_cpu::{self, addi, beq, halt, lw, sw};
    use crate::graph::passes::optimize;
    use crate::kernels::{build, KernelConfig};
    use crate::tensor::ir::lower;

    /// Full host-DUT session: the DUT spin-waits on a mailbox flag, the
    /// host preloads data + raises the flag via DMI, the program consumes
    /// it, and the host reads the result back via DMI — the FESVR pattern.
    #[test]
    fn fesvr_style_session() {
        let prog = vec![
            lw(2, 0, 11),  // 0: r2 = flag
            beq(2, 0, 0),  // 1: spin until host raises it
            lw(1, 0, 10),  // 2: r1 = mailbox data
            addi(1, 1, 7), // 3: r1 += 7
            sw(1, 0, 0),   // 4: RAM[0] = r1
            halt(),        // 5
        ];
        let g = tiny_cpu::tiny_cpu(&prog);
        let (opt, _) = optimize(&g);
        let ir = lower(&opt);
        let mut kernel = build(KernelConfig::PSU, &ir);
        // host writes 35 into the mailbox, then raises the flag
        DmiHost::load(kernel.as_mut(), 10, &[35]);
        DmiHost::load(kernel.as_mut(), 11, &[1]);
        let cycles = DmiHost::run_to_halt(kernel.as_mut(), 100).expect("halts");
        assert!(cycles < 50);
        assert_eq!(DmiHost::peek(kernel.as_mut(), 0), 42);
    }
}
