//! DMI-style host–DUT channel (paper §6.2): "RTeAAL Sim connects the
//! frontend server and the DUT by reading and updating DTM signals in the
//! LI at the end of each simulation cycle."
//!
//! [`DmiHost`] is a minimal FESVR analog for `tiny_cpu`: it drives the
//! `dmi_*` input ports to write words into DUT RAM before releasing the
//! core, and reads results back through `dmi_rdata` after completion.
//!
//! The host resolves every port it touches **by name at construction**
//! ([`DmiHost::new`]) and reports a structured error naming the missing
//! port, so a design with extra ports, reordered ports, or no DMI at all
//! fails loudly before the first cycle instead of silently driving the
//! wrong wires. The batched methods ([`DmiHost::load_lanes`],
//! [`DmiHost::run_to_halt_lanes`], [`DmiHost::peek_lane`]) drive a
//! *distinct* DMI program into every lane of a batched kernel — paired
//! with [`designs::tiny_cpu::tiny_cpu_divergent`](crate::designs::tiny_cpu)
//! lane ROMs, that is B different host-DUT sessions per OIM walk.

use crate::kernels::{BatchKernel, SimKernel};
use crate::tensor::ir::LayerIr;

/// FESVR-style DMI host with ports resolved by name.
///
/// Holds the positions of the `dmi_wen` / `dmi_addr` / `dmi_wdata` /
/// `dmi_raddr` input ports (indices into the kernel's input frame) and of
/// the `dmi_rdata` / `halted` outputs (indices into
/// [`SimKernel::outputs`] / [`BatchKernel::lane_outputs`], which follow
/// `LayerIr::output_slots` order). Any kernel built from the same
/// [`LayerIr`] — scalar or batched, dense or sparse — is compatible.
pub struct DmiHost {
    wen: usize,
    addr: usize,
    wdata: usize,
    raddr: usize,
    num_inputs: usize,
    rdata: usize,
    halted: usize,
}

impl DmiHost {
    /// Resolve the DMI ports in `ir`. Errors name the missing port and
    /// list what the design actually exposes.
    pub fn new(ir: &LayerIr) -> Result<DmiHost, String> {
        let input = |name: &str| -> Result<usize, String> {
            ir.input_slots
                .iter()
                .position(|&s| {
                    ir.slot_names.get(s as usize).and_then(|n| n.as_deref()) == Some(name)
                })
                .ok_or_else(|| {
                    let have: Vec<&str> = ir
                        .input_slots
                        .iter()
                        .filter_map(|&s| ir.slot_names.get(s as usize).and_then(|n| n.as_deref()))
                        .collect();
                    format!(
                        "design '{}' has no input port '{name}' (inputs: {have:?})",
                        ir.name
                    )
                })
        };
        let output = |name: &str| -> Result<usize, String> {
            ir.output_slots.iter().position(|(n, _)| n == name).ok_or_else(|| {
                let have: Vec<&str> =
                    ir.output_slots.iter().map(|(n, _)| n.as_str()).collect();
                format!("design '{}' has no output '{name}' (outputs: {have:?})", ir.name)
            })
        };
        Ok(DmiHost {
            wen: input("dmi_wen")?,
            addr: input("dmi_addr")?,
            wdata: input("dmi_wdata")?,
            raddr: input("dmi_raddr")?,
            num_inputs: ir.input_slots.len(),
            rdata: output("dmi_rdata")?,
            halted: output("halted")?,
        })
    }

    /// One scalar input frame with the DMI ports set and every other
    /// port idle (zero).
    fn frame(&self, wen: u64, addr: u64, wdata: u64, raddr: u64) -> Vec<u64> {
        let mut f = vec![0u64; self.num_inputs];
        f[self.wen] = wen;
        f[self.addr] = addr;
        f[self.wdata] = wdata;
        f[self.raddr] = raddr;
        f
    }

    /// Write `words` into DUT RAM starting at `base` (one word per cycle).
    pub fn load(&self, kernel: &mut dyn SimKernel, base: u32, words: &[u32]) {
        for (i, &w) in words.iter().enumerate() {
            kernel.step(&self.frame(1, (base + i as u32) as u64, w as u64, 0));
        }
        // settle cycle with DMI idle
        kernel.step(&self.frame(0, 0, 0, 0));
    }

    /// Read one word of DUT RAM via the DMI read port.
    pub fn peek(&self, kernel: &mut dyn SimKernel, addr: u32) -> u64 {
        // drive raddr; the read is combinational, visible after the step
        kernel.step(&self.frame(0, 0, 0, addr as u64));
        kernel.outputs()[self.rdata].1
    }

    /// Run until the DUT raises `halted` (returns cycles, None on timeout).
    pub fn run_to_halt(&self, kernel: &mut dyn SimKernel, max_cycles: u64) -> Option<u64> {
        for c in 0..max_cycles {
            kernel.step(&self.frame(0, 0, 0, 0));
            if kernel.outputs()[self.halted].1 == 1 {
                return Some(c + 1);
            }
        }
        None
    }

    /// Write a *different* word stream into every lane's RAM, starting at
    /// `base` in each. `words[l]` is lane `l`'s stream; streams may have
    /// different lengths — a lane whose stream is exhausted idles
    /// (`dmi_wen = 0`) while the longer ones finish. Ends with one shared
    /// settle cycle. Errors if `words.len() != kernel.lanes()`.
    pub fn load_lanes(
        &self,
        kernel: &mut dyn BatchKernel,
        base: u32,
        words: &[Vec<u32>],
    ) -> Result<(), String> {
        let lanes = kernel.lanes();
        if words.len() != lanes {
            return Err(format!(
                "load_lanes: {} word streams for a {lanes}-lane kernel",
                words.len()
            ));
        }
        let longest = words.iter().map(Vec::len).max().unwrap_or(0);
        let mut frame = vec![0u64; self.num_inputs * lanes];
        for i in 0..longest {
            frame.fill(0);
            for (l, stream) in words.iter().enumerate() {
                if let Some(&w) = stream.get(i) {
                    frame[self.wen * lanes + l] = 1;
                    frame[self.addr * lanes + l] = (base + i as u32) as u64;
                    frame[self.wdata * lanes + l] = w as u64;
                }
            }
            kernel.step(&frame);
        }
        frame.fill(0);
        kernel.step(&frame);
        Ok(())
    }

    /// Run with the DMI idle until **every** lane raises `halted`.
    /// Returns each lane's halt cycle (counted from this call, 1-based),
    /// or None if any lane is still running after `max_cycles`. Lanes
    /// that halt early keep stepping (the CPU holds its halted state) —
    /// lanes never desynchronize.
    pub fn run_to_halt_lanes(
        &self,
        kernel: &mut dyn BatchKernel,
        max_cycles: u64,
    ) -> Option<Vec<u64>> {
        let lanes = kernel.lanes();
        let frame = vec![0u64; self.num_inputs * lanes];
        let mut halted_at = vec![0u64; lanes];
        let mut running = lanes;
        for c in 0..max_cycles {
            kernel.step(&frame);
            for (l, at) in halted_at.iter_mut().enumerate() {
                if *at == 0 && kernel.lane_outputs(l)[self.halted].1 == 1 {
                    *at = c + 1;
                    running -= 1;
                }
            }
            if running == 0 {
                return Some(halted_at);
            }
        }
        None
    }

    /// Read one word of one lane's RAM. Costs a cycle on the whole batch
    /// (`dmi_raddr` is driven on every lane; only `lane`'s `dmi_rdata`
    /// is returned).
    pub fn peek_lane(
        &self,
        kernel: &mut dyn BatchKernel,
        lane: usize,
        addr: u32,
    ) -> Result<u64, String> {
        let lanes = kernel.lanes();
        if lane >= lanes {
            return Err(format!("peek_lane: lane {lane} out of range ({lanes} lanes)"));
        }
        let mut frame = vec![0u64; self.num_inputs * lanes];
        for l in 0..lanes {
            frame[self.raddr * lanes + l] = addr as u64;
        }
        kernel.step(&frame);
        Ok(kernel.lane_outputs(lane)[self.rdata].1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::designs::tiny_cpu::{self, add, addi, beq, halt, lw, sw};
    use crate::designs::{Design, Stimulus};
    use crate::graph::passes::optimize;
    use crate::kernels::{build, build_batch, build_sparse, KernelConfig};
    use crate::tensor::ir::lower;
    use crate::tensor::oim::Oim;

    /// Full host-DUT session: the DUT spin-waits on a mailbox flag, the
    /// host preloads data + raises the flag via DMI, the program consumes
    /// it, and the host reads the result back via DMI — the FESVR pattern.
    #[test]
    fn fesvr_style_session() {
        let prog = vec![
            lw(2, 0, 11),  // 0: r2 = flag
            beq(2, 0, 0),  // 1: spin until host raises it
            lw(1, 0, 10),  // 2: r1 = mailbox data
            addi(1, 1, 7), // 3: r1 += 7
            sw(1, 0, 0),   // 4: RAM[0] = r1
            halt(),        // 5
        ];
        let g = tiny_cpu::tiny_cpu(&prog);
        let (opt, _) = optimize(&g);
        let ir = lower(&opt);
        let dmi = DmiHost::new(&ir).expect("tiny_cpu exposes the dmi ports");
        let mut kernel = build(KernelConfig::PSU, &ir);
        // host writes 35 into the mailbox, then raises the flag
        dmi.load(kernel.as_mut(), 10, &[35]);
        dmi.load(kernel.as_mut(), 11, &[1]);
        let cycles = dmi.run_to_halt(kernel.as_mut(), 100).expect("halts");
        assert!(cycles < 50);
        assert_eq!(dmi.peek(kernel.as_mut(), 0), 42);
    }

    /// A design without the DMI ports is rejected with an error naming
    /// the port — no panic, no wrong-wire driving.
    #[test]
    fn missing_ports_are_a_structured_error() {
        let g = crate::designs::simple::fir(8, 16);
        let (opt, _) = optimize(&g);
        let ir = lower(&opt);
        let err = DmiHost::new(&ir).unwrap_err();
        assert!(err.contains("dmi_wen"), "error names the missing port: {err}");
        assert!(err.contains("no input port"), "error says what is wrong: {err}");
    }

    /// B host-DUT sessions on one batched kernel: each lane runs a
    /// *different* program (divergent lane ROMs) against *different*
    /// mailbox data (per-lane DMI load), and every lane's result matches
    /// its own program semantics.
    #[test]
    fn divergent_lanes_run_distinct_dmi_programs() {
        // program A: RAM[0] = mailbox + 7;  program B: RAM[0] = mailbox * 2
        let spin = vec![lw(2, 0, 11), beq(2, 0, 0)];
        let mut prog_add = spin.clone();
        prog_add.extend([lw(1, 0, 10), addi(1, 1, 7), sw(1, 0, 0), halt()]);
        let mut prog_dbl = spin;
        prog_dbl.extend([lw(1, 0, 10), add(1, 1, 1), sw(1, 0, 0), halt()]);
        let progs = vec![prog_add.clone(), prog_dbl.clone()];

        let rom_words = prog_add.len().max(prog_dbl.len());
        let d = Design {
            name: "dmi_divergent".into(),
            graph: tiny_cpu::tiny_cpu_divergent(rom_words, &prog_add),
            stimulus: Stimulus::Zero,
            default_cycles: 200,
            lane_init: tiny_cpu::lane_rom_init(rom_words, &progs),
        };
        let (opt, _) = optimize(&d.graph);
        let ir = lower(&opt);
        let oim = Oim::from_ir(&ir);
        let dmi = DmiHost::new(&ir).expect("tiny_cpu exposes the dmi ports");

        let lanes = 4;
        let mailbox = [5u32, 9, 11, 100];
        // lane l runs progs[l % 2]: expected RAM[0] per lane
        let expect = [5 + 7, 9 * 2, 11 + 7, 100 * 2];
        for sparse in [false, true] {
            let mut kernel = if sparse {
                build_sparse(KernelConfig::PSU, &ir, &oim, lanes)
            } else {
                build_batch(KernelConfig::PSU, &ir, &oim, lanes)
            };
            d.apply_lane_init(&opt, kernel.as_mut());
            let per_lane: Vec<Vec<u32>> = mailbox.iter().map(|&m| vec![m]).collect();
            dmi.load_lanes(kernel.as_mut(), 10, &per_lane).unwrap();
            dmi.load_lanes(kernel.as_mut(), 11, &vec![vec![1]; lanes]).unwrap();
            let halted = dmi
                .run_to_halt_lanes(kernel.as_mut(), 200)
                .unwrap_or_else(|| panic!("all lanes halt (sparse={sparse})"));
            assert_eq!(halted.len(), lanes);
            for (l, &want) in expect.iter().enumerate() {
                let got = dmi.peek_lane(kernel.as_mut(), l, 0).unwrap();
                assert_eq!(got, want as u64, "lane {l} result (sparse={sparse})");
            }
            // wrong stream count and bad lane are structured errors
            assert!(dmi.load_lanes(kernel.as_mut(), 0, &[vec![0]]).is_err());
            assert!(dmi.peek_lane(kernel.as_mut(), lanes, 0).is_err());
        }
    }
}
