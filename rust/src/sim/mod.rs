//! Simulation infrastructure: the cycle driver ([`simulator`]), VCD
//! waveform generation ([`vcd`], paper §6.2), activity-driven delta
//! waveforms for the batched engine ([`wave`]) and the DMI-style
//! host–DUT channel ([`dmi`], paper §6.2).

pub mod simulator;
pub mod vcd;
pub mod wave;
pub mod dmi;

pub use simulator::{SimStats, Simulator};
pub use wave::WaveSink;
