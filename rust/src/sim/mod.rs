//! Simulation infrastructure: the cycle driver ([`simulator`]), VCD
//! waveform generation ([`vcd`], paper §6.2) and the DMI-style host–DUT
//! channel ([`dmi`], paper §6.2).

pub mod simulator;
pub mod vcd;
pub mod dmi;

pub use simulator::{SimStats, Simulator};
