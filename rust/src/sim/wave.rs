//! Activity-driven delta waveforms for the lane-batched engine.
//!
//! [`WaveSink`] extracts one lane of a batched run as a VCD stream
//! without diffing the slot file: it consumes the change masks the
//! activity subsystem already computes every cycle
//! ([`crate::activity::WaveMasks`] from a sparse
//! [`crate::kernels::BatchKernel`], or
//! [`crate::coordinator::parallel::BatchParallelSim::wave_changed`] for
//! a partitioned run), so a quiescent cycle costs a single mask test —
//! the waveform inherits the sparse engine's skip rate instead of
//! re-scanning every variable per cycle.
//!
//! ## Why the tracker bits are *exact*, not merely sound
//!
//! The sink's output must be **byte-identical** to a full value-diff
//! scan of the same lane (the scalar [`VcdWriter`] contract: a change
//! line is emitted exactly when the masked value differs from the last
//! emitted one). Gating on activity masks preserves that because the
//! masks are *sufficient* covers of every possible change, and the
//! final emission test is still the writer's per-variable value diff:
//!
//! * **Group-written slots.** Every operation is a pure function of its
//!   operand slots. A clear bit in `active[g]` for lane `l` means no
//!   transitive boundary source of group `g` changed in `l`
//!   ([`crate::activity::ActivityTracker`]'s propagation invariant), so
//!   re-evaluating the group would recompute the *identical* values —
//!   the slot provably holds what a dense run would hold, and skipping
//!   the variable emits exactly what recording an unchanged value
//!   emits: nothing.
//! * **Registers.** `reg_changed[c]` is exact by construction: the
//!   commit loop compares the old register value against the committed
//!   one per lane and sets the bit only on an actual difference.
//! * **Input ports.** The per-port boundary masks are consumed when the
//!   cycle begins, so input variables are gated only by the whole-lane
//!   `changed` union (which includes them); within a visited lane every
//!   input variable is value-diffed. Input ports are few, so this costs
//!   near nothing.
//! * **Out-of-band pokes** (`poke_lane`) can change a slot with no
//!   active group and no commit bit — e.g. a poked self-holding
//!   register. The kernels report such lanes in `recheck`, and the sink
//!   falls back to the full value-diff scan there for one cycle.
//!
//! The union mask `changed` covers all four sources, so a clear lane
//! bit proves the *entire lane* is bit-identical to the previous cycle
//! and the sink returns before touching the slot file. Because
//! [`VcdWriter::record`] still value-diffs every visited variable,
//! over-approximation in the masks (a group that ran but recomputed the
//! same value) never produces a spurious change line — gating only
//! decides which variables are *looked at*, never what is *emitted*.
//! Byte-identity across dense/sparse × P × B is enforced by
//! `tests/wave_identity.rs`.
//!
//! Two attachment modes:
//!
//! * **Kernel mode** ([`WaveSink::attach`], [`WaveSink::sample_kernel`])
//!   — every named slot of one lane of a (dense or sparse) batched
//!   kernel, class-gated per variable as above. This is what
//!   `rteaal sim --lanes B --vcd [--wave-lanes ..]` drives.
//! * **Outputs mode** ([`WaveSink::attach_outputs`],
//!   [`WaveSink::sample_parallel`]) — the design's output ports of one
//!   lane of a partitioned [`BatchParallelSim`] (partition 0 computes
//!   all outputs), lane-gated by [`BatchParallelSim::wave_changed`].
//!   This is what `rteaal sim --parts P --vcd` and the service's `wave`
//!   verb drive; with `W = Vec<u8>` the accumulated bytes are drained
//!   incrementally by [`WaveSink::take_chunk`] (the `serve` streaming
//!   path).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use super::vcd::VcdWriter;
use crate::activity::GroupDepGraph;
use crate::coordinator::parallel::BatchParallelSim;
use crate::kernels::BatchKernel;
use crate::tensor::ir::LayerIr;

/// How one waveform variable's slot gets its value, deciding which
/// change mask gates it (see the module docs for the exactness
/// argument).
#[derive(Clone, Copy, Debug)]
enum VarClass {
    /// Testbench-written input port: gated by the whole-lane `changed`
    /// union only (per-port boundary bits are consumed at cycle begin).
    Input,
    /// Register slot: gated by `reg_changed[c]` (exact commit diff).
    Reg(usize),
    /// Combinational slot written by GDG group `g`: gated by
    /// `active[g]` (purity: not re-evaluated ⇒ identical).
    Group(u32),
    /// No writer at all (a lowered constant): can never change after
    /// the first dump.
    Const,
}

/// A per-lane delta-waveform sink over a lane-batched run. Generic over
/// the byte sink `W` like [`VcdWriter`]: a buffered file for the CLI, a
/// `Vec<u8>` chunk buffer for service streaming, in-memory buffers for
/// the byte-identity tests.
pub struct WaveSink<W: Write = BufWriter<File>> {
    vcd: VcdWriter<W>,
    lane: usize,
    /// slot of variable `i` — a borrow-free copy of the writer's var
    /// table, indexed in emission order
    slots: Vec<u32>,
    /// per-variable gating class; `None` when the kernel reports no
    /// change masks (dense executors) — every sample is a full
    /// value-diff scan then
    classes: Option<Vec<VarClass>>,
}

impl WaveSink<BufWriter<File>> {
    /// [`Self::attach`] writing to a file at `path`.
    pub fn create(
        ir: &LayerIr,
        kernel: &dyn BatchKernel,
        lane: usize,
        path: &Path,
    ) -> std::io::Result<Self> {
        Self::attach(ir, kernel, lane, BufWriter::new(File::create(path)?))
    }

    /// [`Self::attach_outputs`] writing to a file at `path`.
    pub fn create_outputs(ir: &LayerIr, lane: usize, path: &Path) -> std::io::Result<Self> {
        Self::attach_outputs(ir, lane, BufWriter::new(File::create(path)?))
    }
}

impl<W: Write> WaveSink<W> {
    /// Attach a sink for `lane` of `kernel` covering every named slot of
    /// the design. The kernel must be the one later passed to
    /// [`Self::sample_kernel`]: its change masks (if any) are used to
    /// classify each variable once, here.
    pub fn attach(
        ir: &LayerIr,
        kernel: &dyn BatchKernel,
        lane: usize,
        out: W,
    ) -> std::io::Result<Self> {
        assert!(
            lane < kernel.lanes(),
            "wave lane {lane} out of range (kernel has {} lanes)",
            kernel.lanes()
        );
        let vcd = VcdWriter::new(ir, out)?;
        let slots: Vec<u32> = vcd.vars().iter().map(|&(s, _, _)| s).collect();
        let classes = kernel.wave_masks().map(|m| classify(ir, m.gdg, &slots));
        Ok(WaveSink { vcd, lane, slots, classes })
    }

    /// Attach an outputs-only sink for one lane of a partitioned run
    /// (the design's output ports, in declaration order — matching
    /// [`VcdWriter::new_outputs`] and the scalar `--parts --vcd` path).
    pub fn attach_outputs(ir: &LayerIr, lane: usize, out: W) -> std::io::Result<Self> {
        let vcd = VcdWriter::new_outputs(ir, out)?;
        let slots: Vec<u32> = vcd.vars().iter().map(|&(s, _, _)| s).collect();
        Ok(WaveSink { vcd, lane, slots, classes: None })
    }

    /// The lane this sink observes.
    pub fn lane(&self) -> usize {
        self.lane
    }

    /// Emit the sample for time `cycle` from the kernel's post-`step`
    /// state. With change masks: a clear `changed` bit skips the lane in
    /// O(1); otherwise only the variables whose class mask is set in the
    /// lane are visited. Without masks (dense kernel), or in a `recheck`
    /// (poked) lane, every variable is value-diffed — still emitting
    /// byte-identical output, just without the skip.
    pub fn sample_kernel(&mut self, cycle: u64, kernel: &dyn BatchKernel) -> std::io::Result<()> {
        let lanes = kernel.lanes();
        let v = kernel.slots();
        let first = self.vcd.is_first();
        if !first {
            if let Some(m) = kernel.wave_masks() {
                let bit = 1u64 << self.lane;
                if m.changed & bit == 0 {
                    return Ok(()); // lane provably quiescent
                }
                if m.recheck & bit == 0 {
                    if let Some(classes) = &self.classes {
                        self.vcd.begin_sample(cycle);
                        for (i, &slot) in self.slots.iter().enumerate() {
                            let visit = match classes[i] {
                                VarClass::Input => true,
                                VarClass::Reg(c) => m.reg_changed[c] & bit != 0,
                                VarClass::Group(g) => m.active[g as usize] & bit != 0,
                                VarClass::Const => false,
                            };
                            if visit {
                                self.vcd.record(i, v[slot as usize * lanes + self.lane])?;
                            }
                        }
                        self.vcd.end_sample();
                        return Ok(());
                    }
                }
            }
        }
        // first sample, dense kernel, or poked (recheck) lane: full scan
        self.vcd.begin_sample(cycle);
        for (i, &slot) in self.slots.iter().enumerate() {
            self.vcd.record(i, v[slot as usize * lanes + self.lane])?;
        }
        self.vcd.end_sample();
        Ok(())
    }

    /// Emit the sample for time `cycle` from a partitioned run's
    /// post-`step` state (outputs mode). `buf` is a reusable
    /// name/value buffer (see
    /// [`BatchParallelSim::write_lane_outputs`]); it is only refreshed
    /// when the lane is actually visited.
    pub fn sample_parallel(
        &mut self,
        cycle: u64,
        sim: &BatchParallelSim,
        buf: &mut Vec<(String, u64)>,
    ) -> std::io::Result<()> {
        if !self.vcd.is_first() {
            if let Some(m) = sim.wave_changed() {
                if m & (1u64 << self.lane) == 0 {
                    return Ok(()); // lane provably quiescent
                }
            }
        }
        sim.write_lane_outputs(self.lane, buf);
        self.vcd.begin_sample(cycle);
        for i in 0..buf.len() {
            self.vcd.record(i, buf[i].1)?;
        }
        self.vcd.end_sample();
        Ok(())
    }

    /// Flush and drop the sink.
    pub fn finish(self) -> std::io::Result<()> {
        self.vcd.finish()
    }
}

impl WaveSink<Vec<u8>> {
    /// Drain the bytes accumulated since the last call — the service's
    /// incremental `wave` chunks. Concatenating every chunk reproduces
    /// the exact byte stream a file-backed sink would have written.
    pub fn take_chunk(&mut self) -> Vec<u8> {
        std::mem::take(self.vcd.writer_mut())
    }
}

/// Classify each variable's slot by how it gets written (the gating
/// class of the module docs). Priority matters only in that input and
/// register slots are never group outputs; a slot that is none of the
/// three is a lowered constant.
fn classify(ir: &LayerIr, gdg: &GroupDepGraph, slots: &[u32]) -> Vec<VarClass> {
    let inputs: std::collections::HashSet<u32> = ir.input_slots.iter().copied().collect();
    let reg_of: std::collections::HashMap<u32, usize> =
        ir.commits.iter().enumerate().map(|(c, &(reg, _, _))| (reg, c)).collect();
    slots
        .iter()
        .map(|&s| {
            if inputs.contains(&s) {
                VarClass::Input
            } else if let Some(&c) = reg_of.get(&s) {
                VarClass::Reg(c)
            } else if let Some(g) = gdg.writer_of(s) {
                VarClass::Group(g)
            } else {
                VarClass::Const
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::designs::catalog;
    use crate::graph::passes::optimize;
    use crate::kernels::{build_batch, build_sparse, KernelConfig};
    use crate::tensor::ir::lower;
    use crate::tensor::oim::Oim;

    /// In-module smoke test (the full dense/sparse × P × B byte-identity
    /// grid lives in `tests/wave_identity.rs`): a sparse kernel's sink
    /// output equals a dense kernel's full-diff sink output on the same
    /// stimulus, and a frozen run's tail emits zero bytes.
    #[test]
    fn sparse_sink_matches_dense_and_skips_quiescent_tail() {
        let d = catalog("fir8").unwrap();
        let (opt, _) = optimize(&d.graph);
        let ir = lower(&opt);
        let oim = Oim::from_ir(&ir);
        let lanes = 4usize;
        let mut dense = build_batch(KernelConfig::PSU, &ir, &oim, lanes);
        let mut sparse = build_sparse(KernelConfig::PSU, &ir, &oim, lanes);
        let mut sink_d = WaveSink::attach(&ir, dense.as_ref(), 2, Vec::new()).unwrap();
        let mut sink_s = WaveSink::attach(&ir, sparse.as_ref(), 2, Vec::new()).unwrap();
        assert!(sink_d.classes.is_none(), "dense kernels report no masks");
        assert!(sink_s.classes.is_some(), "sparse kernels classify vars");
        let mut stim = d.make_lane_stimulus(lanes);
        let mut frozen = Vec::new();
        for c in 0..20u64 {
            let inputs = stim(c);
            dense.step(&inputs);
            sparse.step(&inputs);
            sink_d.sample_kernel(c, dense.as_ref()).unwrap();
            sink_s.sample_kernel(c, sparse.as_ref()).unwrap();
            frozen = inputs;
        }
        // freeze: repeat the last stimulus. Once the pipeline has
        // drained, the sparse sink must emit nothing at all.
        let mut mark = 0usize;
        for c in 20..48u64 {
            dense.step(&frozen);
            sparse.step(&frozen);
            sink_d.sample_kernel(c, dense.as_ref()).unwrap();
            if c == 40 {
                mark = sink_s.vcd.writer_mut().len();
            }
            sink_s.sample_kernel(c, sparse.as_ref()).unwrap();
        }
        assert_eq!(
            sink_s.vcd.writer_mut().len(),
            mark,
            "frozen tail must cost zero waveform bytes"
        );
        let a = sink_d.vcd.writer_mut().clone();
        let b = sink_s.vcd.writer_mut().clone();
        assert!(!a.is_empty());
        assert_eq!(
            String::from_utf8_lossy(&a),
            String::from_utf8_lossy(&b),
            "sparse mask-gated sink must be byte-identical to the dense full-diff sink"
        );
    }

    /// A mid-run poke lands in the stream exactly as a dense full-diff
    /// sees it (the `recheck` fallback): poke a register in one lane,
    /// step, and the sparse sink still matches the dense sink.
    #[test]
    fn poked_lane_falls_back_to_full_diff() {
        let d = catalog("fir8").unwrap();
        let (opt, _) = optimize(&d.graph);
        let ir = lower(&opt);
        let oim = Oim::from_ir(&ir);
        let lanes = 4usize;
        let lane = 1usize;
        let mut dense = build_batch(KernelConfig::TI, &ir, &oim, lanes);
        let mut sparse = build_sparse(KernelConfig::TI, &ir, &oim, lanes);
        let mut sink_d = WaveSink::attach(&ir, dense.as_ref(), lane, Vec::new()).unwrap();
        let mut sink_s = WaveSink::attach(&ir, sparse.as_ref(), lane, Vec::new()).unwrap();
        let mut stim = d.make_lane_stimulus(lanes);
        let frozen = stim(0);
        for c in 0..6u64 {
            dense.step(&frozen);
            sparse.step(&frozen);
            sink_d.sample_kernel(c, dense.as_ref()).unwrap();
            sink_s.sample_kernel(c, sparse.as_ref()).unwrap();
        }
        let (reg, _, m) = ir.commits[0];
        let poked = (sparse.slots()[reg as usize * lanes + lane] ^ 1) & m;
        dense.poke_lane(reg, lane, poked);
        sparse.poke_lane(reg, lane, poked);
        for c in 6..12u64 {
            dense.step(&frozen);
            sparse.step(&frozen);
            sink_d.sample_kernel(c, dense.as_ref()).unwrap();
            sink_s.sample_kernel(c, sparse.as_ref()).unwrap();
        }
        assert_eq!(
            String::from_utf8_lossy(sink_d.vcd.writer_mut()),
            String::from_utf8_lossy(sink_s.vcd.writer_mut()),
            "poke must surface identically through the recheck fallback"
        );
    }
}
